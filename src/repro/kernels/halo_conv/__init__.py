from .halo_conv import halo_conv2d
from .ops import conv2d_spatial_pallas
from .ref import halo_conv2d_ref
