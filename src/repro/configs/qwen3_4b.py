"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936,
qk_norm, head_dim=128.  [hf:Qwen/Qwen3-8B family; hf]"""
from ..models import transformer_lm as lm
from ..models.transformer_lm import LMConfig
from .base import Arch, lm_cells, register

FULL = LMConfig(
    name="qwen3-4b",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
)

SMOKE = LMConfig(
    name="qwen3-4b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    qk_norm=True,
)

ARCH = register(
    Arch(
        name="qwen3-4b",
        family="lm",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=lm_cells(full_attention=True),
        module=lm,
        notes="dense GQA with qk-norm; HALP spatial partitioning inapplicable "
        "(unbounded receptive field) -- runs DP/TP, see DESIGN.md §4",
    )
)
