"""Pallas TPU kernel: direct 2-D convolution as MXU matmuls.

The paper's compute hot-spot is the conv layer; on TPU the idiomatic form is a
*direct* conv over VMEM-resident row tiles, where each (ky, kx) kernel tap is
one [TILE_H * W, C_in] x [C_in, C_out_tile] matmul on the MXU (an implicit
im2col that never materialises the patch matrix in HBM).

Tiling: the wrapper (ops.py) pre-builds overlapping row tiles -- the explicit
halo materialisation mirrors HALP's boundary rows -- so the kernel sees clean,
non-overlapping BlockSpec blocks:

    x_tiles [N, nT, (TH-1)*s + k, W_ext, C_in] -> block (1, 1, ..., Cin)
    weights [k, k, C_in, C_out]                -> block (k, k, Cin, TC)
    out     [N, nT, TH, W_out, C_out]          -> block (1, 1, TH, W_out, TC)

Grid: (N, nT, C_out / TC).  The wrapper picks TH so the per-step working set
stays <= ~8 MB of VMEM.

Generality (the spatial fast path needs all of it -- see ISSUE/ROADMAP 5):

* ``stride`` > 1: each tap gathers a strided patch from the row tile, so
  every VGG-16 / ConvNeXt stem+downsample conv lowers to the same kernel;
* depthwise convs (``groups == C_in == C_out``, weights [k, k, 1, C]): the
  tap matmul degenerates to a VPU multiply-accumulate over the channel axis;
* ragged row counts: tiles may overhang the tensor -- the wrapper pads the
  overhang with zeros and slices the surplus output rows off, so tile heights
  no longer need to divide the output height (remainder rows were previously
  *dropped silently*; see tests/test_kernels.py regression pins).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int, th: int, w_out: int,
                 stride: int, depthwise: bool):
    """One (batch, row-tile, cout-tile) grid step."""
    cin = x_ref.shape[-1]
    tc = o_ref.shape[-1]
    s = stride
    blk = x_ref[0, 0]  # [(TH-1)*s + k, W_ext, Cin]
    if depthwise:
        acc = jnp.zeros((th, w_out, tc), jnp.float32)
    else:
        acc = jnp.zeros((th * w_out, tc), jnp.float32)
    for ky in range(k):
        for kx in range(k):
            # [TH, W_out, Cin] patch for this tap (strided when s > 1)
            patch = blk[
                ky : ky + (th - 1) * s + 1 : s,
                kx : kx + (w_out - 1) * s + 1 : s,
                :,
            ].astype(jnp.float32)
            if depthwise:
                # one input channel per output channel: a VPU mul-add, no MXU
                acc += patch * w_ref[ky, kx, 0, :].astype(jnp.float32)
            else:
                taps = w_ref[ky, kx, :, :]  # [Cin, TC]
                acc += jnp.dot(
                    patch.reshape(th * w_out, cin),
                    taps.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
    o_ref[0, 0] = acc.reshape(th, w_out, tc).astype(o_ref.dtype)


def conv2d_tiles(
    x_tiles: jax.Array,  # [N, nT, (TH-1)*stride + k, W_ext, Cin]
    weights: jax.Array,  # [k, k, Cin, Cout] ([k, k, 1, C] depthwise)
    *,
    k: int,
    tile_h: int,
    cout_tile: int,
    stride: int = 1,
    groups: int = 1,
    interpret: bool = False,
) -> jax.Array:
    n, nt, th_ext, w_ext, cin = x_tiles.shape
    cout = weights.shape[-1]
    w_out = (w_ext - k) // stride + 1
    assert th_ext == (tile_h - 1) * stride + k, (th_ext, tile_h, stride, k)
    assert cout % cout_tile == 0
    depthwise = groups > 1
    if depthwise:
        if not (groups == cin == cout and weights.shape[2] == 1):
            raise ValueError(
                f"grouped conv supported only for depthwise (groups == Cin == "
                f"Cout); got groups={groups} Cin={cin} Cout={cout}"
            )
        # the tap product is per-channel, so the channel tile must carry the
        # matching input channels -- keep the whole axis in one block
        cout_tile = cout

    kernel = functools.partial(
        _conv_kernel, k=k, th=tile_h, w_out=w_out, stride=stride,
        depthwise=depthwise,
    )
    return pl.pallas_call(
        kernel,
        grid=(n, nt, cout // cout_tile),
        in_specs=[
            pl.BlockSpec(
                (1, 1, th_ext, w_ext, cin), lambda b, t, c: (b, t, 0, 0, 0)
            ),
            pl.BlockSpec(
                (k, k, weights.shape[2], cout_tile), lambda b, t, c: (0, 0, 0, c)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, tile_h, w_out, cout_tile), lambda b, t, c: (b, t, 0, 0, c)
        ),
        out_shape=jax.ShapeDtypeStruct((n, nt, tile_h, w_out, cout), x_tiles.dtype),
        interpret=interpret,
    )(x_tiles, weights)
