"""Per-stage partitioning-scheme search vs halo-only planning.

The claim under test (ROADMAP direction 4, this PR's tentpole): enlarging the
planner's per-stage search space from {halo_segment} to {halo_segment,
non_penetrative, head_sequence} never hurts -- the joint search is seeded at
the halo-only optimum's ratios and the halo-first baseline assignment, so the
searched makespan is bounded by the halo-only one on every cell -- and pays
off decisively where row/halo partitioning cannot apply at all: attention
models, whose attn stages the halo-only planner must leave on the host
(``host_solo``), collapse onto head-split stages priced in the same
rate-independent DES sweep.

Grid: {VGG-16, ViT-L/16} x {symmetric, skewed} 3-ES AGX-Xavier clusters.  Per
cell we record both plans' makespans, the searched per-stage scheme
assignment, and per-stage link bytes (``comm_bytes_per_stage``) -- the
non-penetrative/head-split stages *buy* their compute spread with
redistribution traffic, and the table makes that trade explicit.

Emits ``BENCH_schemes.json`` (``--out`` to move it, ``--smoke`` for the
CI-sized nets).  Acceptance: ``tests/test_benchmarks.py::
test_scheme_sweep_acceptance`` pins searched <= halo-only on every cell and
a >= 10% reduction on at least one; ``test_scheme_bench_artifact_floors``
pins the committed full-run artifact.  CSV rows
(``name,us_per_call,derived``) match the other benchmarks' format.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (  # noqa: E402
    AGX_XAVIER,
    SCHEME_HALO,
    SCHEMES,
    CollabTopology,
    Link,
    comm_bytes_per_stage,
    optimize_plan,
    stage_spans,
    vgg16_geom,
    vit_l16_geom,
)

# Heterogeneity of the skewed cell: platform scales and alternating link rates
# (the regime where ratio search matters most; mirrors tests/test_conformance).
SKEW_SCALES = (1.0, 0.6, 0.35)


def sym_topology() -> CollabTopology:
    return CollabTopology.symmetric(AGX_XAVIER, Link(40e9), n_secondaries=3)


def skew_topology() -> CollabTopology:
    secs = ("e1", "e2", "e3")
    platforms = {"e0": AGX_XAVIER}
    links = {}
    for j, (s, scale) in enumerate(zip(secs, SKEW_SCALES)):
        platforms[s] = AGX_XAVIER.scaled(scale, f"es x{scale:g}")
        rate = 10e9 if j % 2 else 40e9
        links[("e0", s)] = Link(rate)
        links[(s, "e0")] = Link(rate)
    return CollabTopology(
        host="e0", secondaries=secs, platforms=platforms,
        links=links, default_link=Link(40e9),
    )


def bench_nets(smoke: bool) -> dict:
    if smoke:
        return {
            "vgg16": vgg16_geom(in_rows=64),
            "vit_l16": vit_l16_geom(in_rows=64, n_blocks=2),
        }
    return {"vgg16": vgg16_geom(), "vit_l16": vit_l16_geom()}


def _result_record(res, plan_elapsed_s: float) -> dict:
    return dict(
        makespan=res.makespan,
        ratios=list(res.ratios),
        overlap_rows=res.overlap_rows,
        assignment=list(res.schemes) if res.schemes is not None else None,
        evaluations=res.evaluations,
        comm_bytes_per_stage=comm_bytes_per_stage(res.plan),
        elapsed_s=plan_elapsed_s,
    )


def run_cell(net, topology, max_rounds: int = 4) -> dict:
    """One grid cell: halo-only optimum, then the joint scheme search seeded
    at its ratios (so the enlarged space can only match or improve)."""
    t0 = time.perf_counter()
    halo = optimize_plan(net, topology, schemes=(SCHEME_HALO,), max_rounds=max_rounds)
    t_halo = time.perf_counter() - t0
    t0 = time.perf_counter()
    searched = optimize_plan(
        net, topology, schemes=SCHEMES,
        init_ratios=halo.ratios, max_rounds=max_rounds,
    )
    t_search = time.perf_counter() - t0
    return dict(
        halo_only=_result_record(halo, t_halo),
        searched=_result_record(searched, t_search),
        reduction=1.0 - searched.makespan / halo.makespan,
    )


def run_all(smoke: bool = False, out_path: str | None = "BENCH_schemes.json") -> dict:
    nets = bench_nets(smoke)
    cells: dict[str, dict] = {}
    for net_name, net in nets.items():
        for topo_name, topo in (("sym", sym_topology()), ("skew", skew_topology())):
            cells[f"{net_name}/{topo_name}"] = run_cell(net, topo)
    reductions = {k: c["reduction"] for k, c in cells.items()}
    out = dict(
        smoke=smoke,
        nets={
            name: dict(
                in_rows=net.in_rows,
                n_layers=len(net.layers),
                n_stages=len(stage_spans(net)),
            )
            for name, net in nets.items()
        },
        cells=cells,
        min_reduction=min(reductions.values()),
        max_reduction=max(reductions.values()),
    )

    print(f"{'cell':16s} {'halo-only (ms)':>14s} {'searched (ms)':>13s} "
          f"{'reduction':>9s}  assignment")
    for key, cell in cells.items():
        a = cell["searched"]["assignment"]
        short = "all-halo" if a is None else ",".join(s[:4] for s in a[:6]) + (
            ",..." if len(a) > 6 else "")
        print(
            f"{key:16s} {cell['halo_only']['makespan']*1e3:14.3f} "
            f"{cell['searched']['makespan']*1e3:13.3f} "
            f"{cell['reduction']:8.1%}  {short}"
        )
        print(f"scheme_sweep_{key.replace('/', '_')},"
              f"{cell['searched']['makespan']*1e6:.1f},{cell['reduction']:.4f}")
    print(f"\nreduction range: {out['min_reduction']:.1%} .. "
          f"{out['max_reduction']:.1%}")
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)
        print(f"wrote {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized nets")
    ap.add_argument("--out", default="BENCH_schemes.json")
    args = ap.parse_args()
    run_all(smoke=args.smoke, out_path=args.out)
