"""jit'd wrapper for the Pallas direct-conv kernel: padding, halo-tile
construction (the HALP boundary rows, materialised), VMEM budget heuristics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .conv2d import conv2d_tiles

VMEM_BUDGET = 8 * 1024 * 1024  # bytes per grid step we allow ourselves


def _pick_cout_tile(cout: int) -> int:
    """Largest divisor of ``cout`` that fits one MXU lane tile (<= 128)."""
    for tc in range(min(cout, 128), 0, -1):
        if cout % tc == 0:
            return tc
    return 1  # pragma: no cover - range above always yields a divisor


def _pick_tile_h(
    h: int, w_ext: int, cin: int, cout: int, k: int, itemsize: int, stride: int = 1
):
    """Largest tile height (output rows) whose working set fits the VMEM
    budget.  Tiles need not divide ``h``: the kernel wrappers zero-pad the
    final (remainder) tile and slice the surplus rows off, so a prime-height
    shard no longer collapses to 1-row tiles (nor -- worse -- silently loses
    its remainder rows; see tests/test_kernels.py)."""
    for th in (64, 32, 16, 8, 4, 2, 1):
        if th > max(1, h):
            continue
        tc = _pick_cout_tile(cout)
        need = (
            ((th - 1) * stride + k) * w_ext * cin
            + k * k * cin * tc
            + th * ((w_ext - k) // stride + 1) * tc
        ) * max(itemsize, 4)
        if need <= VMEM_BUDGET:
            return th
    return 1


def _tile_rows(x: jax.Array, n_out: int, th: int, k: int, stride: int) -> jax.Array:
    """Stack overlapping row tiles: tile t covers output rows [t*th, t*th+th),
    i.e. input rows [t*th*s, t*th*s + (th-1)*s + k).  The input is zero-padded
    at the bottom so the last tile may overhang (remainder handling)."""
    nt = -(-n_out // th)  # ceil
    tile_ext = (th - 1) * stride + k
    need_rows = (nt - 1) * th * stride + tile_ext
    if need_rows > x.shape[1]:
        x = jnp.pad(x, ((0, 0), (0, need_rows - x.shape[1]), (0, 0), (0, 0)))
    idx = (jnp.arange(nt) * th * stride)[:, None] + jnp.arange(tile_ext)[None]
    return x[:, idx]  # [N, nT, tile_ext, W_ext, Cin]


def conv2d_pallas(
    x: jax.Array,  # [N, H, W, Cin]  (NHWC)
    weights: jax.Array,  # [k, k, Cin, Cout]
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: int = 1,
    groups: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """SAME/VALID conv via the Pallas kernel (k = weights.shape[0])."""
    k = weights.shape[0]
    n, h, w, cin = x.shape
    cout = weights.shape[-1]
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    h_eff = (x.shape[1] - k) // stride + 1  # output rows
    w_ext = x.shape[2]
    th = _pick_tile_h(h_eff, w_ext, cin, cout, k, x.dtype.itemsize, stride)
    x_tiles = _tile_rows(x, h_eff, th, k, stride)
    nt = x_tiles.shape[1]
    y = conv2d_tiles(
        x_tiles, weights, k=k, tile_h=th, cout_tile=_pick_cout_tile(cout),
        stride=stride, groups=groups, interpret=interpret,
    )
    y = y.reshape(n, nt * th, (w_ext - k) // stride + 1, cout)[:, :h_eff]
    if bias is not None:
        y = y + bias
    return y
