"""The paper's contribution: receptive-field-exact partitioning (rf, partition),
HALP / MoDNN scheduling over arbitrary collaboration topologies (topology,
schedule), one shared event topology feeding both latency engines (events),
exact event simulation (simulator), plan-knob search (optimizer), the
service-reliability model (reliability), online joint compute+link adaptive
re-planning with a plan cache (replan), a persistent content-keyed plan store
for warm starts across restarts (planstore), and per-task heterogeneous
placement over a shared ES pool (placement)."""
from .nets import ConvNetGeom, vgg16_geom, vit_l16_geom
from .optimizer import (
    OptimizeResult,
    equal_ratios,
    evaluate_plan,
    evaluate_scheme_assignment,
    optimize_plan,
)
from .partition import (
    HALPPlan,
    PlanInfeasible,
    SCHEME_HALO,
    SCHEME_HOST,
    SCHEME_HS,
    SCHEME_NP,
    SCHEMES,
    SchemePlan,
    SchemeSegment,
    Segment,
    baseline_assignment,
    comm_bytes_per_stage,
    plan_even,
    plan_halp,
    plan_halp_n,
    plan_halp_topology,
    plan_scheme,
    split_rows,
    stage_scheme_options,
    stage_spans,
)
from .placement import (
    PlacementController,
    PlacementResult,
    TaskPlacement,
    place_tasks,
    shared_plan_placement,
    simulate_placement,
)
from .reliability import (
    OffloadChannel,
    probit,
    rate_fluctuation,
    required_slack,
    service_reliability,
)
from .planstore import PLAN_SCHEMA_VERSION, PlanStore, canonical_key, key_hash
from .replan import (
    ComputeRateEstimator,
    LinkRateEstimator,
    PlanCache,
    ReplanConfig,
    ReplanController,
    StaticPlanner,
    bucket_rate,
    compute_band_flops,
    compute_bucket,
    optimize_static,
    rate_bucket,
    topology_fingerprint,
)
from .events import SchemeBatchEvaluator, build_scheme_dag, simulate_scheme
from .rf import (
    LayerGeom,
    RFState,
    attn,
    input_range_exact,
    input_range_paper,
    out_size,
    propagate_range,
    rf_chain,
)
from .schedule import (
    AGX_XAVIER,
    GTX_1080TI,
    TPU_V5E,
    halp_closed_form,
    modnn_time,
    speedup_ratio,
    standalone_time,
)
from .simulator import (
    GaussMarkovTrace,
    Sim,
    enhanced_modnn_delay,
    replay_rate_trace,
    replay_trace,
    serve_latency_table,
    simulate_halp,
    simulate_modnn,
)
from .topology import CollabTopology, Link, Platform
