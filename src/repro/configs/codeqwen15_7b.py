"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (MHA kv=32) d_ff=13440
vocab=92416.  [hf:Qwen/CodeQwen1.5-7B; hf]"""
from ..models import transformer_lm as lm
from ..models.transformer_lm import LMConfig
from .base import Arch, lm_cells, register

FULL = LMConfig(
    name="codeqwen1.5-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=13440,
    vocab=92416,
    rope_theta=1e6,
)

SMOKE = LMConfig(
    name="codeqwen1.5-7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=160,
    vocab=512,
)

ARCH = register(
    Arch(
        name="codeqwen1.5-7b",
        family="lm",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=lm_cells(full_attention=True),
        module=lm,
        notes="dense MHA; qwen1.5 arch",
    )
)
