"""§Perf helper: diff roofline terms between a baseline record and a variant.

    PYTHONPATH=src python benchmarks/perf_compare.py deepseek-v3-671b train_4k opt
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.roofline import RESULTS, terms


def load(arch, cell, mesh, variant=None):
    suffix = f"__{variant}" if variant and variant != "base" else ""
    f = RESULTS / f"{arch}__{cell}__{mesh}{suffix}.json"
    return json.loads(f.read_text())


def compare(arch, cell, variant, mesh="pod16x16"):
    base = load(arch, cell, mesh)
    var = load(arch, cell, mesh, variant)
    tb, tv = terms(base), terms(var)
    print(f"== {arch}/{cell}/{mesh}: base -> {variant} ==")
    for k in ("compute_s", "memory_s", "collective_s"):
        b, v = tb[k], tv[k]
        delta = (v - b) / b * 100 if b else float("inf")
        print(f"  {k:14s} {b:10.4f} -> {v:10.4f}   ({delta:+7.1f}%)")
    print(f"  bottleneck     {tb['bottleneck']:>10s} -> {tv['bottleneck']:>10s}")
    print(f"  roofline_frac  {tb['roofline_frac']:10.4f} -> {tv['roofline_frac']:10.4f}")
    bound_b = max(tb["compute_s"], tb["memory_s"], tb["collective_s"])
    bound_v = max(tv["compute_s"], tv["memory_s"], tv["collective_s"])
    print(f"  bound time     {bound_b:10.4f} -> {bound_v:10.4f}  ({bound_b/bound_v:6.2f}x faster)")
    return tb, tv


if __name__ == "__main__":
    compare(*sys.argv[1:4])
