"""Spatial-parallelism tests: losslessness of the paper's partitioning in JAX.

Single-device semantic checks run in-process; the SPMD shard_map checks run in
a subprocess with 8 forced host devices (this process keeps the default single
CPU device, as the dry-run instructions require).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan_even, plan_halp
from repro.models import vgg
from repro.spatial import halo_sizes, run_plan

CFG = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10)


@pytest.fixture(scope="module")
def vgg_setup():
    params = vgg.init(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
    ref = vgg.features(params, CFG, x)
    return params, x, ref


def test_halp_plan_lossless(vgg_setup):
    """Paper §II claim: receptive-field partitioning does not change the output.

    The plan executor reconstructs every segment's input strictly from owned
    rows + the plan's messages, so this also proves eqs. (10)-(14) suffice."""
    params, x, ref = vgg_setup
    plan = plan_halp(CFG.geom(), overlap_rows=4)
    out = run_plan(plan, params["features"], vgg.apply_layer, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n", [2, 4, 8])
def test_even_plan_lossless(vgg_setup, n):
    params, x, ref = vgg_setup
    plan = plan_even(CFG.geom(), n)
    out = run_plan(plan, params["features"], vgg.apply_layer, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "ratios",
    [
        (0.7, 0.3),
        (0.5, 0.3, 0.2),
        (4.0, 2.0, 1.0, 1.0),  # un-normalised capacity weights
    ],
)
def test_weighted_even_plan_lossless(vgg_setup, ratios):
    """Capacity-weighted splits for heterogeneous pods (a pod mixing device
    generations wants row shares proportional to per-device FLOP/s) must stay
    bit-compatible with single-device inference -- the same executable
    backstop that pins the uniform split."""
    params, x, ref = vgg_setup
    plan = plan_even(CFG.geom(), len(ratios), ratios=ratios)
    norm = [r / sum(ratios) for r in ratios]
    # the weighting actually takes effect: first worker's share ~ its ratio
    rows0 = plan.parts[0].out["w0"].rows
    total0 = sum(plan.parts[0].out[es].rows for es in plan.es_names)
    assert abs(rows0 / total0 - norm[0]) < 0.1
    out = run_plan(plan, params["features"], vgg.apply_layer, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_weighted_even_plan_rejects_bad_ratios():
    import pytest as _pytest

    with _pytest.raises(ValueError, match="one ratio per worker"):
        plan_even(CFG.geom(), 3, ratios=(0.5, 0.5))
    with _pytest.raises(ValueError, match="non-negative"):
        plan_even(CFG.geom(), 2, ratios=(1.0, -0.5))


def test_halp_plan_lossless_other_overlaps(vgg_setup):
    params, x, ref = vgg_setup
    for w in (2, 6, 8):
        plan = plan_halp(CFG.geom(), overlap_rows=w)
        out = run_plan(plan, params["features"], vgg.apply_layer, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "secs,ratios",
    [
        (("e1", "e2", "e3"), None),
        (("e1", "e2", "e3"), (0.5, 0.3, 0.2)),
        (("fast", "slow"), (0.72, 0.28)),
        (("a", "b", "c", "d"), (0.4, 0.3, 0.2, 0.1)),
    ],
)
def test_nway_heterogeneous_plan_lossless(vgg_setup, secs, ratios):
    """The executable-losslessness backstop for the N-way refactor: capacity-
    weighted heterogeneous plans (multiple host zones, skewed segments) run
    through the same executor and still match single-device inference."""
    from repro.core.partition import plan_halp_n

    params, x, ref = vgg_setup
    plan = plan_halp_n(CFG.geom(), secondaries=secs, ratios=ratios, overlap_rows=4)
    out = run_plan(plan, params["features"], vgg.apply_layer, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_optimizer_chosen_plan_lossless(vgg_setup):
    """Whatever plan the optimizer proposes must execute losslessly."""
    from repro.core import CollabTopology, GTX_1080TI, Link, optimize_plan

    params, x, ref = vgg_setup
    slow = GTX_1080TI.scaled(0.4, "slow")
    topo = CollabTopology(
        host="e0",
        secondaries=("fast", "slow"),
        platforms={"e0": GTX_1080TI, "fast": GTX_1080TI, "slow": slow},
        default_link=Link(10e9),
    )
    res = optimize_plan(CFG.geom(), topo, overlap_choices=(2, 4), max_rounds=3)
    out = run_plan(res.plan, params["features"], vgg.apply_layer, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_halo_sizes():
    assert halo_sizes(3, 1, 1) == (1, 1)
    assert halo_sizes(1, 1, 0) == (0, 0)
    assert halo_sizes(2, 2, 0) == (0, 0)  # aligned pool: no halo
    assert halo_sizes(7, 2, 3) == (3, 2)
    assert halo_sizes(5, 1, 2) == (2, 2)
    assert halo_sizes(7, 1, 3) == (3, 3)  # ConvNeXt depthwise


def test_exchange_halos_rejects_thin_shards():
    """A halo larger than the shard height would need rows from two shards
    away; ``x[:, -lo:]`` silently truncated to whatever the shard held,
    shipping wrong rows.  It must raise instead -- the geometry check runs
    before any collective, so it is testable without a mesh."""
    from repro.spatial import conv2d_spatial, exchange_halos
    from repro.models.common import conv_params

    x = jnp.zeros((1, 2, 8, 3))  # 2-row shard
    with pytest.raises(ValueError, match="halo exceeds shard height"):
        exchange_halos(x, 3, 0, "sp")  # lo > Hs
    with pytest.raises(ValueError, match="halo exceeds shard height"):
        exchange_halos(x, 0, 3, "sp")  # hi > Hs
    # boundary: a halo of exactly the shard height is legal (whole-shard
    # donation) -- the geometry check must not reject it
    from repro.spatial.halo import _check_halo_fits

    _check_halo_fits(2, 2, 2)  # no raise
    # the overlapped HALP schedule path validates too (its own ppermutes
    # slice x[:, -lo:] the same way): 7x7 conv on a 2-row shard needs lo=hi=3
    params = conv_params(jax.random.PRNGKey(0), 7, 3, 4)
    with pytest.raises(ValueError, match="halo exceeds shard height"):
        conv2d_spatial(x, params, k=7, s=1, p=3, overlap=True)


def test_shard_heights_weighted_split():
    from repro.spatial import shard_heights

    # equal default, exact
    assert shard_heights(64, 4) == (16, 16, 16, 16)
    # capacity-weighted, stride-aligned, sums preserved
    hts = shard_heights(64, 4, ratios=(1.0, 0.55, 0.35, 0.8), align=8)
    assert sum(hts) == 64 and all(h % 8 == 0 for h in hts)
    assert max(hts) > min(hts) >= 8  # genuinely skewed, every shard non-empty
    # heavier ratio never gets fewer rows
    hts2 = shard_heights(60, 3, ratios=(3, 2, 1), align=2)
    assert sum(hts2) == 60 and hts2[0] >= hts2[1] >= hts2[2]
    with pytest.raises(ValueError, match="not divisible"):
        shard_heights(62, 4, align=4)
    with pytest.raises(ValueError, match="at least"):
        shard_heights(16, 5, align=8)  # 2 units cannot feed 5 shards
    with pytest.raises(ValueError, match="one ratio per shard"):
        shard_heights(64, 4, ratios=(1, 2))
    with pytest.raises(ValueError, match="non-negative"):
        shard_heights(64, 2, ratios=(-1, 2))


def test_padded_shard_layout_roundtrip():
    from repro.spatial import merge_padded_shards, to_padded_shards

    hts = (12, 8, 4, 8)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 5, 3))
    xp = to_padded_shards(x, hts)
    assert xp.shape == (2, 4 * 12, 5, 3)
    # invariant: rows past each shard's valid height are zero
    for j, h in enumerate(hts):
        blk = np.asarray(xp[:, j * 12 : (j + 1) * 12])
        np.testing.assert_array_equal(blk[:, h:], 0.0)
        np.testing.assert_array_equal(blk[:, :h], np.asarray(x[:, sum(hts[:j]) : sum(hts[:j]) + h]))
    np.testing.assert_array_equal(np.asarray(merge_padded_shards(xp, hts)), np.asarray(x))
    with pytest.raises(ValueError, match="sum of shard heights"):
        to_padded_shards(x, (12, 8, 4, 4))
    with pytest.raises(ValueError, match="blocks of"):
        merge_padded_shards(xp[:, :-1], hts)


def test_plan_shard_heights_consumes_weighted_plan():
    """The spatial engine consumes plan_even(ratios=...): the plan's
    first-layer row shares become the deployment's shard heights, re-quantised
    to the net's stride alignment."""
    from repro.spatial import plan_shard_heights, shard_heights, spatial_alignment

    net = CFG.geom()
    align = spatial_alignment(net)
    assert align == 32  # five 2x2 pools
    net3 = vgg.VGGConfig(
        img_res=64, width_mult=0.125, num_classes=10,
        blocks=((2, 64), (2, 128), (3, 256)),
    ).geom()
    align3 = spatial_alignment(net3)
    assert align3 == 8
    plan = plan_even(net3, 4, ratios=(4.0, 2.0, 1.0, 1.0))
    hts = plan_shard_heights(plan, align=align3)
    assert sum(hts) == net3.in_rows and all(h % align3 == 0 for h in hts)
    assert hts[0] >= hts[1] >= hts[2]  # follows the plan's capacity weighting
    # equal plan degenerates to the equal split
    assert plan_shard_heights(plan_even(net3, 4), align=align3) == (16, 16, 16, 16)
    # and the ratios round-trip through the same quantiser
    assert hts == shard_heights(net3.in_rows, 4, ratios=[
        plan.parts[0].out[es].rows for es in plan.es_names], align=align3)


def test_weighted_conv_rejects_bad_heights():
    from repro.models.common import conv_params
    from repro.spatial import conv2d_spatial

    params = conv_params(jax.random.PRNGKey(0), 3, 3, 4)
    x = jnp.zeros((1, 8, 8, 3))
    with pytest.raises(ValueError, match="not all divisible by stride"):
        conv2d_spatial(x, params, k=3, s=2, p=1, heights=(8, 7, 8, 8))
    with pytest.raises(ValueError, match="halo exceeds shard height"):
        conv2d_spatial(x, params, k=7, s=1, p=3, heights=(8, 2, 8, 8))
    with pytest.raises(ValueError, match="positive"):
        conv2d_spatial(x, params, k=3, s=1, p=1, heights=(8, 0, 8, 8))


def test_run_plan_time_observer_attribution(vgg_setup):
    """Zero-config serve-side timing attribution: run_plan emits one
    (es, flops, elapsed) sample per ES whose FLOP counts match the plan's
    exact row algebra, and the samples round-trip through
    ComputeRateEstimator.observe_samples."""
    from repro.core.replan import ComputeRateEstimator

    params, x, ref = vgg_setup
    net = CFG.geom()
    plan = plan_even(net, 3, ratios=(0.5, 0.3, 0.2))
    samples = []
    out = run_plan(plan, params["features"], vgg.apply_layer, x,
                   time_observer=lambda es, fl, dt: samples.append((es, fl, dt)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
    assert sorted(es for es, _, _ in samples) == sorted(plan.es_names)
    for es, fl, dt in samples:
        want_fl = sum(
            net.layer_flops(i, plan.parts[i].out[es].rows)
            for i in range(len(net.layers)) if plan.parts[i].out[es]
        )
        assert fl == pytest.approx(want_fl)  # exact row algebra, not a guess
        assert dt > 0
    est = ComputeRateEstimator({es: 1e9 for es in plan.es_names})
    rates = est.observe_samples(samples)
    for es, fl, dt in samples:
        assert rates[es] == pytest.approx(est.rate(es))
        assert est.rate(es) > 0


def _find_jaxpr_with(jaxpr, prim_name):
    """Innermost (sub-)jaxpr whose own eqn list contains ``prim_name``."""
    if any(e.primitive.name == prim_name for e in jaxpr.eqns):
        return jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", v if hasattr(v, "eqns") else None)
            if inner is not None and hasattr(inner, "eqns"):
                found = _find_jaxpr_with(inner, prim_name)
                if found is not None:
                    return found
    return None


def _contains_pallas(eqn):
    if eqn.primitive.name == "pallas_call":
        return True
    for v in eqn.params.values():
        inner = getattr(v, "jaxpr", v if hasattr(v, "eqns") else None)
        if inner is not None and hasattr(inner, "eqns"):
            if any(_contains_pallas(e) for e in inner.eqns):
                return True
    return False


def test_weighted_pallas_bottom_halo_overlapped():
    """The fused weighted pallas path must keep the bottom halo OUT of the
    ``pallas_call``: the kernel runs on local rows + the top halo only, and
    the bottom ``ppermute`` is consumed solely by the thin post-kernel fix-up
    conv -- so the scheduler can hide the bottom collective behind the whole
    kernel rather than just its last tiles (ROADMAP direction 5 note).

    Structural pin: in the traced jaxpr, the bottom ppermute's output must
    not be an ancestor of any pallas_call input, yet must still reach the
    function output (through the fix-up).  Plus a numeric losslessness check
    on the same geometry."""
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.models.common import conv_params
    from repro.models.layers import conv2d
    from repro.spatial import conv2d_spatial

    k, s, p = 5, 1, 1  # lo = 1, hi = 3: halo operands distinguishable by rows
    params = conv_params(jax.random.PRNGKey(0), k, 3, 4)
    mesh = Mesh(np.array(jax.devices()[:1]), ("sp",))
    heights = (16,)  # min height 16 >= n_fix*s + lo = 4: overlapped path
    fn = shard_map(
        partial(conv2d_spatial, k=k, s=s, p=p, axis_name="sp", overlap=True,
                engine="pallas", interpret=True, heights=heights),
        mesh=mesh,
        in_specs=(P(None, "sp", None, None), P()),
        out_specs=P(None, "sp", None, None),
        check_rep=False,
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8, 3))

    body = _find_jaxpr_with(jax.make_jaxpr(fn)(x, params).jaxpr, "ppermute")
    assert body is not None, "no ppermute in the traced weighted pallas conv"
    pperms = [e for e in body.eqns if e.primitive.name == "ppermute"]
    assert len(pperms) == 2, [e.params for e in pperms]
    bot_pperm = max(pperms, key=lambda e: e.invars[0].aval.shape[1])
    assert bot_pperm.invars[0].aval.shape[1] == 3  # the hi-row donation

    tainted = set(bot_pperm.outvars)
    kernel_seen = False
    for eqn in body.eqns:
        if eqn is bot_pperm:
            continue
        hit = any(hasattr(v, "count") and v in tainted for v in eqn.invars)
        if _contains_pallas(eqn):
            kernel_seen = True
            assert not hit, "pallas_call consumes the bottom ppermute (no overlap)"
        elif hit:
            tainted.update(eqn.outvars)
    assert kernel_seen, "no pallas_call in the traced weighted conv"
    assert any(
        hasattr(v, "count") and v in tainted for v in body.outvars
    ), "bottom halo never reaches the output (fix-up conv missing)"

    # numeric: the overlapped path stays lossless on the same geometry
    # (height pads asymmetrically by the halo sizes: lo above, hi below)
    want = conv2d(x, params, stride=s, padding=[(1, 3), (p, p)])
    got = fn(x, params)[:, : heights[0] // s]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_spmd_halo_exchange_multidevice():
    """Run the shard_map halo-exchange suite on 8 forced host devices."""
    script = os.path.join(os.path.dirname(__file__), "spatial_multidev_impl.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, script], capture_output=True, text=True, env=env, timeout=600
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "ALL MULTIDEV SPATIAL CHECKS PASSED" in res.stdout
