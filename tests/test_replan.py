"""Online re-planning tests: rate estimator, bucketing, plan cache,
hysteresis, channel replay, serving integration, and losslessness of
replanned plans."""
import jax
import numpy as np
import pytest

from repro.core import (
    AGX_XAVIER,
    CollabTopology,
    GaussMarkovTrace,
    Link,
    OffloadChannel,
    PlanCache,
    ReplanConfig,
    ReplanController,
    StaticPlanner,
    bucket_rate,
    optimize_static,
    rate_bucket,
    replay_rate_trace,
)
from repro.core.reliability import IMAGE_BYTES
from repro.core.replan import LinkRateEstimator
from repro.models import vgg
from repro.runtime.serve import plan_aware_batch_size
from repro.spatial import run_plan

CFG = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10)
NET = CFG.geom()
NOMINAL = 120e6


def small_topology() -> CollabTopology:
    return CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={"e0": AGX_XAVIER, "a": AGX_XAVIER, "b": AGX_XAVIER},
        default_link=Link(NOMINAL),
    )


# closed-form objective: plan *validity* and cache/hysteresis mechanics are
# what these tests exercise, so the ~20x cheaper engine keeps them fast
FAST = ReplanConfig(use_simulator=False, alpha=1.0, hysteresis=1, bucket_frac=0.5)


def observe_rate(ctl: ReplanController, rate: float) -> None:
    """One epoch's worth of probe observations on b's (volatile) link."""
    for pair in (("e0", "b"), ("b", "e0")):
        ctl.observe_transfer(*pair, IMAGE_BYTES, 8.0 * IMAGE_BYTES / rate)


# -- bucketing ----------------------------------------------------------------


def test_rate_bucket_bands():
    f = 0.25
    # same band iff within the geometric width; representative inside the band
    for r in (40e6, 120e6, 2.5e9, 100e9):
        b = rate_bucket(r, f)
        assert rate_bucket(r * 1.001, f) in (b, b + 1)
        rep = bucket_rate(b, f)
        assert rep / r < (1 + f) and r / rep < (1 + f)
    # monotone in the rate
    rates = [10e6 * (1.3**i) for i in range(20)]
    buckets = [rate_bucket(r, f) for r in rates]
    assert buckets == sorted(buckets)


def test_rate_bucket_exact_mode_and_errors():
    # bucket_frac <= 0 keys on the exact rate (always-replan degenerate mode)
    assert rate_bucket(123.0e6, 0.0) == 123.0e6
    assert bucket_rate(123.0e6, 0.0) == 123.0e6
    with pytest.raises(ValueError):
        rate_bucket(0.0, 0.25)


# -- estimator ----------------------------------------------------------------


def test_estimator_seeds_from_topology_and_ewma():
    topo = small_topology()
    est = LinkRateEstimator.from_topology(topo, alpha=0.4)
    assert est.rate("e0", "b") == NOMINAL
    assert set(est.rates()) == set(topo.collab_pairs())
    # one observed transfer at 30 Mbps moves the estimate 40% of the way
    est.observe("e0", "b", 125_000.0, 8 * 125_000.0 / 30e6)
    assert est.rate("e0", "b") == pytest.approx(0.6 * NOMINAL + 0.4 * 30e6)
    assert est.rate("b", "e0") == NOMINAL  # directions are independent
    with pytest.raises(ValueError):
        est.observe("e0", "b", 0.0, 1.0)
    with pytest.raises(ValueError):
        LinkRateEstimator({}, alpha=0.0)


# -- plan cache ---------------------------------------------------------------


def test_plan_cache_lru_and_stats():
    cache = PlanCache(capacity=2)
    a, b, c = object(), object(), object()
    assert cache.get("a") is None  # miss
    cache.put("a", a)
    cache.put("b", b)
    assert cache.get("a") is a  # hit; refreshes LRU position
    cache.put("c", c)  # evicts b (least recently used)
    assert cache.get("b") is None
    assert cache.get("a") is a and cache.get("c") is c
    assert cache.evictions == 1 and len(cache) == 2
    assert cache.hits == 3 and cache.misses == 2
    assert cache.hit_rate == pytest.approx(0.6)
    assert cache.entries() == [a, c]
    with pytest.raises(ValueError):
        PlanCache(capacity=0)


# -- hysteresis (step() only: no optimisation happens) ------------------------


def test_hysteresis_debounces_single_epoch_excursions():
    ctl = ReplanController(
        NET, small_topology(), ReplanConfig(alpha=1.0, hysteresis=3, bucket_frac=0.5)
    )
    # one deviant epoch, then back to nominal: never adopted
    observe_rate(ctl, 30e6)
    assert ctl.step() is False
    observe_rate(ctl, NOMINAL)
    assert ctl.step() is False
    assert ctl.replans == 0
    # the deviant bucket must persist `hysteresis` consecutive epochs
    observe_rate(ctl, 30e6)
    assert ctl.step() is False
    observe_rate(ctl, 30e6)
    assert ctl.step() is False
    observe_rate(ctl, 30e6)
    assert ctl.step() is True
    assert ctl.replans == 1
    # in-bucket jitter never triggers (29 vs 30 Mbps share a 50% band)
    observe_rate(ctl, 29e6)
    assert ctl.step() is False


def test_hysteresis_leq_one_adopts_immediately():
    ctl = ReplanController(
        NET, small_topology(), ReplanConfig(alpha=1.0, hysteresis=0, bucket_frac=0.5)
    )
    observe_rate(ctl, 30e6)
    assert ctl.step() is True and ctl.replans == 1


def test_hysteresis_not_starved_by_monotone_drift():
    """A channel crossing one bucket band per epoch still replans: the counter
    tracks consecutive epochs *outside* the active bands, not epochs on one
    candidate key."""
    ctl = ReplanController(
        NET, small_topology(), ReplanConfig(alpha=1.0, hysteresis=2, bucket_frac=0.5)
    )
    observe_rate(ctl, 60e6)  # new band vs the 120 Mbps nominal
    assert ctl.step() is False
    observe_rate(ctl, 30e6)  # yet another band: still counts toward adoption
    assert ctl.step() is True
    assert ctl.replans == 1


# -- controller + cache -------------------------------------------------------


def test_controller_cache_hits_on_bucket_revisit():
    ctl = ReplanController(NET, small_topology(), FAST)
    p_nominal = ctl.plan_for_epoch()  # miss 1: nominal bucket
    observe_rate(ctl, 30e6)
    p_slow = ctl.plan_for_epoch()  # miss 2: degraded bucket
    observe_rate(ctl, NOMINAL)
    assert ctl.plan_for_epoch() is p_nominal  # hit: nominal bucket cached
    observe_rate(ctl, 30e6)
    assert ctl.plan_for_epoch() is p_slow  # hit: degraded bucket cached
    assert ctl.cache.misses == 2 and ctl.cache.hits == 2
    assert ctl.optimizer_calls == 2 and ctl.replans == 3


def test_shared_cache_across_controllers():
    cache = PlanCache()
    a = ReplanController(NET, small_topology(), FAST, cache=cache)
    a.plan_for_epoch()
    b = ReplanController(NET, small_topology(), FAST, cache=cache)
    b.plan_for_epoch()  # identical fingerprint + bucket: shared entry
    assert cache.misses == 1 and cache.hits == 1
    assert b.optimizer_calls == 0
    # a different optimiser config must NOT collide on the shared cache
    # (bucket indices are grid-relative, so bucket_frac keys the fingerprint)
    c = ReplanController(
        NET, small_topology(),
        ReplanConfig(use_simulator=False, alpha=1.0, hysteresis=1, bucket_frac=0.3),
        cache=cache,
    )
    c.plan_for_epoch()
    assert c.optimizer_calls == 1 and cache.misses == 2


def test_serving_reads_do_not_skew_epoch_telemetry():
    """plan/makespan/predicted_latency peek at the cache: hit/miss counters
    keep measuring plan requests per control epoch only."""
    ctl = ReplanController(NET, small_topology(), FAST)
    ctl.plan_for_epoch()  # 1 miss (fills the cache)
    hits, misses = ctl.cache.hits, ctl.cache.misses
    _ = ctl.plan
    _ = ctl.makespan
    _ = ctl.predicted_latency(4)
    ctl.observe_batch_latency(4, 0.01)
    assert (ctl.cache.hits, ctl.cache.misses) == (hits, misses)
    ctl.plan_for_epoch()  # the epoch path still counts
    assert ctl.cache.hits == hits + 1


# -- trace + replay -----------------------------------------------------------


def test_gauss_markov_trace_deterministic_and_bounded():
    tr = GaussMarkovTrace(lo=30e6, hi=120e6, corr=0.9, sigma_frac=0.2, seed=4)
    rates = tr.rates(100)
    assert rates == tr.rates(100)  # seeded determinism
    assert all(30e6 <= r <= 120e6 for r in rates)
    assert len(set(rates)) > 10  # actually moves
    frozen = GaussMarkovTrace(lo=1.0, hi=2.0, corr=1.0, sigma_frac=0.0, start=1.5)
    assert frozen.rates(5) == [1.5] * 5
    with pytest.raises(ValueError):
        GaussMarkovTrace(lo=2.0, hi=1.0)
    with pytest.raises(ValueError):
        GaussMarkovTrace(lo=0.0, hi=1.0, corr=1.5)


def test_replay_validates_traces():
    topo = small_topology()
    planner = StaticPlanner(optimize_static(NET, topo, FAST).plan)
    with pytest.raises(ValueError, match="at least one"):
        replay_rate_trace(NET, topo, planner, {}, n_tasks=1)
    short = {("e0", "b"): [NOMINAL] * 3, ("b", "e0"): [NOMINAL] * 3}
    with pytest.raises(ValueError, match="shortest trace"):
        replay_rate_trace(NET, topo, planner, short, n_epochs=5, n_tasks=1)
    assert len(replay_rate_trace(NET, topo, planner, short, n_tasks=1)) == 3


def test_replay_adaptive_beats_static_on_sustained_collapse():
    """b's link collapses 120 -> 30 Mbps at epoch 4 and stays: the adaptive
    planner re-balances after the hysteresis lag and wins on mean makespan;
    the DES objective keeps this a ground-truth comparison."""
    topo = small_topology()
    n = 16
    trace = [NOMINAL] * 4 + [30e6] * (n - 4)
    link_rates = {("e0", "b"): trace, ("b", "e0"): trace}
    cfg = ReplanConfig(n_tasks=2, hysteresis=1)
    static = replay_rate_trace(
        NET, topo, StaticPlanner(optimize_static(NET, topo, cfg).plan),
        link_rates, n_tasks=2,
    )
    ctl = ReplanController(NET, topo, cfg)
    adaptive = replay_rate_trace(NET, topo, ctl, link_rates, n_tasks=2)
    mean = lambda run: sum(r["makespan"] for r in run) / len(run)
    assert mean(adaptive) < 0.99 * mean(static)
    assert ctl.replans >= 1
    assert "planner_stats" in adaptive[-1]
    # once re-balanced, the adaptive plan wins in the degraded regime
    assert adaptive[-1]["makespan"] < static[-1]["makespan"]


# -- serving integration ------------------------------------------------------


def test_plan_aware_batch_size_tracks_channel():
    ctl = ReplanController(NET, small_topology(), FAST)
    channel = OffloadChannel(rate_bps=100e6, sigma_s=1e-3)
    generous = plan_aware_batch_size(ctl, 2.0, channel, target=0.999, max_batch=8)
    tight = plan_aware_batch_size(ctl, 0.045, channel, target=0.999, max_batch=8)
    assert 1 <= tight <= generous <= 8
    assert generous == 8  # 2 s of slack admits everything on the small net
    mid = plan_aware_batch_size(ctl, 0.06, channel, target=0.999, max_batch=8)
    # a measured collapse raises the predicted makespan, shrinking admission
    observe_rate(ctl, 5e6)
    ctl.step()
    degraded = plan_aware_batch_size(ctl, 0.06, channel, target=0.999, max_batch=8)
    assert degraded <= mid


def test_observe_batch_latency_calibrates_predictions():
    ctl = ReplanController(NET, small_topology(), FAST)
    before = ctl.predicted_latency(2)
    # measured latency 3x the raw prediction -> calibration moves up (alpha=1)
    ctl.observe_batch_latency(2, 3.0 * before)
    after = ctl.predicted_latency(2)
    assert after == pytest.approx(3.0 * before, rel=1e-6)
    # clamped against outliers
    ctl.observe_batch_latency(2, 1e6)
    assert ctl.stats()["calibration"] <= 10.0


# -- losslessness of replanned plans ------------------------------------------


def test_replanned_plan_is_lossless():
    ctl = ReplanController(NET, small_topology(), FAST)
    observe_rate(ctl, 30e6)
    plan = ctl.plan_for_epoch()
    params = vgg.init(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, CFG.img_res, CFG.img_res, 3))
    ref = vgg.features(params, CFG, x)
    out = run_plan(plan, params["features"], vgg.apply_layer, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
