"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run forces 512 host devices *before* any
jax initialisation; tests and benches see the default single device)."""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_spatial_mesh",
    "mesh_axes",
    "dp_axes",
    "fsdp_axes",
]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_spatial_mesh(n: int | None = None, *, axis: str = "sp"):
    """1-D mesh over the image-height axis for the HALP spatial executor
    (``repro.spatial``): ``n`` devices (default: all local devices) along a
    single ``"sp"`` axis.  Capacity-weighted deployments keep this equal-block
    mesh and encode the skew in the padded shard layout
    (``repro.spatial.halo.shard_heights``)."""
    n = n or len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh):
    """Axes carrying data parallelism (batch sharding)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh):
    """Axes over which large models additionally shard parameters (ZeRO-3)."""
    return dp_axes(mesh)
