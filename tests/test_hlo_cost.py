"""HLO cost analyzer tests: exact on toy modules; scan-multiplied; consistent
with XLA's cost_analysis on scan-free programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _compiled_text(lambda a, b: a @ b, x, w)
    got = analyze_hlo(c.as_text())
    assert got.flops == 2 * 128 * 256 * 512
    cost = c.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    assert got.flops == pytest.approx(float(cost["flops"]), rel=0.01)


def test_scan_flops_multiplied_by_trip_count():
    """The whole point: XLA counts the while body once; we count it L times."""

    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    per_layer = 2 * 128 * 256 * 256
    for L in (2, 8, 32):
        ws = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
        c = _compiled_text(f, x, ws)
        got = analyze_hlo(c.as_text())
        assert got.flops == pytest.approx(L * per_layer, rel=0.05), L
        # XLA's own count stays at one body -- documents the artifact we fix
        cost = c.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else cost
        assert float(cost["flops"]) == pytest.approx(per_layer, rel=0.05)


def test_conv_flops_exact():
    x = jax.ShapeDtypeStruct((2, 16, 16, 8), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 3, 8, 16), jnp.float32)

    def f(a, b):
        return jax.lax.conv_general_dilated(
            a, b, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )

    c = _compiled_text(f, x, w)
    got = analyze_hlo(c.as_text())
    want = 2 * (2 * 16 * 16 * 16) * (3 * 3 * 8)
    assert got.flops == pytest.approx(want, rel=0.05)


def test_bytes_reasonable_vs_xla():
    """Bytes accounting within 2x of XLA's on a scan-free program."""
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(a):
        return jnp.tanh(a @ a.T).sum()

    c = _compiled_text(f, x)
    got = analyze_hlo(c.as_text())
    cost = c.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    assert got.bytes_accessed > 0
    assert 0.5 * xla_bytes <= got.bytes_accessed <= 2.0 * xla_bytes


def test_scan_bytes_scale_with_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b8 = analyze_hlo(_compiled_text(f, x, jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)).as_text())
    b32 = analyze_hlo(_compiled_text(f, x, jax.ShapeDtypeStruct((32, 256, 256), jnp.float32)).as_text())
    assert b32.bytes_accessed > 3.0 * b8.bytes_accessed
