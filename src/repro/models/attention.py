"""Attention variants for the LM family: GQA (+RoPE, qk-norm), MLA (DeepSeek),
with KV caches for decode.  Shapes: x [B, T, D]; caches [B, S, ...]."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from .common import Params, dense_params, keygen, norm_params
from .layers import dense, rmsnorm

# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, T, H, d]; positions: [B, T] (absolute)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, T, d/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GQAConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qk_norm: bool = False
    rope_theta: float = 1e6


def gqa_init(key, cfg: GQAConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    p = {
        "wq": dense_params(next(ks), cfg.d_model, cfg.n_heads * cfg.d_head, bias=False, dtype=dtype),
        "wk": dense_params(next(ks), cfg.d_model, cfg.n_kv_heads * cfg.d_head, bias=False, dtype=dtype),
        "wv": dense_params(next(ks), cfg.d_model, cfg.n_kv_heads * cfg.d_head, bias=False, dtype=dtype),
        "wo": dense_params(next(ks), cfg.n_heads * cfg.d_head, cfg.d_model, bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_params(cfg.d_head, bias=False, dtype=dtype)
        p["k_norm"] = norm_params(cfg.d_head, bias=False, dtype=dtype)
    return p


CHUNK_MIN_T = 4096  # query lengths >= this use the O(S)-memory chunked path
Q_CHUNK = 1024


def _sdpa(q, k, v, mask, scale):
    """q: [B,T,H,d] k,v: [B,S,Hkv,d] -> [B,T,H,d]; grouped heads broadcast."""
    b, t, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q = q.reshape(b, t, hkv, g, d)
    logits = jnp.einsum("bthgd,bshd->bhgts", q, k) * scale
    logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v)
    return out.reshape(b, t, h, d)


def _sdpa_chunked_causal(q, k, v, scale, chunk=Q_CHUNK):
    """Causal attention scanned over query blocks: peak memory is one
    [B, H, chunk, S] logits block instead of [B, H, T, S] (the pure-JAX
    flash-equivalent used by 4k-train / 32k-prefill shapes; the Pallas TPU
    kernel in repro.kernels.attention is the on-device analogue)."""
    b, t, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    nblk = t // chunk
    qb = q.reshape(b, nblk, chunk, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kv_pos = jnp.arange(k.shape[1])

    # NOTE: the block index is the scan CARRY, not a scanned arange -- a
    # scanned-input mask is loop-invariant per block, so XLA hoists and stacks
    # all nblk [chunk, S] masks into one HBM-resident input.  The body is
    # rematerialised so the backward pass recomputes the [chunk, S] probs
    # instead of saving nblk stacked f32 residuals (flash-style; compute is
    # far from the bound here -- §Perf iteration 2).
    def body(blk, qi):  # qi [B,Hkv,G,chunk,d]
        q_pos = blk * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bhgtd,bshd->bhgts", qi, k) * scale
        mask = (q_pos[:, None] >= kv_pos[None, :])[None, None, None]
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(qi.dtype)
        return blk + 1, jnp.einsum("bhgts,bshd->bhgtd", probs, v)

    body = jax.checkpoint(body, prevent_cse=False)
    _, outs = lax.scan(body, jnp.int32(0), qb)
    # outs [nblk, B, Hkv, G, chunk, d] -> [B, T, H, d]
    outs = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, t, h, d)
    return outs


def gqa_apply(
    p: Params,
    cfg: GQAConfig,
    x: jax.Array,
    positions: jax.Array,
    mask: jax.Array,
    kv: tuple[jax.Array, jax.Array] | None = None,
    cache_index: jax.Array | None = None,
):
    """Returns (out, (k_cache, v_cache)).

    Training: kv=None, mask [B,1,1,T,T] causal.  Decode: kv = full caches
    [B,S,max] and ``cache_index`` the write position; x is the new token block.
    """
    b, t, _ = x.shape
    q = dense(x, p["wq"]).reshape(b, t, cfg.n_heads, cfg.d_head)
    k = dense(x, p["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    v = dense(x, p["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv is not None:
        k_cache, v_cache = kv
        k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, cache_index, 0, 0))
        v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, cache_index, 0, 0))
        k, v = k_cache, v_cache
    scale = cfg.d_head ** -0.5
    if kv is None and t >= CHUNK_MIN_T and t % Q_CHUNK == 0:
        out = _sdpa_chunked_causal(q, k, v, scale)
    else:
        out = _sdpa(q, k, v, mask, scale)
    out = dense(out.reshape(b, t, cfg.n_heads * cfg.d_head), p["wo"])
    return out, (k, v)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3, arXiv:2412.19437)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 1e4

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    h = cfg.n_heads
    return {
        "wdq": dense_params(next(ks), cfg.d_model, cfg.q_lora_rank, bias=False, dtype=dtype),
        "q_norm": norm_params(cfg.q_lora_rank, bias=False, dtype=dtype),
        "wuq": dense_params(next(ks), cfg.q_lora_rank, h * cfg.qk_head_dim, bias=False, dtype=dtype),
        "wdkv": dense_params(next(ks), cfg.d_model, cfg.kv_lora_rank, bias=False, dtype=dtype),
        "kv_norm": norm_params(cfg.kv_lora_rank, bias=False, dtype=dtype),
        "wukv": dense_params(
            next(ks), cfg.kv_lora_rank, h * (cfg.qk_nope_head_dim + cfg.v_head_dim),
            bias=False, dtype=dtype,
        ),
        "wkr": dense_params(next(ks), cfg.d_model, cfg.qk_rope_head_dim, bias=False, dtype=dtype),
        "wo": dense_params(next(ks), h * cfg.v_head_dim, cfg.d_model, bias=False, dtype=dtype),
    }


def mla_apply(
    p: Params,
    cfg: MLAConfig,
    x: jax.Array,
    positions: jax.Array,
    mask: jax.Array,
    cache: jax.Array | None = None,  # [B, S, kv_lora + rope] compressed KV cache
    cache_index: jax.Array | None = None,
):
    """Multi-head Latent Attention.  The cache stores only the *compressed*
    latent (kv_lora_rank + rope dims per token) -- MLA's memory win."""
    b, t, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(dense(x, p["wdq"]), p["q_norm"])
    q = dense(cq, p["wuq"]).reshape(b, t, h, cfg.qk_head_dim)
    q_nope, q_rope = q[..., : cfg.qk_nope_head_dim], q[..., cfg.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = rmsnorm(dense(x, p["wdkv"]), p["kv_norm"])  # [B,T,kv_lora]
    k_rope_new = apply_rope(
        dense(x, p["wkr"])[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0]  # [B,T,rope] shared across heads
    latent_new = jnp.concatenate([ckv, k_rope_new], axis=-1)
    if cache is not None:
        cache = lax.dynamic_update_slice(
            cache, latent_new.astype(cache.dtype), (0, cache_index, 0)
        )
        latent = cache
    else:
        latent = latent_new
    ckv_all = latent[..., : cfg.kv_lora_rank]
    k_rope = latent[..., cfg.kv_lora_rank :]

    kv = dense(ckv_all, p["wukv"]).reshape(
        b, latent.shape[1], h, cfg.qk_nope_head_dim + cfg.v_head_dim
    )
    k_nope, v = kv[..., : cfg.qk_nope_head_dim], kv[..., cfg.qk_nope_head_dim :]

    scale = cfg.qk_head_dim ** -0.5
    if cache is None and t >= 4096 and t % 1024 == 0:
        out = _mla_chunked_causal(q_nope, q_rope, k_nope, k_rope, v, scale)
    else:
        logits = (
            jnp.einsum("bthd,bshd->bhts", q_nope, k_nope)
            + jnp.einsum("bthd,bsd->bhts", q_rope, k_rope)
        ) * scale
        logits = jnp.where(
            mask[:, :, 0] if mask.ndim == 5 else mask, logits, jnp.finfo(logits.dtype).min
        )
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = jnp.einsum("bhts,bshd->bthd", probs, v)
    out = dense(out.reshape(b, t, h * cfg.v_head_dim), p["wo"])
    return out, cache


def _mla_chunked_causal(q_nope, q_rope, k_nope, k_rope, v, scale, chunk=1024):
    b, t, h, dn = q_nope.shape
    nblk = t // chunk
    qn = q_nope.reshape(b, nblk, chunk, h, dn).transpose(1, 0, 3, 2, 4)
    qr = q_rope.reshape(b, nblk, chunk, h, q_rope.shape[-1]).transpose(1, 0, 3, 2, 4)
    kv_pos = jnp.arange(k_nope.shape[1])

    def body(blk, inp):  # blk carried: see _sdpa_chunked_causal note
        qni, qri = inp
        q_pos = blk * chunk + jnp.arange(chunk)
        logits = (
            jnp.einsum("bhtd,bshd->bhts", qni, k_nope)
            + jnp.einsum("bhtd,bsd->bhts", qri, k_rope)
        ) * scale
        mask = (q_pos[:, None] >= kv_pos[None, :])[None, None]
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(qni.dtype)
        return blk + 1, jnp.einsum("bhts,bshd->bhtd", probs, v)

    body = jax.checkpoint(body, prevent_cse=False)
    _, outs = lax.scan(body, jnp.int32(0), (qn, qr))
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, t, h, v.shape[-1])


def causal_mask(t: int, dtype=jnp.bool_) -> jax.Array:
    return jnp.tril(jnp.ones((t, t), dtype))[None, None, None]  # [1,1,1,T,T]


def decode_mask(s_max: int, cache_index: jax.Array) -> jax.Array:
    """[1,1,1,1,S]: positions <= cache_index are visible."""
    return (jnp.arange(s_max) <= cache_index)[None, None, None, None]
