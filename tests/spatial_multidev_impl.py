"""Multi-device spatial-parallelism checks; run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (see test_spatial.py).
Exits non-zero on any mismatch."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.models import vgg
from repro.models.layers import conv2d, max_pool, relu
from repro.spatial import (
    conv2d_spatial,
    max_pool_spatial,
    merge_padded_shards,
    shard_heights,
    to_padded_shards,
)
from repro.models.common import conv_params

assert len(jax.devices()) == 8, jax.devices()
mesh = Mesh(np.array(jax.devices()).reshape(8), ("sp",))


def check(name, got, want, tol=2e-5):
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, (name, got.shape, want.shape)
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol, err_msg=name)
    print(f"ok: {name}")


# --- single conv, sweep of geometries, both schedules -----------------------
key = jax.random.PRNGKey(0)
for (k, s, p, c_in, c_out, h) in [
    (3, 1, 1, 3, 16, 64),     # VGG body
    (1, 1, 0, 8, 16, 32),     # pointwise
    (5, 1, 2, 4, 8, 64),      # 5x5 (paper-bug regime handled exactly)
    (7, 2, 3, 3, 16, 64),     # ResNet/EfficientNet stem
    (3, 2, 1, 8, 8, 64),      # strided 3x3
    (2, 2, 0, 4, 4, 32),      # pool-like conv
]:
    kp, kx, key = (*jax.random.split(key, 2), key)
    params = conv_params(kp, k, c_in, c_out)
    x = jax.random.normal(kx, (2, h, h, c_in))
    want = conv2d(x, params, stride=s, padding=[(p, p), (p, p)])
    for overlap in (False, True):
        fn = shard_map(
            partial(conv2d_spatial, k=k, s=s, p=p, axis_name="sp", overlap=overlap),
            mesh=mesh,
            in_specs=(P(None, "sp", None, None), P()),
            out_specs=P(None, "sp", None, None),
        )
        got = fn(x, params)
        check(f"conv k{k}s{s}p{p} overlap={overlap}", got, want)

# --- depthwise conv (EfficientNet / ConvNeXt path) --------------------------
kp, kx, key = (*jax.random.split(key, 2), key)
c = 8
params = conv_params(kp, 7, c, c, groups=c)
x = jax.random.normal(kx, (1, 56, 56, c))
want = conv2d(x, params, stride=1, padding=[(3, 3), (3, 3)], groups=c)
fn = shard_map(
    partial(conv2d_spatial, k=7, s=1, p=3, axis_name="sp", overlap=True, groups=c),
    mesh=mesh,
    in_specs=(P(None, "sp", None, None), P()),
    out_specs=P(None, "sp", None, None),
)
check("depthwise 7x7", fn(x, params), want)

# --- fused Pallas engine: same geometry sweep through ONE pallas_call --------
# (pallas_call has no shard_map replication rule -> check_rep=False)
key = jax.random.PRNGKey(21)
for (k, s, p, c_in, c_out, h, g) in [
    (3, 1, 1, 3, 16, 64, 1),
    (1, 1, 0, 8, 16, 32, 1),
    (5, 1, 2, 4, 8, 64, 1),
    (7, 2, 3, 3, 16, 64, 1),
    (3, 2, 1, 8, 8, 64, 1),
    (2, 2, 0, 4, 4, 32, 1),
    (7, 1, 3, 8, 8, 56, 8),  # depthwise through the kernel's VPU path
]:
    kp, kx, key = (*jax.random.split(key, 2), key)
    params = conv_params(kp, k, c_in, c_out, groups=g)
    x = jax.random.normal(kx, (2, h, h, c_in))
    want = conv2d(x, params, stride=s, padding=[(p, p), (p, p)], groups=g)
    fn = shard_map(
        partial(conv2d_spatial, k=k, s=s, p=p, axis_name="sp", groups=g,
                engine="pallas", interpret=True),
        mesh=mesh,
        in_specs=(P(None, "sp", None, None), P()),
        out_specs=P(None, "sp", None, None),
        check_rep=False,
    )
    check(f"pallas conv k{k}s{s}p{p}g{g}", fn(x, params), want)

# --- thin-shard fallback: t_hi < t_lo (no interior rows at 4-row shards) -----
kp, kx, key = (*jax.random.split(key, 2), key)
params = conv_params(kp, 7, 4, 8)
x = jax.random.normal(kx, (1, 32, 16, 4))  # 8 shards x 4 rows, lo = hi = 3
want = conv2d(x, params, stride=1, padding=[(3, 3), (3, 3)])
for engine in ("lax", "pallas"):
    fn = shard_map(
        partial(conv2d_spatial, k=7, s=1, p=3, axis_name="sp", overlap=True,
                engine=engine, interpret=True),
        mesh=mesh,
        in_specs=(P(None, "sp", None, None), P()),
        out_specs=P(None, "sp", None, None),
        check_rep=False,
    )
    check(f"thin-shard k7 (t_hi < t_lo) {engine}", fn(x, params), want)

# --- capacity-weighted shards: skewed split in the padded equal-block layout -
H = 64
hts = shard_heights(H, 8, ratios=[4, 3, 2, 1, 1, 2, 3, 4], align=2)
assert sum(hts) == H and max(hts) > min(hts), hts
for (k, s, p, c_in, c_out, g) in [
    (3, 1, 1, 3, 8, 1),
    (5, 1, 2, 4, 8, 1),   # 5x5 boundary slabs, weighted
    (3, 2, 1, 8, 8, 1),
    (7, 2, 3, 3, 8, 1),
    (7, 1, 3, 8, 8, 8),   # depthwise (groups > 1) boundary slabs, weighted
]:
    kp, kx, key = (*jax.random.split(key, 2), key)
    params = conv_params(kp, k, c_in, c_out, groups=g)
    x = jax.random.normal(kx, (2, H, 17, c_in))
    want = conv2d(x, params, stride=s, padding=[(p, p), (p, p)], groups=g)
    xp = to_padded_shards(x, hts)
    o_hts = tuple(hh // s for hh in hts)
    for engine, overlap in (("lax", True), ("lax", False), ("pallas", True)):
        fn = shard_map(
            partial(conv2d_spatial, k=k, s=s, p=p, axis_name="sp",
                    overlap=overlap, groups=g, engine=engine, interpret=True,
                    heights=hts),
            mesh=mesh,
            in_specs=(P(None, "sp", None, None), P()),
            out_specs=P(None, "sp", None, None),
            check_rep=False,
        )
        got = merge_padded_shards(fn(xp, params), o_hts)
        check(f"weighted conv k{k}s{s}p{p}g{g} {engine} ov={overlap}", got, want)

# --- taller weighted shards: every geometry above takes the *overlapped*
# bottom-halo pallas path (min height >= n_fix*s + lo, so the kernel runs
# without the pre-kernel bottom splice and the fix-up conv patches the edge)
hts_tall = (10, 8, 6, 6, 6, 8, 10, 10)  # sum 64, all even, min 6
assert sum(hts_tall) == H and min(hts_tall) >= 6  # k7s1p3: n_fix*s + lo = 6
for (k, s, p, c_in, c_out, g) in [
    (3, 1, 1, 3, 8, 1),
    (5, 1, 2, 4, 8, 1),
    (7, 2, 3, 3, 8, 1),
    (7, 1, 3, 8, 8, 8),   # depthwise overlapped fix-up
]:
    kp, kx, key = (*jax.random.split(key, 2), key)
    params = conv_params(kp, k, c_in, c_out, groups=g)
    x = jax.random.normal(kx, (2, H, 17, c_in))
    want = conv2d(x, params, stride=s, padding=[(p, p), (p, p)], groups=g)
    fn = shard_map(
        partial(conv2d_spatial, k=k, s=s, p=p, axis_name="sp",
                overlap=True, groups=g, engine="pallas", interpret=True,
                heights=hts_tall),
        mesh=mesh,
        in_specs=(P(None, "sp", None, None), P()),
        out_specs=P(None, "sp", None, None),
        check_rep=False,
    )
    got = merge_padded_shards(fn(to_padded_shards(x, hts_tall), params),
                              tuple(hh // s for hh in hts_tall))
    check(f"weighted-tall overlapped-bottom k{k}s{s}p{p}g{g}", got, want)

# weighted max pool: k == s (no halo) and k > s (bottom-halo path)
x = jax.random.normal(key, (2, H, 16, 4))
xp = to_padded_shards(x, hts)
from jax import lax as _lax

for (k, s) in [(2, 2), (3, 2)]:
    xe = jnp.concatenate([x, jnp.zeros((2, k - s, 16, 4))], axis=1)
    want = _lax.reduce_window(
        xe, -jnp.inf, _lax.max, (1, k, k, 1), (1, s, s, 1), "VALID"
    )
    fn = shard_map(
        partial(max_pool_spatial, k=k, s=s, axis_name="sp", heights=hts),
        mesh=mesh,
        in_specs=P(None, "sp", None, None),
        out_specs=P(None, "sp", None, None),
    )
    got = merge_padded_shards(fn(xp), tuple(hh // s for hh in hts))
    check(f"weighted maxpool k{k}s{s}", got, want)

# --- max pool ----------------------------------------------------------------
x = jax.random.normal(key, (2, 64, 64, 4))
want = max_pool(x, 2, 2)
fn = shard_map(
    partial(max_pool_spatial, k=2, s=2, axis_name="sp"),
    mesh=mesh,
    in_specs=P(None, "sp", None, None),
    out_specs=P(None, "sp", None, None),
)
check("maxpool 2x2", fn(x), want)

# --- full VGG feature extractor under shard_map ------------------------------
cfg = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10)
params = vgg.init(jax.random.PRNGKey(3), cfg)
x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 64, 3))
want = vgg.features(params, cfg, x)


def spatial_features(x, feats):
    geom = cfg.geom()
    for p_l, g in zip(feats, geom.layers):
        if g.kind == "pool":
            x = max_pool_spatial(x, g.k, g.s, axis_name="sp")
        else:
            x = relu(conv2d_spatial(x, p_l, g.k, g.s, g.p, axis_name="sp", overlap=True))
    return x


fn = shard_map(
    spatial_features,
    mesh=mesh,
    in_specs=(P(None, "sp", None, None), P()),
    out_specs=P(None, "sp", None, None),
)
# 64 rows / 8 devices = 8 rows per shard; after 4 pools the shard is 4/8... the
# last block would underflow 1 row/shard -> run on the first 3 blocks instead.
cfg_sp = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10,
                       blocks=((2, 64), (2, 128), (3, 256)))
params_sp = vgg.init(jax.random.PRNGKey(3), cfg_sp)
want_sp = vgg.features(params_sp, cfg_sp, x)


def spatial_features_sp(x, feats):
    geom = cfg_sp.geom()
    for p_l, g in zip(feats, geom.layers):
        if g.kind == "pool":
            x = max_pool_spatial(x, g.k, g.s, axis_name="sp")
        else:
            x = relu(conv2d_spatial(x, p_l, g.k, g.s, g.p, axis_name="sp", overlap=True))
    return x


fn = shard_map(
    spatial_features_sp,
    mesh=mesh,
    in_specs=(P(None, "sp", None, None), P()),
    out_specs=P(None, "sp", None, None),
)
check("vgg features (3 blocks, 8-way SP)", fn(x, params_sp["features"]), want_sp)

# --- full weighted VGG stack through the fused engine ------------------------
# 2 blocks -> stride alignment 4; 8-way skewed split of 64 rows.
cfg_w = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10,
                      blocks=((2, 64), (2, 128)))
params_w = vgg.init(jax.random.PRNGKey(5), cfg_w)
hts_w = shard_heights(64, 8, ratios=[4, 3, 2, 1, 1, 2, 3, 4], align=4)
assert max(hts_w) > min(hts_w), hts_w
want_w = vgg.features(params_w, cfg_w, x)


def spatial_features_weighted(xs, feats):
    hts = hts_w
    for p_l, g in zip(feats, cfg_w.geom().layers):
        if g.kind == "pool":
            xs = max_pool_spatial(xs, g.k, g.s, axis_name="sp", heights=hts)
        else:
            xs = relu(conv2d_spatial(xs, p_l, g.k, g.s, g.p, axis_name="sp",
                                     overlap=True, engine="pallas",
                                     interpret=True, heights=hts))
        hts = tuple(hh // g.s for hh in hts)
    return xs


fn = shard_map(
    spatial_features_weighted,
    mesh=mesh,
    in_specs=(P(None, "sp", None, None), P()),
    out_specs=P(None, "sp", None, None),
    check_rep=False,
)
got_w = merge_padded_shards(
    fn(to_padded_shards(x, hts_w), params_w["features"]),
    tuple(hh // 4 for hh in hts_w),
)
check("vgg features weighted+fused (2 blocks, skewed 8-way)", got_w, want_w)

print("ALL MULTIDEV SPATIAL CHECKS PASSED")

# --- pipeline parallelism over 8 stages --------------------------------------
from repro.parallel.pipeline import pipeline_apply

S = 8
D = 16
M = 6
key = jax.random.PRNGKey(7)
ws = jax.random.normal(key, (S, D, D)) * 0.3
xs = jax.random.normal(jax.random.PRNGKey(8), (M, 4, D))

def stage_fn(w, x):
    return jnp.tanh(x @ w)

# reference: sequential through all stages
ref = xs
for i in range(S):
    ref = jax.vmap(lambda mb: stage_fn(ws[i], mb))(ref)

pipe = shard_map(
    lambda w, x: pipeline_apply(w[0], x, stage_fn, "sp"),  # drop the stage dim
    mesh=mesh,
    in_specs=(P("sp"), P()),       # one stage's weights per device
    out_specs=P(),                  # outputs valid on the last stage
    check_rep=False,
)
got = pipe(ws, xs)
check("pipeline 8-stage forward", got, ref, tol=1e-4)

print("ALL MULTIDEV CHECKS PASSED (incl. pipeline)")
