"""unet-sd15 [diffusion]: img_res=512 latent_res=64 ch=320 ch_mult=1-2-4-4
n_res_blocks=2 attn_res=4-2-1 ctx_dim=768.  [arXiv:2112.10752; paper]"""
from ..models import unet
from ..models.unet import UNetConfig
from .base import Arch, diffusion_cells, register

FULL = UNetConfig(name="unet-sd15", img_res=512, ch=320, ch_mult=(1, 2, 4, 4),
                  n_res_blocks=2, attn_down=(1, 2, 4), ctx_dim=768)
SMOKE = UNetConfig(name="unet-sd15-smoke", img_res=64, ch=32, ch_mult=(1, 2),
                   n_res_blocks=1, attn_down=(1, 2), ctx_dim=32, ctx_len=7,
                   n_heads=4, groups=8)

ARCH = register(
    Arch(
        name="unet-sd15",
        family="diffusion",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=diffusion_cells(),
        module=unet,
        notes="conv path is sliding-window (paper partitioning direct); "
        "attention levels synchronise spatially",
    )
)
