"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280, MLA, 1 shared + 256 routed top-8, MTP.  [arXiv:2412.19437; hf]

Structural details from the paper: first 3 layers dense (d_ff 18432), MLA with
q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128, MTP depth 1.
"""
from ..models import transformer_lm as lm
from ..models.attention import MLAConfig
from ..models.moe import MoEConfig
from ..models.transformer_lm import LMConfig
from .base import Arch, lm_cells, register

FULL = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=18432,  # dense layers (first 3)
    vocab=129280,
    rope_theta=1e4,
    attn="mla",
    mla=MLAConfig(d_model=7168, n_heads=128),
    moe=MoEConfig(d_model=7168, n_experts=256, top_k=8, d_ff=2048, n_shared=1,
                  router_bias=True),
    first_k_dense=3,
    mtp_depth=1,
)

SMOKE = LMConfig(
    name="deepseek-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=192,
    vocab=512,
    attn="mla",
    mla=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(d_model=64, n_experts=8, top_k=2, d_ff=96, n_shared=1,
                  router_bias=True, capacity_factor=2.0),
    first_k_dense=1,
    mtp_depth=1,
)

ARCH = register(
    Arch(
        name="deepseek-v3-671b",
        family="lm",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=lm_cells(full_attention=True),
        module=lm,
        notes="MLA compressed KV cache (576/token); 256-way EP; bf16 optimizer "
        "moments to fit 512 x 16 GB (DESIGN.md memory budget)",
    )
)
