"""Pallas TPU kernel: direct 2-D convolution (stride 1) as MXU matmuls.

The paper's compute hot-spot is the conv layer; on TPU the idiomatic form is a
*direct* conv over VMEM-resident row tiles, where each (ky, kx) kernel tap is
one [TILE_H * W, C_in] x [C_in, C_out_tile] matmul on the MXU (an implicit
im2col that never materialises the patch matrix in HBM).

Tiling: the wrapper (ops.py) pre-builds overlapping row tiles -- the explicit
halo materialisation mirrors HALP's boundary rows -- so the kernel sees clean,
non-overlapping BlockSpec blocks:

    x_tiles [N, nT, TH + k - 1, W + 2p, C_in]  -> block (1, 1, TH+k-1, W+2p, Cin)
    weights [k, k, C_in, C_out]                -> block (k, k, Cin, TC)
    out     [N, nT, TH, W, C_out]              -> block (1, 1, TH, W, TC)

Grid: (N, nT, C_out / TC).  VMEM per step ~= (TH+2) * (W+2) * Cin * 4  +
k*k*Cin*TC*4 + TH*W*TC*4 -- the wrapper picks TH so this stays <= ~8 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int, th: int, w_out: int):
    """One (batch, row-tile, cout-tile) grid step."""
    cin = x_ref.shape[-1]
    tc = o_ref.shape[-1]
    acc = jnp.zeros((th * w_out, tc), jnp.float32)
    for ky in range(k):
        for kx in range(k):
            # [TH, W, Cin] patch for this tap -> one MXU matmul
            patch = x_ref[0, 0, ky : ky + th, kx : kx + w_out, :]
            taps = w_ref[ky, kx, :, :]  # [Cin, TC]
            acc += jnp.dot(
                patch.reshape(th * w_out, cin).astype(jnp.float32),
                taps.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
    o_ref[0, 0] = acc.reshape(th, w_out, tc).astype(o_ref.dtype)


def conv2d_tiles(
    x_tiles: jax.Array,  # [N, nT, TH + k - 1, W + 2p, Cin]
    weights: jax.Array,  # [k, k, Cin, Cout]
    *,
    k: int,
    tile_h: int,
    cout_tile: int,
    interpret: bool = False,
) -> jax.Array:
    n, nt, th_ext, w_ext, cin = x_tiles.shape
    cout = weights.shape[-1]
    w_out = w_ext - (k - 1)
    assert th_ext == tile_h + k - 1
    assert cout % cout_tile == 0

    kernel = functools.partial(_conv_kernel, k=k, th=tile_h, w_out=w_out)
    return pl.pallas_call(
        kernel,
        grid=(n, nt, cout // cout_tile),
        in_specs=[
            pl.BlockSpec(
                (1, 1, th_ext, w_ext, cin), lambda b, t, c: (b, t, 0, 0, 0)
            ),
            pl.BlockSpec((k, k, cin, cout_tile), lambda b, t, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, tile_h, w_out, cout_tile), lambda b, t, c: (b, t, 0, 0, c)
        ),
        out_shape=jax.ShapeDtypeStruct((n, nt, tile_h, w_out, cout), x_tiles.dtype),
        interpret=interpret,
    )(x_tiles, weights)
