"""Training driver used by launch/train.py and the examples: builds the step
bundle for an (arch, cell), wires the synthetic stream, and runs under the
fault-tolerant trainer."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs import get
from ..configs.steps import build, realize
from ..data.pipeline import DiffusionStream, ImageStream, TokenStream
from .fault import FaultConfig, FaultTolerantTrainer

__all__ = ["make_trainer", "train_smoke"]


def _stream_for(arch, cfg, bundle):
    ins = bundle.inputs
    if "tokens" in ins:
        b, s = ins["tokens"].shape
        return TokenStream(vocab=cfg.vocab, batch=b, seq_len=s)
    if "images" in ins:
        b, r = ins["images"].shape[:2]
        return ImageStream(img_res=r, batch=b, num_classes=cfg.num_classes)
    if "latents" in ins:
        b, r = ins["latents"].shape[:2]
        ctx = (cfg.ctx_len, cfg.ctx_dim) if hasattr(cfg, "ctx_dim") else None
        ncls = getattr(cfg, "num_classes", 1000)
        return DiffusionStream(latent_res=r, batch=b, latent_ch=ins["latents"].shape[-1],
                               n_classes=ncls, ctx=ctx)
    raise ValueError(f"no stream for inputs {list(ins)}")


def make_trainer(
    arch_name: str,
    cell: str = "train_4k",
    *,
    smoke: bool = True,
    fault_cfg: FaultConfig | None = None,
    fault_hook=None,
):
    """Returns (trainer, initial_state)."""
    arch = get(arch_name)
    bundle = build(arch, cell, smoke=smoke)
    cfg = arch.smoke_cfg if smoke else arch.cfg
    state, _ = realize(arch, bundle, jax.random.PRNGKey(0), smoke=smoke)
    stream = _stream_for(arch, cfg, bundle)
    step_fn = jax.jit(bundle.fn, donate_argnums=(0,))
    trainer = FaultTolerantTrainer(
        step_fn, stream, fault_cfg or FaultConfig(), fault_hook=fault_hook
    )
    return trainer, state


def train_smoke(arch_name: str, n_steps: int = 5, cell: str | None = None) -> dict:
    """A few real optimizer steps on CPU; returns loss trajectory."""
    cells = {"lm": "train_4k", "vision": "cls_224", "diffusion": "train_256"}
    arch = get(arch_name)
    cell = cell or cells[arch.family]
    import tempfile

    trainer, state = make_trainer(
        arch_name, cell, fault_cfg=FaultConfig(ckpt_dir=tempfile.mkdtemp(), ckpt_every=1000)
    )
    state, stats = trainer.run(state, n_steps, resume=False)
    return {"losses": stats.losses, "steps": stats.steps}
