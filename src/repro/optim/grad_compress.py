"""Gradient compression for cross-pod data parallelism (beyond-paper feature).

At multi-pod scale the ``pod`` axis rides the slowest links (DCI), so the
cross-pod gradient all-reduce is the dominant collective.  Two standard
compressors, both error-feedback-free and stateless (safe under pjit):

* bf16 compression -- cast grads to bfloat16 *before* the cross-pod psum
  (2x byte reduction; the within-pod reduction stays f32).
* top-k-per-tensor magnitude sparsification with dense fallback for small
  tensors (used by the fault-tolerant trainer when the link budget is tight).

These mirror the HALP idea at another level of the hierarchy: shrink the bytes
that must cross the slow boundary so the transfer hides behind compute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads, like=None):
    dt = jnp.float32
    return jax.tree_util.tree_map(lambda g: g.astype(dt), grads)


def topk_sparsify(g: jax.Array, frac: float = 0.05, min_size: int = 4096):
    """Keep the top-|frac| entries by magnitude (dense mask form -- the sparse
    *byte* accounting is what the roofline uses; XLA ships the masked tensor)."""
    if g.size < min_size:
        return g
    k = max(1, int(g.size * frac))
    flat = g.reshape(-1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0).reshape(g.shape)


def compress_topk(grads, frac: float = 0.05):
    return jax.tree_util.tree_map(lambda g: topk_sparsify(g, frac), grads)
