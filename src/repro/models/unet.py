"""Stable-Diffusion-1.5 U-Net (Rombach et al., arXiv:2112.10752) -- unet-sd15.

Latent-space U-Net: ch=320, ch_mult=(1,2,4,4), 2 res blocks per level,
self+cross attention (ctx_dim=768) at downsample factors 1/2/4, timestep
conditioning.  The conv path is sliding-window (paper partitioning applies);
attention levels synchronise spatially (cheap at low res -- DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import Params, conv_params, dense_params, keygen, norm_params
from .dit import timestep_embedding
from .layers import conv2d, dense, gelu, groupnorm, silu

__all__ = ["UNetConfig", "init", "apply"]


@dataclass(frozen=True)
class UNetConfig:
    name: str = "unet-sd15"
    img_res: int = 512
    latent_ch: int = 4
    ch: int = 320
    ch_mult: tuple[int, ...] = (1, 2, 4, 4)
    n_res_blocks: int = 2
    attn_down: tuple[int, ...] = (1, 2, 4)  # downsample factors with attention
    ctx_dim: int = 768
    ctx_len: int = 77
    n_heads: int = 8
    groups: int = 32
    attn_f32: bool = True  # f32 softmax (training); serving uses bf16 (SD-style fp16 inference)

    @property
    def latent_res(self) -> int:
        return self.img_res // 8


def _res_init(key, c_in, c_out, temb_dim, dtype):
    ks = keygen(key)
    p = {
        "n1": norm_params(c_in, dtype=dtype),
        "c1": conv_params(next(ks), 3, c_in, c_out, dtype=dtype),
        "temb": dense_params(next(ks), temb_dim, c_out, dtype=dtype),
        "n2": norm_params(c_out, dtype=dtype),
        "c2": conv_params(next(ks), 3, c_out, c_out, dtype=dtype),
    }
    if c_in != c_out:
        p["skip"] = conv_params(next(ks), 1, c_in, c_out, dtype=dtype)
    return p


def _res_apply(p, x, temb, groups):
    h = conv2d(silu(groupnorm(x, p["n1"], groups)), p["c1"], padding=1)
    h = h + dense(silu(temb), p["temb"])[:, None, None, :]
    h = conv2d(silu(groupnorm(h, p["n2"], groups)), p["c2"], padding=1)
    skip = conv2d(x, p["skip"], padding="VALID") if "skip" in p else x
    return skip + h


def _attn_init(key, c, ctx_dim, dtype):
    ks = keygen(key)
    return {
        "norm": norm_params(c, dtype=dtype),
        "proj_in": dense_params(next(ks), c, c, dtype=dtype),
        # self-attention
        "sq": dense_params(next(ks), c, c, bias=False, dtype=dtype),
        "sk": dense_params(next(ks), c, c, bias=False, dtype=dtype),
        "sv": dense_params(next(ks), c, c, bias=False, dtype=dtype),
        "so": dense_params(next(ks), c, c, dtype=dtype),
        "n1": norm_params(c, dtype=dtype),
        # cross-attention to the text context
        "cq": dense_params(next(ks), c, c, bias=False, dtype=dtype),
        "ck": dense_params(next(ks), ctx_dim, c, bias=False, dtype=dtype),
        "cv": dense_params(next(ks), ctx_dim, c, bias=False, dtype=dtype),
        "co": dense_params(next(ks), c, c, dtype=dtype),
        "n2": norm_params(c, dtype=dtype),
        # geglu ffn
        "ff1": dense_params(next(ks), c, 8 * c, dtype=dtype),
        "ff2": dense_params(next(ks), 4 * c, c, dtype=dtype),
        "n3": norm_params(c, dtype=dtype),
        "proj_out": dense_params(next(ks), c, c, dtype=dtype),
    }


def _mha(q, k, v, heads, f32=True):
    b, n, c = q.shape
    m = k.shape[1]
    q = q.reshape(b, n, heads, c // heads)
    k = k.reshape(b, m, heads, c // heads)
    v = v.reshape(b, m, heads, c // heads)
    logits = jnp.einsum("bnhd,bmhd->bhnm", q, k) / jnp.sqrt(c / heads)
    if f32:
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(q.dtype)
    else:  # serving: keep the softmax chain in bf16 (halves HBM boundary bytes)
        probs = jax.nn.softmax(logits, -1)
    return jnp.einsum("bhnm,bmhd->bnhd", probs, v).reshape(b, n, c)


def _ln(x, p, eps=1e-5):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["b"]


def _attn_apply(p, x, ctx, heads, groups, f32=True):
    b, h, w, c = x.shape
    res = x
    xn = groupnorm(x, p["norm"], groups)
    t = dense(xn.reshape(b, h * w, c), p["proj_in"])
    # self
    tn = _ln(t, p["n1"])
    t = t + dense(_mha(dense(tn, p["sq"]), dense(tn, p["sk"]), dense(tn, p["sv"]), heads, f32), p["so"])
    # cross
    tn = _ln(t, p["n2"])
    t = t + dense(_mha(dense(tn, p["cq"]), dense(ctx, p["ck"]), dense(ctx, p["cv"]), heads, f32), p["co"])
    # geglu
    tn = _ln(t, p["n3"])
    u = dense(tn, p["ff1"])
    a, g = jnp.split(u, 2, axis=-1)
    t = t + dense(a * gelu(g), p["ff2"])
    return res + dense(t, p["proj_out"]).reshape(b, h, w, c)


def init(key, cfg: UNetConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    ch = cfg.ch
    temb_dim = 4 * ch
    p: Params = {
        "t1": dense_params(next(ks), ch, temb_dim, dtype=dtype),
        "t2": dense_params(next(ks), temb_dim, temb_dim, dtype=dtype),
        "conv_in": conv_params(next(ks), 3, cfg.latent_ch, ch, dtype=dtype),
        "down": [],
        "mid": {},
        "up": [],
        "norm_out": norm_params(ch, dtype=dtype),
        "conv_out": conv_params(next(ks), 3, ch, cfg.latent_ch, dtype=dtype),
    }
    chans = [ch]  # skip-connection channel bookkeeping
    c_cur = ch
    down = []
    for li, mult in enumerate(cfg.ch_mult):
        c_out = ch * mult
        level = {"res": [], "attn": []}
        has_attn = 2**li in cfg.attn_down
        for _ in range(cfg.n_res_blocks):
            level["res"].append(_res_init(next(ks), c_cur, c_out, temb_dim, dtype))
            level["attn"].append(
                _attn_init(next(ks), c_out, cfg.ctx_dim, dtype) if has_attn else {}
            )
            c_cur = c_out
            chans.append(c_cur)
        if li + 1 < len(cfg.ch_mult):
            level["downsample"] = conv_params(next(ks), 3, c_cur, c_cur, dtype=dtype)
            chans.append(c_cur)
        down.append(level)
    p["down"] = down
    p["mid"] = {
        "res1": _res_init(next(ks), c_cur, c_cur, temb_dim, dtype),
        "attn": _attn_init(next(ks), c_cur, cfg.ctx_dim, dtype),
        "res2": _res_init(next(ks), c_cur, c_cur, temb_dim, dtype),
    }
    up = []
    for li, mult in reversed(list(enumerate(cfg.ch_mult))):
        c_out = ch * mult
        level = {"res": [], "attn": []}
        has_attn = 2**li in cfg.attn_down
        for _ in range(cfg.n_res_blocks + 1):
            c_skip = chans.pop()
            level["res"].append(_res_init(next(ks), c_cur + c_skip, c_out, temb_dim, dtype))
            level["attn"].append(
                _attn_init(next(ks), c_out, cfg.ctx_dim, dtype) if has_attn else {}
            )
            c_cur = c_out
        if li > 0:
            level["upsample"] = conv_params(next(ks), 3, c_cur, c_cur, dtype=dtype)
        up.append(level)
    p["up"] = up
    return p


def apply(params: Params, cfg: UNetConfig, x, t, ctx) -> jax.Array:
    """x [B, h, w, latent_ch] (latent), t [B], ctx [B, 77, ctx_dim] -> eps."""
    t_emb = timestep_embedding(t, cfg.ch).astype(x.dtype)
    temb = dense(silu(dense(t_emb, params["t1"])), params["t2"])
    h = conv2d(x, params["conv_in"], padding=1)
    skips = [h]
    for li, level in enumerate(params["down"]):
        for p_res, p_attn in zip(level["res"], level["attn"]):
            h = _res_apply(p_res, h, temb, cfg.groups)
            if p_attn:
                h = _attn_apply(p_attn, h, ctx, cfg.n_heads, cfg.groups, cfg.attn_f32)
            skips.append(h)
        if "downsample" in level:
            h = conv2d(h, level["downsample"], stride=2, padding=1)
            skips.append(h)
    m = params["mid"]
    h = _res_apply(m["res1"], h, temb, cfg.groups)
    h = _attn_apply(m["attn"], h, ctx, cfg.n_heads, cfg.groups, cfg.attn_f32)
    h = _res_apply(m["res2"], h, temb, cfg.groups)
    for li, level in enumerate(params["up"]):
        for p_res, p_attn in zip(level["res"], level["attn"]):
            h = jnp.concatenate([h, skips.pop()], axis=-1)
            h = _res_apply(p_res, h, temb, cfg.groups)
            if p_attn:
                h = _attn_apply(p_attn, h, ctx, cfg.n_heads, cfg.groups, cfg.attn_f32)
        if "upsample" in level:
            b, hh, ww, c = h.shape
            h = jax.image.resize(h, (b, hh * 2, ww * 2, c), "nearest")
            h = conv2d(h, level["upsample"], padding=1)
    h = silu(groupnorm(h, params["norm_out"], cfg.groups))
    return conv2d(h, params["conv_out"], padding=1)
