"""Mixture-of-Experts FFN with capacity-based top-k routing (GShard-style).

The dispatch is expressed as gathers/scatters over an [E, C] slot table so the
expert compute is a single ``einsum('ecd,edf->ecf')`` -- the layout GSPMD
shards cleanly with experts on the ``model`` mesh axis (expert parallelism).
``capacity_factor`` >= E/top_k reproduces dropless routing exactly (used by the
tests' per-token oracle comparison); production configs use ~1.25.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import Params, dense_params, keygen
from .layers import dense, silu

__all__ = ["MoEConfig", "moe_init", "moe_apply", "router_topk"]


@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    n_shared: int = 0  # always-on shared experts (DeepSeek-V3: 1)
    capacity_factor: float = 1.25
    router_bias: bool = False  # aux-loss-free bias (DeepSeek-V3)
    dropless_below: int = 256  # token counts <= this route drop-free (decode)


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff

    def expert_stack(k):
        std = (1.0 / d) ** 0.5
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "w1": std * jax.random.normal(k1, (e, d, f), jnp.float32).astype(dtype),
            "w3": std * jax.random.normal(k2, (e, d, f), jnp.float32).astype(dtype),
            "w2": (1.0 / f) ** 0.5
            * jax.random.normal(k3, (e, f, d), jnp.float32).astype(dtype),
        }

    p = {
        "router": dense_params(next(ks), d, e, bias=False, std=0.02, dtype=dtype),
        "experts": expert_stack(next(ks)),
    }
    if cfg.router_bias:
        p["router_b"] = jnp.zeros((e,), dtype)
    if cfg.n_shared:
        fs = cfg.d_ff * cfg.n_shared
        p["shared"] = {
            "w1": dense_params(next(ks), d, fs, bias=False, dtype=dtype),
            "w3": dense_params(next(ks), d, fs, bias=False, dtype=dtype),
            "w2": dense_params(next(ks), fs, d, bias=False, dtype=dtype),
        }
    return p


def router_topk(p: Params, cfg: MoEConfig, x: jax.Array):
    """x: [T, D] -> (gates [T,k] renormalised, ids [T,k], router probs [T,E])."""
    logits = dense(x, p["router"]).astype(jnp.float32)
    if "router_b" in p:
        logits = logits + p["router_b"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates.astype(x.dtype), ids, probs


def _dispatch(ids: jax.Array, e: int, capacity: int):
    """ids: [T, k] expert assignment -> (slot_expert [T,k], slot_pos [T,k], keep).

    Sort-based position-in-expert (O(N log N) bytes): a stable argsort groups
    the flattened token-major slots by expert; each slot's position is its
    rank minus the first rank of its expert.  Identical assignment semantics
    to the GShard one-hot cumsum (stable sort preserves token-major priority)
    at ~E x lower memory traffic -- the cumsum materialises [T*k, E] and
    prefix-scans it in log passes, which dominated the DeepSeek train-step
    bytes in the baseline roofline (EXPERIMENTS.md §Perf iteration 1)."""
    t, k = ids.shape
    flat = ids.reshape(-1)  # [N = T*k], token-major order
    n = flat.shape[0]
    order = jnp.argsort(flat, stable=True)  # slots grouped by expert
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    sorted_ids = flat[order]
    first_rank = jnp.searchsorted(sorted_ids, jnp.arange(e, dtype=flat.dtype))
    pos = ranks - first_rank[flat].astype(jnp.int32)  # position within expert
    keep = pos < capacity
    return flat.reshape(t, k), pos.reshape(t, k), keep.reshape(t, k)


def moe_apply(p: Params, cfg: MoEConfig, x: jax.Array) -> tuple[jax.Array, dict]:
    """x: [T, D] -> (y [T, D], aux dict with load-balancing stats)."""
    tkn, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    capacity = max(1, int(cfg.capacity_factor * tkn * k / e))
    # dropless for small token counts (decode steps): per-expert load can never
    # exceed the token count, so capacity = tkn makes routing exact.
    if tkn <= cfg.dropless_below:
        capacity = max(capacity, tkn)
    gates, ids, probs = router_topk(p, cfg, x)
    flat_e, pos, keep = _dispatch(ids, e, capacity)

    # gather tokens into [E, C, D] slots; dropped (token, choice) pairs go to a
    # dummy slot so kept slots have exactly one writer.  The sharding hints
    # anchor the dispatch boundary: tokens batch-sharded in, slots
    # expert-sharded out, so GSPMD reshards with one all-to-all instead of
    # all-reducing [N, D] partial products (§Perf deepseek iteration 3).
    from ..parallel.hints import constrain

    x = constrain(x, "moe_tokens")
    dummy = e * capacity
    slot = jnp.where(keep, flat_e * capacity + pos, dummy)  # [T, k]
    token_idx = jnp.broadcast_to(jnp.arange(tkn)[:, None], (tkn, k))
    xs = jnp.zeros((e * capacity + 1, d), x.dtype)
    xs = xs.at[slot.reshape(-1)].set(x[token_idx.reshape(-1)])
    xs = constrain(xs[:-1].reshape(e, capacity, d), "moe_slots")

    w = p["experts"]
    h = jnp.einsum("ecd,edf->ecf", xs, w["w1"])
    g = jnp.einsum("ecd,edf->ecf", xs, w["w3"])
    y_e = jnp.einsum("ecf,efd->ecd", silu(h) * g, w["w2"]).reshape(e * capacity, d)

    # combine back with gates (dropped choices contribute zero)
    y_pad = jnp.concatenate([y_e, jnp.zeros((1, d), y_e.dtype)], axis=0)
    picked = y_pad[slot.reshape(-1)].reshape(tkn, k, d)
    y = constrain(jnp.sum(picked * (gates * keep)[..., None], axis=1), "moe_tokens")

    if cfg.n_shared:
        s = p["shared"]
        y = y + dense(silu(dense(x, s["w1"])) * dense(x, s["w3"]), s["w2"])

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(ids, e, dtype=jnp.float32) * keep[..., None]).sum(1), axis=0
    ) / k
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux
