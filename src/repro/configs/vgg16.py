"""vgg16 -- the paper's own evaluation model (not part of the assigned pool;
used by the HALP reproduction benchmarks and examples)."""
from ..models import vgg
from ..models.vgg import VGGConfig
from .base import Arch, Cell, register

FULL = VGGConfig()
SMOKE = VGGConfig(img_res=64, width_mult=0.125, num_classes=10)

ARCH = register(
    Arch(
        name="vgg16",
        family="convnet",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells={
            "halp_224": Cell("halp_224", "serve", {"img_res": 224, "batch": 1}),
        },
        module=vgg,
        notes="paper model; served through the HALP spatial engine",
    )
)
