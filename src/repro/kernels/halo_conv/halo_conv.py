"""Pallas TPU kernel: HALP-fused spatially-sharded conv.

Inside a shard_map program each device holds x_shard [B, Hs, W, C] plus the
thin halos produced by ppermute (repro.spatial.halo).  The naive path
materialises concat([top_halo, x, bot_halo]) in HBM before convolving; this op
instead assembles only the *boundary row tiles* from the halos and feeds one
``conv2d_tiles`` pallas_call -- the interior tiles gather straight from the
shard.  That is HALP's schedule at kernel granularity: interior compute is
independent of the halos, so XLA's latency-hiding scheduler overlaps the
ppermute with the interior matmuls, and the boundary tiles are the only
consumers of remote data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..conv2d.conv2d import conv2d_tiles
from ..conv2d.ops import _pick_tile_h


def halo_conv2d(
    x_shard: jax.Array,  # [B, Hs, W, C]
    top_halo: jax.Array | None,  # [B, lo, W, C] (already width-aligned with x)
    bot_halo: jax.Array | None,  # [B, hi, W, C]
    weights: jax.Array,  # [k, k, Cin, Cout]
    bias: jax.Array | None = None,
    *,
    padding: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Stride-1 conv over a height shard with explicit halos; returns the
    shard's [B, Hs, W_out, Cout] output rows."""
    k = weights.shape[0]
    lo = 0 if top_halo is None else top_halo.shape[1]
    hi = 0 if bot_halo is None else bot_halo.shape[1]
    assert lo + hi == k - 1, "halos must cover the receptive field"
    b, hs, w, cin = x_shard.shape
    cout = weights.shape[-1]

    def wpad(a):
        return jnp.pad(a, ((0, 0), (0, 0), (padding, padding), (0, 0))) if padding else a

    x = wpad(x_shard)
    w_ext = x.shape[2]
    th = _pick_tile_h(hs, w_ext, cin, cout, k, x.dtype.itemsize)
    nt = hs // th

    # interior tiles (no halo dependence) gather straight from the shard;
    # boundary tiles splice in the halo rows.  Tile t covers extended rows
    # [t*th - lo, t*th + th + hi) where extended row r maps to: top halo for
    # r < 0, shard row r for 0 <= r < hs, bottom halo for r >= hs.
    top_ext = wpad(top_halo) if top_halo is not None else None
    bot_ext = wpad(bot_halo) if bot_halo is not None else None

    def rows(lo_r: int, hi_r: int):  # extended rows [lo_r, hi_r)
        pieces = []
        if lo_r < 0:
            seg = (
                top_ext[:, lo + lo_r : lo + min(hi_r, 0)]
                if top_ext is not None
                else jnp.zeros((b, min(hi_r, 0) - lo_r, w_ext, cin), x.dtype)
            )
            pieces.append(seg)
        mid_lo, mid_hi = max(lo_r, 0), min(hi_r, hs)
        if mid_hi > mid_lo:
            pieces.append(x[:, mid_lo:mid_hi])
        if hi_r > hs:
            seg = (
                bot_ext[:, max(lo_r, hs) - hs : hi_r - hs]
                if bot_ext is not None
                else jnp.zeros((b, hi_r - max(lo_r, hs), w_ext, cin), x.dtype)
            )
            pieces.append(seg)
        return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=1)

    tiles = [rows(t * th - lo, t * th + th + hi) for t in range(nt)]
    x_tiles = jnp.stack(tiles, axis=1)  # [B, nT, TH + k - 1, W_ext, C]
    y = conv2d_tiles(
        x_tiles,
        weights,
        k=k,
        tile_h=th,
        cout_tile=min(cout, 128),
        interpret=interpret,
    )
    y = y.reshape(b, hs, w_ext - (k - 1), cout)
    if bias is not None:
        y = y + bias
    return y
