"""Benchmark entry point: one function per paper table/figure + the roofline
tables derived from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run

Prints human-readable tables interleaved with ``name,us_per_call,derived`` CSV
rows (the scaffold contract).
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    from benchmarks import ablation_overlap, paper_tables, roofline

    print("#" * 72)
    print("# HALP paper reproduction (Li, Iosifidis, Zhang 2022)")
    print("#" * 72)
    paper_tables.run_all()
    ablation_overlap.run()

    print()
    print("#" * 72)
    print("# Roofline analysis from the multi-pod dry-run (EXPERIMENTS.md)")
    print("#" * 72)
    for mesh in ("pod16x16", "pod2x16x16"):
        if list(roofline.RESULTS.glob(f"*__{mesh}.json")):
            roofline.print_table(mesh)
        else:
            print(f"(no dry-run results for {mesh}; run repro.launch.dryrun first)")


if __name__ == "__main__":
    main()
