"""Pure-jnp oracle for the conv2d kernel (no lax.conv -- explicit tap sum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(
    x: jax.Array,
    weights: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: int = 1,
    groups: int = 1,
) -> jax.Array:
    """NHWC x [k,k,Cin,Cout] conv; sum of shifted (strided) einsums.
    ``groups > 1`` is the depthwise case (weights [k, k, 1, C])."""
    k = weights.shape[0]
    s = stride
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    n, h, w, cin = x.shape
    ho, wo = (h - k) // s + 1, (w - k) // s + 1
    if groups > 1 and not (groups == cin == weights.shape[-1] and weights.shape[2] == 1):
        raise ValueError(f"only depthwise groups supported, got groups={groups}")
    acc = jnp.zeros((n, ho, wo, weights.shape[-1]), jnp.float32)
    for ky in range(k):
        for kx in range(k):
            patch = x[
                :, ky : ky + (ho - 1) * s + 1 : s, kx : kx + (wo - 1) * s + 1 : s, :
            ].astype(jnp.float32)
            if groups > 1:
                acc = acc + patch * weights[ky, kx, 0].astype(jnp.float32)
            else:
                acc = acc + jnp.einsum(
                    "nhwc,cd->nhwd", patch, weights[ky, kx].astype(jnp.float32)
                )
    if bias is not None:
        acc = acc + bias
    return acc.astype(x.dtype)
