"""swin-b [vision]: img_res=224 patch=4 window=7 depths=2-2-18-2
dims=128-256-512-1024.  [arXiv:2103.14030; paper]"""
from ..models import swin
from ..models.swin import SwinConfig
from .base import Arch, register, vision_cells

FULL = SwinConfig(name="swin-b", img_res=224, patch=4, window=7,
                  depths=(2, 2, 18, 2), dims=(128, 256, 512, 1024),
                  n_heads=(4, 8, 16, 32))
SMOKE = SwinConfig(name="swin-b-smoke", img_res=64, patch=4, window=4,
                   depths=(2, 2), dims=(32, 64), n_heads=(2, 4), num_classes=10)

ARCH = register(
    Arch(
        name="swin-b",
        family="vision",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=vision_cells(),
        module=swin,
        notes="bounded receptive field (7x7 windows): shifted windows need a "
        "one-window halo -- the transformer analogue of HALP's boundary "
        "exchange (cls_384 uses window 12)",
    )
)
