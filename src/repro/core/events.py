"""Shared HALP event topology: one plan-walk feeding both latency engines.

The closed-form recursion (``repro.core.schedule``) and the discrete-event
simulator (``repro.core.simulator``) must price the *same* jobs and messages
or their cross-validation is meaningless.  Historically each engine re-derived
the message structure from the plan independently; this module centralises it:

* per-slot *dependent* rows (the boundary rows a secondary must compute first
  and ship to its adjacent host zones, paper eq. 16's t_cmp^dep),
* per-zone host chunks (rows each adjacent secondary is waiting for,
  eqs. 11-12 / 18), the initial image slices (eq. 10) and the final sub-output
  merge (eqs. 13-14), and
* :func:`build_halp_dag`, which lays the full job/message DAG onto any
  ``Sim``-compatible scheduler with per-ES platforms and per-link rates drawn
  from a :class:`~repro.core.topology.CollabTopology`.

The closed form consumes the per-layer quantities; the simulator consumes the
DAG.  Both therefore see identical work and identical bytes by construction.
"""
from __future__ import annotations

from dataclasses import dataclass

from .nets import ConvNetGeom, DTYPE_BYTES
from .partition import HALPPlan, Segment, plan_halp_topology
from .topology import CollabTopology

__all__ = [
    "SecStep",
    "ZoneStep",
    "init_bytes",
    "sec_step",
    "zone_step",
    "final_bytes",
    "resolve_halp_setup",
    "build_halp_dag",
    "build_multitask_dag",
]


def resolve_halp_setup(
    net: ConvNetGeom,
    platform=None,
    link=None,
    overlap_rows: int | None = None,
    topology: CollabTopology | None = None,
    ratios=None,
    plan: HALPPlan | None = None,
    host_platform=None,
) -> tuple[CollabTopology, HALPPlan]:
    """Resolve the two calling conventions shared by both latency engines.

    Paper-style ``(platform, link)`` builds the symmetric two-secondary
    topology with the paper's equal split; topology-style takes an explicit
    :class:`CollabTopology` (capacity-weighted ratios by default).  Conflicting
    combinations raise ``TypeError`` instead of silently ignoring arguments."""
    if plan is not None and (ratios is not None or overlap_rows is not None):
        raise TypeError(
            "plan= already fixes the partition; do not also pass "
            "ratios/overlap_rows (they would be silently ignored)"
        )
    if topology is None:
        if platform is None or link is None:
            raise TypeError("pass either (platform, link) or topology=")
        topology = CollabTopology.symmetric(platform, link, host_platform=host_platform)
        if ratios is None:
            ratios = (0.5, 0.5)  # the paper's equal split, not capacity-weighted
    elif platform is not None or link is not None or host_platform is not None:
        raise TypeError(
            "topology= already carries platforms and links; do not also pass "
            "platform/link/host_platform (they would be silently ignored)"
        )
    if plan is None:
        plan = plan_halp_topology(
            net, topology, overlap_rows=4 if overlap_rows is None else overlap_rows,
            ratios=ratios,
        )
    return topology, plan


def init_bytes(plan: HALPPlan, sec_slot: str) -> float:
    """Eq. (10): bytes of the initial image slice sent to a secondary ES."""
    net = plan.net
    seg = plan.parts[0].inp[sec_slot]
    return DTYPE_BYTES * seg.rows * net.in_rows * net.in_channels


def final_bytes(plan: HALPPlan, sec_slot: str) -> float:
    """Eqs. (13)-(14): the g_N sub-output a secondary ships for the head merge."""
    return plan.message_bytes(len(plan.parts) - 1, sec_slot, plan.host)


@dataclass(frozen=True)
class SecStep:
    """One secondary slot's work at one layer."""

    slot: str
    own_rows: int
    dep_rows: int  # boundary rows computed first (sum over adjacent zones)
    sends: tuple[tuple[str, Segment, float], ...]  # (zone, rows, bytes) to host


@dataclass(frozen=True)
class ZoneStep:
    """One host zone's work at one layer: a chunk per adjacent secondary."""

    slot: str
    zone_rows: int
    above: str  # secondary above the zone (its rows are computed first)
    below: str
    rows_for_above: int
    bytes_to_above: float
    bytes_to_below: float


def _union_rows(segs: list[Segment]) -> int:
    """Distinct rows covered by possibly-overlapping segments (a 1-row middle
    secondary can owe the *same* row to both adjacent zones; it computes it
    once)."""
    rows = 0
    cur_hi = 0
    for seg in sorted((s for s in segs if s), key=lambda s: s.lo):
        lo = max(seg.lo, cur_hi + 1)
        if seg.hi >= lo:
            rows += seg.hi - lo + 1
            cur_hi = seg.hi
    return rows


def sec_step(plan: HALPPlan, layer: int, slot: str) -> SecStep:
    own = plan.parts[layer].out[slot]
    if layer + 1 >= len(plan.parts):
        # g_N: the whole sub-output is the boundary (eqs. 13-14).  The seed
        # convention -- kept for every N so cross-N accounting is uniform --
        # prices this send here AND in the final merge; the nominal zone key
        # is inert (no next layer to gate).
        zones = plan.adjacent_zones(slot)
        sends = (
            ((zones[0], own, plan.message_bytes(layer, slot, plan.host)),)
            if own and zones
            else ()
        )
        return SecStep(slot=slot, own_rows=own.rows, dep_rows=own.rows, sends=sends)
    # Adjacent zones are always listed (an empty send still orders the zone's
    # chunk behind the secondary's dep compute); non-adjacent zones appear
    # only when auto-reduced plans route rows into a widened host tail zone
    # (a direct uplink -- the no-secondary-exchange invariant is untouched).
    adjacent = plan.adjacent_zones(slot)
    targets = [*adjacent] + [
        z for z in plan.zone_slots if z not in adjacent and plan.message(layer, slot, z)
    ]
    sends = []
    for z in targets:
        seg = plan.message(layer, slot, z)
        sends.append((z, seg, plan.message_bytes(layer, slot, z)))
    return SecStep(
        slot=slot,
        own_rows=own.rows,
        dep_rows=min(own.rows, _union_rows([seg for _, seg, _ in sends])),
        sends=tuple(sends),
    )


def zone_step(plan: HALPPlan, layer: int, slot: str) -> ZoneStep:
    above, below = plan.adjacent_secondaries(slot)
    m_above = plan.message(layer, slot, above)
    return ZoneStep(
        slot=slot,
        zone_rows=plan.parts[layer].out[slot].rows,
        above=above,
        below=below,
        rows_for_above=m_above.rows,
        bytes_to_above=plan.message_bytes(layer, slot, above),
        bytes_to_below=plan.message_bytes(layer, slot, below),
    )


def _row_flops(net: ConvNetGeom) -> list[float]:
    """Per-layer FLOPs per output row, hoisted once per DAG build (``sizes()``
    is O(layers), so calling it per job would be quadratic)."""
    sizes = net.sizes()
    return [g.flops_per_out_row(sizes[i + 1]) for i, g in enumerate(net.layers)]


def build_halp_dag(sim, plans: list[HALPPlan], topology: CollabTopology) -> list[int]:
    """Lay the full HALP job/message DAG for ``len(plans)`` concurrent tasks.

    Resources: the host ES name (host compute), ``{slot}^{t}`` (secondary
    compute, one instance per task), ``link:a->b`` (directed point-to-point
    links, full duplex).  The host serves the per-task zones in task order
    within each layer (paper §IV.B).  Returns the head job id of every task.

    This is the paper's §IV.B deployment: every task runs the *same* plan on
    its own clone of the secondary group (N x n_tasks distinct secondaries),
    so secondary resources are suffixed per task.  For *physically shared*
    secondaries with per-task plans, see :func:`build_multitask_dag`.
    """
    return _lay_halp_dag(sim, plans, topology, lambda t, s: f"{s}^{t}")


def build_multitask_dag(sim, plans: list[HALPPlan], topology: CollabTopology) -> list[int]:
    """Lay the job/message DAG for ``len(plans)`` tasks on ONE physical pool.

    Unlike :func:`build_halp_dag` (per-task secondary clones), every plan's
    slot names here are *physical* ES names of ``topology``: two tasks that
    name the same secondary contend for it (FIFO), all tasks contend for the
    host, and a directed link ``link:a->b`` is one resource no matter how
    many tasks route over it.  This is the engine behind per-task
    heterogeneous placement (``repro.core.placement``): tasks may carry
    different plans over different sub-topologies, and shared host/link
    contention falls out of the resource naming rather than being modelled
    separately.  Returns the head job id of every task."""
    if not plans:
        raise ValueError("need at least one task plan")
    net = plans[0].net
    host = plans[0].host
    for t, plan in enumerate(plans):
        if plan.net != net:
            raise ValueError(f"task {t}: all tasks must share one network geometry")
        if plan.host != host:
            raise ValueError(f"task {t}: host {plan.host!r} != task 0 host {host!r}")
        for s in plan.secondary_slots:
            if s not in topology.platforms:
                raise ValueError(f"task {t}: secondary {s!r} not in the topology pool")
    return _lay_halp_dag(sim, plans, topology, lambda t, s: s)


def _lay_halp_dag(sim, plans: list[HALPPlan], topology: CollabTopology, sec_res) -> list[int]:
    """Shared DAG builder behind both multi-task deployments.

    ``sec_res(task, slot)`` names the compute resource of a secondary slot
    (and its link endpoints).  Per layer, each secondary computes its
    dependent boundary rows first and ships them to the host zones that need
    them while computing the rest (eq. 16); the host computes each zone's
    rows-for-above chunk, sends it, then the rest, then sends below
    (eq. 18) -- a zone's chunks gate on the boundary messages it consumes
    from the previous layer.
    """
    net = plans[0].net
    host = plans[0].host
    n_layers = len(net.layers)
    row_flops = _row_flops(net)

    def cmp_time(es: str, layer: int, rows: int) -> float:
        return topology.platform_of(es).compute_time(row_flops[layer] * rows)

    last_chunk: dict[tuple[int, str], int | None] = {}
    # (task, sec_slot, layer) -> message jobs the secondary needs before layer
    sec_gate: dict[tuple[int, str, int], list[int]] = {}
    # (task, layer, zone_slot) -> {src_sec: boundary message gating the zone}
    zone_in: dict[tuple[int, int, str], dict[str, int]] = {}

    # initial image distribution host -> secondaries (eq. 10)
    for t, plan in enumerate(plans):
        for s in plan.secondary_slots:
            jid = sim.add(
                f"int[{t}]{s}",
                f"link:{host}->{sec_res(t, s)}",
                topology.link_between(host, s).comm_time(init_bytes(plan, s)),
            )
            sec_gate[(t, s, 0)] = [jid]

    for i in range(n_layers):
        # --- secondaries: dep chunk first, then rest; send dep while resting.
        for t, plan in enumerate(plans):
            for s in plan.secondary_slots:
                step = sec_step(plan, i, s)
                deps = [last_chunk.get((t, s))] + sec_gate.get((t, s, i), [])
                a = sim.add(
                    f"cmp[{t}]{s}.g{i}.dep",
                    sec_res(t, s),
                    cmp_time(s, i, step.dep_rows),
                    deps,
                )
                for z, _seg, nbytes in step.sends:
                    m = sim.add(
                        f"msg[{t}]{s}->{host}.g{i}",
                        f"link:{sec_res(t, s)}->{host}",
                        topology.link_between(s, host).comm_time(nbytes),
                        [a],
                    )
                    if i + 1 < n_layers:
                        zone_in.setdefault((t, i + 1, z), {})[s] = m
                b = sim.add(
                    f"cmp[{t}]{s}.g{i}.rest",
                    sec_res(t, s),
                    cmp_time(s, i, step.own_rows - step.dep_rows),
                    [a],
                )
                last_chunk[(t, s)] = b
        # --- host: per task, zones in row order: chunk for the secondary above,
        # send; chunk the rest (gated on the below secondary's rows), send below.
        for t, plan in enumerate(plans):
            for z in plan.zone_slots:
                step = zone_step(plan, i, z)
                gates = zone_in.get((t, i, z), {})
                a = sim.add(
                    f"cmp[{t}]{z}.g{i}.for_{step.above}",
                    host,
                    cmp_time(host, i, step.rows_for_above),
                    [last_chunk.get((t, host)), gates.get(step.above)],
                )
                s1 = sim.add(
                    f"msg[{t}]{z}->{step.above}.g{i}",
                    f"link:{host}->{sec_res(t, step.above)}",
                    topology.link_between(host, step.above).comm_time(step.bytes_to_above),
                    [a],
                )
                b = sim.add(
                    f"cmp[{t}]{z}.g{i}.rest",
                    host,
                    cmp_time(host, i, step.zone_rows - step.rows_for_above),
                    # the rest chunk consumes every other boundary message the
                    # zone received (positionally below, plus -- in reduced
                    # plans -- any dropped secondary routing into a tail zone)
                    [a] + [m for src, m in gates.items() if src != step.above],
                )
                s2 = sim.add(
                    f"msg[{t}]{z}->{step.below}.g{i}",
                    f"link:{host}->{sec_res(t, step.below)}",
                    topology.link_between(host, step.below).comm_time(step.bytes_to_below),
                    [b],
                )
                last_chunk[(t, host)] = b
                if i + 1 < n_layers:
                    sec_gate.setdefault((t, step.above, i + 1), []).append(s1)
                    sec_gate.setdefault((t, step.below, i + 1), []).append(s2)
                # NOTE: zone rows stay on the host -- no job for the local move.

    # final merge: secondaries ship their g_N sub-outputs; host runs the head.
    heads = []
    for t, plan in enumerate(plans):
        merged = []
        for s in plan.secondary_slots:
            m = sim.add(
                f"final[{t}]{s}->{host}",
                f"link:{sec_res(t, s)}->{host}",
                topology.link_between(s, host).comm_time(final_bytes(plan, s)),
                [last_chunk[(t, s)]],
            )
            merged.append(m)
        h = sim.add(
            f"head[{t}]",
            host,
            topology.platform_of(host).compute_time(net.head_flops),
            merged + [last_chunk[(t, host)]],
        )
        heads.append(h)
    return heads
