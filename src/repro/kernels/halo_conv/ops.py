"""shard_map-level wrapper: ppermute halos + the HALP-fused Pallas conv.

``repro.spatial.halo.conv2d_spatial(engine="pallas")`` is the deployed entry
point (it adds capacity-weighted shards and the lax fallback); this wrapper
stays as the minimal kernels-level form for equal shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .halo_conv import halo_conv2d


def conv2d_spatial_pallas(
    x: jax.Array,  # [B, Hs, W, C] height shard
    weights: jax.Array,
    bias=None,
    *,
    stride: int = 1,
    padding: int = 1,
    groups: int = 1,
    axis_name: str = "sp",
    interpret: bool = False,
) -> jax.Array:
    """Drop-in for repro.spatial.halo.conv2d_spatial (k = weights k) with
    the Pallas kernel as the compute body."""
    k = weights.shape[0]
    lo, hi = padding, k - padding - stride
    n = lax.psum(1, axis_name)
    top = bot = None
    if lo > 0:
        top = lax.ppermute(x[:, -lo:], axis_name, [(i, i + 1) for i in range(n - 1)])
    if hi > 0:
        bot = lax.ppermute(x[:, :hi], axis_name, [(i, i - 1) for i in range(1, n)])
    return halo_conv2d(
        x, top, bot, weights, bias, stride=stride, padding=padding,
        groups=groups, interpret=interpret,
    )
