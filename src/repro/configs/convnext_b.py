"""convnext-b [vision]: img_res=224 depths=3-3-27-3 dims=128-256-512-1024.
[arXiv:2201.03545; paper]"""
from ..models import convnext
from ..models.convnext import ConvNeXtConfig
from .base import Arch, register, vision_cells

FULL = ConvNeXtConfig(name="convnext-b", img_res=224, depths=(3, 3, 27, 3),
                      dims=(128, 256, 512, 1024))
SMOKE = ConvNeXtConfig(name="convnext-b-smoke", img_res=64, depths=(2, 2, 6, 2),
                       dims=(32, 64, 96, 128), num_classes=10)

ARCH = register(
    Arch(
        name="convnext-b",
        family="vision",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=vision_cells(),
        module=convnext,
        notes="pure sliding-window net; 7x7 depthwise = widest halos in the "
        "pool -- flagship for the spatial engine",
    )
)
