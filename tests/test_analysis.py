"""Static analyzer conformance: valid artifacts pass, seeded corruptions are
caught with precise diagnostics.

Every mutation test corrupts a *valid* plan/DAG/template/config in one
targeted way and asserts the analyzer reports the matching check id naming
the corrupted site (layer, slot, stage, job, resource, config field) -- the
"teeth" contract of ``repro.analysis``.  Positive tests pin that the real
committed artifacts (builder-produced plans, builder-laid DAGs, the live
``ReplanConfig`` fingerprint partition) are finding-free, so CI failures from
``tools/check.py`` are always real regressions.
"""
import dataclasses
import inspect
import pickle
import subprocess
import sys
import time
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))  # tools/ is a plain directory, not a package

from repro.analysis import (
    AnalysisError,
    check_dag,
    check_keying,
    check_kernel_geometry,
    check_plan,
    check_plan_kernels,
    check_template,
)
from repro.analysis import plan_check as plan_check_module
from repro.core.events import DagTemplate, _layout_quantities, build_halp_dag
from repro.core.nets import vgg16_geom, vit_l16_geom
from repro.core.optimizer import optimize_plan
from repro.core.partition import (
    EMPTY,
    HALPPlan,
    Segment,
    plan_even,
    plan_halp_topology,
    plan_layout,
    plan_scheme,
)
from repro.core.planstore import PlanStore
from repro.core.simulator import Sim
from tools.precompute_plans import demo_net, demo_topology


# ---------------------------------------------------------------------------
# fixtures / mutation helpers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def net():
    return demo_net()


@pytest.fixture(scope="module")
def topo():
    return demo_topology()


@pytest.fixture(scope="module")
def halp_plan(net, topo):
    return plan_halp_topology(net, topo)


@pytest.fixture(scope="module")
def scheme_plan(net, topo):
    return plan_scheme(net, topo)


@pytest.fixture(scope="module")
def vit_net():
    return vit_l16_geom(in_rows=64, n_blocks=2, d=64, heads=4, d_ff=128)


@pytest.fixture(scope="module")
def vit_plan(vit_net, topo):
    return plan_scheme(vit_net, topo)


def mutate_part(plan: HALPPlan, layer: int, slot: str, out=None, inp=None) -> HALPPlan:
    """One-slot surgical mutation of a (frozen) HALPPlan."""
    part = plan.parts[layer]
    new_out, new_inp = dict(part.out), dict(part.inp)
    if out is not None:
        new_out[slot] = out
    if inp is not None:
        new_inp[slot] = inp
    bad = dataclasses.replace(part, out=new_out, inp=new_inp)
    parts = plan.parts[:layer] + (bad,) + plan.parts[layer + 1 :]
    return dataclasses.replace(plan, parts=parts)


def findings_of(rep, check: str):
    return [f for f in rep.findings if f.check == check]


# ---------------------------------------------------------------------------
# positive: committed artifacts are finding-free
# ---------------------------------------------------------------------------


def test_valid_plans_pass(halp_plan, scheme_plan, vit_plan, net, topo):
    for plan in (halp_plan, scheme_plan, vit_plan, plan_even(net, 3)):
        rep = check_plan(plan)
        assert rep.ok, str(rep)
        assert rep.checks > 0
    lay = plan_layout(net, topo.secondaries, host=topo.host)
    assert check_plan(lay).ok  # layouts are materialised then checked


def test_valid_dag_and_template_pass(net, topo, halp_plan):
    sim = Sim()
    build_halp_dag(sim, [halp_plan], topo)
    rep = check_dag(sim)
    assert rep.ok, str(rep)
    lay = plan_layout(net, topo.secondaries, host=topo.host)
    tmpl = DagTemplate.from_layouts([lay], topo, physical=False)
    rep = check_template(tmpl, _layout_quantities([lay]), topo)
    assert rep.ok, str(rep)


def test_live_keying_partition_is_clean():
    rep = check_keying()
    assert rep.ok, str(rep)


# ---------------------------------------------------------------------------
# plan corruptions
# ---------------------------------------------------------------------------


def test_row_gap_caught(halp_plan):
    slot = next(s for s in halp_plan.es_names if halp_plan.parts[0].out[s])
    seg = halp_plan.parts[0].out[slot]
    bad = mutate_part(halp_plan, 0, slot, out=Segment(seg.lo + 1, seg.hi))
    rep = check_plan(bad)
    gaps = findings_of(rep, "plan.coverage")
    assert gaps and "gap" in gaps[0].detail
    assert "layer 0" in gaps[0].where


def test_row_overlap_caught(halp_plan):
    owners = [s for s in halp_plan.es_names if halp_plan.parts[0].out[s]]
    assert len(owners) >= 2
    slot = owners[1]
    seg = halp_plan.parts[0].out[slot]
    bad = mutate_part(halp_plan, 0, slot, out=Segment(seg.lo - 1, seg.hi))
    rep = check_plan(bad)
    hits = [f for f in findings_of(rep, "plan.coverage") if "overlap" in f.detail]
    assert hits and slot in hits[0].where


def test_tail_gap_caught(halp_plan):
    owners = [s for s in halp_plan.es_names if halp_plan.parts[0].out[s]]
    slot = owners[-1]
    seg = halp_plan.parts[0].out[slot]
    bad = mutate_part(halp_plan, 0, slot, out=Segment(seg.lo, seg.hi - 1))
    rep = check_plan(bad)
    assert any("gap at tail" in f.detail for f in findings_of(rep, "plan.coverage"))


def test_short_halo_caught(halp_plan):
    slot = next(s for s in halp_plan.es_names if halp_plan.parts[0].out[s])
    inp = halp_plan.parts[0].inp[slot]
    bad = mutate_part(halp_plan, 0, slot, inp=Segment(inp.lo + 1, inp.hi))
    rep = check_plan(bad)
    hits = [f for f in findings_of(rep, "plan.rf") if "short halo" in f.detail]
    assert hits and slot in hits[0].where


def test_surplus_input_caught(halp_plan):
    # pick a slot whose exact input range starts past row 1, widen it
    layer, slot = next(
        (i, s)
        for i in range(len(halp_plan.parts))
        for s in halp_plan.es_names
        if halp_plan.parts[i].out.get(s) and halp_plan.parts[i].inp[s].lo > 1
    )
    inp = halp_plan.parts[layer].inp[slot]
    bad = mutate_part(halp_plan, layer, slot, inp=Segment(inp.lo - 1, inp.hi))
    rep = check_plan(bad)
    assert any("surplus input" in f.detail for f in findings_of(rep, "plan.rf"))


def test_idle_slot_with_input_caught(halp_plan):
    slot = next(s for s in halp_plan.es_names if halp_plan.parts[0].out[s])
    bad = mutate_part(halp_plan, 0, slot, out=EMPTY)
    rep = check_plan(bad)
    assert any("unpriced transfer" in f.detail for f in findings_of(rep, "plan.rf"))


def test_auto_reduce_reactivation_caught(halp_plan):
    assert halp_plan.slot_owner, "demo plan should be hosted"
    sec = halp_plan.secondary_slots[-1]
    conv_layers = [
        i
        for i, g in enumerate(halp_plan.net.layers)
        if g.kind != "pool" and halp_plan.parts[i].out[sec]
    ]
    layer = conv_layers[0]
    assert any(
        i > layer for i in conv_layers
    ), "need a later conv layer where the secondary is active again"
    bad = mutate_part(halp_plan, layer, sec, out=EMPTY, inp=EMPTY)
    rep = check_plan(bad)
    hits = findings_of(rep, "plan.reduce")
    assert hits and sec in hits[0].where and "re-activated" in hits[0].detail


def test_attention_row_split_caught(vit_net, topo, halp_plan):
    # graft an attention layer into a row-partitioned HALP plan: layer 1 of
    # the demo plan becomes attn while >1 slot owns its rows
    net = halp_plan.net
    g = net.layers[1]
    attn_g = dataclasses.replace(g, kind="attn", heads=1)
    bad_net = dataclasses.replace(
        net, layers=net.layers[:1] + (attn_g,) + net.layers[2:]
    )
    bad = dataclasses.replace(halp_plan, net=bad_net)
    rep = check_plan(bad)
    hits = findings_of(rep, "plan.scheme")
    assert hits and "no receptive-field row split exists" in hits[0].detail


def test_illegal_scheme_for_stage_caught(vit_plan, vit_net):
    # assign non_penetrative to a stage containing attention layers
    attn_stage = next(
        idx
        for idx, (a, b) in enumerate(vit_plan.spans)
        if any(g.kind == "attn" for g in vit_net.layers[a : b + 1])
    )
    assignment = list(vit_plan.assignment)
    assignment[attn_stage] = "non_penetrative"
    bad = dataclasses.replace(vit_plan, assignment=tuple(assignment))
    rep = check_plan(bad)
    hits = [f for f in findings_of(rep, "plan.scheme") if "illegal" in f.detail]
    assert hits and f"stage {attn_stage}" in hits[0].where


def test_spans_mismatch_caught(scheme_plan):
    bad = dataclasses.replace(scheme_plan, spans=scheme_plan.spans[:-1])
    rep = check_plan(bad)
    hits = [f for f in findings_of(rep, "plan.scheme") if f.where == "stage spans"]
    assert hits


def test_head_divisibility_caught(vit_plan):
    # d=64 heads=4 is valid; heads=3 does not divide 64
    net = vit_plan.net
    layers = tuple(
        dataclasses.replace(g, heads=3) if g.kind == "attn" else g
        for g in net.layers
    )
    bad = dataclasses.replace(vit_plan, net=dataclasses.replace(net, layers=layers))
    rep = check_plan(bad)
    hits = findings_of(rep, "plan.heads")
    assert hits and "not divisible by heads=3" in hits[0].detail


def test_secondary_exchange_caught(halp_plan):
    # a secondary's input reaching past both neighbours into a far shard:
    # widen a later-layer input beyond what adjacency can donate
    plan = halp_plan
    sizes = plan.net.sizes()
    layer = next(
        i
        for i in range(1, len(plan.parts))
        if plan.net.layers[i - 1].kind != "attn"
        and plan.net.layers[i].kind != "attn"
        and plan.parts[i].out.get(plan.es_names[0])
    )
    slot = plan.es_names[0]
    bad = mutate_part(plan, layer, slot, inp=Segment(1, sizes[layer]))
    rep = check_plan(bad)
    # the widened input is simultaneously a surplus-rf and an illegal-message
    # finding; the message-legality one must name the boundary
    assert findings_of(rep, "plan.halo") or findings_of(rep, "plan.rf")
    assert not rep.ok


# ---------------------------------------------------------------------------
# DAG corruptions
# ---------------------------------------------------------------------------


def _demo_sim(halp_plan, topo):
    sim = Sim()
    build_halp_dag(sim, [halp_plan], topo)
    return sim


def test_fifo_cycle_caught(halp_plan, topo):
    sim = _demo_sim(halp_plan, topo)
    # same-resource pair (a, b) with a earlier: forward dep a -> b plus the
    # FIFO edge a -> b's predecessor chain forms a cycle
    by_res = {}
    pair = None
    for job in sim.jobs:
        if job.resource in by_res:
            pair = (by_res[job.resource], job.jid)
            break
        by_res[job.resource] = job.jid
    assert pair is not None
    a, b = pair
    sim.jobs[a].deps = sim.jobs[a].deps + (b,)
    rep = check_dag(sim)
    assert findings_of(rep, "dag.event-order"), "forward dep must be reported"
    hits = findings_of(rep, "dag.deadlock")
    assert hits and "cycle" in hits[0].detail


def test_orphan_transfer_caught(halp_plan, topo):
    sim = _demo_sim(halp_plan, topo)
    last_cmp = max(
        j.jid for j in sim.jobs if not j.resource.startswith("link:")
    )
    src = sim.jobs[last_cmp].resource
    sim.add("stray[0]", f"link:{src}->nowhere", 0.5, deps=[last_cmp])
    rep = check_dag(sim)
    hits = findings_of(rep, "dag.orphan")
    assert hits and "stray[0]" in hits[0].where and "never used" in hits[0].detail


def test_last_layer_double_priced_sends_are_exempt(halp_plan, topo):
    # the seed convention: unconsumed msg[...] before a final[...] on the same
    # link is NOT an orphan (events.sec_step last-layer sends)
    sim = _demo_sim(halp_plan, topo)
    rep = check_dag(sim)
    assert not findings_of(rep, "dag.orphan")
    unconsumed_msgs = [
        j
        for j in sim.jobs
        if j.name.startswith("msg[")
        and j.duration > 0
        and not any(j.jid in other.deps for other in sim.jobs)
    ]
    assert unconsumed_msgs, "demo DAG should exercise the exemption"


def test_transfer_endpoint_mismatch_caught(halp_plan, topo):
    sim = _demo_sim(halp_plan, topo)
    msg = next(j for j in sim.jobs if j.resource.startswith("link:") and j.deps)
    src, dst = msg.resource[5:].split("->", 1)
    msg.resource = f"link:elsewhere->{dst}"
    rep = check_dag(sim)
    hits = findings_of(rep, "dag.transfer")
    assert hits and "would not exist at departure" in hits[0].detail


def test_template_duration_corruption_caught(net, topo):
    lay = plan_layout(net, topo.secondaries, host=topo.host)
    tmpl = DagTemplate.from_layouts([lay], topo, physical=False)
    q = _layout_quantities([lay])
    target = next(j for j, job in enumerate(tmpl.sim.jobs) if job.duration > 0)
    tmpl.nums[target] *= 2.0
    rep = check_template(tmpl, q, topo)
    hits = findings_of(rep, "dag.template")
    assert hits and tmpl.sim.jobs[target].name in hits[0].where


# ---------------------------------------------------------------------------
# kernel geometry
# ---------------------------------------------------------------------------


def test_kernel_support_divergence_caught():
    # force a wrong predicate claim: w=3 < k=5 cannot produce output columns
    rep = check_kernel_geometry(5, 1, 0, w=3, supported=True)
    hits = findings_of(rep, "kernel.support")
    assert hits and "fails to trace" in hits[0].detail


def test_kernel_forfeited_support_caught():
    rep = check_kernel_geometry(3, 1, 1, w=16, supported=False)
    hits = findings_of(rep, "kernel.support")
    assert hits and "forfeited" in hits[0].detail


def test_narrow_width_geometries_rejected_by_predicate():
    # regression pin for the _pallas_supported / halo_conv2d divergence: the
    # predicate now agrees with the kernel on non-positive output widths
    for k, s, p, w in ((5, 1, 0, 3), (4, 2, 0, 3)):
        rep = check_kernel_geometry(k, s, p, w=w, hs=4)
        assert rep.ok, str(rep)


def test_halo_conv2d_narrow_width_error_is_crisp():
    import jax.numpy as jnp

    from repro.kernels.halo_conv import halo_conv2d

    x = jnp.zeros((1, 4, 3, 8))
    top = None
    bot = jnp.zeros((1, 4, 3, 8))
    wts = jnp.zeros((5, 5, 8, 8))
    with pytest.raises(ValueError, match="non-positive output width"):
        halo_conv2d(x, top, bot, wts, stride=1, padding=0)


def test_plan_kernels_pass_on_demo(halp_plan):
    rep = check_plan_kernels(halp_plan)
    assert rep.ok, str(rep)
    assert rep.checks > 0


# ---------------------------------------------------------------------------
# keying lint corruptions (synthetic sources: check_keying takes source text)
# ---------------------------------------------------------------------------

GOOD_STORE_SRC = """
class PlanStore:
    def get(self, key):
        canon = canonical_key(key)
        if row[1] != self.schema_version:
            return None
        return row
"""


def _replan_src(fields, keyed, excluded):
    field_lines = "\n".join(f"    {f}: int = 0" for f in fields)
    excl = ",\n".join(f"    {f!r}: {why!r}" for f, why in excluded.items())
    reads = ", ".join(f"config.{f}" for f in keyed)
    return f"""
FINGERPRINT_EXCLUDED = {{
{excl}
}}

class ReplanConfig:
{field_lines}

class ReplanController:
    def __init__(self, config):
        self._fingerprint = ({reads},)
"""


def test_unkeyed_field_caught():
    src = _replan_src(
        ["alpha", "new_knob"], ["alpha"], {}
    )  # new_knob neither keyed nor excluded
    rep = check_keying(src, GOOD_STORE_SRC)
    hits = findings_of(rep, "keying.unkeyed")
    assert hits and "ReplanConfig.new_knob" == hits[0].where
    assert "silently share stale plan-store entries" in hits[0].detail


def test_stale_exclusion_caught():
    src = _replan_src(["alpha"], ["alpha"], {"gone": "this field was removed long ago"})
    rep = check_keying(src, GOOD_STORE_SRC)
    hits = findings_of(rep, "keying.stale-exclusion")
    assert hits and "'gone'" in hits[0].where


def test_missing_justification_caught():
    src = _replan_src(["alpha", "beta"], ["alpha"], {"beta": "perf"})
    rep = check_keying(src, GOOD_STORE_SRC)
    hits = findings_of(rep, "keying.no-justification")
    assert hits and "'beta'" in hits[0].where


def test_contradiction_caught():
    src = _replan_src(
        ["alpha"], ["alpha"], {"alpha": "excluded for a very well argued reason"}
    )
    rep = check_keying(src, GOOD_STORE_SRC)
    assert findings_of(rep, "keying.contradiction")


def test_store_veto_removal_caught():
    src = _replan_src(["alpha"], ["alpha"], {})
    bad_store = """
class PlanStore:
    def get(self, key):
        return pickle.loads(row[2])
"""
    rep = check_keying(src, bad_store)
    hits = findings_of(rep, "keying.store-veto")
    details = " ".join(f.detail for f in hits)
    assert "hash collision" in details and "schema" in details


# ---------------------------------------------------------------------------
# plan-store wiring: corrupt rows degrade to misses, never serve
# ---------------------------------------------------------------------------


def test_store_garbage_payload_invalidated(tmp_path, net, topo):
    store = PlanStore(tmp_path / "s.sqlite")
    key = (("plan", "k"), (0,))
    store.put(key, optimize_plan(net, topo, max_rounds=1))
    assert store.get(key) is not None
    store._conn.execute("UPDATE plans SET payload = ?", (b"\x80garbage",))
    store._conn.commit()
    assert store.get(key) is None
    assert store.invalid == 1 and store.misses == 1
    assert len(store) == 0, "the corrupt row must be deleted"


def test_store_corrupt_plan_invalidated(tmp_path, net, topo):
    store = PlanStore(tmp_path / "s.sqlite")
    key = (("plan", "k"), (0,))
    res = optimize_plan(net, topo, max_rounds=1)
    store.put(key, res)
    plan = res.plan
    slot = next(s for s in plan.es_names if plan.parts[0].out[s])
    seg = plan.parts[0].out[slot]
    bad = dataclasses.replace(
        res, plan=mutate_part(plan, 0, slot, out=Segment(seg.lo + 1, seg.hi))
    )
    store._conn.execute("UPDATE plans SET payload = ?", (pickle.dumps(bad),))
    store._conn.commit()
    assert store.get(key) is None
    assert store.invalid == 1
    assert len(store) == 0
    assert store.stats()["invalid"] == 1


def test_store_non_plan_payloads_pass_through(tmp_path):
    store = PlanStore(tmp_path / "s.sqlite")
    store.put((("plan", "k"), (0,)), "just-a-string")
    assert store.get((("plan", "k"), (0,))) == "just-a-string"
    assert store.hits == 1 and store.invalid == 0


# ---------------------------------------------------------------------------
# verify= gates
# ---------------------------------------------------------------------------


def test_optimize_plan_verify_passes(net, topo):
    res = optimize_plan(net, topo, max_rounds=1, verify=True)
    assert check_plan(res.plan).ok


def test_run_plan_verify_rejects_corrupt_plan(halp_plan):
    import jax.numpy as jnp

    from repro.spatial.partition_apply import run_plan

    slot = next(s for s in halp_plan.es_names if halp_plan.parts[0].out[s])
    seg = halp_plan.parts[0].out[slot]
    bad = mutate_part(halp_plan, 0, slot, out=Segment(seg.lo + 1, seg.hi))
    x = jnp.zeros((1, bad.net.in_rows, bad.net.in_rows, bad.net.in_channels))
    with pytest.raises(AnalysisError) as exc:
        run_plan(bad, [None] * len(bad.net.layers), None, x, verify=True)
    assert "plan.coverage" in str(exc.value)


# ---------------------------------------------------------------------------
# performance / purity contracts
# ---------------------------------------------------------------------------


def test_plan_check_is_fast_on_full_vgg16(topo):
    plan = plan_scheme(vgg16_geom(), topo)
    times = []
    for _ in range(2):
        t0 = time.perf_counter()
        rep = check_plan(plan)
        times.append(time.perf_counter() - t0)
    assert rep.ok, str(rep)
    assert min(times) < 0.05, f"plan_check took {min(times) * 1e3:.1f} ms"


def test_plan_check_never_imports_jax():
    src = inspect.getsource(plan_check_module)
    assert "import jax" not in src


def test_check_cli_exit_codes(tmp_path):
    env = dict(PYTHONPATH=str(REPO / "src"), PATH="/usr/bin:/bin:/usr/local/bin")
    ok = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check.py")],
        capture_output=True, text=True, env=env,
    )
    assert ok.returncode == 0, ok.stdout + ok.stderr
    # a store with one undeserializable row must fail the CLI
    store = PlanStore(tmp_path / "bad.sqlite")
    store.put((("plan", "k"), (0,)), "placeholder")
    store._conn.execute("UPDATE plans SET payload = ?", (b"\x80garbage",))
    store._conn.commit()
    store.close()
    bad = subprocess.run(
        [
            sys.executable,
            str(REPO / "tools" / "check.py"),
            "--store",
            str(tmp_path / "bad.sqlite"),
        ],
        capture_output=True, text=True, env=env,
    )
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "store.payload" in bad.stdout
