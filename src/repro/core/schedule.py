"""HALP / MoDNN latency models (paper §IV, eqs. 10-23) + platform calibration.

Two latency engines exist in this package:

* this module -- the paper's *closed-form recursions* implemented verbatim
  (eqs. 16-20 single task, eqs. 22-23 multi-task, plus the MoDNN baseline as the
  paper describes it in §I/§V), and
* ``repro.core.simulator`` -- an exact discrete-event simulation of the same
  job/message DAG, used as ground truth by the benchmarks.

Platform efficiency is *calibrated* against the paper's own anchor timings
(§V.C: t_pre = 4.7 ms for VGG-16 on the GTX 1080TI; Table II: 124 fps on the
Jetson AGX Xavier), because the paper's measured times do not follow peak-FLOP
arithmetic exactly (cuDNN effects).  Every downstream number (Figs. 6-7,
Tables II-III) is then *derived*, not fitted.
"""
from __future__ import annotations

from dataclasses import dataclass

from .nets import ConvNetGeom, DTYPE_BYTES, vgg16_geom
from .partition import E0, E1, E2, HALPPlan, plan_even, plan_halp

__all__ = [
    "Platform",
    "Link",
    "GTX_1080TI",
    "AGX_XAVIER",
    "TPU_V5E",
    "standalone_time",
    "halp_closed_form",
    "modnn_time",
    "speedup_ratio",
]


@dataclass(frozen=True)
class Platform:
    name: str
    peak_flops: float  # advertised peak (fp32 for the paper's GPUs)
    eff_flops: float  # calibrated effective FLOP/s

    def compute_time(self, flops: float) -> float:
        return flops / self.eff_flops


@dataclass(frozen=True)
class Link:
    rate_bps: float  # bits per second

    def comm_time(self, nbytes: float) -> float:
        return 8.0 * nbytes / self.rate_bps


def _calibrated(name: str, peak: float, t_pre_vgg16: float) -> Platform:
    eff = vgg16_geom().total_flops() / t_pre_vgg16
    return Platform(name=name, peak_flops=peak, eff_flops=eff)


# Paper anchors: §V.C gives t_pre = 4.7 ms (1080TI); Table II gives 124 fps for
# the pre-trained model on Xavier => 4 frames / 124 fps = 32.26 ms per batch,
# which the paper treats as t_pre (perfect batch amortisation; see DESIGN.md).
GTX_1080TI = _calibrated("GTX 1080TI", peak=11.3e12, t_pre_vgg16=4.7e-3)
AGX_XAVIER = _calibrated("JETSON AGX Xavier", peak=1.3e12, t_pre_vgg16=4.0 / 124.0)
# TPU v5e (the deployment target of the framework; used by spatial/ analyses).
TPU_V5E = Platform(name="TPU v5e", peak_flops=197e12, eff_flops=0.55 * 197e12)


def standalone_time(net: ConvNetGeom, platform: Platform) -> float:
    """t_pre: the whole task on one ES (eq. 21 denominator)."""
    return platform.compute_time(net.total_flops())


def speedup_ratio(t: float, t_pre: float) -> float:
    """Paper eq. (21): rho = 1 - T/t_pre (plotted in Figs. 6-7)."""
    return 1.0 - t / t_pre


def _init_bytes(plan: HALPPlan, es: str) -> float:
    """Eq. (10): bytes of the initial image slice sent to a secondary ES."""
    net = plan.net
    seg = plan.parts[0].inp[es]
    return DTYPE_BYTES * seg.rows * net.in_rows * net.in_channels


def halp_closed_form(
    net: ConvNetGeom,
    platform: Platform,
    link: Link,
    overlap_rows: int = 4,
    n_tasks: int = 1,
) -> dict:
    """Paper eqs. (16)-(20) (single task) and (22)-(23) (multi-task).

    For ``n_tasks > 1`` the host processes the per-task overlap zones
    sequentially within each layer (paper §IV.B) while K independent secondary
    pairs compute; the recursion below is the paper's, with the host term
    replaced by eq. (22).
    """
    plan = plan_halp(net, overlap_rows=overlap_rows)
    n_layers = len(net.layers)
    width = net.sizes()

    def cmp_rows(i: int, rows: int) -> float:
        return platform.compute_time(net.layers[i].flops_per_out_row(width[i + 1]) * rows)

    # Per-layer ingredient times (identical for e1 and e2 up to a row).
    T_sec = {E1: 0.0, E2: 0.0}  # eq. 17 accumulators
    T_host = 0.0  # eq. 19 accumulator
    per_layer = []
    for i in range(n_layers):
        t_sec_arrival = {}
        for ek in (E1, E2):
            dep = plan.message(i, ek, E0)
            own = plan.parts[i].out[ek]
            t_cmp_dep = cmp_rows(i, dep.rows)
            t_com_dep = link.comm_time(plan.message_bytes(i, ek, E0)) * n_tasks
            t_cmp_rest = cmp_rows(i, own.rows - dep.rows)
            t_int = link.comm_time(_init_bytes(plan, ek)) if i == 0 else 0.0
            # eq. (16)
            t_layer = t_int + t_cmp_dep + max(t_com_dep, t_cmp_rest)
            prev = T_sec[ek]
            T_sec[ek] = prev + t_layer  # eq. (17)
            # arrival of ek's boundary rows at the host (second term of eq. 19)
            t_sec_arrival[ek] = prev + t_int + t_cmp_dep + t_com_dep
        # host term: eq. (18) single task, eq. (22) multi-task
        m1 = plan.message(i, E0, E1)
        zone = plan.parts[i].out[E0]
        t_cmp_a = cmp_rows(i, m1.rows)
        t_cmp_b = cmp_rows(i, zone.rows - m1.rows)
        t_com_1 = link.comm_time(plan.message_bytes(i, E0, E1))
        t_com_2 = link.comm_time(plan.message_bytes(i, E0, E2))
        if i == n_layers - 1:
            t_host = cmp_rows(i, zone.rows)
        elif n_tasks == 1:
            t_host = t_cmp_a + max(t_com_1, t_cmp_b + t_com_2)  # eq. (18)
        else:
            # eq. (22): K tasks' overlap zones computed sequentially; the m-th
            # pair's send starts after the first m zone computations.
            t_zone = t_cmp_a + t_cmp_b
            t_host = max(
                m * t_zone + max(t_com_1, t_com_2) for m in range(1, n_tasks + 1)
            )
        # eq. (19)
        T_host = max(t_host + T_host, max(t_sec_arrival.values()))
        per_layer.append(
            dict(layer=net.layers[i].name, T_host=T_host, T_e1=T_sec[E1], T_e2=T_sec[E2])
        )

    # g_N: secondaries ship their full sub-outputs to the host (eqs. 13-14),
    # which merges them and runs the head (FLs).
    t_final_com = max(
        link.comm_time(plan.message_bytes(n_layers - 1, ek, E0)) for ek in (E1, E2)
    ) * n_tasks
    T_gn = max(T_host, max(T_sec.values()) + t_final_com)  # eq. (20)
    t_head = platform.compute_time(net.head_flops) * n_tasks
    total = T_gn + t_head  # eq. (15)
    return dict(total=total, per_layer=per_layer, plan=plan)


def modnn_time(
    net: ConvNetGeom,
    platform: Platform,
    link: Link,
    n_workers: int,
) -> float:
    """MoDNN-style conventional layer-wise parallelization (paper Fig. 3, §I).

    Workers hold an even slice; after each CL all boundary rows are exchanged
    *synchronously through the host* (compute and communication do not overlap),
    serialised on the host NIC.  This is the paper's baseline behaviour: the
    per-layer time is max-worker-compute + gather + scatter.
    """
    plan = plan_even(net, n_workers)
    width = net.sizes()
    total = 0.0
    names = plan.es_names
    host = names[0]
    # initial scatter of the image slices to the n-1 non-host workers
    total += sum(
        link.comm_time(DTYPE_BYTES * plan.parts[0].inp[w].rows * net.in_rows * net.in_channels)
        for w in names[1:]
    )
    for i in range(len(net.layers)):
        cmp = max(
            platform.compute_time(
                net.layers[i].flops_per_out_row(width[i + 1]) * plan.parts[i].out[w].rows
            )
            for w in names
        )
        gather = scatter = 0.0
        for a in names:
            for b in names:
                if a == b:
                    continue
                nbytes = plan.message_bytes(i, a, b)
                if nbytes == 0.0:
                    continue
                if b == host:
                    gather += link.comm_time(nbytes)
                elif a == host:
                    scatter += link.comm_time(nbytes)
                else:  # worker->worker routed via the host: counts both ways
                    gather += link.comm_time(nbytes)
                    scatter += link.comm_time(nbytes)
        total += cmp + gather + scatter
    # final merge of all sub-outputs to the host + head
    total += sum(
        link.comm_time(plan.net.feature_bytes(len(net.layers) - 1, plan.parts[-1].out[w].rows))
        for w in names[1:]
    )
    total += platform.compute_time(net.head_flops)
    return total
