"""Execute a HALP plan segment-by-segment and verify losslessness (paper §II-§IV).

This is the paper's collaboration scheme as *executable dataflow*: each slot's
feature rows are materialised separately, and the input of every layer segment
is reconstructed **strictly** from (a) rows the slot computed itself and (b)
the inter-slot messages the plan prescribes (eqs. 10-14 / exact range algebra).
If the plan's messages were insufficient, reconstruction would fail loudly --
so equality with the single-device reference proves both the receptive-field
partitioning *and* the message algebra.

The executor is topology-agnostic: it walks ``plan.es_names`` generically, so
the same code runs the paper's symmetric ``(e1, e0, e2)`` triple, N-way
capacity-weighted heterogeneous plans (``plan_halp_n`` with skewed ratios and
multiple host zones), and the worker splits of the TPU spatial engine --
including capacity-weighted ``plan_even(..., ratios=...)`` splits for pods
mixing device generations (row shares proportional to per-device FLOP/s).
This is the correctness backstop for every plan the optimizer may propose,
batched or scalar (the batched engine's layouts materialise through the very
same ``plan_from_layout`` path this executor consumes).

Runs on a single device (no shard_map): this is the semantic model. The SPMD
deployment form lives in ``repro.spatial.halo``.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from ..core.nets import ConvNetGeom
from ..core.partition import (
    HALPPlan,
    SCHEME_HALO,
    SCHEME_HOST,
    SCHEME_HS,
    SCHEME_NP,
    SchemePlan,
    Segment,
    _split_counts,
)

__all__ = ["run_plan", "segment_forward"]


def _raw_range(o_lo: int, o_hi: int, k: int, s: int, p: int) -> tuple[int, int]:
    """Unclipped input range (may extend into the zero padding)."""
    return (o_lo - 1) * s + 1 - p, (o_hi - 1) * s + k - p


def segment_forward(apply_layer, params, geom, x_rows: jax.Array, seg: Segment,
                    avail: Segment, in_rows: int) -> jax.Array:
    """Compute output rows ``seg`` of one layer given input rows ``avail``
    (a contiguous, 1-indexed slice of the layer input held in ``x_rows``)."""
    raw_lo, raw_hi = _raw_range(seg.lo, seg.hi, geom.k, geom.s, geom.p)
    lo, hi = max(raw_lo, 1), min(raw_hi, in_rows)
    if not (avail.lo <= lo and hi <= avail.hi):
        raise AssertionError(
            f"insufficient rows: need {lo}..{hi}, have {avail.lo}..{avail.hi}"
        )
    sl = x_rows[:, lo - avail.lo : hi - avail.lo + 1]
    pad_top = lo - raw_lo
    pad_bot = raw_hi - hi
    padw = geom.p if geom.kind != "pool" else 0
    if pad_top or pad_bot or padw:
        sl = jnp.pad(sl, ((0, 0), (pad_top, pad_bot), (padw, padw), (0, 0)))
    y = apply_layer(params, geom, sl)
    assert y.shape[1] == seg.rows, (y.shape, seg)
    return y


def run_plan(
    plan: "HALPPlan | SchemePlan",
    layer_params: list,
    apply_layer,
    x: jax.Array,
    time_observer: Callable[[str, float, float], None] | None = None,
    verify: bool = False,
) -> jax.Array:
    """Run the full plan; returns the merged final feature map (host side).

    ``apply_layer(params, geom, x_slice)`` must be the VALID-padding layer
    primitive (``repro.models.vgg.apply_layer`` or compatible).

    ``time_observer(es, flops, elapsed_s)``: zero-config per-ES timing
    attribution.  When set, every ES's segments are executed synchronously
    (``block_until_ready``) and, once per call, the observer receives that
    ES's total FLOP count (exact row algebra via ``net.layer_flops``) and
    measured wall-clock -- the ``(es, flops, elapsed)`` sample
    :meth:`~repro.runtime.serve.BatchingEngine.observe_es_time` /
    :class:`~repro.core.replan.ComputeRateEstimator` expect, with no manual
    bookkeeping in the serving executor.  Timing requires eager per-segment
    execution, so do not wrap the whole ``run_plan`` in ``jax.jit`` when
    observing (jit ``apply_layer`` instead to keep the kernels compiled).

    ``plan`` may also be a :class:`~repro.core.partition.SchemePlan`: each
    segment then executes under its own scheme (halo segments recurse through
    this very function on their sub-plan) and the observer receives samples
    attributed to physical ES names across all segments.

    ``verify=True`` statically verifies the plan
    (:func:`repro.analysis.check_plan` -- coverage, receptive-field halos,
    message legality) before touching any array, raising
    :class:`repro.analysis.AnalysisError` instead of producing a silently
    wrong feature map from a corrupted plan."""
    if verify:
        from ..analysis import check_plan

        check_plan(plan).raise_if_failed("run_plan")
    if isinstance(plan, SchemePlan):
        return _run_scheme_plan(plan, layer_params, apply_layer, x, time_observer)
    net: ConvNetGeom = plan.net
    sizes = net.sizes()
    es_names = plan.es_names

    # initial distribution: each ES receives its eq.-(10) image slice
    avail: dict[str, tuple[Segment, jax.Array]] = {}
    for es in es_names:
        seg = plan.parts[0].inp[es]
        avail[es] = (seg, x[:, seg.lo - 1 : seg.hi])

    flops_acc = {es: 0.0 for es in es_names}
    secs_acc = {es: 0.0 for es in es_names}

    outs: dict[str, jax.Array] = {}
    for i, g in enumerate(net.layers):
        part = plan.parts[i]
        outs = {}
        for es in es_names:
            if not part.out[es]:
                outs[es] = None
                continue
            t0 = time.perf_counter() if time_observer else 0.0
            y = segment_forward(
                apply_layer, layer_params[i], g, avail[es][1], part.out[es],
                avail[es][0], sizes[i],
            )
            if time_observer:
                jax.block_until_ready(y)
                secs_acc[es] += time.perf_counter() - t0
                flops_acc[es] += net.layer_flops(i, part.out[es].rows)
            outs[es] = y
        if i + 1 == len(net.layers):
            break
        # message exchange: every ES's next-layer input = own rows + messages
        new_avail = {}
        for dst in es_names:
            pieces: list[tuple[Segment, jax.Array]] = []
            own = part.out[dst]
            if own:
                pieces.append((own, outs[dst]))
            for src in es_names:
                seg = plan.message(i, src, dst)
                if seg:
                    src_seg = part.out[src]
                    sl = outs[src][:, seg.lo - src_seg.lo : seg.hi - src_seg.lo + 1]
                    pieces.append((seg, sl))
            if not pieces:  # ES owns no rows at this depth (tiny feature map)
                new_avail[dst] = (Segment(1, 0), None)
                continue
            pieces.sort(key=lambda t: t[0].lo)
            for (a, _), (b, _) in zip(pieces, pieces[1:]):
                if b.lo != a.hi + 1:
                    raise AssertionError(f"non-contiguous input for {dst} at layer {i}")
            seg_all = Segment(pieces[0][0].lo, pieces[-1][0].hi)
            new_avail[dst] = (seg_all, jnp.concatenate([t[1] for t in pieces], axis=1))
        avail = new_avail

    if time_observer:
        for es in es_names:
            if flops_acc[es] > 0 and secs_acc[es] > 0:
                time_observer(es, flops_acc[es], secs_acc[es])

    # final merge on the host (paper: sub-outputs -> FL input)
    ordered = sorted(es_names, key=lambda es: plan.parts[-1].out[es].lo)
    return jnp.concatenate([outs[es] for es in ordered if plan.parts[-1].out[es]], axis=1)


def _slice_last_axis(params, lo: int, hi: int):
    """Every array leaf's last axis restricted to ``[lo, hi)`` -- the shared
    shard selector for output-channel splits (conv ``w``/``b``) and head-major
    Q/K/V splits (slicing ``[lo*dh, hi*dh)`` picks whole heads)."""
    return jax.tree_util.tree_map(lambda a: a[..., lo:hi], params)


def _bounds(counts: list[int]) -> list[int]:
    out = [0]
    for c in counts:
        out.append(out[-1] + c)
    return out


def _run_scheme_plan(
    plan: SchemePlan,
    layer_params: list,
    apply_layer,
    x: jax.Array,
    time_observer: Callable[[str, float, float], None] | None,
) -> jax.Array:
    """Execute a mixed-scheme plan segment-by-segment (hub model).

    The host holds the full feature map at every segment boundary.  Halo
    segments recurse through :func:`run_plan` on their sub-plan (row algebra
    verified there); hub segments materialise each secondary's shard from
    *exactly* the slice of parameters/input its scheme prescribes -- a
    non-penetrative secondary only ever sees its filter slice, a head/sequence
    secondary its head or token-row range -- and concatenation along the split
    axis reconstructs the layer output, so equality with the single-device
    reference proves the scheme's losslessness the same way the halo
    executor's strict reconstruction does."""
    net: ConvNetGeom = plan.net
    sizes = net.sizes()
    host = plan.host
    all_es = (*plan.secondaries, host)
    flops_acc = {es: 0.0 for es in all_es}
    secs_acc = {es: 0.0 for es in all_es}

    def acc(es: str, fl: float, dt: float) -> None:
        flops_acc[es] += fl
        secs_acc[es] += dt

    def timed(es: str, fl: float, fn):
        if time_observer is None:
            return fn()
        t0 = time.perf_counter()
        y = fn()
        jax.block_until_ready(y)
        acc(es, fl, time.perf_counter() - t0)
        return y

    for seg, hp in zip(plan.segments, plan.halo_plans):
        if seg.scheme == SCHEME_HALO:
            sub_obs = (
                (lambda slot, fl, dt, _hp=hp: acc(_hp.owner_of(slot), fl, dt))
                if time_observer
                else None
            )
            x = run_plan(
                hp,
                layer_params[seg.start : seg.stop + 1],
                apply_layer,
                x,
                time_observer=sub_obs,
            )
            continue
        for off in range(seg.stop - seg.start + 1):
            i = seg.start + off
            g = net.layers[i]
            avail = Segment(1, sizes[i])
            full_out = Segment(1, sizes[i + 1])
            if seg.scheme == SCHEME_HOST:
                x = timed(
                    host,
                    net.layer_flops(i),
                    lambda: segment_forward(
                        apply_layer, layer_params[i], g, x, full_out, avail, sizes[i]
                    ),
                )
                continue
            pieces: list[jax.Array] = []
            if seg.scheme == SCHEME_NP:
                b = _bounds(_split_counts(g.c_out, plan.ratios))
                for j, es in enumerate(plan.secondaries):
                    lo, hi = b[j], b[j + 1]
                    if lo == hi:
                        continue
                    frac = (hi - lo) / g.c_out
                    if g.kind == "conv":
                        # dense filters: full input, a slice of the filters
                        y = timed(
                            es,
                            net.layer_flops(i) * frac,
                            lambda: segment_forward(
                                apply_layer,
                                _slice_last_axis(layer_params[i], lo, hi),
                                g, x, full_out, avail, sizes[i],
                            ),
                        )
                    else:
                        # channel-local (pool/depthwise): slice of the channels
                        p = (
                            _slice_last_axis(layer_params[i], lo, hi)
                            if layer_params[i]
                            else layer_params[i]
                        )
                        y = timed(
                            es,
                            net.layer_flops(i) * frac,
                            lambda: segment_forward(
                                apply_layer, p, g, x[..., lo:hi], full_out,
                                avail, sizes[i],
                            ),
                        )
                    pieces.append(y)
                x = jnp.concatenate(pieces, axis=-1)
            elif seg.scheme == SCHEME_HS:
                if g.kind == "attn":
                    dh = g.c_in // g.heads
                    b = _bounds(_split_counts(g.heads, plan.ratios))
                    for j, es in enumerate(plan.secondaries):
                        lo, hi = b[j] * dh, b[j + 1] * dh
                        if lo == hi:
                            continue
                        frac = (b[j + 1] - b[j]) / g.heads
                        y = timed(
                            es,
                            net.layer_flops(i) * frac,
                            lambda: apply_layer(
                                _slice_last_axis(layer_params[i], lo, hi), g, x
                            ),
                        )
                        pieces.append(y)
                    x = jnp.concatenate(pieces, axis=-1)
                else:
                    b = _bounds(_split_counts(sizes[i + 1], plan.ratios))
                    for j, es in enumerate(plan.secondaries):
                        rows = Segment(b[j] + 1, b[j + 1])
                        if not rows:
                            continue
                        y = timed(
                            es,
                            net.layer_flops(i, rows.rows),
                            lambda: segment_forward(
                                apply_layer, layer_params[i], g, x, rows,
                                avail, sizes[i],
                            ),
                        )
                        pieces.append(y)
                    x = jnp.concatenate(pieces, axis=1)
            else:
                raise AssertionError(f"unknown scheme {seg.scheme!r}")

    if time_observer:
        for es in all_es:
            if flops_acc[es] > 0 and secs_acc[es] > 0:
                time_observer(es, flops_acc[es], secs_acc[es])
    return x
