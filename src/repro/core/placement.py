"""Per-task heterogeneous placement: one ES pool, one sub-topology per task.

The paper's multi-task extension (§IV, eq. 22) deploys every task on an
identical clone of one secondary group and shares only the host; its
reliability results under time-variant channels -- and DistrEdge's per-device
adaptive splits (arXiv 2202.01699) -- show the win on a *heterogeneous*
cluster comes from matching each task's placement to current per-ES /
per-link conditions instead.  This module does that end to end:

* :class:`TaskPlacement` -- the assignment itself: a partition of the pool's
  secondaries into per-task groups, plus one :class:`~repro.core.partition.HALPPlan`
  per task over its sub-topology (fastest ES first, so thin-layer
  auto-reduction sheds the weakest member).

* :func:`place_tasks` -- the placement optimizer: greedy capacity-weighted
  (LPT-style) assignment of secondaries to tasks, a local-search pass that
  swaps/moves ESs between tasks, and per-task plan-knob refinement via
  :func:`~repro.core.optimizer.optimize_plan` (warm-started from the
  incumbent plan's knobs).  Candidates are scored by the discrete-event
  simulator through :func:`~repro.core.events.build_multitask_dag`, which
  keys resources by *physical* ES/link names -- shared host and link
  contention across tasks is therefore modelled by construction, not
  estimated.  With ``engine="batched"`` (default) each pair-scan's swap/move
  neighbourhood is priced speculatively as one
  :class:`~repro.core.events.MultitaskBatchEvaluator` sweep (plan layouts +
  cached multi-task DAG templates + ``Sim.run_batch``) with an
  assignment-keyed memo; ``engine="scalar"`` keeps the historical
  one-candidate-at-a-time pricing callable as the benchmark baseline.  The
  engines share the search loop and score bit-identically, so they return
  the same placement.

* :func:`shared_plan_placement` -- the paper-faithful baseline the benchmark
  compares against: secondaries grouped in pool order, every task running the
  same equal-split plan geometry (no capacity awareness anywhere).

* :class:`PlacementController` -- the online loop: the
  :class:`~repro.core.replan.ReplanController` machinery (EWMA link-rate AND
  per-ES compute-rate estimates -> quantised buckets -> shared hysteresis ->
  cache), but a bucket switch -- whether a link band or a straggling ES's
  compute band moved -- re-*places* every task instead of re-optimising one
  shared plan.
  ``predicted_latency`` prices a batch by tiling the active placement's plans
  over the batch's tasks and simulating them on the shared pool, so
  :func:`~repro.runtime.serve.plan_aware_batch_size` admits batches against
  the true contended makespan.

Plans are geometry-only row partitions, so every placement is lossless by
construction; ``tests/test_placement.py`` executes random placements through
``spatial/partition_apply.run_plan`` to prove it, and
``benchmarks/multitask_placement.py`` reproduces the paper's 4-tasks-per-batch
scenario with per-task placement beating the shared-plan baseline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

from .events import MultitaskBatchEvaluator, _layout_cached, build_multitask_dag
from .nets import ConvNetGeom
from .optimizer import optimize_plan
from .partition import HALPPlan, plan_halp_topology
from .replan import ReplanConfig, ReplanController
from .simulator import Sim
from .topology import CollabTopology

__all__ = [
    "TaskPlacement",
    "PlacementResult",
    "place_tasks",
    "shared_plan_placement",
    "simulate_placement",
    "PlacementController",
]


@dataclass(frozen=True)
class TaskPlacement:
    """A partition of one pool's secondaries into per-task sub-clusters.

    ``assignments[t]`` are the physical secondaries serving task ``t`` (row
    order = the order given; put faster ESs first), ``plans[t]`` the HALP plan
    over that sub-topology.  Slot names in every plan are physical ES names,
    so :func:`~repro.core.events.build_multitask_dag` resolves contention on
    the shared host and links directly from the names."""

    pool: CollabTopology
    assignments: tuple[tuple[str, ...], ...]
    plans: tuple[HALPPlan, ...]

    def __post_init__(self) -> None:
        if len(self.assignments) != len(self.plans):
            raise ValueError("need exactly one plan per task assignment")
        if not self.assignments:
            raise ValueError("a placement needs at least one task")
        seen: set[str] = set()
        for t, (group, plan) in enumerate(zip(self.assignments, self.plans)):
            if plan.host != self.pool.host:
                raise ValueError(f"task {t}: plan host {plan.host!r} != pool host")
            if tuple(plan.secondary_slots) != tuple(group):
                raise ValueError(
                    f"task {t}: plan slots {plan.secondary_slots} != assignment {group}"
                )
            for s in group:
                if s not in self.pool.secondaries:
                    raise ValueError(f"task {t}: {s!r} is not in the pool")
                if s in seen:
                    raise ValueError(f"secondary {s!r} assigned to more than one task")
                seen.add(s)

    @property
    def n_tasks(self) -> int:
        return len(self.assignments)

    def sub_topology(self, task: int) -> CollabTopology:
        return self.pool.sub_topology(self.assignments[task])


@dataclass
class PlacementResult:
    """Outcome of :func:`place_tasks` (duck-typed like
    :class:`~repro.core.optimizer.OptimizeResult` where the replan/cache
    machinery needs it: ``makespan`` is the cached score)."""

    placement: TaskPlacement
    makespan: float  # DES makespan of the whole batch on the shared pool
    avg_delay: float  # mean per-task finish time (the paper's Fig. 7 metric)
    per_task_finish: tuple[float, ...]
    knobs: tuple[tuple[tuple[float, ...], int], ...]  # per-task (ratios, overlap)
    evaluations: int = 0
    history: list[tuple[tuple[tuple[str, ...], ...], float]] = field(default_factory=list)


def _simulate_plans(
    net: ConvNetGeom,
    plans: Sequence[HALPPlan],
    topology: CollabTopology,
    slowdown: dict[str, float] | None = None,
) -> dict:
    """One DES run of a plan set on a shared pool -- the single source of the
    makespan / per-task-finish accounting for both the optimizer's candidate
    scores and the reported placement metrics."""
    sim = Sim()
    if slowdown:
        sim.slowdown.update(slowdown)
    heads = build_multitask_dag(sim, list(plans), topology)
    makespan = sim.run()
    finishes = [sim.finish_of(h) for h in heads]
    return dict(
        total=makespan,
        per_task_finish=finishes,
        avg_delay=sum(finishes) / len(finishes),
        sim=sim,
    )


def simulate_placement(
    net: ConvNetGeom,
    placement: TaskPlacement,
    topology: CollabTopology | None = None,
    slowdown: dict[str, float] | None = None,
) -> dict:
    """Exact DES of a placement on its (shared) pool.

    ``topology`` overrides the pool's rates (e.g. the bucket-representative
    estimates of a controller) without touching the geometry.  Returns the
    same record shape as :func:`~repro.core.simulator.simulate_halp`."""
    return _simulate_plans(
        net, placement.plans, topology or placement.pool, slowdown=slowdown
    )


def _ranked(pool: CollabTopology) -> list[str]:
    """Pool secondaries fastest-first (ties keep pool order -- deterministic)."""
    order = {s: j for j, s in enumerate(pool.secondaries)}
    return sorted(pool.secondaries, key=lambda s: (-pool.platforms[s].eff_flops, order[s]))


def _greedy_groups(pool: CollabTopology, n_tasks: int, min_per_task: int) -> list[list[str]]:
    """LPT-style capacity balancing: walk ESs fastest-first, give each to the
    task with the least total effective FLOP/s -- under-filled tasks (below
    ``min_per_task``) take priority so every task ends up with a feasible
    sub-cluster.  Groups keep fastest-first internal order."""
    groups: list[list[str]] = [[] for _ in range(n_tasks)]
    cap = [0.0] * n_tasks
    for s in _ranked(pool):
        under = [t for t in range(n_tasks) if len(groups[t]) < min_per_task]
        t = min(under or range(n_tasks), key=lambda t: (cap[t], t))
        groups[t].append(s)
        cap[t] += pool.platforms[s].eff_flops
    return groups


def _plans_for(
    net: ConvNetGeom,
    pool: CollabTopology,
    groups: Sequence[Sequence[str]],
    overlap_rows: int,
) -> tuple[tuple[HALPPlan, ...], tuple[tuple[tuple[float, ...], int], ...]]:
    """Capacity-ratio plans for every group (the cheap scoring mode).
    Raises ValueError/AssertionError when any group is infeasible."""
    plans = []
    knobs = []
    for group in groups:
        sub = pool.sub_topology(group)
        ratios = sub.capacity_ratios()
        plans.append(plan_halp_topology(net, sub, overlap_rows=overlap_rows, ratios=ratios))
        knobs.append((ratios, overlap_rows))
    return tuple(plans), tuple(knobs)


def _score(net: ConvNetGeom, pool: CollabTopology, plans: Sequence[HALPPlan], objective: str) -> float:
    run = _simulate_plans(net, plans, pool)
    return run["total"] if objective == "makespan" else run["avg_delay"]


def place_tasks(
    net: ConvNetGeom,
    pool: CollabTopology,
    n_tasks: int,
    *,
    overlap_rows: int = 4,
    min_per_task: int = 2,
    swap_rounds: int = 4,
    objective: str = "avg_delay",
    optimize_final: bool = True,
    overlap_choices: Sequence[int] = (2, 4, 6, 8),
    max_rounds: int = 4,
    engine: str = "batched",
) -> PlacementResult:
    """Partition the pool's secondaries across ``n_tasks`` concurrent tasks.

    Three phases, all scored by the shared-contention DES
    (:func:`simulate_placement`), minimising ``objective`` (``"avg_delay"``,
    the paper's per-task mean, or ``"makespan"``):

    1. **Greedy capacity-weighted assignment** -- LPT over effective FLOP/s,
       every task guaranteed ``min_per_task`` secondaries.
    2. **Local-search swaps** -- for every task pair, try swapping each ES
       pair and moving single ESs from larger groups; accept strict
       improvements, repeat up to ``swap_rounds`` rounds or to convergence.
       This is where link asymmetry gets fixed: a fast ES behind a slow link
       migrates to the task that loads its uplink least.  With
       ``engine="batched"`` each pair's whole swap/move neighbourhood is
       priced speculatively in one vectorized DES sweep and memoised by
       assignment, so the sequential acceptance scan below is mostly memo
       hits; ``engine="scalar"`` prices one candidate at a time (the
       pre-template baseline, kept callable for ``benchmarks/planner_speed``).
       Both engines score bit-identically and return the same placement.
    3. **Per-task plan refinement** (``optimize_final``) -- each winner group's
       (ratios, overlap) knobs searched by
       :func:`~repro.core.optimizer.optimize_plan` on its own sub-topology,
       warm-started from the incumbent plan's capacity-ratio knobs and using
       the same pricing ``engine``; the refined plan set is kept only if it
       improves the joint score (per-task refinement ignores host contention,
       so it is re-validated jointly).

    Requires ``len(pool.secondaries) >= n_tasks * min_per_task``."""
    if n_tasks < 1:
        raise ValueError(f"need at least one task, got {n_tasks}")
    if objective not in ("avg_delay", "makespan"):
        raise ValueError(f"objective must be 'avg_delay' or 'makespan', got {objective!r}")
    if engine not in ("batched", "scalar"):
        raise ValueError(f"engine must be 'batched' or 'scalar', got {engine!r}")
    if pool.n_secondaries < n_tasks * min_per_task:
        raise ValueError(
            f"pool has {pool.n_secondaries} secondaries; {n_tasks} tasks need "
            f">= {n_tasks * min_per_task} (min_per_task={min_per_task})"
        )
    evals = 0
    history: list[tuple[tuple[tuple[str, ...], ...], float]] = []
    evaluator = (
        MultitaskBatchEvaluator(net, pool, overlap_rows=overlap_rows)
        if engine == "batched"
        else None
    )
    # assignment-keyed score memo (batched engine only -- the scalar engine
    # keeps the historical price-every-candidate cost the benchmark measures)
    memo: dict[tuple, float] = {}

    def price_all(cands: Sequence[Sequence[Sequence[str]]]) -> list[float]:
        nonlocal evals
        keys = [tuple(tuple(g) for g in c) for c in cands]
        out: list[float | None] = [None] * len(cands)
        if evaluator is not None:
            for k, kk in enumerate(keys):
                if kk in memo:
                    out[k] = memo[kk]
            fresh = [(k, keys[k]) for k in range(len(cands)) if out[k] is None]
            if fresh:
                results = evaluator.evaluate([kk for _, kk in fresh])
                evals += len(fresh)
                for (k, kk), res in zip(fresh, results):
                    if res is None:
                        v = float("inf")
                    else:
                        v = res["total"] if objective == "makespan" else res["avg_delay"]
                        history.append((kk, v))
                    memo[kk] = v
                    out[k] = v
        else:
            for k, kk in enumerate(keys):
                evals += 1
                try:
                    plans, _knobs = _plans_for(net, pool, kk, overlap_rows)
                    v = _score(net, pool, plans, objective)
                    history.append((kk, v))
                except (AssertionError, ValueError):
                    v = float("inf")
                out[k] = v
        return [v if v is not None else float("inf") for v in out]

    rank = {s: j for j, s in enumerate(_ranked(pool))}  # invariant per call

    def apply_move(groups, t1: int, t2: int, s1, s2):
        """The move's resulting assignment, or None if it is no longer valid
        against the *current* groups (they mutate when accepts land mid-scan)."""
        if s1 is not None and s1 not in groups[t1]:
            return None
        if s2 is not None and s2 not in groups[t2]:
            return None
        if s1 is None and len(groups[t2]) <= min_per_task:
            return None
        if s2 is None and len(groups[t1]) <= min_per_task:
            return None
        cand = [list(g) for g in groups]
        if s1 is not None:
            cand[t1].remove(s1)
            cand[t2].append(s1)
        if s2 is not None:
            cand[t2].remove(s2)
            cand[t1].append(s2)
        # keep fastest-first order inside each group
        for g in cand:
            g.sort(key=lambda s: rank[s])
        return cand

    groups = _greedy_groups(pool, n_tasks, min_per_task)
    best = price_all([groups])[0]
    if not math.isfinite(best):
        raise ValueError(
            f"no feasible placement for {n_tasks} tasks on this pool "
            f"(greedy assignment {groups} has no valid HALP plan)"
        )

    for _ in range(swap_rounds):
        improved = False
        for t1 in range(n_tasks):
            for t2 in range(t1 + 1, n_tasks):
                candidates = []
                for s1 in groups[t1]:
                    for s2 in groups[t2]:
                        candidates.append((s1, s2))  # swap
                    if len(groups[t1]) > min_per_task:
                        candidates.append((s1, None))  # move t1 -> t2
                for s2 in groups[t2]:
                    if len(groups[t2]) > min_per_task:
                        candidates.append((None, s2))  # move t2 -> t1
                if evaluator is not None:
                    # speculative batch: the whole neighbourhood of the current
                    # assignment in one vectorized sweep; the acceptance scan
                    # below then runs on memo hits until the base moves
                    price_all(
                        [c for s1, s2 in candidates if (c := apply_move(groups, t1, t2, s1, s2))]
                    )
                for idx, (s1, s2) in enumerate(candidates):
                    cand = apply_move(groups, t1, t2, s1, s2)
                    if cand is None:
                        continue
                    score = price_all([cand])[0]
                    if score < best - 1e-15:
                        best = score
                        groups = cand
                        improved = True
                        if evaluator is not None:
                            price_all(
                                [
                                    c
                                    for m1, m2 in candidates[idx + 1 :]
                                    if (c := apply_move(groups, t1, t2, m1, m2))
                                ]
                            )
        if not improved:
            break

    best_plans, best_knobs = _plans_for(net, pool, groups, overlap_rows)

    def joint_score(plans: Sequence[HALPPlan], knobs) -> dict | None:
        """One shared-pool DES run of an explicit plan set: the batched engine
        prices it through the template path (bit-identical), the scalar
        engine through ``simulate_placement``'s machinery."""
        if evaluator is not None:
            layouts = [
                _layout_cached(net, tuple(g), pool.host, w, tuple(r))
                for g, (r, w) in zip(groups, knobs)
            ]
            if all(lay is not None for lay in layouts):
                return evaluator.evaluate_layout_sets([layouts])[0]
        run = _simulate_plans(net, plans, pool)
        return dict(
            total=run["total"],
            avg_delay=run["avg_delay"],
            per_task_finish=tuple(run["per_task_finish"]),
        )

    if optimize_final:
        refined_plans = []
        refined_knobs = []
        for group, (init_ratios, _w) in zip(groups, best_knobs):
            sub = pool.sub_topology(group)
            res = optimize_plan(
                net,
                sub,
                n_tasks=1,
                overlap_choices=overlap_choices,
                max_rounds=max_rounds,
                init_ratios=init_ratios,  # warm start: the incumbent plan's knobs
                engine=engine,
            )
            refined_plans.append(res.plan)
            refined_knobs.append((res.ratios, res.overlap_rows))
            evals += res.evaluations
        run = joint_score(refined_plans, refined_knobs)
        score = run["total"] if objective == "makespan" else run["avg_delay"]
        evals += 1
        if score < best:
            best, best_plans, best_knobs = score, tuple(refined_plans), tuple(refined_knobs)

    placement = TaskPlacement(
        pool=pool,
        assignments=tuple(tuple(g) for g in groups),
        plans=best_plans,
    )
    final = joint_score(best_plans, best_knobs)
    return PlacementResult(
        placement=placement,
        makespan=final["total"],
        avg_delay=final["avg_delay"],
        per_task_finish=tuple(final["per_task_finish"]),
        knobs=best_knobs,
        evaluations=evals,
        history=history,
    )


def shared_plan_placement(
    net: ConvNetGeom,
    pool: CollabTopology,
    n_tasks: int,
    overlap_rows: int = 4,
) -> TaskPlacement:
    """The paper's §IV.B multi-task deployment on a physical pool: secondaries
    grouped **in pool order** into equal-size groups, every task running the
    **same equal-split plan geometry** (eq. 22's assumption that all tasks
    share one partition over one cluster).  ESs beyond
    ``n_tasks * (M // n_tasks)`` stay unused, exactly as a symmetric
    deployment would leave them.  This is the baseline
    ``benchmarks/multitask_placement.py`` measures per-task placement
    against."""
    if n_tasks < 1:
        raise ValueError(f"need at least one task, got {n_tasks}")
    group_size = pool.n_secondaries // n_tasks
    if group_size < 2:
        raise ValueError(
            f"pool has {pool.n_secondaries} secondaries; the shared-plan "
            f"baseline needs >= 2 per task for {n_tasks} tasks"
        )
    ratios = tuple(1.0 / group_size for _ in range(group_size))
    assignments = tuple(
        tuple(pool.secondaries[t * group_size : (t + 1) * group_size])
        for t in range(n_tasks)
    )
    plans = tuple(
        plan_halp_topology(
            net, pool.sub_topology(group), overlap_rows=overlap_rows, ratios=ratios
        )
        for group in assignments
    )
    return TaskPlacement(pool=pool, assignments=assignments, plans=plans)


class PlacementController(ReplanController):
    """Channel-adaptive *placement*: on every adopted bucket switch, re-place
    all tasks over the pool instead of re-optimising one shared plan.

    Inherits the full :class:`~repro.core.replan.ReplanController` loop --
    EWMA per-link estimates over the pool's 2M host<->secondary links, EWMA
    per-ES compute estimates over all M+1 ESs (``observe_compute``),
    geometric rate buckets with shared hysteresis, LRU cache (namespaced via
    ``_cache_kind`` so both controller kinds can share a cache), telemetry --
    and swaps only the recompute step: a cache miss runs
    :func:`place_tasks` for ``config.n_tasks`` tasks against the
    bucket-representative rates and platforms.  A straggling ES therefore
    changes the *assignment* itself (capacity ranking, LPT balance, and the
    swap search all read the rebuilt ``eff_flops``), not just the row split
    within fixed groups.

    Serving integration: ``predicted_latency(b)`` tiles the active
    placement's plans over ``b`` tasks and runs the shared-pool DES -- tasks
    beyond ``config.n_tasks`` wrap onto the same physical secondaries, so the
    prediction includes the queueing a too-large batch would suffer.  Hand it
    to :func:`~repro.runtime.serve.plan_aware_batch_size` unchanged, and wire
    ``observe_batch_latency`` as the serving engine's observer just like the
    plan controller."""

    _cache_kind = "placement"

    def __init__(
        self,
        net: ConvNetGeom,
        pool: CollabTopology,
        config: ReplanConfig = ReplanConfig(),
        cache=None,
        placement_options: dict | None = None,
        store=None,
    ):
        self.placement_options = dict(placement_options or {})
        super().__init__(net, pool, config=config, cache=cache, store=store)

    def _optimize(self, topology: CollabTopology) -> PlacementResult:
        return place_tasks(
            self.net, topology, self.config.n_tasks, **self.placement_options
        )

    # -- placement protocol ---------------------------------------------------

    def placement_for_epoch(self) -> TaskPlacement:
        """One control epoch: hysteresis step, then the (cached) placement."""
        self.step()
        return self.current().placement

    @property
    def placement(self) -> TaskPlacement:
        return self._active_result().placement

    def plan_for_epoch(self) -> HALPPlan:
        raise TypeError(
            "a PlacementController serves one plan per task, not one shared "
            "plan; use placement_for_epoch() / .placement"
        )

    @property
    def plan(self) -> HALPPlan:
        raise TypeError(
            "a PlacementController serves one plan per task, not one shared "
            "plan; use .placement (or .placement.plans[task])"
        )

    # -- serving integration --------------------------------------------------

    def _price_batch(self, batch_size: int) -> float:
        placement = self._active_result().placement
        plans = [placement.plans[t % placement.n_tasks] for t in range(batch_size)]
        sim = Sim()
        heads = build_multitask_dag(sim, plans, self.estimated_topology())
        sim.run()
        return max(sim.finish_of(h) for h in heads)
