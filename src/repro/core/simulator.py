"""Discrete-event simulator for collaborative-ES schedules (ground truth).

The paper's closed-form recursions (eqs. 16-20, 22-23) approximate a job/message
DAG executed by FIFO compute resources (the ESs) and full-duplex point-to-point
links.  This module simulates that DAG exactly:

* every compute chunk and every message is a :class:`Job` bound to a resource,
* a resource serves its jobs in submission order (list scheduling -- the paper's
  schedule is static), a job starts when its resource is free *and* all
  dependencies have finished,
* the makespan of the sink job is the inference time.

Benchmarks use this engine; ``tests/test_schedule.py`` cross-validates it
against the closed forms.  The same engine doubles as the straggler /
fault-injection harness of the runtime (``repro.runtime.fault``): per-resource
slowdown factors and message-drop retries model node degradation at scale.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .nets import ConvNetGeom, DTYPE_BYTES
from .partition import E0, E1, E2, HALPPlan, plan_even, plan_halp
from .schedule import Link, Platform

__all__ = ["Sim", "Job", "simulate_halp", "simulate_modnn", "enhanced_modnn_delay"]


@dataclass
class Job:
    jid: int
    name: str
    resource: str
    duration: float
    deps: tuple[int, ...]
    start: float = 0.0
    finish: float = 0.0


class Sim:
    """Static list-scheduling simulator over FIFO resources."""

    def __init__(self) -> None:
        self.jobs: list[Job] = []
        self.slowdown: dict[str, float] = {}

    def add(self, name: str, resource: str, duration: float, deps=()) -> int:
        jid = len(self.jobs)
        deps = tuple(d for d in deps if d is not None)
        self.jobs.append(Job(jid, name, resource, max(0.0, duration), deps))
        return jid

    def run(self) -> float:
        """Resolve start/finish for all jobs; returns the makespan."""
        free: dict[str, float] = {}
        # Jobs on a resource are served in submission order (FIFO). Because a
        # later job on the same resource cannot start before an earlier one, a
        # single forward pass in submission order is exact as long as deps only
        # point backwards -- which the builders guarantee.
        for job in self.jobs:
            for d in job.deps:
                if d >= job.jid:
                    raise ValueError(f"forward dependency {d} -> {job.jid}")
            ready = max((self.jobs[d].finish for d in job.deps), default=0.0)
            start = max(ready, free.get(job.resource, 0.0))
            dur = job.duration * self.slowdown.get(job.resource, 1.0)
            job.start = start
            job.finish = start + dur
            free[job.resource] = job.finish
        return max((j.finish for j in self.jobs), default=0.0)

    def finish_of(self, jid: int) -> float:
        return self.jobs[jid].finish


def _chunk_time(net: ConvNetGeom, platform: Platform, i: int, rows: int) -> float:
    width = net.sizes()[i + 1]
    return platform.compute_time(net.layers[i].flops_per_out_row(width) * rows)


def simulate_halp(
    net: ConvNetGeom,
    platform: Platform,
    link: Link,
    overlap_rows: int = 4,
    n_tasks: int = 1,
    host_platform: Platform | None = None,
    slowdown: dict[str, float] | None = None,
) -> dict:
    """Simulate HALP for ``n_tasks`` tasks on 2*n_tasks secondaries + one host.

    Resources: ``e0`` (host compute), ``e{k}^{t}`` (secondary compute),
    ``link:a->b`` (directed point-to-point links; Ethernet full duplex).  The
    host serves the per-task overlap zones in task order within each layer
    (paper §IV.B).  ``slowdown`` maps resource name -> multiplicative factor
    (straggler injection).
    """
    host_platform = host_platform or platform
    plans = [plan_halp(net, overlap_rows=overlap_rows) for _ in range(n_tasks)]
    sim = Sim()
    if slowdown:
        sim.slowdown.update(slowdown)
    n_layers = len(net.layers)

    # job-id bookkeeping: last compute chunk per (task, es) per layer, and the
    # message that es needs before starting layer i.  The host gets one inbox
    # slot per source secondary, so its top chunk only waits for e1's rows and
    # its bottom chunk only for e2's.
    last_chunk: dict[tuple[int, str], int | None] = {}
    inbox: dict[tuple[int, str, int], int | None] = {}  # (task, es, layer) -> msg job
    host_inbox: dict[tuple[int, int, str], int | None] = {}  # (task, layer, src)

    def sec(t: int, ek: str) -> str:
        return f"{ek}^{t}"

    # initial image distribution host -> secondaries (eq. 10)
    for t in range(n_tasks):
        plan = plans[t]
        for ek in (E1, E2):
            nbytes = DTYPE_BYTES * plan.parts[0].inp[ek].rows * net.in_rows * net.in_channels
            jid = sim.add(
                f"int[{t}]{ek}", f"link:e0->{sec(t, ek)}", link.comm_time(nbytes)
            )
            inbox[(t, ek, 0)] = jid
        inbox[(t, E0, 0)] = None

    for i in range(n_layers):
        # --- secondaries: dep chunk first, then rest; send dep while resting.
        for t in range(n_tasks):
            plan = plans[t]
            for ek in (E1, E2):
                own = plan.parts[i].out[ek]
                dep = plan.message(i, ek, E0)
                deps = [last_chunk.get((t, ek)), inbox.get((t, ek, i))]
                a = sim.add(
                    f"cmp[{t}]{ek}.g{i}.dep",
                    sec(t, ek),
                    _chunk_time(net, platform, i, dep.rows),
                    deps,
                )
                m = sim.add(
                    f"msg[{t}]{ek}->e0.g{i}",
                    f"link:{sec(t, ek)}->e0",
                    link.comm_time(plan.message_bytes(i, ek, E0)),
                    [a],
                )
                b = sim.add(
                    f"cmp[{t}]{ek}.g{i}.rest",
                    sec(t, ek),
                    _chunk_time(net, platform, i, own.rows - dep.rows),
                    [a],
                )
                last_chunk[(t, ek)] = b
                if i + 1 < n_layers:
                    host_inbox[(t, i + 1, ek)] = m  # host needs this before layer i+1
        # --- host: per task (in order): chunk for e1, send; chunk rest, send to e2.
        for t in range(n_tasks):
            plan = plans[t]
            zone = plan.parts[i].out[E0]
            m1 = plan.message(i, E0, E1)
            deps = [last_chunk.get((t, E0)), host_inbox.get((t, i, E1))]
            a = sim.add(
                f"cmp[{t}]e0.g{i}.for_e1",
                E0,
                _chunk_time(net, host_platform, i, m1.rows),
                deps,
            )
            s1 = sim.add(
                f"msg[{t}]e0->e1.g{i}",
                f"link:e0->{sec(t, E1)}",
                link.comm_time(plan.message_bytes(i, E0, E1)),
                [a],
            )
            b = sim.add(
                f"cmp[{t}]e0.g{i}.rest",
                E0,
                _chunk_time(net, host_platform, i, zone.rows - m1.rows),
                [a, host_inbox.get((t, i, E2))],
            )
            s2 = sim.add(
                f"msg[{t}]e0->e2.g{i}",
                f"link:e0->{sec(t, E2)}",
                link.comm_time(plan.message_bytes(i, E0, E2)),
                [b],
            )
            last_chunk[(t, E0)] = b
            if i + 1 < n_layers:
                inbox[(t, E1, i + 1)] = s1
                inbox[(t, E2, i + 1)] = s2
            # NOTE: the host->e0 "message" is local (no job).

    # final merge: secondaries ship their g_N sub-outputs; host runs the head.
    heads = []
    for t in range(n_tasks):
        plan = plans[t]
        merged = []
        for ek in (E1, E2):
            m = sim.add(
                f"final[{t}]{ek}->e0",
                f"link:{sec(t, ek)}->e0",
                link.comm_time(plan.message_bytes(n_layers - 1, ek, E0)),
                [last_chunk[(t, ek)]],
            )
            merged.append(m)
        h = sim.add(
            f"head[{t}]",
            E0,
            host_platform.compute_time(net.head_flops),
            merged + [last_chunk[(t, E0)]],
        )
        heads.append(h)
    makespan = sim.run()
    finishes = [sim.finish_of(h) for h in heads]
    return dict(
        total=makespan,
        per_task_finish=finishes,
        avg_delay=sum(finishes) / len(finishes),
        sim=sim,
    )


def simulate_modnn(
    net: ConvNetGeom,
    platform: Platform,
    link: Link,
    n_workers: int,
    slowdown: dict[str, float] | None = None,
) -> dict:
    """Conventional layer-wise parallelization (MoDNN): synchronous halo
    exchange through the host after every CL; host NIC serialises transfers."""
    plan = plan_even(net, n_workers)
    names = plan.es_names
    host = names[0]
    sim = Sim()
    if slowdown:
        sim.slowdown.update(slowdown)
    n_layers = len(net.layers)
    last: dict[str, int | None] = {}
    gate: dict[str, int | None] = {}  # message that worker w waits on before layer i

    for w in names[1:]:
        nbytes = DTYPE_BYTES * plan.parts[0].inp[w].rows * net.in_rows * net.in_channels
        gate[w] = sim.add(f"int.{w}", f"link:{host}->{w}", link.comm_time(nbytes))
    gate[host] = None

    for i in range(n_layers):
        chunks = {}
        for w in names:
            rows = plan.parts[i].out[w].rows
            chunks[w] = sim.add(
                f"cmp.{w}.g{i}", w, _chunk_time(net, platform, i, rows), [last.get(w), gate.get(w)]
            )
        # synchronous exchange: gathers serialise on host RX, scatters on host TX,
        # and every worker waits for its scatter before the next layer.
        gathers = []
        for w in names:
            for v in names:
                if v == w:
                    continue
                nbytes = plan.message_bytes(i, w, v)
                if nbytes:
                    gathers.append(
                        sim.add(
                            f"gather.{w}->{v}.g{i}",
                            f"{host}:rx",
                            link.comm_time(nbytes),
                            [chunks[w]],
                        )
                    )
        barrier = sim.add(f"merge.g{i}", host, 0.0, [chunks[host]] + gathers)
        for w in names:
            need = sum(
                plan.message_bytes(i, v, w) for v in names if v != w
            )
            if w == host or need == 0.0:
                gate[w] = barrier
            else:
                gate[w] = sim.add(
                    f"scatter.->{w}.g{i}", f"{host}:tx", link.comm_time(need), [barrier]
                )
        last = dict(chunks)

    final = []
    for w in names[1:]:
        nbytes = net.feature_bytes(n_layers - 1, plan.parts[-1].out[w].rows)
        final.append(
            sim.add(f"final.{w}", f"{host}:rx", link.comm_time(nbytes), [last[w]])
        )
    head = sim.add("head", host, platform.compute_time(net.head_flops), final + [last[host]])
    total = sim.run()
    return dict(total=total, sim=sim)


def enhanced_modnn_delay(
    net: ConvNetGeom, platform: Platform, link: Link, n_es: int = 9, n_tasks: int = 4
) -> dict:
    """Paper §V.C 'Enhanced MoDNN': first (n_tasks - 1) tasks run in parallel on
    disjoint groups of n_es // (n_tasks - 1) ESs, the last on all n_es.

    Returns T^E1, T^E2, the average per-task delay T^E1 + T^E2/n_tasks and
    throughput n_tasks / (T^E1 + T^E2)."""
    group = n_es // (n_tasks - 1)
    t_e1 = simulate_modnn(net, platform, link, group)["total"]
    t_e2 = simulate_modnn(net, platform, link, n_es)["total"]
    return dict(
        T_E1=t_e1,
        T_E2=t_e2,
        avg_delay=t_e1 + t_e2 / n_tasks,
        throughput=n_tasks / (t_e1 + t_e2),
    )
