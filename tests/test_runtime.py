"""Runtime tests: checkpoint/restore, fault-tolerant training, serving engine,
optimizer, data determinism."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ImageStream, TokenStream
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.optim.grad_compress import compress_bf16, topk_sparsify
from repro.runtime import (
    BatchingEngine,
    FaultConfig,
    FaultTolerantTrainer,
    InjectedFault,
    Request,
    ServeConfig,
    choose_batch_size,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.core.reliability import OffloadChannel, service_reliability


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params, cfg)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_bf16_moments():
    cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4, 4))}
    state = adamw_init(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    params2, state2 = adamw_update({"w": jnp.ones((4, 4))}, state, params, cfg)
    assert params2["w"].dtype == params["w"].dtype
    assert bool(jnp.isfinite(params2["w"]).all())


def test_warmup_cosine_monotone_warmup():
    assert float(warmup_cosine(0)) == 0.0
    assert float(warmup_cosine(500, warmup=1000)) == pytest.approx(0.5)
    assert float(warmup_cosine(1000)) == pytest.approx(1.0, abs=1e-3)


def test_grad_compress():
    g = {"a": jnp.arange(8192.0).reshape(64, 128)}
    c = compress_bf16(g)
    assert c["a"].dtype == jnp.bfloat16
    s = topk_sparsify(g["a"], frac=0.1)
    nz = float(jnp.count_nonzero(s)) / s.size
    assert 0.05 < nz < 0.15


def test_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6.0).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.array(7, jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree, extra={"arch": "x"})
        save_checkpoint(d, 7, tree)
        assert latest_step(d) == 7
        like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
        restored, step, extra = restore_checkpoint(d, like, step=3)
        assert step == 3 and extra == {"arch": "x"}
        for a, b in zip(jax.tree_util.tree_leaves(restored), jax.tree_util.tree_leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_data_streams_deterministic():
    s1 = TokenStream(vocab=100, batch=2, seq_len=8, seed=1)
    s2 = TokenStream(vocab=100, batch=2, seq_len=8, seed=1)
    np.testing.assert_array_equal(s1.batch_at(5)["tokens"], s2.batch_at(5)["tokens"])
    assert not np.array_equal(s1.batch_at(5)["tokens"], s1.batch_at(6)["tokens"])
    i1 = ImageStream(img_res=8, batch=2, num_classes=4, seed=2)
    np.testing.assert_array_equal(i1.batch_at(0)["images"], i1.batch_at(0)["images"])


def test_fault_tolerant_trainer_recovers():
    """Inject a failure mid-run; the trainer restores from the checkpoint and
    converges to the same final state as an uninterrupted run."""
    from repro.runtime.train import make_trainer

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        # uninterrupted reference
        t_ref, s_ref = make_trainer(
            "qwen3-4b", "train_4k", fault_cfg=FaultConfig(ckpt_dir=d1, ckpt_every=2)
        )
        s_ref, stats_ref = t_ref.run(s_ref, 6, resume=False)

        # faulting run: blows up at step 3, twice
        boom = {"n": 0}

        def hook(i):
            if i == 3 and boom["n"] < 2:
                boom["n"] += 1
                raise InjectedFault(f"chaos at step {i}")

        t2, s2 = make_trainer(
            "qwen3-4b", "train_4k",
            fault_cfg=FaultConfig(ckpt_dir=d2, ckpt_every=2),
            fault_hook=hook,
        )
        s2, stats = t2.run(s2, 6, resume=False)
        assert stats.failures == 2 and stats.restores >= 2
        # deterministic stream + checkpoint replay => identical final params
        p_ref, p2 = s_ref[0], s2[0]
        for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6
            )
        assert int(s2[2]) == 6  # step counter advanced to completion


class _CountStream:
    """Deterministic toy stream: batch i carries the scalar i."""

    def batch_at(self, i):
        return {"x": jnp.asarray(float(i))}


def _toy_trainer(ckpt_dir, max_failures=3, fault_hook=None, **kw):
    def step(state, x):
        return {"w": state["w"] + x}, {"loss": x}

    cfg = FaultConfig(ckpt_dir=ckpt_dir, ckpt_every=2, max_failures=max_failures)
    return FaultTolerantTrainer(step, _CountStream(), cfg, fault_hook=fault_hook, **kw)


def _fault_once_at(steps):
    fired = set()

    def hook(i):
        if i in steps and i not in fired:
            fired.add(i)
            raise InjectedFault(f"chaos at step {i}")

    return hook


def test_trainer_stats_dedupe_replayed_steps():
    """Steps replayed after a checkpoint restore must not be re-counted: a
    fault at step 3 (ckpt at 2) replays step 2, which historically double-fed
    steps/losses/EMA for every replayed step."""
    with tempfile.TemporaryDirectory() as d:
        t = _toy_trainer(d, fault_hook=_fault_once_at({3}))
        state, stats = t.run({"w": jnp.zeros(())}, 6, resume=False)
        assert stats.failures == 1 and stats.restores == 1
        assert stats.steps == 6  # not 7: the replayed step 2 counts once
        assert stats.losses == [0.0, 1.0, 2.0, 3.0, 4.0, 5.0]
        assert float(state["w"]) == sum(range(6))  # replay itself is correct


def test_trainer_retry_budget_is_consecutive_not_total():
    """max_failures bounds *consecutive unrecovered* failures: a long run with
    sparse transient faults (more total faults than the budget, but recovered
    progress in between) must complete.  The historical counter never reset,
    so it raised on the (max_failures+1)-th fault of the whole run."""
    with tempfile.TemporaryDirectory() as d:
        t = _toy_trainer(d, max_failures=1, fault_hook=_fault_once_at({1, 3, 5}))
        state, stats = t.run({"w": jnp.zeros(())}, 8, resume=False)
        assert stats.failures == 3  # the stats keep counting the total
        assert stats.steps == 8 and float(state["w"]) == sum(range(8))


def test_trainer_consecutive_failures_still_bounded():
    """A genuinely stuck step (faulting every attempt) must still raise."""

    def always_boom(i):
        if i == 3:
            raise InjectedFault("hard fault at step 3")

    with tempfile.TemporaryDirectory() as d:
        t = _toy_trainer(d, max_failures=2, fault_hook=always_boom)
        with pytest.raises(RuntimeError, match="consecutive"):
            t.run({"w": jnp.zeros(())}, 6, resume=False)


def test_trainer_recovery_before_first_checkpoint_rewinds_state():
    """A fault before any checkpoint exists must rewind the *state* together
    with the step index: rewinding only the index re-applies already-consumed
    batches to an already-advanced state (and the stats dedupe would make
    that corruption silent)."""

    class _OneBasedStream:
        def batch_at(self, i):
            return {"x": jnp.asarray(float(i + 1))}  # nonzero first batch

    def step(state, x):
        return {"w": state["w"] + x}, {"loss": x}

    with tempfile.TemporaryDirectory() as d:
        cfg = FaultConfig(ckpt_dir=d, ckpt_every=10, max_failures=3)  # no ckpt fits
        t = FaultTolerantTrainer(
            step, _OneBasedStream(), cfg, fault_hook=_fault_once_at({1})
        )
        state, stats = t.run({"w": jnp.zeros(())}, 4, resume=False)
        assert stats.failures == 1
        # batches 1..4 applied exactly once: 10, not 11 (batch 1 twice)
        assert float(state["w"]) == 10.0
        assert stats.steps == 4 and stats.losses == [1.0, 2.0, 3.0, 4.0]


def test_trainer_recovery_ignores_stale_checkpoints_from_prior_runs():
    """A fresh resume=False run recovering from a transient fault must not
    restore a checkpoint a *previous* run left in the same ckpt_dir: that
    would jump it to foreign state/progress (possibly past its own n_steps).
    Only checkpoints within this run's own [start_step, high_water] qualify;
    otherwise the run replays from its entry state."""
    with tempfile.TemporaryDirectory() as d:
        t1 = _toy_trainer(d)
        t1.run({"w": jnp.zeros(())}, 8, resume=False)  # leaves step_8 etc.
        t2 = _toy_trainer(d, fault_hook=_fault_once_at({1}))
        state, stats = t2.run({"w": jnp.zeros(())}, 4, resume=False)
        assert stats.failures == 1
        assert stats.steps == 4  # ran its own 4 steps, not run 1's leftovers
        assert float(state["w"]) == sum(range(4))  # 0+1+2+3, from entry state


def test_trainer_stats_count_fresh_reruns():
    """The replay-dedupe watermark must not leak across runs: a second
    resume=False run on the same trainer re-executes from step 0 for real,
    so its steps count (and reach the compute observer) again."""
    seen = []
    with tempfile.TemporaryDirectory() as d:
        t = _toy_trainer(
            d, compute_observer=lambda es, fl, dt: seen.append(es),
            step_flops=1e9,
        )
        t.run({"w": jnp.zeros(())}, 4, resume=False)
        assert t.stats.steps == 4 and len(seen) == 4
        t.run({"w": jnp.zeros(())}, 4, resume=False)  # fresh run, same trainer
        assert t.stats.steps == 8 and len(seen) == 8


def test_serve_config_rejects_shed_as_max_batch():
    """Building an engine on an admission result of 0 (shed) would busy-loop
    taking empty batches forever; ServeConfig refuses it loudly."""
    with pytest.raises(ValueError, match="shed"):
        ServeConfig(max_batch=0)


def test_trainer_compute_observer_feeds_planner_once_per_step():
    """The straggler-stats feed of the joint re-planner: each *newly
    completed* step reports (es, flops, dt) exactly once -- replayed steps
    after a restore must not double-feed the compute estimator."""
    seen = []
    with tempfile.TemporaryDirectory() as d:
        t = _toy_trainer(
            d,
            fault_hook=_fault_once_at({3}),
            compute_observer=lambda es, fl, dt: seen.append((es, fl, dt)),
            es_name="b",
            step_flops=2e9,
        )
        t.run({"w": jnp.zeros(())}, 6, resume=False)
    assert len(seen) == 6  # one per unique step despite the replay
    assert all(es == "b" and fl == 2e9 and dt > 0 for es, fl, dt in seen)
    # and the samples drive a ComputeRateEstimator as wired in production
    from repro.core import ComputeRateEstimator

    est = ComputeRateEstimator({"b": 1e12}, alpha=1.0)
    for es, fl, dt in seen:
        est.observe(es, fl, dt)
    assert est.rate("b") == pytest.approx(seen[-1][1] / seen[-1][2])


def test_batching_engine_es_timing_hook():
    """observe_es_time forwards per-ES chunk timings to the wired observer
    (the compute half of the joint replan loop); without a wire it is a
    no-op."""
    seen = []
    eng = BatchingEngine(
        jax.jit(lambda b: b),
        ServeConfig(max_batch=2),
        es_observer=lambda es, fl, dt: seen.append((es, fl, dt)),
    )
    eng.observe_es_time("e1", 3.2e9, 0.004)
    eng.observe_es_time("e2", 1.6e9, 0.004)
    assert seen == [("e1", 3.2e9, 0.004), ("e2", 1.6e9, 0.004)]
    # unwired engine: silently ignored
    BatchingEngine(jax.jit(lambda b: b), ServeConfig()).observe_es_time("e1", 1.0, 1.0)


def test_losses_decrease_smoke():
    from repro.runtime.train import train_smoke

    out = train_smoke("vit-l16", n_steps=8)
    assert out["steps"] == 8
    assert all(np.isfinite(out["losses"]))


def test_batching_engine_deadlines():
    calls = {"n": 0}

    inner = jax.jit(lambda b: jnp.sum(b, axis=(1, 2, 3)))

    def fn(batch):
        calls["n"] += 1  # counts batch executions (fn itself is not traced)
        return inner(batch)

    eng = BatchingEngine(fn, ServeConfig(max_batch=4))
    for i in range(10):
        eng.submit(jnp.ones((4, 4, 3)) * i, deadline_s=5.0)
    stats = eng.run_until_drained()
    assert stats["completed"] == 10
    assert stats["deadline_met_frac"] == 1.0
    assert calls["n"] == 3  # 4 + 4 + 2(padded)


def test_batching_engine_edf_order():
    """Earliest-deadline-first: tight-deadline requests run in the first batch."""
    eng = BatchingEngine(jax.jit(lambda b: b), ServeConfig(max_batch=2))
    r_loose = eng.submit(jnp.zeros(()), deadline_s=10.0)
    r_tight = eng.submit(jnp.zeros(()), deadline_s=0.5)
    r_mid = eng.submit(jnp.zeros(()), deadline_s=2.0)
    first = eng.step()
    assert {r.rid for r in first} == {r_tight, r_mid}


def test_choose_batch_size_policy():
    """Bigger channels admit bigger batches; the policy is monotone."""
    lat = lambda b: 2e-3 + 1e-3 * b  # linear latency model
    ch_fast = OffloadChannel(rate_bps=100e6, sigma_s=1e-3)
    ch_slow = OffloadChannel(rate_bps=35e6, sigma_s=5e-3)
    b_fast = choose_batch_size(lat, 4.0 / 30.0, ch_fast, target=0.999, max_batch=16)
    b_slow = choose_batch_size(lat, 4.0 / 30.0, ch_slow, target=0.999, max_batch=16)
    assert b_fast >= b_slow
    assert 1 <= b_slow <= 16


def test_request_declares_result_field():
    """``BatchingEngine.step`` assigns per-request outputs; the dataclass must
    declare the field (not rely on instance-attribute injection)."""
    names = {f.name for f in dataclasses.fields(Request)}
    assert "result" in names
    assert Request(deadline=1.0, rid=1).result is None


def test_service_reliability_sigma_zero_is_a_step():
    """sigma=0 degenerates to a deterministic deadline check (boundary met)."""
    ch = OffloadChannel(rate_bps=40e6, sigma_s=0.0)  # mu = 4 Mbit / 40 Mbps = 0.1 s
    assert service_reliability(ch, 0.0333, 4.0 / 30.0) == 1.0  # slack ~0, met
    assert service_reliability(ch, 0.0334, 4.0 / 30.0) == 0.0
    assert service_reliability(ch, 0.0, 4.0 / 30.0) == 1.0


def test_choose_batch_size_sigma_zero_deterministic():
    """With a deterministic channel the policy picks the exact cutoff batch."""
    ch = OffloadChannel(rate_bps=40e6, sigma_s=0.0)  # mu = 0.1 s
    lat = lambda b: 5e-3 * b
    # feasible iff 0.1 + 0.005 b <= 4/30 = 0.1333... i.e. b <= 6
    assert choose_batch_size(lat, 4.0 / 30.0, ch, target=0.99999, max_batch=16) == 6


def test_choose_batch_size_unreachable_target_sheds():
    """When no batch size clears the reliability target, the policy returns 0
    (shed/reject) -- the historical fallback of 1 silently admitted requests
    that were already known to miss their deadline."""
    ch = OffloadChannel(rate_bps=40e6, sigma_s=5e-3)
    assert choose_batch_size(lambda b: 10.0, 4.0 / 30.0, ch, max_batch=16) == 0
    # a deterministic channel whose offload alone blows the deadline: even
    # b=1 with zero inference time is infeasible -> shed
    ch0 = OffloadChannel(rate_bps=1e6, sigma_s=0.0)  # mu = 4 s >> D
    assert choose_batch_size(lambda b: 0.0, 4.0 / 30.0, ch0, max_batch=4) == 0


def test_choose_batch_size_non_monotone_latency():
    """A latency spike at a middle batch size must not mask larger feasible
    batches: the policy returns the *largest* batch clearing the target."""
    ch = OffloadChannel(rate_bps=40e6, sigma_s=0.0)  # mu = 0.1 s
    lat = lambda b: 0.2 if b == 3 else 1e-3 * b  # b=3 infeasible, b=8 fine
    assert choose_batch_size(lat, 4.0 / 30.0, ch, target=0.99999, max_batch=8) == 8


def test_batching_engine_observer_sees_executed_width():
    """The engine reports (executed batch width, elapsed) per batch -- the
    feedback hook the online re-planner calibrates against.  With pad_to_max
    the final short batch runs (and is reported) at the padded width, since
    that is the size the measured latency corresponds to."""
    seen = []
    eng = BatchingEngine(
        jax.jit(lambda b: b),
        ServeConfig(max_batch=4),
        observer=lambda n, dt: seen.append((n, dt)),
    )
    for i in range(10):
        eng.submit(jnp.ones(()) * i, deadline_s=5.0)
    eng.run_until_drained()
    assert [n for n, _ in seen] == [4, 4, 4]  # last batch padded 2 -> 4
    assert all(dt >= 0.0 for _, dt in seen)

    seen.clear()
    eng = BatchingEngine(
        jax.jit(lambda b: b),
        ServeConfig(max_batch=4, pad_to_max=False),
        observer=lambda n, dt: seen.append((n, dt)),
    )
    for i in range(10):
        eng.submit(jnp.ones(()) * i, deadline_s=5.0)
    eng.run_until_drained()
    assert [n for n, _ in seen] == [4, 4, 2]  # unpadded: true sizes
