"""Deterministic fallback for the subset of ``hypothesis`` this suite uses.

The container image may not ship ``hypothesis``; rather than skip five whole
test modules, this shim re-implements the small API surface they need
(``given``, ``settings``, ``strategies.integers/sampled_from/data``) as a
seeded pseudo-random example driver.  It has no shrinking and no database --
it simply runs each property ``max_examples`` times with reproducible draws,
which preserves the tests' bug-finding power for regressions while keeping
collection green.

Usage (at the top of a test module)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # pragma: no cover - exercised only without hypothesis
        from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

__all__ = ["given", "settings", "st", "strategies"]

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw_fn, label=""):
        self._draw = draw_fn
        self._label = label

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"_Strategy({self._label})"


class _DataObject:
    """Mimics hypothesis' ``data()`` object: interactive draws inside the test."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label=None):
        return strategy.draw(self._rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng), "data()")


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: rng.randint(min_value, max_value),
            f"integers({min_value}, {max_value})",
        )

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))], "sampled_from")

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_ignored):
        return _Strategy(
            lambda rng: rng.uniform(min_value, max_value),
            f"floats({min_value}, {max_value})",
        )

    @staticmethod
    def data():
        return _DataStrategy()


st = strategies = _Strategies()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Records ``max_examples`` on the decorated function (deadline ignored)."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Runs the test once per example with deterministic per-example seeds.

    Positional strategies map onto the test function's parameters in order,
    keyword strategies by name (matching hypothesis' behaviour for the simple
    signatures this suite uses).
    """

    def deco(fn):
        params = [
            p.name
            for p in inspect.signature(fn).parameters.values()
            if p.kind in (p.POSITIONAL_OR_KEYWORD, p.POSITIONAL_ONLY)
        ]
        mapping = dict(zip(params, arg_strategies))
        mapping.update(kw_strategies)

        @functools.wraps(fn)
        def wrapper():
            n = getattr(
                wrapper,
                "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES),
            )
            for ex in range(n):
                # crc32 is stable across processes (unlike str hash, which is
                # salted), so a falsifying example number is replayable
                rng = random.Random((zlib.crc32(fn.__qualname__.encode()) << 32) | ex)
                kwargs = {name: strat.draw(rng) for name, strat in mapping.items()}
                try:
                    fn(**kwargs)
                except Exception as e:  # noqa: BLE001 - re-raise with the example
                    raise AssertionError(
                        f"falsifying example (#{ex}): {fn.__name__}({kwargs!r})"
                    ) from e

        # strip the now-bound parameters so pytest doesn't see fixtures
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
