"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6.  [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from ..models import transformer_lm as lm
from ..models.moe import MoEConfig
from ..models.transformer_lm import LMConfig
from .base import Arch, lm_cells, register

FULL = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=163840,
    rope_theta=1e6,
    moe=MoEConfig(d_model=2048, n_experts=64, top_k=6, d_ff=1408, n_shared=0),
)

SMOKE = LMConfig(
    name="moonshot-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=96,
    vocab=512,
    moe=MoEConfig(d_model=64, n_experts=8, top_k=2, d_ff=96, capacity_factor=2.0),
)

ARCH = register(
    Arch(
        name="moonshot-v1-16b-a3b",
        family="lm",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=lm_cells(full_attention=True),
        module=lm,
        notes="all-MoE (64e top-6, per-expert ff 1408); expert parallelism on "
        "the model axis",
    )
)
