"""Parameter / input sharding rules (DP + TP + EP + SP + optional FSDP).

Rules are (path-regex, spec) pairs matched against ``a/b/c`` pytree paths;
specs are axis-name tuples where the special token ``"fsdp"`` resolves to the
mesh's data axes (ZeRO-3 parameter sharding, enabled for the large LM configs)
and may silently drop to replication when a dimension is not divisible.
Stacked layer pytrees (leading scan axis) are handled by left-padding specs
with None when the leaf rank exceeds the spec rank.

Input sharding is per (family, cell-kind) with two deliberate SP cases -- the
paper's technique deployed through GSPMD:

* vision ``serve_b1``       -- image *height* sharded (spatial partitioning;
  XLA inserts the exact halo exchanges the rf-arithmetic prescribes),
* diffusion ``gen_1024``    -- latent height sharded across ``data``.
"""
from __future__ import annotations

import re
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import Arch, Cell
from ..launch.mesh import dp_axes, fsdp_axes

__all__ = [
    "param_shardings",
    "input_shardings",
    "state_shardings",
    "shard_rules",
    "spatial_shardings",
    "weighted_spatial_inputs",
]

M = "model"


def _lm_rules(big: bool):
    from .variants import get_variant

    v = get_variant()
    big = big or v.lm_fsdp_small
    fs = "fsdp" if big else None
    embed = (M, fs) if v.embed_vocab_shard else (fs, M)
    head = (None, None) if v.replicate_lm_head else (fs, M)
    if v.gather_experts:
        return [
            (r"embed$", embed),
            (r"lm_head/w$", head),
            (r"(wq|wk|wv|wqkv)/w$", (fs, M)),
            (r"wo/w$", (M, fs)),
            (r"(wdq|wuq|wdkv|wukv|wkr)/w$", (fs, M)),
            (r"ffn/(w1|w3)/w$", (fs, M)),
            (r"ffn/w2/w$", (M, fs)),
            (r"experts/", (None, None, None)),
            (r"router/w$", (None, None)),
        ]
    return [
        (r"embed$", embed),
        (r"lm_head/w$", head),
        (r"(wq|wk|wv|wqkv)/w$", (fs, M)),
        (r"wo/w$", (M, fs)),
        (r"(wdq|wuq|wdkv|wukv|wkr)/w$", (fs, M)),
        (r"ffn/(w1|w3)/w$", (fs, M)),
        (r"ffn/w2/w$", (M, fs)),
        (r"shared/(w1|w3)/w$", (fs, M)),
        (r"shared/w2/w$", (M, fs)),
        (r"experts/(w1|w3)$", (M, "fsdp" if big else None, None)),
        (r"experts/w2$", (M, None, "fsdp" if big else None)),
        (r"mtp/proj/w$", (fs, M)),
        (r"router/w$", (None, None)),
    ]


def _vision_rules():
    return [
        (r"(patch_embed|stem)/w$", (None, None, None, M)),
        (r"(wqkv|fc1|pw1)/w$", (None, M)),
        (r"(wo|fc2|pw2)/w$", (M, None)),
        (r"head/w$", (None, M)),
        (r"merge/w$", (None, M)),
        (r"(expand|project|head_conv|dw|down)/w$", (None, None, None, M)),
        (r"(se_reduce|se_expand|fc)/w$", (None, M)),
    ]


def _diffusion_rules():
    return [
        (r"(fc1|ff1|wqkv|sq|sk|sv|cq|ck|cv|t_mlp1|t1|proj_in)/w$", (None, M)),
        (r"(fc2|ff2|wo|so|co|t_mlp2|t2|proj_out)/w$", (M, None)),
        (r"ada/w$", (None, M)),
        (r"final_ada/w$", (None, M)),
        (r"(c1|c2|conv_in|conv_out|downsample|upsample|skip)/w$", (None, None, None, M)),
        (r"temb/w$", (None, M)),
        (r"(patch_embed)/w$", (None, None, None, M)),
        (r"final/w$", (None, M)),
        (r"label_embed$", (None, M)),
    ]


def shard_rules(arch: Arch):
    from .variants import get_variant

    if arch.family == "lm":
        big = arch.name.startswith(("deepseek", "moonshot"))
        return _lm_rules(big)
    if arch.family == "vision":
        return _vision_rules()
    if arch.family == "diffusion":
        if get_variant().diffusion_spatial2d:
            return []  # replicate params; parallelism is purely spatial
        return _diffusion_rules()
    return []


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh: Mesh, token) -> int:
    if token is None:
        return 1
    if isinstance(token, tuple):
        return int(np.prod([mesh.shape[a] for a in token]))
    return mesh.shape[token]


def _resolve(spec_tokens, mesh: Mesh, shape) -> P:
    """Map rule tokens onto the mesh, dropping non-divisible entries, and
    left-pad with None for stacked (scan) leading axes."""
    fs = fsdp_axes(mesh)
    tokens = []
    for t in spec_tokens:
        if t == "fsdp":
            t = fs if len(fs) > 1 else fs[0]
        tokens.append(t)
    if len(tokens) < len(shape):
        tokens = [None] * (len(shape) - len(tokens)) + tokens
    tokens = tokens[: len(shape)]
    out = []
    for dim, t in zip(shape, tokens):
        if t is not None and dim % _axis_size(mesh, t) == 0:
            out.append(t)
        else:
            out.append(None)
    return P(*out)


def param_shardings(abstract_params, arch: Arch, mesh: Mesh):
    rules = [(re.compile(rx), spec) for rx, spec in shard_rules(arch)]

    def assign(path, leaf):
        ps = _path_str(path)
        for rx, spec in rules:
            if rx.search(ps):
                return NamedSharding(mesh, _resolve(spec, mesh, leaf.shape))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


def state_shardings(abstract_state, arch: Arch, mesh: Mesh):
    """(params, opt, step): moments follow the parameter sharding."""
    params_abs, opt_abs, _ = abstract_state
    p_sh = param_shardings(params_abs, arch, mesh)
    mu_sh = jax.tree_util.tree_map(
        lambda s, a: s, p_sh, opt_abs["mu"]
    )  # same tree structure
    opt_sh = {"mu": mu_sh, "nu": mu_sh, "count": NamedSharding(mesh, P())}
    return (p_sh, opt_sh, NamedSharding(mesh, P()))


def _dp(mesh) -> Any:
    d = dp_axes(mesh)
    return d if len(d) > 1 else d[0]


def input_shardings(bundle_inputs, arch: Arch, cell: Cell, mesh: Mesh):
    """Per-input NamedShardings for one (arch, cell) bundle."""
    dp = _dp(mesh)
    multi = "pod" in mesh.axis_names
    out = {}
    for name, spec in bundle_inputs.items():
        if name == "images" and "sp" in mesh.axis_names:
            # dedicated spatial mesh (make_spatial_mesh): height over "sp"
            sh = NamedSharding(mesh, P(None, "sp", None, None))
        elif name in ("tokens", "labels") and arch.family == "lm":
            b = spec.shape[0]
            tok = dp if b % _axis_size(mesh, dp) == 0 else "data"
            sh = NamedSharding(mesh, P(tok, *([None] * (len(spec.shape) - 1))))
        elif name == "cache":
            sh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, _cache_spec(s.shape, mesh)), spec
            )
        elif name == "index":
            sh = NamedSharding(mesh, P())
        elif name in ("images",):
            b, r = spec.shape[0], spec.shape[1]
            if b == 1:  # serve_b1: the paper's SP -- shard the height axis
                ax = dp if r % _axis_size(mesh, dp) == 0 else "data"
                sh = NamedSharding(mesh, P(None, ax, None, None))
            elif b % _axis_size(mesh, dp) == 0:
                sh = NamedSharding(mesh, P(dp, None, None, None))
            else:
                sh = NamedSharding(mesh, P("data", None, None, None))
        elif name in ("latents", "noise"):
            from .variants import get_variant

            b, r = spec.shape[0], spec.shape[1]
            if get_variant().diffusion_spatial2d and cell.kind == "gen":
                # the paper's technique in 2-D: H over data, W over model
                sh = NamedSharding(mesh, P(None, "data", "model", None))
            elif b % _axis_size(mesh, dp) == 0:
                sh = NamedSharding(mesh, P(dp, None, None, None))
            elif multi and b % mesh.shape["pod"] == 0 and r % mesh.shape["data"] == 0:
                sh = NamedSharding(mesh, P("pod", "data", None, None))
            elif b % mesh.shape["data"] == 0:
                sh = NamedSharding(mesh, P("data", None, None, None))
            else:  # small-batch gen: spatial sharding of the latent height
                sh = NamedSharding(mesh, P(None, "data", None, None))
        elif name in ("t", "cond", "ctx"):
            b = spec.shape[0]
            ax = dp if b % _axis_size(mesh, dp) == 0 else ("data" if b % mesh.shape["data"] == 0 else None)
            sh = NamedSharding(mesh, P(ax, *([None] * (len(spec.shape) - 1))))
        else:
            b = spec.shape[0] if spec.shape else None
            ax = dp if b and b % _axis_size(mesh, dp) == 0 else None
            sh = NamedSharding(
                mesh, P(ax, *([None] * (max(0, len(spec.shape) - 1)))) if spec.shape else P()
            )
        out[name] = sh
    return out


def spatial_shardings(mesh: Mesh, *, axis: str = "sp"):
    """(activation, param) NamedShardings for the HALP spatial executor:
    activations [B, H(or n*Hmax padded), W, C] height-sharded over ``axis``,
    params replicated.  Works for both the equal split and the
    capacity-weighted padded layout (which keeps equal per-device blocks)."""
    return (
        NamedSharding(mesh, P(None, axis, None, None)),
        NamedSharding(mesh, P()),
    )


def weighted_spatial_inputs(x, plan_or_heights, mesh: Mesh, *, axis: str = "sp",
                            align: int = 1):
    """Lay a global image batch out for the capacity-weighted spatial executor.

    ``plan_or_heights`` is either an N-way ``plan_even(ratios=...)`` HALPPlan
    (its first-layer row shares become the shard heights, re-quantised to
    ``align`` -- pass ``spatial_alignment(net)``) or an explicit height tuple.
    Returns ``(x_padded_sharded, heights)``: the padded equal-block layout the
    weighted ``conv2d_spatial(heights=...)`` ops expect, placed with the
    height sharding over ``axis``."""
    from ..spatial.halo import plan_shard_heights, to_padded_shards

    if hasattr(plan_or_heights, "parts"):
        heights = plan_shard_heights(plan_or_heights, align)
    else:
        heights = tuple(int(h) for h in plan_or_heights)
    n = mesh.shape[axis]
    if len(heights) != n:
        raise ValueError(f"{len(heights)} shard heights for a {n}-way {axis!r} axis")
    xp = to_padded_shards(x, heights)
    act_sh, _ = spatial_shardings(mesh, axis=axis)
    return jax.device_put(xp, act_sh), heights


def _cache_spec(shape, mesh: Mesh) -> P:
    """KV caches: [L, B, S, H, dh] -> batch over data, SEQUENCE over model.

    Sequence sharding gives distributed-softmax decode attention: per-shard
    logits stay local, the softmax reduces via tiny psums, and the weighted
    value sum all-reduces one [B, 1, H, dh] vector per layer.  (Head-dim
    sharding -- the first design -- made GSPMD all-gather the whole cache
    shard every step: +40 GB/step on qwen3 decode; §Perf decode iteration.)
    MLA latent caches: [L, B, S, R] -> same layout."""
    dp = _dp(mesh)
    b = shape[1]
    bt = dp if b % _axis_size(mesh, dp) == 0 else (
        "data" if b % mesh.shape["data"] == 0 else None
    )
    s_ax = M if shape[2] % mesh.shape[M] == 0 else None
    if len(shape) == 5:
        return P(None, bt, s_ax, None, None)
    if len(shape) == 4:
        return P(None, bt, s_ax, None)
    return P()
