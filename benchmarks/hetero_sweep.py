"""Heterogeneity sweep: capacity-aware HALP vs. the paper's naive equal split.

The paper evaluates HALP on identical secondaries only; real edge clusters mix
device generations and link qualities.  This benchmark sweeps secondary speed
ratios and link-rate asymmetries on VGG-16 and reports, for each scenario,

* the naive equal-split plan's simulated makespan (the paper's default),
* the capacity-weighted plan (ratios proportional to effective FLOP/s), and
* the optimizer-chosen plan (coordinate descent over ratios x overlap),

plus the N-way scaling of the symmetric cluster.  CSV rows
(``name,us_per_call,derived``) match the other benchmarks' format.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.core import (
    GTX_1080TI,
    CollabTopology,
    Link,
    equal_ratios,
    evaluate_plan,
    optimize_plan,
    simulate_halp,
    standalone_time,
    vgg16_geom,
)

NET = vgg16_geom()


def _two_secondary_topology(slow_factor: float, slow_gbps: float, fast_gbps: float = 40.0):
    slow = GTX_1080TI.scaled(slow_factor, f"slow x{slow_factor:g}")
    return CollabTopology(
        host="e0",
        secondaries=("fast", "slow"),
        platforms={"e0": GTX_1080TI, "fast": GTX_1080TI, "slow": slow},
        links={
            ("e0", "fast"): Link(fast_gbps * 1e9), ("fast", "e0"): Link(fast_gbps * 1e9),
            ("e0", "slow"): Link(slow_gbps * 1e9), ("slow", "e0"): Link(slow_gbps * 1e9),
        },
    )


def sweep_heterogeneous_pairs() -> dict:
    """One fast + one slow secondary across speed/link asymmetry levels."""
    out = {}
    print("\n== Heterogeneity sweep: equal split vs capacity split vs optimizer (ms) ==")
    print(f"{'scenario':28s} {'equal':>8s} {'capacity':>9s} {'optimized':>10s} {'gain':>7s}")
    for slow_factor, slow_gbps in (
        (1.0, 40.0), (0.7, 40.0), (0.5, 40.0), (0.35, 10.0), (0.25, 5.0),
    ):
        topo = _two_secondary_topology(slow_factor, slow_gbps)
        equal = evaluate_plan(NET, topo, equal_ratios(topo), 4)
        capacity = evaluate_plan(NET, topo, topo.capacity_ratios(), 4)
        res = optimize_plan(NET, topo)
        gain = 1.0 - res.makespan / equal
        name = f"slow_x{slow_factor:g}_@{slow_gbps:g}G"
        print(
            f"{name:28s} {equal*1e3:8.3f} {capacity*1e3:9.3f} {res.makespan*1e3:10.3f} "
            f"{gain*100:6.1f}%  (ratios={[round(r, 3) for r in res.ratios]}, w={res.overlap_rows})"
        )
        print(f"hetero_{name},{res.makespan*1e6:.1f},{gain:.4f}")
        out[name] = dict(
            equal=equal, capacity=capacity, optimized=res.makespan,
            ratios=res.ratios, overlap=res.overlap_rows, gain=gain,
        )
    return out


def sweep_nway_scaling() -> dict:
    """Symmetric N-way scaling: more collaborating pairs on one host."""
    out = {}
    t_pre = standalone_time(NET, GTX_1080TI)
    print("\n== N-way scaling, identical secondaries @ 40 Gbps ==")
    print(f"{'N':>3s} {'T (ms)':>8s} {'speedup':>8s}")
    for n in (2, 3, 4, 5):
        topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9), n_secondaries=n)
        t = simulate_halp(NET, topology=topo)["total"]
        print(f"{n:3d} {t*1e3:8.3f} {t_pre/t:7.2f}x")
        print(f"nway_{n},{t*1e6:.1f},{t_pre/t:.3f}")
        out[n] = dict(total=t, speedup=t_pre / t)
    return out


def run_all() -> dict:
    return dict(pairs=sweep_heterogeneous_pairs(), nway=sweep_nway_scaling())


if __name__ == "__main__":
    run_all()
