"""HALP / MoDNN latency models (paper §IV, eqs. 10-23) + platform calibration.

Two latency engines exist in this package:

* this module -- the paper's *closed-form recursions* generalised to arbitrary
  :class:`~repro.core.topology.CollabTopology` instances (eqs. 16-20 single
  task, eqs. 22-23 multi-task, heterogeneous per-ES compute and per-link
  communication terms, N secondaries with K = N - 1 host zones), plus the
  MoDNN baseline as the paper describes it in §I/§V, and
* ``repro.core.simulator`` -- an exact discrete-event simulation of the same
  job/message DAG, used as ground truth by the benchmarks.

Both engines price the event topology produced by ``repro.core.events`` (one
plan-walk, two consumers), so their cross-validation in
``tests/test_schedule.py`` is structural, not coincidental.  For the paper's
symmetric two-secondary setting the recursion below reproduces the original
eqs. 16-20/22-23 term for term.

Platform efficiency is *calibrated* against the paper's own anchor timings
(§V.C: t_pre = 4.7 ms for VGG-16 on the GTX 1080TI; Table II: 124 fps on the
Jetson AGX Xavier), because the paper's measured times do not follow peak-FLOP
arithmetic exactly (cuDNN effects).  Every downstream number (Figs. 6-7,
Tables II-III) is then *derived*, not fitted.
"""
from __future__ import annotations

from typing import Sequence

from .events import final_bytes, init_bytes, resolve_halp_setup, sec_step, zone_step
from .nets import ConvNetGeom, vgg16_geom
from .partition import HALPPlan, plan_even
from .topology import CollabTopology, Link, Platform

__all__ = [
    "Platform",
    "Link",
    "CollabTopology",
    "GTX_1080TI",
    "AGX_XAVIER",
    "TPU_V5E",
    "standalone_time",
    "halp_closed_form",
    "modnn_time",
    "speedup_ratio",
]


def _calibrated(name: str, peak: float, t_pre_vgg16: float) -> Platform:
    eff = vgg16_geom().total_flops() / t_pre_vgg16
    return Platform(name=name, peak_flops=peak, eff_flops=eff)


# Paper anchors: §V.C gives t_pre = 4.7 ms (1080TI); Table II gives 124 fps for
# the pre-trained model on Xavier => 4 frames / 124 fps = 32.26 ms per batch,
# which the paper treats as t_pre (perfect batch amortisation; see DESIGN.md).
GTX_1080TI = _calibrated("GTX 1080TI", peak=11.3e12, t_pre_vgg16=4.7e-3)
AGX_XAVIER = _calibrated("JETSON AGX Xavier", peak=1.3e12, t_pre_vgg16=4.0 / 124.0)
# TPU v5e (the deployment target of the framework; used by spatial/ analyses).
TPU_V5E = Platform(name="TPU v5e", peak_flops=197e12, eff_flops=0.55 * 197e12)


def standalone_time(net: ConvNetGeom, platform: Platform) -> float:
    """t_pre: the whole task on one ES (eq. 21 denominator)."""
    return platform.compute_time(net.total_flops())


def speedup_ratio(t: float, t_pre: float) -> float:
    """Paper eq. (21): rho = 1 - T/t_pre (plotted in Figs. 6-7)."""
    return 1.0 - t / t_pre


def _init_bytes(plan: HALPPlan, es: str) -> float:
    """Eq. (10) -- kept as an alias of ``events.init_bytes`` for callers."""
    return init_bytes(plan, es)


def halp_closed_form(
    net: ConvNetGeom,
    platform: Platform | None = None,
    link: Link | None = None,
    overlap_rows: int | None = None,
    n_tasks: int = 1,
    topology: CollabTopology | None = None,
    ratios: Sequence[float] | None = None,
    plan: HALPPlan | None = None,
    multitask_bound: str = "list",
) -> dict:
    """Paper eqs. (16)-(20) (single task) and (22)-(23) (multi-task), over an
    arbitrary collaboration topology.

    The recursion runs over the plan's ordered slot list: every secondary
    accumulates eq. (17) with its *own* platform and link rates, the host term
    walks the K zones in row order (eq. 18 per zone for a single task), and
    eq. (19)/(20) close the recursion with per-link arrival times.  With the
    symmetric two-secondary topology this is the paper's recursion verbatim.

    ``multitask_bound`` selects the ``n_tasks > 1`` host term:

    * ``"list"`` (default) -- the tightened bound: flatten the per-task zone
      chunk lists in the order the host actually serves them (task-major, row
      order within a task; paper §IV.B) and take the list-scheduling makespan
      ``max_q (sum_{r<=q} cmp_r + com_q)``, i.e. every chunk's send overlaps
      all later chunks' compute.  For a single task this is exactly eq. (18)
      and its K-zone generalisation; for multiple tasks it is term-by-term
      <= the paper's eq. (22) (see ``docs/equations.md``).
    * ``"eq22"`` -- the paper's eq. (22) verbatim-generalised: all per-task
      zone sets priced as fully serialised compute plus one worst-case send,
      ``max_m (m * t_zone + t_com_max)``.  Kept as the reference bound the
      conformance suite asserts the tightened form against.
    """
    if multitask_bound not in ("list", "eq22"):
        raise ValueError(f"multitask_bound must be 'list' or 'eq22', got {multitask_bound!r}")
    topology, plan = resolve_halp_setup(
        net, platform, link, overlap_rows, topology, ratios, plan
    )
    host = plan.host
    host_platform = topology.platform_of(host)
    n_layers = len(net.layers)
    width = net.sizes()

    def cmp_rows(p: Platform, i: int, rows: int) -> float:
        return p.compute_time(net.layers[i].flops_per_out_row(width[i + 1]) * rows)

    secs = plan.secondary_slots
    zones = plan.zone_slots
    T_sec = {s: 0.0 for s in secs}  # eq. 17 accumulators
    T_host = 0.0  # eq. 19 accumulator
    per_layer = []
    for i in range(n_layers):
        t_sec_arrival = {}
        for s in secs:
            step = sec_step(plan, i, s)
            p_s = topology.platform_of(s)
            up = topology.link_between(s, host)
            t_cmp_dep = cmp_rows(p_s, i, step.dep_rows)
            t_com_dep = up.comm_time(sum(nb for _, _, nb in step.sends)) * n_tasks
            t_cmp_rest = cmp_rows(p_s, i, step.own_rows - step.dep_rows)
            t_int = (
                topology.link_between(host, s).comm_time(init_bytes(plan, s))
                if i == 0
                else 0.0
            )
            # eq. (16)
            t_layer = t_int + t_cmp_dep + max(t_com_dep, t_cmp_rest)
            prev = T_sec[s]
            T_sec[s] = prev + t_layer  # eq. (17)
            # arrival of s's boundary rows at the host (second term of eq. 19)
            t_sec_arrival[s] = prev + t_int + t_cmp_dep + t_com_dep
        # host term: eq. (18) single task, eq. (22) multi-task, summed over zones
        if i == n_layers - 1:
            t_host = sum(cmp_rows(host_platform, i, plan.parts[i].out[z].rows) for z in zones)
        elif n_tasks == 1:
            if len(zones) == 1:
                # eq. (18) verbatim (the paper's two-secondary form)
                step = zone_step(plan, i, zones[0])
                t_cmp_a = cmp_rows(host_platform, i, step.rows_for_above)
                t_cmp_b = cmp_rows(host_platform, i, step.zone_rows - step.rows_for_above)
                t_com_1 = topology.link_between(host, step.above).comm_time(step.bytes_to_above)
                t_com_2 = topology.link_between(host, step.below).comm_time(step.bytes_to_below)
                t_host = t_cmp_a + max(t_com_1, t_cmp_b + t_com_2)
            else:
                # K zones: the host computes chunks in row order and each
                # chunk's send overlaps all later chunks (non-blocking NIC),
                # so the busy time is the list-scheduling makespan
                # max_q (sum_{r<=q} cmp_r + com_q) -- eq. (18) generalised.
                cum = 0.0
                t_host = 0.0
                for z in zones:
                    step = zone_step(plan, i, z)
                    cum += cmp_rows(host_platform, i, step.rows_for_above)
                    t_host = max(
                        t_host,
                        cum
                        + topology.link_between(host, step.above).comm_time(
                            step.bytes_to_above
                        ),
                    )
                    cum += cmp_rows(
                        host_platform, i, step.zone_rows - step.rows_for_above
                    )
                    t_host = max(
                        t_host,
                        cum
                        + topology.link_between(host, step.below).comm_time(
                            step.bytes_to_below
                        ),
                    )
        elif multitask_bound == "eq22":
            # eq. (22): the per-task zones are computed sequentially; the m-th
            # group's sends start after the first m zone-sets are done.
            t_zone = sum(cmp_rows(host_platform, i, plan.parts[i].out[z].rows) for z in zones)
            t_com_max = 0.0
            for z in zones:
                step = zone_step(plan, i, z)
                t_com_max = max(
                    t_com_max,
                    topology.link_between(host, step.above).comm_time(step.bytes_to_above),
                    topology.link_between(host, step.below).comm_time(step.bytes_to_below),
                )
            t_host = max(m * t_zone + t_com_max for m in range(1, n_tasks + 1))
        else:
            # Tightened eq. (22): the host serves the per-task zone chunks in
            # task order (paper §IV.B), each chunk's send overlapping every
            # later chunk's compute (non-blocking NIC) -- the same
            # list-scheduling bound as the single-task K-zone case, flattened
            # across tasks.  Each term is <= its eq. (22) counterpart:
            # the compute prefix sum is <= m * t_zone and each send is <=
            # t_com_max, so the bound can only tighten (asserted on the
            # conformance grid in tests/test_conformance.py).
            cum = 0.0
            t_host = 0.0
            for _m in range(n_tasks):
                for z in zones:
                    step = zone_step(plan, i, z)
                    cum += cmp_rows(host_platform, i, step.rows_for_above)
                    t_host = max(
                        t_host,
                        cum
                        + topology.link_between(host, step.above).comm_time(
                            step.bytes_to_above
                        ),
                    )
                    cum += cmp_rows(
                        host_platform, i, step.zone_rows - step.rows_for_above
                    )
                    t_host = max(
                        t_host,
                        cum
                        + topology.link_between(host, step.below).comm_time(
                            step.bytes_to_below
                        ),
                    )
        # eq. (19)
        T_host = max(t_host + T_host, max(t_sec_arrival.values()))
        entry = dict(layer=net.layers[i].name, T_host=T_host)
        entry.update({f"T_{s}": T_sec[s] for s in secs})
        per_layer.append(entry)

    # g_N: secondaries ship their full sub-outputs to the host (eqs. 13-14),
    # which merges them and runs the head (FLs).
    t_final_com = max(
        topology.link_between(s, host).comm_time(final_bytes(plan, s)) * n_tasks
        for s in secs
    )
    T_gn = max(T_host, max(T_sec.values()) + t_final_com)  # eq. (20)
    t_head = host_platform.compute_time(net.head_flops) * n_tasks
    total = T_gn + t_head  # eq. (15)
    return dict(total=total, per_layer=per_layer, plan=plan)


def modnn_time(
    net: ConvNetGeom,
    platform: Platform,
    link: Link,
    n_workers: int,
) -> float:
    """MoDNN-style conventional layer-wise parallelization (paper Fig. 3, §I).

    Workers hold an even slice; after each CL all boundary rows are exchanged
    *synchronously through the host* (compute and communication do not overlap),
    serialised on the host NIC.  This is the paper's baseline behaviour: the
    per-layer time is max-worker-compute + gather + scatter.
    """
    plan = plan_even(net, n_workers)
    width = net.sizes()
    total = 0.0
    names = plan.es_names
    host = names[0]
    # initial scatter of the image slices to the n-1 non-host workers
    total += sum(link.comm_time(init_bytes(plan, w)) for w in names[1:])
    for i in range(len(net.layers)):
        cmp = max(
            platform.compute_time(
                net.layers[i].flops_per_out_row(width[i + 1]) * plan.parts[i].out[w].rows
            )
            for w in names
        )
        gather = scatter = 0.0
        for a in names:
            for b in names:
                if a == b:
                    continue
                nbytes = plan.message_bytes(i, a, b)
                if nbytes == 0.0:
                    continue
                if b == host:
                    gather += link.comm_time(nbytes)
                elif a == host:
                    scatter += link.comm_time(nbytes)
                else:  # worker->worker routed via the host: counts both ways
                    gather += link.comm_time(nbytes)
                    scatter += link.comm_time(nbytes)
        total += cmp + gather + scatter
    # final merge of all sub-outputs to the host + head
    total += sum(
        link.comm_time(plan.net.feature_bytes(len(net.layers) - 1, plan.parts[-1].out[w].rows))
        for w in names[1:]
    )
    total += platform.compute_time(net.head_flops)
    return total
