"""Segment-based task partitioning (paper §III, eqs. 5-9) and the HALP plan.

The host ES partitions every layer's *output rows* into contiguous **slots**
along the row axis.  Slots alternate between secondary segments and host-owned
overlapping zones (paper Fig. 2 / eqs. 6-7); with N secondaries there are
K = N - 1 zones:

    s_0 | zone_0 | s_1 | zone_1 | ... | zone_{K-1} | s_K

For the paper's symmetric pair this degenerates to the familiar triple

    rows 1..a           -> secondary e1
    rows a+1..a+w       -> host e0     (the "overlapping zone", w ~ 4 rows)
    rows a+w+1..O       -> secondary e2

Each slot's required *input rows* follow from the receptive-field arithmetic
(eqs. 8-9 / exact interval algebra), and all inter-slot messages follow from
range intersections, so the plan is lossless by construction.  Secondary
segment sizes may be *capacity-weighted* (``ratios``; DistrEdge-style unequal
splits for heterogeneous ESs), and every zone is owned by the host, preserving
the scheme's invariant that secondaries never exchange rows directly.
``plan_even`` provides the N-way even split for the TPU spatial-parallel
engine (``repro.spatial``) and the MoDNN baseline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Sequence, TYPE_CHECKING

from .nets import ConvNetGeom, DTYPE_BYTES
from .rf import input_range_exact

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from .topology import CollabTopology

__all__ = [
    "Segment",
    "LayerPartition",
    "HALPPlan",
    "PlanInfeasible",
    "PlanLayout",
    "split_rows",
    "plan_halp",
    "plan_halp_n",
    "plan_halp_topology",
    "plan_layout",
    "plan_from_layout",
    "plan_even",
    "SCHEME_HALO",
    "SCHEME_NP",
    "SCHEME_HS",
    "SCHEME_HOST",
    "SCHEMES",
    "SchemeSegment",
    "SchemeLayout",
    "SchemePlan",
    "stage_spans",
    "stage_scheme_options",
    "baseline_assignment",
    "scheme_layout",
    "plan_scheme",
    "hub_segment_fracs",
    "comm_bytes_per_stage",
]


class PlanInfeasible(ValueError):
    """A partition that cannot be realised under the HALP invariants.

    Carries the offending ``layer`` and the layers auto-reduction should try
    shrinking (``reduce_at``), so :func:`plan_halp_n` can degrade gracefully
    instead of giving up."""

    def __init__(self, layer: int, msg: str, reduce_at: tuple[int, ...] = ()):
        super().__init__(msg)
        self.layer = layer
        self.reduce_at = reduce_at or (layer,)

E1, E0, E2 = "e1", "e0", "e2"  # paper's ES names; e0 is the host


@dataclass(frozen=True)
class Segment:
    """1-indexed inclusive row range; empty iff lo > hi."""

    lo: int
    hi: int

    @property
    def rows(self) -> int:
        return max(0, self.hi - self.lo + 1)

    def intersect(self, other: "Segment") -> "Segment":
        return Segment(max(self.lo, other.lo), min(self.hi, other.hi))

    def __bool__(self) -> bool:  # truthy iff non-empty
        return self.rows > 0


EMPTY = Segment(1, 0)

# Interval twin of EMPTY for the layout layer (plain tuples, no dataclass).
EMPTY_IV = (1, 0)


def _message_iv(
    need: tuple[int, int], own: tuple[int, int], got: tuple[int, int]
) -> tuple[int, int]:
    """The message algebra shared by :meth:`HALPPlan.message` and
    :class:`PlanLayout`: rows of ``own`` that ``need`` covers and ``got``
    does not already hold.  Intervals are 1-indexed inclusive, empty iff
    lo > hi.  One definition serves both views so the batched planning
    engine cannot drift from the materialised plan."""
    lo = max(need[0], own[0])
    hi = min(need[1], own[1])
    if lo > hi:
        return EMPTY_IV
    pieces = []
    if lo < got[0]:
        pieces.append((lo, min(hi, got[0] - 1)))
    if hi > got[1]:
        pieces.append((max(lo, got[1] + 1), hi))
    if not pieces:
        return EMPTY_IV
    if len(pieces) == 1:
        return pieces[0]
    # src on both sides of dst cannot happen with contiguous ordered segments
    raise AssertionError("non-contiguous message; segment ordering violated")


# Cross-candidate cache of per-layer walk quantities (see PlanLayout.walk):
# a layer's priced rows are a pure function of (its slot boundaries, the next
# layer's input needs), and coordinate-descent candidates share most layers.
_WALK_LAYER_CACHE: dict[tuple, tuple] = {}


def _union_iv_rows(ivs: list[tuple[int, int]]) -> int:
    """Distinct rows covered by possibly-overlapping intervals (a 1-row middle
    secondary can owe the *same* row to two adjacent zones; it computes it
    once)."""
    rows = 0
    cur_hi = 0
    for lo, hi in sorted(iv for iv in ivs if iv[0] <= iv[1]):
        lo = max(lo, cur_hi + 1)
        if hi >= lo:
            rows += hi - lo + 1
            cur_hi = hi
    return rows


@dataclass(frozen=True)
class LayerPartition:
    """Partition of one layer: output segments and required input ranges per slot."""

    index: int
    out: dict[str, Segment]
    inp: dict[str, Segment]  # exact input rows each slot needs (eqs. 8-9, exact form)


@dataclass(frozen=True)
class HALPPlan:
    net: ConvNetGeom
    parts: tuple[LayerPartition, ...]
    es_names: tuple[str, ...]  # slot names in row order: (e1, e0, e2) or N-way
    host: str = E0  # the ES that owns every overlapping zone
    slot_owner: tuple[str, ...] = ()  # parallel to es_names; () -> slots own themselves

    def owner_of(self, slot: str) -> str:
        """The physical ES that computes ``slot`` (zones resolve to the host)."""
        if self.slot_owner:
            return self.slot_owner[self.es_names.index(slot)]
        return slot

    @property
    def secondary_slots(self) -> tuple[str, ...]:
        return tuple(s for s in self.es_names if self.owner_of(s) != self.host)

    @property
    def zone_slots(self) -> tuple[str, ...]:
        return tuple(s for s in self.es_names if self.owner_of(s) == self.host)

    def adjacent_zones(self, sec_slot: str) -> tuple[str, ...]:
        """Host zone slots bordering a secondary slot (above first, in row order)."""
        idx = self.es_names.index(sec_slot)
        out = []
        for j in (idx - 1, idx + 1):
            if 0 <= j < len(self.es_names) and self.owner_of(self.es_names[j]) == self.host:
                out.append(self.es_names[j])
        return tuple(out)

    def adjacent_secondaries(self, zone_slot: str) -> tuple[str, str]:
        """The (above, below) secondary slots bordering a host zone."""
        idx = self.es_names.index(zone_slot)
        return self.es_names[idx - 1], self.es_names[idx + 1]

    def owner_rows(self, layer: int, es: str) -> Segment:
        return self.parts[layer].out[es]

    def active_secondaries(self, layer: int) -> tuple[str, ...]:
        """Secondary slots owning at least one row at ``layer`` (auto-reduced
        or ratio-starved slots drop out of this list)."""
        return tuple(s for s in self.secondary_slots if self.parts[layer].out[s])

    def message(self, layer: int, src: str, dst: str) -> Segment:
        """Rows of layer ``layer``'s *output* that src owns and dst needs as
        input for layer ``layer + 1`` (or for the head merge if last layer)."""
        if layer + 1 >= len(self.parts):
            # final layer: everything the secondaries own is sent to the host
            # to be merged as the FL input (paper eqs. 13-14, g_i = g_N case).
            if dst == self.host and self.owner_of(src) != self.host:
                return self.parts[layer].out[src]
            return EMPTY
        if src == dst:
            return EMPTY
        need = self.parts[layer + 1].inp[dst]
        own = self.parts[layer].out[src]
        got = self.parts[layer].out[dst]
        lo, hi = _message_iv((need.lo, need.hi), (own.lo, own.hi), (got.lo, got.hi))
        return Segment(lo, hi) if lo <= hi else EMPTY

    def message_bytes(self, layer: int, src: str, dst: str) -> float:
        seg = self.message(layer, src, dst)
        if not seg:
            return 0.0
        g = self.net.layers[layer]
        width = self.net.sizes()[layer + 1]
        return DTYPE_BYTES * seg.rows * width * g.c_out


def _split_counts(total: int, ratios: Sequence[float]) -> list[int]:
    """Row counts of :func:`split_rows`'s segments (the partitioner's inner
    loop only needs counts, not Segment objects)."""
    if total < 0:
        raise ValueError(f"total must be >= 0, got {total}")
    if abs(sum(ratios) - 1.0) > 1e-9:
        raise ValueError(f"ratios must sum to 1, got {sum(ratios)}")
    bounds = [0]
    acc = 0.0
    for r in ratios[:-1]:
        acc += r
        bounds.append(min(total, max(bounds[-1], int(round(acc * total)))))
    bounds.append(total)
    return [hi - lo for lo, hi in zip(bounds[:-1], bounds[1:])]


def split_rows(total: int, ratios: Sequence[float]) -> list[Segment]:
    """Paper eqs. (6)-(7) generalised: contiguous segments by cumulative ratio.

    Segments exactly cover 1..total; rounding via the cumulative boundary keeps
    every segment within +-1 row of its exact ratio share.  Heavily skewed
    ratios on small totals may produce *empty* segments (lo > hi) -- callers
    that need a minimum occupancy must redistribute (see ``plan_halp_n``)."""
    counts = _split_counts(total, ratios)
    segs = []
    lo = 0
    for c in counts:
        segs.append(Segment(lo + 1, lo + c))
        lo += c
    return segs


def _align_down(x: int, align: int) -> int:
    return (x // align) * align


def _pool_alignment(net: ConvNetGeom, i: int, o: int) -> int:
    """Product of pooling strides between layer i and the next conv, reduced
    until it is small relative to the feature map (seed heuristic)."""
    align = 1
    for h in net.layers[i + 1 :]:
        if h.kind != "pool":
            break
        align *= h.s
    while align > max(1, o // 4):
        align //= 2
    return max(1, align)


def _min_one_unit(counts: list[int], body_u: int) -> list[int]:
    """Give every secondary at least one unit when the body is large enough,
    taking units from the largest segment (largest-remainder style fixup)."""
    n = len(counts)
    if body_u < n:
        return counts
    counts = list(counts)
    while min(counts) < 1:
        counts[counts.index(max(counts))] -= 1
        counts[counts.index(min(counts))] += 1
    return counts


def _conv_slot_rows(
    o: int, overlap_rows: int, ratios: Sequence[float], align: int
) -> list[int]:
    """Row counts of the 2K+1 slots (sec, zone, sec, ..., sec) for one conv layer.

    Works in units of ``align`` so that both edges of every host zone land on
    pooling-stride multiples (pools never cross a slot boundary); the last
    secondary absorbs the division remainder."""
    n_sec = len(ratios)
    k_zones = n_sec - 1
    w_eff = min(overlap_rows, max(1, o - 2))
    units = o // align
    w_u = max(1, -(-w_eff // align))  # ceil
    while units - k_zones * w_u < n_sec and w_u > 1:
        w_u -= 1
    body_u = units - k_zones * w_u
    if body_u < 0:
        raise ValueError(
            f"cannot fit {n_sec} secondaries + {k_zones} zones into {o} rows"
        )
    sec_u = _min_one_unit(_split_counts(body_u, ratios), body_u)
    counts = []
    for j in range(n_sec):
        counts.append(sec_u[j] * align)
        if j < k_zones:
            counts.append(w_u * align)
    counts[-1] += o - units * align  # remainder rows go to the last secondary
    return counts


def _reduced_slot_rows(
    o: int, overlap_rows: int, ratios: Sequence[float], align: int, n_active: int
) -> list[int]:
    """Slot row counts when only the first ``n_active`` secondaries stay active.

    Layout (graceful degradation, part 2): the leading ``n_active`` secondaries
    keep their interleaved thin zones, the zone right after the last active
    secondary becomes a *host-owned tail* absorbing the row share of every
    dropped secondary, and all trailing slots own zero rows:

        s_0 | z_0 | ... | s_{n'-1} | tail (host) | 0 | 0 | ...

    The tail must be host-owned: at the layer where reduction kicks in, the
    dropped secondaries' previous-layer rows feed the tail region, and only
    sec->host transfers preserve the no-secondary-exchange invariant.  The
    tail therefore takes the *combined ratio share of the dropped
    secondaries*, keeping every active segment at roughly the size it has in
    the unreduced layout (so thin overlap zones still cover the boundaries)."""
    n_sec = len(ratios)
    if n_active >= n_sec:
        return _conv_slot_rows(o, overlap_rows, ratios, align)
    k_thin = n_active - 1
    w_eff = min(overlap_rows, max(1, o - 2))
    units = o // align
    w_u = max(1, -(-w_eff // align))  # ceil
    while units - k_thin * w_u < n_active + 1 and w_u > 1:
        w_u -= 1
    body_u = units - k_thin * w_u
    if body_u < n_active + 1:  # active secondaries + a non-empty host tail
        raise ValueError(
            f"cannot fit {n_active} active secondaries + a host tail into {o} rows"
        )
    shares = [*ratios[:n_active], sum(ratios[n_active:])]
    total = sum(shares)
    counts_u = _split_counts(body_u, [r / total for r in shares])
    # every active secondary and the tail need at least one unit each
    while min(counts_u) < 1:
        counts_u[counts_u.index(max(counts_u))] -= 1
        counts_u[counts_u.index(min(counts_u))] += 1
    counts = []
    for j in range(n_active):
        counts.append(counts_u[j] * align)
        if j < k_thin:
            counts.append(w_u * align)
    # host tail zone absorbs the dropped share and the alignment remainder
    counts.append(counts_u[-1] * align + (o - units * align))
    counts.extend([0] * (2 * (n_sec - n_active) - 1))
    return counts


def plan_halp(
    net: ConvNetGeom,
    overlap_rows: int = 4,
    es_names: tuple[str, str, str] = (E1, E0, E2),
    ratios: Sequence[float] | None = None,
    auto_reduce: bool = True,
) -> HALPPlan:
    """The paper's 2-secondary HALP partition (§IV.A) -- thin wrapper over
    :func:`plan_halp_n` preserving the original ``(e1, e0, e2)`` interface."""
    lo_name, host, hi_name = es_names
    return plan_halp_n(
        net,
        secondaries=(lo_name, hi_name),
        host=host,
        overlap_rows=overlap_rows,
        ratios=ratios,
        auto_reduce=auto_reduce,
    )


def plan_halp_n(
    net: ConvNetGeom,
    secondaries: Sequence[str],
    host: str = E0,
    overlap_rows: int = 4,
    ratios: Sequence[float] | None = None,
    auto_reduce: bool = True,
) -> HALPPlan:
    """Build the N-way heterogeneous HALP partition.

    Per conv layer, K = N - 1 host zones of ``overlap_rows`` output rows are
    interleaved with N secondary segments whose sizes follow ``ratios``
    (default: equal; pass capacity weights for heterogeneous ESs).  Zone
    boundaries are kept aligned to the strides of the pooling layers that
    follow *before the next conv* (where the partition is re-balanced anyway),
    so pools never cross a slot boundary (paper: "the host ES does not need to
    send the output of the current CL ... for the pooling layer").  Pool
    layers inherit the previous layer's boundaries divided by the stride.

    The plan asserts the scheme's invariant that secondaries never exchange
    rows directly: all boundary traffic flows through the host.  Layers too
    thin to give every secondary at least one alignment unit degrade
    gracefully in two stages.  First, smaller-ratio secondaries may own
    *zero* rows at a layer (they idle; the plan stays lossless).  Second,
    with ``auto_reduce`` (the default), layers where even that breaks the
    invariant -- more slots than rows, or a thin slot forcing a
    secondary-secondary message -- shrink to fewer *active* secondaries: the
    trailing secondaries are dropped from that depth on (monotone -- once
    dropped, an ES stays idle for the rest of the net) and the host absorbs
    their row share in a widened tail zone (:func:`_reduced_slot_rows`).
    Order secondaries fastest-first so reductions shed the weakest ESs.
    Only when even a single active secondary cannot hold a layer does the
    partitioner raise, with the remediation in the message.  With
    ``auto_reduce=False`` any violation raises immediately (the pre-reduction
    behaviour, kept for strict-isolation callers and error-path tests)."""
    return plan_from_layout(
        plan_layout(
            net,
            secondaries,
            host=host,
            overlap_rows=overlap_rows,
            ratios=ratios,
            auto_reduce=auto_reduce,
        )
    )


def _reduce_caps(caps: list[int], exc: PlanInfeasible, conv_anchor: list[int]) -> bool:
    """Shrink the active-secondary cap at the first reducible layer the
    violation names; False when every candidate is already at one secondary
    (the 'even N=1 fails' terminal case)."""
    for j in exc.reduce_at:
        if not 0 <= j < len(caps):
            continue
        j = conv_anchor[j]
        eff = min(caps[: j + 1])
        if eff > 1:
            caps[j] = eff - 1
            return True
    return False


@lru_cache(maxsize=256)
def _net_aligns(net: ConvNetGeom) -> tuple[int, ...]:
    """Per-layer zone alignment, hoisted once per geometry (pools inherit the
    previous layer's boundaries, so their entry is unused)."""
    sizes = net.sizes()
    return tuple(
        _pool_alignment(net, i, sizes[i + 1]) if g.kind != "pool" else 1
        for i, g in enumerate(net.layers)
    )


def _slot_names(secondaries: tuple[str, ...], host: str) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Slot names in row order (sec, zone, sec, ...) and their physical owners."""
    n_sec = len(secondaries)
    k_zones = n_sec - 1
    zone_names = (
        (host,) if k_zones == 1 else tuple(f"{host}#{j}" for j in range(k_zones))
    )
    slots: list[str] = []
    owners: list[str] = []
    for j, s in enumerate(secondaries):
        slots.append(s)
        owners.append(s)
        if j < k_zones:
            slots.append(zone_names[j])
            owners.append(host)
    return tuple(slots), tuple(owners)


@dataclass
class PlanLayout:
    """Integer skeleton of a HALP plan: slot boundaries + input ranges per layer.

    This is the partitioner's result *before* Segment materialisation.  Every
    quantity the latency engines price -- row counts, dependent boundary rows,
    message rows -- derives from it with plain integer arithmetic, so the
    batched planning engine (:class:`repro.core.events.DagTemplate`) can score
    candidate ``(ratios, overlap)`` pairs without building :class:`HALPPlan`
    objects.  :func:`plan_halp_n` materialises this same layout into the full
    plan (:func:`plan_from_layout`), so the two views cannot diverge.

    Slot ``p`` of layer ``i`` owns output rows ``bounds[i][p]+1 ..
    bounds[i][p+1]``; even positions are secondary segments, odd positions are
    host zones.  ``signature`` fingerprints the *structure* of the job/message
    DAG the layout induces (which sends exist per secondary per layer) -- two
    layouts with equal signatures differ only in job durations."""

    net: ConvNetGeom
    host: str
    secondaries: tuple[str, ...]
    overlap_rows: int
    ratios: tuple[float, ...]
    bounds: tuple[tuple[int, ...], ...]
    inp: tuple[tuple[tuple[int, int], ...], ...]
    slots: tuple[str, ...] = field(init=False)
    owners: tuple[str, ...] = field(init=False)

    def __post_init__(self) -> None:
        self.slots, self.owners = _slot_names(self.secondaries, self.host)
        self.n_slots = len(self.slots)
        self.n_layers = len(self.bounds)
        self.sec_pos = tuple(range(0, self.n_slots, 2))
        self.zone_pos = tuple(range(1, self.n_slots, 2))
        self._walked: tuple | None = None

    def out_iv(self, i: int, p: int) -> tuple[int, int]:
        b = self.bounds[i]
        return (b[p] + 1, b[p + 1])

    def message_iv(self, i: int, src_p: int, dst_p: int) -> tuple[int, int]:
        """Interval twin of :meth:`HALPPlan.message` between two *slots*.

        At the last layer slot-to-slot messages are empty (the only last-layer
        traffic is the secondaries' final merge into the host, which is not a
        slot -- see :meth:`final` semantics in ``HALPPlan.message``)."""
        if src_p == dst_p or i + 1 >= self.n_layers:
            return EMPTY_IV
        return _message_iv(
            self.inp[i + 1][dst_p], self.out_iv(i, src_p), self.out_iv(i, dst_p)
        )

    def walk(self) -> tuple:
        """One fused pass over the layout producing everything the batched
        DES evaluator needs, cached: ``(signature, init_rows, sec_rows_per_
        layer, zone_rows_per_layer, final_rows)``.

        The row lists follow the exact job order of the DAG builder's per-task
        blocks (``events._layout_quantities`` stitches them into the template
        parameter vector); the logic is the interval twin of
        ``events.sec_step`` / ``events.zone_step``, inlined for speed -- any
        divergence from those reference step functions is caught bit-exactly
        by the template build self-check."""
        if self._walked is not None:
            return self._walked
        bounds, inp = self.bounds, self.inp
        n_layers, n_slots = self.n_layers, self.n_slots
        sec_pos, zone_pos = self.sec_pos, self.zone_pos
        init_rows = tuple(
            max(0, inp[0][p][1] - inp[0][p][0] + 1) for p in sec_pos
        )
        sec_layers: list[tuple] = []
        zone_layers: list[tuple] = []
        sig_rows: list[tuple] = []
        if len(_WALK_LAYER_CACHE) > 8192:  # unbounded candidate streams
            _WALK_LAYER_CACHE.clear()
        for i in range(n_layers):
            b = bounds[i]
            last = i + 1 >= n_layers
            ninp = None if last else inp[i + 1]
            # layer quantities are a pure function of this layer's boundaries
            # and the next layer's input needs; candidates overlap heavily
            # (coordinate descent moves a few boundary rows per step), so the
            # cache short-circuits most layers of most candidates
            ckey = (b, ninp)
            cached = _WALK_LAYER_CACHE.get(ckey)
            if cached is not None:
                svals, zvals, sig_row = cached
                sec_layers.append(svals)
                zone_layers.append(zvals)
                sig_rows.append(sig_row)
                continue
            svals: list[float] = []
            sig_row: list[tuple] = []
            for p in sec_pos:
                own_lo, own_hi = b[p] + 1, b[p + 1]
                own = own_hi - own_lo + 1
                if last:
                    targets = ()
                    if own > 0 and n_slots > 1:
                        targets = ((p - 1 if p else p + 1, (own_lo, own_hi)),)
                    dep = own
                else:
                    adjacent = []
                    extra = []
                    for z in zone_pos:
                        # inline message_iv(i, p, z)
                        need = ninp[z]
                        lo = max(need[0], own_lo)
                        hi = min(need[1], own_hi)
                        if lo > hi:
                            iv = EMPTY_IV
                        else:
                            got_lo, got_hi = b[z] + 1, b[z + 1]
                            p1, p2 = lo < got_lo, hi > got_hi
                            if p1 and p2:
                                raise AssertionError(
                                    "non-contiguous message; segment ordering violated"
                                )
                            if p1:
                                iv = (lo, min(hi, got_lo - 1))
                            elif p2:
                                iv = (max(lo, got_hi + 1), hi)
                            else:
                                iv = EMPTY_IV
                        if z == p - 1 or z == p + 1:
                            adjacent.append((z, iv))
                        elif iv != EMPTY_IV:
                            extra.append((z, iv))
                    targets = tuple(adjacent + extra)
                    dep = min(own, _union_iv_rows([iv for _, iv in targets]))
                svals.append(dep)
                for _z, iv in targets:
                    svals.append(max(0, iv[1] - iv[0] + 1))
                svals.append(own - dep)
                sig_row.append(tuple(z for z, _ in targets))
            zvals: list[float] = []
            for z in zone_pos:
                if last:
                    above = below = 0
                else:
                    zone_iv = (b[z] + 1, b[z + 1])
                    iva = _message_iv(ninp[z - 1], zone_iv, (b[z - 1] + 1, b[z]))
                    above = iva[1] - iva[0] + 1 if iva[0] <= iva[1] else 0
                    ivb = _message_iv(ninp[z + 1], zone_iv, (b[z + 1] + 1, b[z + 2]))
                    below = ivb[1] - ivb[0] + 1 if ivb[0] <= ivb[1] else 0
                zrows = b[z + 1] - b[z]
                zvals += [above, above, zrows - above, below]
            entry = (tuple(svals), tuple(zvals), tuple(sig_row))
            _WALK_LAYER_CACHE[ckey] = entry
            sec_layers.append(entry[0])
            zone_layers.append(entry[1])
            sig_rows.append(entry[2])
        lb = bounds[-1]
        final_rows = tuple(lb[p + 1] - lb[p] for p in sec_pos) + (1.0,)
        signature = (self.secondaries, tuple(sig_rows))
        self._walked = (signature, init_rows, sec_layers, zone_layers, final_rows)
        return self._walked

    @property
    def signature(self) -> tuple:
        return self.walk()[0]


def plan_layout(
    net: ConvNetGeom,
    secondaries: Sequence[str],
    host: str = E0,
    overlap_rows: int = 4,
    ratios: Sequence[float] | None = None,
    auto_reduce: bool = True,
) -> PlanLayout:
    """Compute the N-way HALP layout (validation + auto-reduction + invariant
    check, identical to :func:`plan_halp_n`, which materialises this result)."""
    secondaries = tuple(secondaries)
    n_sec = len(secondaries)
    if n_sec < 2:
        raise ValueError("HALP needs at least two secondaries around the host")
    if host in secondaries:
        raise ValueError(f"host {host!r} cannot also be a secondary")
    if ratios is None:
        ratios = [1.0 / n_sec] * n_sec
    if len(ratios) != n_sec:
        raise ValueError("need one ratio per secondary")
    total_ratio = sum(ratios)
    if total_ratio <= 0 or any(r < 0 for r in ratios):
        raise ValueError(f"ratios must be non-negative with a positive sum, got {ratios}")
    ratios = [r / total_ratio for r in ratios]
    for i, g in enumerate(net.layers):
        if g.kind == "attn":
            raise PlanInfeasible(
                i,
                f"layer {i} ({g.name}) is attention: every output row depends on "
                f"every input row, so no receptive-field row partition exists -- "
                f"use the head_sequence scheme (plan_scheme)",
                reduce_at=(i,),
            )
    n_layers = len(net.layers)
    # a cap only changes the layout of a *conv* layer; pools inherit, so a
    # reduction aimed at a pool must land on the conv it inherits from
    conv_anchor: list[int] = []
    for i, g in enumerate(net.layers):
        conv_anchor.append(i if g.kind != "pool" or i == 0 else conv_anchor[i - 1])
    caps = [n_sec] * n_layers
    # layer memos survive cap iterations: ratios/overlap are fixed here, so a
    # re-build after a cap reduction recomputes only the layers whose active
    # count actually changed
    conv_cache: dict[tuple, tuple[int, ...]] = {}
    inp_cache: dict[tuple, tuple] = {}
    for _ in range(n_sec * n_layers + 1):
        try:
            layout = _build_layout(
                net, secondaries, host, overlap_rows, ratios, caps, auto_reduce,
                conv_cache, inp_cache,
            )
            _check_layout(layout)
            return layout
        except PlanInfeasible as exc:
            if not auto_reduce or not _reduce_caps(caps, exc, conv_anchor):
                raise
    raise AssertionError("auto-reduce failed to converge")  # pragma: no cover


def _build_layout(
    net: ConvNetGeom,
    secondaries: tuple[str, ...],
    host: str,
    overlap_rows: int,
    ratios: Sequence[float],
    caps: Sequence[int],
    auto_reduce: bool,
    conv_cache: dict[tuple, tuple[int, ...]] | None = None,
    inp_cache: dict[tuple, tuple] | None = None,
) -> PlanLayout:
    n_sec = len(secondaries)
    n_slots = 2 * n_sec - 1
    sizes = net.sizes()
    aligns = _net_aligns(net)
    bounds: list[tuple[int, ...]] = []
    inp: list[tuple[tuple[int, int], ...]] = []
    active = n_sec
    # Memos: nets repeat layer geometry (VGG blocks share the same (rows,
    # alignment) for several convs), so within one candidate most layers are
    # layout-identical -- compute each distinct one once.  plan_layout passes
    # shared dicts so auto-reduce retries also reuse them.
    conv_cache = {} if conv_cache is None else conv_cache
    inp_cache = {} if inp_cache is None else inp_cache
    for i, g in enumerate(net.layers):
        o = sizes[i + 1]
        if auto_reduce:
            # monotone: a cap at any earlier layer (pools included) holds on
            active = min(active, caps[i])
        if g.kind == "pool":
            # pools inherit the previous layer's boundaries (divided by stride).
            prev = bounds[-1]
            bt = (0, *(prev[j] // g.s for j in range(1, n_slots)), o)
        else:
            align = aligns[i]
            counts = conv_cache.get((o, align, active))
            if counts is None:
                if not auto_reduce:
                    counts = _conv_slot_rows(o, overlap_rows, ratios, align)
                else:
                    while True:
                        try:
                            counts = _reduced_slot_rows(o, overlap_rows, ratios, align, active)
                            break
                        except ValueError as err:
                            if active <= 1:
                                raise PlanInfeasible(
                                    i,
                                    f"layer {i} ({o} output rows): {err}; even a single "
                                    f"active secondary does not fit -- use a larger input "
                                    f"or run this layer on one ES",
                                    reduce_at=(i,),
                                ) from err
                            active -= 1
                # keyed on the *final* active: a hit therefore implies the
                # reduction loop already succeeded at this count, so the cap
                # trajectory is identical to recomputing
                conv_cache[(o, align, active)] = counts
            b = [0]
            for c in counts:
                b.append(b[-1] + c)
            bt = tuple(b)
        bounds.append(bt)
        ikey = (g.k, g.s, g.p, sizes[i], bt)
        row = inp_cache.get(ikey)
        if row is None:
            k_, s_, p_ = g.k, g.s, g.p
            size_in = sizes[i]
            # inline input_range_exact (bounds are valid by construction)
            row = tuple(
                (max(bt[p] * s_ + 1 - p_, 1), min((bt[p + 1] - 1) * s_ + k_ - p_, size_in))
                if bt[p + 1] > bt[p]
                else EMPTY_IV
                for p in range(n_slots)
            )
            inp_cache[ikey] = row
        inp.append(row)
    return PlanLayout(
        net=net,
        host=host,
        secondaries=secondaries,
        overlap_rows=overlap_rows,
        ratios=tuple(ratios),
        bounds=tuple(bounds),
        inp=tuple(inp),
    )


def plan_from_layout(layout: PlanLayout) -> HALPPlan:
    """Materialise a :class:`PlanLayout` into the full Segment-based plan."""
    parts: list[LayerPartition] = []
    for i in range(layout.n_layers):
        b = layout.bounds[i]
        out = {
            slot: Segment(b[p] + 1, b[p + 1]) for p, slot in enumerate(layout.slots)
        }
        inp = {
            slot: Segment(*layout.inp[i][p]) for p, slot in enumerate(layout.slots)
        }
        parts.append(LayerPartition(index=i, out=out, inp=inp))
    return HALPPlan(
        net=layout.net,
        parts=tuple(parts),
        es_names=layout.slots,
        host=layout.host,
        slot_owner=layout.owners,
    )


def plan_halp_topology(
    net: ConvNetGeom,
    topology: "CollabTopology",
    overlap_rows: int = 4,
    ratios: Sequence[float] | None = None,
    auto_reduce: bool = True,
) -> HALPPlan:
    """HALP plan for a :class:`~repro.core.topology.CollabTopology`.

    ``ratios`` defaults to the topology's compute-capacity weights (segment
    sizes proportional to effective FLOP/s)."""
    if ratios is None:
        ratios = topology.capacity_ratios()
    return plan_halp_n(
        net,
        secondaries=topology.secondaries,
        host=topology.host,
        overlap_rows=overlap_rows,
        ratios=ratios,
        auto_reduce=auto_reduce,
    )


def plan_even(net: ConvNetGeom, n: int, ratios: Sequence[float] | None = None) -> HALPPlan:
    """N-way contiguous split (used by the TPU spatial engine and the MoDNN
    baseline).

    ``ratios`` weights the per-worker row shares (capacity-weighted splits for
    heterogeneous pods -- a pod mixing TPU generations wants segment sizes
    proportional to per-device effective FLOP/s, exactly like
    :meth:`~repro.core.topology.CollabTopology.capacity_ratios` does for ES
    clusters); the default stays the uniform split.  Any weighting is lossless
    by construction -- the executable backstop
    (``spatial/partition_apply.run_plan``) reconstructs every segment's input
    from the same exact receptive-field algebra."""
    if ratios is None:
        ratios = [1.0 / n] * n
    else:
        ratios = list(ratios)
        if len(ratios) != n:
            raise ValueError(f"need one ratio per worker, got {len(ratios)} for n={n}")
        total = sum(ratios)
        if total <= 0 or any(r < 0 for r in ratios):
            raise ValueError(f"ratios must be non-negative with a positive sum, got {ratios}")
        ratios = [r / total for r in ratios]
    names = tuple(f"w{j}" for j in range(n))
    sizes = net.sizes()
    parts = []
    for i, g in enumerate(net.layers):
        o = sizes[i + 1]
        segs = split_rows(o, ratios)
        out = dict(zip(names, segs))
        inp = {
            es: (
                Segment(*input_range_exact(seg.lo, seg.hi, g.k, g.s, g.p, sizes[i]))
                if seg
                else EMPTY
            )
            for es, seg in out.items()
        }
        parts.append(LayerPartition(index=i, out=out, inp=inp))
    return HALPPlan(net=net, parts=tuple(parts), es_names=names)


def _check_layout(layout: PlanLayout) -> None:
    """Enforce the message invariants both latency engines rely on.

    * **Secondaries never exchange rows directly** (the scheme's hard
      invariant -- there is no secondary-secondary link).  Violations mean a
      slot is too thin for the receptive field: widen the overlap zone,
      rebalance the ratios, or let auto-reduction drop the slot.
    * **Host-zone -> secondary messages must come from an adjacent slot**:
      the zone chunk schedule (``events.zone_step``) only prices sends to the
      two neighbouring secondaries, so a skip there would be unpriced.
    * Secondary -> host messages may target *any* zone (physically a direct
      uplink; ``events.sec_step`` prices sends to every zone), and rows moving
      between two host-owned zones never leave the host (a local move; the
      host computes layers in submission order, so the rows are resident)."""
    slots = layout.slots
    n_slots = layout.n_slots
    for i in range(layout.n_layers - 1):
        b = layout.bounds[i]
        ninp = layout.inp[i + 1]
        for pa in range(n_slots):
            a_host = pa % 2 == 1  # odd positions are host-owned zones
            own_lo, own_hi = b[pa] + 1, b[pa + 1]
            if own_lo > own_hi:
                continue  # empty source slot sends nothing
            for pb in range(n_slots):
                if pb == pa:
                    continue
                b_host = pb % 2 == 1
                if a_host and b_host:
                    continue  # zone-to-zone: host-local move
                if not a_host and b_host:
                    continue  # sec -> any host zone: direct uplink, priced
                if abs(pa - pb) <= 1 and a_host != b_host:
                    continue  # adjacent host<->sec: the paper's boundary flow
                need = ninp[pb]
                lo = max(need[0], own_lo)
                hi = min(need[1], own_hi)
                if lo > hi:
                    continue
                lo, hi = _message_iv(need, (own_lo, own_hi), (b[pb] + 1, b[pb + 1]))
                if lo > hi:
                    continue
                if not a_host and not b_host:
                    raise PlanInfeasible(
                        i,
                        f"layer {i}: secondaries {slots[pa]} and {slots[pb]} would "
                        f"exchange rows {lo}..{hi} directly; widen the overlap zone, "
                        f"rebalance the segment ratios, or enable auto_reduce",
                        reduce_at=(i + 1, i),
                    )
                raise PlanInfeasible(
                    i,
                    f"layer {i}: zone {slots[pa]} would need to send rows "
                    f"{lo}..{hi} to non-adjacent secondary {slots[pb]}; widen "
                    f"the overlap zone or rebalance the segment ratios",
                    reduce_at=(i + 1, i),
                )


# ---------------------------------------------------------------------------
# Per-stage partitioning schemes (ROADMAP direction 4)
#
# The halo'd row-segment layout above is one *scheme*.  A plan may now choose a
# scheme per **stage** (the layer groups between pooling boundaries, plus one
# stage per attention block):
#
# * ``halo_segment``   -- the receptive-field row split above, bit-identical to
#   ``plan_halp_n`` when chosen for every stage of a conv net.
# * ``non_penetrative`` -- output-channel splits (NPTP, arxiv 2501.04489): zero
#   overlap zones and *no halo edges* in the DAG.  Channel-local layers
#   (pool/depthwise) forward their partition for free; dense convs re-gather
#   the full input through the host hub.
# * ``head_sequence``  -- attention stages: heads split across secondaries
#   (each head attends over the full token grid), the pointwise convs between
#   them split by token rows.  The only scheme that partitions attention.
# * ``host_solo``      -- implicit fallback (not part of the searchable
#   vocabulary): the host computes the stage alone.  Stages no scheme in the
#   vocabulary can legally partition degrade to this.
#
# Non-halo schemes use a **hub model**: the host holds the full feature map at
# segment boundaries and relays every redistribution (the no-secondary-exchange
# invariant carries over -- all traffic is host<->secondary).  The host
# contributes no compute inside hub segments; its capacity is spent relaying.
# ---------------------------------------------------------------------------

SCHEME_HALO = "halo_segment"
SCHEME_NP = "non_penetrative"
SCHEME_HS = "head_sequence"
SCHEME_HOST = "host_solo"
SCHEMES = (SCHEME_HALO, SCHEME_NP, SCHEME_HS)


def _is_pointwise(g) -> bool:
    return g.kind == "conv" and g.k == 1 and g.s == 1 and g.p == 0


def stage_spans(net: ConvNetGeom) -> tuple[tuple[int, int], ...]:
    """Inclusive (start, stop) layer spans of the scheme stages.

    A new stage starts at layer 0, after every pooling layer (where the halo
    layout re-balances anyway), and at every attention layer (pointwise layers
    following an attention stay in its stage, so ViT blocks are one stage)."""
    starts = [
        i
        for i, g in enumerate(net.layers)
        if i == 0 or g.kind == "attn" or net.layers[i - 1].kind == "pool"
    ]
    stops = [s - 1 for s in starts[1:]] + [len(net.layers) - 1]
    return tuple(zip(starts, stops))


def _scheme_valid(net: ConvNetGeom, span: tuple[int, int], scheme: str) -> bool:
    layers = net.layers[span[0] : span[1] + 1]
    if scheme in (SCHEME_HALO, SCHEME_NP):
        return all(g.kind != "attn" for g in layers)
    if scheme == SCHEME_HS:
        return all(g.kind == "attn" or _is_pointwise(g) for g in layers)
    if scheme == SCHEME_HOST:
        return True
    raise ValueError(f"unknown partitioning scheme {scheme!r}")


def stage_scheme_options(
    net: ConvNetGeom, span: tuple[int, int], schemes: Sequence[str] = SCHEMES
) -> tuple[str, ...]:
    """Vocabulary members legal for one stage, in vocabulary order; stages no
    scheme can partition fall back to the host computing them alone."""
    opts = tuple(s for s in schemes if _scheme_valid(net, span, s))
    return opts or (SCHEME_HOST,)


def baseline_assignment(
    net: ConvNetGeom, schemes: Sequence[str] = SCHEMES
) -> tuple[str, ...]:
    """First legal vocabulary member per stage (halo-first under the default
    vocabulary, matching the pre-scheme planner wherever it applied)."""
    return tuple(
        stage_scheme_options(net, span, schemes)[0] for span in stage_spans(net)
    )


@dataclass(frozen=True)
class SchemeSegment:
    """A maximal run of consecutive same-scheme stages, planned as one unit."""

    scheme: str
    start: int  # first layer index (inclusive)
    stop: int  # last layer index (inclusive)
    stages: tuple[int, ...]  # stage indices fused into this segment


def fuse_assignment(
    spans: Sequence[tuple[int, int]], assignment: Sequence[str]
) -> tuple[SchemeSegment, ...]:
    if len(spans) != len(assignment):
        raise ValueError(
            f"need one scheme per stage: {len(assignment)} schemes, {len(spans)} stages"
        )
    segs: list[SchemeSegment] = []
    for idx, (span, sch) in enumerate(zip(spans, assignment)):
        if segs and segs[-1].scheme == sch:
            last = segs[-1]
            segs[-1] = SchemeSegment(sch, last.start, span[1], last.stages + (idx,))
        else:
            segs.append(SchemeSegment(sch, span[0], span[1], (idx,)))
    return tuple(segs)


@lru_cache(maxsize=512)
def _segment_subnet(net: ConvNetGeom, start: int, stop: int) -> ConvNetGeom:
    """The layers of one segment as a standalone geometry (head_flops = 0: the
    overall head runs once, after the whole net)."""
    sizes = net.sizes()
    return ConvNetGeom(
        name=f"{net.name}[{start}:{stop}]",
        in_rows=sizes[start],
        in_channels=net.layers[start].c_in,
        layers=net.layers[start : stop + 1],
        head_flops=0.0,
    )


def hub_segment_fracs(
    net: ConvNetGeom, seg: SchemeSegment, ratios: Sequence[float]
) -> tuple[tuple, tuple[float, ...]]:
    """Work fractions of one hub-relayed (non_penetrative / head_sequence)
    segment: per layer a ``(relay, up, down, cmp)`` entry -- ``relay`` is the
    *structural* flag (does this layer redistribute through the host at all?
    it depends only on the layer kinds, never on the ratios, so every
    candidate of one assignment shares one DAG structure), and the tuples are
    per-secondary fractions of (the layer's input tensor uploaded to the
    host, the input tensor downloaded from the host, the layer's FLOPs
    computed) -- plus the final per-secondary fractions of the last layer's
    output gathered back.

    The fractions encode the hub redistribution algebra:

    * channel-local layers (pool/depthwise under NP; consecutive pointwise
      convs under HS) keep the partition of the previous layer, so up = down
      = 0 -- the transfer-free case that motivates the scheme;
    * partition-axis changes re-gather through the host: each secondary
      uploads the slice it holds and downloads what it lacks (dense convs and
      attention need the *full* input: down = 1 - held);
    * at the segment's first layer the host already holds the full map
      (up = 0, down = what each secondary needs).

    All fractions are of full-tensor bits/FLOPs, so every scheme prices
    through the same rate-independent template machinery as halo layouts."""
    n = len(ratios)
    sizes = net.sizes()
    zeros = (0.0,) * n
    held: tuple[float, ...] | None = None
    held_axis: str | None = None  # "channel" | "heads" | "rows"
    per_layer = []
    for i in range(seg.start, seg.stop + 1):
        g = net.layers[i]
        relay = True
        if seg.scheme == SCHEME_NP:
            counts = _split_counts(g.c_out, ratios)
            share = tuple(c / g.c_out for c in counts)
            if g.kind == "conv":  # dense: every filter needs the full input
                up = held if held is not None else zeros
                down = tuple(1.0 - h for h in (held or zeros))
            else:  # pool/depthwise: channel-local, partition carries over
                if held_axis == "channel":
                    relay, up, down = False, zeros, zeros
                else:
                    up, down = zeros, share
            held, held_axis = share, "channel"
        elif seg.scheme == SCHEME_HS:
            if g.kind == "attn":
                counts = _split_counts(g.heads, ratios)
                share = tuple(c / g.heads for c in counts)
                up = held if held is not None else zeros
                down = tuple(1.0 - h for h in (held or zeros))
                held, held_axis = share, "heads"
            else:  # pointwise conv: token-row split
                o = sizes[i + 1]
                counts = _split_counts(o, ratios)
                share = tuple(c / o for c in counts)
                if held_axis == "rows":
                    # same row partition carries over: transfer-free
                    relay, up, down = False, zeros, zeros
                elif held is None:
                    up, down = zeros, share
                else:  # scatter after a head split: upload heads, download rows
                    up, down = held, share
                held, held_axis = share, "rows"
        else:
            raise ValueError(f"{seg.scheme!r} is not a hub scheme")
        per_layer.append((relay, up, down, share))
    return tuple(per_layer), (held if held is not None else zeros)


def _norm_ratios(ratios: Sequence[float] | None, n_sec: int) -> tuple[float, ...]:
    if ratios is None:
        return (1.0 / n_sec,) * n_sec
    if len(ratios) != n_sec:
        raise ValueError("need one ratio per secondary")
    total = sum(ratios)
    if total <= 0 or any(r < 0 for r in ratios):
        raise ValueError(f"ratios must be non-negative with a positive sum, got {ratios}")
    return tuple(r / total for r in ratios)


@dataclass
class SchemeLayout:
    """Integer/fraction skeleton of a mixed-scheme plan (the scheme twin of
    :class:`PlanLayout`): per segment either a halo sub-layout or the hub
    fraction table.  Everything the batched DES prices derives from it."""

    net: ConvNetGeom
    host: str
    secondaries: tuple[str, ...]
    overlap_rows: int
    ratios: tuple[float, ...]
    assignment: tuple[str, ...]
    spans: tuple[tuple[int, int], ...]
    segments: tuple[SchemeSegment, ...]
    halo_layouts: tuple[PlanLayout | None, ...]  # parallel to segments
    hub_fracs: tuple  # parallel to segments; None for halo/host_solo segments

    @property
    def signature(self) -> tuple:
        """Structure fingerprint: two layouts with equal signatures induce the
        same job/message DAG and differ only in durations."""
        return (
            self.secondaries,
            tuple(
                (seg.scheme, seg.start, seg.stop, lay.signature if lay else None)
                for seg, lay in zip(self.segments, self.halo_layouts)
            ),
        )


def scheme_layout(
    net: ConvNetGeom,
    secondaries: Sequence[str],
    host: str = E0,
    overlap_rows: int = 4,
    ratios: Sequence[float] | None = None,
    assignment: Sequence[str] | None = None,
    schemes: Sequence[str] = SCHEMES,
    auto_reduce: bool = True,
) -> SchemeLayout:
    """Build the mixed-scheme layout for one per-stage scheme assignment.

    Raises :class:`PlanInfeasible` (via the halo sub-planner) when a halo
    segment cannot be realised; hub segments are always feasible."""
    secondaries = tuple(secondaries)
    if len(secondaries) < 2:
        raise ValueError("scheme plans need at least two secondaries around the host")
    if host in secondaries:
        raise ValueError(f"host {host!r} cannot also be a secondary")
    ratios = _norm_ratios(ratios, len(secondaries))
    spans = stage_spans(net)
    if assignment is None:
        assignment = baseline_assignment(net, schemes)
    assignment = tuple(assignment)
    for span, sch in zip(spans, assignment):
        if not _scheme_valid(net, span, sch):
            raise ValueError(
                f"scheme {sch!r} is not valid for stage {span} of {net.name}"
            )
    segments = fuse_assignment(spans, assignment)
    halo_layouts: list[PlanLayout | None] = []
    hub: list = []
    for seg in segments:
        if seg.scheme == SCHEME_HALO:
            sub = _segment_subnet(net, seg.start, seg.stop)
            halo_layouts.append(
                plan_layout(
                    sub,
                    secondaries,
                    host=host,
                    overlap_rows=overlap_rows,
                    ratios=ratios,
                    auto_reduce=auto_reduce,
                )
            )
            hub.append(None)
        elif seg.scheme == SCHEME_HOST:
            halo_layouts.append(None)
            hub.append(None)
        else:
            halo_layouts.append(None)
            hub.append(hub_segment_fracs(net, seg, ratios))
    return SchemeLayout(
        net=net,
        host=host,
        secondaries=secondaries,
        overlap_rows=overlap_rows,
        ratios=ratios,
        assignment=assignment,
        spans=spans,
        segments=segments,
        halo_layouts=tuple(halo_layouts),
        hub_fracs=tuple(hub),
    )


@dataclass(frozen=True)
class SchemePlan:
    """Materialised mixed-scheme plan: the executable twin of
    :class:`SchemeLayout` (halo segments carry full :class:`HALPPlan`\\ s over
    their sub-net).  ``spatial.partition_apply.run_plan`` executes it
    losslessly, scheme by scheme."""

    net: ConvNetGeom
    host: str
    secondaries: tuple[str, ...]
    ratios: tuple[float, ...]
    overlap_rows: int
    assignment: tuple[str, ...]
    spans: tuple[tuple[int, int], ...]
    segments: tuple[SchemeSegment, ...]
    halo_plans: tuple[HALPPlan | None, ...]  # parallel to segments


def plan_from_scheme_layout(layout: SchemeLayout) -> SchemePlan:
    return SchemePlan(
        net=layout.net,
        host=layout.host,
        secondaries=layout.secondaries,
        ratios=layout.ratios,
        overlap_rows=layout.overlap_rows,
        assignment=layout.assignment,
        spans=layout.spans,
        segments=layout.segments,
        halo_plans=tuple(
            plan_from_layout(lay) if lay is not None else None
            for lay in layout.halo_layouts
        ),
    )


def plan_scheme(
    net: ConvNetGeom,
    topology: "CollabTopology",
    overlap_rows: int = 4,
    ratios: Sequence[float] | None = None,
    assignment: Sequence[str] | None = None,
    schemes: Sequence[str] = SCHEMES,
    auto_reduce: bool = True,
) -> SchemePlan:
    """Mixed-scheme plan for a topology (the scheme twin of
    :func:`plan_halp_topology`).  ``ratios`` defaults to capacity weights;
    ``assignment`` defaults to the first legal vocabulary member per stage."""
    if ratios is None:
        ratios = topology.capacity_ratios()
    return plan_from_scheme_layout(
        scheme_layout(
            net,
            topology.secondaries,
            host=topology.host,
            overlap_rows=overlap_rows,
            ratios=ratios,
            assignment=assignment,
            schemes=schemes,
            auto_reduce=auto_reduce,
        )
    )


def _halo_plan_comm_bytes(plan: HALPPlan) -> list[float]:
    """Per-layer link bytes of a halo plan: initial input scatter (charged to
    the first layer), boundary messages, and the final merge (charged to the
    last layer).  Host-zone-to-host-zone moves are host-local (no link)."""
    net = plan.net
    sizes = net.sizes()
    out = [0.0] * len(net.layers)
    for s in plan.secondary_slots:
        seg = plan.parts[0].inp[s]
        out[0] += DTYPE_BYTES * seg.rows * sizes[0] * net.in_channels
    host = plan.host
    for i in range(len(net.layers)):
        for src in plan.es_names:
            for dst in plan.es_names:
                if src == dst:
                    continue
                if plan.owner_of(src) == host and plan.owner_of(dst) == host:
                    continue
                out[i] += plan.message_bytes(i, src, dst)
    return out


def comm_bytes_per_stage(plan: "HALPPlan | SchemePlan") -> list[float]:
    """Link bytes (host<->secondary, both directions) attributed to each stage
    of :func:`stage_spans` -- the benchmark's per-stage comm accounting, one
    definition for halo-only and mixed-scheme plans."""
    net = plan.net
    spans = stage_spans(net)
    stage_of = [0] * len(net.layers)
    for si, (lo, hi) in enumerate(spans):
        for i in range(lo, hi + 1):
            stage_of[i] = si
    out = [0.0] * len(spans)
    if isinstance(plan, HALPPlan):
        for i, b in enumerate(_halo_plan_comm_bytes(plan)):
            out[stage_of[i]] += b
        return out
    sizes = net.sizes()
    for seg, hp in zip(plan.segments, plan.halo_plans):
        if seg.scheme == SCHEME_HOST:
            continue
        if seg.scheme == SCHEME_HALO:
            for off, b in enumerate(_halo_plan_comm_bytes(hp)):
                out[stage_of[seg.start + off]] += b
            continue
        fracs, final = hub_segment_fracs(net, seg, plan.ratios)
        for off, (_relay, up, down, _cmp) in enumerate(fracs):
            i = seg.start + off
            g = net.layers[i]
            in_bytes = DTYPE_BYTES * sizes[i] * sizes[i] * g.c_in
            out[stage_of[i]] += in_bytes * (sum(up) + sum(down))
        g = net.layers[seg.stop]
        out_bytes = DTYPE_BYTES * sizes[seg.stop + 1] * sizes[seg.stop + 1] * g.c_out
        out[stage_of[seg.stop]] += out_bytes * sum(final)
    return out
