"""Collaboration topology: which ESs collaborate, at what speeds, over what links.

The paper's §IV scheme is presented for one symmetric triple (two identical
secondary ESs around one host), but nothing in the receptive-field algebra
requires that.  :class:`CollabTopology` captures the general case:

* an ordered list of *secondary* ESs (their order is their position along the
  partitioned row axis),
* one designated *host* ES that owns every overlapping zone and relays all
  boundary traffic (the no-secondary-exchange invariant), and
* per-ES compute :class:`Platform`\\ s and *directed* per-pair :class:`Link`
  rates (uplink and downlink of an ES may differ).

All four engines consume it: the partitioner derives capacity-weighted segment
ratios from it, the closed-form recursion and the discrete-event simulator
charge per-ES compute and per-link communication from it, and the optimizer
searches plan knobs against it.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Sequence

__all__ = ["Platform", "Link", "CollabTopology"]


@dataclass(frozen=True)
class Platform:
    name: str
    peak_flops: float  # advertised peak (fp32 for the paper's GPUs)
    eff_flops: float  # calibrated effective FLOP/s

    def compute_time(self, flops: float) -> float:
        return flops / self.eff_flops

    def scaled(self, factor: float, name: str | None = None) -> "Platform":
        """A platform ``factor`` x as fast (heterogeneous-cluster modelling)."""
        return Platform(
            name=name or f"{self.name} x{factor:g}",
            peak_flops=self.peak_flops * factor,
            eff_flops=self.eff_flops * factor,
        )


@dataclass(frozen=True)
class Link:
    rate_bps: float  # bits per second

    def comm_time(self, nbytes: float) -> float:
        return 8.0 * nbytes / self.rate_bps


@dataclass(frozen=True)
class CollabTopology:
    """One host + N ordered secondaries with per-ES platforms and per-link rates.

    ``links`` maps directed ``(src, dst)`` ES-name pairs to :class:`Link`;
    pairs not listed fall back to ``default_link``.  ``secondaries`` are
    ordered along the partitioned row axis (first name owns the topmost
    segment).
    """

    host: str
    secondaries: tuple[str, ...]
    platforms: Mapping[str, Platform]
    links: Mapping[tuple[str, str], Link] = field(default_factory=dict)
    default_link: Link | None = None

    def __post_init__(self) -> None:
        if len(self.secondaries) < 1:
            raise ValueError("need at least one secondary ES")
        if self.host in self.secondaries:
            raise ValueError(f"host {self.host!r} cannot also be a secondary")
        for es in (self.host, *self.secondaries):
            if es not in self.platforms:
                raise ValueError(f"no platform for ES {es!r}")

    @property
    def n_secondaries(self) -> int:
        return len(self.secondaries)

    @property
    def es_names(self) -> tuple[str, ...]:
        return (self.host, *self.secondaries)

    def platform_of(self, es: str) -> Platform:
        return self.platforms[es]

    def link_between(self, src: str, dst: str) -> Link:
        link = self.links.get((src, dst), self.default_link)
        if link is None:
            raise KeyError(f"no link {src!r} -> {dst!r} and no default_link")
        return link

    def capacity_ratios(self) -> tuple[float, ...]:
        """Secondary segment ratios proportional to effective FLOP/s.

        This is the DistrEdge-style capacity-aware starting point; the
        optimizer refines it further when link rates are also asymmetric."""
        eff = [self.platforms[s].eff_flops for s in self.secondaries]
        total = sum(eff)
        return tuple(e / total for e in eff)

    def collab_pairs(self) -> tuple[tuple[str, str], ...]:
        """Every directed host<->secondary pair the HALP schedule can use.

        Secondaries never exchange rows directly (the scheme's invariant), so
        these 2N pairs are exactly the links a rate estimator must track."""
        pairs: list[tuple[str, str]] = []
        for s in self.secondaries:
            pairs.append((self.host, s))
            pairs.append((s, self.host))
        return tuple(pairs)

    def sub_topology(self, secondaries: Sequence[str]) -> "CollabTopology":
        """This pool restricted to ``secondaries`` (same host, same rates).

        The subset keeps the *given* order -- it becomes the row order of the
        sub-cluster's plan, so callers (e.g. the per-task placement engine)
        can put faster ESs first and let thin-layer auto-reduction shed the
        weakest members.  Links touching dropped ESs are filtered out."""
        secs = tuple(secondaries)
        if len(set(secs)) != len(secs):
            raise ValueError(f"duplicate secondaries in subset: {secs}")
        for s in secs:
            if s not in self.secondaries:
                raise ValueError(f"{s!r} is not a secondary of this topology")
        keep = {self.host, *secs}
        return CollabTopology(
            host=self.host,
            secondaries=secs,
            platforms={es: self.platforms[es] for es in keep},
            links={p: l for p, l in self.links.items() if p[0] in keep and p[1] in keep},
            default_link=self.default_link,
        )

    def with_links(
        self,
        links: Mapping[tuple[str, str], Link],
        default_link: Link | None = None,
    ) -> "CollabTopology":
        """A copy with some directed link rates replaced (same ESs/platforms).

        This is the measured-rate rebuild used by the online re-planner: pairs
        not in ``links`` keep their current rate (or the default link)."""
        merged = dict(self.links)
        merged.update(links)
        return dataclasses.replace(
            self, links=merged, default_link=default_link or self.default_link
        )

    def with_platforms(self, platforms: Mapping[str, Platform]) -> "CollabTopology":
        """A copy with some ES platforms replaced (same names/links).

        The compute-side mirror of :meth:`with_links`: the measured-compute
        rebuild used by the online re-planner when per-ES effective FLOP/s
        drift away from the calibrated nominals (a straggling secondary).
        ESs not in ``platforms`` keep their current platform; naming an ES
        the topology does not have raises (a typo would otherwise silently
        leave the straggler unmodelled)."""
        merged = dict(self.platforms)
        for es, plat in platforms.items():
            if es not in merged:
                raise ValueError(f"{es!r} is not an ES of this topology")
            merged[es] = plat
        return dataclasses.replace(self, platforms=merged)

    @staticmethod
    def symmetric(
        platform: Platform,
        link: Link,
        n_secondaries: int = 2,
        host_platform: Platform | None = None,
        host: str = "e0",
    ) -> "CollabTopology":
        """The paper's setting: identical secondaries, one shared link rate.

        For ``n_secondaries=2`` the ES names are the paper's ``(e1, e0, e2)``;
        larger clusters get ``e1..eN`` around the same host."""
        names = tuple(f"e{j}" for j in range(1, n_secondaries + 1))
        platforms = {host: host_platform or platform}
        platforms.update({s: platform for s in names})
        return CollabTopology(
            host=host, secondaries=names, platforms=platforms, default_link=link
        )
