"""N-way heterogeneous HALP: topology plumbing, seed regression pins,
losslessness of optimizer-shaped plans, closed form vs. simulator on
asymmetric clusters, and split_rows edge cases."""
import math

import pytest

from repro.core import (
    GTX_1080TI,
    AGX_XAVIER,
    CollabTopology,
    Link,
    Platform,
    equal_ratios,
    evaluate_plan,
    halp_closed_form,
    optimize_plan,
    plan_halp,
    plan_halp_n,
    plan_halp_topology,
    simulate_halp,
    split_rows,
    vgg16_geom,
)
from repro.core.partition import Segment

NET = vgg16_geom()

# ---------------------------------------------------------------------------
# regression pins: the generalised engines must reproduce the seed (3-ES,
# equal-split) implementation EXACTLY -- segments, closed form, and simulator.
# Values captured from the pre-refactor implementation at commit 6c503ba.
# ---------------------------------------------------------------------------

SEED_SEGMENTS = [
    ((1, 110), (111, 114), (115, 224)),
    ((1, 110), (111, 114), (115, 224)),
    ((1, 55), (56, 57), (58, 112)),
    ((1, 54), (55, 58), (59, 112)),
    ((1, 54), (55, 58), (59, 112)),
    ((1, 27), (28, 29), (30, 56)),
    ((1, 26), (27, 30), (31, 56)),
    ((1, 26), (27, 30), (31, 56)),
    ((1, 26), (27, 30), (31, 56)),
    ((1, 13), (14, 15), (16, 28)),
    ((1, 12), (13, 16), (17, 28)),
    ((1, 12), (13, 16), (17, 28)),
    ((1, 12), (13, 16), (17, 28)),
    ((1, 6), (7, 8), (9, 14)),
    ((1, 5), (6, 9), (10, 14)),
    ((1, 5), (6, 9), (10, 14)),
    ((1, 4), (5, 8), (9, 14)),
    ((1, 2), (3, 4), (5, 7)),
]

SEED_TOTALS = {
    ("GTX 1080TI", 40e9): (0.0022701472675424237, 0.002231829963287529, 0.002853283601028227),
    ("GTX 1080TI", 100e9): (0.0021964960675424235, 0.0021785509596849535, 0.002810598161028227),
    ("JETSON AGX Xavier", 40e9): (0.014861223294045456, 0.01481812758713077, 0.01916614034803174),
    ("JETSON AGX Xavier", 100e9): (0.014787572094045456, 0.01477200150713077, 0.01912345490803174),
}


def test_symmetric_plan_matches_seed_segments_exactly():
    plan = plan_halp(NET, overlap_rows=4)
    assert plan.es_names == ("e1", "e0", "e2")
    assert plan.host == "e0"
    assert plan.secondary_slots == ("e1", "e2")
    assert plan.zone_slots == ("e0",)
    for i, part in enumerate(plan.parts):
        got = tuple((part.out[e].lo, part.out[e].hi) for e in ("e1", "e0", "e2"))
        assert got == SEED_SEGMENTS[i], (i, got)


def test_symmetric_engines_match_seed_totals_exactly():
    """Closed-form total and simulator makespans (1 and 4 tasks) are
    bit-identical to the pre-refactor implementation."""
    for plat in (GTX_1080TI, AGX_XAVIER):
        for rate in (40e9, 100e9):
            cf = halp_closed_form(NET, plat, Link(rate))["total"]
            ev = simulate_halp(NET, plat, Link(rate))["total"]
            ev4 = simulate_halp(NET, plat, Link(rate), n_tasks=4)["total"]
            want = SEED_TOTALS[(plat.name, rate)]
            assert (cf, ev, ev4) == want, (plat.name, rate)


# ---------------------------------------------------------------------------
# topology plumbing
# ---------------------------------------------------------------------------


def test_topology_validation():
    with pytest.raises(ValueError):
        CollabTopology(host="h", secondaries=(), platforms={"h": GTX_1080TI})
    with pytest.raises(ValueError):
        CollabTopology(host="h", secondaries=("h",), platforms={"h": GTX_1080TI})
    with pytest.raises(ValueError):
        CollabTopology(host="h", secondaries=("a",), platforms={"h": GTX_1080TI})
    topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9))
    assert topo.secondaries == ("e1", "e2")
    with pytest.raises(KeyError):
        CollabTopology(
            host="h", secondaries=("a", "b"),
            platforms={"h": GTX_1080TI, "a": GTX_1080TI, "b": GTX_1080TI},
        ).link_between("h", "a")


def test_capacity_ratios_proportional_to_eff_flops():
    slow = GTX_1080TI.scaled(0.25, "slow")
    topo = CollabTopology(
        host="e0",
        secondaries=("fast", "slow"),
        platforms={"e0": GTX_1080TI, "fast": GTX_1080TI, "slow": slow},
        default_link=Link(40e9),
    )
    r = topo.capacity_ratios()
    assert r[0] == pytest.approx(0.8) and r[1] == pytest.approx(0.2)
    plan = plan_halp_topology(NET, topo)
    # the fast secondary owns ~4x the rows of the slow one at the input layer
    fast_rows = plan.parts[0].out["fast"].rows
    slow_rows = plan.parts[0].out["slow"].rows
    assert 3.0 < fast_rows / slow_rows < 5.0


def test_directed_links_differ():
    topo = CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={"e0": GTX_1080TI, "a": GTX_1080TI, "b": GTX_1080TI},
        links={("e0", "a"): Link(10e9)},
        default_link=Link(40e9),
    )
    assert topo.link_between("e0", "a").rate_bps == 10e9
    assert topo.link_between("a", "e0").rate_bps == 40e9


# ---------------------------------------------------------------------------
# N-way plan structure
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [3, 4, 5])
def test_nway_plan_tiles_and_isolates(n):
    secs = tuple(f"e{j}" for j in range(1, n + 1))
    plan = plan_halp_n(NET, secondaries=secs, overlap_rows=4)
    sizes = NET.sizes()
    assert len(plan.es_names) == 2 * n - 1
    assert plan.secondary_slots == secs
    for i, part in enumerate(plan.parts):
        o = sizes[i + 1]
        segs = [part.out[s] for s in plan.es_names]
        assert segs[0].lo == 1 and segs[-1].hi == o
        for a, b in zip(segs, segs[1:]):
            assert b.lo == a.hi + 1
        assert sum(s.rows for s in segs) == o
    # no secondary-secondary exchange, ever
    for i in range(len(plan.parts) - 1):
        for a in secs:
            for b in secs:
                if a != b:
                    assert not plan.message(i, a, b), (i, a, b)


def test_nway_pool_boundaries_inherited():
    plan = plan_halp_n(NET, secondaries=("e1", "e2", "e3"), overlap_rows=4)
    for i, g in enumerate(NET.layers):
        if g.kind != "pool":
            continue
        prev = plan.parts[i - 1].out
        cur = plan.parts[i].out
        for slot in plan.es_names[:-1]:
            assert cur[slot].hi == prev[slot].hi // g.s


def test_thin_layers_idle_low_ratio_secondaries():
    """Graceful degradation: on layers too thin to feed every secondary, the
    small slots own zero rows (idle) while the plan keeps tiling and
    isolating -- it does not raise and does not break losslessness."""
    plan = plan_halp_n(NET, secondaries=("a", "b", "c", "d", "e"))
    rows16 = {s: plan.parts[16].out[s].rows for s in plan.secondary_slots}
    assert sum(rows16.values()) > 0
    assert min(rows16.values()) == 0  # somebody idles at the 14-row layer
    # full tiling still holds at that layer
    o = NET.sizes()[17]
    assert sum(plan.parts[16].out[s].rows for s in plan.es_names) == o


def test_optimizer_all_infeasible_raises_clearly():
    """With auto-reduction disabled, an oversized cluster has no strictly
    isolating plan and the optimizer must say so (the old error path).  With
    the default graceful degradation the same search succeeds."""
    topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9), n_secondaries=16)
    with pytest.raises(ValueError, match="no feasible HALP plan"):
        optimize_plan(NET, topo, overlap_choices=(4,), max_rounds=1, auto_reduce=False)
    res = optimize_plan(NET, topo, overlap_choices=(4,), max_rounds=1)
    assert math.isfinite(res.makespan)


def test_too_many_secondaries_strict_mode_raises():
    """Without auto-reduction, 16 secondaries + 15 zones cannot fit VGG-16's
    14-row deep layers, and 6-way breaks isolation at the same depth -- both
    must fail loudly, with the remediation in the message.  (The default
    auto-reduce behaviour for the same clusters is pinned in
    tests/test_partition.py::test_feasibility_boundary_pinned_vgg16.)"""
    with pytest.raises((AssertionError, ValueError)):
        plan_halp_n(
            NET, secondaries=tuple(f"e{j}" for j in range(1, 17)), auto_reduce=False
        )
    with pytest.raises(ValueError, match="widen the overlap zone"):
        plan_halp_n(
            NET, secondaries=tuple(f"e{j}" for j in range(1, 7)), auto_reduce=False
        )


# ---------------------------------------------------------------------------
# closed form vs. simulator: the systematic cross-validation now lives in
# tests/test_conformance.py (parametrized grid with pinned slacks); only the
# straggler-resource plumbing check stays here.
# ---------------------------------------------------------------------------


def test_straggler_slot_resources_nway():
    topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9), n_secondaries=3)
    base = simulate_halp(NET, topology=topo)["total"]
    slow = simulate_halp(NET, topology=topo, slowdown={"e2^0": 2.0})["total"]
    assert slow > base


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_optimizer_beats_equal_split_on_heterogeneous_cluster():
    """One fast + one slow secondary at unequal link rates: the optimizer's
    capacity-aware plan must beat the paper's naive equal split clearly."""
    slow = GTX_1080TI.scaled(0.35, "slow")
    topo = CollabTopology(
        host="e0",
        secondaries=("fast", "slow"),
        platforms={"e0": GTX_1080TI, "fast": GTX_1080TI, "slow": slow},
        links={
            ("e0", "fast"): Link(40e9), ("fast", "e0"): Link(40e9),
            ("e0", "slow"): Link(10e9), ("slow", "e0"): Link(10e9),
        },
    )
    naive = evaluate_plan(NET, topo, equal_ratios(topo), 4)
    res = optimize_plan(NET, topo)
    assert math.isfinite(res.makespan)
    assert res.makespan < 0.75 * naive, (res.makespan, naive)
    # the chosen split favours the fast secondary
    assert res.ratios[0] > 0.6
    # and the optimizer never returns something worse than its own start
    start = evaluate_plan(NET, topo, topo.capacity_ratios(), res.overlap_rows)
    assert res.makespan <= start + 1e-12


def test_optimizer_on_symmetric_cluster_stays_near_equal():
    topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9))
    res = optimize_plan(NET, topo, overlap_choices=(4,), max_rounds=4)
    assert abs(res.ratios[0] - 0.5) < 0.15
    seed_total = simulate_halp(NET, GTX_1080TI, Link(40e9))["total"]
    assert res.makespan <= seed_total * 1.001


def test_evaluate_plan_infeasible_is_inf():
    topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9), n_secondaries=16)
    assert evaluate_plan(
        NET, topo, equal_ratios(topo), 4, auto_reduce=False
    ) == float("inf")
    # graceful degradation makes the same cluster priceable
    assert math.isfinite(evaluate_plan(NET, topo, equal_ratios(topo), 4))


# ---------------------------------------------------------------------------
# split_rows edge cases
# ---------------------------------------------------------------------------


def test_split_rows_skewed_ratios():
    segs = split_rows(100, [0.9, 0.05, 0.05])
    assert sum(s.rows for s in segs) == 100
    assert segs[0].rows == 90
    for a, b in zip(segs, segs[1:]):
        assert b.lo == a.hi + 1


def test_split_rows_total_smaller_than_n():
    segs = split_rows(2, [0.25, 0.25, 0.25, 0.25])
    assert sum(s.rows for s in segs) == 2
    assert segs[0].lo == 1 and segs[-1].hi == 2
    # boundaries stay monotone; some segments are empty
    assert sum(1 for s in segs if not s) == 2


def test_split_rows_extreme_skew_keeps_cover():
    segs = split_rows(10, [0.998, 0.001, 0.001])
    assert sum(s.rows for s in segs) == 10
    assert segs[0].lo == 1 and segs[-1].hi == 10
    for a, b in zip(segs, segs[1:]):
        assert b.lo == a.hi + 1


def test_split_rows_zero_total():
    segs = split_rows(0, [0.5, 0.5])
    assert all(not s for s in segs)


def test_split_rows_rejects_bad_input():
    with pytest.raises(ValueError):
        split_rows(10, [0.5, 0.4])
    with pytest.raises(ValueError):
        split_rows(-1, [0.5, 0.5])


def test_segment_basics():
    assert Segment(3, 2).rows == 0
    assert not Segment(3, 2)
    assert Segment(1, 5).intersect(Segment(4, 9)) == Segment(4, 5)


# ---------------------------------------------------------------------------
# batched-engine controls: eval_budget, tol, engine equality
# ---------------------------------------------------------------------------


def test_optimizer_engines_return_identical_plans():
    """Batched and scalar pricing share one search loop and bit-identical
    scores, so the returned plan must be *equal*, not merely close."""
    topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9), n_secondaries=3)
    batched = optimize_plan(NET, topo)
    scalar = optimize_plan(NET, topo, engine="scalar")
    assert batched.ratios == scalar.ratios
    assert batched.overlap_rows == scalar.overlap_rows
    assert batched.makespan == scalar.makespan


def test_optimizer_engines_identical_under_eval_budget():
    """Under an eval_budget the batched engine must not speculate (it would
    spend the budget on candidates the scalar engine never prices), so both
    engines cut the budget at the same candidate and return the same plan --
    the property the replan cache relies on to share entries across engines."""
    skewed = CollabTopology(
        host="e0",
        secondaries=("a", "b", "c"),
        platforms={
            "e0": GTX_1080TI,
            "a": GTX_1080TI,
            "b": GTX_1080TI.scaled(0.5, "b"),
            "c": GTX_1080TI.scaled(0.25, "c"),
        },
        links={
            ("e0", "a"): Link(40e9), ("a", "e0"): Link(40e9),
            ("e0", "b"): Link(8e9), ("b", "e0"): Link(8e9),
            ("e0", "c"): Link(20e9), ("c", "e0"): Link(20e9),
        },
        default_link=Link(40e9),
    )
    for budget in (8, 30):
        batched = optimize_plan(NET, skewed, eval_budget=budget)
        scalar = optimize_plan(NET, skewed, eval_budget=budget, engine="scalar")
        assert batched.ratios == scalar.ratios, budget
        assert batched.overlap_rows == scalar.overlap_rows, budget
        assert batched.makespan == scalar.makespan, budget
        assert batched.evaluations == scalar.evaluations <= budget


def test_optimizer_eval_budget_caps_priced_candidates():
    """eval_budget is the hard bound a controller puts on worst-case replan
    latency: the search must stop pricing at the cap and still return the
    best feasible plan found within it."""
    topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9))
    full = optimize_plan(NET, topo)
    capped = optimize_plan(NET, topo, eval_budget=6)
    assert capped.evaluations <= 6
    assert math.isfinite(capped.makespan)
    assert capped.makespan >= full.makespan  # less search can't do better
    with pytest.raises(ValueError, match="eval_budget"):
        optimize_plan(NET, topo, eval_budget=0)


def test_optimizer_tol_early_exit_trades_quality_for_latency():
    """A large tol stops after the first descent round; the result is valid
    and never better than the unbounded search, with fewer evaluations."""
    slow = GTX_1080TI.scaled(0.4, "slow")
    topo = CollabTopology(
        host="e0",
        secondaries=("fast", "slow"),
        platforms={"e0": GTX_1080TI, "fast": GTX_1080TI, "slow": slow},
        default_link=Link(10e9),
    )
    full = optimize_plan(NET, topo)
    quick = optimize_plan(NET, topo, tol=float("inf"))
    assert quick.evaluations < full.evaluations
    assert math.isfinite(quick.makespan)
    assert quick.makespan >= full.makespan
    # tol=0 (default) must not early-exit: identical to the full search
    default = optimize_plan(NET, topo, tol=0.0)
    assert default.makespan == full.makespan


def test_optimizer_rejects_unknown_engine():
    topo = CollabTopology.symmetric(GTX_1080TI, Link(40e9))
    with pytest.raises(ValueError, match="engine"):
        optimize_plan(NET, topo, engine="magic")
