"""Serving sweep: tail latency, deadline attainment, and shedding under
production traffic models.

The paper's §V.D evaluates service reliability for one batch size under a
time-variant channel; nothing in it models *load*.  The authors' prototype
(arXiv 2211.13778) serves real request streams and DistrEdge (arXiv
2202.01699) reports tail latency rather than means because production
arrivals are bursty.  This sweep drives the event-driven serving loop
(``repro.runtime.serve.serve_trace``) end-to-end through the batched DES:

* the service-time model is ``repro.core.simulator.serve_latency_table`` --
  the full HALP DAG priced per batch width through ``Sim.run_batch`` -- for a
  Xavier-class host + two-secondary cluster on 2.5 Gbps links, cross-checked
  against the online controller's ``ReplanController.latency_table`` (the
  plan-aware admission path);
* three seeded arrival processes (``repro.runtime.traffic``): steady Poisson,
  a diurnal sinusoid day, and a flash crowd whose burst offered load is ~3x
  the cluster's saturated-batch capacity;
* three deadline classes (premium 150 ms @ 0.999, standard 400 ms @ 0.99,
  bulk 2 s @ 0.9) admitted per §V.D: a request that cannot clear its class
  target even alone in a batch is shed, and every admitted batch is the
  largest EDF prefix whose members all clear their targets.

Each process runs with admission on and off (the accept-everything baseline);
the full run simulates a >=10^6-request day per policy in well under a
minute of wall clock -- no ``time.sleep`` anywhere, the clock is virtual.

Emits ``BENCH_serve.json`` (``--out`` to move it, ``--smoke`` for the CI
artifact run; only the full run satisfies the >=10^6 floor).  Acceptance:
``tests/test_benchmarks.py::test_serve_sweep_acceptance`` pins the
flash-crowd property (shedding keeps the premium class's deadline-met
fraction at or above the no-shedding baseline) and the committed artifact's
request-count floor.  CSV rows (``name,us_per_call,derived``) match the
other benchmarks' format.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    AGX_XAVIER,
    CollabTopology,
    Link,
    OffloadChannel,
    ReplanConfig,
    ReplanController,
    serve_latency_table,
    vgg16_geom,
)
from repro.runtime import (  # noqa: E402
    DeadlineClass,
    DiurnalProcess,
    FlashCrowdProcess,
    PoissonProcess,
    ServeLoopConfig,
    make_trace,
    serve_trace,
)

NET = vgg16_geom()
NOMINAL_BPS = 2.5e9
MAX_BATCH = 8
# 100 Mbps IoT->host uplink at Table III scale: mu = 40 ms for the 4-image
# batch, sigma at the mild fluctuation level
CHANNEL = OffloadChannel(rate_bps=100e6, sigma_s=2e-3)
CLASSES = (
    DeadlineClass("premium", 0.15, target=0.999, share=0.2),
    DeadlineClass("standard", 0.4, target=0.99, share=0.5),
    DeadlineClass("bulk", 2.0, target=0.9, share=0.3),
)
DAY_S = 86_400.0


def build_topology() -> CollabTopology:
    return CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={"e0": AGX_XAVIER, "a": AGX_XAVIER, "b": AGX_XAVIER},
        default_link=Link(NOMINAL_BPS),
    )


def build_processes(smoke: bool) -> dict[str, tuple[object, float]]:
    """name -> (arrival process, horizon).  Full mode totals >= 10^6 requests
    across the three; smoke shrinks the horizons, not the structure."""
    horizon = 3_600.0 if smoke else DAY_S
    # burst offered load ~3x the saturated-batch capacity (~112 req/s at
    # MAX_BATCH=8 under this channel+table), so admission has real work
    bursts = (
        ((0.25 * horizon, 0.04 * horizon, 300.0), (0.75 * horizon, 0.02 * horizon, 150.0))
        if not smoke
        else ((0.25 * horizon, 0.05 * horizon, 300.0),)
    )
    return {
        "poisson": (PoissonProcess(rate_hz=5.8, seed=101), horizon),
        "diurnal": (
            DiurnalProcess(base_rate_hz=4.0, amplitude=0.8, period_s=horizon, seed=202),
            horizon,
        ),
        "flash_crowd": (
            FlashCrowdProcess(base_rate_hz=3.0, bursts=bursts, seed=303),
            horizon,
        ),
    }


def _record(served) -> dict:
    return {"overall": served.stats(), "classes": served.class_stats()}


def run_sweep(smoke: bool = False) -> dict:
    topo = build_topology()
    lat_des = serve_latency_table(NET, topology=topo, max_batch=MAX_BATCH)[0]
    # the plan-aware path: the online controller prices the same curve off its
    # active (cached) plan -- what `plan_aware_batch_size` admits against
    ctl = ReplanController(NET, topo, ReplanConfig(n_tasks=4))
    lat_ctl = ctl.latency_table(MAX_BATCH)
    out: dict = {
        "max_batch": MAX_BATCH,
        "channel": {"rate_bps": CHANNEL.rate_bps, "sigma_s": CHANNEL.sigma_s,
                    "mu_s": CHANNEL.mu_s},
        "classes": [
            {"name": c.name, "deadline_s": c.deadline_s, "target": c.target,
             "share": c.share}
            for c in CLASSES
        ],
        "lat_table_des": [float(v) for v in lat_des],
        "lat_table_controller": [float(v) for v in lat_ctl],
        "processes": {},
    }
    n_total = 0
    for name, (proc, horizon) in build_processes(smoke).items():
        trace = make_trace(proc, CLASSES, horizon, seed=17)
        rec: dict = {"n": len(trace), "horizon_s": horizon,
                     "process": type(proc).__name__}
        for policy, admission in (("shed", True), ("noshed", False)):
            t0 = time.perf_counter()
            served = serve_trace(
                trace,
                lat_des,
                ServeLoopConfig(
                    max_batch=MAX_BATCH, max_delay_s=0.002, admission=admission,
                    channel=CHANNEL, seed=23,
                ),
            )
            rec[policy] = _record(served)
            rec[policy]["serve_wall_s"] = time.perf_counter() - t0
        out["processes"][name] = rec
        n_total += len(trace)
    out["n_total"] = n_total
    fc = out["processes"]["flash_crowd"]
    out["flash_premium_met_shed"] = fc["shed"]["classes"]["premium"]["deadline_met_frac"]
    out["flash_premium_met_noshed"] = (
        fc["noshed"]["classes"]["premium"]["deadline_met_frac"]
    )
    return out


def run_all(smoke: bool = False, out_path: str | None = "BENCH_serve.json") -> dict:
    out = run_sweep(smoke=smoke)
    print(
        f"\n== Serving sweep: {out['n_total']} requests across 3 arrival "
        f"processes, max_batch={MAX_BATCH}, offload mu="
        f"{out['channel']['mu_s']*1e3:.0f} ms =="
    )
    print(
        f"{'process':12s} {'policy':7s} {'n':>8s} {'p99 (ms)':>9s} {'p999 (ms)':>9s} "
        f"{'met':>7s} {'shed':>7s} {'premium met':>11s}"
    )
    for name, rec in out["processes"].items():
        for policy in ("shed", "noshed"):
            o = rec[policy]["overall"]
            prem = rec[policy]["classes"]["premium"]["deadline_met_frac"]
            print(
                f"{name:12s} {policy:7s} {rec['n']:8d} {o['p99_latency_s']*1e3:9.1f} "
                f"{o['p999_latency_s']*1e3:9.1f} {o['deadline_met_frac']:7.4f} "
                f"{o['shed_rate']:7.4f} {prem:11.4f}"
            )
            print(
                f"serve_{name}_{policy},{o['p99_latency_s']*1e6:.1f},"
                f"{o['deadline_met_frac']:.6f}"
            )
    print(
        f"\nflash-crowd premium deadline-met: shed "
        f"{out['flash_premium_met_shed']:.4f} vs no-shed "
        f"{out['flash_premium_met_noshed']:.4f}"
    )
    print(
        f"serve_flash_premium_gain,,"
        f"{out['flash_premium_met_shed'] - out['flash_premium_met_noshed']:.4f}"
    )
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True, default=str)
        print(f"\nwrote {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    run_all(smoke=args.smoke, out_path=args.out)
