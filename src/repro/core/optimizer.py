"""Latency-minimising search over HALP plan knobs (segment ratios, overlap).

The paper fixes the partition a priori (equal halves, a 4-row zone); on a
heterogeneous cluster that leaves latency on the table -- a fast secondary
should own more rows (DistrEdge, arXiv 2202.01699) and the optimal overlap
width trades host work against host->secondary boundary traffic.  This module
searches those knobs directly against the discrete-event simulator (the ground
truth the paper's recursion approximates):

* decision variables: the N secondary segment ratios (a simplex point) and the
  overlap-zone width in output rows,
* objective: the simulated makespan of ``n_tasks`` concurrent tasks on the
  given :class:`~repro.core.topology.CollabTopology`,
* method: cyclic coordinate descent on the ratio simplex (move mass onto one
  secondary at a time, renormalise) interleaved with an exhaustive scan of the
  overlap choices, with step-size halving -- the objective is piecewise
  constant in the ratios (segments are integer rows), so gradient-free moves
  with a shrinking step are the right tool.

Infeasible candidates (a plan whose messages would skip a slot, or more slots
than rows) are rejected by the partitioner's invariant checks and priced +inf.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

from .nets import ConvNetGeom
from .partition import HALPPlan, plan_halp_topology
from .simulator import simulate_halp
from .topology import CollabTopology

__all__ = ["OptimizeResult", "optimize_plan", "evaluate_plan", "equal_ratios"]


@dataclass
class OptimizeResult:
    ratios: tuple[float, ...]
    overlap_rows: int
    makespan: float
    plan: HALPPlan
    evaluations: int
    history: list[tuple[tuple[float, ...], int, float]] = field(default_factory=list)


def equal_ratios(topology: CollabTopology) -> tuple[float, ...]:
    """The naive capacity-blind split (the paper's default)."""
    n = topology.n_secondaries
    return tuple(1.0 / n for _ in range(n))


def evaluate_plan(
    net: ConvNetGeom,
    topology: CollabTopology,
    ratios: Sequence[float],
    overlap_rows: int,
    n_tasks: int = 1,
    auto_reduce: bool = True,
) -> float:
    """Simulated makespan of one candidate; +inf if the plan is infeasible.

    ``auto_reduce=False`` restricts the search to strictly-isolating plans
    (no per-layer secondary reduction); thin layers then price +inf."""
    try:
        plan = plan_halp_topology(
            net, topology, overlap_rows=overlap_rows, ratios=ratios,
            auto_reduce=auto_reduce,
        )
        return simulate_halp(net, topology=topology, n_tasks=n_tasks, plan=plan)["total"]
    except (AssertionError, ValueError):
        return float("inf")


def optimize_plan(
    net: ConvNetGeom,
    topology: CollabTopology,
    n_tasks: int = 1,
    overlap_choices: Sequence[int] = (2, 4, 6, 8),
    init_ratios: Sequence[float] | None = None,
    step: float = 0.08,
    min_step: float = 0.005,
    min_ratio: float = 0.02,
    max_rounds: int = 12,
    objective: Callable[[tuple[float, ...], int], float] | None = None,
    auto_reduce: bool = True,
) -> OptimizeResult:
    """Coordinate-descent search for the fastest (ratios, overlap) pair.

    Starts from the topology's capacity-weighted ratios (or ``init_ratios``),
    then alternates (a) an exhaustive scan of ``overlap_choices`` and (b) one
    cyclic pass moving ratio mass onto/off each secondary, halving the step
    whenever a full round fails to improve.  Terminates when the step falls
    below ``min_step`` or after ``max_rounds``.

    ``objective`` may replace the default simulated-makespan objective (e.g.
    to optimise the closed form instead, or average delay for multi-task)."""
    evals = 0
    history: list[tuple[tuple[float, ...], int, float]] = []

    def default_objective(ratios: tuple[float, ...], w: int) -> float:
        return evaluate_plan(
            net, topology, ratios, w, n_tasks=n_tasks, auto_reduce=auto_reduce
        )

    fn = objective or default_objective

    def priced(ratios: tuple[float, ...], w: int) -> float:
        nonlocal evals
        evals += 1
        v = fn(ratios, w)
        history.append((ratios, w, v))
        return v

    def renorm(raw: Sequence[float]) -> tuple[float, ...]:
        clipped = [max(min_ratio, r) for r in raw]
        total = sum(clipped)
        return tuple(r / total for r in clipped)

    ratios = renorm(init_ratios or topology.capacity_ratios())
    n = len(ratios)
    best_w = overlap_choices[0]
    best = float("inf")
    for w in overlap_choices:
        v = priced(ratios, w)
        if v < best:
            best, best_w = v, w

    rounds = 0
    while step >= min_step and rounds < max_rounds:
        rounds += 1
        improved = False
        for j in range(n):
            for sign in (1.0, -1.0):
                raw = list(ratios)
                raw[j] = max(min_ratio, raw[j] + sign * step)
                cand = renorm(raw)
                if cand == ratios:
                    continue
                v = priced(cand, best_w)
                if v < best:
                    best, ratios, improved = v, cand, True
        for w in overlap_choices:
            if w == best_w:
                continue
            v = priced(ratios, w)
            if v < best:
                best, best_w, improved = v, w, True
        if not improved:
            step *= 0.5
    if not math.isfinite(best):
        raise ValueError(
            f"no feasible HALP plan for {topology.n_secondaries} secondaries on "
            f"{net.name} over overlap choices {tuple(overlap_choices)}; use fewer "
            f"secondaries or a larger input"
        )
    plan = plan_halp_topology(
        net, topology, overlap_rows=best_w, ratios=ratios, auto_reduce=auto_reduce
    )
    return OptimizeResult(
        ratios=ratios,
        overlap_rows=best_w,
        makespan=best,
        plan=plan,
        evaluations=evals,
        history=history,
    )
