"""AST lint: every ``ReplanConfig`` knob must key the plan store -- or say why not.

The silent-stale-plan bug class: a new optimiser-facing config field lands,
nobody folds it into ``ReplanController._fingerprint``, and two controllers
with different settings silently share (wrong) cache/store entries.  PRs 8-9
defended against this by hand (docstring comments per knob); this pass makes
the partition machine-checked:

* every field of the ``ReplanConfig`` dataclass is either read inside the
  ``self._fingerprint = (...)`` tuple (``config.<field>``) or named in the
  module-level ``FINGERPRINT_EXCLUDED`` dict with a non-trivial justification
  string (``keying.unkeyed`` otherwise);
* a field may not be both fingerprinted and excluded
  (``keying.contradiction``), and exclusions for fields that no longer exist
  are flagged (``keying.stale-exclusion``) -- dead justifications rot;
* the fingerprint may not read fields the dataclass does not define
  (``keying.unknown-field``);
* ``PlanStore.get`` must keep its two row-level vetoes: the canonical-key
  text comparison (hash-collision veto) and the ``schema_version`` check
  (``keying.store-veto`` if either disappears).

The lint operates on *source text* (defaults to the installed
``repro.core.replan`` / ``repro.core.planstore`` files) so mutation tests can
feed corrupted sources without touching the real modules.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Report

__all__ = ["check_keying"]

MIN_JUSTIFICATION = 10  # characters; "perf" is not a justification


def _module_source(modname: str) -> str:
    import importlib

    mod = importlib.import_module(modname)
    return Path(mod.__file__).read_text()


def _config_fields(tree: ast.Module, cls: str) -> list[str] | None:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls:
            return [
                stmt.target.id
                for stmt in node.body
                if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name)
            ]
    return None


def _excluded(tree: ast.Module) -> dict[str, object] | None:
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
            value = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
            value = node.value
        else:
            continue
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "FINGERPRINT_EXCLUDED":
                if not isinstance(value, ast.Dict):
                    return None
                out: dict[str, object] = {}
                for k, v in zip(value.keys, value.values):
                    key = k.value if isinstance(k, ast.Constant) else None
                    val = v.value if isinstance(v, ast.Constant) else None
                    out[str(key)] = val
                return out
    return None


def _fingerprint_reads(tree: ast.Module) -> set[str] | None:
    """Field names read as ``config.<x>`` / ``self.config.<x>`` inside any
    ``self._fingerprint = ...`` assignment."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        if not any(
            isinstance(t, ast.Attribute)
            and t.attr == "_fingerprint"
            and isinstance(t.value, ast.Name)
            and t.value.id == "self"
            for t in node.targets
        ):
            continue
        reads: set[str] = set()
        for sub in ast.walk(node.value):
            if not isinstance(sub, ast.Attribute):
                continue
            base = sub.value
            if isinstance(base, ast.Name) and base.id == "config":
                reads.add(sub.attr)
            elif (
                isinstance(base, ast.Attribute)
                and base.attr == "config"
                and isinstance(base.value, ast.Name)
                and base.value.id == "self"
            ):
                reads.add(sub.attr)
        return reads
    return None


def check_keying(
    replan_source: str | None = None, planstore_source: str | None = None
) -> Report:
    """Lint the config-keying contract; returns a Report (never raises)."""
    rep = Report()
    if replan_source is None:
        replan_source = _module_source("repro.core.replan")
    if planstore_source is None:
        planstore_source = _module_source("repro.core.planstore")

    try:
        tree = ast.parse(replan_source)
    except SyntaxError as exc:
        rep.add("keying.parse", "replan.py", f"unparseable source: {exc}")
        return rep

    rep.tick()
    fields = _config_fields(tree, "ReplanConfig")
    if fields is None:
        rep.add("keying.parse", "ReplanConfig", "dataclass not found in replan source")
        return rep

    rep.tick()
    excluded = _excluded(tree)
    if excluded is None:
        rep.add(
            "keying.exclusion-list",
            "FINGERPRINT_EXCLUDED",
            "module-level dict literal not found: non-keyed config fields "
            "need an explicit, justified exclusion list",
        )
        excluded = {}

    rep.tick()
    keyed = _fingerprint_reads(tree)
    if keyed is None:
        rep.add(
            "keying.parse",
            "ReplanController._fingerprint",
            "no `self._fingerprint = ...` assignment found",
        )
        return rep

    for f in fields:
        rep.tick()
        if f in keyed and f in excluded:
            rep.add(
                "keying.contradiction",
                f"ReplanConfig.{f}",
                "both folded into the fingerprint and listed in "
                "FINGERPRINT_EXCLUDED -- one of the two is wrong",
            )
        elif f not in keyed and f not in excluded:
            rep.add(
                "keying.unkeyed",
                f"ReplanConfig.{f}",
                "neither folded into ReplanController._fingerprint nor named "
                "in FINGERPRINT_EXCLUDED: two controllers differing only in "
                "this knob would silently share stale plan-store entries",
            )
    for f in sorted(excluded):
        rep.tick()
        if f not in fields:
            rep.add(
                "keying.stale-exclusion",
                f"FINGERPRINT_EXCLUDED[{f!r}]",
                "excludes a field ReplanConfig no longer defines",
            )
            continue
        just = excluded[f]
        if not isinstance(just, str) or len(just.strip()) < MIN_JUSTIFICATION:
            rep.add(
                "keying.no-justification",
                f"FINGERPRINT_EXCLUDED[{f!r}]",
                f"exclusion needs a justification string (>= "
                f"{MIN_JUSTIFICATION} chars), got {just!r}",
            )
    for f in sorted(keyed - set(fields)):
        rep.tick()
        rep.add(
            "keying.unknown-field",
            f"ReplanController._fingerprint -> config.{f}",
            "fingerprint reads a field ReplanConfig does not define",
        )

    # --- PlanStore.get row vetoes
    try:
        stree = ast.parse(planstore_source)
    except SyntaxError as exc:
        rep.add("keying.parse", "planstore.py", f"unparseable source: {exc}")
        return rep
    get_fn = None
    for node in ast.walk(stree):
        if isinstance(node, ast.ClassDef) and node.name == "PlanStore":
            for stmt in node.body:
                if isinstance(stmt, ast.FunctionDef) and stmt.name == "get":
                    get_fn = stmt
    rep.tick()
    if get_fn is None:
        rep.add("keying.parse", "PlanStore.get", "method not found in planstore source")
        return rep
    names = {
        sub.id for sub in ast.walk(get_fn) if isinstance(sub, ast.Name)
    } | {sub.attr for sub in ast.walk(get_fn) if isinstance(sub, ast.Attribute)}
    rep.tick()
    if "canonical_key" not in names:
        rep.add(
            "keying.store-veto",
            "PlanStore.get",
            "canonical-key text comparison missing: a 64-bit hash collision "
            "would serve another operating point's plan",
        )
    rep.tick()
    if "schema_version" not in names:
        rep.add(
            "keying.store-veto",
            "PlanStore.get",
            "schema_version row check missing: rows written under an older "
            "plan schema would be served as current",
        )
    return rep
