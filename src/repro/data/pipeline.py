"""Deterministic synthetic data pipeline (shard-aware).

Every batch is a pure function of (seed, step), so a restarted / resharded job
replays the exact stream -- the property the fault-tolerant trainer relies on
(exactly-once semantics without a data-service dependency).  On a mesh, arrays
are built per-shard with ``jax.make_array_from_callback`` so no host ever
materialises the global batch (the multi-pod path); on a single device it
degrades to plain arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStream", "ImageStream", "DiffusionStream"]


def _rng(seed: int, step: int, salt: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed, counter=[step, salt, 0, 0]))


@dataclass
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        g = _rng(self.seed, step, 1)
        toks = g.integers(0, self.vocab, (self.batch, self.seq_len + 1), dtype=np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class ImageStream:
    img_res: int
    batch: int
    num_classes: int
    channels: int = 3
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        g = _rng(self.seed, step, 2)
        imgs = g.standard_normal(
            (self.batch, self.img_res, self.img_res, self.channels), dtype=np.float32
        )
        labels = g.integers(0, self.num_classes, (self.batch,), dtype=np.int32)
        return {"images": jnp.asarray(imgs), "labels": jnp.asarray(labels)}


@dataclass
class DiffusionStream:
    latent_res: int
    batch: int
    latent_ch: int = 4
    n_classes: int = 1000
    ctx: tuple | None = None  # (len, dim) for text-conditioned models
    n_steps: int = 1000
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        g = _rng(self.seed, step, 3)
        shape = (self.batch, self.latent_res, self.latent_res, self.latent_ch)
        out = {
            "latents": jnp.asarray(g.standard_normal(shape, dtype=np.float32)),
            "noise": jnp.asarray(g.standard_normal(shape, dtype=np.float32)),
            "t": jnp.asarray(g.integers(0, self.n_steps, (self.batch,), dtype=np.int32)),
        }
        if self.ctx is None:
            out["cond"] = jnp.asarray(
                g.integers(0, self.n_classes, (self.batch,), dtype=np.int32)
            )
        else:
            L, d = self.ctx
            out["cond"] = jnp.asarray(
                g.standard_normal((self.batch, L, d), dtype=np.float32)
            )
        return out


def device_batch(batch: dict, shardings: dict | None = None) -> dict:
    """Place a host batch on devices, honouring per-input shardings if given."""
    if not shardings:
        return jax.device_put(batch)
    return {
        k: jax.device_put(v, shardings.get(k)) if shardings.get(k) else jax.device_put(v)
        for k, v in batch.items()
    }
