"""Recompute the hlo_cost fields of every dry-run JSON from the archived
compressed HLO -- lets the cost model iterate without recompiling.

    PYTHONPATH=src python benchmarks/reanalyze.py
"""
import json
import sys
from pathlib import Path

import zstandard as zstd

sys.path.insert(0, "src")

from repro.launch.hlo_cost import analyze_hlo

RESULTS = Path(__file__).parent / "dryrun_results"


def main():
    dctx = zstd.ZstdDecompressor()
    n = 0
    for jf in sorted(RESULTS.glob("*.json")):
        if jf.name.startswith("_"):
            continue
        rec = json.loads(jf.read_text())
        if rec.get("status") != "ok":
            continue
        hf = RESULTS / "hlo" / (jf.stem + ".hlo.zst")
        if not hf.exists():
            print(f"no HLO archive for {jf.name}; skipping")
            continue
        text = dctx.decompress(hf.read_bytes(), max_output_size=2**31).decode()
        hc = analyze_hlo(text)
        rec["hlo_cost"] = {
            "flops": hc.flops,
            "bytes_accessed": hc.bytes_accessed,
            "collective_bytes": hc.collective_bytes,
            "per_collective": hc.per_collective,
            "collective_counts": hc.collective_counts,
            "unknown_trip_whiles": hc.unknown_trip_whiles,
        }
        jf.write_text(json.dumps(rec, indent=2))
        n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
