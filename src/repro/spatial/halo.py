"""SPMD spatial parallelism: receptive-field-exact halo exchange (TPU form of HALP).

Under ``shard_map`` the image height axis is sharded across a mesh axis.  Each
device computes a conv layer on its own rows after exchanging the thin halo the
receptive field requires (``halo_lo = p`` rows from the neighbour above,
``halo_hi = k - p - s`` rows from below, the exact analogue of the paper's
eqs. 8-9 for an even N-way split).

Two execution modes:

* ``overlap=False`` -- exchange, then one VALID conv over the extended slab.
* ``overlap=True``  -- the HALP schedule: the ``ppermute`` for the halos is
  issued first, the *interior* rows (which need no remote data) are convolved
  immediately, and the boundary rows are finished when the halos land.  On TPU
  the XLA latency-hiding scheduler overlaps the collective with the interior
  conv -- communication is hidden behind compute, exactly the paper's
  "seamless collaboration" (see DESIGN.md for the host-ES -> SPMD mapping).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["halo_sizes", "exchange_halos", "conv2d_spatial", "max_pool_spatial"]


def halo_sizes(k: int, s: int, p: int) -> tuple[int, int]:
    """Rows needed from the neighbour above / below for an aligned shard."""
    lo, hi = p, k - p - s
    if lo < 0 or lo >= k or hi >= k:
        raise ValueError(f"unsupported geometry k={k} s={s} p={p}")
    return lo, max(0, hi)


def _check_halo_fits(hs: int, lo: int, hi: int) -> None:
    """A neighbour can only donate rows it owns: a halo larger than the shard
    height would need rows from *two* shards away.  ``x[:, -lo:]`` silently
    truncates to the ``hs`` available rows in that case -- the receiving
    shard would convolve wrong (shifted) rows -- so fail loudly instead."""
    if lo > hs or hi > hs:
        raise ValueError(
            f"halo exceeds shard height: need lo={lo}/hi={hi} rows from the "
            f"neighbouring shards but each shard holds only {hs} rows; use "
            f"fewer/taller shards (or run this layer unsharded)"
        )


def exchange_halos(x: jax.Array, lo: int, hi: int, axis_name: str) -> jax.Array:
    """Return x extended with ``lo`` rows from above and ``hi`` rows from below.

    Edge shards receive zeros (the conv's zero padding).  x: [B, Hs, W, C].
    Raises ``ValueError`` when the shard is too thin to donate the requested
    halo (``lo > Hs`` or ``hi > Hs``) instead of silently truncating."""
    _check_halo_fits(x.shape[1], lo, hi)
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    parts = [x]
    if lo:
        down = [(i, (i + 1) % n) for i in range(n)]  # my bottom rows -> next shard
        top = lax.ppermute(x[:, -lo:], axis_name, down)
        top = jnp.where(idx == 0, jnp.zeros_like(top), top)
        parts.insert(0, top)
    if hi:
        up = [(i, (i - 1) % n) for i in range(n)]  # my top rows -> previous shard
        bot = lax.ppermute(x[:, :hi], axis_name, up)
        bot = jnp.where(idx == n - 1, jnp.zeros_like(bot), bot)
        parts.append(bot)
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else x


def _conv_valid(x, p, s, groups=1):
    y = lax.conv_general_dilated(
        x, p["w"], (s, s), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    if "b" in p:
        y = y + p["b"]
    return y


def conv2d_spatial(
    x: jax.Array,
    params,
    k: int,
    s: int = 1,
    p: int = 0,
    axis_name: str = "sp",
    overlap: bool = True,
    groups: int = 1,
) -> jax.Array:
    """Spatially-sharded conv (height axis sharded over ``axis_name``).

    Requires the shard height to be a multiple of ``s``.  Width uses ordinary
    SAME semantics via explicit padding.
    """
    b, hs, w, c = x.shape
    if hs % s:
        raise ValueError(f"shard rows {hs} not divisible by stride {s}")
    lo, hi = halo_sizes(k, s, p)
    if p:  # width padding (the height padding is the edge shards' zero halos)
        x = jnp.pad(x, ((0, 0), (0, 0), (p, p), (0, 0)))

    if not overlap or (lo == 0 and hi == 0):
        ext = exchange_halos(x, lo, hi, axis_name)
        y = _conv_valid(ext, params, s, groups)
        return y[:, : hs // s]

    # --- HALP schedule: issue halos first, compute interior, then boundaries.
    # (x is already width-padded, so the halos carry the width padding too.)
    _check_halo_fits(hs, lo, hi)
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    top_halo = bot_halo = None
    if lo:
        top_halo = lax.ppermute(
            x[:, -lo:], axis_name, [(i, (i + 1) % n) for i in range(n)]
        )
        top_halo = jnp.where(idx == 0, jnp.zeros_like(top_halo), top_halo)
    if hi:
        bot_halo = lax.ppermute(
            x[:, :hi], axis_name, [(i, (i - 1) % n) for i in range(n)]
        )
        bot_halo = jnp.where(idx == n - 1, jnp.zeros_like(bot_halo), bot_halo)

    # Within-shard output row t (0-indexed) reads extended rows
    # [t*s - lo, t*s - lo + k); interior rows touch no halo.
    nrows = hs // s
    t_lo = -(-lo // s)  # ceil(lo / s)
    t_hi = (hs + lo - k) // s
    if t_hi < t_lo:  # shard too thin for an interior: plain exchanged conv
        parts = [q for q in (top_halo, x, bot_halo) if q is not None]
        ext = jnp.concatenate(parts, axis=1) if len(parts) > 1 else x
        return _conv_valid(ext, params, s, groups)[:, :nrows]

    pieces = []
    if t_lo > 0:  # top boundary rows 0..t_lo-1 finish once the top halo lands
        slab = jnp.concatenate([top_halo, x[:, : (t_lo - 1) * s - lo + k]], axis=1)
        pieces.append(_conv_valid(slab, params, s, groups)[:, :t_lo])
    pieces.append(
        _conv_valid(x[:, t_lo * s - lo : t_hi * s - lo + k], params, s, groups)
    )
    if t_hi + 1 < nrows:  # bottom boundary rows
        slab = x[:, (t_hi + 1) * s - lo :]
        if bot_halo is not None:
            slab = jnp.concatenate([slab, bot_halo], axis=1)
        pieces.append(_conv_valid(slab, params, s, groups)[:, : nrows - t_hi - 1])
    return jnp.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]


def max_pool_spatial(x: jax.Array, k: int = 2, s: int = 2, axis_name: str = "sp") -> jax.Array:
    """Spatially-sharded max pool (aligned shards need no halo when k == s)."""
    b, hs, w, c = x.shape
    if hs % s:
        raise ValueError("shard not aligned to pool stride")
    lo, hi = halo_sizes(k, s, 0)
    x = exchange_halos(x, lo, hi, axis_name)
    y = lax.reduce_window(x, -jnp.inf, lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")
    return y[:, : hs // s]
