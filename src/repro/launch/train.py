"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch vit-l16 --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --cell train_4k \
        --steps 100 --ckpt-dir /tmp/ckpt

Smoke-scale configs run real optimizer steps on the local device(s); full
configs are launched the same way on a real TPU slice (the step bundle, the
sharding rules and the fault-tolerant driver are identical -- only the mesh
and ``--smoke`` flag change).  On a multi-host slice each process runs this
same entrypoint (jax.distributed initializes from the TPU environment).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    args = ap.parse_args()

    from repro.configs import get
    from repro.runtime.fault import FaultConfig
    from repro.runtime.train import make_trainer

    arch = get(args.arch)
    default_cell = {"lm": "train_4k", "vision": "cls_224", "diffusion": "train_256"}
    cell = args.cell or default_cell[arch.family]
    trainer, state = make_trainer(
        args.arch,
        cell,
        smoke=not args.full,
        fault_cfg=FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
    )
    state, stats = trainer.run(state, args.steps, resume=not args.no_resume)
    print(f"arch={args.arch} cell={cell} steps={stats.steps} failures={stats.failures}")
    if stats.losses:
        print(f"loss[0]={stats.losses[0]:.4f} loss[-1]={stats.losses[-1]:.4f}")
        print(f"ema_step_s={stats.ema_step_s*1e3:.1f}ms stragglers={stats.stragglers}")


if __name__ == "__main__":
    main()
