"""Static verification of built job/message DAGs.

Works on any ``repro.core.simulator.Sim`` (list of ``Job``\\ s with explicit
dependencies plus implicit per-resource FIFO order) -- the objects laid by
``events.build_halp_dag`` / ``events.build_scheme_dag`` and embedded in
``events.DagTemplate``.  Checks:

* **Event-order consistency** (``dag.event-order``): every explicit
  dependency points backwards in submission order.  ``Sim.run`` rejects a
  forward dependency at run time; here it is caught without running.
* **Deadlock freedom** (``dag.deadlock``): the precedence digraph -- explicit
  dependency edges *plus* the per-resource FIFO edges ``Sim._merged_deps``
  folds in -- must be acyclic.  A cycle means the list schedule (and the
  vectorized ``Sim.run_batch`` longest-path sweep) could never complete: a
  static race/deadlock detector.
* **Transfer endpoints** (``dag.transfer``): a job on ``link:src->dst`` may
  only depend on work at ``src`` (compute on ``src`` or a transfer arriving
  at ``src``), and may only be consumed by work at ``dst`` (compute on
  ``dst`` or a transfer departing ``dst``) -- data cannot teleport.
* **Orphan transfers** (``dag.orphan``): a positive-duration transfer with no
  consumer means rows are shipped and never used.  One documented exception
  is exempt: the seed convention prices each secondary's *last-layer*
  boundary send both as a ``msg[...]`` job and in the ``final[...]`` merge
  (see ``events.sec_step``), so an unconsumed ``msg[t]...`` job is allowed
  iff a later ``final[t]...`` job exists on the same link for the same task.

:func:`check_template` additionally audits a ``DagTemplate``'s duration
factorisation against the scalar builder node-for-node: for the quantity
vector of the candidate the template was built from, ``nums * q / rate`` must
reproduce every job's scalar-priced duration bit-for-bit.
"""
from __future__ import annotations

import re

from .findings import Report

__all__ = ["check_dag", "check_template"]

_TASK_RE = re.compile(r"^[a-z]+\[(\d+)\]")


def _task_of(name: str) -> str | None:
    m = _TASK_RE.match(name)
    return m.group(1) if m else None


def _link_endpoints(resource: str) -> tuple[str, str] | None:
    if not resource.startswith("link:") or "->" not in resource:
        return None
    src, dst = resource[5:].split("->", 1)
    return src, dst


def check_dag(sim) -> Report:
    """Statically verify a built DAG; returns a Report (never raises)."""
    rep = Report()
    jobs = list(sim.jobs)
    n = len(jobs)
    if not jobs:
        rep.add("dag.empty", "sim", "no jobs")
        return rep

    # --- explicit deps must point backwards (Sim.run's contract)
    edges: list[list[int]] = [[] for _ in range(n)]  # dep -> successors
    consumers: list[list[int]] = [[] for _ in range(n)]  # explicit only
    for job in jobs:
        for d in job.deps:
            rep.tick()
            if not 0 <= d < n:
                rep.add(
                    "dag.event-order",
                    f"job {job.jid} ({job.name})",
                    f"depends on nonexistent job {d}",
                )
                continue
            if d >= job.jid:
                rep.add(
                    "dag.event-order",
                    f"job {job.jid} ({job.name}) on {job.resource}",
                    f"depends on later job {d} ({jobs[d].name}): resource FIFO "
                    f"edges are inconsistent with event order",
                )
            edges[d].append(job.jid)
            consumers[d].append(job.jid)

    # --- FIFO edges: previous job on the same resource precedes the next
    last_on: dict[str, int] = {}
    for job in jobs:
        prev = last_on.get(job.resource)
        if prev is not None:
            edges[prev].append(job.jid)
        last_on[job.resource] = job.jid

    # --- cycle detection over deps + FIFO edges (Kahn; leftovers = cycles)
    rep.tick()
    indeg = [0] * n
    for succs in edges:
        for j in succs:
            if 0 <= j < n:
                indeg[j] += 1
    queue = [j for j in range(n) if indeg[j] == 0]
    seen = 0
    while queue:
        u = queue.pop()
        seen += 1
        for v in edges[u]:
            if 0 <= v < n:
                indeg[v] -= 1
                if indeg[v] == 0:
                    queue.append(v)
    if seen < n:
        stuck = [j for j in range(n) if indeg[j] > 0]
        cycle = _find_cycle(edges, stuck)
        names = " -> ".join(
            f"{jobs[j].name}@{jobs[j].resource}" for j in cycle[:6]
        )
        rep.add(
            "dag.deadlock",
            f"{len(stuck)} job(s) unreachable",
            f"dependency + resource-FIFO edges form a cycle ({names}"
            f"{' -> ...' if len(cycle) > 6 else ''}): Sim.run_batch's "
            f"longest-path sweep would never converge",
        )

    # --- transfer producer/consumer endpoint locality + orphans
    finals_on: dict[tuple[str, str | None], int] = {}
    for job in jobs:
        if job.name.startswith("final[") and _link_endpoints(job.resource):
            finals_on[(job.resource, _task_of(job.name))] = job.jid

    for job in jobs:
        ends = _link_endpoints(job.resource)
        if ends is None:
            continue
        src, dst = ends
        for d in job.deps:
            if not 0 <= d < n:
                continue
            rep.tick()
            dep = jobs[d]
            dep_ends = _link_endpoints(dep.resource)
            ok = (dep.resource == src) if dep_ends is None else (dep_ends[1] == src)
            if not ok:
                rep.add(
                    "dag.transfer",
                    f"job {job.jid} ({job.name}) on {job.resource}",
                    f"producer {dep.name} runs on {dep.resource}, not at the "
                    f"link's source {src!r}: the transferred rows would not "
                    f"exist at departure",
                )
        rep.tick()
        bad_consumers = []
        for c in consumers[job.jid]:
            con = jobs[c]
            con_ends = _link_endpoints(con.resource)
            ok = (con.resource == dst) if con_ends is None else (con_ends[0] == dst)
            if not ok:
                bad_consumers.append(con)
        for con in bad_consumers:
            rep.add(
                "dag.transfer",
                f"job {job.jid} ({job.name}) on {job.resource}",
                f"consumer {con.name} runs on {con.resource}, not at the "
                f"link's destination {dst!r}: the rows arrive where nothing "
                f"reads them",
            )
        if job.duration > 0 and not consumers[job.jid]:
            exempt = False
            if job.name.startswith("msg["):
                fin = finals_on.get((job.resource, _task_of(job.name)))
                exempt = fin is not None and fin > job.jid
            if not exempt:
                rep.add(
                    "dag.orphan",
                    f"job {job.jid} ({job.name}) on {job.resource}",
                    f"positive-duration transfer ({job.duration:.3g}s) with no "
                    f"consumer: rows shipped to {dst!r} are never used",
                )
    return rep


def _find_cycle(edges: list[list[int]], stuck: list[int]) -> list[int]:
    """One concrete cycle among the nodes Kahn could not clear."""
    stuck_set = set(stuck)
    start = stuck[0]
    path: list[int] = []
    pos: dict[int, int] = {}
    u = start
    while u not in pos:
        pos[u] = len(path)
        path.append(u)
        u = next((v for v in edges[u] if v in stuck_set), None)
        if u is None:  # pragma: no cover - stuck nodes always have a stuck succ
            return path
    return path[pos[u] :]


def check_template(template, quantities, topology) -> Report:
    """Audit a ``DagTemplate``'s factorisation against its scalar builder.

    ``quantities`` must be the quantity vector of the candidate the template's
    ``sim`` was laid for (``events._layout_quantities`` /
    ``events._scheme_quantities``); every job's ``nums[j] * q[j] / rate[j]``
    must equal the duration the scalar builder priced, bit-for-bit."""
    import numpy as np

    rep = Report()
    jobs = template.sim.jobs
    q = np.asarray(quantities, dtype=np.float64).reshape(-1)
    rep.tick()
    if len(q) != len(jobs):
        rep.add(
            "dag.template",
            "quantity walk",
            f"{len(q)} quantities for {len(jobs)} builder jobs: the layout "
            f"walk and the DAG builder fell out of step",
        )
        return rep
    ref = template.durations(q, topology)[0]
    for j, job in enumerate(jobs):
        rep.tick()
        if ref[j] != job.duration:
            rep.add(
                "dag.template",
                f"job {j} ({job.name}) on {job.resource}",
                f"template factorisation prices {ref[j]!r} but the scalar "
                f"builder priced {job.duration!r}: nums/den lanes diverge from "
                f"the event builder",
            )
    return rep
