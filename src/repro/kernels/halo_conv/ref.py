"""Pure-jnp oracle for the halo conv: concat-then-conv."""
from __future__ import annotations

import jax.numpy as jnp

from ..conv2d.ref import conv2d_ref


def halo_conv2d_ref(
    x_shard, top_halo, bot_halo, weights, bias=None, *, stride=1, padding=1, groups=1
):
    parts = [p for p in (top_halo, x_shard, bot_halo) if p is not None]
    ext = jnp.concatenate(parts, axis=1) if len(parts) > 1 else x_shard
    # height is already extended by the halos; only pad width
    if padding:
        ext = jnp.pad(ext, ((0, 0), (0, 0), (padding, padding), (0, 0)))
    y = conv2d_ref(ext, weights, bias, stride=stride, padding=0, groups=groups)
    return y
