"""Learning-rate schedules (pure functions of the step count)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 1000, total: int = 100_000, floor: float = 0.1):
    """Linear warmup then cosine decay to ``floor`` of peak; returns a scale."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(1.0, warmup)
    frac = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


def constant(step):
    return jnp.ones_like(jnp.asarray(step, jnp.float32))
