"""Per-architecture smoke tests: reduced config of the same family, one
forward / train / decode step on CPU, asserting output shapes and no NaNs.
(The FULL configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, list_archs
from repro.configs.steps import build, realize

LM_ARCHS = ["qwen3-4b", "codeqwen1.5-7b", "moonshot-v1-16b-a3b", "deepseek-v3-671b"]
VISION_ARCHS = ["vit-l16", "swin-b", "convnext-b", "efficientnet-b7"]
DIFFUSION_ARCHS = ["dit-xl2", "unet-sd15"]


def _finite(tree) -> bool:
    return all(
        bool(jnp.isfinite(x).all())
        for x in jax.tree_util.tree_leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


def test_registry_complete():
    archs = list_archs()
    for a in LM_ARCHS + VISION_ARCHS + DIFFUSION_ARCHS + ["vgg16"]:
        assert a in archs


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_train_smoke(name):
    arch = get(name)
    bundle = build(arch, "train_4k", smoke=True)
    state, inputs = realize(arch, bundle, jax.random.PRNGKey(0))
    fn = jax.jit(bundle.fn)
    new_state, metrics = fn(state, **inputs)
    assert _finite(metrics), metrics
    assert float(metrics["total"]) > 0
    # a second step must also be finite (optimizer state is sane)
    new_state2, metrics2 = fn(new_state, **inputs)
    assert _finite(metrics2)
    assert int(new_state2[2]) == 2


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_decode_smoke(name):
    arch = get(name)
    bundle = build(arch, "decode_32k", smoke=True)
    state, inputs = realize(arch, bundle, jax.random.PRNGKey(0))
    logits, new_cache = jax.jit(bundle.fn)(state, **inputs)
    assert logits.shape == (2, arch.smoke_cfg.vocab)
    assert _finite(logits)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_prefill_smoke(name):
    arch = get(name)
    bundle = build(arch, "prefill_32k", smoke=True)
    state, inputs = realize(arch, bundle, jax.random.PRNGKey(0))
    logits = jax.jit(bundle.fn)(state, **inputs)
    assert logits.shape[-1] == arch.smoke_cfg.vocab
    assert _finite(logits)


def test_lm_long_500k_skip_recorded():
    for name in LM_ARCHS:
        cell = get(name).cells["long_500k"]
        assert cell.skip and "sub-quadratic" in cell.skip


@pytest.mark.parametrize("name", VISION_ARCHS)
@pytest.mark.parametrize("cell", ["cls_224", "serve_b1"])
def test_vision_smoke(name, cell):
    arch = get(name)
    bundle = build(arch, cell, smoke=True)
    state, inputs = realize(arch, bundle, jax.random.PRNGKey(0))
    out = jax.jit(bundle.fn)(state, **inputs)
    if bundle.kind == "train":
        _, metrics = out
        assert _finite(metrics)
    else:
        assert out.shape == (1, arch.smoke_cfg.num_classes)
        assert _finite(out)


@pytest.mark.parametrize("name", DIFFUSION_ARCHS)
def test_diffusion_train_smoke(name):
    arch = get(name)
    bundle = build(arch, "train_256", smoke=True)
    state, inputs = realize(arch, bundle, jax.random.PRNGKey(0))
    new_state, metrics = jax.jit(bundle.fn)(state, **inputs)
    assert _finite(metrics)
    assert float(metrics["loss"]) > 0


@pytest.mark.parametrize("name", DIFFUSION_ARCHS)
def test_diffusion_gen_smoke(name):
    arch = get(name)
    bundle = build(arch, "gen_fast", smoke=True)
    state, inputs = realize(arch, bundle, jax.random.PRNGKey(0))
    lat = jax.jit(bundle.fn)(state, **inputs)
    assert lat.shape == inputs["latents"].shape
    assert _finite(lat)


def test_decode_matches_forward_gqa():
    """Decode with a KV cache must reproduce teacher-forced forward logits."""
    arch = get("qwen3-4b")
    cfg = arch.smoke_cfg
    from repro.models import transformer_lm as lm

    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    full_logits, _ = lm.forward(params, cfg, toks)
    cache = lm.init_cache(cfg, 2, 16)
    for i in range(8):
        step_logits, cache = lm.decode_step(params, cfg, cache, toks[:, i : i + 1], i)
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(full_logits[:, i]), rtol=2e-4, atol=2e-4
        )


def test_decode_matches_forward_mla():
    arch = get("deepseek-v3-671b")
    cfg = arch.smoke_cfg
    from repro.models import transformer_lm as lm

    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    full_logits, _ = lm.forward(params, cfg, toks)
    cache = lm.init_cache(cfg, 2, 12)
    for i in range(6):
        step_logits, cache = lm.decode_step(params, cfg, cache, toks[:, i : i + 1], i)
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(full_logits[:, i]), rtol=2e-4, atol=2e-4
        )
