"""Spatial calibration: measured kernel wall-clock vs the DES's predictions.

Closes the measured-vs-modelled loop for the spatial executor
(``repro.spatial``).  Three stages:

1. **Measure.**  Every layer of a VGG-style backbone is executed for real --
   the lax conv the unfused schedule runs, and the fused Pallas halo-conv
   (``repro.kernels.halo_conv``, ``interpret=True`` on CPU CI) -- and timed
   per shard row-count.  This yields genuine per-layer FLOP rates for the
   machine the benchmark runs on.

2. **Compose.**  The measured per-layer rates are composed into full-network
   makespans with the schedule algebra of paper eqs. 9-15, priced by the
   repo's DES (:class:`~repro.core.simulator.Sim`) over an emulated skewed
   4-device mesh (per-device capacity factors scale the measured times --
   a pod mixing device generations):

   * *unfused*  -- halo exchange, then the layer's full compute
     (compute waits on the ppermute);
   * *fused*    -- interior rows start immediately, only the boundary rows
     wait on the halos (the ``engine="pallas"`` fused schedule);
   * *equal*    -- H/N rows per shard; *weighted* -- rows follow capacity
     (``shard_heights(ratios=caps)``), the ``plan_even(ratios=...)``
     deployment.

   Fused must beat unfused (halo latency hidden behind interior compute) and
   weighted must beat equal (no shard straggles) -- both pinned by
   ``tests/test_benchmarks.py``.  The composition uses the *lax*-measured
   rates for both schedules: interpret-mode Pallas timing is an emulation
   artefact, and using one rate isolates the schedule difference (on real
   TPU hardware the recorded ``pallas_s`` timings replace it).

3. **Calibrate.**  The weighted run's per-shard ``(es, flops, elapsed)``
   samples -- the exact triples ``run_plan(..., time_observer=...)`` emits in
   serving -- feed a :class:`~repro.core.replan.ComputeRateEstimator` seeded
   with (deliberately wrong) nominal platform rates.  The DES is then priced
   nominal vs calibrated against the measured-rate ground truth: the
   calibrated prediction error must come in far below the nominal one.

Emits ``BENCH_spatial.json`` (``--out`` to move it, ``--smoke`` for the CI
artifact run).  CSV rows (``name,us_per_call,derived``) match the other
benchmarks' format.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import AGX_XAVIER, Link  # noqa: E402
from repro.core.replan import ComputeRateEstimator  # noqa: E402
from repro.core.simulator import Sim  # noqa: E402
from repro.kernels.halo_conv.halo_conv import halo_conv2d  # noqa: E402
from repro.models.vgg import VGGConfig  # noqa: E402
from repro.spatial.halo import halo_sizes, shard_heights, spatial_alignment  # noqa: E402

N_SHARDS = 4
# emulated skewed mesh: per-device capacity factors (mixed device generations)
CAPS = (1.0, 0.55, 0.35, 0.8)
LINK = Link(200e6)  # ES-ES halo link (edge-box Ethernet class)
NOMINAL_FLOPS = AGX_XAVIER.eff_flops  # the (wrong-for-CPU) nominal per shard


def build_net(smoke: bool):
    """3-block VGG body at 64 px: stride alignment 8 => 4-way weighted splits
    stay stride-divisible through every pool."""
    cfg = VGGConfig(
        img_res=64,
        width_mult=0.125 if smoke else 0.25,
        num_classes=10,
        blocks=((2, 64), (2, 128), (3, 256)),
    )
    return cfg.geom()


def _time_fn(fn, *args, repeats: int) -> float:
    jax.block_until_ready(fn(*args))  # compile / warm caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_layers(net, *, interpret: bool, repeats: int) -> list[dict]:
    """Per-layer measured wall-clock at the equal-split shard height: the lax
    conv over the halo-extended slab (what the unfused schedule executes) and
    the fused Pallas halo-conv (what ``engine="pallas"`` executes)."""
    sizes = net.sizes()
    key = jax.random.PRNGKey(0)
    out = []
    for i, g in enumerate(net.layers):
        r_in = sizes[i] // N_SHARDS
        r_out = r_in // g.s
        flops = net.layer_flops(i, r_out)
        key, kx, kw = jax.random.split(key, 3)
        if g.kind == "pool":
            x = jax.random.normal(kx, (1, r_in, sizes[i], g.c_in))
            pool = jax.jit(
                lambda a: lax.reduce_window(
                    a, -jnp.inf, lax.max, (1, g.k, g.k, 1), (1, g.s, g.s, 1), "VALID"
                )
            )
            lax_s = _time_fn(pool, x, repeats=repeats)
            pallas_s = None
        else:
            lo, hi = halo_sizes(g.k, g.s, g.p)
            w_pad = sizes[i] + 2 * g.p
            ext = jax.random.normal(kx, (1, (r_out - 1) * g.s + g.k, w_pad, g.c_in))
            wts = jax.random.normal(kw, (g.k, g.k, g.c_in, g.c_out)) * 0.05
            conv = jax.jit(
                lambda a, w: lax.conv_general_dilated(
                    a, w, (g.s, g.s), "VALID",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                )
            )
            lax_s = _time_fn(conv, ext, wts, repeats=repeats)
            x = jax.random.normal(kx, (1, r_in, sizes[i], g.c_in))
            top = jnp.zeros((1, lo, sizes[i], g.c_in)) if lo else None
            bot = jnp.zeros((1, hi, sizes[i], g.c_in)) if hi else None
            fused = jax.jit(
                lambda a, t, bb, w: halo_conv2d(
                    a, t, bb, w, stride=g.s, padding=g.p, interpret=interpret
                )
            )
            pallas_s = _time_fn(fused, x, top, bot, wts, repeats=repeats)
        out.append(
            dict(
                layer=g.name, kind=g.kind, rows=r_out, flops=flops,
                lax_s=lax_s, pallas_s=pallas_s,
                rate=flops / lax_s,  # measured FLOP/s for this layer shape
            )
        )
    return out


def _halo_geometry(g):
    """(lo, hi, boundary_out_rows) of one layer for the schedule algebra."""
    lo, hi = (0, g.k - g.s) if g.kind == "pool" else halo_sizes(g.k, g.s, g.p)
    nb = -(-lo // g.s) + -(-hi // g.s)  # output rows touching any halo
    return lo, hi, nb


def des_makespan(net, heights, rate_of, *, fused: bool, link: Link = LINK) -> float:
    """Price one full forward through the DES: per-shard compute chains with
    neighbour halo transfers on dedicated links.

    ``rate_of(j, i)`` is shard j's FLOP/s on layer i (measured per-layer rates
    for the ground truth; one scalar per shard for estimator predictions).
    ``fused`` switches the per-layer dependency structure: unfused compute
    waits on the halos; fused splits compute into an interior chunk dependent
    only on the previous layer and a boundary chunk gated by the halos --
    eqs. 9-15 as an event topology."""
    sim = Sim()
    sizes = net.sizes()
    h = list(heights)
    last: list[int | None] = [None] * N_SHARDS
    for i, g in enumerate(net.layers):
        lo, hi, nb = _halo_geometry(g)
        t_halo_lo = link.comm_time(lo * sizes[i] * g.c_in * 4.0)
        t_halo_hi = link.comm_time(hi * sizes[i] * g.c_in * 4.0)
        halos: list[list[int]] = [[] for _ in range(N_SHARDS)]
        for j in range(N_SHARDS):
            if lo and j > 0:
                halos[j].append(
                    sim.add(f"halo_dn.{i}.{j}", f"link:{j-1}->{j}", t_halo_lo,
                            [last[j - 1]])
                )
            if hi and j < N_SHARDS - 1:
                halos[j].append(
                    sim.add(f"halo_up.{i}.{j}", f"link:{j+1}->{j}", t_halo_hi,
                            [last[j + 1]])
                )
        for j in range(N_SHARDS):
            rows = h[j] // g.s
            rate = rate_of(j, i)
            if fused and halos[j] and rows > nb:
                interior = sim.add(
                    f"cmp_int.{i}.{j}", f"w{j}",
                    net.layer_flops(i, rows - nb) / rate, [last[j]],
                )
                last[j] = sim.add(
                    f"cmp_bnd.{i}.{j}", f"w{j}",
                    net.layer_flops(i, nb) / rate, [interior] + halos[j],
                )
            else:
                last[j] = sim.add(
                    f"cmp.{i}.{j}", f"w{j}",
                    net.layer_flops(i, rows) / rate, [last[j]] + halos[j],
                )
            h[j] = rows
    return sim.run()


def run_all(smoke: bool = False, out_path: str | None = "BENCH_spatial.json") -> dict:
    net = build_net(smoke)
    repeats = 2 if smoke else 5
    layers = measure_layers(net, interpret=True, repeats=repeats)

    equal = tuple([net.in_rows // N_SHARDS] * N_SHARDS)
    weighted = shard_heights(
        net.in_rows, N_SHARDS, ratios=CAPS, align=spatial_alignment(net)
    )

    def measured_rate(j, i):  # measured per-layer rate scaled by device capacity
        return layers[i]["rate"] * CAPS[j]

    makespans = {
        f"{split}_{sched}": des_makespan(
            net, hts, measured_rate, fused=(sched == "fused")
        )
        for split, hts in (("equal", equal), ("weighted", weighted))
        for sched in ("unfused", "fused")
    }
    fused_speedup = makespans["equal_unfused"] / makespans["equal_fused"]
    weighted_speedup = makespans["equal_fused"] / makespans["weighted_fused"]

    # --- calibration loop: the weighted run's (es, flops, elapsed) samples ---
    samples = []
    h = list(weighted)
    for i, g in enumerate(net.layers):
        for j in range(N_SHARDS):
            rows = h[j] // g.s
            fl = net.layer_flops(i, rows)
            samples.append((f"w{j}", fl, fl / measured_rate(j, i)))
        h = [q // g.s for q in h]

    est = ComputeRateEstimator({f"w{j}": NOMINAL_FLOPS for j in range(N_SHARDS)})
    for _ in range(3):  # EWMA needs a few folds to forget the (wrong) nominal
        est.observe_samples(samples)

    truth = makespans["weighted_fused"]
    pred_nominal = des_makespan(
        net, weighted, lambda j, i: NOMINAL_FLOPS, fused=True
    )
    pred_calibrated = des_makespan(
        net, weighted, lambda j, i: est.rate(f"w{j}"), fused=True
    )
    err_nominal = abs(pred_nominal - truth) / truth
    err_calibrated = abs(pred_calibrated - truth) / truth

    out = dict(
        n_shards=N_SHARDS,
        caps=CAPS,
        link_bps=LINK.rate_bps,
        smoke=smoke,
        equal_heights=equal,
        weighted_heights=weighted,
        layers=layers,
        makespans=makespans,
        fused_speedup=fused_speedup,
        weighted_speedup=weighted_speedup,
        n_samples=len(samples),
        rates_calibrated={f"w{j}": est.rate(f"w{j}") for j in range(N_SHARDS)},
        pred_nominal=pred_nominal,
        pred_calibrated=pred_calibrated,
        err_nominal=err_nominal,
        err_calibrated=err_calibrated,
    )

    print(f"\n== Spatial calibration: {len(net.layers)} layers, "
          f"{N_SHARDS} shards, caps {CAPS}, link {LINK.rate_bps/1e6:.0f} Mbps ==")
    print(f"{'layer':10s} {'rows':>4s} {'lax (us)':>9s} {'pallas (us)':>11s} "
          f"{'GFLOP/s':>8s}")
    for L in layers:
        ps = f"{L['pallas_s']*1e6:11.0f}" if L["pallas_s"] else " " * 11
        print(f"{L['layer']:10s} {L['rows']:4d} {L['lax_s']*1e6:9.0f} {ps} "
              f"{L['rate']/1e9:8.2f}")
    for name, ms in makespans.items():
        print(f"spatial_{name},{ms*1e6:.1f},")
    print(f"fused over unfused: {fused_speedup:.3f}x ; weighted over equal "
          f"(skewed mesh): {weighted_speedup:.3f}x")
    print(f"spatial_fused_speedup,,{fused_speedup:.4f}")
    print(f"spatial_weighted_speedup,,{weighted_speedup:.4f}")
    print(f"calibration: nominal err {err_nominal*100:.1f}% -> calibrated err "
          f"{err_calibrated*100:.1f}% ({len(samples)} samples x3 folds)")
    print(f"spatial_calib_err,,{err_calibrated:.4f}")

    if out_path:
        with open(out_path, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True, default=str)
        print(f"\nwrote {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_spatial.json")
    args = ap.parse_args()
    run_all(smoke=args.smoke, out_path=args.out)
