"""End-to-end serving driver (the paper's workload type): batched image
requests served through the HALP-partitioned VGG-16 with deadline tracking --
the host-ES/secondary-ES collaboration as a real request loop.

    PYTHONPATH=src python examples/serve_halp.py --requests 48
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.core import OffloadChannel, plan_halp
from repro.core.replan import ComputeRateEstimator
from repro.models import vgg
from repro.runtime.serve import BatchingEngine, ServeConfig, choose_batch_size
from repro.spatial import run_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--deadline-ms", type=float, default=2000.0)
    args = ap.parse_args()

    arch = get("vgg16")
    cfg = arch.smoke_cfg
    params = vgg.init(jax.random.PRNGKey(0), cfg)
    plan = plan_halp(cfg.geom(), overlap_rows=4)

    # zero-config per-ES timing attribution: run_plan itself reports one
    # (es, flops, elapsed) sample per ES per inference straight into the
    # engine's observe_es_time -> ComputeRateEstimator; nothing is measured
    # by hand here.  run_plan stays eager for the timing; the per-layer
    # primitive and the head are jitted so the kernels remain compiled.
    apply_jit = jax.jit(vgg.apply_layer, static_argnums=(1,))
    head_jit = jax.jit(lambda feats: jnp.argmax(vgg.head(params, feats), axis=-1))
    est = ComputeRateEstimator({es: 1e9 for es in plan.es_names})
    eng = None  # bound below; warm-up calls before that are not attributed

    def model(batch):
        feats = run_plan(
            plan, params["features"], apply_jit, batch,
            time_observer=eng.observe_es_time if eng is not None else None,
        )
        return head_jit(feats)

    # pick the batch size with the paper's reliability policy: measure the
    # latency curve, then admit the largest batch meeting the deadline target.
    res = cfg.img_res
    lat = {}
    for b in (1, 2, 4, 8):
        xb = jnp.zeros((b, res, res, 3))
        jax.block_until_ready(model(xb))  # compile
        t0 = time.monotonic()
        for _ in range(3):
            jax.block_until_ready(model(xb))
        lat[b] = (time.monotonic() - t0) / 3
    print("latency curve:", {b: f"{t*1e3:.1f}ms" for b, t in lat.items()})
    ch = OffloadChannel(rate_bps=100e6, sigma_s=1e-3)
    batch = choose_batch_size(
        lambda b: lat[min(lat, key=lambda k: abs(k - b))],
        args.deadline_ms / 1e3,
        ch,
        target=0.999,
        max_batch=8,
    )
    print(f"reliability-chosen max_batch = {batch}")
    if batch == 0:  # admission says shed: no batch meets the deadline target
        raise SystemExit("admission returned 0 (shed): deadline infeasible")

    eng = BatchingEngine(model, ServeConfig(max_batch=batch), es_observer=est.observe)
    key = jax.random.PRNGKey(1)
    t0 = time.monotonic()
    for i in range(args.requests):
        key, k = jax.random.split(key)
        eng.submit(jax.random.normal(k, (res, res, 3)), deadline_s=args.deadline_ms / 1e3)
    stats = eng.run_until_drained()
    wall = time.monotonic() - t0
    print(
        f"served {stats['completed']} requests in {wall:.2f}s "
        f"({stats['completed']/wall:.1f} req/s), deadline met: "
        f"{stats['deadline_met_frac']*100:.1f}%, p99 {stats['p99_latency_s']*1e3:.0f}ms"
    )
    print(
        "measured per-ES compute (auto-attributed):",
        {es: f"{est.rate(es)/1e9:.2f} GFLOP/s" for es in plan.es_names},
    )


if __name__ == "__main__":
    main()
