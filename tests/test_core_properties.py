"""Property tests on the scheduling core + config registry invariants."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    GTX_1080TI,
    Link,
    plan_halp,
    simulate_halp,
    simulate_modnn,
    standalone_time,
    vgg16_geom,
)
from repro.parallel.pipeline import bubble_fraction

NET = vgg16_geom()


@given(st.sampled_from([1e9, 5e9, 20e9, 60e9, 100e9]))
@settings(max_examples=5, deadline=None)
def test_halp_monotone_in_rate(rate):
    """Faster links never hurt."""
    t_lo = simulate_halp(NET, GTX_1080TI, Link(rate))["total"]
    t_hi = simulate_halp(NET, GTX_1080TI, Link(rate * 2))["total"]
    assert t_hi <= t_lo + 1e-12


@given(st.integers(1, 6))
@settings(max_examples=6, deadline=None)
def test_halp_multitask_scales_sublinearly(k):
    """K tasks on K pairs + shared host finish in << K x single-task time."""
    link = Link(40e9)
    t1 = simulate_halp(NET, GTX_1080TI, link, n_tasks=1)["total"]
    tk = simulate_halp(NET, GTX_1080TI, link, n_tasks=k)["total"]
    assert tk >= t1 - 1e-12
    assert tk <= k * t1  # far better than sequential


@given(st.integers(2, 12))
@settings(max_examples=8, deadline=None)
def test_modnn_more_workers_less_compute_time(n):
    """At infinite rate, MoDNN approaches the 1/n compute bound."""
    t = simulate_modnn(NET, GTX_1080TI, Link(1e15), n)["total"]
    t_pre = standalone_time(NET, GTX_1080TI)
    assert t < t_pre
    assert t > t_pre / n * 0.9  # cannot beat perfect parallelism


@given(st.integers(2, 10))
@settings(max_examples=8, deadline=None)
def test_overlap_zone_width_covers(w):
    """Any overlap width >= 2 yields a valid plan with no secondary exchange
    (the plan constructor asserts it); message bytes decrease in w for e0->ek
    is not guaranteed, but plans must stay consistent."""
    plan = plan_halp(NET, overlap_rows=w)
    sizes = NET.sizes()
    for i, part in enumerate(plan.parts):
        assert part.out["e1"].rows + part.out["e0"].rows + part.out["e2"].rows == sizes[i + 1]


def test_bubble_fraction():
    assert bubble_fraction(2, 1) == pytest.approx(0.5)
    assert bubble_fraction(2, 14) == pytest.approx(1 / 15)
    assert bubble_fraction(8, 56) == pytest.approx(7 / 63)


def test_registry_cells_total_40():
    """The assigned pool: 10 archs x 4 shapes = 40 cells (+ vgg16 extra)."""
    from repro.configs import get, list_archs

    assigned = [a for a in list_archs() if a != "vgg16"]
    assert len(assigned) == 10
    total = sum(len(get(a).cells) for a in assigned)
    assert total == 40
    # every skip is recorded with a reason
    for a in assigned:
        for c in get(a).cells.values():
            if c.skip:
                assert "sub-quadratic" in c.skip


def test_dryrun_artifacts_have_corrected_costs():
    """All ok dry-run records carry the while-trip-corrected hlo_cost."""
    import json
    from pathlib import Path

    results = Path(__file__).resolve().parents[1] / "benchmarks" / "dryrun_results"
    if not results.exists():
        pytest.skip("dry-run not executed")
    n = 0
    for f in results.glob("*__pod16x16.json"):
        rec = json.loads(f.read_text())
        if rec["status"] == "ok":
            assert "hlo_cost" in rec, f.name
            assert rec["hlo_cost"]["flops"] > 0, f.name
            n += 1
    assert n >= 36
