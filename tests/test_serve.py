"""Serving-pipeline conformance harness: traffic models, admission, the
event-driven virtual-time loop (this subsystem's ``test_conformance.py``).

Everything runs in simulated time -- there is no ``time.sleep`` anywhere and
no wall-clock assertion; the :class:`~repro.runtime.serve.VirtualClock` and
the trace loop's virtual event clock are the only notions of time.  The
property tests run under real ``hypothesis`` when installed and under
``tests/_hypothesis_fallback.py`` otherwise (same API subset)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core.reliability import (
    OffloadChannel,
    phi,
    probit,
    required_slack,
    service_reliability,
)
from repro.runtime.serve import (
    BatchingEngine,
    ServeConfig,
    ServedTrace,
    ServeLoopConfig,
    VirtualClock,
    choose_batch_size,
    serve_trace,
)
from repro.runtime.traffic import (
    DeadlineClass,
    DiurnalProcess,
    FlashCrowdProcess,
    PoissonProcess,
    Trace,
    make_trace,
)

LAT = np.array([0.012, 0.016, 0.020, 0.024, 0.028, 0.032, 0.036, 0.040])
CLASSES = (
    DeadlineClass("premium", 0.15, target=0.999, share=0.2),
    DeadlineClass("standard", 0.4, target=0.99, share=0.5),
    DeadlineClass("bulk", 2.0, target=0.9, share=0.3),
)
CH = OffloadChannel(rate_bps=100e6, sigma_s=2e-3)  # mu = 40 ms
CH0 = OffloadChannel(rate_bps=100e6, sigma_s=0.0)


def _assert_served_equal(a: ServedTrace, b: ServedTrace) -> None:
    assert np.array_equal(a.fin, b.fin, equal_nan=True)
    assert np.array_equal(a.shed, b.shed)
    assert np.array_equal(a.met, b.met)
    assert a.n_batches == b.n_batches
    assert np.array_equal(a.batch_size_counts, b.batch_size_counts)


# ---------------------------------------------------------------------------
# probit / required_slack: the reliability integral inverted for admission
# ---------------------------------------------------------------------------


def test_probit_inverts_phi():
    for p in (0.5, 0.9, 0.99, 0.999, 0.99999, 0.1, 0.025):
        assert phi(probit(p)) == pytest.approx(p, abs=1e-9)
    assert probit(0.5) == pytest.approx(0.0, abs=1e-9)
    for bad in (0.0, 1.0, -0.1, 1.1):
        with pytest.raises(ValueError):
            probit(bad)


def test_required_slack_inverts_service_reliability():
    """reliability(ch, t_inf, D) >= target  iff  D >= required_slack: the
    threshold sits exactly at the target's quantile."""
    t_inf = 0.02
    for target in (0.9, 0.99, 0.999):
        d = required_slack(CH, t_inf, target)
        assert service_reliability(CH, t_inf, d) == pytest.approx(target, abs=1e-9)
        assert service_reliability(CH, t_inf, d + 1e-6) > target
        assert service_reliability(CH, t_inf, d - 1e-6) < target
    # monotone in target; degenerate deterministic channel
    assert required_slack(CH, t_inf, 0.999) > required_slack(CH, t_inf, 0.9)
    assert required_slack(CH0, t_inf, 0.42) == CH0.mu_s + t_inf
    with pytest.raises(ValueError):
        required_slack(CH, t_inf, 1.0)


# ---------------------------------------------------------------------------
# VirtualClock + asynchronous batch formation (ready/poll)
# ---------------------------------------------------------------------------


def test_virtual_clock_semantics():
    clk = VirtualClock(start_s=5.0)
    assert clk() == 5.0 and clk.now() == 5.0
    assert clk.advance(1.5) == 6.5
    assert clk.advance_to(10.0) == 10.0
    with pytest.raises(ValueError):
        clk.advance(-0.1)
    with pytest.raises(ValueError):
        clk.advance_to(9.0)
    assert clk() == 10.0  # failed moves leave time untouched


def test_batch_formation_decoupled_from_execution():
    """ready()/poll(): a batch launches when full OR when the head request has
    waited max_delay_s -- a pure decision on (queue, clock), no sleeping."""
    clk = VirtualClock()
    eng = BatchingEngine(
        jax.jit(lambda b: b), ServeConfig(max_batch=3, max_delay_s=0.010), clock=clk
    )
    assert not eng.ready() and eng.poll() == []  # empty queue never launches
    eng.submit(jnp.zeros(()), deadline_s=1.0)
    assert not eng.ready()  # neither full nor timed out
    clk.advance(0.005)
    assert not eng.ready() and eng.poll() == []
    clk.advance(0.005)  # head has now waited exactly max_delay_s (0.005*2
    # is binary-exactly the 0.01 literal; 0.009+0.001 would not be)
    assert eng.ready()
    done = eng.poll()
    assert len(done) == 1 and not eng.queue
    # full batch launches immediately, with no waiting at all
    for _ in range(3):
        eng.submit(jnp.zeros(()), deadline_s=1.0)
    assert eng.ready()
    assert len(eng.poll()) == 3


# ---------------------------------------------------------------------------
# BatchingEngine edge cases
# ---------------------------------------------------------------------------


def test_edf_pop_with_duplicate_deadlines():
    """Duplicate deadlines must not break the heap pop: all duplicates drain,
    and a strictly earlier deadline still precedes every duplicate."""
    clk = VirtualClock()
    eng = BatchingEngine(jax.jit(lambda b: b), ServeConfig(max_batch=3), clock=clk)
    dup = [eng.submit(jnp.zeros(()), deadline_s=2.0) for _ in range(3)]
    tight = eng.submit(jnp.zeros(()), deadline_s=0.5)
    first = eng.step()
    assert first[0].rid == tight  # earliest deadline leads the first batch
    assert {r.rid for r in first[1:]} <= set(dup)
    rest = eng.step()
    assert {r.rid for r in first[1:]} | {r.rid for r in rest} == set(dup)


def test_stats_on_zero_and_one_completed():
    clk = VirtualClock()
    eng = BatchingEngine(jax.jit(lambda b: b), ServeConfig(max_batch=2), clock=clk)
    s0 = eng.stats()
    assert s0["completed"] == 0 and s0["deadline_met_frac"] == 0.0
    assert s0["p50_latency_s"] == 0.0 and s0["p99_latency_s"] == 0.0  # no NaNs
    eng.submit(jnp.zeros(()), deadline_s=1.0)
    clk.advance(0.25)
    eng.step()
    s1 = eng.stats()
    assert s1["completed"] == 1 and s1["deadline_met_frac"] == 1.0
    # a single sample is every percentile of itself
    assert s1["p50_latency_s"] == pytest.approx(0.25)
    assert s1["p99_latency_s"] == pytest.approx(0.25)


def test_run_until_drained_respects_max_batches():
    eng = BatchingEngine(jax.jit(lambda b: b), ServeConfig(max_batch=4))
    for i in range(10):
        eng.submit(jnp.ones(()) * i, deadline_s=5.0)
    stats = eng.run_until_drained(max_batches=2)
    assert stats["completed"] == 8  # two full batches executed...
    assert len(eng.queue) == 2  # ...and the residual queue is intact
    eng.run_until_drained()
    assert eng.stats()["completed"] == 10 and not eng.queue


def test_pad_to_max_reports_executed_width_variants():
    """pad_to_max=True reports the padded (executed) width; False the true
    request count -- the replan calibration depends on the distinction."""
    for pad, want in ((True, [4, 4, 4]), (False, [4, 4, 2])):
        seen = []
        eng = BatchingEngine(
            jax.jit(lambda b: b),
            ServeConfig(max_batch=4, pad_to_max=pad),
            observer=lambda n, dt: seen.append(n),
        )
        for i in range(10):
            eng.submit(jnp.ones(()) * i, deadline_s=5.0)
        eng.run_until_drained()
        assert seen == want


# ---------------------------------------------------------------------------
# choose_batch_size properties (the PR-5 shed semantics, property-tested)
# ---------------------------------------------------------------------------

_lat_base = st.floats(min_value=1e-4, max_value=5e-2)
_lat_slope = st.floats(min_value=1e-5, max_value=2e-2)
_deadline = st.floats(min_value=1e-3, max_value=1.0)
_target = st.floats(min_value=0.5, max_value=0.999999)
_sigma = st.floats(min_value=0.0, max_value=2e-2)
_rate = st.floats(min_value=2e6, max_value=1e9)


@settings(max_examples=60, deadline=None)
@given(a=_lat_base, c=_lat_slope, d1=_deadline, d2=_deadline, sig=_sigma, rate=_rate)
def test_choose_batch_size_monotone_in_deadline(a, c, d1, d2, sig, rate):
    ch = OffloadChannel(rate_bps=rate, sigma_s=sig)
    lat = lambda b: a + c * b
    lo, hi = min(d1, d2), max(d1, d2)
    assert choose_batch_size(lat, lo, ch, target=0.99, max_batch=16) <= choose_batch_size(
        lat, hi, ch, target=0.99, max_batch=16
    )


@settings(max_examples=60, deadline=None)
@given(a=_lat_base, c=_lat_slope, d=_deadline, t1=_target, t2=_target, rate=_rate)
def test_choose_batch_size_antitone_in_target(a, c, d, t1, t2, rate):
    ch = OffloadChannel(rate_bps=rate, sigma_s=5e-3)
    lat = lambda b: a + c * b
    lo, hi = min(t1, t2), max(t1, t2)
    assert choose_batch_size(lat, d, ch, target=lo, max_batch=16) >= choose_batch_size(
        lat, d, ch, target=hi, max_batch=16
    )


@settings(max_examples=60, deadline=None)
@given(
    a=_lat_base, c=_lat_slope, d=_deadline, t=_target, sig=_sigma, rate=_rate,
    mb=st.integers(min_value=1, max_value=24),
)
def test_choose_batch_size_bounds_and_shed_semantics(a, c, d, t, sig, rate, mb):
    """0 <= result <= max_batch, and 0 means even b=1 misses the target."""
    ch = OffloadChannel(rate_bps=rate, sigma_s=sig)
    lat = lambda b: a + c * b
    b = choose_batch_size(lat, d, ch, target=t, max_batch=mb)
    assert 0 <= b <= mb
    if b == 0:
        assert service_reliability(ch, lat(1), d) < t
    else:
        assert service_reliability(ch, lat(b), d) >= t


# ---------------------------------------------------------------------------
# Arrival generators: seeded determinism + rate semantics
# ---------------------------------------------------------------------------


def test_generators_seeded_determinism():
    """Same seed => bit-identical trace (fresh instances); different seed
    diverges.  Holds for every process and for make_trace's labels."""
    procs = [
        lambda seed: PoissonProcess(rate_hz=20.0, seed=seed),
        lambda seed: DiurnalProcess(base_rate_hz=15.0, period_s=100.0, seed=seed),
        lambda seed: FlashCrowdProcess(base_rate_hz=10.0, seed=seed),
    ]
    for make in procs:
        t1, t2 = make(5).times(50.0), make(5).times(50.0)
        assert np.array_equal(t1, t2)
        assert not np.array_equal(t1, make(6).times(50.0))
    tr1 = make_trace(PoissonProcess(20.0, seed=1), CLASSES, 50.0, seed=9)
    tr2 = make_trace(PoissonProcess(20.0, seed=1), CLASSES, 50.0, seed=9)
    assert np.array_equal(tr1.arrival, tr2.arrival)
    assert np.array_equal(tr1.cls, tr2.cls)
    # label seed independent of the arrival process seed
    tr3 = make_trace(PoissonProcess(20.0, seed=1), CLASSES, 50.0, seed=10)
    assert np.array_equal(tr1.arrival, tr3.arrival)
    assert not np.array_equal(tr1.cls, tr3.cls)


def test_poisson_rate_recovered_from_trace():
    """Arrival count AND mean inter-arrival gap both recover rate_hz -- the
    gap check guards a silent rate/interval inversion (exponential(rate)
    instead of exponential(1/rate) would pass a smoke test at rate ~ 1)."""
    rate, horizon = 80.0, 2_000.0
    t = PoissonProcess(rate_hz=rate, seed=3).times(horizon)
    assert t.size == pytest.approx(rate * horizon, rel=0.03)
    assert float(np.diff(t).mean()) == pytest.approx(1.0 / rate, rel=0.03)
    assert t[0] >= 0.0 and t[-1] < horizon
    assert np.all(np.diff(t) >= 0)


def test_diurnal_modulation_and_bounds():
    proc = DiurnalProcess(base_rate_hz=50.0, amplitude=0.8, period_s=1_000.0, seed=4)
    assert proc.rate_at(250.0) == pytest.approx(90.0)  # peak = base*(1+amp)
    assert proc.rate_at(750.0) == pytest.approx(10.0)  # trough
    t = proc.times(1_000.0)
    peak_n = ((t >= 100.0) & (t < 400.0)).sum()  # window around the peak
    trough_n = ((t >= 600.0) & (t < 900.0)).sum()
    assert peak_n > 3 * trough_n
    # mean rate over one full period is the base rate
    assert t.size == pytest.approx(50.0 * 1_000.0, rel=0.05)


def test_flash_crowd_burst_rate():
    proc = FlashCrowdProcess(
        base_rate_hz=10.0, bursts=((100.0, 50.0, 200.0),), seed=8
    )
    t = proc.times(400.0)
    in_burst = ((t >= 100.0) & (t < 150.0)).sum()
    outside = t.size - in_burst
    assert in_burst == pytest.approx(50.0 * 210.0, rel=0.08)  # base + extra
    assert outside == pytest.approx(350.0 * 10.0, rel=0.15)
    assert np.all(np.diff(t) >= 0)  # merged streams stay sorted


def test_traffic_validation_errors():
    with pytest.raises(ValueError):
        DeadlineClass("x", deadline_s=0.0)
    with pytest.raises(ValueError):
        DeadlineClass("x", 1.0, target=1.0)  # unattainable under Gaussian offload
    with pytest.raises(ValueError):
        DeadlineClass("x", 1.0, share=0.0)
    with pytest.raises(ValueError):
        PoissonProcess(rate_hz=0.0)
    with pytest.raises(ValueError):
        DiurnalProcess(base_rate_hz=1.0, amplitude=1.5)  # negative rates
    with pytest.raises(ValueError):
        FlashCrowdProcess(base_rate_hz=1.0, bursts=((0.0, -1.0, 5.0),))
    with pytest.raises(ValueError):
        Trace(np.array([2.0, 1.0]), np.array([0, 0]), (CLASSES[0],))  # unsorted
    with pytest.raises(ValueError):
        Trace(np.array([1.0, 2.0]), np.array([0, 3]), (CLASSES[0],))  # bad label
    with pytest.raises(ValueError):
        make_trace(PoissonProcess(1.0), (), 10.0)


def test_trace_deadlines_derive_from_classes():
    tr = make_trace(PoissonProcess(20.0, seed=1), CLASSES, 20.0, seed=2)
    rel = np.array([c.deadline_s for c in CLASSES])
    assert np.array_equal(tr.deadlines(), tr.arrival + rel[tr.cls])
    assert len(tr) == tr.arrival.size


# ---------------------------------------------------------------------------
# serve_trace: the event-driven loop end to end
# ---------------------------------------------------------------------------


def test_serve_trace_validation():
    tr = make_trace(PoissonProcess(20.0, seed=1), CLASSES, 5.0, seed=2)
    with pytest.raises(ValueError):
        serve_trace(tr, LAT[:4], ServeLoopConfig(max_batch=8))  # table too short
    with pytest.raises(ValueError):
        serve_trace(tr, np.stack([LAT, LAT]), ServeLoopConfig())  # rows != bounds+1
    with pytest.raises(ValueError):
        serve_trace(tr, -LAT, ServeLoopConfig())  # non-positive entries
    with pytest.raises(ValueError):
        ServeLoopConfig(max_batch=0)
    with pytest.raises(ValueError):
        ServeLoopConfig(max_delay_s=-1e-3)
    with pytest.raises(ValueError):
        ServeLoopConfig(segment_bounds=(2.0, 1.0))


def test_serve_trace_empty_trace():
    tr = Trace(np.empty(0), np.empty(0, dtype=np.int64), CLASSES)
    out = serve_trace(tr, LAT)
    assert out.n_batches == 0 and len(out.fin) == 0
    s = out.stats()
    assert s["completed"] == 0 and s["p99_latency_s"] == 0.0
    assert s["deadline_met_frac"] == 0.0 and s["mean_batch"] == 0.0


def test_serve_trace_deterministic_and_conserving():
    tr = make_trace(FlashCrowdProcess(30.0, seed=2), CLASSES, 120.0, seed=3)
    cfg = ServeLoopConfig(max_batch=8, channel=CH, seed=11)
    a, b = serve_trace(tr, LAT, cfg), serve_trace(tr, LAT, cfg)
    _assert_served_equal(a, b)
    # conservation: every request is either completed or shed, exactly once
    assert int((~a.shed).sum()) + int(a.shed.sum()) == len(tr)
    assert np.isnan(a.fin[a.shed]).all() and np.isfinite(a.fin[~a.shed]).all()
    assert not a.met[a.shed].any()  # shed requests never meet
    # batch accounting: histogram matches served count and batch count
    assert a.batch_size_counts[0] == 0
    widths = np.arange(a.batch_size_counts.size)
    assert int(a.batch_size_counts @ widths) == int((~a.shed).sum())
    assert int(a.batch_size_counts.sum()) == a.n_batches
    # stats coherence
    s = a.stats()
    assert s["completed"] + s["shed"] == s["n"] == len(tr)
    assert s["deadline_met_frac"] == pytest.approx(a.met.mean())
    per_cls = a.class_stats()
    assert sum(c["n"] for c in per_cls.values()) == len(tr)
    assert sum(c["completed"] for c in per_cls.values()) == s["completed"]


def test_serve_trace_edf_admission_order():
    """A later-arriving tight-deadline request overtakes a queued loose one,
    and the admission cap serves it alone when width 2 would blow its slack."""
    classes = (DeadlineClass("tight", 0.05, target=0.9),
               DeadlineClass("loose", 10.0, target=0.9))
    tr = Trace(np.array([0.0, 0.001]), np.array([1, 0]), classes)  # loose first
    lat = np.array([0.030, 10.0])  # width 2 is hopeless for the tight class
    out = serve_trace(tr, lat, ServeLoopConfig(max_batch=2, max_delay_s=0.01))
    assert not out.shed.any()
    assert out.fin[1] < out.fin[0]  # EDF: tight served first, alone
    assert out.met[1]
    assert out.n_batches == 2 and out.batch_size_counts[1] == 2


def test_serve_trace_sheds_doomed_head_only():
    """A request whose slack cannot clear its target even at b=1 is shed; the
    rest of the queue is served (the per-request PR-5 shed semantics)."""
    classes = (DeadlineClass("doomed", 0.010, target=0.9),
               DeadlineClass("fine", 5.0, target=0.9))
    tr = Trace(np.array([0.0, 0.0]), np.array([0, 1]), classes)
    out = serve_trace(tr, np.array([0.030, 0.035]),
                      ServeLoopConfig(max_batch=2, max_delay_s=0.002))
    assert bool(out.shed[0]) and not bool(out.shed[1])
    assert bool(out.met[1]) and not bool(out.met[0])
    assert out.n_batches == 1 and out.batch_size_counts[1] == 1


def test_serve_trace_no_admission_serves_everything():
    tr = make_trace(FlashCrowdProcess(40.0, seed=5), CLASSES, 60.0, seed=6)
    out = serve_trace(tr, LAT, ServeLoopConfig(max_batch=8, admission=False, channel=CH))
    assert not out.shed.any()
    assert out.stats()["completed"] == len(tr)


def test_serve_trace_segmented_table():
    """Per-segment latency rows apply by formation time: a 10x slower second
    half must push that half's latencies up, and both paths agree."""
    tr = make_trace(PoissonProcess(15.0, seed=7), CLASSES, 60.0, seed=8)
    table = np.stack([LAT, 10.0 * LAT])
    cfg = dict(max_batch=8, segment_bounds=(30.0,), admission=False)
    out = serve_trace(tr, table, ServeLoopConfig(**cfg))
    _assert_served_equal(
        out, serve_trace(tr, table, ServeLoopConfig(**cfg, fast_path=False))
    )
    lat = out.latency()
    first, second = tr.arrival < 29.0, tr.arrival >= 30.0
    assert np.nanmean(lat[second]) > 3.0 * np.nanmean(lat[first])


@settings(max_examples=15, deadline=None)
@given(
    rate=st.floats(min_value=5.0, max_value=120.0),
    seed=st.integers(min_value=0, max_value=10_000),
    mb=st.integers(min_value=2, max_value=8),
    sig=st.sampled_from([0.0, 2e-3, 9e-3]),
    admit=st.sampled_from([True, False]),
)
def test_property_fast_path_bit_identical(rate, seed, mb, sig, admit):
    """The vectorized fast path and the scalar event loop are the same
    function: identical fins, sheds, mets, and batch histograms, across
    underload, overload, noisy channels, and both admission policies."""
    tr = make_trace(PoissonProcess(rate, seed=seed), CLASSES, 25.0, seed=seed + 1)
    base = dict(max_batch=mb, admission=admit, seed=seed,
                channel=OffloadChannel(rate_bps=100e6, sigma_s=sig))
    fast = serve_trace(tr, LAT, ServeLoopConfig(**base, fast_path=True))
    slow = serve_trace(tr, LAT, ServeLoopConfig(**base, fast_path=False))
    _assert_served_equal(fast, slow)


@settings(max_examples=15, deadline=None)
@given(
    rate=st.floats(min_value=5.0, max_value=80.0),
    seed=st.integers(min_value=0, max_value=10_000),
    mb=st.integers(min_value=1, max_value=8),
)
def test_property_deterministic_channel_admits_only_winners(rate, seed, mb):
    """With sigma=0 the reliability model is a step function, so admission
    becomes a theorem: every admitted request meets its deadline, always."""
    tr = make_trace(PoissonProcess(rate, seed=seed), CLASSES, 20.0, seed=seed + 1)
    out = serve_trace(
        tr, LAT, ServeLoopConfig(max_batch=mb, channel=CH0, seed=seed)
    )
    assert out.met[~out.shed].all()
    # and the loop conserves requests under any load
    assert int(out.shed.sum()) + int((~out.shed).sum()) == len(tr)


@settings(max_examples=20, deadline=None)
@given(
    slack_scale=st.floats(min_value=0.5, max_value=1.5),
    sig=st.sampled_from([1e-3, 5e-3, 9e-3]),
    target=st.floats(min_value=0.6, max_value=0.999),
)
def test_property_singleton_admission_matches_choose_batch_size(
    slack_scale, sig, target
):
    """For an isolated request the trace loop's margin test IS
    choose_batch_size's b=1 feasibility: both shed or both admit, on either
    side of the required_slack threshold."""
    ch = OffloadChannel(rate_bps=100e6, sigma_s=sig)
    delay = 0.002
    # relative deadline scaled around the exact singleton threshold
    rel_dl = (required_slack(ch, LAT[0], target) + delay) * slack_scale
    cls = (DeadlineClass("c", rel_dl, target=target),)
    tr = Trace(np.array([0.0]), np.array([0]), cls)
    out = serve_trace(
        tr, LAT, ServeLoopConfig(max_batch=8, max_delay_s=delay, channel=ch)
    )
    # slack available once the batch forms (the head waited max_delay)
    expect_admit = (
        choose_batch_size(
            lambda b: LAT[b - 1], rel_dl - delay, ch, target=target, max_batch=1
        )
        == 1
    )
    assert bool(out.shed[0]) == (not expect_admit)


def test_serve_trace_forms_batch_when_queue_fills_mid_wait():
    """The queue reaching max_batch *during* the head's delay wait must form
    the batch at the max_batch-th arrival (BatchingEngine's launch-when-full
    rule), not at the head's full delay budget -- on both code paths."""
    cls = (DeadlineClass("c", 10.0, target=0.9),)
    cfg = dict(max_batch=3, max_delay_s=0.5, admission=False)
    # fills at t=0.2 < 0.0+0.5: one width-3 batch formed at 0.2
    tr = Trace(np.array([0.0, 0.1, 0.2]), np.zeros(3, dtype=np.int64), cls)
    out = serve_trace(tr, LAT, ServeLoopConfig(**cfg))
    assert out.n_batches == 1 and out.batch_size_counts[3] == 1
    assert np.allclose(out.fin, 0.2 + LAT[2])
    _assert_served_equal(
        out, serve_trace(tr, LAT, ServeLoopConfig(**cfg, fast_path=False))
    )
    # the third arrival misses the budget: the head's delay still rules and
    # the late request becomes its own batch
    tr2 = Trace(np.array([0.0, 0.1, 0.9]), np.zeros(3, dtype=np.int64), cls)
    out2 = serve_trace(tr2, LAT, ServeLoopConfig(**cfg))
    assert out2.n_batches == 2
    assert out2.batch_size_counts[2] == 1 and out2.batch_size_counts[1] == 1
    assert np.allclose(out2.fin[:2], 0.5 + LAT[1])
    assert np.allclose(out2.fin[2], 0.9 + 0.5 + LAT[0])
    _assert_served_equal(
        out2, serve_trace(tr2, LAT, ServeLoopConfig(**cfg, fast_path=False))
    )


def _engine_reference(tr, lat, mb, max_delay):
    """Step-by-step BatchingEngine + VirtualClock reference for serve_trace
    (admission off, deterministic channel): submit each arrival at its exact
    arrival instant, launch by eng.ready() gated on a single busy server, and
    charge lat[b-1] of virtual service time per width-b batch.  Returns
    (fin per request, n_batches, batch-size histogram)."""
    clk = VirtualClock()
    eng = BatchingEngine(
        jax.jit(lambda b: b),
        ServeConfig(max_batch=mb, max_delay_s=max_delay, pad_to_max=False),
        clock=clk,
    )
    arr = tr.arrival
    rel = np.array([c.deadline_s for c in tr.classes])[tr.cls]
    n = len(tr)
    fin = np.full(n, np.nan)
    counts = np.zeros(mb + 1, dtype=np.int64)
    n_batches = 0
    i = 0
    free = 0.0
    while i < n or eng.queue:
        now = clk.now()
        while i < n and arr[i] <= now:
            eng.submit(jnp.zeros(()), deadline_s=float(rel[i]))
            i += 1
        if eng.queue and now >= free:
            if eng.ready():
                batch = eng.step()
                b = len(batch)
                t_fin = now + lat[b - 1]
                for r in batch:
                    fin[r.rid - 1] = t_fin  # rids: 1-based submission order
                free = t_fin
                counts[b] += 1
                n_batches += 1
                continue
            exp = eng._oldest_pending().arrival + max_delay
            if exp <= now:
                # fp edge: ready()'s (now - a) >= delay can round an ulp
                # below delay at the nominal expiry a + delay -- crawl ulps
                # until the engine agrees (1-2 iterations), never past it
                clk.advance_to(float(np.nextafter(now, np.inf)))
                continue
        cands = []
        if i < n:
            cands.append(float(arr[i]))
        if eng.queue:
            if free > now:
                # blocked on the busy server: the next decision instant is
                # free (the head's expiry may already be behind us)
                cands.append(free)
            else:
                cands.append(eng._oldest_pending().arrival + max_delay)
        clk.advance_to(min(cands))
    return fin, n_batches, counts


@settings(max_examples=12, deadline=None)
@given(
    rate=st.floats(min_value=20.0, max_value=200.0),
    seed=st.integers(min_value=0, max_value=10_000),
    mb=st.integers(min_value=2, max_value=6),
    delay=st.sampled_from([0.005, 0.02, 0.1]),
    fast=st.sampled_from([True, False]),
)
def test_property_matches_batching_engine_reference(rate, seed, mb, delay, fast):
    """Both serve_trace code paths replicate the live BatchingEngine's
    semantics on random traces -- same batches formed at the same times (full
    -- including filling mid-wait -- or head-delay-expired), same EDF
    membership, same completions.  High rates with small max_batch make the
    full-queue-mid-wait case the dominant regime."""
    tr = make_trace(PoissonProcess(rate, seed=seed), CLASSES, 4.0, seed=seed + 1)
    cfg = ServeLoopConfig(
        max_batch=mb, max_delay_s=delay, admission=False, fast_path=fast
    )
    out = serve_trace(tr, LAT, cfg)
    ref_fin, ref_batches, ref_counts = _engine_reference(tr, LAT, mb, delay)
    assert out.n_batches == ref_batches
    assert np.array_equal(out.batch_size_counts, ref_counts)
    assert np.allclose(out.fin, ref_fin, rtol=0.0, atol=1e-9, equal_nan=True)


def test_serve_trace_offload_noise_is_seeded():
    tr = make_trace(PoissonProcess(30.0, seed=1), CLASSES, 30.0, seed=2)
    a = serve_trace(tr, LAT, ServeLoopConfig(channel=CH, seed=5))
    b = serve_trace(tr, LAT, ServeLoopConfig(channel=CH, seed=5))
    c = serve_trace(tr, LAT, ServeLoopConfig(channel=CH, seed=6))
    _assert_served_equal(a, b)
    assert not np.array_equal(a.fin, c.fin, equal_nan=True)  # noise seed moves fins
    # deterministic channel: seed is inert
    d = serve_trace(tr, LAT, ServeLoopConfig(channel=CH0, seed=5))
    e = serve_trace(tr, LAT, ServeLoopConfig(channel=CH0, seed=99))
    _assert_served_equal(d, e)


def test_serve_trace_flash_crowd_shedding_protects_served_requests():
    """Under a burst at ~3x capacity, shedding keeps admitted requests on
    deadline while the no-shed baseline queues everyone into missing."""
    tr = make_trace(FlashCrowdProcess(10.0, bursts=((10.0, 20.0, 300.0),), seed=4),
                    CLASSES, 60.0, seed=5)
    shed = serve_trace(tr, LAT, ServeLoopConfig(max_batch=8, channel=CH0))
    noshed = serve_trace(
        tr, LAT, ServeLoopConfig(max_batch=8, channel=CH0, admission=False)
    )
    assert shed.stats()["shed_rate"] > 0.2  # the burst forces real shedding
    assert shed.stats()["met_of_admitted"] == 1.0  # sigma=0: admitted == met
    for name in ("premium", "standard", "bulk"):
        assert (
            shed.class_stats()[name]["deadline_met_frac"]
            >= noshed.class_stats()[name]["deadline_met_frac"]
        )
