"""Per-task heterogeneous placement: structure, optimizer, controller, and
property-based losslessness of every placement the engine can emit."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    GTX_1080TI,
    CollabTopology,
    Link,
    PlacementController,
    ReplanConfig,
    ReplanController,
    TaskPlacement,
    place_tasks,
    plan_halp_topology,
    shared_plan_placement,
    simulate_placement,
    simulate_halp,
    vgg16_geom,
)
from repro.core.events import build_multitask_dag
from repro.core.replan import PlanCache
from repro.core.simulator import Sim

NET = vgg16_geom()


def hetero_pool(n: int = 8, slow_links: bool = True) -> CollabTopology:
    scales = (1.0, 1.0, 0.6, 0.6, 0.35, 0.35, 0.2, 0.2, 0.5, 0.9)[:n]
    secs = tuple(f"e{j}" for j in range(1, n + 1))
    platforms = {"e0": GTX_1080TI}
    links = {}
    for s, scale in zip(secs, scales):
        platforms[s] = GTX_1080TI.scaled(scale, f"es x{scale:g}")
        if slow_links and scale < 0.5:
            links[("e0", s)] = Link(10e9)
            links[(s, "e0")] = Link(10e9)
    return CollabTopology(
        host="e0", secondaries=secs, platforms=platforms,
        links=links, default_link=Link(40e9),
    )


# ---------------------------------------------------------------------------
# TaskPlacement structure
# ---------------------------------------------------------------------------


def test_placement_validation():
    pool = hetero_pool(4)
    plan = plan_halp_topology(NET, pool.sub_topology(("e1", "e2")))
    other = plan_halp_topology(NET, pool.sub_topology(("e3", "e4")))
    TaskPlacement(pool=pool, assignments=(("e1", "e2"), ("e3", "e4")), plans=(plan, other))
    with pytest.raises(ValueError, match="more than one task"):
        TaskPlacement(pool=pool, assignments=(("e1", "e2"), ("e1", "e2")), plans=(plan, plan))
    with pytest.raises(ValueError, match="!= assignment"):
        TaskPlacement(pool=pool, assignments=(("e3", "e4"),), plans=(plan,))
    with pytest.raises(ValueError, match="at least one task"):
        TaskPlacement(pool=pool, assignments=(), plans=())


def test_sub_topology_preserves_rates_and_order():
    pool = hetero_pool(6)
    sub = pool.sub_topology(("e5", "e2"))
    assert sub.secondaries == ("e5", "e2")  # caller's order = row order
    assert sub.link_between("e0", "e5").rate_bps == 10e9
    assert sub.link_between("e0", "e2").rate_bps == 40e9
    with pytest.raises(ValueError):
        pool.sub_topology(("e1", "nope"))
    with pytest.raises(ValueError, match="duplicate"):
        pool.sub_topology(("e1", "e1"))


def test_build_multitask_dag_validates():
    pool = hetero_pool(4)
    p1 = plan_halp_topology(NET, pool.sub_topology(("e1", "e2")))
    with pytest.raises(ValueError, match="at least one"):
        build_multitask_dag(Sim(), [], pool)
    foreign = plan_halp_topology(
        NET, CollabTopology.symmetric(GTX_1080TI, Link(40e9), host="h0")
    )
    with pytest.raises(ValueError, match="host"):
        build_multitask_dag(Sim(), [p1, foreign], pool)


def test_multitask_dag_models_contention():
    """Two tasks on the same physical pair must take longer than one (shared
    secondaries + host), but less than twice (pipelining); two tasks on
    disjoint pairs must beat two tasks on one shared pair."""
    pool = hetero_pool(4, slow_links=False)
    pair_a = plan_halp_topology(NET, pool.sub_topology(("e1", "e2")))
    pair_b = plan_halp_topology(NET, pool.sub_topology(("e3", "e4")))

    def makespan(plans):
        sim = Sim()
        heads = build_multitask_dag(sim, plans, pool)
        sim.run()
        return max(sim.finish_of(h) for h in heads)

    one = makespan([pair_a])
    shared = makespan([pair_a, pair_a])
    disjoint = makespan([pair_a, pair_b])
    assert one < shared < 2.0 * one
    assert disjoint < shared


def test_single_task_multitask_dag_matches_simulate_halp():
    """For one task the physical-pool DAG must price exactly like the
    classic per-task-clone DAG (same plan, same rates -- only resource
    names differ)."""
    pool = hetero_pool(2)
    sub = pool.sub_topology(("e1", "e2"))
    plan = plan_halp_topology(NET, sub)
    sim = Sim()
    heads = build_multitask_dag(sim, [plan], pool)
    sim.run()
    ours = max(sim.finish_of(h) for h in heads)
    ref = simulate_halp(NET, topology=sub, plan=plan)["total"]
    assert ours == pytest.approx(ref, rel=1e-12)


# ---------------------------------------------------------------------------
# placement optimizer
# ---------------------------------------------------------------------------


def test_place_tasks_structure_and_quality():
    pool = hetero_pool(8)
    res = place_tasks(NET, pool, 4, optimize_final=False, swap_rounds=2)
    placement = res.placement
    assert placement.n_tasks == 4
    assigned = [s for g in placement.assignments for s in g]
    assert sorted(assigned) == sorted(pool.secondaries)  # partition, no reuse
    assert all(len(g) >= 2 for g in placement.assignments)
    # capacity balance: no task gets both fast ESs
    for g in placement.assignments:
        assert not {"e1", "e2"} <= set(g)
    # the joint score the result reports is reproducible
    sim = simulate_placement(NET, placement)
    assert res.makespan == pytest.approx(sim["total"], rel=1e-12)
    assert res.avg_delay == pytest.approx(sim["avg_delay"], rel=1e-12)


def test_place_tasks_beats_shared_plan_baseline():
    pool = hetero_pool(8)
    shared = simulate_placement(NET, shared_plan_placement(NET, pool, 4))
    res = place_tasks(NET, pool, 4, optimize_final=False, swap_rounds=2)
    assert res.avg_delay < shared["avg_delay"]
    assert res.makespan < shared["total"]


def test_place_tasks_rejects_bad_inputs():
    pool = hetero_pool(4)
    with pytest.raises(ValueError, match="need >="):
        place_tasks(NET, pool, 3)
    with pytest.raises(ValueError, match="objective"):
        place_tasks(NET, pool, 2, objective="latency")
    with pytest.raises(ValueError, match="at least one task"):
        place_tasks(NET, pool, 0)


def test_shared_plan_placement_is_pool_order_equal_split():
    pool = hetero_pool(8)
    placement = shared_plan_placement(NET, pool, 4)
    assert placement.assignments == (
        ("e1", "e2"), ("e3", "e4"), ("e5", "e6"), ("e7", "e8")
    )
    # equal split: first layer segments of both secondaries within one row
    for plan in placement.plans:
        a, b = (plan.parts[0].out[s].rows for s in plan.secondary_slots)
        assert abs(a - b) <= 8  # equal ratios, alignment rounding only


# ---------------------------------------------------------------------------
# controller + serving integration
# ---------------------------------------------------------------------------


def _controller(pool, **options):
    opts = dict(optimize_final=False, swap_rounds=1)
    opts.update(options)
    return PlacementController(
        NET, pool, ReplanConfig(n_tasks=2, max_rounds=2),
        placement_options=opts,
    )


def test_placement_controller_replaces_on_bucket_switch():
    pool = hetero_pool(4)
    ctl = _controller(pool)
    first = ctl.placement_for_epoch()
    assert ctl.optimizer_calls == 1
    # stable channel: cached, no extra optimisation
    again = ctl.placement_for_epoch()
    assert again is first
    assert ctl.optimizer_calls == 1
    # e1's link collapses 40 -> 4 Gbps: bucket switch after hysteresis
    for _ in range(4):
        ctl.observe_transfer("e1", "e0", 1e6, 8.0 * 1e6 / 4e9)
        ctl.observe_transfer("e0", "e1", 1e6, 8.0 * 1e6 / 4e9)
    ctl.placement_for_epoch()
    switched = ctl.placement_for_epoch()
    assert ctl.replans >= 1 and ctl.optimizer_calls == 2
    assert isinstance(switched, TaskPlacement)


def test_placement_controller_replaces_on_compute_straggler():
    """A straggling ES moves its compute bucket: the controller re-places all
    tasks against the degraded platform, and the straggler's assignment load
    shrinks (here: e1, nominally the fastest ES, collapses to 0.15x and the
    new placement no longer leans on it)."""
    pool = hetero_pool(4)
    ctl = _controller(pool)
    first = ctl.placement_for_epoch()
    t_e1 = next(t for t, g in enumerate(first.assignments) if "e1" in g)
    rows_before = sum(
        pt.out["e1"].rows for pt in first.plans[t_e1].parts if "e1" in pt.out
    )
    nom = pool.platform_of("e1").eff_flops
    for _ in range(4):  # past the hysteresis
        ctl.observe_compute("e1", 1e9, 1e9 / (0.15 * nom))
        ctl.placement_for_epoch()
    switched = ctl.placement
    # the gradual EWMA may cross more than one band on its way down
    assert ctl.replans >= 1 and ctl.optimizer_calls >= 2
    assert switched is not first
    # the degraded platform reaches the placement engine...
    est = ctl.estimated_topology()
    # EWMA after 4 samples of 0.15x sits near 0.26x; band rep within a band
    assert est.platform_of("e1").eff_flops < 0.35 * nom
    assert est.platform_of("e2").eff_flops == pool.platform_of("e2").eff_flops
    # ...and the straggler carries fewer rows than it did as the fastest ES
    t_e1b = next(t for t, g in enumerate(switched.assignments) if "e1" in g)
    rows_after = sum(
        pt.out["e1"].rows for pt in switched.plans[t_e1b].parts if "e1" in pt.out
    )
    assert rows_after < rows_before


def test_placement_controller_serving_surface():
    from repro.core.reliability import OffloadChannel
    from repro.runtime.serve import plan_aware_batch_size

    pool = hetero_pool(4)
    ctl = _controller(pool)
    ctl.placement_for_epoch()
    # contention pricing: a batch wrapping onto the same secondaries queues
    lat2, lat4 = ctl.predicted_latency(2), ctl.predicted_latency(4)
    assert lat2 < lat4 < 3.0 * lat2
    ctl.observe_batch_latency(2, lat2 * 1.5)
    assert ctl.predicted_latency(2) > lat2  # calibration folded in
    b = plan_aware_batch_size(
        ctl, deadline_s=4.0 / 30.0,
        channel=OffloadChannel(rate_bps=60e6, sigma_s=5e-3), max_batch=8,
    )
    assert 1 <= b <= 8
    with pytest.raises(TypeError, match="placement_for_epoch"):
        ctl.plan_for_epoch()


def test_controller_kinds_share_one_cache_without_collisions():
    pool = hetero_pool(4)
    cache = PlanCache()
    plan_ctl = ReplanController(NET, pool, ReplanConfig(n_tasks=2, max_rounds=1), cache=cache)
    place_ctl = PlacementController(
        NET, pool, ReplanConfig(n_tasks=2, max_rounds=1), cache=cache,
        placement_options=dict(optimize_final=False, swap_rounds=1),
    )
    plan_ctl.plan_for_epoch()
    place_ctl.placement_for_epoch()
    assert len(cache) == 2  # namespaced by _cache_kind: no overwrite
    assert plan_ctl.optimizer_calls == 1 and place_ctl.optimizer_calls == 1


# ---------------------------------------------------------------------------
# property: every placement executes bit-exact (losslessness)
# ---------------------------------------------------------------------------


@given(
    n_pool=st.integers(4, 6),
    n_tasks=st.integers(1, 2),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=4, deadline=None)
def test_placement_lossless_property(n_pool, n_tasks, seed):
    """Any TaskPlacement over a random feasible heterogeneous pool executes
    bit-exact vs the single-device forward, for every task (run_plan
    reconstructs each layer input strictly from owned rows + plan messages,
    so success proves the message algebra of every per-task plan)."""
    import random

    import jax
    import numpy as np
    from repro.models import vgg
    from repro.spatial import run_plan

    rng = random.Random(seed)
    cfg = vgg.VGGConfig(img_res=64, width_mult=0.25, num_classes=10)
    net = cfg.geom()
    secs = tuple(f"e{j}" for j in range(1, n_pool + 1))
    platforms = {"e0": GTX_1080TI}
    links = {}
    for s in secs:
        platforms[s] = GTX_1080TI.scaled(rng.uniform(0.2, 1.0), f"r{s}")
        rate = rng.choice((10e9, 25e9, 40e9))
        links[("e0", s)] = Link(rate)
        links[(s, "e0")] = Link(rate)
    pool = CollabTopology(
        host="e0", secondaries=secs, platforms=platforms,
        links=links, default_link=Link(40e9),
    )
    res = place_tasks(net, pool, n_tasks, optimize_final=False, swap_rounds=1)

    params = vgg.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 64, 64, 3))
    ref = vgg.features(params, cfg, x)
    for plan in res.placement.plans:
        out = run_plan(plan, params["features"], vgg.apply_layer, x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
        )
