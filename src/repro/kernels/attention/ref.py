"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q [B,H,T,D], k/v [B,H,S,D] -> [B,H,T,D] (f32 math)."""
    b, h, t, d = q.shape
    s = k.shape[2]
    logits = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(d)
    if causal:
        mask = jnp.arange(t)[:, None] >= jnp.arange(s)[None, :]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32)).astype(q.dtype)
