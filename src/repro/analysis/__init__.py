"""Static plan/DAG/kernel verification (no device, no execution).

The paper's correctness guarantee -- receptive-field-aware partitioning keeps
distributed inference bit-identical to local inference -- is enforced
dynamically by the ``run_plan`` losslessness tests.  This package proves the
same invariant surface *by analysis*, in milliseconds per plan:

* :mod:`~repro.analysis.plan_check` -- row coverage, receptive-field
  exactness, halo algebra/reach, message legality, auto-reduce monotonicity,
  scheme-stage legality, head divisibility (pure integer arithmetic; no JAX);
* :mod:`~repro.analysis.dag_check` -- acyclicity of dependency + resource-FIFO
  edges (static deadlock detection), transfer endpoint locality, orphan
  transfers, template-vs-scalar-builder duration audits;
* :mod:`~repro.analysis.kernel_check` -- ``jax.eval_shape`` abstract
  evaluation of the fused Pallas ``halo_conv2d`` path (support-predicate
  agreement, output shapes, remainder tiles) before ``shard_map`` tracing;
* :mod:`~repro.analysis.keying_lint` -- AST enforcement of the
  config-fingerprint partition (every ``ReplanConfig`` field keys the plan
  store or carries a justified exclusion) and ``PlanStore.get``'s row vetoes.

Wired in as load-bearing infrastructure: ``PlanStore.get`` runs
:func:`check_plan` on deserialized rows before serving them,
``optimize_plan(verify=True)`` / ``run_plan(verify=True)`` gate on it, and
``tools/check.py`` runs all four analyzers over the warm-store artifact and
the benchmark configs in CI.  ``docs/analysis.md`` catalogues every invariant
with its paper-equation or code-contract origin.
"""
from .dag_check import check_dag, check_template
from .findings import AnalysisError, Finding, Report
from .keying_lint import check_keying
from .kernel_check import check_kernel_geometry, check_plan_kernels
from .plan_check import check_plan

__all__ = [
    "AnalysisError",
    "Finding",
    "Report",
    "check_dag",
    "check_keying",
    "check_kernel_geometry",
    "check_plan",
    "check_plan_kernels",
    "check_template",
]
