"""jit'd wrapper: GQA-aware flash attention entry point."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import flash_attention


def gqa_flash(
    q: jax.Array,  # [B, T, H, D]  (model layout)
    k: jax.Array,  # [B, S, Hkv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Grouped-query flash attention: broadcasts KV heads to Q heads and runs
    the Pallas kernel in [B, H, T, D] layout."""
    b, t, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
    qb = 128 if t % 128 == 0 else max(x for x in (64, 32, 16, 8, 4, 2, 1) if t % x == 0)
    kb = 128 if k.shape[1] % 128 == 0 else max(
        x for x in (64, 32, 16, 8, 4, 2, 1) if k.shape[1] % x == 0
    )
    out = flash_attention(
        qt, kt, vt, causal=causal, q_block=qb, kv_block=kb, interpret=interpret
    )
    return out.transpose(0, 2, 1, 3)
