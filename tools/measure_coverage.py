"""Measure line coverage of src/repro without coverage.py installed.

The CI coverage gate (``pytest --cov=repro --cov-fail-under=...``) needs a
pinned floor, but the development container ships neither ``coverage`` nor
``pytest-cov``.  This tool approximates coverage.py's statement coverage with
a ``sys.settrace`` tracer restricted to ``src/repro`` files: executed lines
are collected per file, executable lines are recovered from the compiled
code objects (``dis.findlinestarts``, recursively), and the ratio is printed
as JSON.  Differences vs coverage.py are small (a few tenths of a percent,
e.g. around ``TYPE_CHECKING`` blocks), which is why the CI floor is pinned a
few points *below* the number printed here.

Usage::

    python tools/measure_coverage.py [pytest args...]

Runs the full tier-1 suite by default; pass a subset of test files to get a
cheaper lower bound (a subset can only under-count coverage).
"""
from __future__ import annotations

import dis
import json
import pathlib
import sys
import threading

ROOT = pathlib.Path(__file__).resolve().parents[1]
PREFIX = str(ROOT / "src" / "repro") + "/"

executed: dict[str, set[int]] = {}


def _tracer(frame, event, arg):
    filename = frame.f_code.co_filename
    if not filename.startswith(PREFIX):
        return None
    lines = executed.setdefault(filename, set())
    lines.add(frame.f_lineno)

    def local(frame, event, arg):
        if event == "line":
            lines.add(frame.f_lineno)
        return local

    return local


def _code_lines(code) -> set[int]:
    lines = {line for _, line in dis.findlinestarts(code) if line is not None}
    for const in code.co_consts:
        if hasattr(const, "co_code"):
            lines |= _code_lines(const)
    return lines


def main(argv: list[str]) -> int:
    sys.path.insert(0, str(ROOT / "src"))
    import pytest

    sys.settrace(_tracer)
    threading.settrace(_tracer)
    rc = pytest.main(["-q", "-p", "no:cacheprovider", *argv])
    sys.settrace(None)
    threading.settrace(None)

    total = covered = 0
    per_file = {}
    for path in sorted((ROOT / "src" / "repro").rglob("*.py")):
        code = compile(path.read_text(), str(path), "exec")
        lines = _code_lines(code)
        hit = len(lines & executed.get(str(path), set()))
        per_file[str(path.relative_to(ROOT))] = (hit, len(lines))
        total += len(lines)
        covered += hit
    print(json.dumps(dict(
        pytest_exit=int(rc),
        covered=covered,
        total=total,
        pct=round(100.0 * covered / total, 2),
        worst=sorted(per_file.items(), key=lambda kv: kv[1][0] / max(1, kv[1][1]))[:10],
    ), indent=2))
    return int(rc)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
