import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production meshes and extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --cell train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all             # 40 cells, single-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod # 2-pod pass

Results (memory analysis, FLOPs/bytes, per-collective byte totals) are cached
as JSON under benchmarks/dryrun_results/ -- benchmarks/roofline.py renders the
EXPERIMENTS.md tables from them.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

_CACHE_DIR = "/tmp/jax_compile_cache"
jax.config.update("jax_compilation_cache_dir", _CACHE_DIR)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 2.0)

from repro.configs import get, list_archs
from repro.configs.steps import build
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.parallel.sharding import (
    input_shardings,
    param_shardings,
    state_shardings,
)
from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every dtype[dims] occurrence in an HLO type string."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective result-shape bytes (per device), summed over all call
    sites.  ``-start`` variants are counted; their ``-done`` twins are not."""
    per = {c: 0 for c in _COLLECTIVES}
    count = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        if not (s.startswith("%") or s.startswith("ROOT")):
            continue
        if "-done" in s:
            continue
        m = re.search(r"=\s+(\([^)]*\)|\S+)\s+([\w-]+)", s)
        if not m:
            continue
        op = m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base in per:
            per[base] += _shape_bytes(m.group(1))
            count[base] += 1
    per["total"] = sum(per[c] for c in _COLLECTIVES)
    per["counts"] = count
    return per


def _out_shardings(bundle, arch, cell, mesh, state_sh, in_sh):
    dp = dp_axes(mesh)
    dpx = dp if len(dp) > 1 else dp[0]

    def rep():
        return NamedSharding(mesh, P())

    if bundle.kind == "train":
        metrics = jax.eval_shape(bundle.fn, bundle.state, *bundle.input_list)[1]
        return (state_sh, jax.tree_util.tree_map(lambda _: rep(), metrics))
    if bundle.kind == "prefill":
        return NamedSharding(mesh, P(None, None, "model"))
    if bundle.kind == "decode":
        logits = NamedSharding(mesh, P(None, "model"))
        return (logits, in_sh["cache"])
    if bundle.kind == "gen":
        return in_sh["latents"]
    if bundle.kind == "serve":
        return NamedSharding(mesh, P())
    return None


def run_cell(
    arch_name: str,
    cell_name: str,
    multi_pod: bool,
    verbose: bool = True,
    variant: str = "base",
) -> dict:
    from repro.parallel import hints
    from repro.parallel.variants import set_variant

    v = set_variant(variant)
    arch = get(arch_name)
    cell = arch.cells[cell_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch_name,
        "cell": cell_name,
        "mesh": mesh_name,
        "family": arch.family,
        "variant": variant,
    }
    if cell.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes(mesh)
    dpx = dp if len(dp) > 1 else dp[0]
    # NOTE: anchoring the MoE dispatch boundary (moe_tokens/moe_slots hints)
    # was measured and REFUTED -- GSPMD implemented the forced reshard worse
    # than its own choice (deepseek bound 194 s -> 302 s); the hint names stay
    # in the model as no-ops.  See EXPERIMENTS.md §Perf iteration 3.
    if v.seq_shard_activations:
        hints.set_rules(
            {"lm_residual": NamedSharding(mesh, P(dpx, "model", None))}
        )
    elif v.constrain_residual:
        hints.set_rules(
            {"lm_residual": NamedSharding(mesh, P(dpx, None, None))}
        )
    else:
        hints.clear_rules()
    bundle = build(arch, cell_name)
    in_sh = input_shardings(bundle.inputs, arch, cell, mesh)
    if bundle.kind == "train":
        state_sh = state_shardings(bundle.state, arch, mesh)
    else:
        state_sh = param_shardings(bundle.state, arch, mesh)
    out_sh = _out_shardings(bundle, arch, cell, mesh, state_sh, in_sh)

    jitted = jax.jit(
        bundle.fn,
        in_shardings=(state_sh, *[in_sh[k] for k in bundle.inputs]),
        out_shardings=out_sh,
        donate_argnums=(0,) if bundle.donate_state else (),
    )
    with mesh:
        lowered = jitted.lower(bundle.state, *bundle.input_list)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        rec["cost"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0))),
        }
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo)
    rec["hlo_bytes"] = len(hlo)
    # while-trip-corrected accounting (XLA's cost_analysis counts scanned layer
    # stacks once; see repro.launch.hlo_cost) -- the roofline source of truth.
    hc = analyze_hlo(hlo)
    rec["hlo_cost"] = {
        "flops": hc.flops,
        "bytes_accessed": hc.bytes_accessed,
        "collective_bytes": hc.collective_bytes,
        "per_collective": hc.per_collective,
        "collective_counts": hc.collective_counts,
        "unknown_trip_whiles": hc.unknown_trip_whiles,
    }
    try:  # archive compressed HLO for offline perf iteration
        import zstandard as zstd

        hdir = RESULTS_DIR / "hlo"
        hdir.mkdir(parents=True, exist_ok=True)
        sfx = "" if rec.get("variant", "base") == "base" else f"__{rec['variant']}"
        name = f"{rec['arch']}__{rec['cell']}__{rec['mesh']}{sfx}.hlo.zst"
        (hdir / name).write_bytes(zstd.ZstdCompressor(level=6).compress(hlo.encode()))
    except Exception:
        pass
    rec["status"] = "ok"

    if verbose:
        print(f"--- {arch_name} / {cell_name} / {mesh_name} ---")
        print(f"lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print("memory_analysis:", rec["memory"])
        print("cost_analysis:", rec["cost"])
        print("collective bytes/device:", {k: v for k, v in rec["collectives"].items() if k != "counts"})
    return rec


def save(rec: dict):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    suffix = "" if rec.get("variant", "base") == "base" else f"__{rec['variant']}"
    name = f"{rec['arch']}__{rec['cell']}__{rec['mesh']}{suffix}.json"
    (RESULTS_DIR / name).write_text(json.dumps(rec, indent=2))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-cached", action="store_true")
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()

    assigned = [a for a in list_archs() if a != "vgg16"]
    targets = []
    if args.all:
        for a in assigned:
            for c in get(a).cells:
                targets.append((a, c))
    else:
        cells = [args.cell] if args.cell else list(get(args.arch).cells)
        targets = [(args.arch, c) for c in cells]

    failures = []
    for a, c in targets:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        suffix = "" if args.variant == "base" else f"__{args.variant}"
        cache = RESULTS_DIR / f"{a}__{c}__{mesh_name}{suffix}.json"
        if args.skip_cached and cache.exists():
            st = json.loads(cache.read_text()).get("status")
            if st in ("ok", "skipped"):
                print(f"cached: {a}/{c}/{mesh_name} ({st})")
                continue
        try:
            rec = run_cell(a, c, args.multi_pod, variant=args.variant)
        except Exception as e:
            rec = {
                "arch": a, "cell": c,
                "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-3000:],
            }
            print(f"ERROR {a}/{c}: {e}")
            failures.append((a, c))
        save(rec)
    if failures:
        print("FAILURES:", failures)
        return 1
    print("dry-run complete: all cells ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
