from .pipeline import DiffusionStream, ImageStream, TokenStream, device_batch
