"""Serving launcher: HALP-partitioned VGG-16 (the paper's workload) or any
vision arch, through the deadline-aware batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch vgg16 --requests 32
    PYTHONPATH=src python -m repro.launch.serve --arch vit-l16 --requests 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vgg16")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=500.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get
    from repro.runtime.serve import BatchingEngine, ServeConfig

    arch = get(args.arch)
    cfg = arch.smoke_cfg
    params = arch.module.init(jax.random.PRNGKey(0), cfg)

    if args.arch == "vgg16":
        from repro.core import plan_halp
        from repro.models import vgg
        from repro.spatial import run_plan

        plan = plan_halp(cfg.geom(), overlap_rows=4)

        def model(batch):
            feats = run_plan(plan, params["features"], vgg.apply_layer, batch)
            return vgg.head(params, feats)

        print(f"serving vgg16 through the HALP plan ({len(plan.parts)} layers, "
              f"3 collaborating segments)")
    else:
        def model(batch):
            return arch.module.apply(params, cfg, batch)

    fn = jax.jit(model)
    res = cfg.img_res
    eng = BatchingEngine(fn, ServeConfig(max_batch=args.max_batch))
    key = jax.random.PRNGKey(1)
    t0 = time.monotonic()
    for i in range(args.requests):
        key, k = jax.random.split(key)
        eng.submit(jax.random.normal(k, (res, res, 3)), deadline_s=args.deadline_ms / 1e3)
    stats = eng.run_until_drained()
    wall = time.monotonic() - t0
    print(f"requests={stats['completed']} deadline_met={stats['deadline_met_frac']:.3f} "
          f"p50={stats['p50_latency_s']*1e3:.1f}ms p99={stats['p99_latency_s']*1e3:.1f}ms "
          f"throughput={stats['completed']/wall:.1f} req/s")


if __name__ == "__main__":
    main()
