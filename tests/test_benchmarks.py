"""Benchmark-level reproduction assertions: our numbers vs. the paper's."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import paper_tables


def test_table2_reproduction_quality():
    """HALP throughput within 8% of the paper at every (platform, rate)."""
    out = paper_tables.table2_throughput()
    for (plat, rate), (ours, paper) in out.items():
        assert abs(ours - paper) / paper < 0.08, (plat, rate, ours, paper)


def test_fig6_speedup_band():
    """Single-task x-speedup covers the paper's claim (1.7-2.0x or better)."""
    out = paper_tables.fig6_single_task()
    for (plat, rate), (speedup, rho) in out.items():
        assert speedup >= 1.7, (plat, rate, speedup)
        assert 0 < rho < 1


def test_fig7_multi_task_band():
    """4-task average-delay speedup in/above the paper's 1.67-1.81x band."""
    out = paper_tables.fig7_multi_task()
    for (plat, rate), speedup in out.items():
        assert 1.55 <= speedup <= 2.3, (plat, rate, speedup)


def test_table3_reproduction_quality():
    """Reliability within 2e-3 of the paper at the paper-implied constants."""
    out = paper_tables.table3_reliability()
    for key, (ours, paper) in out.items():
        assert abs(ours - paper) < 2e-3, (key, ours, paper)


def test_table4_optimizer_beats_equal_split():
    """The heterogeneous-cluster optimizer must clearly beat the naive equal
    split (acceptance criterion of the N-way refactor)."""
    out = paper_tables.table4_heterogeneous_optimizer()
    assert out["optimized"] < 0.75 * out["equal"]
    assert out["gain"] > 0.3


def test_hetero_sweep_monotone_gain():
    """Optimizer gain grows with cluster asymmetry; N-way scaling helps."""
    from benchmarks import hetero_sweep

    pairs = hetero_sweep.sweep_heterogeneous_pairs()
    gains = [v["gain"] for v in pairs.values()]
    assert all(b >= a - 0.02 for a, b in zip(gains, gains[1:])), gains
    nway = hetero_sweep.sweep_nway_scaling()
    assert nway[3]["speedup"] > nway[2]["speedup"]


def test_replan_sweep_acceptance():
    """The cached adaptive planner must strictly beat the static nominal-rate
    plan on a time-variant trace (reliability at the 133.3 ms deadline and
    mean makespan), keep the steady-state cache hit rate >= 90%, and every
    replanned plan must execute losslessly via run_plan."""
    from benchmarks import replan_sweep

    out = replan_sweep.run_sweep(include_always=False, max_verify_plans=3)
    static, cached = out["static"], out["cached"]
    assert cached["mean_makespan"] < static["mean_makespan"]
    assert cached["mean_reliability"] > static["mean_reliability"]
    assert cached["min_reliability"] > static["min_reliability"]
    assert cached["steady_state_hit_rate"] >= 0.90
    # the cache amortises: an order of magnitude fewer optimizer calls than
    # the always-replan policy would need (one per epoch)
    assert cached["optimizer_calls"] <= out["n_epochs"] // 5
    assert out["plans_verified_lossless"] == 3


def test_straggler_sweep_acceptance():
    """Joint compute+link adaptation must beat the link-only controller by a
    pinned margin on mean makespan under a straggling secondary (with every
    joint-controller plan verified lossless via run_plan), and must serve
    plans *identical* to the link-only controller when compute never drifts
    (the nominal-anchored compute bands make adaptivity free until a
    straggler appears)."""
    from benchmarks import straggler_sweep

    out = straggler_sweep.run_sweep(n_epochs=40, max_verify_plans=3)
    link_only, joint = out["link_only"], out["joint"]
    # the pinned straggler margin (measured ~21% at 40 epochs, ~28% at 140)
    assert out["joint_vs_link_only_gain"] >= 0.10, out["joint_vs_link_only_gain"]
    assert joint["mean_makespan"] < link_only["mean_makespan"]
    assert joint["max_makespan"] < link_only["max_makespan"]
    assert joint["mean_reliability"] >= link_only["mean_reliability"]
    assert joint["min_reliability"] >= link_only["min_reliability"]
    # compute-blind control is no better than no control here: the channel
    # barely moves the makespan, the straggler dominates it
    assert link_only["mean_makespan"] > 0.95 * out["static"]["mean_makespan"]
    # equality regression: no compute drift -> same plans, same makespans
    assert out["nodrift_plans_equal"] is True
    assert out["nodrift_makespans_equal"] is True
    a_replans, b_replans = out["nodrift_replans"]
    assert a_replans == b_replans  # same link-bucket switches, nothing more
    assert out["plans_verified_lossless"] == 3


def test_spatial_calibration_acceptance():
    """Measured-kernel schedule composition must show the fused kernel's halo
    overlap winning over the unfused exchange-then-compute schedule, the
    capacity-weighted split winning over the equal split on the skewed mesh,
    and the measured (es, flops, elapsed) samples -- round-tripped through
    ComputeRateEstimator -- must pull the DES prediction error far below the
    nominal-rate prediction."""
    from benchmarks import spatial_calibration

    out = spatial_calibration.run_all(smoke=True, out_path=None)
    # fused hides the halo latency behind interior compute: strictly faster
    assert out["fused_speedup"] >= 1.02, out["fused_speedup"]
    # weighted split keeps the slow shard from straggling (caps 1.0..0.35)
    assert out["weighted_speedup"] >= 1.2, out["weighted_speedup"]
    assert sum(out["weighted_heights"]) == sum(out["equal_heights"])
    assert max(out["weighted_heights"]) > max(out["equal_heights"])
    # every conv layer was actually executed and timed on both engines
    convs = [L for L in out["layers"] if L["kind"] != "pool"]
    assert convs and all(L["lax_s"] > 0 and L["pallas_s"] > 0 for L in convs)
    # calibration: measured samples through ComputeRateEstimator must beat
    # the (deliberately wrong) nominal rates by a wide margin
    assert out["err_calibrated"] < 0.5 * out["err_nominal"], (
        out["err_calibrated"], out["err_nominal"])
    assert out["err_calibrated"] < 0.35, out["err_calibrated"]


def test_multitask_placement_acceptance():
    """Per-task heterogeneous placement must strictly beat the paper's
    shared-plan deployment on the same shared-contention DES -- mean per-task
    delay AND batch makespan -- with every plan of both deployments verified
    lossless via run_plan (acceptance criteria of the placement engine)."""
    from benchmarks import multitask_placement

    out = multitask_placement.run_comparison(swap_rounds=2, optimize_final=False)
    shared, per_task = out["shared"], out["per_task"]
    assert per_task["avg_delay"] < shared["avg_delay"]
    assert per_task["makespan"] < shared["makespan"]
    # the heterogeneous pool is skewed enough that capacity-aware grouping
    # alone buys a large margin; pin a conservative floor on it
    assert out["gain_avg"] > 0.25, out["gain_avg"]
    # 4 per-task plans + 4 shared-baseline plans, all bit-compatible
    assert out["plans_verified_lossless"] == 8


def test_planner_speed_acceptance():
    """The batched planning engine must return plans *equal* to the scalar
    path in every scenario (shared search loop, bit-identical pricing) at a
    >= 5x median speedup floor.  Full runs track the >= 10x single-task
    optimize target in BENCH_planner.json; the smoke floor absorbs CI noise."""
    from benchmarks import planner_speed

    out = planner_speed.run_all(smoke=True, out_path=None)
    for name, sc in out["scenarios"].items():
        assert sc["plans_equal"], f"{name}: engines returned different plans"
        assert sc["speedup"] >= 5.0, (name, sc["speedup"])


def test_serve_sweep_acceptance():
    """The serving pipeline under a flash crowd: admission shedding must keep
    every class's deadline-met fraction -- premium above all -- at or above
    the accept-everything baseline, shed a real fraction during the burst,
    and the DES latency table driving admission must be positive and
    non-decreasing in batch width."""
    from benchmarks import serve_sweep

    out = serve_sweep.run_sweep(smoke=True)
    lat = out["lat_table_des"]
    assert all(v > 0 for v in lat)
    assert all(b >= a for a, b in zip(lat, lat[1:])), lat
    # controller's plan-aware curve prices the same cluster: same ballpark
    ratio = out["lat_table_controller"][0] / lat[0]
    assert 0.5 < ratio < 2.0, ratio
    fc = out["processes"]["flash_crowd"]
    assert out["flash_premium_met_shed"] >= out["flash_premium_met_noshed"]
    for cls in ("premium", "standard", "bulk"):
        assert (
            fc["shed"]["classes"][cls]["deadline_met_frac"]
            >= fc["noshed"]["classes"][cls]["deadline_met_frac"]
        ), cls
    assert fc["shed"]["overall"]["shed_rate"] > 0.05
    assert fc["noshed"]["overall"]["shed_rate"] == 0.0
    # off-burst load is comfortable: steady Poisson meets ~everything
    po = out["processes"]["poisson"]
    assert po["shed"]["overall"]["deadline_met_frac"] > 0.99


def test_serve_bench_artifact_floors():
    """The committed full-run artifact must cover >= 10^6 simulated requests
    across the three arrival processes and carry the tail/attainment/shed
    fields per process x policy (the PR's acceptance floor)."""
    import json

    path = Path(__file__).resolve().parents[1] / "BENCH_serve.json"
    if not path.exists():
        pytest.skip("BENCH_serve.json not committed yet")
    out = json.loads(path.read_text())
    assert out["n_total"] >= 1_000_000, out["n_total"]
    assert set(out["processes"]) == {"poisson", "diurnal", "flash_crowd"}
    for rec in out["processes"].values():
        for policy in ("shed", "noshed"):
            o = rec[policy]["overall"]
            for k in ("p99_latency_s", "p999_latency_s", "deadline_met_frac",
                      "shed_rate", "completed"):
                assert k in o, (policy, k)
            assert o["p999_latency_s"] >= o["p99_latency_s"] >= 0.0
            assert set(rec[policy]["classes"]) == {"premium", "standard", "bulk"}
    assert out["flash_premium_met_shed"] >= out["flash_premium_met_noshed"]


def test_planstore_bench_acceptance():
    """Warm restart against a populated PlanStore must serve the whole drift
    trace with ZERO optimizer calls and bit-identical plans/makespans to the
    cold run, and a changed optimizer config must force re-optimisation (the
    tentpole acceptance criteria of the persistent plan store)."""
    from benchmarks import planstore_bench

    out = planstore_bench.run_all(smoke=True, out_path=None)
    assert out["warm_optimizer_calls"] == 0
    assert out["plans_bit_identical"] is True
    assert out["makespans_bit_identical"] is True
    assert out["warm"]["store_hits"] == out["cold"]["optimizer_calls"]
    assert out["reconfigured_reoptimized"] is True
    assert out["reconfigured"]["store_hits"] == 0  # never serves a stale plan
    # the restart speedup is the point: store read vs full optimisation
    assert out["warm_first_plan_speedup"] >= 5.0, out["warm_first_plan_speedup"]
    # drift really exercised the lattice (several operating points visited)
    assert out["distinct_operating_points"] >= 5


def test_planstore_bench_artifact_floors():
    """The committed full-run artifact must carry the warm-restart claims at
    full trace length (the PR's acceptance floor)."""
    import json

    path = Path(__file__).resolve().parents[1] / "BENCH_planstore.json"
    if not path.exists():
        pytest.skip("BENCH_planstore.json not committed yet")
    out = json.loads(path.read_text())
    assert out["n_epochs"] >= 100
    assert out["warm_optimizer_calls"] == 0
    assert out["plans_bit_identical"] is True
    assert out["makespans_bit_identical"] is True
    assert out["reconfigured_reoptimized"] is True
    assert out["warm_first_plan_speedup"] >= 10.0
    assert out["cold"]["optimizer_calls"] >= 20  # real lattice coverage
    assert out["warm"]["store_hits"] == out["cold"]["optimizer_calls"]
    assert out["warm"]["store_entries"] == out["cold"]["store_entries"]


def test_scheme_sweep_acceptance():
    """The joint per-stage scheme search must never lose to halo-only
    planning on any grid cell (it is seeded at the halo-only optimum), must
    cut the makespan by >= 10% on at least one cell (the attention model,
    where halo partitioning cannot apply and head splits can), and every
    cell must carry per-stage comm-byte accounting for both plans."""
    from benchmarks import scheme_sweep

    out = scheme_sweep.run_all(smoke=True, out_path=None)
    assert set(out["cells"]) == {
        "vgg16/sym", "vgg16/skew", "vit_l16/sym", "vit_l16/skew"
    }
    for key, cell in out["cells"].items():
        assert cell["reduction"] >= -1e-12, (key, cell["reduction"])
        n_stages = out["nets"][key.split("/")[0]]["n_stages"]
        for rec in (cell["halo_only"], cell["searched"]):
            bytes_per_stage = rec["comm_bytes_per_stage"]
            assert len(bytes_per_stage) == n_stages
            assert all(b >= 0 for b in bytes_per_stage)
        assert cell["searched"]["makespan"] <= cell["halo_only"]["makespan"]
    assert out["max_reduction"] >= 0.10, out["max_reduction"]
    # the attention model's win comes from head splits, not ratio tweaks
    for topo in ("sym", "skew"):
        searched = out["cells"][f"vit_l16/{topo}"]["searched"]["assignment"]
        assert "head_sequence" in searched, searched


def test_scheme_bench_artifact_floors():
    """The committed full-run artifact must cover the full-size nets and
    carry the tentpole's acceptance numbers (no cell regresses, >= 10%
    reduction somewhere)."""
    import json

    path = Path(__file__).resolve().parents[1] / "BENCH_schemes.json"
    if not path.exists():
        pytest.skip("BENCH_schemes.json not committed yet")
    out = json.loads(path.read_text())
    assert out["smoke"] is False
    assert out["nets"]["vgg16"]["in_rows"] == 224
    assert out["nets"]["vit_l16"]["in_rows"] == 224
    assert out["nets"]["vit_l16"]["n_layers"] == 1 + 24 * 4  # patch + 24 blocks
    assert set(out["cells"]) == {
        "vgg16/sym", "vgg16/skew", "vit_l16/sym", "vit_l16/skew"
    }
    for key, cell in out["cells"].items():
        assert cell["reduction"] >= -1e-12, (key, cell["reduction"])
        assert cell["halo_only"]["comm_bytes_per_stage"]
        assert cell["searched"]["comm_bytes_per_stage"]
    assert out["max_reduction"] >= 0.10, out["max_reduction"]


def test_roofline_results_complete():
    """Dry-run artifacts exist for all 40 cells x both meshes (ok or recorded
    skip), i.e. deliverables (e)/(g) are materialised."""
    from benchmarks import roofline

    for mesh in ("pod16x16", "pod2x16x16"):
        recs = roofline.load_all(mesh)
        if not recs:
            pytest.skip(f"dry-run not yet executed for {mesh}")
        assert len(recs) == 40, (mesh, len(recs))
        bad = [r for r in recs if r["status"] not in ("ok", "skipped")]
        assert not bad, [(r["arch"], r["cell"], r.get("error", "")[:60]) for r in bad]
        skips = [r for r in recs if r["status"] == "skipped"]
        assert len(skips) == 4  # long_500k x 4 full-attention LMs
