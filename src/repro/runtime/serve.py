"""Serving engine: dynamic batching with the paper's deadline model.

Requests arrive with a deadline; the batcher groups them (max batch / max
delay), the engine runs the jitted forward (vision / VGG-HALP / LM decode),
and per-request completion is checked against deadlines.  Batch-size selection
uses the paper's reliability machinery: given the measured per-batch latency
model and an offload-time distribution, ``choose_batch_size`` picks the
largest batch whose P(deadline met) clears the target -- Table III turned into
a scheduling policy (the beyond-paper integration of §V-D).

The engine closes the measurement loop of the online re-planner
(``repro.core.replan``) on both axes: every executed batch's (size, latency)
is handed to an optional observer -- typically
``ReplanController.observe_batch_latency`` -- and per-ES chunk timings
reported through ``observe_es_time`` feed ``ReplanController.observe_compute``
(the compute side of joint compute+link adaptation: a straggling secondary is
attributed, not just absorbed into the scalar calibration).
``plan_aware_batch_size`` re-runs the admission policy against the *current*
plan's predicted makespan, so the admitted batch tracks channel and compute
drift alike; a return of ``0`` means shed -- no batch size can meet the
deadline at the target reliability.
The same loop drives per-task placement
(``repro.core.placement.PlacementController``): a bucket switch re-places
every task over the shared ES pool, and the controller's
``predicted_latency`` prices a candidate batch by simulating its tasks on
that pool -- including the queueing of tasks that wrap onto the same
secondaries -- so admission follows both the channel and the placement.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.reliability import OffloadChannel, service_reliability

__all__ = [
    "Request",
    "ServeConfig",
    "BatchingEngine",
    "choose_batch_size",
    "plan_aware_batch_size",
]


@dataclass(order=True)
class Request:
    deadline: float
    rid: int = field(compare=False)
    payload: Any = field(compare=False, default=None)
    arrival: float = field(compare=False, default=0.0)
    done: float | None = field(compare=False, default=None)
    result: Any = field(compare=False, default=None)  # per-request model output


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_delay_s: float = 0.002
    pad_to_max: bool = True  # keep one compiled shape (prod: bucketed shapes)

    def __post_init__(self) -> None:
        # choose_batch_size/plan_aware_batch_size return 0 to mean "shed"; an
        # engine built with max_batch=0 would busy-loop taking empty batches
        # forever, so refuse loudly -- the caller must handle shedding itself
        if self.max_batch < 1:
            raise ValueError(
                f"max_batch must be >= 1, got {self.max_batch}; an admission "
                f"result of 0 means shed/reject -- do not build an engine on it"
            )


class BatchingEngine:
    """Deadline-aware dynamic batcher around a jitted ``fn(batch_payloads)``."""

    def __init__(
        self,
        fn: Callable,
        cfg: ServeConfig,
        clock: Callable = time.monotonic,
        observer: Callable[[int, float], None] | None = None,
        es_observer: Callable[[str, float, float], None] | None = None,
    ):
        self.fn = fn
        self.cfg = cfg
        self.clock = clock
        # called with (batch_size, elapsed_s) after every executed batch; wire
        # ReplanController.observe_batch_latency here to close the replan loop
        self.observer = observer
        # called with (es_name, flops, elapsed_s) for every reported per-ES
        # chunk execution; wire ReplanController.observe_compute here to close
        # the compute side of the joint replan loop (see observe_es_time)
        self.es_observer = es_observer
        self.queue: list[Request] = []  # deadline-ordered heap (EDF)
        self.completed: list[Request] = []
        self._rid = 0

    def submit(self, payload, deadline_s: float) -> int:
        self._rid += 1
        req = Request(
            deadline=self.clock() + deadline_s,
            rid=self._rid,
            payload=payload,
            arrival=self.clock(),
        )
        heapq.heappush(self.queue, req)
        return self._rid

    def observe_es_time(self, es: str, flops: float, elapsed_s: float) -> None:
        """Per-ES timing hook: the distributed executor reports one measured
        compute chunk (which ES ran it, its FLOP count, wall-clock) as it
        completes.  Forwards to ``es_observer`` -- typically
        ``ReplanController.observe_compute`` -- so a straggling secondary
        moves the controller's compute estimate and, past the hysteresis,
        triggers a joint re-plan/re-placement.  The whole-batch ``observer``
        only calibrates a scalar latency factor; this hook is what attributes
        slowness to a *specific* ES."""
        if self.es_observer is not None:
            self.es_observer(es, flops, elapsed_s)

    def _take_batch(self) -> list[Request]:
        batch = []
        while self.queue and len(batch) < self.cfg.max_batch:
            batch.append(heapq.heappop(self.queue))
        return batch

    def step(self) -> list[Request]:
        """Run one batch (earliest-deadline-first).  Returns completed reqs."""
        batch = self._take_batch()
        if not batch:
            return []
        payloads = [r.payload for r in batch]
        n = len(payloads)
        if self.cfg.pad_to_max and n < self.cfg.max_batch:
            payloads = payloads + [payloads[-1]] * (self.cfg.max_batch - n)
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *payloads)
        t0 = self.clock()
        out = self.fn(stacked)
        jax.block_until_ready(out)
        now = self.clock()
        if self.observer is not None:
            # report the *executed* width: with pad_to_max the forward ran
            # len(payloads) wide regardless of how many real requests were in
            # it, and that is the size the measured latency corresponds to
            # (anything else would skew a replan controller's calibration)
            self.observer(len(payloads), now - t0)
        for i, r in enumerate(batch):
            r.done = now
            r.result = jax.tree_util.tree_map(lambda x: x[i], out)
            self.completed.append(r)
        return batch

    def run_until_drained(self, max_batches: int = 10_000):
        b = 0
        while self.queue and b < max_batches:
            self.step()
            b += 1
        return self.stats()

    def stats(self) -> dict:
        met = [r for r in self.completed if r.done is not None and r.done <= r.deadline]
        lat = [r.done - r.arrival for r in self.completed if r.done is not None]
        return {
            "completed": len(self.completed),
            "deadline_met_frac": len(met) / max(1, len(self.completed)),
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
        }


def choose_batch_size(
    per_batch_latency_s: Callable[[int], float],
    deadline_s: float,
    channel: OffloadChannel,
    target: float = 0.99999,
    max_batch: int = 64,
) -> int:
    """Largest batch size whose service reliability clears ``target``
    (paper §V-D as an admission-control policy).

    Returns ``0`` when *no* batch size clears the target: the request stream
    cannot meet its deadline at the required reliability on the current plan
    and channel, so the caller must shed/reject (or renegotiate the deadline)
    rather than admit doomed work.  The historical behaviour of falling back
    to ``1`` silently admitted requests that were already known to miss."""
    best = 0
    for b in range(1, max_batch + 1):
        t_inf = per_batch_latency_s(b)
        rel = service_reliability(channel, t_inf, deadline_s)
        if rel >= target:
            best = b
    return best


def plan_aware_batch_size(
    controller,
    deadline_s: float,
    channel: OffloadChannel,
    target: float = 0.99999,
    max_batch: int = 64,
) -> int:
    """``choose_batch_size`` against the *current* plan's predicted makespan.

    ``controller`` is a :class:`~repro.core.replan.ReplanController` or a
    :class:`~repro.core.placement.PlacementController`: its
    ``predicted_latency(b)`` prices a b-task batch on whatever the controller
    is serving right now -- the closed form on the shared plan, or the
    shared-pool DES over the per-task placement (calibrated by measured batch
    latencies either way) -- so after a re-plan or re-placement the admitted
    batch size follows without re-measuring a latency curve.

    Like :func:`choose_batch_size`, returns ``0`` when even a single-task
    batch cannot clear ``target`` under the current plan's predicted
    makespan: the caller sheds until the controller re-plans onto a faster
    operating point (or the channel recovers)."""
    return choose_batch_size(
        controller.predicted_latency, deadline_s, channel, target=target, max_batch=max_batch
    )
