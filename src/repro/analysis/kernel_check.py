"""Abstract evaluation of the fused Pallas ``halo_conv2d`` path.

``spatial.halo.conv2d_spatial(engine="pallas")`` routes a geometry to the
fused kernel iff ``_pallas_supported`` says so; a divergence between that
predicate and what ``halo_conv2d`` actually accepts surfaces as a cryptic
trace-time error *inside* ``shard_map`` (where the failing shapes are per
device and the geometry that chose the path is long gone).  This analyzer
catches the divergence statically:

* **Support agreement** (``kernel.support``): for a geometry, the predicate's
  claim must match whether the kernel abstractly traces (``jax.eval_shape``
  -- shape propagation only, no device execution, no data).  Both directions
  are findings: claiming support for a geometry the kernel rejects breaks the
  fused path at trace time; rejecting a geometry the kernel accepts silently
  forfeits the fused path.
* **Output shape** (``kernel.shape``): a traced kernel must produce exactly
  ``[B, Hs // s, W_out, Cout]`` with ``W_out = (W + 2p - k) // s + 1`` --
  the shard's contribution to eq. 7's row partition.
* **Remainder tiles** (``kernel.tiles``): shard heights need not divide the
  tile height; the final tile overhangs into zero padding.  The probe forces
  a non-dividing ``tile_h`` and requires the same output shape -- pinning the
  ceil-tiling contract (``nt = ceil(n_out / th)``) that once silently dropped
  remainder rows.

:func:`check_plan_kernels` walks a plan and probes every distinct conv
geometry x shard height it would deploy, so unsupported shapes are caught
before ``shard_map`` tracing.  JAX is imported lazily -- ``plan_check`` and
the rest of the package stay importable without it.
"""
from __future__ import annotations

from ..core.partition import HALPPlan, SchemePlan, SCHEME_HALO
from .findings import Report

__all__ = ["check_kernel_geometry", "check_plan_kernels"]


def check_kernel_geometry(
    k: int,
    s: int = 1,
    p: int = 0,
    *,
    groups: int = 1,
    c_in: int = 8,
    c_out: int = 8,
    hs: int = 8,
    w: int = 16,
    batch: int = 1,
    supported: bool | None = None,
) -> Report:
    """Verify predicate/kernel agreement for one geometry via ``eval_shape``.

    ``supported`` overrides the ``_pallas_supported`` claim (mutation tests
    use it to prove a wrong predicate is caught)."""
    import jax
    import jax.numpy as jnp

    from ..kernels.halo_conv import halo_conv2d
    from ..spatial.halo import _pallas_supported

    rep = Report()
    if hs % s:
        raise ValueError(f"shard rows {hs} not divisible by stride {s} (caller contract)")
    where = f"k={k} s={s} p={p} groups={groups} c={c_in}->{c_out} hs={hs} w={w}"

    wts_shape = (k, k, 1 if groups > 1 else c_in, c_out)
    wts = jax.ShapeDtypeStruct(wts_shape, jnp.float32)
    claim = (
        supported
        if supported is not None
        else _pallas_supported(k, s, p, groups, c_in, wts, w)
    )

    lo, hi = p, max(0, k - p - s)
    x = jax.ShapeDtypeStruct((batch, hs, w, c_in), jnp.float32)
    top = jax.ShapeDtypeStruct((batch, lo, w, c_in), jnp.float32) if lo else None
    bot = jax.ShapeDtypeStruct((batch, hi, w, c_in), jnp.float32) if hi else None

    def trace(tile_h=None):
        return jax.eval_shape(
            lambda xs, t, b, wt: halo_conv2d(
                xs, t, b, wt, None, stride=s, padding=p, groups=groups, tile_h=tile_h
            ),
            x,
            top,
            bot,
            wts,
        )

    rep.tick()
    try:
        out = trace()
        traced, err = True, None
    except Exception as exc:  # trace-time rejection, any flavour
        traced, err = False, exc

    if claim and not traced:
        rep.add(
            "kernel.support",
            where,
            f"_pallas_supported claims the fused kernel handles this geometry "
            f"but halo_conv2d fails to trace: {type(err).__name__}: {err}",
        )
        return rep
    if not claim and traced:
        rep.add(
            "kernel.support",
            where,
            "halo_conv2d traces this geometry but _pallas_supported rejects "
            "it: the fused path is forfeited for a supported shape",
        )
    if not traced:
        return rep

    w_out = (w + 2 * p - k) // s + 1
    expect = (batch, hs // s, w_out, c_out)
    rep.tick()
    if tuple(out.shape) != expect:
        rep.add(
            "kernel.shape",
            where,
            f"fused kernel output shape {tuple(out.shape)} != expected "
            f"[B, Hs//s, W_out, Cout] = {expect}",
        )
        return rep

    # remainder-tile path: force a tile height that does not divide n_out
    n_out = hs // s
    if n_out >= 2:
        rep.tick()
        try:
            out_r = trace(tile_h=max(1, n_out - 1))
        except Exception as exc:
            rep.add(
                "kernel.tiles",
                where,
                f"remainder-tile path (tile_h={max(1, n_out - 1)}, n_out="
                f"{n_out}) fails to trace: {type(exc).__name__}: {exc}",
            )
            return rep
        if tuple(out_r.shape) != expect:
            rep.add(
                "kernel.tiles",
                where,
                f"remainder-tile output shape {tuple(out_r.shape)} != {expect}: "
                f"overhang rows are not sliced off",
            )
    return rep


def _plan_geometries(plan: HALPPlan) -> set[tuple]:
    """Distinct (k, s, p, groups, c_in, c_out, hs, w) the plan would deploy."""
    geoms: set[tuple] = set()
    sizes = plan.net.sizes()
    for i, g in enumerate(plan.net.layers):
        if g.kind not in ("conv", "depthwise"):
            continue
        groups = g.c_in if g.kind == "depthwise" else 1
        width = sizes[i]  # square maps: input width == input rows
        for slot in plan.es_names:
            seg = plan.parts[i].out.get(slot)
            if not seg:
                continue
            hs = seg.rows * g.s  # aligned shard: hs input rows per output row
            geoms.add((g.k, g.s, g.p, groups, g.c_in, g.c_out, hs, width))
    return geoms


def check_plan_kernels(plan) -> Report:
    """Probe every conv geometry x shard height a plan deploys.

    A finding here means deploying the plan through the Pallas engine would
    either crash at ``shard_map`` trace time (support divergence) or shard a
    layer the kernel cannot express."""
    rep = Report()
    if isinstance(plan, SchemePlan):
        for seg, sub in zip(plan.segments, plan.halo_plans):
            if seg.scheme == SCHEME_HALO and sub is not None:
                rep.extend(check_plan_kernels(sub))
        return rep
    if not isinstance(plan, HALPPlan):
        rep.add("plan.type", type(plan).__name__, "not a HALPPlan / SchemePlan")
        return rep
    for k, s, p, groups, c_in, c_out, hs, w in sorted(_plan_geometries(plan)):
        rep.extend(
            check_kernel_geometry(
                k, s, p, groups=groups, c_in=c_in, c_out=c_out, hs=hs, w=w
            )
        )
    return rep
