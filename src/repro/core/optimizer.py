"""Latency-minimising search over HALP plan knobs (segment ratios, overlap).

The paper fixes the partition a priori (equal halves, a 4-row zone); on a
heterogeneous cluster that leaves latency on the table -- a fast secondary
should own more rows (DistrEdge, arXiv 2202.01699) and the optimal overlap
width trades host work against host->secondary boundary traffic.  This module
searches those knobs directly against the discrete-event simulator (the ground
truth the paper's recursion approximates):

* decision variables: the N secondary segment ratios (a simplex point) and the
  overlap-zone width in output rows,
* objective: the simulated makespan of ``n_tasks`` concurrent tasks on the
  given :class:`~repro.core.topology.CollabTopology`,
* method: steepest coordinate descent on the ratio simplex (move mass onto one
  secondary at a time, renormalise) joined with the overlap choices, with
  step-size halving -- the objective is piecewise constant in the ratios
  (segments are integer rows), so gradient-free moves with a shrinking step
  are the right tool.  Each round's whole perturbation neighbourhood
  (2N ratio moves + |W|-1 overlap switches) is priced as **one batched DES
  call** (:class:`~repro.core.events.HalpBatchEvaluator`: plan layouts +
  cached DAG templates + ``Sim.run_batch``), with a ``(ratios, overlap)``
  memo so renormalisation collisions and revisited operating points are never
  re-priced.  ``engine="scalar"`` keeps the one-candidate-at-a-time pricing
  path (plan build + DAG build + scalar DES per candidate) callable: the two
  engines share the search loop and their scores are bit-identical, so they
  return the same plan -- the scalar engine exists as the baseline that
  ``benchmarks/planner_speed.py`` measures the batched engine against.

Infeasible candidates (a plan whose messages would skip a slot, or more slots
than rows) are rejected by the partitioner's invariant checks and priced +inf.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

from .events import HalpBatchEvaluator, SchemeBatchEvaluator, simulate_scheme
from .nets import ConvNetGeom
from .partition import (
    HALPPlan,
    SCHEME_HALO,
    SchemePlan,
    plan_halp_topology,
    plan_scheme,
    stage_scheme_options,
    stage_spans,
)
from .simulator import simulate_halp
from .topology import CollabTopology

__all__ = [
    "OptimizeResult",
    "optimize_plan",
    "evaluate_plan",
    "evaluate_scheme_assignment",
    "equal_ratios",
]


@dataclass
class OptimizeResult:
    ratios: tuple[float, ...]
    overlap_rows: int
    makespan: float
    plan: "HALPPlan | SchemePlan"
    evaluations: int
    history: list[tuple] = field(default_factory=list)
    # Per-stage scheme assignment of the winning plan; None for halo-only
    # searches (the legacy path, whose plans stay bit-identical plan_halp_n
    # output).  History entries are (ratios, overlap, score) on the legacy
    # path and (ratios, overlap, assignment, score) on the joint path.
    schemes: tuple[str, ...] | None = None


def equal_ratios(topology: CollabTopology) -> tuple[float, ...]:
    """The naive capacity-blind split (the paper's default)."""
    n = topology.n_secondaries
    return tuple(1.0 / n for _ in range(n))


def _verify_plan(plan, context: str) -> None:
    """Opt-in static verification gate (``verify=True``): raises
    :class:`repro.analysis.AnalysisError` naming every violated invariant."""
    from ..analysis import check_plan

    check_plan(plan).raise_if_failed(context)


def evaluate_plan(
    net: ConvNetGeom,
    topology: CollabTopology,
    ratios: Sequence[float],
    overlap_rows: int,
    n_tasks: int = 1,
    auto_reduce: bool = True,
) -> float:
    """Simulated makespan of one candidate; +inf if the plan is infeasible.

    ``auto_reduce=False`` restricts the search to strictly-isolating plans
    (no per-layer secondary reduction); thin layers then price +inf."""
    try:
        plan = plan_halp_topology(
            net, topology, overlap_rows=overlap_rows, ratios=ratios,
            auto_reduce=auto_reduce,
        )
        return simulate_halp(net, topology=topology, n_tasks=n_tasks, plan=plan)["total"]
    except (AssertionError, ValueError):
        return float("inf")


def evaluate_scheme_assignment(
    net: ConvNetGeom,
    topology: CollabTopology,
    ratios: Sequence[float],
    overlap_rows: int,
    assignment: Sequence[str],
    n_tasks: int = 1,
    auto_reduce: bool = True,
) -> float:
    """Simulated makespan of one (ratios, overlap, scheme-assignment) candidate.

    The scheme-search analogue of :func:`evaluate_plan`: prices a mixed-scheme
    plan through the scheme DAG (one rate-independent DES sweep) and returns
    +inf when the candidate is infeasible (e.g. a halo stage whose segments
    cannot isolate, or a scheme invalid for a stage's layer kinds)."""
    try:
        return simulate_scheme(
            net,
            topology,
            ratios=tuple(ratios),
            overlap_rows=overlap_rows,
            assignment=tuple(assignment),
            n_tasks=n_tasks,
            auto_reduce=auto_reduce,
        )["total"]
    except (AssertionError, ValueError):
        return float("inf")


def optimize_plan(
    net: ConvNetGeom,
    topology: CollabTopology,
    n_tasks: int = 1,
    overlap_choices: Sequence[int] = (2, 4, 6, 8),
    init_ratios: Sequence[float] | None = None,
    step: float = 0.08,
    min_step: float = 0.005,
    min_ratio: float = 0.02,
    max_rounds: int = 12,
    objective: Callable[[tuple[float, ...], int], float] | None = None,
    auto_reduce: bool = True,
    engine: str = "batched",
    eval_budget: int | None = None,
    tol: float = 0.0,
    schemes: Sequence[str] = (SCHEME_HALO,),
    verify: bool = False,
) -> OptimizeResult:
    """Steepest coordinate-descent search for the fastest (ratios, overlap).

    Starts from the topology's capacity-weighted ratios (or ``init_ratios``)
    and the best of ``overlap_choices`` there, then per round prices the whole
    perturbation neighbourhood -- mass on/off each secondary at the current
    ``step`` plus every other overlap width -- and moves to the best strictly
    improving candidate, halving the step when none improves.  Terminates when
    the step falls below ``min_step``, after ``max_rounds`` rounds, when a
    round's improvement falls below ``tol`` (early exit -- lets controllers
    trade tail latency for plan quality), or when ``eval_budget`` priced
    evaluations have been spent (the hard cap on worst-case replan latency;
    ``max_rounds``/``min_step`` alone only bound the round *count*).

    ``engine="batched"`` (default) prices each neighbourhood as one
    :class:`~repro.core.events.HalpBatchEvaluator` sweep and memoises scores
    by ``(ratios, overlap)`` so duplicate renormalised candidates are never
    re-priced; ``engine="scalar"`` prices candidates one at a time through
    :func:`evaluate_plan` (the pre-template path, kept callable as the
    benchmark baseline).  Both engines share this search loop and produce
    bit-identical scores, hence identical plans -- including under an
    ``eval_budget``, where the batched engine prices lazily (no speculative
    prefetch) so the budget cuts at the same candidate on both engines.

    ``objective`` may replace the default simulated-makespan objective (e.g.
    to optimise the closed form instead, or average delay for multi-task);
    the batched DES fast path then does not apply, but the memo still does.

    ``schemes`` is the per-stage partitioning-scheme vocabulary.  The default
    halo-only vocabulary on an attention-free net keeps the legacy search
    (bit-identical trajectory, plans, and ``history`` shape).  Any larger
    vocabulary -- or any net with attention layers, which halo segments cannot
    split -- routes to the *joint* (scheme-per-stage, ratios, overlap) search:
    the same speculative cyclic-descent skeleton with a scheme-flip pass per
    round, memoised by ``(ratios, overlap, assignment)`` and priced through
    the scheme DAG (:class:`~repro.core.events.SchemeBatchEvaluator`).  A
    custom ``objective`` is incompatible with the joint space (its signature
    has no assignment argument) and raises ``ValueError`` there.

    ``verify=True`` runs the static verifier
    (:func:`repro.analysis.check_plan`) on the winning plan before returning
    and raises :class:`repro.analysis.AnalysisError` on any finding -- an
    opt-in guard for callers that ship plans to remote executors."""
    if engine not in ("batched", "scalar"):
        raise ValueError(f"engine must be 'batched' or 'scalar', got {engine!r}")
    if eval_budget is not None and eval_budget < 1:
        raise ValueError(f"eval_budget must be >= 1, got {eval_budget}")
    schemes = tuple(schemes)
    if schemes != (SCHEME_HALO,) or any(g.kind == "attn" for g in net.layers):
        if objective is not None:
            raise ValueError(
                "a custom objective is halo-only: the joint scheme search "
                "prices (ratios, overlap, assignment) candidates through the "
                "scheme DAG and cannot route them to an (ratios, overlap) "
                "objective; drop `objective` or use schemes=(SCHEME_HALO,)"
            )
        result = _optimize_scheme_plan(
            net,
            topology,
            schemes=schemes,
            n_tasks=n_tasks,
            overlap_choices=overlap_choices,
            init_ratios=init_ratios,
            step=step,
            min_step=min_step,
            min_ratio=min_ratio,
            max_rounds=max_rounds,
            auto_reduce=auto_reduce,
            engine=engine,
            eval_budget=eval_budget,
            tol=tol,
        )
        if verify:
            _verify_plan(result.plan, "optimize_plan")
        return result
    evals = 0
    history: list[tuple[tuple[float, ...], int, float]] = []
    batched = engine == "batched"
    evaluator = (
        HalpBatchEvaluator(net, topology, n_tasks=n_tasks, auto_reduce=auto_reduce)
        if batched and objective is None
        else None
    )

    def default_objective(ratios: tuple[float, ...], w: int) -> float:
        return evaluate_plan(
            net, topology, ratios, w, n_tasks=n_tasks, auto_reduce=auto_reduce
        )

    fn = objective or default_objective
    # Scores memo: the batched engine always consults it; the scalar engine
    # normally keeps the historical price-every-candidate behaviour (the cost
    # profile the benchmark compares against) -- scores are bit-identical
    # either way, so the unbudgeted trajectory cannot differ.  Under an
    # eval_budget BOTH engines memoise: re-priced duplicates would otherwise
    # consume the scalar engine's budget at different candidates than the
    # batched engine's, and the budget cut-off must land identically for the
    # engines to return the same plan.
    use_memo = batched or eval_budget is not None
    memo: dict[tuple[tuple[float, ...], int], float] = {}

    def price_all(cands: list[tuple[tuple[float, ...], int]]) -> list[float]:
        nonlocal evals
        out: list[float | None] = [None] * len(cands)
        if use_memo:
            for k, c in enumerate(cands):
                if c in memo:
                    out[k] = memo[c]
        fresh = [(k, c) for k, c in enumerate(cands) if out[k] is None]
        if eval_budget is not None:
            fresh = fresh[: max(0, eval_budget - evals)]
        if fresh:
            if evaluator is not None:
                scores = evaluator.evaluate([c for _, c in fresh])
            else:
                scores = [fn(r, w) for _, (r, w) in fresh]
            evals += len(fresh)
            for (k, c), v in zip(fresh, scores):
                memo[c] = v
                out[k] = v
                history.append((c[0], c[1], v))
        # candidates beyond an exhausted budget stay unpriced: +inf keeps them
        # unselectable without spending evaluations on them
        return [v if v is not None else float("inf") for v in out]

    def renorm(raw: Sequence[float]) -> tuple[float, ...]:
        clipped = [max(min_ratio, r) for r in raw]
        total = sum(clipped)
        return tuple(r / total for r in clipped)

    ratios = renorm(init_ratios or topology.capacity_ratios())
    n = len(ratios)
    scan = [(ratios, w) for w in overlap_choices]
    scores = price_all(scan)
    best = float("inf")
    best_w = overlap_choices[0]
    for (_, w), v in zip(scan, scores):
        if v < best:
            best, best_w = v, w

    moves = [(j, sign) for j in range(n) for sign in (1.0, -1.0)]
    # Speculative neighbourhood prefetch spends evaluations on candidates the
    # acceptance scan may never reach (a mid-scan accept shifts the base), so
    # under an eval_budget it would cut the budget at *different* candidates
    # than the scalar engine's lazy acceptance-order pricing -- breaking the
    # identical-plans guarantee the replan cache keying relies on.  Budgeted
    # searches therefore price lazily on both engines (the batched evaluator
    # and the memo still apply, per candidate).
    speculate = evaluator is not None and eval_budget is None

    def perturbed(base: tuple[float, ...], j: int, sign: float) -> tuple[float, ...]:
        raw = list(base)
        raw[j] = max(min_ratio, raw[j] + sign * step)
        return renorm(raw)

    rounds = 0
    converged = False
    while step >= min_step and rounds < max_rounds and not converged:
        if eval_budget is not None and evals >= eval_budget:
            break
        rounds += 1
        improved = False
        round_start = best
        # The acceptance order is the classic cyclic pass (identical plans to
        # the sequential optimizer); batching happens *speculatively*: the
        # whole remaining neighbourhood of the current base is priced in one
        # sweep, so the sequential scan below is all memo hits until an
        # accepted move shifts the base -- at which point the remainder is
        # re-batched from the new base.
        if speculate:
            price_all(
                [(c, best_w) for jj, ss in moves if (c := perturbed(ratios, jj, ss)) != ratios]
            )
        for idx, (j, sign) in enumerate(moves):
            cand = perturbed(ratios, j, sign)
            if cand == ratios:
                continue
            v = price_all([(cand, best_w)])[0]
            if v < best:
                best, ratios, improved = v, cand, True
                if speculate:
                    price_all(
                        [
                            (c, best_w)
                            for jj, ss in moves[idx + 1 :]
                            if (c := perturbed(ratios, jj, ss)) != ratios
                        ]
                    )
        if speculate:
            price_all([(ratios, w) for w in overlap_choices if w != best_w])
        for w in overlap_choices:
            if w == best_w:
                continue
            v = price_all([(ratios, w)])[0]
            if v < best:
                best, best_w, improved = v, w, True
        if not improved:
            step *= 0.5
        elif math.isfinite(best) and round_start - best < tol:
            converged = True  # tol early-exit: bound the controller's tail
    if not math.isfinite(best):
        raise ValueError(
            f"no feasible HALP plan for {topology.n_secondaries} secondaries on "
            f"{net.name} over overlap choices {tuple(overlap_choices)}; use fewer "
            f"secondaries or a larger input"
        )
    plan = plan_halp_topology(
        net, topology, overlap_rows=best_w, ratios=ratios, auto_reduce=auto_reduce
    )
    if verify:
        _verify_plan(plan, "optimize_plan")
    return OptimizeResult(
        ratios=ratios,
        overlap_rows=best_w,
        makespan=best,
        plan=plan,
        evaluations=evals,
        history=history,
    )


def _optimize_scheme_plan(
    net: ConvNetGeom,
    topology: CollabTopology,
    schemes: tuple[str, ...],
    n_tasks: int,
    overlap_choices: Sequence[int],
    init_ratios: Sequence[float] | None,
    step: float,
    min_step: float,
    min_ratio: float,
    max_rounds: int,
    auto_reduce: bool,
    engine: str,
    eval_budget: int | None,
    tol: float,
) -> OptimizeResult:
    """Joint (scheme-per-stage, ratios, overlap) coordinate descent.

    Same skeleton as the legacy halo-only loop -- initial overlap scan, cyclic
    ratio moves with speculative neighbourhood prefetch, step halving -- with a
    scheme-flip pass inserted between the ratio and overlap passes: each stage
    tries every alternative scheme from its vocabulary at the current
    (ratios, overlap), accepting strict improvements cyclically.  Candidates
    are memoised by the full ``(ratios, overlap, assignment)`` triple so a
    flip that returns to an already-priced operating point is free; the
    batched engine prices each neighbourhood as one
    :class:`~repro.core.events.SchemeBatchEvaluator` sweep, and budget
    semantics mirror the legacy loop (lazy pricing when budgeted, so both
    engines cut at the same candidate).
    """
    evals = 0
    history: list[tuple] = []
    batched = engine == "batched"
    evaluator = (
        SchemeBatchEvaluator(net, topology, n_tasks=n_tasks, auto_reduce=auto_reduce)
        if batched
        else None
    )
    spans = stage_spans(net)
    options = [stage_scheme_options(net, sp, schemes) for sp in spans]
    assignment: tuple[str, ...] = tuple(opts[0] for opts in options)

    use_memo = batched or eval_budget is not None
    memo: dict[tuple[tuple[float, ...], int, tuple[str, ...]], float] = {}

    def price_all(
        cands: list[tuple[tuple[float, ...], int, tuple[str, ...]]]
    ) -> list[float]:
        nonlocal evals
        out: list[float | None] = [None] * len(cands)
        if use_memo:
            for k, c in enumerate(cands):
                if c in memo:
                    out[k] = memo[c]
        fresh = [(k, c) for k, c in enumerate(cands) if out[k] is None]
        if eval_budget is not None:
            fresh = fresh[: max(0, eval_budget - evals)]
        if fresh:
            if evaluator is not None:
                scores = evaluator.evaluate([c for _, c in fresh])
            else:
                scores = [
                    evaluate_scheme_assignment(
                        net, topology, r, w, a, n_tasks=n_tasks, auto_reduce=auto_reduce
                    )
                    for _, (r, w, a) in fresh
                ]
            evals += len(fresh)
            for (k, c), v in zip(fresh, scores):
                memo[c] = v
                out[k] = v
                history.append((c[0], c[1], c[2], v))
        return [v if v is not None else float("inf") for v in out]

    def renorm(raw: Sequence[float]) -> tuple[float, ...]:
        clipped = [max(min_ratio, r) for r in raw]
        total = sum(clipped)
        return tuple(r / total for r in clipped)

    ratios = renorm(init_ratios or topology.capacity_ratios())
    n = len(ratios)
    scan = [(ratios, w, assignment) for w in overlap_choices]
    scores = price_all(scan)
    best = float("inf")
    best_w = overlap_choices[0]
    for (_, w, _a), v in zip(scan, scores):
        if v < best:
            best, best_w = v, w

    moves = [(j, sign) for j in range(n) for sign in (1.0, -1.0)]
    flips = [(si, alt) for si, opts in enumerate(options) for alt in opts]
    speculate = evaluator is not None and eval_budget is None

    def perturbed(base: tuple[float, ...], j: int, sign: float) -> tuple[float, ...]:
        raw = list(base)
        raw[j] = max(min_ratio, raw[j] + sign * step)
        return renorm(raw)

    def flipped(
        base: tuple[str, ...], si: int, alt: str
    ) -> tuple[str, ...]:
        return base[:si] + (alt,) + base[si + 1 :]

    rounds = 0
    converged = False
    while step >= min_step and rounds < max_rounds and not converged:
        if eval_budget is not None and evals >= eval_budget:
            break
        rounds += 1
        improved = False
        round_start = best
        # --- ratio pass (cyclic accepts; speculative re-batch on accept) ---
        if speculate:
            price_all(
                [
                    (c, best_w, assignment)
                    for jj, ss in moves
                    if (c := perturbed(ratios, jj, ss)) != ratios
                ]
            )
        for idx, (j, sign) in enumerate(moves):
            cand = perturbed(ratios, j, sign)
            if cand == ratios:
                continue
            v = price_all([(cand, best_w, assignment)])[0]
            if v < best:
                best, ratios, improved = v, cand, True
                if speculate:
                    price_all(
                        [
                            (c, best_w, assignment)
                            for jj, ss in moves[idx + 1 :]
                            if (c := perturbed(ratios, jj, ss)) != ratios
                        ]
                    )
        # --- scheme-flip pass: one stage at a time over its vocabulary ---
        if speculate:
            price_all(
                [
                    (ratios, best_w, a)
                    for si, alt in flips
                    if (a := flipped(assignment, si, alt)) != assignment
                ]
            )
        for idx, (si, alt) in enumerate(flips):
            cand_a = flipped(assignment, si, alt)
            if cand_a == assignment:
                continue
            v = price_all([(ratios, best_w, cand_a)])[0]
            if v < best:
                best, assignment, improved = v, cand_a, True
                if speculate:
                    price_all(
                        [
                            (ratios, best_w, a)
                            for sj, a2 in flips[idx + 1 :]
                            if (a := flipped(assignment, sj, a2)) != assignment
                        ]
                    )
        # --- overlap pass ---
        if speculate:
            price_all(
                [(ratios, w, assignment) for w in overlap_choices if w != best_w]
            )
        for w in overlap_choices:
            if w == best_w:
                continue
            v = price_all([(ratios, w, assignment)])[0]
            if v < best:
                best, best_w, improved = v, w, True
        if not improved:
            step *= 0.5
        elif math.isfinite(best) and round_start - best < tol:
            converged = True
    if not math.isfinite(best):
        raise ValueError(
            f"no feasible plan for {topology.n_secondaries} secondaries on "
            f"{net.name} over schemes {schemes} and overlap choices "
            f"{tuple(overlap_choices)}; widen the vocabulary or use fewer "
            f"secondaries"
        )
    halo_only = all(a == SCHEME_HALO for a in assignment) and not any(
        g.kind == "attn" for g in net.layers
    )
    plan: HALPPlan | SchemePlan
    if halo_only:
        # All-halo winner on a halo-partitionable net: hand back the legacy
        # plan object so downstream executors/caches see bit-identical
        # plan_halp_n output regardless of which vocabulary was searched.
        plan = plan_halp_topology(
            net, topology, overlap_rows=best_w, ratios=ratios, auto_reduce=auto_reduce
        )
    else:
        plan = plan_scheme(
            net,
            topology,
            overlap_rows=best_w,
            ratios=ratios,
            assignment=assignment,
            schemes=schemes,
            auto_reduce=auto_reduce,
        )
    return OptimizeResult(
        ratios=ratios,
        overlap_rows=best_w,
        makespan=best,
        plan=plan,
        evaluations=evals,
        history=history,
        schemes=assignment,
    )
