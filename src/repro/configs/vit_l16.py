"""vit-l16 [vision]: img_res=224 patch=16 n_layers=24 d_model=1024 n_heads=16
d_ff=4096.  [arXiv:2010.11929; paper]"""
from ..models import vit
from ..models.vit import ViTConfig
from .base import Arch, register, vision_cells

FULL = ViTConfig(name="vit-l16", img_res=224, patch=16, n_layers=24,
                 d_model=1024, n_heads=16, d_ff=4096)
SMOKE = ViTConfig(name="vit-l16-smoke", img_res=64, patch=8, n_layers=2,
                  d_model=64, n_heads=4, d_ff=128, num_classes=10)

ARCH = register(
    Arch(
        name="vit-l16",
        family="vision",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=vision_cells(),
        module=vit,
        notes="conv stem is partitionable; global attention makes per-layer "
        "receptive field unbounded -> DP/TP (DESIGN.md §4)",
    )
)
