"""Model zoo: pure-JAX definitions for the paper's VGG-16 and the 10 assigned
architectures (see repro.configs)."""
