"""Checkpointing: pytree <-> sharded .npz + msgpack manifest.

Layout per checkpoint: ``<dir>/step_<N>/arrays.npz`` (one entry per leaf,
keyed by the pytree path) + ``meta.msgpack`` (step, arch name, leaf index,
dtypes).  Atomic via write-to-temp + rename; ``latest_step`` scans the
directory so a restarted job resumes from the newest complete checkpoint
(crash-consistent restore is exercised by tests/test_runtime.py).
"""
from __future__ import annotations

import os
import shutil
import tempfile
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _paths_and_leaves(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: Any, extra: dict | None = None):
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        arrays = {}
        manifest = []
        for i, (key, leaf) in enumerate(_paths_and_leaves(tree)):
            arr = np.asarray(leaf)
            if arr.dtype == jnp.bfloat16:
                arrays[f"a{i}"] = arr.view(np.uint16)
                manifest.append({"key": key, "dtype": "bfloat16"})
            else:
                arrays[f"a{i}"] = arr
                manifest.append({"key": key, "dtype": str(arr.dtype)})
        np.savez(tmp / "arrays.npz", **arrays)
        meta = {"step": step, "manifest": manifest, "extra": extra or {}}
        (tmp / "meta.msgpack").write_bytes(msgpack.packb(meta))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
    finally:
        if tmp.exists():
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "meta.msgpack").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, like: Any, step: int | None = None):
    """Restore into the structure of ``like`` (abstract or concrete pytree).
    Returns (tree, step, extra)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:09d}"
    meta = msgpack.unpackb((d / "meta.msgpack").read_bytes())
    data = np.load(d / "arrays.npz")
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    leaves = []
    for i, entry in enumerate(meta["manifest"]):
        arr = data[f"a{i}"]
        if entry["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr))
    if len(leaves) != len(flat_like):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected {len(flat_like)}"
        )
    return treedef.unflatten(leaves), meta["step"], meta["extra"]
