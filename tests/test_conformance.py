"""Closed-form vs DES conformance grid (paper eqs. 16-20 / 22-23).

One systematic cross-validation replaces the per-feature spot checks that used
to live in test_schedule/test_topology: every (cluster size, link/platform
skew, task count) cell asserts the closed-form recursion stays an **upper
bound** on the exact discrete-event simulation, within a **pinned slack** --
the bound's measured looseness at the time it was pinned.  A future change
that silently loosens (or breaks the bound direction of) either engine fails
the grid immediately.

Also pinned here: the tightened multi-task host term (``multitask_bound=
"list"``) is never looser than the paper's eq. 22 (``"eq22"``) anywhere on
the grid, and strictly tighter where K > 1 zones meet asymmetric links.

The vectorized DES (``Sim.run_batch``) and the batched candidate evaluator
(``events.HalpBatchEvaluator``: plan layouts + DAG templates) must match the
scalar engines to float *equality* -- not closeness -- on every cell: the
online planner's batched fast path is only trustworthy if it is the same
simulator, and any drift in the layout/template factorisation shows up here
as a single-bit diff.  Hypothesis property tests extend the same claim to
random plans and random per-resource slowdowns.
"""
import dataclasses
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    AGX_XAVIER,
    GTX_1080TI,
    SCHEME_HALO,
    SCHEME_NP,
    SCHEMES,
    CollabTopology,
    Link,
    SchemeBatchEvaluator,
    halp_closed_form,
    plan_scheme,
    simulate_halp,
    simulate_scheme,
    stage_scheme_options,
    stage_spans,
    standalone_time,
    vgg16_geom,
)
from repro.core.events import HalpBatchEvaluator, MultitaskBatchEvaluator
from repro.core.optimizer import evaluate_plan
from repro.core.simulator import Sim

NET = vgg16_geom()

# Bound-direction tolerance: the closed form must not dip below the DES by
# more than float noise anywhere on the grid.
LOWER_TOL = 1e-9

SKEW_SCALES = (1.0, 0.5, 0.8, 0.3, 0.65)


def sym_topology(n: int, platform=GTX_1080TI) -> CollabTopology:
    return CollabTopology.symmetric(platform, Link(40e9), n_secondaries=n)


def skew_topology(n: int) -> CollabTopology:
    """Heterogeneous platforms (x1.0 .. x0.3) with alternating 40/10 Gbps
    links -- the regime where eq. 22's worst-case terms are loosest."""
    secs = tuple(f"e{j}" for j in range(1, n + 1))
    platforms = {"e0": GTX_1080TI}
    links = {}
    for j, (s, scale) in enumerate(zip(secs, SKEW_SCALES)):
        platforms[s] = GTX_1080TI.scaled(scale, f"es x{scale:g}")
        rate = 10e9 if j % 2 else 40e9
        links[("e0", s)] = Link(rate)
        links[(s, "e0")] = Link(rate)
    return CollabTopology(
        host="e0", secondaries=secs, platforms=platforms,
        links=links, default_link=Link(40e9),
    )


TOPOLOGIES = {
    "sym": sym_topology,
    "skew": skew_topology,
    "sym-agx": lambda n: sym_topology(n, AGX_XAVIER),
}

# Pinned upper slack per cell: measured closed-form/DES ratio at pin time
# (see the PR that introduced this file) plus ~3-5% headroom.  The bound
# loosens with zone count K and link skew; that structure should survive
# refactors -- a cell blowing its slack means an engine changed behaviour.
UPPER_SLACK = {
    # (n_secondaries, kind, n_tasks): max allowed cf/ev
    (2, "sym", 1): 1.05, (2, "sym", 4): 1.11,
    (2, "skew", 1): 1.06, (2, "skew", 4): 1.26,
    (2, "sym-agx", 1): 1.04, (2, "sym-agx", 4): 1.05,
    (3, "sym", 1): 1.09, (3, "sym", 4): 1.11,
    (3, "skew", 1): 1.15, (3, "skew", 4): 1.49,
    (3, "sym-agx", 1): 1.05, (3, "sym-agx", 4): 1.05,
    (5, "sym", 1): 1.11, (5, "sym", 4): 1.08,
    (5, "skew", 1): 1.14, (5, "skew", 4): 1.22,
    (5, "sym-agx", 1): 1.05, (5, "sym-agx", 4): 1.05,
}

GRID = sorted(UPPER_SLACK)


@pytest.mark.parametrize("n_sec,kind,n_tasks", GRID)
def test_closed_form_upper_bounds_des_within_pinned_slack(n_sec, kind, n_tasks):
    topo = TOPOLOGIES[kind](n_sec)
    cf = halp_closed_form(NET, topology=topo, n_tasks=n_tasks)["total"]
    ev = simulate_halp(NET, topology=topo, n_tasks=n_tasks)["total"]
    assert cf >= ev * (1.0 - LOWER_TOL), (
        f"closed form lost the upper-bound property: cf={cf} < ev={ev}"
    )
    slack = UPPER_SLACK[(n_sec, kind, n_tasks)]
    assert cf <= ev * slack, (
        f"closed form loosened past its pinned slack {slack}: cf/ev={cf / ev:.4f}"
    )


@pytest.mark.parametrize("n_sec,kind,n_tasks", GRID)
def test_tightened_bound_never_looser_than_eq22(n_sec, kind, n_tasks):
    """The list-scheduling multi-task host term is term-by-term <= eq. 22,
    and identical to it for a single task (where both reduce to eq. 18)."""
    topo = TOPOLOGIES[kind](n_sec)
    tight = halp_closed_form(NET, topology=topo, n_tasks=n_tasks)["total"]
    legacy = halp_closed_form(
        NET, topology=topo, n_tasks=n_tasks, multitask_bound="eq22"
    )["total"]
    assert tight <= legacy + 1e-15, (tight, legacy)
    if n_tasks == 1:
        assert tight == legacy


def test_tightened_bound_strictly_tighter_where_k_gt_1():
    """With K > 1 zones and skewed links the tightening is strict (the whole
    point of generalising eq. 22 for the multi-zone case)."""
    for n_sec in (3, 5):
        topo = skew_topology(n_sec)
        tight = halp_closed_form(NET, topology=topo, n_tasks=4)["total"]
        legacy = halp_closed_form(
            NET, topology=topo, n_tasks=4, multitask_bound="eq22"
        )["total"]
        assert tight < legacy, (n_sec, tight, legacy)


def test_multitask_bound_rejects_unknown_mode():
    with pytest.raises(ValueError, match="multitask_bound"):
        halp_closed_form(NET, GTX_1080TI, Link(40e9), multitask_bound="magic")


# ---------------------------------------------------------------------------
# Vectorized DES + batched evaluator: float equality with the scalar engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_sec,kind,n_tasks", GRID)
def test_run_batch_matches_scalar_sim(n_sec, kind, n_tasks):
    """Both ``run_batch`` code paths (plain-float small-batch and numpy
    wide-batch) must reproduce the scalar ``Sim.run`` makespan exactly."""
    topo = TOPOLOGIES[kind](n_sec)
    res = simulate_halp(NET, topology=topo, n_tasks=n_tasks)
    sim = res["sim"]
    small = sim.run_batch()  # B=1: the plain-float path
    assert float(small.makespan[0]) == res["total"]
    durations = np.array([[job.duration for job in sim.jobs]])
    wide = sim.run_batch(np.repeat(durations, 40, axis=0))  # forces numpy path
    assert all(float(m) == res["total"] for m in wide.makespan)


@pytest.mark.parametrize("n_sec,kind,n_tasks", GRID)
def test_batched_evaluator_matches_evaluate_plan(n_sec, kind, n_tasks):
    """Layout + template + run_batch candidate scores == plan build + DAG
    build + scalar DES, bit for bit, across ratios/overlap candidates."""
    topo = TOPOLOGIES[kind](n_sec)
    n = topo.n_secondaries
    skewed = tuple(j + 1.0 for j in range(n))
    total = sum(skewed)
    cands = [
        (tuple(1.0 / n for _ in range(n)), 4),
        (tuple(r / total for r in skewed), 2),
        (tuple(r / total for r in reversed(skewed)), 8),
    ]
    evaluator = HalpBatchEvaluator(NET, topo, n_tasks=n_tasks)
    batched = evaluator.evaluate(cands)
    scalar = [evaluate_plan(NET, topo, r, w, n_tasks=n_tasks) for r, w in cands]
    assert batched == scalar


def test_multitask_evaluator_matches_simulate_placement():
    """The shared-pool (physical-resource) template path must equal the
    scalar multi-task DES on makespan, mean delay, and per-task finishes."""
    from repro.core.placement import shared_plan_placement, simulate_placement

    pool = skew_topology(5).with_links({})
    ev = MultitaskBatchEvaluator(NET, pool)
    groups = (("e1", "e4"), ("e2", "e3", "e5"))
    res = ev.evaluate([groups])[0]
    from repro.core.partition import plan_halp_topology

    plans = [
        plan_halp_topology(NET, pool.sub_topology(g), overlap_rows=4)
        for g in groups
    ]
    from repro.core.placement import _simulate_plans

    ref = _simulate_plans(NET, plans, pool)
    assert res["total"] == ref["total"]
    assert res["avg_delay"] == ref["avg_delay"]
    assert res["per_task_finish"] == tuple(ref["per_task_finish"])


@given(
    n_sec=st.integers(min_value=2, max_value=4),
    overlap=st.sampled_from([2, 4, 6, 8]),
    data=st.data(),
)
@settings(max_examples=10, deadline=None)
def test_run_batch_matches_scalar_under_random_plans_and_slowdowns(
    n_sec, overlap, data
):
    """Property: for random ratios, overlap widths, and per-resource slowdown
    factors, the vectorized forward pass equals the scalar DES exactly."""
    raw = [
        data.draw(st.integers(min_value=1, max_value=9), label=f"r{j}")
        for j in range(n_sec)
    ]
    ratios = tuple(r / sum(raw) for r in raw)
    topo = skew_topology(n_sec)
    res = simulate_halp(NET, topology=topo, ratios=ratios, overlap_rows=overlap)
    sim = res["sim"]
    resources = sorted({job.resource for job in sim.jobs})
    for res_name in resources[:: max(1, len(resources) // 3)]:
        sim.slowdown[res_name] = 1.0 + data.draw(
            st.integers(min_value=0, max_value=30), label="slow"
        ) / 10.0
    scalar = sim.run()
    batch = sim.run_batch()
    assert float(batch.makespan[0]) == scalar
    # and the wide-batch numpy path agrees with itself and the scalar run
    durations = np.array([[job.duration for job in sim.jobs]])
    wide = sim.run_batch(np.repeat(durations, 40, axis=0))
    assert all(float(m) == scalar for m in wide.makespan)


@given(
    n_sec=st.integers(min_value=2, max_value=4),
    overlap=st.sampled_from([2, 4, 6, 8]),
    n_tasks=st.sampled_from([1, 3]),
    data=st.data(),
)
@settings(max_examples=10, deadline=None)
def test_batched_evaluator_property(n_sec, overlap, n_tasks, data):
    """Property: batched candidate scores equal the scalar pricing path for
    random ratio simplex points (including heavily skewed, auto-reducing and
    infeasible ones, which must price +inf identically)."""
    raw = [
        data.draw(st.integers(min_value=0, max_value=9), label=f"r{j}")
        for j in range(n_sec)
    ]
    if sum(raw) == 0:
        raw[0] = 1
    ratios = tuple(r / sum(raw) for r in raw)
    topo = skew_topology(n_sec)
    evaluator = HalpBatchEvaluator(NET, topo, n_tasks=n_tasks)
    batched = evaluator.evaluate([(ratios, overlap)])
    scalar = [evaluate_plan(NET, topo, ratios, overlap, n_tasks=n_tasks)]
    assert batched == scalar


# ---------------------------------------------------------------------------
# Per-stage partitioning schemes: mixed-scheme DAG pricing + lossless execution
# ---------------------------------------------------------------------------
#
# The scheme DAG (``events.build_scheme_dag``) must be the *same simulator* as
# the legacy HALP DAG wherever the spaces coincide: an all-halo assignment
# prices float-identically to ``evaluate_plan`` at n_tasks=1 (at n_tasks>1 the
# scheme DAG serialises segment barriers through the host FIFO, a deliberately
# tighter ordering, so equality is only claimed for the single-task pricing
# the planner search uses).  The batched candidate evaluator must equal the
# scalar engine to float equality on every scheme cell, mirroring the
# HalpBatchEvaluator contract above.

SCHEME_RATIOS = (0.5, 0.3, 0.2)


def _scheme_assignment(net, scheme_kind):
    spans = stage_spans(net)
    options = [stage_scheme_options(net, sp, SCHEMES) for sp in spans]
    if scheme_kind == "halo":
        return tuple(SCHEME_HALO for _ in spans)
    if scheme_kind == "non_penetrative":
        return tuple(SCHEME_NP if SCHEME_NP in o else o[0] for o in options)
    assert scheme_kind == "mixed"
    return tuple(
        (SCHEME_NP if si % 2 else SCHEME_HALO)
        if (SCHEME_NP if si % 2 else SCHEME_HALO) in opts
        else opts[0]
        for si, opts in enumerate(options)
    )


@pytest.mark.parametrize("kind", ["sym", "skew"])
@pytest.mark.parametrize("scheme_kind", ["halo", "non_penetrative", "mixed"])
def test_scheme_grid_batched_matches_scalar(scheme_kind, kind):
    """Every {scheme} x {topology} cell: the batched scheme evaluator equals
    the scalar DES bit for bit, and the all-halo cells collapse onto the
    legacy HALP pricing path exactly."""
    topo = TOPOLOGIES[kind](3)
    assignment = _scheme_assignment(NET, scheme_kind)
    total = simulate_scheme(
        NET, topo, ratios=SCHEME_RATIOS, overlap_rows=4, assignment=assignment
    )["total"]
    assert math.isfinite(total) and total > 0
    batched = SchemeBatchEvaluator(NET, topo).evaluate(
        [(SCHEME_RATIOS, 4, assignment)]
    )
    assert batched == [total]
    if scheme_kind == "halo":
        assert total == evaluate_plan(NET, topo, SCHEME_RATIOS, 4, n_tasks=1)


def test_all_halo_scheme_plan_is_the_halp_plan():
    """Choosing halo_segment for every stage must reproduce
    ``plan_halp_topology``'s plan *exactly* -- the scheme layer is a strict
    superset of the legacy planner, not a fork of it."""
    from repro.core import plan_halp_topology

    topo = skew_topology(3)
    sp = plan_scheme(
        NET, topo, overlap_rows=4, ratios=SCHEME_RATIOS,
        assignment=_scheme_assignment(NET, "halo"),
    )
    hp = plan_halp_topology(NET, topo, ratios=SCHEME_RATIOS, overlap_rows=4)
    assert len(sp.segments) == 1  # all-halo stages fuse into one segment
    assert sp.segments[0].scheme == SCHEME_HALO
    sub = sp.halo_plans[0]
    # the segment subnet is the same geometry under a span-suffixed name
    assert sub.net.layers == hp.net.layers
    assert sub.net.in_rows == hp.net.in_rows
    assert dataclasses.replace(sub, net=hp.net) == hp


@given(overlap=st.sampled_from([2, 4, 8]), data=st.data())
@settings(max_examples=10, deadline=None)
def test_scheme_batched_evaluator_property(overlap, data):
    """Property: random per-stage scheme assignments and random ratio simplex
    points price float-identically through the batched evaluator and the
    scalar scheme DES."""
    spans = stage_spans(NET)
    assignment = tuple(
        data.draw(st.sampled_from(stage_scheme_options(NET, sp, SCHEMES)), label=f"s{si}")
        for si, sp in enumerate(spans)
    )
    raw = [
        data.draw(st.integers(min_value=1, max_value=9), label=f"r{j}")
        for j in range(3)
    ]
    ratios = tuple(r / sum(raw) for r in raw)
    topo = skew_topology(3)
    scalar = simulate_scheme(
        NET, topo, ratios=ratios, overlap_rows=overlap, assignment=assignment
    )["total"]
    batched = SchemeBatchEvaluator(NET, topo).evaluate([(ratios, overlap, assignment)])
    assert batched == [scalar]


_EXEC_CACHE: dict = {}


def _exec_setup():
    """Small runnable VGG (module-level cache; jax imports lazily so the
    pricing-only tests above stay importable without touching jax)."""
    if not _EXEC_CACHE:
        import jax

        from repro.models import vgg

        cfg = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10)
        params = vgg.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
        _EXEC_CACHE.update(
            cfg=cfg, params=params, x=x, ref=vgg.features(params, cfg, x)
        )
    return _EXEC_CACHE


@given(overlap=st.sampled_from([2, 4]), data=st.data())
@settings(max_examples=6, deadline=None)
def test_random_mixed_scheme_plans_execute_lossless(overlap, data):
    """Property: random mixed-scheme plans (random per-stage assignment drawn
    from each stage's legal vocabulary, random capacity ratios) execute
    through ``run_plan`` to the single-device reference within float noise --
    the executable-losslessness backstop for every scheme, not just halo."""
    from repro.models import vgg
    from repro.spatial import run_plan

    env = _exec_setup()
    net = env["cfg"].geom()
    spans = stage_spans(net)
    assignment = tuple(
        data.draw(st.sampled_from(stage_scheme_options(net, sp, SCHEMES)), label=f"s{si}")
        for si, sp in enumerate(spans)
    )
    raw = [
        data.draw(st.integers(min_value=1, max_value=3), label=f"r{j}")
        for j in range(2)
    ]
    ratios = tuple(r / sum(raw) for r in raw)
    topo = sym_topology(2)
    plan = plan_scheme(
        net, topo, overlap_rows=overlap, ratios=ratios, assignment=assignment
    )
    out = run_plan(plan, env["params"]["features"], vgg.apply_layer, env["x"])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(env["ref"]), rtol=2e-5, atol=2e-5
    )


def test_joint_scheme_search_engine_equality():
    """Optimizer engine-equality extended to the enlarged (scheme-per-stage,
    ratios, overlap) space: batched and scalar engines return the identical
    plan, score, and assignment, and under an eval budget they also spend the
    identical number of evaluations before cutting."""
    from repro.core import optimize_plan

    net = vgg16_geom(in_rows=64)
    topo = skew_topology(2)
    kw = dict(overlap_choices=(4,), max_rounds=2, schemes=SCHEMES)
    rb = optimize_plan(net, topo, engine="batched", **kw)
    rs = optimize_plan(net, topo, engine="scalar", **kw)
    assert rb.makespan == rs.makespan
    assert rb.ratios == rs.ratios
    assert rb.overlap_rows == rs.overlap_rows
    assert rb.schemes == rs.schemes
    assert rb.plan == rs.plan
    bb = optimize_plan(net, topo, engine="batched", eval_budget=8, **kw)
    bs = optimize_plan(net, topo, engine="scalar", eval_budget=8, **kw)
    assert bb.makespan == bs.makespan
    assert bb.schemes == bs.schemes
    assert bb.evaluations == bs.evaluations == 8  # the budget binds (full run: 11)


@pytest.mark.parametrize("n_tasks", [1, 4])
def test_degenerate_single_es_exact(n_tasks):
    """N = 1 cell of the grid: no collaboration at all.  The closed form is
    t_pre x n_tasks (eq. 21's denominator), and a single-resource DES chain
    reproduces it exactly -- both engines share the FLOP model, so this cell
    must be equality, not a bound."""
    t_pre = standalone_time(NET, GTX_1080TI)
    sim = Sim()
    prev = None
    sizes = NET.sizes()
    for _ in range(n_tasks):
        for i, g in enumerate(NET.layers):
            prev = sim.add(
                f"g{i}", "e0",
                GTX_1080TI.compute_time(g.flops_per_out_row(sizes[i + 1]) * sizes[i + 1]),
                [prev],
            )
        prev = sim.add("head", "e0", GTX_1080TI.compute_time(NET.head_flops), [prev])
    total = sim.run()
    assert total == pytest.approx(t_pre * n_tasks, rel=1e-12)
