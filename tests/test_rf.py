"""Receptive-field arithmetic tests (paper §II, eqs. 1-4, 8-9)."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core.rf import (
    LayerGeom,
    RFState,
    conv,
    input_range_exact,
    input_range_paper,
    out_size,
    pool,
    propagate_range,
    rf_chain,
)
from repro.core.nets import vgg16_geom


def test_out_size_eq1():
    assert out_size(224, 3, 1, 1) == 224
    assert out_size(224, 2, 2, 0) == 112
    assert out_size(224, 7, 2, 3) == 112
    assert out_size(224, 11, 4, 2) == 55  # AlexNet conv1


def test_rf_chain_vgg16_block1():
    net = vgg16_geom()
    states = rf_chain(224, net.layers)
    # conv1_1: r=3, j=1 ; conv1_2: r=5, j=1 ; pool1: r=6, j=2
    assert (states[0].rf, states[0].jump) == (3, 1)
    assert (states[1].rf, states[1].jump) == (5, 1)
    assert (states[2].rf, states[2].jump) == (6, 2)
    # output sizes follow eq. (1) through the whole chain
    assert states[-1].out == 7
    # the receptive field of the last conv (conv5_3) in VGG-16 is 196 (literature)
    assert states[-2].rf == 196 and states[-1].rf == 212


def test_input_range_exact_basics():
    # 3x3 s1 p1: output row o needs rows o-1..o+1 clipped
    assert input_range_exact(1, 10, 3, 1, 1, 224) == (1, 11)
    assert input_range_exact(5, 10, 3, 1, 1, 224) == (4, 11)
    assert input_range_exact(220, 224, 3, 1, 1, 224) == (219, 224)
    # 2x2 s2 p0 pool: output row o needs rows 2o-1..2o
    assert input_range_exact(3, 5, 2, 2, 0, 224) == (5, 10)
    # 7x7 s2 p3 stem
    assert input_range_exact(1, 1, 7, 2, 3, 224) == (1, 4)


@given(
    k=st.integers(1, 7),
    s=st.integers(1, 4),
    in_rows=st.integers(8, 64),
    data=st.data(),
)
@settings(max_examples=200, deadline=None)
def test_exact_range_covers_dependency(k, s, in_rows, data):
    """Property: computing a conv restricted to input_range_exact rows gives the
    same values as slicing the full conv output (losslessness, 1-D analogue)."""
    p = data.draw(st.integers(0, k // 2))
    if in_rows + 2 * p < k:
        return
    o = out_size(in_rows, k, s, p)
    o_lo = data.draw(st.integers(1, o))
    o_hi = data.draw(st.integers(o_lo, o))
    x = np.random.RandomState(0).randn(in_rows)
    w = np.ones(k)
    xp = np.pad(x, (p, p))
    full = np.array([xp[(i - 1) * s : (i - 1) * s + k] @ w for i in range(1, o + 1)])
    lo, hi = input_range_exact(o_lo, o_hi, k, s, p, in_rows)
    # re-run the conv on the slice only (with the padding the slice touches)
    pad_lo = p if lo == 1 else 0
    pad_hi = p if hi == in_rows else 0
    xs = np.pad(x[lo - 1 : hi], (pad_lo, pad_hi))
    offset = (o_lo - 1) * s - (lo - 1) - (p - pad_lo)
    part = np.array(
        [xs[offset + (i - o_lo) * s : offset + (i - o_lo) * s + k] @ w for i in range(o_lo, o_hi + 1)]
    )
    np.testing.assert_allclose(part, full[o_lo - 1 : o_hi], atol=1e-12)


@given(
    k=st.integers(1, 5),
    s=st.integers(1, 3),
    in_rows=st.integers(16, 64),
    data=st.data(),
)
@settings(max_examples=200, deadline=None)
def test_paper_range_covers_exact(k, s, in_rows, data):
    """Paper eqs. (8)-(9) vs. exact algebra.

    FINDING (documented in DESIGN.md): the paper's end-row formula (eq. 9,
    ``IE = sigma + (OE+1) j - floor((r-1)/2)``) *under-provisions* input rows
    whenever r > 2j + 1 -- i.e. for any single layer with k > 2s + 1 (5x5/s1
    convs, 7x7/s2 stems, ...).  It is exactly adequate for VGG-16 (k=3, s=1,
    where it coincides with the exact range), which is why the paper's own
    evaluation never trips it.  The start-row formula (eq. 8) is always exact.
    Our framework therefore partitions with the exact interval algebra.
    """
    p = data.draw(st.integers(0, k // 2))
    if in_rows + 2 * p < k:
        return
    g = LayerGeom("g", "conv", k, s, p)
    state = rf_chain(in_rows, [g])[0]
    o = state.out
    o_lo = data.draw(st.integers(1, o))
    o_hi = data.draw(st.integers(o_lo, o))
    e_lo, e_hi = input_range_exact(o_lo, o_hi, k, s, p, in_rows)
    p_lo, p_hi = input_range_paper(o_lo, o_hi, state, in_rows)
    # eq. (8) start row: always covers (and with s=1 exactly matches) the need.
    assert p_lo <= e_lo
    # closed-form deficit of eq. (9) vs. the exact end row (unclipped):
    deficit = (k - 1 - 2 * s) if k % 2 else (k - 2 - 2 * s)
    if deficit <= 0:
        # the paper's regime (VGG-16: k=3, s=1): eq. (9) provisions enough rows.
        assert p_hi >= e_hi
    elif p_hi < in_rows and e_hi < in_rows:
        # paper-bug regime (k > 2s+1): eq. (9) is short by exactly `deficit`.
        assert e_hi - p_hi == deficit


def test_propagate_range_chain():
    net = vgg16_geom()
    # the first output row of the final pool depends on a bounded input window
    ranges = propagate_range(net.layers, 224, len(net.layers) - 1, (1, 1))
    lo, hi = ranges[0]
    assert lo == 1  # clipped at the top
    states = rf_chain(224, net.layers)
    assert hi <= states[-1].rf  # bounded by the cumulative receptive field
    # ranges must be monotone (each level's range maps inside the previous)
    assert len(ranges) == len(net.layers) + 1


def test_cumulative_equals_composed_per_layer():
    """Composing exact per-layer ranges == one-shot propagate (consistency)."""
    net = vgg16_geom()
    li = 8
    ranges = propagate_range(net.layers, 224, li, (3, 20))
    sizes = net.sizes()
    lo, hi = 3, 20
    for i in range(li, -1, -1):
        g = net.layers[i]
        lo, hi = input_range_exact(lo, hi, g.k, g.s, g.p, sizes[i])
    assert (lo, hi) == ranges[0]
