"""Straggler sweep: static vs link-only vs joint compute+link adaptation.

The paper re-plans for nothing; PR 2 closed the loop for *link* rates only.
The authors' own prototype (arXiv 2211.13778) and DistrEdge (arXiv 2202.01699)
both find that measured per-device compute drifts as much as the channel: a
secondary ES that thermally throttles or picks up co-located load stretches
every makespan while holding the same row share.  This sweep replays a
straggling secondary through the discrete-event simulator and compares three
policies on identical traces (``repro.core.simulator.replay_trace``):

* **static**    -- one plan optimised for the nominal rates (the paper's
  deployment model: no measurement ever reaches the plan),
* **link_only** -- :class:`~repro.core.replan.ReplanController` with
  ``adapt_compute=False``: the PR-2 controller, blind to compute drift (it
  sees the same compute probes, but drops them),
* **joint**     -- the same controller with compute adaptation on (default):
  per-ES EWMA compute estimates -> nominal-anchored geometric bands -> the
  shared hysteresis/cache/optimise loop.

Scenario: one Xavier-class host and two Xavier-class secondaries on nominal
2.5 Gbps ES-ES links (compute-dominant at VGG-16 scale).  Secondary ``b``
straggles: its effective FLOP/s wanders over 0.3-1.0x nominal (mean-reverting
around 0.45x -- sustained degradation with recovery excursions) while both
ES-ES links drift mildly (0.8-2.5 Gbps, so the link-only controller has real
channel work to do and its disadvantage is purely the compute blindness).
Reliability per epoch is §V.D's ``Phi((D - mu_off - T_inf) / sigma)`` with
``T_inf`` the DES makespan of the plan the policy served *that epoch* under
the *true* rates, at Table III's middle fluctuation level.

A second, no-drift scenario pins the equality regression: with compute frozen
at the nominals, the joint controller must serve **identical plans** to the
link-only controller on every epoch (the nominal-anchored compute bands make
band 0's representative the exact nominal, so compute adaptivity costs
nothing until a straggler appears).

Every distinct plan the joint controller cached is executed end-to-end via
``spatial/partition_apply.run_plan`` (through
``benchmarks/replan_sweep.verify_plans_lossless``) and checked lossless
against the single-device forward.

Emits ``BENCH_straggler.json`` (``--out`` to move it, ``--smoke`` for the CI
artifact run).  Acceptance: ``tests/test_benchmarks.py::
test_straggler_sweep_acceptance`` pins the joint-vs-link-only margin, the
no-drift equality, and the losslessness count.  CSV rows
(``name,us_per_call,derived``) match the other benchmarks' format.
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    AGX_XAVIER,
    CollabTopology,
    GaussMarkovTrace,
    Link,
    OffloadChannel,
    ReplanConfig,
    ReplanController,
    StaticPlanner,
    optimize_static,
    replay_trace,
    service_reliability,
    vgg16_geom,
)

try:  # either invocation style: `python benchmarks/straggler_sweep.py` or module
    from benchmarks.replan_sweep import verify_plans_lossless  # noqa: E402
except ModuleNotFoundError:  # pragma: no cover - direct-script path setup
    sys.path.insert(0, "benchmarks")
    from replan_sweep import verify_plans_lossless  # noqa: E402

NET = vgg16_geom()
DEADLINE_S = 4.0 / 30.0  # 30 FPS with 4 tasks per batch (paper §V.D)
OFFLOAD_SIGMA_S = 9e-3  # Table III's middle fluctuation level
N_TASKS = 4
NOMINAL_BPS = 2.5e9
NOMINAL_FLOPS = AGX_XAVIER.eff_flops


def build_topology() -> CollabTopology:
    return CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={"e0": AGX_XAVIER, "a": AGX_XAVIER, "b": AGX_XAVIER},
        default_link=Link(NOMINAL_BPS),
    )


def build_traces(n_epochs: int, compute_drift: bool) -> tuple[dict, dict, list[float]]:
    """(link traces, compute traces, offload-rate trace) for one scenario.

    ``compute_drift=False`` freezes b's compute at the nominal (the equality
    scenario); the link and offload traces are identical either way."""
    trace_a = GaussMarkovTrace(
        lo=1.5e9, hi=NOMINAL_BPS, corr=0.9, sigma_frac=0.1, seed=3
    ).rates(n_epochs)
    trace_b = GaussMarkovTrace(
        lo=0.8e9, hi=NOMINAL_BPS, corr=0.9, sigma_frac=0.1, seed=5
    ).rates(n_epochs)
    link_rates = {
        ("e0", "a"): trace_a, ("a", "e0"): trace_a,
        ("e0", "b"): trace_b, ("b", "e0"): trace_b,
    }
    if compute_drift:
        straggle = GaussMarkovTrace(
            lo=0.3 * NOMINAL_FLOPS, hi=NOMINAL_FLOPS, mean=0.45 * NOMINAL_FLOPS,
            corr=0.92, sigma_frac=0.08, start=NOMINAL_FLOPS, seed=7,
        ).rates(n_epochs)
    else:
        straggle = [NOMINAL_FLOPS] * n_epochs
    compute_rates = {"b": straggle}
    offload = GaussMarkovTrace(
        lo=40e6, hi=120e6, corr=0.9, sigma_frac=0.12, seed=11
    ).rates(n_epochs)
    return link_rates, compute_rates, offload


def _metrics(results: list[dict], offload: list[float]) -> dict:
    makespans = [r["makespan"] for r in results]
    rels = [
        service_reliability(
            OffloadChannel(rate_bps=offload[i], sigma_s=OFFLOAD_SIGMA_S),
            makespans[i],
            DEADLINE_S,
        )
        for i in range(len(makespans))
    ]
    return dict(
        mean_makespan=sum(makespans) / len(makespans),
        max_makespan=max(makespans),
        mean_reliability=sum(rels) / len(rels),
        min_reliability=min(rels),
    )


def run_sweep(
    n_epochs: int = 140,
    verify: bool = True,
    max_verify_plans: int | None = None,
    include_nodrift: bool = True,
) -> dict:
    """Run all policies on shared traces; returns per-policy metrics plus the
    no-drift equality regression."""
    topo = build_topology()
    link_rates, compute_rates, offload = build_traces(n_epochs, compute_drift=True)
    config = ReplanConfig(n_tasks=N_TASKS)
    link_only_config = ReplanConfig(n_tasks=N_TASKS, adapt_compute=False)
    out: dict = {"n_epochs": n_epochs}

    static_res = optimize_static(NET, topo, config)
    static_run = replay_trace(
        NET, topo, StaticPlanner(static_res.plan),
        link_rates=link_rates, compute_rates=compute_rates, n_tasks=N_TASKS,
    )
    out["static"] = _metrics(static_run, offload)

    link_ctl = ReplanController(NET, topo, link_only_config)
    link_run = replay_trace(
        NET, topo, link_ctl,
        link_rates=link_rates, compute_rates=compute_rates, n_tasks=N_TASKS,
    )
    out["link_only"] = _metrics(link_run, offload)
    out["link_only"].update(
        optimizer_calls=link_ctl.optimizer_calls, replans=link_ctl.replans
    )

    joint_ctl = ReplanController(NET, topo, config)
    joint_run = replay_trace(
        NET, topo, joint_ctl,
        link_rates=link_rates, compute_rates=compute_rates, n_tasks=N_TASKS,
    )
    out["joint"] = _metrics(joint_run, offload)
    out["joint"].update(joint_ctl.stats())
    out["joint_vs_link_only_gain"] = (
        1.0 - out["joint"]["mean_makespan"] / out["link_only"]["mean_makespan"]
    )

    if include_nodrift:
        # equality regression: compute never drifts -> identical plans per epoch
        nl_links, nl_compute, _ = build_traces(n_epochs, compute_drift=False)
        a = ReplanController(NET, topo, config)
        b = ReplanController(NET, topo, link_only_config)
        run_a = replay_trace(
            NET, topo, a, link_rates=nl_links, compute_rates=nl_compute,
            n_tasks=N_TASKS,
        )
        run_b = replay_trace(
            NET, topo, b, link_rates=nl_links, compute_rates=nl_compute,
            n_tasks=N_TASKS,
        )
        out["nodrift_plans_equal"] = all(
            ra["plan"].parts == rb["plan"].parts for ra, rb in zip(run_a, run_b)
        )
        out["nodrift_makespans_equal"] = all(
            ra["makespan"] == rb["makespan"] for ra, rb in zip(run_a, run_b)
        )
        out["nodrift_replans"] = (a.replans, b.replans)

    if verify:
        out["plans_verified_lossless"] = verify_plans_lossless(
            joint_ctl, max_plans=max_verify_plans
        )
    return out


def run_all(smoke: bool = False, out_path: str | None = "BENCH_straggler.json") -> dict:
    out = run_sweep(
        n_epochs=40 if smoke else 140,
        max_verify_plans=3 if smoke else None,
    )
    print(
        f"\n== Straggler sweep: {out['n_epochs']} epochs, secondary b at "
        f"0.3-1.0x compute (mean 0.45x), links 0.8-2.5 Gbps, deadline "
        f"{DEADLINE_S*1e3:.1f} ms =="
    )
    print(
        f"{'policy':10s} {'mean T (ms)':>11s} {'max T (ms)':>10s} "
        f"{'mean rel':>9s} {'min rel':>9s} {'optimizes':>9s}"
    )
    for policy in ("static", "link_only", "joint"):
        m = out[policy]
        optimizes = m.get("optimizer_calls", 1 if policy == "static" else 0)
        print(
            f"{policy:10s} {m['mean_makespan']*1e3:11.2f} {m['max_makespan']*1e3:10.2f} "
            f"{m['mean_reliability']:9.6f} {m['min_reliability']:9.6f} {optimizes:9d}"
        )
        print(
            f"straggler_{policy},{m['mean_makespan']*1e6:.1f},{m['mean_reliability']:.6f}"
        )
    print(
        f"\njoint beats link-only by {out['joint_vs_link_only_gain']*100:.1f}% "
        f"mean makespan; joint cache hit rate {out['joint']['cache_hit_rate']:.3f}"
    )
    print(f"straggler_joint_gain,,{out['joint_vs_link_only_gain']:.4f}")
    if "nodrift_plans_equal" in out:
        print(
            f"no-drift equality: plans_equal={out['nodrift_plans_equal']} "
            f"makespans_equal={out['nodrift_makespans_equal']} "
            f"(joint/link-only replans {out['nodrift_replans']})"
        )
    if "plans_verified_lossless" in out:
        print(
            f"losslessness: {out['plans_verified_lossless']} distinct joint-"
            f"controller plans verified bit-compatible via run_plan"
        )
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True, default=str)
        print(f"\nwrote {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_straggler.json")
    args = ap.parse_args()
    run_all(smoke=args.smoke, out_path=args.out)
