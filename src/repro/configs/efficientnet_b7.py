"""efficientnet-b7 [vision]: img_res=600 width_mult=2.0 depth_mult=3.1.
[arXiv:1905.11946; paper]"""
from ..models import efficientnet
from ..models.efficientnet import EfficientNetConfig
from .base import Arch, register, vision_cells

FULL = EfficientNetConfig(name="efficientnet-b7", img_res=600,
                          width_mult=2.0, depth_mult=3.1)
SMOKE = EfficientNetConfig(name="efficientnet-b7-smoke", img_res=64,
                           width_mult=0.25, depth_mult=0.35, num_classes=10)

ARCH = register(
    Arch(
        name="efficientnet-b7",
        family="vision",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=vision_cells(),
        module=efficientnet,
        notes="MBConv+SE; HALP partitioning applies layer-wise, the SE global "
        "pool is the one cross-segment sync (DESIGN.md §4)",
    )
)
