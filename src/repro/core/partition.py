"""Segment-based task partitioning (paper §III, eqs. 5-9) and the HALP plan.

The host ES partitions every layer's *output rows* into contiguous **slots**
along the row axis.  Slots alternate between secondary segments and host-owned
overlapping zones (paper Fig. 2 / eqs. 6-7); with N secondaries there are
K = N - 1 zones:

    s_0 | zone_0 | s_1 | zone_1 | ... | zone_{K-1} | s_K

For the paper's symmetric pair this degenerates to the familiar triple

    rows 1..a           -> secondary e1
    rows a+1..a+w       -> host e0     (the "overlapping zone", w ~ 4 rows)
    rows a+w+1..O       -> secondary e2

Each slot's required *input rows* follow from the receptive-field arithmetic
(eqs. 8-9 / exact interval algebra), and all inter-slot messages follow from
range intersections, so the plan is lossless by construction.  Secondary
segment sizes may be *capacity-weighted* (``ratios``; DistrEdge-style unequal
splits for heterogeneous ESs), and every zone is owned by the host, preserving
the scheme's invariant that secondaries never exchange rows directly.
``plan_even`` provides the N-way even split for the TPU spatial-parallel
engine (``repro.spatial``) and the MoDNN baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, TYPE_CHECKING

from .nets import ConvNetGeom, DTYPE_BYTES
from .rf import input_range_exact

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from .topology import CollabTopology

__all__ = [
    "Segment",
    "LayerPartition",
    "HALPPlan",
    "PlanInfeasible",
    "split_rows",
    "plan_halp",
    "plan_halp_n",
    "plan_halp_topology",
    "plan_even",
]


class PlanInfeasible(ValueError):
    """A partition that cannot be realised under the HALP invariants.

    Carries the offending ``layer`` and the layers auto-reduction should try
    shrinking (``reduce_at``), so :func:`plan_halp_n` can degrade gracefully
    instead of giving up."""

    def __init__(self, layer: int, msg: str, reduce_at: tuple[int, ...] = ()):
        super().__init__(msg)
        self.layer = layer
        self.reduce_at = reduce_at or (layer,)

E1, E0, E2 = "e1", "e0", "e2"  # paper's ES names; e0 is the host


@dataclass(frozen=True)
class Segment:
    """1-indexed inclusive row range; empty iff lo > hi."""

    lo: int
    hi: int

    @property
    def rows(self) -> int:
        return max(0, self.hi - self.lo + 1)

    def intersect(self, other: "Segment") -> "Segment":
        return Segment(max(self.lo, other.lo), min(self.hi, other.hi))

    def __bool__(self) -> bool:  # truthy iff non-empty
        return self.rows > 0


EMPTY = Segment(1, 0)


@dataclass(frozen=True)
class LayerPartition:
    """Partition of one layer: output segments and required input ranges per slot."""

    index: int
    out: dict[str, Segment]
    inp: dict[str, Segment]  # exact input rows each slot needs (eqs. 8-9, exact form)


@dataclass(frozen=True)
class HALPPlan:
    net: ConvNetGeom
    parts: tuple[LayerPartition, ...]
    es_names: tuple[str, ...]  # slot names in row order: (e1, e0, e2) or N-way
    host: str = E0  # the ES that owns every overlapping zone
    slot_owner: tuple[str, ...] = ()  # parallel to es_names; () -> slots own themselves

    def owner_of(self, slot: str) -> str:
        """The physical ES that computes ``slot`` (zones resolve to the host)."""
        if self.slot_owner:
            return self.slot_owner[self.es_names.index(slot)]
        return slot

    @property
    def secondary_slots(self) -> tuple[str, ...]:
        return tuple(s for s in self.es_names if self.owner_of(s) != self.host)

    @property
    def zone_slots(self) -> tuple[str, ...]:
        return tuple(s for s in self.es_names if self.owner_of(s) == self.host)

    def adjacent_zones(self, sec_slot: str) -> tuple[str, ...]:
        """Host zone slots bordering a secondary slot (above first, in row order)."""
        idx = self.es_names.index(sec_slot)
        out = []
        for j in (idx - 1, idx + 1):
            if 0 <= j < len(self.es_names) and self.owner_of(self.es_names[j]) == self.host:
                out.append(self.es_names[j])
        return tuple(out)

    def adjacent_secondaries(self, zone_slot: str) -> tuple[str, str]:
        """The (above, below) secondary slots bordering a host zone."""
        idx = self.es_names.index(zone_slot)
        return self.es_names[idx - 1], self.es_names[idx + 1]

    def owner_rows(self, layer: int, es: str) -> Segment:
        return self.parts[layer].out[es]

    def active_secondaries(self, layer: int) -> tuple[str, ...]:
        """Secondary slots owning at least one row at ``layer`` (auto-reduced
        or ratio-starved slots drop out of this list)."""
        return tuple(s for s in self.secondary_slots if self.parts[layer].out[s])

    def message(self, layer: int, src: str, dst: str) -> Segment:
        """Rows of layer ``layer``'s *output* that src owns and dst needs as
        input for layer ``layer + 1`` (or for the head merge if last layer)."""
        if layer + 1 >= len(self.parts):
            # final layer: everything the secondaries own is sent to the host
            # to be merged as the FL input (paper eqs. 13-14, g_i = g_N case).
            if dst == self.host and self.owner_of(src) != self.host:
                return self.parts[layer].out[src]
            return EMPTY
        need = self.parts[layer + 1].inp[dst]
        own = self.parts[layer].out[src]
        got = self.parts[layer].out[dst]
        inter = need.intersect(own)
        if not inter or src == dst:
            return EMPTY
        # dst already owns `got`; only rows outside it must travel.
        pieces = []
        if inter.lo < got.lo:
            pieces.append(Segment(inter.lo, min(inter.hi, got.lo - 1)))
        if inter.hi > got.hi:
            pieces.append(Segment(max(inter.lo, got.hi + 1), inter.hi))
        if not pieces:
            return EMPTY
        if len(pieces) == 1:
            return pieces[0]
        # src on both sides of dst cannot happen with contiguous ordered segments
        raise AssertionError("non-contiguous message; segment ordering violated")

    def message_bytes(self, layer: int, src: str, dst: str) -> float:
        seg = self.message(layer, src, dst)
        if not seg:
            return 0.0
        g = self.net.layers[layer]
        width = self.net.sizes()[layer + 1]
        return DTYPE_BYTES * seg.rows * width * g.c_out


def split_rows(total: int, ratios: Sequence[float]) -> list[Segment]:
    """Paper eqs. (6)-(7) generalised: contiguous segments by cumulative ratio.

    Segments exactly cover 1..total; rounding via the cumulative boundary keeps
    every segment within +-1 row of its exact ratio share.  Heavily skewed
    ratios on small totals may produce *empty* segments (lo > hi) -- callers
    that need a minimum occupancy must redistribute (see ``plan_halp_n``)."""
    if total < 0:
        raise ValueError(f"total must be >= 0, got {total}")
    if abs(sum(ratios) - 1.0) > 1e-9:
        raise ValueError(f"ratios must sum to 1, got {sum(ratios)}")
    bounds = [0]
    acc = 0.0
    for r in ratios[:-1]:
        acc += r
        bounds.append(min(total, max(bounds[-1], int(round(acc * total)))))
    bounds.append(total)
    return [Segment(lo + 1, hi) for lo, hi in zip(bounds[:-1], bounds[1:])]


def _align_down(x: int, align: int) -> int:
    return (x // align) * align


def _pool_alignment(net: ConvNetGeom, i: int, o: int) -> int:
    """Product of pooling strides between layer i and the next conv, reduced
    until it is small relative to the feature map (seed heuristic)."""
    align = 1
    for h in net.layers[i + 1 :]:
        if h.kind != "pool":
            break
        align *= h.s
    while align > max(1, o // 4):
        align //= 2
    return max(1, align)


def _min_one_unit(counts: list[int], body_u: int) -> list[int]:
    """Give every secondary at least one unit when the body is large enough,
    taking units from the largest segment (largest-remainder style fixup)."""
    n = len(counts)
    if body_u < n:
        return counts
    counts = list(counts)
    while min(counts) < 1:
        counts[counts.index(max(counts))] -= 1
        counts[counts.index(min(counts))] += 1
    return counts


def _conv_slot_rows(
    o: int, overlap_rows: int, ratios: Sequence[float], align: int
) -> list[int]:
    """Row counts of the 2K+1 slots (sec, zone, sec, ..., sec) for one conv layer.

    Works in units of ``align`` so that both edges of every host zone land on
    pooling-stride multiples (pools never cross a slot boundary); the last
    secondary absorbs the division remainder."""
    n_sec = len(ratios)
    k_zones = n_sec - 1
    w_eff = min(overlap_rows, max(1, o - 2))
    units = o // align
    w_u = max(1, -(-w_eff // align))  # ceil
    while units - k_zones * w_u < n_sec and w_u > 1:
        w_u -= 1
    body_u = units - k_zones * w_u
    if body_u < 0:
        raise ValueError(
            f"cannot fit {n_sec} secondaries + {k_zones} zones into {o} rows"
        )
    sec_u = _min_one_unit([s.rows for s in split_rows(body_u, ratios)], body_u)
    counts = []
    for j in range(n_sec):
        counts.append(sec_u[j] * align)
        if j < k_zones:
            counts.append(w_u * align)
    counts[-1] += o - units * align  # remainder rows go to the last secondary
    return counts


def _reduced_slot_rows(
    o: int, overlap_rows: int, ratios: Sequence[float], align: int, n_active: int
) -> list[int]:
    """Slot row counts when only the first ``n_active`` secondaries stay active.

    Layout (graceful degradation, part 2): the leading ``n_active`` secondaries
    keep their interleaved thin zones, the zone right after the last active
    secondary becomes a *host-owned tail* absorbing the row share of every
    dropped secondary, and all trailing slots own zero rows:

        s_0 | z_0 | ... | s_{n'-1} | tail (host) | 0 | 0 | ...

    The tail must be host-owned: at the layer where reduction kicks in, the
    dropped secondaries' previous-layer rows feed the tail region, and only
    sec->host transfers preserve the no-secondary-exchange invariant.  The
    tail therefore takes the *combined ratio share of the dropped
    secondaries*, keeping every active segment at roughly the size it has in
    the unreduced layout (so thin overlap zones still cover the boundaries)."""
    n_sec = len(ratios)
    if n_active >= n_sec:
        return _conv_slot_rows(o, overlap_rows, ratios, align)
    k_thin = n_active - 1
    w_eff = min(overlap_rows, max(1, o - 2))
    units = o // align
    w_u = max(1, -(-w_eff // align))  # ceil
    while units - k_thin * w_u < n_active + 1 and w_u > 1:
        w_u -= 1
    body_u = units - k_thin * w_u
    if body_u < n_active + 1:  # active secondaries + a non-empty host tail
        raise ValueError(
            f"cannot fit {n_active} active secondaries + a host tail into {o} rows"
        )
    shares = [*ratios[:n_active], sum(ratios[n_active:])]
    total = sum(shares)
    counts_u = [s.rows for s in split_rows(body_u, [r / total for r in shares])]
    # every active secondary and the tail need at least one unit each
    while min(counts_u) < 1:
        counts_u[counts_u.index(max(counts_u))] -= 1
        counts_u[counts_u.index(min(counts_u))] += 1
    counts = []
    for j in range(n_active):
        counts.append(counts_u[j] * align)
        if j < k_thin:
            counts.append(w_u * align)
    # host tail zone absorbs the dropped share and the alignment remainder
    counts.append(counts_u[-1] * align + (o - units * align))
    counts.extend([0] * (2 * (n_sec - n_active) - 1))
    return counts


def plan_halp(
    net: ConvNetGeom,
    overlap_rows: int = 4,
    es_names: tuple[str, str, str] = (E1, E0, E2),
    ratios: Sequence[float] | None = None,
    auto_reduce: bool = True,
) -> HALPPlan:
    """The paper's 2-secondary HALP partition (§IV.A) -- thin wrapper over
    :func:`plan_halp_n` preserving the original ``(e1, e0, e2)`` interface."""
    lo_name, host, hi_name = es_names
    return plan_halp_n(
        net,
        secondaries=(lo_name, hi_name),
        host=host,
        overlap_rows=overlap_rows,
        ratios=ratios,
        auto_reduce=auto_reduce,
    )


def plan_halp_n(
    net: ConvNetGeom,
    secondaries: Sequence[str],
    host: str = E0,
    overlap_rows: int = 4,
    ratios: Sequence[float] | None = None,
    auto_reduce: bool = True,
) -> HALPPlan:
    """Build the N-way heterogeneous HALP partition.

    Per conv layer, K = N - 1 host zones of ``overlap_rows`` output rows are
    interleaved with N secondary segments whose sizes follow ``ratios``
    (default: equal; pass capacity weights for heterogeneous ESs).  Zone
    boundaries are kept aligned to the strides of the pooling layers that
    follow *before the next conv* (where the partition is re-balanced anyway),
    so pools never cross a slot boundary (paper: "the host ES does not need to
    send the output of the current CL ... for the pooling layer").  Pool
    layers inherit the previous layer's boundaries divided by the stride.

    The plan asserts the scheme's invariant that secondaries never exchange
    rows directly: all boundary traffic flows through the host.  Layers too
    thin to give every secondary at least one alignment unit degrade
    gracefully in two stages.  First, smaller-ratio secondaries may own
    *zero* rows at a layer (they idle; the plan stays lossless).  Second,
    with ``auto_reduce`` (the default), layers where even that breaks the
    invariant -- more slots than rows, or a thin slot forcing a
    secondary-secondary message -- shrink to fewer *active* secondaries: the
    trailing secondaries are dropped from that depth on (monotone -- once
    dropped, an ES stays idle for the rest of the net) and the host absorbs
    their row share in a widened tail zone (:func:`_reduced_slot_rows`).
    Order secondaries fastest-first so reductions shed the weakest ESs.
    Only when even a single active secondary cannot hold a layer does the
    partitioner raise, with the remediation in the message.  With
    ``auto_reduce=False`` any violation raises immediately (the pre-reduction
    behaviour, kept for strict-isolation callers and error-path tests)."""
    secondaries = tuple(secondaries)
    n_sec = len(secondaries)
    if n_sec < 2:
        raise ValueError("HALP needs at least two secondaries around the host")
    if host in secondaries:
        raise ValueError(f"host {host!r} cannot also be a secondary")
    if ratios is None:
        ratios = [1.0 / n_sec] * n_sec
    if len(ratios) != n_sec:
        raise ValueError("need one ratio per secondary")
    total_ratio = sum(ratios)
    if total_ratio <= 0 or any(r < 0 for r in ratios):
        raise ValueError(f"ratios must be non-negative with a positive sum, got {ratios}")
    ratios = [r / total_ratio for r in ratios]
    n_layers = len(net.layers)
    # a cap only changes the layout of a *conv* layer; pools inherit, so a
    # reduction aimed at a pool must land on the conv it inherits from
    conv_anchor: list[int] = []
    for i, g in enumerate(net.layers):
        conv_anchor.append(i if g.kind != "pool" or i == 0 else conv_anchor[i - 1])
    caps = [n_sec] * n_layers
    for _ in range(n_sec * n_layers + 1):
        try:
            plan = _build_plan(
                net, secondaries, host, overlap_rows, ratios, caps, auto_reduce
            )
            _check_plan_messages(plan)
            return plan
        except PlanInfeasible as exc:
            if not auto_reduce or not _reduce_caps(caps, exc, conv_anchor):
                raise
    raise AssertionError("auto-reduce failed to converge")  # pragma: no cover


def _reduce_caps(caps: list[int], exc: PlanInfeasible, conv_anchor: list[int]) -> bool:
    """Shrink the active-secondary cap at the first reducible layer the
    violation names; False when every candidate is already at one secondary
    (the 'even N=1 fails' terminal case)."""
    for j in exc.reduce_at:
        if not 0 <= j < len(caps):
            continue
        j = conv_anchor[j]
        eff = min(caps[: j + 1])
        if eff > 1:
            caps[j] = eff - 1
            return True
    return False


def _build_plan(
    net: ConvNetGeom,
    secondaries: tuple[str, ...],
    host: str,
    overlap_rows: int,
    ratios: Sequence[float],
    caps: Sequence[int],
    auto_reduce: bool,
) -> HALPPlan:
    n_sec = len(secondaries)
    k_zones = n_sec - 1
    zone_names = (
        (host,) if k_zones == 1 else tuple(f"{host}#{j}" for j in range(k_zones))
    )
    slots: list[str] = []
    owners: list[str] = []
    for j, s in enumerate(secondaries):
        slots.append(s)
        owners.append(s)
        if j < k_zones:
            slots.append(zone_names[j])
            owners.append(host)

    sizes = net.sizes()
    parts: list[LayerPartition] = []
    active = n_sec
    for i, g in enumerate(net.layers):
        o = sizes[i + 1]
        if auto_reduce:
            # monotone: a cap at any earlier layer (pools included) holds on
            active = min(active, caps[i])
        if g.kind == "pool":
            # pools inherit the previous layer's boundaries (divided by stride).
            prev = parts[-1].out
            out = {}
            lo = 1
            for j, slot in enumerate(slots):
                hi = o if j == len(slots) - 1 else prev[slot].hi // g.s
                out[slot] = Segment(lo, hi)
                lo = hi + 1
        else:
            align = _pool_alignment(net, i, o)
            if not auto_reduce:
                counts = _conv_slot_rows(o, overlap_rows, ratios, align)
            else:
                while True:
                    try:
                        counts = _reduced_slot_rows(o, overlap_rows, ratios, align, active)
                        break
                    except ValueError as err:
                        if active <= 1:
                            raise PlanInfeasible(
                                i,
                                f"layer {i} ({o} output rows): {err}; even a single "
                                f"active secondary does not fit -- use a larger input "
                                f"or run this layer on one ES",
                                reduce_at=(i,),
                            ) from err
                        active -= 1
            out = {}
            lo = 1
            for slot, cnt in zip(slots, counts):
                out[slot] = Segment(lo, lo + cnt - 1)
                lo += cnt
        inp = {
            es: (
                Segment(*input_range_exact(seg.lo, seg.hi, g.k, g.s, g.p, sizes[i]))
                if seg
                else EMPTY
            )
            for es, seg in out.items()
        }
        parts.append(LayerPartition(index=i, out=out, inp=inp))
    return HALPPlan(
        net=net,
        parts=tuple(parts),
        es_names=tuple(slots),
        host=host,
        slot_owner=tuple(owners),
    )


def plan_halp_topology(
    net: ConvNetGeom,
    topology: "CollabTopology",
    overlap_rows: int = 4,
    ratios: Sequence[float] | None = None,
    auto_reduce: bool = True,
) -> HALPPlan:
    """HALP plan for a :class:`~repro.core.topology.CollabTopology`.

    ``ratios`` defaults to the topology's compute-capacity weights (segment
    sizes proportional to effective FLOP/s)."""
    if ratios is None:
        ratios = topology.capacity_ratios()
    return plan_halp_n(
        net,
        secondaries=topology.secondaries,
        host=topology.host,
        overlap_rows=overlap_rows,
        ratios=ratios,
        auto_reduce=auto_reduce,
    )


def plan_even(net: ConvNetGeom, n: int) -> HALPPlan:
    """N-way even split (used by the TPU spatial engine and the MoDNN baseline)."""
    names = tuple(f"w{j}" for j in range(n))
    sizes = net.sizes()
    parts = []
    for i, g in enumerate(net.layers):
        o = sizes[i + 1]
        segs = split_rows(o, [1.0 / n] * n)
        out = dict(zip(names, segs))
        inp = {
            es: (
                Segment(*input_range_exact(seg.lo, seg.hi, g.k, g.s, g.p, sizes[i]))
                if seg
                else EMPTY
            )
            for es, seg in out.items()
        }
        parts.append(LayerPartition(index=i, out=out, inp=inp))
    return HALPPlan(net=net, parts=tuple(parts), es_names=names)


def _check_plan_messages(plan: HALPPlan) -> None:
    """Enforce the message invariants both latency engines rely on.

    * **Secondaries never exchange rows directly** (the scheme's hard
      invariant -- there is no secondary-secondary link).  Violations mean a
      slot is too thin for the receptive field: widen the overlap zone,
      rebalance the ratios, or let auto-reduction drop the slot.
    * **Host-zone -> secondary messages must come from an adjacent slot**:
      the zone chunk schedule (``events.zone_step``) only prices sends to the
      two neighbouring secondaries, so a skip there would be unpriced.
    * Secondary -> host messages may target *any* zone (physically a direct
      uplink; ``events.sec_step`` prices sends to every zone), and rows moving
      between two host-owned zones never leave the host (a local move; the
      host computes layers in submission order, so the rows are resident)."""
    order = {s: j for j, s in enumerate(plan.es_names)}
    host = plan.host
    for i in range(len(plan.parts) - 1):
        for a in plan.es_names:
            owner_a = plan.owner_of(a)
            for b in plan.es_names:
                if a == b:
                    continue
                owner_b = plan.owner_of(b)
                if owner_a == owner_b == host:
                    continue  # zone-to-zone: host-local move
                if owner_a != host and owner_b == host:
                    continue  # sec -> any host zone: direct uplink, priced
                adjacent = abs(order[a] - order[b]) <= 1
                if adjacent and (owner_a == host) != (owner_b == host):
                    continue  # adjacent host<->sec: the paper's boundary flow
                seg = plan.message(i, a, b)
                if not seg:
                    continue
                if owner_a != host and owner_b != host:
                    raise PlanInfeasible(
                        i,
                        f"layer {i}: secondaries {a} and {b} would exchange rows "
                        f"{seg.lo}..{seg.hi} directly; widen the overlap zone, "
                        f"rebalance the segment ratios, or enable auto_reduce",
                        reduce_at=(i + 1, i),
                    )
                raise PlanInfeasible(
                    i,
                    f"layer {i}: zone {a} would need to send rows "
                    f"{seg.lo}..{seg.hi} to non-adjacent secondary {b}; widen "
                    f"the overlap zone or rebalance the segment ratios",
                    reduce_at=(i + 1, i),
                )
