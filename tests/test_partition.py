"""Partitioner tests (paper §III eqs. 5-9 + HALP plan invariants)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core.nets import vgg16_geom
from repro.core.partition import (
    E0,
    E1,
    E2,
    PlanInfeasible,
    Segment,
    _reduce_caps,
    plan_even,
    plan_halp,
    plan_halp_n,
    split_rows,
)


def test_split_rows_covers_exactly():
    segs = split_rows(224, [0.49, 0.02, 0.49])
    assert segs[0].lo == 1 and segs[-1].hi == 224
    for a, b in zip(segs, segs[1:]):
        assert b.lo == a.hi + 1
    assert sum(s.rows for s in segs) == 224


@given(
    total=st.integers(4, 500),
    n=st.integers(2, 8),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=200, deadline=None)
def test_split_rows_property(total, n, seed):
    import random

    rng = random.Random(seed)
    raw = [rng.random() + 0.05 for _ in range(n)]
    ratios = [r / sum(raw) for r in raw]
    segs = split_rows(total, ratios)
    assert segs[0].lo == 1 and segs[-1].hi == total
    assert sum(s.rows for s in segs) == total
    for a, b in zip(segs, segs[1:]):
        assert b.lo == a.hi + 1


def test_halp_plan_vgg16_structure():
    net = vgg16_geom()
    plan = plan_halp(net, overlap_rows=4)
    sizes = net.sizes()
    for i, part in enumerate(plan.parts):
        o = sizes[i + 1]
        # segments tile 1..O in (e1, e0, e2) order
        assert part.out[E1].lo == 1
        assert part.out[E2].hi == o
        assert part.out[E0].lo == part.out[E1].hi + 1
        assert part.out[E2].lo == part.out[E0].hi + 1
        # the host zone is thin (the paper's "overlapping zone is only 4 rows")
        if net.layers[i].kind == "conv":
            assert part.out[E0].rows <= 6
        # input ranges stay inside the layer input
        for es in (E1, E0, E2):
            seg = part.inp[es]
            assert 1 <= seg.lo <= seg.hi <= sizes[i]


def test_secondaries_never_exchange():
    net = vgg16_geom()
    plan = plan_halp(net, overlap_rows=4)
    for i in range(len(plan.parts) - 1):
        assert not plan.message(i, E1, E2)
        assert not plan.message(i, E2, E1)


def test_pool_layers_need_no_host_message():
    """Paper §IV.A: 'if the next layer is pooling layer, the host does not need
    to send the output of the current CL to secondary ESs'."""
    net = vgg16_geom()
    plan = plan_halp(net, overlap_rows=4)
    for i, g in enumerate(net.layers[:-1]):
        if net.layers[i + 1].kind == "pool":
            assert plan.message_bytes(i, E0, E1) == 0.0
            assert plan.message_bytes(i, E0, E2) == 0.0


def test_paper_eq10_init_bytes():
    """Eq. (10): the initial slice to each secondary is ~half the image."""
    from repro.core.schedule import _init_bytes

    net = vgg16_geom()
    plan = plan_halp(net, overlap_rows=4)
    for ek in (E1, E2):
        nbytes = _init_bytes(plan, ek)
        # between 45% and 60% of the full 224x224x3 float32 image
        full = 4 * 224 * 224 * 3
        assert 0.45 * full < nbytes < 0.6 * full


def test_message_bytes_match_eq11_form():
    """Our range-algebra message equals the paper's eq. (11) closed form
    4*(IE^{e1}_{gi} - OS^{e0}_{g_{i-1}} + 1)*I*c for host->e1 at conv layers
    whose predecessor partition aligns (the paper's assumed regime)."""
    net = vgg16_geom()
    plan = plan_halp(net, overlap_rows=4)
    sizes = net.sizes()
    checked = 0
    for i in range(1, len(net.layers) - 1):
        g = net.layers[i]
        if g.kind != "conv" or net.layers[i - 1].kind != "conv":
            continue
        ie_e1 = plan.parts[i].inp[E1].hi
        os_e0 = plan.parts[i - 1].out[E0].lo
        if ie_e1 < os_e0:
            continue
        expected = 4 * (ie_e1 - os_e0 + 1) * sizes[i] * g.c_in
        assert plan.message_bytes(i - 1, E0, E1) == expected
        checked += 1
    assert checked >= 4


def test_feasibility_boundary_pinned_vgg16():
    """Regression-pin the jagged feasibility boundary in N on VGG-16, so
    future partitioner changes cannot silently shift it:

    * N=5 and N=8 never trigger auto-reduction -- the strict-isolation plan
      is identical to the default one (their thin layers degrade via
      *idle slots* only: N=5 idles two slots at g16-17, N=8 idles e5 at the
      14x14 block and hands the whole 14-row layers to the host),
    * N=6 is the jagged hole: strict mode raises at the 14-row depth, the
      default auto-reduces to one active secondary there with the host
      absorbing the tail."""
    net = vgg16_geom()
    sizes = net.sizes()

    # --- N=5 / N=8: idle-slot degradation only; auto-reduce is a no-op
    for n in (5, 8):
        secs = tuple(f"e{j}" for j in range(1, n + 1))
        default = plan_halp_n(net, secondaries=secs, overlap_rows=4)
        strict = plan_halp_n(net, secondaries=secs, overlap_rows=4, auto_reduce=False)
        for a, b in zip(default.parts, strict.parts):
            assert a.out == b.out, (n, a.index)

    plan5 = plan_halp_n(net, secondaries=tuple(f"e{j}" for j in range(1, 6)))
    assert plan5.active_secondaries(15) == ("e1", "e2", "e3", "e4", "e5")
    assert plan5.active_secondaries(16) == ("e1", "e3", "e5")  # e2/e4 idle
    assert plan5.active_secondaries(17) == ("e1", "e3", "e5")

    plan8 = plan_halp_n(net, secondaries=tuple(f"e{j}" for j in range(1, 9)))
    for layer in (12, 13, 14, 15):
        assert "e5" not in plan8.active_secondaries(layer)
        assert len(plan8.active_secondaries(layer)) == 7
    # the 14-row layers fit 7 host zones + nothing else: host owns everything
    assert plan8.active_secondaries(16) == ()
    assert sum(plan8.parts[16].out[z].rows for z in plan8.zone_slots) == sizes[17]

    # --- N=6: the hole.  Strict mode raises (the pre-PR boundary) ...
    with pytest.raises(PlanInfeasible, match="exchange rows"):
        plan_halp_n(
            net, secondaries=tuple(f"e{j}" for j in range(1, 7)), auto_reduce=False
        )
    # ... and the default reduces g16-17 to one active secondary + host tail.
    plan6 = plan_halp_n(net, secondaries=tuple(f"e{j}" for j in range(1, 7)))
    acts = [len(plan6.active_secondaries(i)) for i in range(len(plan6.parts))]
    assert acts == [6] * 16 + [1, 1]
    assert plan6.parts[16].out["e1"] == Segment(1, 2)
    assert plan6.parts[16].out["e0#0"] == Segment(3, 14)  # host-owned tail
    for s in ("e2", "e3", "e4", "e5", "e6"):
        assert not plan6.parts[16].out[s]


def test_auto_reduce_terminal_case_raises():
    """_reduce_caps refuses once every candidate layer is down to one active
    secondary -- the 'even N=1 fails' terminal that keeps the loud raise."""
    exc = PlanInfeasible(0, "x", reduce_at=(1, 0))
    caps = [2, 2]
    assert _reduce_caps(caps, exc, [0, 1]) is True and caps == [2, 1]
    assert _reduce_caps(caps, exc, [0, 1]) is True and caps == [1, 1]
    assert _reduce_caps(caps, exc, [0, 1]) is False  # both candidates at 1
    # out-of-range candidates are skipped, not crashed on
    assert _reduce_caps([3], PlanInfeasible(0, "x", reduce_at=(5,)), [0]) is False


def test_plan_even_tiles():
    net = vgg16_geom()
    for n in (2, 3, 4, 8):
        plan = plan_even(net, n)
        for i, part in enumerate(plan.parts):
            o = net.sizes()[i + 1]
            segs = [part.out[w] for w in plan.es_names]
            assert segs[0].lo == 1 and segs[-1].hi == o
            assert sum(s.rows for s in segs) == o
