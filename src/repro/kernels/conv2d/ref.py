"""Pure-jnp oracle for the conv2d kernel (no lax.conv -- explicit tap sum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(
    x: jax.Array, weights: jax.Array, bias: jax.Array | None = None, *, padding: int = 1
) -> jax.Array:
    """Stride-1 conv, NHWC x [k,k,Cin,Cout]; sum of shifted einsums."""
    k = weights.shape[0]
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    n, h, w, cin = x.shape
    ho, wo = h - (k - 1), w - (k - 1)
    acc = jnp.zeros((n, ho, wo, weights.shape[-1]), jnp.float32)
    for ky in range(k):
        for kx in range(k):
            patch = x[:, ky : ky + ho, kx : kx + wo, :].astype(jnp.float32)
            acc = acc + jnp.einsum("nhwc,cd->nhwd", patch, weights[ky, kx].astype(jnp.float32))
    if bias is not None:
        acc = acc + bias
    return acc.astype(x.dtype)
