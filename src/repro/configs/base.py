"""Arch registry: every assigned architecture is a selectable config exposing a
uniform interface consumed by the launcher, the dry-run, the smoke tests and
the serving/training drivers.

An :class:`Arch` carries the *exact* assigned full config, a reduced smoke
config (same family, tiny dims) and one :class:`Cell` per assigned input shape.
``repro.configs.steps`` builds the jit-able step function + abstract input
specs for any (arch, cell); ``repro.parallel.sharding`` owns the partitioning.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

__all__ = ["Cell", "Arch", "REGISTRY", "register", "get", "list_archs"]


@dataclass(frozen=True)
class Cell:
    """One assigned (architecture x input shape) cell."""

    name: str
    kind: str  # "train" | "prefill" | "decode" | "gen" | "serve"
    meta: dict = field(default_factory=dict)  # batch, seq_len, img_res, steps...
    skip: str | None = None  # reason if the cell is inapplicable (recorded)


@dataclass(frozen=True)
class Arch:
    name: str
    family: str  # "lm" | "vision" | "diffusion" | "convnet"
    cfg: Any
    smoke_cfg: Any
    cells: dict[str, Cell]
    module: Any  # the model module (init/apply/loss_fn)
    notes: str = ""


REGISTRY: dict[str, Arch] = {}


def register(arch: Arch) -> Arch:
    REGISTRY[arch.name] = arch
    return arch


def get(name: str) -> Arch:
    if name not in REGISTRY:
        # import side-effect registration
        from . import _load_all  # noqa

        _load_all()
    return REGISTRY[name]


def list_archs() -> list[str]:
    from . import _load_all

    _load_all()
    return sorted(REGISTRY)


# The assigned LM shape set (shared by the 4 LM archs).
def lm_cells(*, full_attention: bool) -> dict[str, Cell]:
    cells = {
        "train_4k": Cell("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
        "prefill_32k": Cell(
            "prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}
        ),
        "decode_32k": Cell(
            "decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}
        ),
        "long_500k": Cell(
            "long_500k",
            "decode",
            {"seq_len": 524288, "global_batch": 1},
            skip=(
                "full-attention architecture; long_500k requires sub-quadratic "
                "attention per the assignment (skip recorded in DESIGN.md)"
                if full_attention
                else None
            ),
        ),
    }
    return cells


def vision_cells() -> dict[str, Cell]:
    return {
        "cls_224": Cell("cls_224", "train", {"img_res": 224, "batch": 256}),
        "cls_384": Cell("cls_384", "train", {"img_res": 384, "batch": 64}),
        "serve_b1": Cell("serve_b1", "serve", {"img_res": 224, "batch": 1}),
        "serve_b128": Cell("serve_b128", "serve", {"img_res": 224, "batch": 128}),
    }


def diffusion_cells() -> dict[str, Cell]:
    return {
        "train_256": Cell("train_256", "train", {"img_res": 256, "batch": 256, "steps": 1000}),
        "gen_1024": Cell("gen_1024", "gen", {"img_res": 1024, "batch": 4, "steps": 50}),
        "gen_fast": Cell("gen_fast", "gen", {"img_res": 512, "batch": 16, "steps": 4}),
        "train_1024": Cell("train_1024", "train", {"img_res": 1024, "batch": 32, "steps": 1000}),
    }
