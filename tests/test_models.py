"""Model-component tests: MoE dispatch vs. per-token oracle, chunked vs. full
attention, RoPE properties, norm invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.models.attention import (
    GQAConfig,
    _sdpa,
    _sdpa_chunked_causal,
    apply_rope,
    causal_mask,
    gqa_apply,
    gqa_init,
)
from repro.models.common import norm_params
from repro.models.layers import layernorm, rmsnorm, softmax_xent
from repro.models.moe import MoEConfig, moe_apply, moe_init, router_topk


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe_oracle(p, cfg, x):
    """Per-token loop: route each token to its top-k experts, no capacity."""
    gates, ids, _ = router_topk(p, cfg, x)
    w = p["experts"]
    outs = []
    for t in range(x.shape[0]):
        acc = jnp.zeros_like(x[t])
        for j in range(cfg.top_k):
            e = int(ids[t, j])
            h = jax.nn.silu(x[t] @ w["w1"][e]) * (x[t] @ w["w3"][e])
            acc = acc + gates[t, j] * (h @ w["w2"][e])
        outs.append(acc)
    y = jnp.stack(outs)
    if cfg.n_shared:
        s = p["shared"]
        y = y + (jax.nn.silu(x @ s["w1"]["w"]) * (x @ s["w3"]["w"])) @ s["w2"]["w"]
    return y


@pytest.mark.parametrize("n_shared", [0, 1])
def test_moe_matches_per_token_oracle(n_shared):
    cfg = MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff=48, n_shared=n_shared,
                    capacity_factor=8.0)  # dropless
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 32))
    got, aux = moe_apply(p, cfg, x)
    want = _moe_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    assert float(aux["dropped_frac"]) == 0.0


def test_moe_capacity_drops_are_bounded():
    cfg = MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff=16,
                    capacity_factor=1.0, dropless_below=0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (512, 16))
    y, aux = moe_apply(p, cfg, x)
    assert 0.0 <= float(aux["dropped_frac"]) < 0.5
    assert bool(jnp.isfinite(y).all())


def test_moe_load_balance_loss_sane():
    cfg = MoEConfig(d_model=16, n_experts=8, top_k=2, d_ff=16, capacity_factor=4.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 16))
    _, aux = moe_apply(p, cfg, x)
    # perfectly balanced -> 1.0; collapsed -> ~ E; random init lands low
    assert 0.9 < float(aux["load_balance_loss"]) < 4.0


def test_moe_grads_flow_through_router():
    cfg = MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff=16, capacity_factor=4.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    g = jax.grad(lambda pp: moe_apply(pp, cfg, x)[0].sum())(p)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
    assert float(jnp.abs(g["experts"]["w1"]).sum()) > 0


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def test_chunked_attention_matches_full():
    b, t, h, hkv, d = 2, 4096, 4, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, t, h, d))
    k = jax.random.normal(keys[1], (b, t, hkv, d))
    v = jax.random.normal(keys[2], (b, t, hkv, d))
    full = _sdpa(q, k, v, causal_mask(t), d**-0.5)
    chunked = _sdpa_chunked_causal(q, k, v, d**-0.5, chunk=512)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_rope_relative_property():
    """RoPE: <q_m, k_n> depends only on (m - n)."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))

    def dot_at(m, n):
        qm = apply_rope(q, jnp.array([[m]]), 1e4)[0, 0, 0]
        kn = apply_rope(k, jnp.array([[n]]), 1e4)[0, 0, 0]
        return float(qm @ kn)

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-5)
    assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), rel=1e-5)
    assert abs(dot_at(5, 3) - dot_at(50, 3)) > 1e-6  # genuinely positional


def test_gqa_decode_incremental_equals_batch():
    cfg = GQAConfig(d_model=32, n_heads=4, n_kv_heads=2, d_head=8, qk_norm=True)
    p = gqa_init(jax.random.PRNGKey(0), cfg)
    b, t = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, 32))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    full, _ = gqa_apply(p, cfg, x, pos, causal_mask(t))
    k_cache = jnp.zeros((b, t, 2, 8))
    v_cache = jnp.zeros((b, t, 2, 8))
    for i in range(t):
        mask = (jnp.arange(t) <= i)[None, None, None, None]
        out, (k_cache, v_cache) = gqa_apply(
            p, cfg, x[:, i : i + 1], pos[:, i : i + 1], mask,
            kv=(k_cache, v_cache), cache_index=i,
        )
        np.testing.assert_allclose(
            np.asarray(out[:, 0]), np.asarray(full[:, i]), rtol=2e-4, atol=2e-4
        )


# ---------------------------------------------------------------------------
# norms / losses (hypothesis)
# ---------------------------------------------------------------------------


@given(st.integers(1, 8), st.integers(2, 64))
@settings(max_examples=30, deadline=None)
def test_layernorm_normalises(b, d):
    x = jax.random.normal(jax.random.PRNGKey(b * d), (b, d)) * 10 + 3
    y = layernorm(x, norm_params(d))
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-4)
    if d > 4:
        np.testing.assert_allclose(np.asarray(jnp.var(y, -1)), 1.0, atol=1e-2)


@given(st.integers(2, 64))
@settings(max_examples=30, deadline=None)
def test_rmsnorm_scale_invariant(d):
    x = jax.random.normal(jax.random.PRNGKey(d), (3, d))
    p = norm_params(d, bias=False)
    y1 = rmsnorm(x, p)
    y2 = rmsnorm(7.5 * x, p)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=1e-5)


def test_softmax_xent_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 7))
    labels = jnp.array([1, 0, 6, 3])
    want = -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(4), labels])
    got = softmax_xent(logits, labels)
    assert float(got) == pytest.approx(float(want), rel=1e-6)
