"""Static structural verification of partition plans.

Proves -- by pure integer arithmetic on the plan, no JAX, no execution --
the invariants the paper's correctness argument rests on:

* **Row coverage** (paper eq. 7): every layer's output rows ``1..O_i`` are
  owned exactly once across the slot sequence -- no gaps, no overlaps.
* **Receptive-field exactness** (eqs. 8-9, exact form in ``rf.py``): each
  slot's declared input range equals ``input_range_exact`` of its output
  segment -- too little means wrong output rows (a *short halo*), too much
  means unpriced communication.
* **Halo algebra** (eqs. 8-9 / ``spatial.halo.halo_sizes``): per conv layer
  ``lo = p`` and ``hi = k - p - s`` satisfy ``lo + hi == k - s`` and the
  geometry is one the aligned-shard exchange supports; per slot, needed rows
  beyond its own span must be donatable by *adjacent* owners only (halo <=
  shard height -- rows from two shards away cannot be exchanged).
* **Message legality** (``partition._check_layout`` contract): secondaries
  never exchange rows directly, and host zones only send to adjacent
  secondaries -- anything else would be unpriced by both latency engines.
* **Auto-reduce monotonicity** (``partition._reduced_slot_rows`` contract):
  once a trailing secondary is dropped at a conv layer it stays dropped --
  the active suffix can only shrink with depth.
* **Scheme-stage legality** (``stage_spans`` / ``SCHEMES``): a
  :class:`SchemePlan`'s spans match the net's stage structure, every
  per-stage scheme is legal for its layer kinds, segments are the exact
  fusion of the assignment, and halo segments carry sub-plans over the right
  sub-geometry.
* **Head-split divisibility**: head_sequence stages need ``d % heads == 0``
  (``run_plan`` slices per-head parameter blocks of width ``d // heads``).

The entry point is :func:`check_plan`; it accepts ``HALPPlan``,
``SchemePlan``, ``PlanLayout`` and ``SchemeLayout`` objects and returns a
:class:`~repro.analysis.findings.Report`.
"""
from __future__ import annotations

from ..core.partition import (
    HALPPlan,
    PlanLayout,
    SchemeLayout,
    SchemePlan,
    SCHEME_HALO,
    SCHEME_HOST,
    SCHEME_HS,
    _scheme_valid,
    _segment_subnet,
    fuse_assignment,
    plan_from_layout,
    plan_from_scheme_layout,
    stage_spans,
)
from ..core.rf import input_range_exact
from .findings import Report

__all__ = ["check_plan"]


def check_plan(plan) -> Report:
    """Statically verify a plan object; returns a Report (never raises)."""
    rep = Report()
    if isinstance(plan, PlanLayout):
        plan = plan_from_layout(plan)
    if isinstance(plan, SchemeLayout):
        plan = plan_from_scheme_layout(plan)
    if isinstance(plan, SchemePlan):
        _check_scheme_plan(plan, rep)
    elif isinstance(plan, HALPPlan):
        _check_halp_plan(plan, rep)
    else:
        rep.add(
            "plan.type",
            type(plan).__name__,
            "not a HALPPlan / SchemePlan / PlanLayout / SchemeLayout",
        )
    return rep


# ---------------------------------------------------------------------------
# HALP (halo'd row-segment) plans
# ---------------------------------------------------------------------------


def _check_halp_plan(plan: HALPPlan, rep: Report, ctx: str = "") -> None:
    net = plan.net
    sizes = net.sizes()
    slots = plan.es_names
    hosted = bool(plan.slot_owner)
    n_layers = len(net.layers)

    rep.tick()
    if len(plan.parts) != n_layers:
        rep.add(
            "plan.coverage",
            f"{ctx}plan",
            f"{len(plan.parts)} layer partitions for {n_layers} layers",
        )
        return

    # trailing-empty-secondary suffix at the previous conv layer (auto-reduce
    # drops secondaries from the tail; the suffix may only grow with depth)
    prev_suffix = 0
    prev_suffix_layer = -1

    for i, g in enumerate(net.layers):
        o, rows_in = sizes[i + 1], sizes[i]
        part = plan.parts[i]
        where = f"{ctx}layer {i} ({g.name})"

        if g.kind == "attn":
            rep.tick()
            owners = [s for s in slots if part.out.get(s)]
            if len(owners) > 1:
                rep.add(
                    "plan.scheme",
                    where,
                    "attention layer row-partitioned across "
                    f"{len(owners)} slots; every output row of attention "
                    "depends on every input row, so no receptive-field row "
                    "split exists (use the head_sequence scheme)",
                )
            continue

        # --- exact row coverage: no gaps, no overlaps, full span 1..o
        rep.tick()
        cur = 0
        for slot in slots:
            seg = part.out.get(slot)
            if seg is None:
                rep.add("plan.coverage", f"{where}, slot {slot}", "slot missing from partition")
                continue
            if not seg:
                continue
            if seg.lo < 1 or seg.hi > o:
                rep.add(
                    "plan.coverage",
                    f"{where}, slot {slot}",
                    f"owns rows {seg.lo}..{seg.hi} outside the layer's 1..{o}",
                )
            if seg.lo <= cur:
                rep.add(
                    "plan.coverage",
                    f"{where}, slot {slot}",
                    f"rows {seg.lo}..{min(seg.hi, cur)} already owned by a "
                    f"preceding slot (overlap)",
                )
            elif seg.lo > cur + 1:
                rep.add(
                    "plan.coverage",
                    f"{where}, slot {slot}",
                    f"rows {cur + 1}..{seg.lo - 1} owned by nobody (gap)",
                )
            cur = max(cur, seg.hi)
        if cur < o:
            rep.add(
                "plan.coverage", where, f"rows {cur + 1}..{o} owned by nobody (gap at tail)"
            )

        # --- halo algebra of the layer geometry (eqs. 8-9 / halo_sizes)
        if g.kind in ("conv", "depthwise"):
            rep.tick()
            lo, hi = g.p, g.k - g.p - g.s
            if g.p < 0 or lo >= g.k or hi >= g.k:
                rep.add(
                    "plan.halo",
                    where,
                    f"unsupported halo geometry k={g.k} s={g.s} p={g.p} "
                    f"(need 0 <= p < k and k - p - s < k)",
                )
            # lo + hi == k - s holds identically for lo=p, hi=k-p-s; what can
            # break it is hi < 0 (p > k - s): the top halo then over-covers
            # and the aligned exchange clamps -- legal, priced, no finding.

        # --- receptive-field exactness of every declared input range
        for slot in slots:
            rep.tick()
            seg = part.out.get(slot)
            inp = part.inp.get(slot)
            sw = f"{where}, slot {slot}"
            if seg is None:
                continue  # already reported above
            if not seg:
                if inp:
                    rep.add(
                        "plan.rf",
                        sw,
                        f"owns no output rows but declares input rows "
                        f"{inp.lo}..{inp.hi} (unpriced transfer)",
                    )
                continue
            exp = input_range_exact(seg.lo, seg.hi, g.k, g.s, g.p, rows_in)
            got = (inp.lo, inp.hi) if inp else None
            if got != exp:
                if got is None or got[0] > exp[0] or got[1] < exp[1]:
                    rep.add(
                        "plan.rf",
                        sw,
                        f"short halo: output rows {seg.lo}..{seg.hi} need input "
                        f"rows {exp[0]}..{exp[1]} (eq. 8-9 exact) but the plan "
                        f"provides {got[0]}..{got[1]}" if got else
                        f"short halo: output rows {seg.lo}..{seg.hi} need input "
                        f"rows {exp[0]}..{exp[1]} but the plan provides none",
                    )
                else:
                    rep.add(
                        "plan.rf",
                        sw,
                        f"surplus input: rows {got[0]}..{got[1]} declared but the "
                        f"receptive field of output rows {seg.lo}..{seg.hi} is "
                        f"exactly {exp[0]}..{exp[1]} (unpriced transfer rows)",
                    )

        # --- halo reach / message legality between consecutive layers
        if i > 0 and net.layers[i - 1].kind != "attn":
            if hosted:
                _check_messages(plan, i - 1, rep, ctx)
            else:
                _check_flat_reach(plan, i, rep, ctx)

        # --- auto-reduce monotonicity (hosted plans, conv layers only:
        # pools inherit divided boundaries and may transiently zero a slot)
        if hosted and g.kind != "pool":
            rep.tick()
            secs = plan.secondary_slots
            empty = [not part.out.get(s) for s in secs]
            suffix = 0
            for e in reversed(empty):
                if not e:
                    break
                suffix += 1
            if suffix < prev_suffix:
                revived = secs[len(secs) - prev_suffix]
                rep.add(
                    "plan.reduce",
                    f"{where}, secondary {revived}",
                    f"re-activated after being auto-reduced away at layer "
                    f"{prev_suffix_layer}: a dropped secondary must stay idle "
                    f"for the rest of the net (monotone reduction)",
                )
            else:
                prev_suffix, prev_suffix_layer = suffix, i


def _msg_iv(need, own, got):
    """Interval twin of ``partition._message_iv`` that reports instead of
    asserting: returns (lo, hi, contiguous)."""
    lo = max(need[0], own[0])
    hi = min(need[1], own[1])
    if lo > hi:
        return 1, 0, True
    p1, p2 = lo < got[0], hi > got[1]
    if p1 and p2:
        return lo, hi, False
    if p1:
        return lo, min(hi, got[0] - 1), True
    if p2:
        return max(lo, got[1] + 1), hi, True
    return 1, 0, True


def _check_messages(plan: HALPPlan, i: int, rep: Report, ctx: str) -> None:
    """Port of ``partition._check_layout`` for one layer boundary, reporting
    findings instead of raising (works on corrupted plans)."""
    slots = plan.es_names
    host = plan.host
    out_i = plan.parts[i].out
    got_i = out_i  # dst's already-held rows live in the same layer's output
    inp_next = plan.parts[i + 1].inp
    where = f"{ctx}layer {i}"
    for pa, sa in enumerate(slots):
        own = out_i.get(sa)
        if not own:
            continue
        a_host = plan.owner_of(sa) == host
        for pb, sb in enumerate(slots):
            if pb == pa:
                continue
            rep.tick()
            b_host = plan.owner_of(sb) == host
            if a_host and b_host:
                continue  # zone-to-zone: host-local move
            if not a_host and b_host:
                continue  # sec -> any zone: direct uplink, priced
            if abs(pa - pb) <= 1 and a_host != b_host:
                continue  # adjacent host<->sec: the paper's boundary flow
            need = inp_next.get(sb)
            got = got_i.get(sb)
            if need is None or got is None:
                continue  # missing slots reported by the coverage pass
            lo, hi, contig = _msg_iv(
                (need.lo, need.hi), (own.lo, own.hi), (got.lo, got.hi)
            )
            if not contig:
                rep.add(
                    "plan.halo",
                    f"{where}, {sa}->{sb}",
                    f"non-contiguous message {lo}..{hi} minus held rows "
                    f"{got.lo}..{got.hi}: segment ordering violated",
                )
                continue
            if lo > hi:
                continue
            if not a_host and not b_host:
                rep.add(
                    "plan.halo",
                    f"{where}, {sa}->{sb}",
                    f"secondaries would exchange rows {lo}..{hi} directly; "
                    f"there is no secondary-secondary link (halo exceeds the "
                    f"neighbouring shard height)",
                )
            else:
                rep.add(
                    "plan.halo",
                    f"{where}, {sa}->{sb}",
                    f"zone would send rows {lo}..{hi} to a non-adjacent "
                    f"secondary; the zone-chunk schedule only prices sends to "
                    f"the two neighbours",
                )


def _check_flat_reach(plan: HALPPlan, i: int, rep: Report, ctx: str) -> None:
    """Flat (unhosted) plans -- the spatial shard_map deployment: a shard's
    input may only extend into the *adjacent* shards' previous-layer rows
    (halo <= shard height; ppermute exchanges one neighbour deep)."""
    slots = plan.es_names
    prev_out = plan.parts[i - 1].out
    inp = plan.parts[i].inp
    where = f"{ctx}layer {i}"
    for idx, slot in enumerate(slots):
        need = inp.get(slot)
        if not need:
            continue
        rep.tick()
        reach = [
            prev_out.get(slots[j])
            for j in (idx - 1, idx, idx + 1)
            if 0 <= j < len(slots)
        ]
        reach = [r for r in reach if r]
        if not reach:
            rep.add(
                "plan.halo",
                f"{where}, slot {slot}",
                f"needs input rows {need.lo}..{need.hi} but neither it nor its "
                f"neighbours own any previous-layer rows",
            )
            continue
        lo = min(r.lo for r in reach)
        hi = max(r.hi for r in reach)
        if need.lo < lo or need.hi > hi:
            rep.add(
                "plan.halo",
                f"{where}, slot {slot}",
                f"needs input rows {need.lo}..{need.hi} but adjacent shards "
                f"only cover {lo}..{hi}: halo exceeds shard height (rows from "
                f"two shards away cannot be exchanged)",
            )


# ---------------------------------------------------------------------------
# Mixed-scheme plans
# ---------------------------------------------------------------------------


def _check_scheme_plan(plan: SchemePlan, rep: Report) -> None:
    net = plan.net

    rep.tick()
    if plan.host in plan.secondaries:
        rep.add("plan.scheme", "topology", f"host {plan.host!r} is also a secondary")
    rep.tick()
    if len(plan.ratios) != len(plan.secondaries):
        rep.add(
            "plan.scheme",
            "ratios",
            f"{len(plan.ratios)} ratios for {len(plan.secondaries)} secondaries",
        )
    elif any(r < 0 for r in plan.ratios) or not sum(plan.ratios) > 0:
        rep.add("plan.scheme", "ratios", f"not a normalisable weighting: {plan.ratios}")

    # --- stage structure must match the net
    rep.tick()
    spans = stage_spans(net)
    if plan.spans != spans:
        rep.add(
            "plan.scheme",
            "stage spans",
            f"plan spans {plan.spans} != stage_spans(net) {spans}; the stage "
            f"structure is derived from pooling/attention boundaries and "
            f"cannot be chosen",
        )
        return  # everything below is relative to the true spans
    rep.tick()
    if len(plan.assignment) != len(spans):
        rep.add(
            "plan.scheme",
            "assignment",
            f"{len(plan.assignment)} schemes for {len(spans)} stages",
        )
        return

    # --- per-stage scheme legality
    for idx, (span, sch) in enumerate(zip(spans, plan.assignment)):
        rep.tick()
        try:
            ok = _scheme_valid(net, span, sch)
        except ValueError:
            ok = False
        if not ok:
            kinds = ",".join(g.kind for g in net.layers[span[0] : span[1] + 1])
            rep.add(
                "plan.scheme",
                f"stage {idx} (layers {span[0]}-{span[1]})",
                f"scheme {sch!r} is illegal for layer kinds [{kinds}]",
            )

    # --- segments must be the exact fusion of the assignment
    rep.tick()
    try:
        segs = fuse_assignment(spans, plan.assignment)
    except ValueError as exc:
        rep.add("plan.scheme", "segments", str(exc))
        return
    if plan.segments != segs:
        rep.add(
            "plan.scheme",
            "segments",
            f"plan segments do not fuse the assignment: {plan.segments} != {segs}",
        )
        return

    # --- per-segment payloads
    if len(plan.halo_plans) != len(plan.segments):
        rep.add(
            "plan.scheme",
            "segments",
            f"{len(plan.halo_plans)} halo sub-plans for {len(plan.segments)} segments",
        )
        return
    for idx, (seg, sub) in enumerate(zip(plan.segments, plan.halo_plans)):
        swhere = f"segment {idx} ({seg.scheme}, layers {seg.start}-{seg.stop})"
        rep.tick()
        if seg.scheme == SCHEME_HALO:
            if sub is None:
                rep.add("plan.scheme", swhere, "halo segment without a HALP sub-plan")
                continue
            ref = _segment_subnet(net, seg.start, seg.stop)
            if sub.net.layers != ref.layers or sub.net.in_rows != ref.in_rows:
                rep.add(
                    "plan.scheme",
                    swhere,
                    f"sub-plan geometry {sub.net.name!r} does not match the "
                    f"segment's layers of {net.name!r}",
                )
                continue
            _check_halp_plan(sub, rep, ctx=f"{swhere}, ")
        else:
            if sub is not None:
                rep.add(
                    "plan.scheme", swhere, f"{seg.scheme} segment carries a HALP sub-plan"
                )
            if seg.scheme == SCHEME_HS:
                for i in range(seg.start, seg.stop + 1):
                    g = net.layers[i]
                    if g.kind != "attn":
                        continue
                    rep.tick()
                    if g.heads < 1 or g.c_in % g.heads:
                        rep.add(
                            "plan.heads",
                            f"{swhere}, layer {i} ({g.name})",
                            f"d={g.c_in} not divisible by heads={g.heads}: the "
                            f"head-sequence executor slices per-head parameter "
                            f"blocks of width d // heads",
                        )
            elif seg.scheme == SCHEME_HOST:
                pass  # host computes alone: nothing to verify
