"""Segment-based task partitioning (paper §III, eqs. 5-9) and the HALP plan.

The host ES partitions every layer's *output rows* into three contiguous
segments (paper Fig. 2 / eqs. 6-7):

    rows 1..a           -> secondary e1
    rows a+1..a+w       -> host e0     (the "overlapping zone", w ~ 4 rows)
    rows a+w+1..O       -> secondary e2

and derives each ES's required *input rows* from the receptive-field arithmetic
(eqs. 8-9 / exact interval algebra).  All inter-ES messages follow from range
intersections, so the plan is lossless by construction.  The same machinery
generalises to K collaborating pairs (paper §IV.B) and to N-way even splits for
the TPU spatial-parallel engine (``repro.spatial``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .nets import ConvNetGeom, DTYPE_BYTES
from .rf import input_range_exact

__all__ = [
    "Segment",
    "LayerPartition",
    "HALPPlan",
    "split_rows",
    "plan_halp",
    "plan_even",
]

E1, E0, E2 = "e1", "e0", "e2"  # paper's ES names; e0 is the host


@dataclass(frozen=True)
class Segment:
    """1-indexed inclusive row range; empty iff lo > hi."""

    lo: int
    hi: int

    @property
    def rows(self) -> int:
        return max(0, self.hi - self.lo + 1)

    def intersect(self, other: "Segment") -> "Segment":
        return Segment(max(self.lo, other.lo), min(self.hi, other.hi))

    def __bool__(self) -> bool:  # truthy iff non-empty
        return self.rows > 0


EMPTY = Segment(1, 0)


@dataclass(frozen=True)
class LayerPartition:
    """Partition of one layer: output segments and required input ranges per ES."""

    index: int
    out: dict[str, Segment]
    inp: dict[str, Segment]  # exact input rows each ES needs (eqs. 8-9, exact form)


@dataclass(frozen=True)
class HALPPlan:
    net: ConvNetGeom
    parts: tuple[LayerPartition, ...]
    es_names: tuple[str, ...]  # order along rows: (e1, e0, e2) or N-way

    def owner_rows(self, layer: int, es: str) -> Segment:
        return self.parts[layer].out[es]

    def message(self, layer: int, src: str, dst: str) -> Segment:
        """Rows of layer ``layer``'s *output* that src owns and dst needs as
        input for layer ``layer + 1`` (or for the head merge if last layer)."""
        if layer + 1 >= len(self.parts):
            # final layer: everything the secondaries own is sent to the host
            # to be merged as the FL input (paper eqs. 13-14, g_i = g_N case).
            if dst == E0 and src != E0:
                return self.parts[layer].out[src]
            return EMPTY
        need = self.parts[layer + 1].inp[dst]
        own = self.parts[layer].out[src]
        got = self.parts[layer].out[dst]
        inter = need.intersect(own)
        if not inter or src == dst:
            return EMPTY
        # dst already owns `got`; only rows outside it must travel.
        pieces = []
        if inter.lo < got.lo:
            pieces.append(Segment(inter.lo, min(inter.hi, got.lo - 1)))
        if inter.hi > got.hi:
            pieces.append(Segment(max(inter.lo, got.hi + 1), inter.hi))
        if not pieces:
            return EMPTY
        if len(pieces) == 1:
            return pieces[0]
        # src on both sides of dst cannot happen with contiguous ordered segments
        raise AssertionError("non-contiguous message; segment ordering violated")

    def message_bytes(self, layer: int, src: str, dst: str) -> float:
        seg = self.message(layer, src, dst)
        if not seg:
            return 0.0
        g = self.net.layers[layer]
        width = self.net.sizes()[layer + 1]
        return DTYPE_BYTES * seg.rows * width * g.c_out


def split_rows(total: int, ratios: Sequence[float]) -> list[Segment]:
    """Paper eqs. (6)-(7) generalised: contiguous segments by cumulative ratio.

    Segments exactly cover 1..total; rounding via cumulative floor keeps every
    segment within +-1 row of its exact ratio share.
    """
    if abs(sum(ratios) - 1.0) > 1e-9:
        raise ValueError(f"ratios must sum to 1, got {sum(ratios)}")
    bounds = [0]
    acc = 0.0
    for r in ratios[:-1]:
        acc += r
        bounds.append(int(round(acc * total)))
    bounds.append(total)
    return [Segment(lo + 1, hi) for lo, hi in zip(bounds[:-1], bounds[1:])]


def _align_down(x: int, align: int) -> int:
    return (x // align) * align


def plan_halp(
    net: ConvNetGeom,
    overlap_rows: int = 4,
    es_names: tuple[str, str, str] = (E1, E0, E2),
) -> HALPPlan:
    """Build the HALP partition for a conv net (paper §IV.A).

    Per layer the host zone is ``overlap_rows`` output rows centred between two
    near-equal secondary segments.  Boundaries are kept even in front of stride-2
    layers so pooling never crosses a segment boundary (paper: "the host ES does
    not need to send the output of the current CL ... for the pooling layer").
    The plan asserts that secondaries never need each other's rows -- all
    boundary traffic flows through the host, as the scheme requires.
    """
    lo_name, host, hi_name = es_names
    sizes = net.sizes()
    parts: list[LayerPartition] = []
    for i, g in enumerate(net.layers):
        o = sizes[i + 1]
        if g.kind == "pool":
            # pools inherit the previous layer's boundaries (divided by stride);
            # choose the host zone as the pooled image of the previous host zone.
            prev = parts[-1].out
            out = {
                lo_name: Segment(1, prev[lo_name].hi // g.s),
                host: Segment(prev[lo_name].hi // g.s + 1, prev[host].hi // g.s),
                hi_name: Segment(prev[host].hi // g.s + 1, o),
            }
        else:
            w = min(overlap_rows, max(1, o - 2))
            a = (o - w) // 2
            # Align both host-zone boundaries to the strides of the pooling
            # layers that follow *before the next conv* (where the partition is
            # re-balanced anyway), so pools never cross a segment boundary.
            align = 1
            for h in net.layers[i + 1 :]:
                if h.kind != "pool":
                    break
                align *= h.s
            while align > max(1, o // 4):
                align //= 2
            if align > 1:
                a = max(align, _align_down(a, align))
                w = ((w + align - 1) // align) * align
                w = min(w, max(1, o - a - 1))
            out = {
                lo_name: Segment(1, a),
                host: Segment(a + 1, a + w),
                hi_name: Segment(a + w + 1, o),
            }
        inp = {
            es: (
                Segment(*input_range_exact(seg.lo, seg.hi, g.k, g.s, g.p, sizes[i]))
                if seg
                else EMPTY
            )
            for es, seg in out.items()
        }
        parts.append(LayerPartition(index=i, out=out, inp=inp))
    plan = HALPPlan(net=net, parts=tuple(parts), es_names=es_names)
    _check_no_secondary_exchange(plan, lo_name, hi_name)
    return plan


def plan_even(net: ConvNetGeom, n: int) -> HALPPlan:
    """N-way even split (used by the TPU spatial engine and the MoDNN baseline)."""
    names = tuple(f"w{j}" for j in range(n))
    sizes = net.sizes()
    parts = []
    for i, g in enumerate(net.layers):
        o = sizes[i + 1]
        segs = split_rows(o, [1.0 / n] * n)
        out = dict(zip(names, segs))
        inp = {
            es: (
                Segment(*input_range_exact(seg.lo, seg.hi, g.k, g.s, g.p, sizes[i]))
                if seg
                else EMPTY
            )
            for es, seg in out.items()
        }
        parts.append(LayerPartition(index=i, out=out, inp=inp))
    return HALPPlan(net=net, parts=tuple(parts), es_names=names)


def _check_no_secondary_exchange(plan: HALPPlan, lo_name: str, hi_name: str) -> None:
    for i in range(len(plan.parts) - 1):
        for a, b in ((lo_name, hi_name), (hi_name, lo_name)):
            seg = plan.message(i, a, b)
            if seg:
                raise AssertionError(
                    f"layer {i}: secondary {a} would need to send rows "
                    f"{seg.lo}..{seg.hi} to {b}; widen the overlap zone"
                )
