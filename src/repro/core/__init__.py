"""The paper's contribution: receptive-field-exact partitioning (rf, partition),
HALP / MoDNN scheduling over arbitrary collaboration topologies (topology,
schedule), one shared event topology feeding both latency engines (events),
exact event simulation (simulator), plan-knob search (optimizer), the
service-reliability model (reliability), online joint compute+link adaptive
re-planning with a plan cache (replan), a persistent content-keyed plan store
for warm starts across restarts (planstore), and per-task heterogeneous
placement over a shared ES pool (placement)."""
from .nets import ConvNetGeom, vgg16_geom
from .optimizer import OptimizeResult, equal_ratios, evaluate_plan, optimize_plan
from .partition import (
    HALPPlan,
    PlanInfeasible,
    Segment,
    plan_even,
    plan_halp,
    plan_halp_n,
    plan_halp_topology,
    split_rows,
)
from .placement import (
    PlacementController,
    PlacementResult,
    TaskPlacement,
    place_tasks,
    shared_plan_placement,
    simulate_placement,
)
from .reliability import (
    OffloadChannel,
    probit,
    rate_fluctuation,
    required_slack,
    service_reliability,
)
from .planstore import PLAN_SCHEMA_VERSION, PlanStore, canonical_key, key_hash
from .replan import (
    ComputeRateEstimator,
    LinkRateEstimator,
    PlanCache,
    ReplanConfig,
    ReplanController,
    StaticPlanner,
    bucket_rate,
    compute_band_flops,
    compute_bucket,
    optimize_static,
    rate_bucket,
    topology_fingerprint,
)
from .rf import (
    LayerGeom,
    RFState,
    input_range_exact,
    input_range_paper,
    out_size,
    propagate_range,
    rf_chain,
)
from .schedule import (
    AGX_XAVIER,
    GTX_1080TI,
    TPU_V5E,
    halp_closed_form,
    modnn_time,
    speedup_ratio,
    standalone_time,
)
from .simulator import (
    GaussMarkovTrace,
    Sim,
    enhanced_modnn_delay,
    replay_rate_trace,
    replay_trace,
    serve_latency_table,
    simulate_halp,
    simulate_modnn,
)
from .topology import CollabTopology, Link, Platform
