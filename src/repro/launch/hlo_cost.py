"""HLO-text cost accounting with while-loop trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
its trip count, so scanned layer stacks (the transformer/diffusion models) are
under-reported by ~L x.  The optimized HLO text, however, carries
``known_trip_count`` on every counted loop -- this module re-derives

    flops            (dot + convolution, exact shape math)
    bytes accessed   (operands + results of non-fused top-level ops)
    collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
                      collective-permute result shapes)

per computation and folds them up the call graph with the right multipliers:
while bodies x trip_count, fusion interiors skipped (the call site accounts
their traffic), call/conditional x 1.  Validated against cost_analysis on
scan-free modules (tests/test_hlo_cost.py) and against L x single-layer math
on scanned ones.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HLOCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "u1": 1, "s1": 1, "pred": 1, "c64": 8, "c128": 16, "tuple": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)

_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "iota",
    "after-all", "partition-id", "replica-id", "while", "conditional", "call",
}

# Elementwise/layout ops fuse into their producers/consumers on TPU -- they do
# not independently touch HBM.  (The CPU-backend HLO we analyse fuses less
# than TPU XLA would; skipping these approximates the TPU schedule.  Real
# materialisation points -- dot/conv results, reduces, slices, copies,
# concatenates, collectives -- still count in full.)
_FUSED_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "negate", "abs", "sign",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "sqrt", "rsqrt", "cbrt", "sine", "cosine", "logistic", "erf",
    "maximum", "minimum", "clamp", "select", "compare", "convert", "not",
    "and", "or", "xor", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "is-finite", "popcnt", "remainder", "atan2",
    "broadcast", "reshape", "map", "reduce-precision", "stochastic-convert",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result type is either a tuple "( ... )" (may contain /*index=N*/ comments but
# never nested parens) or a single array type (no parens/spaces).
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^()]*\)|[^\s(]+)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _canon(name: str) -> str:
    """Normalise an op/computation name to the %-prefixed form."""
    return name if name.startswith("%") else "%" + name


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    rest: str  # operands + attributes (raw tail of the line)


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)


@dataclass
class HLOCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = field(default_factory=dict)
    unknown_trip_whiles: int = 0
    collective_counts: dict = field(default_factory=dict)
    bytes_by_opcode: dict = field(default_factory=dict)  # diagnostics

    def _merge_scaled(self, sub: "HLOCost", mult: float) -> None:
        self.flops += mult * sub.flops
        self.bytes_accessed += mult * sub.bytes_accessed
        self.collective_bytes += mult * sub.collective_bytes
        self.unknown_trip_whiles += sub.unknown_trip_whiles
        for c, v in sub.per_collective.items():
            self.per_collective[c] = self.per_collective.get(c, 0) + mult * v
        for c, v in sub.collective_counts.items():
            self.collective_counts[c] = self.collective_counts.get(c, 0) + mult * v
        for c, v in sub.bytes_by_opcode.items():
            self.bytes_by_opcode[c] = self.bytes_by_opcode.get(c, 0) + mult * v


def _parse(text: str) -> tuple[dict, str, dict]:
    comps: dict[str, _Comp] = {}
    types: dict[str, str] = {}
    entry = None
    cur: _Comp | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = _Comp(_canon(m.group(1)))
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                comps[cur.name] = cur
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(s)
        if m:
            op = _Op(_canon(m.group(1)), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            types[op.name] = op.result_type
    return comps, entry, types


def _operands(rest: str) -> list[str]:
    """Operand op-names from the call parentheses.  ``rest`` starts just
    *after* the opening paren (consumed by _OP_RE), i.e. at paren depth 1.

    Newer XLA prints operands with their full types, e.g.
    ``dot(f32[8,16]{1,0} %Arg_0.1, f32[16,4]{1,0} %Arg_1.2)``, so the split
    must ignore commas nested in ``{}``/``[]`` (layouts, shapes) and the
    operand name is the *last* whitespace token of each piece."""
    paren = 1
    nest = 0  # {} / [] nesting inside the operand list
    pieces: list[str] = []
    buf: list[str] = []
    for ch in rest:
        if ch == "(":
            paren += 1
        elif ch == ")":
            paren -= 1
            if paren == 0:
                break
        elif ch in "{[":
            nest += 1
        elif ch in "}]":
            nest -= 1
        elif ch == "," and paren == 1 and nest == 0:
            pieces.append("".join(buf))
            buf = []
            continue
        buf.append(ch)
    if buf:
        pieces.append("".join(buf))
    out = []
    for piece in pieces:
        toks = piece.split()
        if not toks:
            continue
        name = toks[-1]
        if name.startswith("%"):
            out.append(name)
        elif re.fullmatch(r"[\w.\-]+", name) and not _SHAPE_RE.fullmatch(name):
            # operand printed without the % sigil (newer HLO dumps)
            out.append("%" + name)
    return out


def _dims_attr(rest: str, key: str) -> list[int]:
    m = re.search(key + r"=\{([\d,]*)\}", rest)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(x) for x in m.group(2).split(",") if x]


def _dot_flops(op: _Op, types: dict) -> float:
    ops = _operands(op.rest)
    if len(ops) < 2:
        return 0.0
    lhs = _shape_dims(types.get(ops[0], ""))
    lc = _dims_attr(op.rest, "lhs_contracting_dims")
    lb = _dims_attr(op.rest, "lhs_batch_dims")
    res = _shape_dims(op.result_type)
    k = 1
    for d in lc:
        if d < len(lhs):
            k *= lhs[d]
    out = 1
    for d in res:
        out *= d
    return 2.0 * out * k


def _conv_flops(op: _Op, types: dict) -> float:
    ops = _operands(op.rest)
    if len(ops) < 2:
        return 0.0
    rhs = _shape_dims(types.get(ops[1], ""))  # kernel
    res = _shape_dims(op.result_type)
    m = re.search(r"dim_labels=(\w+)_(\w+)->", op.rest)
    out = 1
    for d in res:
        out *= d
    if not m or not rhs:
        return 2.0 * out  # fallback
    kernel_labels = m.group(2)  # e.g. "01io"
    k_spatial = 1
    cin = 1
    for lab, dim in zip(kernel_labels, rhs):
        if lab == "i":
            cin = dim
        elif lab != "o":
            k_spatial *= dim
    g = re.search(r"feature_group_count=(\d+)", op.rest)
    groups = int(g.group(1)) if g else 1
    # rhs 'i' dim is already per-group input features
    return 2.0 * out * k_spatial * cin


def analyze_hlo(text: str) -> HLOCost:
    comps, entry, types = _parse(text)
    if entry is None:
        return HLOCost()

    fused: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=(%?[\w.\-]+)", op.rest)
                if m:
                    fused.add(_canon(m.group(1)))

    cache: dict[str, HLOCost] = {}

    def cost_of(name: str, stack=()) -> HLOCost:
        if name in cache:
            return cache[name]
        if name in stack:  # recursion guard
            return HLOCost()
        comp = comps.get(name)
        total = HLOCost(per_collective={c: 0.0 for c in _COLLECTIVES},
                        collective_counts={c: 0 for c in _COLLECTIVES})
        if comp is None:
            return total
        for op in comp.ops:
            _, res_bytes = _shape_elems_bytes(op.result_type)
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if op.opcode.endswith("-done"):
                continue
            if op.opcode == "while":
                m = re.search(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)', op.rest)
                trip = int(m.group(1)) if m else None
                if trip is None:
                    m2 = re.search(r"trip_count=(\d+)", op.rest)
                    trip = int(m2.group(1)) if m2 else 1
                    if m2 is None:
                        total.unknown_trip_whiles += 1
                body = re.search(r"body=(%?[\w.\-]+)", op.rest)
                cond = re.search(r"condition=(%?[\w.\-]+)", op.rest)
                for ref, mult in ((body, trip), (cond, trip + 1)):
                    if ref:
                        total._merge_scaled(cost_of(_canon(ref.group(1)), stack + (name,)), mult)
                continue
            if op.opcode in ("call", "conditional", "async-start"):
                for ref in re.finditer(r"(?:to_apply|calls|branch_computations=\{?)=?(%?[\w.\-]+)", op.rest):
                    total._merge_scaled(cost_of(_canon(ref.group(1)), stack + (name,)), 1)
                # fall through to count the call site's own bytes
            # flops
            if op.opcode == "dot":
                total.flops += _dot_flops(op, types)
            elif op.opcode == "convolution":
                total.flops += _conv_flops(op, types)
            elif op.opcode == "fusion":
                m = re.search(r"calls=(%?[\w.\-]+)", op.rest)
                if m:
                    sub = cost_of(_canon(m.group(1)), stack + (name,))
                    total.flops += sub.flops  # dots inside fusions still count
            # bytes (XLA-style: slicing ops touch only the slice; loop/tuple
            # plumbing moves nothing -- the body ops account their own reads;
            # elementwise chains fuse on TPU and are skipped)
            if (
                op.opcode not in _SKIP_BYTES
                and op.opcode not in _FUSED_ELEMENTWISE
                and name not in fused
            ):
                if op.opcode in ("dynamic-slice", "gather"):
                    nb = 2 * res_bytes
                elif op.opcode == "dynamic-update-slice":
                    ops_ = _operands(op.rest)
                    upd = (
                        _shape_elems_bytes(types.get(ops_[1], ""))[1]
                        if len(ops_) > 1
                        else res_bytes
                    )
                    nb = 2 * upd
                else:
                    nb = res_bytes + sum(
                        _shape_elems_bytes(types.get(o, ""))[1]
                        for o in _operands(op.rest)
                    )
                total.bytes_accessed += nb
                total.bytes_by_opcode[op.opcode] = (
                    total.bytes_by_opcode.get(op.opcode, 0) + nb
                )
            # collectives
            if base in _COLLECTIVES:
                total.collective_bytes += res_bytes
                total.per_collective[base] += res_bytes
                total.collective_counts[base] += 1
        cache[name] = total
        return total

    # fused computations' dots are accounted at the call site; compute entry.
    result = cost_of(entry)
    return result
