"""Pallas TPU kernel: HALP-fused spatially-sharded conv.

Inside a shard_map program each device holds x_shard [B, Hs, W, C] plus the
thin halos produced by ppermute (repro.spatial.halo).  The naive path
materialises concat([top_halo, x, bot_halo]) in HBM before convolving; this op
instead assembles only the *boundary row tiles* from the halos and feeds one
``conv2d_tiles`` pallas_call -- the interior tiles gather straight from the
shard.  That is HALP's schedule at kernel granularity: interior compute is
independent of the halos, so XLA's latency-hiding scheduler overlaps the
ppermute with the interior matmuls, and the boundary tiles are the only
consumers of remote data (paper eqs. 9-15; docs/equations.md maps the
correspondence).

Geometry: for stride ``s`` the aligned-shard halos satisfy
``lo + hi == k - s`` (``lo = p`` rows from above, ``hi = k - p - s`` from
below -- the exact eq. 8-9 arithmetic), and the shard height must be a
stride multiple.  Shard heights need *not* be tile multiples: the final tile
overhangs into zero padding and the surplus output rows are sliced off
(previously ``nt = hs // th`` silently dropped the remainder rows).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..conv2d.conv2d import conv2d_tiles
from ..conv2d.ops import _pick_cout_tile, _pick_tile_h


def halo_conv2d(
    x_shard: jax.Array,  # [B, Hs, W, C]
    top_halo: jax.Array | None,  # [B, lo, W, C] (already width-aligned with x)
    bot_halo: jax.Array | None,  # [B, hi, W, C]
    weights: jax.Array,  # [k, k, Cin, Cout] ([k, k, 1, C] depthwise)
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: int = 1,
    groups: int = 1,
    tile_h: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Conv over a height shard with explicit halos; returns the shard's
    [B, Hs // stride, W_out, Cout] output rows.

    ``tile_h`` overrides the VMEM-driven tile-height heuristic (tests use it
    to pin the remainder-tile path)."""
    k = weights.shape[0]
    s = stride
    lo = 0 if top_halo is None else top_halo.shape[1]
    hi = 0 if bot_halo is None else bot_halo.shape[1]
    if lo + hi != k - s:
        raise ValueError(
            f"halos must cover the receptive field: need lo + hi == k - s "
            f"(= {k - s}), got lo={lo} hi={hi} for k={k} stride={s}"
        )
    b, hs, w, cin = x_shard.shape
    if hs % s:
        raise ValueError(f"shard rows {hs} not divisible by stride {s}")
    n_out = hs // s
    cout = weights.shape[-1]

    def wpad(a):
        return jnp.pad(a, ((0, 0), (0, 0), (padding, padding), (0, 0))) if padding else a

    x = wpad(x_shard)
    w_ext = x.shape[2]
    if w_ext < k:
        raise ValueError(
            f"non-positive output width: padded width {w_ext} (w={w} + 2*p="
            f"{2 * padding}) < kernel {k}; the map is too narrow to convolve"
        )
    th = tile_h or _pick_tile_h(n_out, w_ext, cin, cout, k, x.dtype.itemsize, s)
    th = max(1, min(th, n_out))
    nt = -(-n_out // th)  # ceil: the last tile may overhang into zero padding
    tile_ext = (th - 1) * s + k
    ext_h = lo + hs + hi

    # Interior tiles (no halo dependence) gather straight from the shard;
    # boundary tiles splice in the halo rows; overhang rows of the final
    # (remainder) tile are zeros.  In *extended* coordinates -- row e is the
    # top halo for e < lo, shard row e - lo for lo <= e < lo + Hs, the bottom
    # halo up to ext_h -- output row r reads ext rows [r*s, r*s + k), so tile
    # t covers ext rows [t*th*s, t*th*s + tile_ext).
    top_ext = wpad(top_halo) if lo else None
    bot_ext = wpad(bot_halo) if hi else None

    def rows(e0: int, e1: int):  # extended rows [e0, e1)
        pieces = []
        if e0 < lo:
            pieces.append(top_ext[:, e0 : min(e1, lo)])
        m0, m1 = max(e0, lo), min(e1, lo + hs)
        if m1 > m0:
            pieces.append(x[:, m0 - lo : m1 - lo])
        b0, b1 = max(e0, lo + hs), min(e1, ext_h)
        if b1 > b0:
            pieces.append(bot_ext[:, b0 - lo - hs : b1 - lo - hs])
        if e1 > max(e0, ext_h):  # remainder-tile overhang: zero padding
            pieces.append(
                jnp.zeros((b, e1 - max(e0, ext_h), w_ext, cin), x.dtype)
            )
        return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=1)

    tiles = [rows(t * th * s, t * th * s + tile_ext) for t in range(nt)]
    x_tiles = jnp.stack(tiles, axis=1)  # [B, nT, tile_ext, W_ext, C]
    y = conv2d_tiles(
        x_tiles,
        weights,
        k=k,
        tile_h=th,
        cout_tile=_pick_cout_tile(cout),
        stride=s,
        groups=groups,
        interpret=interpret,
    )
    y = y.reshape(b, nt * th, (w_ext - k) // s + 1, cout)[:, :n_out]
    if bias is not None:
        y = y + bias
    return y
