"""Fault tolerance: checkpoint/restart training, straggler detection, and the
deadline model shared with the paper's §V-D reliability analysis.

Design for 1000+ nodes (DESIGN.md):
* **checkpoint/restart** -- the trainer checkpoints every K steps and replays
  the deterministic data stream from the restored step; any step-level failure
  (device error, injected fault) triggers restore-and-continue with bounded
  retries.
* **straggler mitigation** -- per-step wall-times feed an EMA; steps slower
  than ``straggler_factor`` x EMA are counted and surfaced.  At scale the
  launcher uses this signal to evict/replace slow hosts; the analytical twin
  (core.simulator slowdown injection + core.reliability deadlines) quantifies
  the effect on service deadlines, exactly as the paper does for time-variant
  channels.
* **elastic scaling** -- batches are pure functions of (seed, step) and
  checkpoints are mesh-agnostic (host npz), so a restore onto a *different*
  mesh (more or fewer pods) resumes bit-exactly; tests restore onto a fresh
  state to prove it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax

from .checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["FaultConfig", "FaultTolerantTrainer", "InjectedFault"]


class InjectedFault(RuntimeError):
    """Raised by tests / chaos hooks to simulate node failure."""


@dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    max_failures: int = 3
    straggler_factor: float = 2.5
    ema_alpha: float = 0.1


@dataclass
class TrainerStats:
    steps: int = 0
    failures: int = 0
    restores: int = 0
    stragglers: int = 0
    ema_step_s: float = 0.0
    losses: list = field(default_factory=list)


class FaultTolerantTrainer:
    """Wraps a jitted train step with checkpoint/restart + straggler stats.

    ``step_fn(state, **batch) -> (state, metrics)``; ``stream.batch_at(i)``
    must be deterministic in ``i`` (repro.data guarantees this)."""

    def __init__(self, step_fn: Callable, stream, cfg: FaultConfig,
                 fault_hook: Callable[[int], None] | None = None):
        self.step_fn = step_fn
        self.stream = stream
        self.cfg = cfg
        self.fault_hook = fault_hook
        self.stats = TrainerStats()

    def _maybe_restore(self, state):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return state, 0
        state, step, _ = restore_checkpoint(self.cfg.ckpt_dir, state)
        self.stats.restores += 1
        return state, step

    def run(self, state, n_steps: int, start_step: int = 0, resume: bool = True):
        if resume:
            state, start_step = self._maybe_restore(state)
        i = start_step
        failures = 0
        while i < n_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(i)  # chaos injection point
                batch = self.stream.batch_at(i)
                t0 = time.time()
                state, metrics = self.step_fn(state, **batch)
                jax.block_until_ready(metrics)
                dt = time.time() - t0
                self._track(dt, metrics)
                i += 1
                if i % self.cfg.ckpt_every == 0 or i == n_steps:
                    save_checkpoint(self.cfg.ckpt_dir, i, state)
            except (InjectedFault, RuntimeError) as e:
                failures += 1
                self.stats.failures += 1
                if failures > self.cfg.max_failures:
                    raise RuntimeError(
                        f"exceeded {self.cfg.max_failures} failures; last: {e}"
                    ) from e
                # restore from the newest complete checkpoint and replay
                step = latest_step(self.cfg.ckpt_dir)
                if step is not None:
                    state, i = self._maybe_restore(state)[0], step
                else:
                    i = start_step
        return state, self.stats

    def _track(self, dt: float, metrics):
        s = self.stats
        if s.ema_step_s == 0.0:
            s.ema_step_s = dt
        elif dt > self.cfg.straggler_factor * s.ema_step_s:
            s.stragglers += 1
        s.ema_step_s = (1 - self.cfg.ema_alpha) * s.ema_step_s + self.cfg.ema_alpha * dt
        s.steps += 1
        loss = metrics.get("total", metrics.get("loss", metrics.get("ce")))
        if loss is not None:
            s.losses.append(float(loss))
