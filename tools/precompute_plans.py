"""Offline bucket-lattice walk: pre-populate a persistent PlanStore.

The replan/placement controllers key cached optima on quantised operating
points (link-rate bands x per-ES compute bands -- see
``repro.core.replan``), and each band's plan is optimised against the band's
*representative* rates, never the raw measurements.  Operating points are
therefore enumerable offline: this tool walks a lattice of band shifts around
the nominal point and calls :meth:`ReplanController.prime` on each, filling a
:class:`~repro.core.planstore.PlanStore` with exactly the entries a live
controller would compute on demand -- same keys (the controller's own
fingerprint/bucket logic, not a reimplementation), same bit-identical plans.

CI runs ``--smoke`` to build a small warm store and uploads it as an
artifact; a controller started against that file serves every lattice point
with zero optimizer calls (``tests/test_planstore.py`` pins this, and
``benchmarks/planstore_bench.py`` measures the restart speedup).

The lattice covers the drift modes the benchmarks exercise: uniform link-band
shifts (channel-wide congestion, ``--link-shifts``) crossed with band shifts
of the *last* secondary's compute (the straggler scenario of
``benchmarks/straggler_sweep.py``, ``--compute-shifts``).  Negative compute
shifts are slower-than-nominal bands (the compute grid is nominal-anchored,
round-to-nearest; the link grid is floor-based -- integer shifts are valid
points on both).

Usage::

    python tools/precompute_plans.py --store plans.sqlite --smoke
    python tools/precompute_plans.py --store plans.sqlite \
        --link-shifts -3 -2 -1 0 1 --compute-shifts -4 -3 -2 -1 0
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (  # noqa: E402
    AGX_XAVIER,
    SCHEMES,
    CollabTopology,
    Link,
    PlanStore,
    ReplanConfig,
    ReplanController,
)
from repro.models import vgg  # noqa: E402

# The demo cluster every store-backed test/benchmark shares: small enough
# that a full lattice optimises in seconds (closed-form objective), real
# enough that plans differ across bands.  tests/test_planstore.py imports
# these builders, so the CI-built artifact matches the test keys exactly.
NOMINAL_BPS = 120e6


def demo_net():
    return vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10).geom()


def demo_topology() -> CollabTopology:
    return CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={"e0": AGX_XAVIER, "a": AGX_XAVIER, "b": AGX_XAVIER},
        default_link=Link(NOMINAL_BPS),
    )


def demo_config() -> ReplanConfig:
    return ReplanConfig(use_simulator=False, alpha=1.0, hysteresis=1, bucket_frac=0.5)


def demo_scheme_config() -> ReplanConfig:
    """The full-vocabulary twin of :func:`demo_config`: per-stage scheme
    search needs the DES objective, and the vocabulary is part of the cache
    fingerprint, so this lattice is disjoint from the halo-only one by
    construction -- the two warm stores coexist in the same file."""
    return ReplanConfig(
        use_simulator=True, n_tasks=1, alpha=1.0, hysteresis=1,
        bucket_frac=0.5, schemes=SCHEMES,
    )


def lattice_keys(
    controller: ReplanController,
    link_shifts: list[int],
    compute_shifts: list[int],
) -> list[tuple]:
    """Bucket keys of the (uniform link shift) x (straggler compute shift)
    lattice around the controller's nominal operating point.  Built by
    shifting the controller's *own* seed key, so grid conventions (floor vs
    nearest, band anchors) can never drift from the live path."""
    base_links, base_compute = controller._active
    straggler = controller.nominal.secondaries[-1]
    keys = []
    for dl in link_shifts:
        links = tuple(sorted((pair, b + dl) for pair, b in base_links))
        for dc in compute_shifts:
            compute = tuple(
                sorted(
                    (es, nom, b + dc if es == straggler else b)
                    for es, nom, b in base_compute
                )
            )
            keys.append((links, compute))
    return keys


def precompute(
    store_path: str,
    link_shifts: list[int],
    compute_shifts: list[int],
    net=None,
    topology: CollabTopology | None = None,
    config: ReplanConfig | None = None,
) -> dict:
    """Walk the lattice into ``store_path``; returns a summary dict.

    Idempotent and incremental: points already in the store are store hits
    (zero optimizer calls), so re-running after widening the shift ranges
    only pays for the new points."""
    t0 = time.perf_counter()
    with PlanStore(store_path) as store:
        controller = ReplanController(
            net if net is not None else demo_net(),
            topology if topology is not None else demo_topology(),
            config if config is not None else demo_config(),
            store=store,
        )
        keys = lattice_keys(controller, link_shifts, compute_shifts)
        for key in keys:
            controller.prime(key)
        summary = dict(
            store=store.path,
            lattice_points=len(keys),
            optimizer_calls=controller.optimizer_calls,
            already_stored=controller.cache.store_hits,
            store_entries=len(store),
            elapsed_s=time.perf_counter() - t0,
        )
    return summary


def main(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--store", default="plans.sqlite", help="PlanStore file to fill")
    ap.add_argument(
        "--smoke", action="store_true", help="CI-sized lattice (3 x 3 points)"
    )
    ap.add_argument(
        "--link-shifts", type=int, nargs="+", default=[-3, -2, -1, 0, 1],
        help="uniform band shifts applied to every link (0 = nominal band)",
    )
    ap.add_argument(
        "--compute-shifts", type=int, nargs="+", default=[-4, -3, -2, -1, 0],
        help="band shifts of the last secondary's compute (straggler axis)",
    )
    args = ap.parse_args(argv)
    link_shifts = [-1, 0, 1] if args.smoke else args.link_shifts
    compute_shifts = [-2, -1, 0] if args.smoke else args.compute_shifts
    out = precompute(args.store, link_shifts, compute_shifts)
    # Scheme-vocabulary lattice: same link bands, nominal compute (scheme
    # choice is most sensitive to the channel; the straggler axis is covered
    # by the halo-only lattice above).  Idempotent like the base walk.
    scheme = precompute(
        args.store, link_shifts, [0], config=demo_scheme_config()
    )
    for label, o in (("halo lattice", out), ("scheme lattice", scheme)):
        print(
            f"{o['store']} [{label}]: {o['lattice_points']} lattice points, "
            f"{o['optimizer_calls']} optimised, {o['already_stored']} already "
            f"stored, {o['store_entries']} entries total "
            f"({o['elapsed_s']:.2f}s)"
        )
    return {"base": out, "scheme": scheme}


if __name__ == "__main__":
    main()
