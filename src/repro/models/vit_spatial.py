"""ViT-L/16 as a *spatial* layer stack runnable by the HALP executor.

The transformer is expressed over the H/patch x W/patch token grid in NHWC --
a patch-embedding conv followed by blocks of [multi-head self-attention, 1x1
out-projection, 1x1 MLP-up, 1x1 MLP-down] -- aligned layer-for-layer with the
analytical geometry ``repro.core.nets.vit_l16_geom`` so the scheme planner can
drive it through ``repro.spatial.partition_apply.run_plan``:

* the 1x1 convs are row-splittable (head_sequence's token-row shards) and
  channel-splittable (non_penetrative's filter shards);
* the attention layer is head-splittable: Q/K/V projections are stored
  head-major in their last axis, so slicing every param's last axis by a head
  range yields exactly that shard of the concatenated attention output.

Residual adds, layernorms, and the softmax head's centering are omitted (as in
the geometry: FLOP-negligible and byte-identical to the 1x1 outputs); the
activation after each conv is ReLU purely for parity with
``repro.models.vgg.apply_layer`` -- the partitioning algebra is elementwise-
activation-agnostic, and losslessness tests compare this model to itself.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..core.nets import ConvNetGeom, vit_l16_geom
from ..core.rf import LayerGeom
from .common import Params, conv_params, dense_params, keygen
from .layers import conv2d, dense, global_avg_pool, relu

__all__ = ["ViTSpatialConfig", "init", "apply_layer", "features", "head", "apply"]


@dataclass(frozen=True)
class ViTSpatialConfig:
    name: str = "vit_l16"
    img_res: int = 224
    patch: int = 16
    in_channels: int = 3
    n_blocks: int = 24
    d: int = 1024
    heads: int = 16
    d_ff: int = 4096
    num_classes: int = 1000

    def geom(self) -> ConvNetGeom:
        return vit_l16_geom(
            in_rows=self.img_res,
            patch=self.patch,
            n_blocks=self.n_blocks,
            d=self.d,
            heads=self.heads,
            d_ff=self.d_ff,
            num_classes=self.num_classes,
            name=self.name,
        )


def init(key: jax.Array, cfg: ViTSpatialConfig) -> Params:
    ks = keygen(key)
    feats: list[Params] = [conv_params(next(ks), cfg.patch, cfg.in_channels, cfg.d)]
    for _ in range(cfg.n_blocks):
        feats.append(
            {
                "q": dense_params(next(ks), cfg.d, cfg.d),
                "k": dense_params(next(ks), cfg.d, cfg.d),
                "v": dense_params(next(ks), cfg.d, cfg.d),
            }
        )
        feats.append(conv_params(next(ks), 1, cfg.d, cfg.d))
        feats.append(conv_params(next(ks), 1, cfg.d, cfg.d_ff))
        feats.append(conv_params(next(ks), 1, cfg.d_ff, cfg.d))
    return {"features": feats, "head": [dense_params(next(ks), cfg.d, cfg.num_classes)]}


def _mhsa(params: Params, geom: LayerGeom, x: jax.Array) -> jax.Array:
    """Self-attention over the token grid; the local head count is derived
    from the param shapes so head-range-sliced params (the head_sequence
    scheme's shards) run through the *same* code as the full layer."""
    b, h, w, _ = x.shape
    dh = geom.c_in // geom.heads
    tokens = x.reshape(b, h * w, -1)
    q, k, v = (dense(tokens, params[n]) for n in ("q", "k", "v"))
    n_local = q.shape[-1] // dh
    s = h * w
    q = q.reshape(b, s, n_local, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, n_local, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, n_local, dh).transpose(0, 2, 1, 3)
    att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(float(dh)), axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, n_local * dh)
    return y.reshape(b, h, w, n_local * dh)


def apply_layer(params: Params, geom: LayerGeom, x: jax.Array) -> jax.Array:
    """One feature layer on (a slice of) the input -- 'VALID' padded, the same
    primitive contract as ``repro.models.vgg.apply_layer``."""
    if geom.kind == "attn":
        return _mhsa(params, geom, x)
    y = conv2d(x, params, stride=geom.s, padding="VALID")
    return relu(y)


def features(params: Params, cfg: ViTSpatialConfig, x: jax.Array) -> jax.Array:
    geom = cfg.geom()
    for p, g in zip(params["features"], geom.layers):
        if g.kind != "pool" and g.p:
            x = jnp.pad(x, ((0, 0), (g.p, g.p), (g.p, g.p), (0, 0)))
        x = apply_layer(p, g, x)
    return x


def head(params: Params, x: jax.Array) -> jax.Array:
    return dense(global_avg_pool(x), params["head"][0])


def apply(params: Params, cfg: ViTSpatialConfig, x: jax.Array) -> jax.Array:
    """Full forward: patch embed + transformer blocks + pooled classifier."""
    return head(params, features(params, cfg, x))
