"""Quickstart: the paper's full pipeline on VGG-16 in one script.

1. receptive-field arithmetic (paper eqs. 1-4),
2. HALP partition plan (eqs. 5-9) + inter-ES message sizes (eqs. 10-14),
3. losslessness: the partitioned forward equals the single-device forward,
4. latency: HALP vs MoDNN vs standalone on the paper's platforms (eqs. 15-23),
5. service reliability under a time-variant channel (Table III model).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    GTX_1080TI,
    AGX_XAVIER,
    Link,
    OffloadChannel,
    plan_halp,
    rf_chain,
    service_reliability,
    simulate_halp,
    simulate_modnn,
    standalone_time,
    vgg16_geom,
)
from repro.models import vgg
from repro.spatial import run_plan

# -- 1. receptive fields ------------------------------------------------------
net = vgg16_geom()
states = rf_chain(net.in_rows, net.layers)
print("== receptive-field chain (VGG-16) ==")
for g, st in list(zip(net.layers, states))[:4] + [(net.layers[-1], states[-1])]:
    print(f"  {g.name:10s} out={st.out:4d} jump={st.jump:3d} rf={st.rf:4d}")

# -- 2. the HALP plan ---------------------------------------------------------
plan = plan_halp(net, overlap_rows=4)
p0 = plan.parts[0]
print("\n== HALP partition, layer conv1_1 ==")
for es in plan.es_names:
    seg = p0.out[es]
    print(f"  {es}: output rows {seg.lo}..{seg.hi} ({seg.rows} rows)")
print(f"  host->e1 message after conv2_1: {plan.message_bytes(3, 'e0', 'e1'):,.0f} bytes")

# -- 3. losslessness ----------------------------------------------------------
cfg = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10)
params = vgg.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
ref = vgg.features(params, cfg, x)
dist = run_plan(plan_halp(cfg.geom(), overlap_rows=4), params["features"], vgg.apply_layer, x)
np.testing.assert_allclose(np.asarray(dist), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("\n== losslessness: distributed == single-device forward  OK ==")

# -- 4. latency ---------------------------------------------------------------
print("\n== inference time (ms), 4 tasks per batch ==")
for plat in (GTX_1080TI, AGX_XAVIER):
    t_pre = standalone_time(net, plat)
    for rate in (40e9, 100e9):
        halp = simulate_halp(net, plat, Link(rate), n_tasks=4)["total"]
        modnn = simulate_modnn(net, plat, Link(rate), 9)["total"]
        print(
            f"  {plat.name:18s} @{rate/1e9:3.0f}G: standalone {t_pre*1e3:6.2f}  "
            f"HALP {halp*1e3:6.2f} ({4/halp:5.0f} fps)  MoDNN {modnn*1e3:6.2f}"
        )

# -- 5. reliability -----------------------------------------------------------
print("\n== service reliability, 30 FPS deadline, Xavier ==")
ch = OffloadChannel(rate_bps=40e6, sigma_s=5e-3)
for name, t_inf in (("standalone", 32.43e-3), ("HALP", 17.77e-3)):
    r = service_reliability(ch, t_inf, 4.0 / 30.0)
    print(f"  {name:10s}: {r:.6f}")
print("\nquickstart complete.")
