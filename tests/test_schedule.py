"""Scheduler / simulator tests: closed form vs. event sim vs. paper anchors."""

import pytest

from repro.core import (
    AGX_XAVIER,
    GTX_1080TI,
    Link,
    OffloadChannel,
    enhanced_modnn_delay,
    halp_closed_form,
    modnn_time,
    rate_fluctuation,
    service_reliability,
    simulate_halp,
    simulate_modnn,
    speedup_ratio,
    standalone_time,
    vgg16_geom,
)

NET = vgg16_geom()


def test_calibration_anchors():
    # §V.C: t_pre = 4.7 ms on the GTX 1080TI; Table II: 124 fps on Xavier.
    assert standalone_time(NET, GTX_1080TI) == pytest.approx(4.7e-3, rel=1e-6)
    assert standalone_time(NET, AGX_XAVIER) == pytest.approx(4.0 / 124.0, rel=1e-6)


def test_halp_beats_standalone_and_modnn():
    """HALP always beats standalone; it beats same-ES-count MoDNN in the
    comm-significant regime (low ES-ES rate), which is the paper's core claim
    ("HALP can save more communication time when transmission rate ... is low").

    ANALYTICAL FINDING (documented in EXPERIMENTS.md): under our clean
    overhead-free model, at >= 40 Gbps a synchronous 3-way even split edges out
    HALP on a *single* task because VGG-16's halo bytes are tiny relative to
    compute; the paper's measured MoDNN carries per-layer sync overheads that
    our baseline charitably omits.  HALP's structural advantage concentrates in
    (a) the low-rate regime and (b) the multi-task regime (host sharing), both
    asserted here and in test_table2/enhanced tests."""
    for plat in (GTX_1080TI, AGX_XAVIER):
        t_pre = standalone_time(NET, plat)
        for rate in (40e9, 60e9, 80e9, 100e9):
            t_halp = simulate_halp(NET, plat, Link(rate))["total"]
            assert t_halp < t_pre, (plat.name, rate)
        for rate in (1e9, 2e9, 5e9):
            link = Link(rate)
            t_halp = simulate_halp(NET, plat, link)["total"]
            t_modnn = simulate_modnn(NET, plat, link, 3)["total"]
            assert t_halp < t_modnn, (plat.name, rate)
        # and at high rate HALP stays within the structural compute bound:
        # its secondaries own ~110/224 of rows vs. 1/3 for the even split.
        t_halp = simulate_halp(NET, plat, Link(100e9))["total"]
        t_modnn = simulate_modnn(NET, plat, Link(100e9), 3)["total"]
        assert t_halp < (110.0 / 224.0) * 3.0 * t_modnn


# closed form vs. simulator: systematically cross-validated on a pinned grid
# in tests/test_conformance.py (and bit-pinned at the seed operating points in
# tests/test_topology.py::test_symmetric_engines_match_seed_totals_exactly).


def test_paper_claim_single_task_speedup():
    """Abstract: HALP accelerates VGG-16 by 1.7-2.0x (single task).

    Our uniform-efficiency analytical model lands slightly above (the paper's
    measured per-layer times include launch overheads); assert the speedup is
    at least the paper's band and below the 3-ES parallelism bound."""
    for plat in (GTX_1080TI, AGX_XAVIER):
        t_pre = standalone_time(NET, plat)
        for rate in (40e9, 100e9):
            t = simulate_halp(NET, plat, Link(rate))["total"]
            assert 1.7 <= t_pre / t < 3.0


def test_paper_claim_multi_task_speedup():
    """Abstract: 1.67-1.81x for 4 tasks per batch."""
    for plat in (GTX_1080TI, AGX_XAVIER):
        t_pre = standalone_time(NET, plat)
        for rate in (40e9, 100e9):
            r = simulate_halp(NET, plat, Link(rate), n_tasks=4)
            speedup = t_pre / r["avg_delay"]
            assert 1.55 <= speedup <= 2.1, (plat.name, rate, speedup)


def test_table2_halp_throughput_anchor():
    """Table II, HALP_GTX 1080TI @100 Gbps = 1423 fps (exact anchor)."""
    r = simulate_halp(NET, GTX_1080TI, Link(100e9), n_tasks=4)
    fps = 4.0 / r["total"]
    assert fps == pytest.approx(1423, rel=0.01)


def test_table2_modnn_40g_anchor():
    """Table II, Original MoDNN @40 Gbps = 327 fps (=> T_M = 3.058 ms)."""
    t = simulate_modnn(NET, GTX_1080TI, Link(40e9), 9)["total"]
    assert 1.0 / t == pytest.approx(327, rel=0.02)


def test_enhanced_modnn_between_original_and_halp():
    for rate in (40e9, 100e9):
        link = Link(rate)
        orig = 1.0 / simulate_modnn(NET, GTX_1080TI, link, 9)["total"]
        enh = enhanced_modnn_delay(NET, GTX_1080TI, link)["throughput"]
        halp = 4.0 / simulate_halp(NET, GTX_1080TI, link, n_tasks=4)["total"]
        assert orig < enh < halp


def test_multi_task_host_serialization():
    """More tasks -> host overlap zones serialise; per-batch time grows, but far
    less than linearly (the whole point of §IV.B)."""
    link = Link(40e9)
    t1 = simulate_halp(NET, GTX_1080TI, link, n_tasks=1)["total"]
    t4 = simulate_halp(NET, GTX_1080TI, link, n_tasks=4)["total"]
    assert t1 < t4 < 2.0 * t1


def test_straggler_injection():
    """A slowed secondary stretches the makespan (fault/straggler model)."""
    link = Link(40e9)
    base = simulate_halp(NET, GTX_1080TI, link)["total"]
    slow = simulate_halp(NET, GTX_1080TI, link, slowdown={"e1^0": 2.0})["total"]
    assert slow > 1.5 * base


def test_reliability_table3_anchors():
    """Table III pre-trained column: 0.815931 @ (40 Mbps, sigma=1 ms) and
    0.571420 @ (40 Mbps, sigma=5 ms) -- both are Phi(0.9/sigma_ms)."""
    t_inf = 32.43e-3  # paper's implied Xavier t_pre (slack = 0.9 ms @ 40 Mbps)
    deadline = 4.0 / 30.0
    for sigma, expect in ((1e-3, 0.815931), (5e-3, 0.571420)):
        ch = OffloadChannel(rate_bps=40e6, sigma_s=sigma)
        r = service_reliability(ch, t_inf, deadline)
        assert r == pytest.approx(expect, abs=2e-3)


def test_reliability_fluctuation_column():
    """Table III header: phi values from the 3-sigma rule."""
    cases = [
        (40e6, 1e-3, 1.2e6),
        (40e6, 5e-3, 5.3e6),
        (60e6, 5e-3, 11.0e6),
        (60e6, 9e-3, 17.3e6),
        (60e6, 14e-3, 23.2e6),
        (100e6, 14e-3, 51.3e6),
        (100e6, 18e-3, 57.4e6),
    ]
    for rate, sigma, expect in cases:
        ch = OffloadChannel(rate_bps=rate, sigma_s=sigma)
        # paper rounds to one decimal in Mbps; allow 5%
        assert rate_fluctuation(ch) == pytest.approx(expect, rel=0.05)


def test_reliability_halp_dominates():
    """HALP's shorter inference time always yields >= reliability (Table III)."""
    deadline = 4.0 / 30.0
    t_pre, t_halp = 32.43e-3, 17.77e-3
    for rate in (40e6, 60e6, 100e6):
        for sigma in (1e-3, 5e-3, 9e-3, 14e-3, 18e-3):
            ch = OffloadChannel(rate_bps=rate, sigma_s=sigma)
            assert service_reliability(ch, t_halp, deadline) >= service_reliability(
                ch, t_pre, deadline
            )


def test_speedup_ratio_eq21():
    assert speedup_ratio(2.81e-3, 4.7e-3) == pytest.approx(0.402, abs=1e-3)
