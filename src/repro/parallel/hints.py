"""Activation-sharding hints: models call ``constrain(x, name)`` at annotated
points; the launcher (or a perf variant) installs concrete shardings for the
names it wants to pin.  Default: no-op, so models stay mesh-agnostic."""
from __future__ import annotations

import jax

_RULES: dict[str, object] = {}


def set_rules(rules: dict[str, object]) -> None:
    global _RULES
    _RULES = dict(rules)


def clear_rules() -> None:
    _RULES.clear()


def constrain(x: jax.Array, name: str) -> jax.Array:
    sharding = _RULES.get(name)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)
