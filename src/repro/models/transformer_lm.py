"""Decoder-only LM covering the assigned LM family:

* qwen3-4b        -- dense, GQA (32q/8kv, head 128), qk-norm
* codeqwen1.5-7b  -- dense, MHA (32/32)
* moonshot-v1-16b-a3b -- MoE 64e top-6, GQA 16/16
* deepseek-v3-671b    -- MLA + MoE (1 shared + 256 routed top-8) + MTP

Layer stacks are *stacked pytrees* scanned with ``jax.lax.scan`` so HLO size
and XLA compile time are depth-independent (the 512-device dry-run compiles the
61-layer DeepSeek config in one scanned block).  ``first_k_dense`` leading
layers (DeepSeek) form a second, smaller stack.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (
    GQAConfig,
    MLAConfig,
    causal_mask,
    decode_mask,
    gqa_apply,
    gqa_init,
    mla_apply,
    mla_init,
)
from .common import Params, dense_params, keygen, norm_params, stack_layers, trunc_normal
from .layers import dense, rmsnorm, silu, softmax_xent
from .moe import MoEConfig, moe_apply, moe_init

__all__ = ["LMConfig", "init", "forward", "loss_fn", "decode_step", "init_cache"]


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int  # dense-FFN hidden size (used by dense layers)
    vocab: int
    qk_norm: bool = False
    rope_theta: float = 1e6
    attn: str = "gqa"  # "gqa" | "mla"
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    first_k_dense: int = 0
    mtp_depth: int = 0  # DeepSeek multi-token prediction heads
    remat: bool = True

    @property
    def gqa(self) -> GQAConfig:
        return GQAConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.d_head,
            qk_norm=self.qk_norm,
            rope_theta=self.rope_theta,
        )

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.first_k_dense if self.moe else 0

    @property
    def n_dense_layers(self) -> int:
        return self.first_k_dense if self.moe else self.n_layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _ffn_init(key, d, f, dtype):
    ks = keygen(key)
    return {
        "w1": dense_params(next(ks), d, f, bias=False, dtype=dtype),
        "w3": dense_params(next(ks), d, f, bias=False, dtype=dtype),
        "w2": dense_params(next(ks), f, d, bias=False, dtype=dtype),
    }


def _block_init(key, cfg: LMConfig, moe_layer: bool, dtype) -> Params:
    ka, kf = jax.random.split(key)
    attn = (
        mla_init(ka, cfg.mla, dtype) if cfg.attn == "mla" else gqa_init(ka, cfg.gqa, dtype)
    )
    ffn = (
        moe_init(kf, cfg.moe, dtype)
        if moe_layer
        else _ffn_init(kf, cfg.d_model, cfg.d_ff, dtype)
    )
    return {
        "ln1": norm_params(cfg.d_model, bias=False, dtype=dtype),
        "attn": attn,
        "ln2": norm_params(cfg.d_model, bias=False, dtype=dtype),
        "ffn": ffn,
    }


def init(key, cfg: LMConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    p: Params = {
        "embed": trunc_normal(next(ks), (cfg.vocab, cfg.d_model), 0.02, dtype),
        "final_norm": norm_params(cfg.d_model, bias=False, dtype=dtype),
        "lm_head": dense_params(next(ks), cfg.d_model, cfg.vocab, bias=False, std=0.02, dtype=dtype),
    }
    if cfg.n_dense_layers:
        p["dense_layers"] = stack_layers(
            lambda k: _block_init(k, cfg, moe_layer=False, dtype=dtype),
            next(ks),
            cfg.n_dense_layers,
        )
    if cfg.n_moe_layers:
        p["moe_layers"] = stack_layers(
            lambda k: _block_init(k, cfg, moe_layer=True, dtype=dtype),
            next(ks),
            cfg.n_moe_layers,
        )
    if cfg.mtp_depth:
        p["mtp"] = stack_layers(
            lambda k: {
                "proj": dense_params(k, 2 * cfg.d_model, cfg.d_model, bias=False, dtype=dtype),
                "block": _block_init(k, cfg, moe_layer=bool(cfg.moe), dtype=dtype),
                "norm_h": norm_params(cfg.d_model, bias=False, dtype=dtype),
                "norm_e": norm_params(cfg.d_model, bias=False, dtype=dtype),
            },
            next(ks),
            cfg.mtp_depth,
        )
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_apply(
    p: Params,
    cfg: LMConfig,
    moe_layer: bool,
    x,
    positions,
    mask,
    cache=None,
    cache_index=None,
):
    h = rmsnorm(x, p["ln1"])
    if cfg.attn == "mla":
        a, new_cache = mla_apply(p["attn"], cfg.mla, h, positions, mask, cache, cache_index)
    else:
        a, new_cache = gqa_apply(p["attn"], cfg.gqa, h, positions, mask, cache, cache_index)
    x = x + a
    h = rmsnorm(x, p["ln2"])
    if moe_layer:
        b, t, d = h.shape
        y, aux = moe_apply(p["ffn"], cfg.moe, h.reshape(b * t, d))
        y = y.reshape(b, t, d)
        lb = aux["load_balance_loss"]
    else:
        y = dense(silu(dense(h, p["ffn"]["w1"])) * dense(h, p["ffn"]["w3"]), p["ffn"]["w2"])
        lb = jnp.zeros((), jnp.float32)
    return x + y, new_cache, lb


def _scan_stack(p_stack, cfg, moe_layer, x, positions, mask):
    from ..parallel.hints import constrain

    blk = partial(_block_apply, cfg=cfg, moe_layer=moe_layer)

    def body(carry, p_l):
        x, lb = carry
        x, _, lb_l = blk(p_l, x=x, positions=positions, mask=mask)
        x = constrain(x, "lm_residual")
        return (x, lb + lb_l), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, lb), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), p_stack)
    return x, lb


def trunk(params: Params, cfg: LMConfig, tokens: jax.Array):
    """tokens [B, T] -> (pre-head hidden [B, T, D], load_balance_loss)."""
    b, t = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    mask = causal_mask(t)
    lb = jnp.zeros((), jnp.float32)
    if cfg.n_dense_layers:
        x, lb1 = _scan_stack(params["dense_layers"], cfg, False, x, positions, mask)
        lb = lb + lb1
    if cfg.n_moe_layers:
        x, lb2 = _scan_stack(params["moe_layers"], cfg, True, x, positions, mask)
        lb = lb + lb2
    return x, lb


def forward(params: Params, cfg: LMConfig, tokens: jax.Array) -> tuple[jax.Array, jax.Array]:
    """tokens [B, T] -> (logits [B, T, V], load_balance_loss)."""
    x, lb = trunk(params, cfg, tokens)
    x = rmsnorm(x, params["final_norm"])
    logits = dense(x, params["lm_head"])
    return logits, lb


def loss_fn(
    params: Params,
    cfg: LMConfig,
    tokens: jax.Array,
    labels: jax.Array,
    lb_coef: float = 0.01,
    mtp_coef: float = 0.3,
) -> tuple[jax.Array, dict]:
    h, lb = trunk(params, cfg, tokens)
    logits = dense(rmsnorm(h, params["final_norm"]), params["lm_head"])
    loss = softmax_xent(logits, labels)
    metrics = {"ce": loss, "load_balance": lb}
    if cfg.mtp_depth and "mtp" in params:
        # DeepSeek-V3 MTP (depth 1): predict token t+2 from the trunk state at
        # t and the embedding of the label at t+1 (shares embed + lm_head).
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        mask = causal_mask(t)
        mtp = jax.tree_util.tree_map(lambda a: a[0], params["mtp"])  # depth-1 module
        emb_next = params["embed"][labels]  # embedding of token t+1
        merged = dense(
            jnp.concatenate([rmsnorm(h, mtp["norm_h"]), rmsnorm(emb_next, mtp["norm_e"])], -1),
            mtp["proj"],
        )
        h2, _, _ = _block_apply(
            mtp["block"], cfg, bool(cfg.moe), merged, positions, mask
        )
        logits2 = dense(rmsnorm(h2, params["final_norm"]), params["lm_head"])
        # labels for t+2: shift `labels` left by one (drop the last column)
        mtp_loss = softmax_xent(logits2[:, :-1], labels[:, 1:])
        metrics["mtp"] = mtp_loss
        loss = loss + mtp_coef * mtp_loss
    loss = loss + lb_coef * lb
    metrics["total"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=jnp.float32) -> Params:
    """Stacked per-layer KV caches.  GQA: k/v [L, B, S, Hkv, dh]; MLA: the
    compressed latent [L, B, S, kv_lora + rope] (MLA's memory advantage)."""

    def stack(n):
        if cfg.attn == "mla":
            return jnp.zeros(
                (n, batch, max_seq, cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim), dtype
            )
        return {
            "k": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, cfg.d_head), dtype),
            "v": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, cfg.d_head), dtype),
        }

    cache: Params = {}
    if cfg.n_dense_layers:
        cache["dense"] = stack(cfg.n_dense_layers)
    if cfg.n_moe_layers:
        cache["moe"] = stack(cfg.n_moe_layers)
    return cache


def _decode_stack(p_stack, cache_stack, cfg, moe_layer, x, positions, mask, index):
    blk = partial(_block_apply, cfg=cfg, moe_layer=moe_layer)

    def body(x, scanned):
        p_l, c_l = scanned
        kv = (c_l["k"], c_l["v"]) if cfg.attn == "gqa" else c_l
        x, new_kv, _ = blk(p_l, x=x, positions=positions, mask=mask, cache=kv, cache_index=index)
        new_c = {"k": new_kv[0], "v": new_kv[1]} if cfg.attn == "gqa" else new_kv
        return x, new_c

    x, new_cache = lax.scan(body, x, (p_stack, cache_stack))
    return x, new_cache


def decode_step(params: Params, cfg: LMConfig, cache: Params, tokens: jax.Array, index):
    """One decode step: tokens [B, 1] at position ``index`` against a cache of
    length max_seq.  Returns (logits [B, vocab], new_cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens]
    positions = jnp.full((b, 1), index, jnp.int32)
    new_cache: Params = {}
    if cfg.n_dense_layers:
        s_max = (
            cache["dense"].shape[2]
            if cfg.attn == "mla"
            else cache["dense"]["k"].shape[2]
        )
        mask = decode_mask(s_max, index)
        x, new_cache["dense"] = _decode_stack(
            params["dense_layers"], cache["dense"], cfg, False, x, positions, mask, index
        )
    if cfg.n_moe_layers:
        s_max = (
            cache["moe"].shape[2] if cfg.attn == "mla" else cache["moe"]["k"].shape[2]
        )
        mask = decode_mask(s_max, index)
        x, new_cache["moe"] = _decode_stack(
            params["moe_layers"], cache["moe"], cfg, True, x, positions, mask, index
        )
    x = rmsnorm(x, params["final_norm"])
    logits = dense(x, params["lm_head"])[:, 0]
    return logits, new_cache
