"""The paper's contribution: receptive-field-exact partitioning (rf, partition),
HALP / MoDNN scheduling (schedule), exact event simulation (simulator), and the
service-reliability model (reliability)."""
from .nets import ConvNetGeom, vgg16_geom
from .partition import HALPPlan, Segment, plan_even, plan_halp, split_rows
from .reliability import OffloadChannel, rate_fluctuation, service_reliability
from .rf import (
    LayerGeom,
    RFState,
    input_range_exact,
    input_range_paper,
    out_size,
    propagate_range,
    rf_chain,
)
from .schedule import (
    AGX_XAVIER,
    GTX_1080TI,
    TPU_V5E,
    Link,
    Platform,
    halp_closed_form,
    modnn_time,
    speedup_ratio,
    standalone_time,
)
from .simulator import Sim, enhanced_modnn_delay, simulate_halp, simulate_modnn
