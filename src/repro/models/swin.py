"""Swin Transformer (Liu et al., arXiv:2103.14030) -- swin-b.

Windowed attention has a *bounded receptive field*, so the paper's
receptive-field partitioning applies directly: shifted windows need exactly a
one-window halo, the transformer analogue of HALP's boundary exchange
(see DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from .common import Params, conv_params, dense_params, keygen, norm_params, stack_layers, trunc_normal
from .layers import conv2d, dense, gelu, layernorm, softmax_xent

__all__ = ["SwinConfig", "init", "apply"]


@dataclass(frozen=True)
class SwinConfig:
    name: str = "swin-b"
    img_res: int = 224
    patch: int = 4
    window: int = 7
    depths: tuple[int, ...] = (2, 2, 18, 2)
    dims: tuple[int, ...] = (128, 256, 512, 1024)
    n_heads: tuple[int, ...] = (4, 8, 16, 32)
    mlp_ratio: int = 4
    num_classes: int = 1000
    in_channels: int = 3
    remat: bool = True


def _block_init(key, dim, heads, window, mlp_ratio, dtype):
    ks = keygen(key)
    return {
        "ln1": norm_params(dim, dtype=dtype),
        "wqkv": dense_params(next(ks), dim, 3 * dim, dtype=dtype),
        "wo": dense_params(next(ks), dim, dim, dtype=dtype),
        "rel_bias": trunc_normal(next(ks), ((2 * window - 1) ** 2, heads), dtype=dtype),
        "ln2": norm_params(dim, dtype=dtype),
        "fc1": dense_params(next(ks), dim, mlp_ratio * dim, dtype=dtype),
        "fc2": dense_params(next(ks), mlp_ratio * dim, dim, dtype=dtype),
    }


def _rel_index(window: int) -> jax.Array:
    """Relative-position index table for a window (static)."""
    coords = jnp.stack(
        jnp.meshgrid(jnp.arange(window), jnp.arange(window), indexing="ij"), 0
    ).reshape(2, -1)
    rel = coords[:, :, None] - coords[:, None, :]  # [2, n, n]
    rel = rel + (window - 1)
    return rel[0] * (2 * window - 1) + rel[1]  # [n, n]


def _window_attention(p, x, heads, window, attn_mask=None):
    """x: [B, nW, n, C] windows -> same shape."""
    b, nw, n, c = x.shape
    qkv = dense(x, p["wqkv"]).reshape(b, nw, n, 3, heads, c // heads)
    q, k, v = qkv[..., 0, :, :], qkv[..., 1, :, :], qkv[..., 2, :, :]
    logits = jnp.einsum("bwnhd,bwmhd->bwhnm", q, k) / jnp.sqrt(c / heads)
    bias = p["rel_bias"][_rel_index(window).reshape(-1)].reshape(n, n, heads)
    logits = logits + bias.transpose(2, 0, 1)[None, None]
    if attn_mask is not None:  # [nW, n, n] boolean (True = keep)
        logits = jnp.where(attn_mask[None, :, None], logits, -1e9)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
    out = jnp.einsum("bwhnm,bwmhd->bwnhd", probs, v).reshape(b, nw, n, c)
    return dense(out, p["wo"])


def _to_windows(x, window):
    """[B, H, W, C] -> [B, nW, window*window, C]"""
    b, h, w, c = x.shape
    x = x.reshape(b, h // window, window, w // window, window, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, (h // window) * (w // window), window * window, c)


def _from_windows(x, window, h, w):
    b = x.shape[0]
    c = x.shape[-1]
    x = x.reshape(b, h // window, w // window, window, window, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, h, w, c)


def _shift_mask(h, w, window, shift) -> jax.Array:
    """Attention mask for shifted windows: tokens attend only within their
    original region (static, computed with numpy-style ops at trace time)."""
    img = jnp.zeros((h, w), jnp.int32)
    bounds = (slice(0, -window), slice(-window, -shift), slice(-shift, None))
    cnt = 0
    for hb in bounds:
        for wb in bounds:
            img = img.at[hb, wb].set(cnt)
            cnt += 1
    win = _to_windows(img[None, :, :, None].astype(jnp.float32), window)[0, :, :, 0]
    return win[:, :, None] == win[:, None, :]  # [nW, n, n]


def _swin_block(p, x, heads, window, shift):
    """x: [B, H, W, C]."""
    b, h, w, c = x.shape
    shortcut = x
    x = layernorm(x, p["ln1"])
    if shift:
        x = jnp.roll(x, (-shift, -shift), axis=(1, 2))
        mask = _shift_mask(h, w, window, shift)
    else:
        mask = None
    xw = _to_windows(x, window)
    xw = _window_attention(p, xw, heads, window, mask)
    x = _from_windows(xw, window, h, w)
    if shift:
        x = jnp.roll(x, (shift, shift), axis=(1, 2))
    x = shortcut + x
    h2 = layernorm(x, p["ln2"])
    return x + dense(gelu(dense(h2, p["fc1"])), p["fc2"])


def init(key, cfg: SwinConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    p: Params = {
        "patch_embed": conv_params(next(ks), cfg.patch, cfg.in_channels, cfg.dims[0], dtype=dtype),
        "patch_norm": norm_params(cfg.dims[0], dtype=dtype),
        "stages": [],
        "ln": norm_params(cfg.dims[-1], dtype=dtype),
        "head": dense_params(next(ks), cfg.dims[-1], cfg.num_classes, dtype=dtype),
    }
    stages = []
    for si, (depth, dim, heads) in enumerate(zip(cfg.depths, cfg.dims, cfg.n_heads)):
        stage = {
            "blocks": stack_layers(
                lambda k, dim=dim, heads=heads: _block_init(
                    k, dim, heads, cfg.window, cfg.mlp_ratio, dtype
                ),
                next(ks),
                depth,
            )
        }
        if si + 1 < len(cfg.depths):
            stage["merge_norm"] = norm_params(4 * dim, dtype=dtype)
            stage["merge"] = dense_params(next(ks), 4 * dim, cfg.dims[si + 1], bias=False, dtype=dtype)
        stages.append(stage)
    p["stages"] = stages
    return p


def apply(params: Params, cfg: SwinConfig, x: jax.Array) -> jax.Array:
    b = x.shape[0]
    x = conv2d(x, params["patch_embed"], stride=cfg.patch, padding="VALID")
    x = layernorm(x, params["patch_norm"])
    for si, stage in enumerate(params["stages"]):
        heads = cfg.n_heads[si]
        hcur = x.shape[1]
        shift = cfg.window // 2 if hcur > cfg.window else 0
        win = min(cfg.window, hcur)

        # shallow stages unroll python-side; deep stages scan (regular, shifted)
        # block *pairs* so HLO size stays bounded.
        blocks = stage["blocks"]
        depth = cfg.depths[si]
        if depth >= 6 and depth % 2 == 0:
            # scan over (regular, shifted) pairs to bound HLO size
            pair = jax.tree_util.tree_map(
                lambda a: a.reshape(depth // 2, 2, *a.shape[1:]), blocks
            )

            def pair_body(h, p_pair):
                p0 = jax.tree_util.tree_map(lambda a: a[0], p_pair)
                p1 = jax.tree_util.tree_map(lambda a: a[1], p_pair)
                h = _swin_block(p0, h, heads, win, 0)
                h = _swin_block(p1, h, heads, win, shift)
                return h, None

            if cfg.remat:
                pair_body = jax.checkpoint(pair_body, prevent_cse=False)
            x, _ = lax.scan(pair_body, x, pair)
        else:
            for li in range(depth):
                p_l = jax.tree_util.tree_map(lambda a: a[li], blocks)
                x = _swin_block(p_l, x, heads, win, shift if li % 2 else 0)
        if "merge" in stage:  # patch merging: 2x2 neighbourhood -> next dim
            bb, h, w, c = x.shape
            x = x.reshape(bb, h // 2, 2, w // 2, 2, c).transpose(0, 1, 3, 2, 4, 5)
            x = x.reshape(bb, h // 2, w // 2, 4 * c)
            x = dense(layernorm(x, stage["merge_norm"]), stage["merge"])
    x = layernorm(x, params["ln"])
    return dense(jnp.mean(x, axis=(1, 2)), params["head"])


def loss_fn(params, cfg: SwinConfig, images, labels):
    logits = apply(params, cfg, images)
    return softmax_xent(logits, labels), {"logits": logits}
