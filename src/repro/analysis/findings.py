"""Finding/Report types shared by every static analyzer.

A *finding* is one violated invariant with enough context (layer, stage,
resource, job) to act on without re-running the analyzer; a *report* is an
ordered collection of findings plus a count of the individual invariant
checks performed (so tests can assert an analyzer actually exercised its
checklist rather than silently skipping it).

Analyzers never raise on bad input -- they report.  Callers that want
exception semantics (``optimize_plan(verify=True)``, ``run_plan(verify=True)``)
use :meth:`Report.raise_if_failed`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["Finding", "Report", "AnalysisError"]


@dataclass(frozen=True)
class Finding:
    """One violated invariant.

    ``check`` is a stable dotted identifier (``plan.coverage``,
    ``dag.deadlock``, ``kernel.support``, ``keying.unkeyed``) so tests and CI
    can match on the invariant class; ``where`` names the site (layer, stage,
    slot, resource, job, config field); ``detail`` is the human diagnostic."""

    check: str
    where: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.check}] {self.where}: {self.detail}"


class AnalysisError(ValueError):
    """Raised by :meth:`Report.raise_if_failed`; carries the full report."""

    def __init__(self, context: str, report: "Report"):
        self.report = report
        lines = "\n".join(f"  {f}" for f in report.findings)
        super().__init__(
            f"{context}: {len(report.findings)} static-analysis finding(s)\n{lines}"
        )


@dataclass
class Report:
    findings: list[Finding] = field(default_factory=list)
    checks: int = 0  # invariant checks performed (passed + failed)

    @property
    def ok(self) -> bool:
        return not self.findings

    def add(self, check: str, where: str, detail: str) -> None:
        self.findings.append(Finding(check, where, detail))

    def tick(self, n: int = 1) -> None:
        """Count ``n`` invariant checks as performed."""
        self.checks += n

    def extend(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        self.checks += other.checks
        return self

    def filtered(self, check_prefix: str) -> list[Finding]:
        return [f for f in self.findings if f.check.startswith(check_prefix)]

    def raise_if_failed(self, context: str) -> None:
        if not self.ok:
            raise AnalysisError(context, self)

    def __str__(self) -> str:
        if self.ok:
            return f"ok ({self.checks} checks)"
        body = "\n".join(str(f) for f in self.findings)
        return f"{len(self.findings)} finding(s) / {self.checks} checks:\n{body}"
