"""Multi-task placement sweep: per-task sub-topologies vs the shared plan.

The paper's §IV.B/§V.C multi-task scenario runs 4 tasks per batch, every task
on an identical secondary group with one shared partition (eq. 22's model).
On a *heterogeneous* pool that deployment leaves latency on the table twice:
grouping in pool order can pair two slow ESs into one task, and the shared
equal-split geometry ignores each group's capacity mix.  This benchmark
reproduces the 4-tasks-per-batch scenario on a 1-host + 8-secondary pool
(two fast, two medium, two slow, two very slow ESs; the slow half behind
10 Gbps links vs 40 Gbps) and compares, on the *same* shared-contention DES
(``build_multitask_dag`` -- host and links are physical resources):

* **shared**   -- ``shared_plan_placement``: pool-order groups, one
  equal-split plan geometry for every task (the paper's model),
* **per-task** -- ``place_tasks``: greedy capacity-weighted assignment +
  local-search swaps + per-task plan refinement.

Every per-task plan is also executed end-to-end via
``spatial/partition_apply.run_plan`` on a thin-channel VGG-16 with identical
224-row spatial geometry (segments asserted identical to the full-width
plans) and checked bit-compatible against the single-device forward.

Acceptance (tests/test_benchmarks.py): per-task placement strictly beats the
shared baseline on mean per-task delay *and* batch makespan, and all plans
verify lossless.  CSV rows (``name,us_per_call,derived``) match the other
benchmarks' format.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.core import (
    GTX_1080TI,
    CollabTopology,
    Link,
    TaskPlacement,
    place_tasks,
    shared_plan_placement,
    simulate_placement,
    standalone_time,
    vgg16_geom,
)
from repro.core.partition import plan_halp_n

NET = vgg16_geom()
N_TASKS = 4
FAST_BPS = 40e9
SLOW_BPS = 10e9
# pool order interleaves nothing: fast pairs first, so the paper-style
# contiguous grouping pairs the two slowest ESs into one task
ES_SCALES = (1.0, 1.0, 0.6, 0.6, 0.35, 0.35, 0.2, 0.2)


def build_pool() -> CollabTopology:
    secs = tuple(f"e{j}" for j in range(1, len(ES_SCALES) + 1))
    platforms = {"e0": GTX_1080TI}
    links = {}
    for s, scale in zip(secs, ES_SCALES):
        platforms[s] = GTX_1080TI.scaled(scale, f"es x{scale:g}")
        rate = FAST_BPS if scale >= 0.6 else SLOW_BPS
        links[("e0", s)] = Link(rate)
        links[(s, "e0")] = Link(rate)
    return CollabTopology(
        host="e0", secondaries=secs, platforms=platforms,
        links=links, default_link=Link(FAST_BPS),
    )


def verify_placement_lossless(placement: TaskPlacement, knobs=None) -> int:
    """Execute every task's plan with ``run_plan`` against the single-device
    forward (thin-channel VGG-16, same 224-row spatial geometry; segments
    asserted identical to the full-width plan's).  Returns plans verified."""
    import jax
    import numpy as np
    from repro.models import vgg
    from repro.spatial import run_plan

    cfg = vgg.VGGConfig(img_res=NET.in_rows, width_mult=0.125, num_classes=10)
    thin_net = cfg.geom()
    params = vgg.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, NET.in_rows, NET.in_rows, 3))
    ref = vgg.features(params, cfg, x)

    for t, (group, full_plan) in enumerate(
        zip(placement.assignments, placement.plans)
    ):
        if knobs is not None:
            ratios, overlap = knobs[t]
        else:
            ratios = placement.sub_topology(t).capacity_ratios()
            overlap = 4
        thin_plan = plan_halp_n(
            thin_net,
            secondaries=group,
            host=placement.pool.host,
            overlap_rows=overlap,
            ratios=ratios,
        )
        for thin_part, full_part in zip(thin_plan.parts, full_plan.parts):
            assert thin_part.out == full_part.out, (
                f"task {t}: row partition diverged at layer {thin_part.index}"
            )
        out = run_plan(thin_plan, params["features"], vgg.apply_layer, x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
        )
    return len(placement.plans)


def run_comparison(
    swap_rounds: int = 4,
    optimize_final: bool = True,
    verify: bool = True,
) -> dict:
    """Score both deployments on the shared-contention DES; returns metrics."""
    pool = build_pool()
    out: dict = {"n_tasks": N_TASKS}

    shared = shared_plan_placement(NET, pool, N_TASKS)
    sh = simulate_placement(NET, shared)
    out["shared"] = dict(
        makespan=sh["total"], avg_delay=sh["avg_delay"],
        per_task=tuple(sh["per_task_finish"]),
        assignments=shared.assignments,
    )

    res = place_tasks(
        NET, pool, N_TASKS, swap_rounds=swap_rounds, optimize_final=optimize_final
    )
    out["per_task"] = dict(
        makespan=res.makespan, avg_delay=res.avg_delay,
        per_task=res.per_task_finish,
        assignments=res.placement.assignments,
        evaluations=res.evaluations,
    )
    out["gain_avg"] = 1.0 - res.avg_delay / sh["avg_delay"]
    out["gain_makespan"] = 1.0 - res.makespan / sh["total"]
    out["speedup_vs_standalone"] = (
        standalone_time(NET, GTX_1080TI) / (res.avg_delay / 1.0)
    )
    if verify:
        # the shared baseline was built with the equal split, so the thin-net
        # rebuild must use the same knobs (capacity ratios only coincide with
        # equal ones inside same-scale groups)
        group_size = len(shared.assignments[0])
        shared_knobs = tuple(
            (tuple(1.0 / group_size for _ in range(group_size)), 4)
            for _ in shared.assignments
        )
        out["plans_verified_lossless"] = verify_placement_lossless(
            res.placement, knobs=res.knobs
        ) + verify_placement_lossless(shared, knobs=shared_knobs)
    return out


def run_all() -> dict:
    out = run_comparison()
    print(
        f"\n== Multi-task placement: {out['n_tasks']} tasks, 8 heterogeneous "
        f"secondaries (x{'/'.join(f'{s:g}' for s in ES_SCALES)}), slow half "
        f"at {SLOW_BPS/1e9:.0f} Gbps =="
    )
    print(f"{'policy':9s} {'mean T (ms)':>11s} {'makespan (ms)':>13s} {'groups'}")
    for policy in ("shared", "per_task"):
        m = out[policy]
        groups = " ".join("+".join(g) for g in m["assignments"])
        print(
            f"{policy:9s} {m['avg_delay']*1e3:11.3f} {m['makespan']*1e3:13.3f} {groups}"
        )
        print(f"placement_{policy},{m['avg_delay']*1e6:.1f},{m['makespan']*1e6:.1f}")
    print(
        f"\nper-task placement cuts mean delay {out['gain_avg']*100:.1f}% and "
        f"makespan {out['gain_makespan']*100:.1f}% vs the shared-plan baseline "
        f"({out['per_task']['evaluations']} DES evaluations)"
    )
    print(f"placement_gain,,{out['gain_avg']:.4f}")
    if "plans_verified_lossless" in out:
        print(
            f"losslessness: {out['plans_verified_lossless']} per-task plans "
            f"verified bit-compatible with the single-device forward via run_plan"
        )
    return out


if __name__ == "__main__":
    run_all()
