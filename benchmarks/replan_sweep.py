"""Replan sweep: static vs cached-adaptive vs always-replan under a
time-variant channel (the Table-III comparison turned into a policy study).

The paper's §V.D quantifies service reliability under a fluctuating offloading
channel but keeps one plan chosen offline against nominal rates.  This sweep
replays a Gauss-Markov channel through the discrete-event simulator and
compares three planners on identical traces:

* **static**   -- one plan optimised for the nominal link rates (the paper's
  deployment model: the plan never sees a measurement),
* **cached**   -- :class:`~repro.core.replan.ReplanController` with the
  default quantised-bucket :class:`~repro.core.replan.PlanCache` + hysteresis,
* **always**   -- the same controller with exact-rate keying and no
  hysteresis, i.e. a fresh ``optimize_plan`` whenever the estimate moves (the
  upper baseline the cache is amortising).

Scenario: one Xavier-class host and two Xavier-class secondaries, nominal
2.5 Gbps ES-ES links; secondary ``b``'s link drifts over 0.1-2.5 Gbps
(mean-reverting around 0.45 Gbps -- measured-rate drift away from the
advertised nominal, the arXiv 2211.13778 testbed observation), while the
IoT->host offloading rate wanders over the paper's 40-120 Mbps band and sets
the per-epoch deadline slack (deadline 4/30 s, sigma 9 ms: Table III's middle
row).  Reliability per epoch is eq. §V.D's
``Phi((D - mu_off - T_inf) / sigma)`` with ``T_inf`` the DES makespan of the
plan the policy served *that epoch* under the *true* rates.

Every distinct plan the cached controller served is also executed end-to-end
with ``spatial/partition_apply.run_plan`` on a thin-channel VGG-16 with the
same 224-row spatial geometry (row partitions depend only on spatial dims, so
the segments are asserted identical) and checked lossless against the
single-device forward.

CSV rows (``name,us_per_call,derived``) match the other benchmarks' format.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.core import (
    AGX_XAVIER,
    CollabTopology,
    GaussMarkovTrace,
    Link,
    OffloadChannel,
    ReplanConfig,
    ReplanController,
    StaticPlanner,
    optimize_static,
    plan_halp_n,
    replay_rate_trace,
    service_reliability,
    vgg16_geom,
)

NET = vgg16_geom()
DEADLINE_S = 4.0 / 30.0  # 30 FPS with 4 tasks per batch (paper §V.D)
OFFLOAD_SIGMA_S = 9e-3  # Table III's middle fluctuation level
N_TASKS = 4
NOMINAL_BPS = 2.5e9


def build_topology() -> CollabTopology:
    return CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={"e0": AGX_XAVIER, "a": AGX_XAVIER, "b": AGX_XAVIER},
        default_link=Link(NOMINAL_BPS),
    )


def build_traces(n_epochs: int) -> tuple[dict, list[float]]:
    """Per-link ES-ES rate traces + the IoT->host offload-rate trace."""
    trace_b = GaussMarkovTrace(
        lo=0.1e9, hi=NOMINAL_BPS, mean=0.45e9, corr=0.92, sigma_frac=0.08,
        start=NOMINAL_BPS, seed=7,
    ).rates(n_epochs)
    trace_a = GaussMarkovTrace(
        lo=1.5e9, hi=NOMINAL_BPS, corr=0.9, sigma_frac=0.1, seed=3
    ).rates(n_epochs)
    link_rates = {
        ("e0", "b"): trace_b, ("b", "e0"): trace_b,
        ("e0", "a"): trace_a, ("a", "e0"): trace_a,
    }
    offload = GaussMarkovTrace(
        lo=40e6, hi=120e6, corr=0.9, sigma_frac=0.12, seed=11
    ).rates(n_epochs)
    return link_rates, offload


def _metrics(results: list[dict], offload: list[float]) -> dict:
    makespans = [r["makespan"] for r in results]
    rels = [
        service_reliability(
            OffloadChannel(rate_bps=offload[i], sigma_s=OFFLOAD_SIGMA_S),
            makespans[i],
            DEADLINE_S,
        )
        for i in range(len(makespans))
    ]
    return dict(
        mean_makespan=sum(makespans) / len(makespans),
        max_makespan=max(makespans),
        mean_reliability=sum(rels) / len(rels),
        min_reliability=min(rels),
    )


def steady_state_hit_rate(results: list[dict], warmup_frac: float = 0.25) -> float:
    """Cache hit rate over the post-warmup window, recovered from the
    per-epoch planner-stats snapshots ``replay_rate_trace`` records."""
    warm = max(1, int(len(results) * warmup_frac))
    before, after = results[warm - 1]["planner_stats"], results[-1]["planner_stats"]
    requests = (after["cache_hits"] + after["cache_misses"]) - (
        before["cache_hits"] + before["cache_misses"]
    )
    hits = after["cache_hits"] - before["cache_hits"]
    return hits / requests if requests else 0.0


def verify_plans_lossless(controller: ReplanController, max_plans: int | None = None) -> int:
    """Execute every distinct cached plan with ``run_plan`` and check it
    against the single-device forward.

    Row partitions depend only on spatial geometry, so each cached
    (ratios, overlap) pair is re-planned on a thin-channel VGG-16 with the
    same 224-row input; the resulting segments are asserted identical to the
    full-width plan's before the numeric check.  Returns the number of plans
    verified; raises on any mismatch."""
    import jax
    import numpy as np
    from repro.models import vgg
    from repro.spatial import run_plan

    cfg = vgg.VGGConfig(img_res=NET.in_rows, width_mult=0.125, num_classes=10)
    thin_net = cfg.geom()
    params = vgg.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, NET.in_rows, NET.in_rows, 3))
    ref = vgg.features(params, cfg, x)

    entries = controller.cache.entries()
    if max_plans is not None:
        entries = entries[-max_plans:]
    for res in entries:
        thin_plan = plan_halp_n(
            thin_net,
            secondaries=controller.nominal.secondaries,
            host=controller.nominal.host,
            overlap_rows=res.overlap_rows,
            ratios=res.ratios,
        )
        for thin_part, full_part in zip(thin_plan.parts, res.plan.parts):
            assert thin_part.out == full_part.out, (
                f"row partition diverged at layer {thin_part.index}"
            )
        out = run_plan(thin_plan, params["features"], vgg.apply_layer, x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
        )
    return len(entries)


def run_sweep(
    n_epochs: int = 160,
    include_always: bool = True,
    verify: bool = True,
    max_verify_plans: int | None = None,
) -> dict:
    """Run all policies on the shared traces; returns per-policy metrics."""
    topo = build_topology()
    link_rates, offload = build_traces(n_epochs)
    config = ReplanConfig(n_tasks=N_TASKS)
    out: dict = {"n_epochs": n_epochs}

    static_res = optimize_static(NET, topo, config)
    static_run = replay_rate_trace(
        NET, topo, StaticPlanner(static_res.plan), link_rates, n_tasks=N_TASKS
    )
    out["static"] = _metrics(static_run, offload)

    cached_ctl = ReplanController(NET, topo, config)
    cached_run = replay_rate_trace(NET, topo, cached_ctl, link_rates, n_tasks=N_TASKS)
    out["cached"] = _metrics(cached_run, offload)
    out["cached"].update(cached_ctl.stats())
    out["cached"]["steady_state_hit_rate"] = steady_state_hit_rate(cached_run)

    if include_always:
        always_ctl = ReplanController(
            NET, topo, ReplanConfig(n_tasks=N_TASKS, bucket_frac=0.0, hysteresis=0)
        )
        always_run = replay_rate_trace(NET, topo, always_ctl, link_rates, n_tasks=N_TASKS)
        out["always"] = _metrics(always_run, offload)
        out["always"].update(
            optimizer_calls=always_ctl.optimizer_calls, replans=always_ctl.replans
        )

    if verify:
        out["plans_verified_lossless"] = verify_plans_lossless(
            cached_ctl, max_plans=max_verify_plans
        )
    return out


def run_all() -> dict:
    out = run_sweep()
    print(
        f"\n== Replan sweep: {out['n_epochs']} epochs, deadline "
        f"{DEADLINE_S*1e3:.1f} ms, offload 40-120 Mbps sigma "
        f"{OFFLOAD_SIGMA_S*1e3:.0f} ms, link b 0.1-2.5 Gbps =="
    )
    print(
        f"{'policy':8s} {'mean T (ms)':>11s} {'max T (ms)':>10s} "
        f"{'mean rel':>9s} {'min rel':>9s} {'optimizes':>9s}"
    )
    for policy in ("static", "cached", "always"):
        if policy not in out:
            continue
        m = out[policy]
        optimizes = m.get("optimizer_calls", 1 if policy == "static" else 0)
        print(
            f"{policy:8s} {m['mean_makespan']*1e3:11.2f} {m['max_makespan']*1e3:10.2f} "
            f"{m['mean_reliability']:9.6f} {m['min_reliability']:9.6f} {optimizes:9d}"
        )
        print(
            f"replan_{policy},{m['mean_makespan']*1e6:.1f},{m['mean_reliability']:.6f}"
        )
    c = out["cached"]
    print(
        f"\ncached: {c['replans']} plan switches, {c['optimizer_calls']} optimizer "
        f"calls over {out['n_epochs']} epochs; cache hit rate {c['cache_hit_rate']:.3f} "
        f"overall, {c['steady_state_hit_rate']:.3f} steady-state"
    )
    print(f"replan_cached_hit_rate,,{c['steady_state_hit_rate']:.4f}")
    if "plans_verified_lossless" in out:
        print(
            f"losslessness: {out['plans_verified_lossless']} distinct replanned "
            f"plans verified bit-compatible with the single-device forward via run_plan"
        )
    return out


if __name__ == "__main__":
    run_all()
