"""Persistent, content-keyed plan store: warm starts across process restarts.

:class:`~repro.core.replan.PlanCache` dies with the process, so every
controller restart re-pays the full cold optimisation for every operating
point it revisits -- exactly the replan latency the authors' prototype paper
(arXiv 2211.13778) shows dominating on real testbeds -- and a fleet of
controllers (DistrEdge-style, arXiv 2202.01699) cannot share warm plans at
all.  This module is the orco-style persistent backing tier behind the LRU:

* **Content keying.**  Entries are keyed on the *exact* in-memory cache
  identity -- the ``(cache kind, topology fingerprint, optimiser-config
  knobs, bucket key)`` tuple :class:`~repro.core.replan.ReplanController`
  already builds -- serialised canonically (:func:`canonical_key`) and hashed
  (sha256).  Two controllers, two processes, or two machines that would hit
  the same in-memory cache entry therefore hit the same store row, and a row
  filled by one controller warm-starts every other.  The canonical text is
  stored alongside the hash and compared on every read, so a hash collision
  can never serve a wrong plan.

* **Reproducible payloads.**  The stored value is the optimised
  :class:`~repro.core.optimizer.OptimizeResult` /
  :class:`~repro.core.placement.PlacementResult` itself (pickled), so a
  store-served plan is *bit-identical* to the freshly-optimised one -- same
  row partition, same float makespan (``benchmarks/planstore_bench.py`` pins
  this).  Keys quantise rates into bands optimised against band
  *representatives* (see :mod:`~repro.core.replan`), so entries are
  reproducible regardless of which measured rate first filled them -- the
  property that makes offline precomputation (``tools/precompute_plans.py``)
  meaningful.

* **Provenance.**  Each row records what the plan was optimised against (the
  band-representative link rates and per-ES platforms), the scored makespan,
  the pricing engine, and a creation timestamp -- enough to audit a fleet's
  shared store or rebuild an entry from its description.

* **Explicit invalidation.**  A changed optimiser config is a different key
  by construction (the knobs live in the fingerprint), so a reconfigured
  controller can never read a stale plan.  A changed *code schema* (the shape
  of plans/results themselves) is handled by :data:`PLAN_SCHEMA_VERSION`:
  every row carries the version it was written under, reads require an exact
  match, and :meth:`PlanStore.prune_stale` garbage-collects outdated rows.
  Bump the constant whenever ``HALPPlan`` / ``OptimizeResult`` /
  ``PlacementResult`` change shape.

Concurrency: sqlite in WAL mode with a busy timeout -- many reader processes
and a writer coexist, which is all the fleet sharing model needs (writers are
rare: one per cache miss).  ``put`` is last-writer-wins on a key, which is
safe because any two writers of the same key computed the same plan from the
same band representatives.
"""
from __future__ import annotations

import hashlib
import json
import logging
import math
import pickle
import sqlite3
import time
from pathlib import Path

_log = logging.getLogger(__name__)

__all__ = ["PLAN_SCHEMA_VERSION", "canonical_key", "key_hash", "PlanStore"]

# Version of the *stored payload schema*: the pickled OptimizeResult /
# PlacementResult object graphs (plans, layouts, topologies).  Reads require
# an exact match, so bumping this invalidates every existing store in one
# line -- the explicit upgrade path for refactors that change plan shape.
PLAN_SCHEMA_VERSION = 2  # v2: OptimizeResult grew `schemes`; plans may be SchemePlan


def canonical_key(key) -> str:
    """Deterministic text form of a cache key tuple.

    Handles exactly the types the replan/placement cache keys are built from
    (nested tuples/lists of str, bool, int, float, None) and refuses anything
    else loudly -- a silently ambiguous serialisation here would alias store
    entries.  Distinct types never collide: strings are JSON-quoted, bools
    render as ``True``/``False``, and floats use ``repr`` (shortest
    round-trip, so distinct floats stay distinct and equal floats -- e.g. a
    band anchor -- always serialise identically)."""
    if isinstance(key, (tuple, list)):
        return "(" + ",".join(canonical_key(k) for k in key) + ")"
    if key is None or isinstance(key, bool):
        return repr(key)
    if isinstance(key, (int, float)):
        if isinstance(key, float) and not math.isfinite(key):
            raise ValueError(f"cache keys must be finite, got {key!r}")
        return repr(key)
    if isinstance(key, str):
        return json.dumps(key)
    raise TypeError(f"unsupported type in cache key: {type(key).__name__} ({key!r})")


def key_hash(key) -> str:
    """sha256 of the canonical key text -- the store's primary key."""
    return hashlib.sha256(canonical_key(key).encode("utf-8")).hexdigest()


def _kind_of(key) -> str:
    """The cache namespace of a controller key: ``key[0][0]`` is the
    controller's ``_cache_kind`` ("plan" / "placement") by construction of
    :class:`~repro.core.replan.ReplanController`'s fingerprint."""
    try:
        kind = key[0][0]
        return kind if isinstance(kind, str) else "other"
    except (TypeError, IndexError, KeyError):
        return "other"


_SCHEMA = """
CREATE TABLE IF NOT EXISTS plans (
    key_hash       TEXT PRIMARY KEY,
    key_text       TEXT NOT NULL,
    kind           TEXT NOT NULL,
    schema_version INTEGER NOT NULL,
    payload        BLOB NOT NULL,
    makespan       REAL,
    engine         TEXT,
    created_s      REAL NOT NULL,
    provenance     TEXT
);
CREATE INDEX IF NOT EXISTS plans_kind ON plans (kind);
"""


class PlanStore:
    """sqlite-backed persistent map from canonical cache keys to optimised
    plan results, with provenance and schema-versioned invalidation.

    Open one per process (connections are cheap; WAL handles concurrent
    processes on the same file).  ``hits`` / ``misses`` / ``stale`` mirror
    :class:`~repro.core.replan.PlanCache` telemetry so warm-start claims are
    measurable; ``stale`` counts reads that found a row written under a
    different :data:`PLAN_SCHEMA_VERSION` (never served -- a restart after a
    schema bump re-optimises rather than risk deserialising an outdated
    shape)."""

    def __init__(self, path: str | Path, schema_version: int = PLAN_SCHEMA_VERSION):
        self.path = str(path)
        self.schema_version = int(schema_version)
        self._conn = sqlite3.connect(self.path, timeout=30.0)
        self._conn.execute("PRAGMA journal_mode=WAL")
        self._conn.executescript(_SCHEMA)
        self._conn.commit()
        self.hits = 0
        self.misses = 0
        self.stale = 0
        self.writes = 0
        self.invalid = 0

    # -- mapping ----------------------------------------------------------

    def get(self, key):
        """The stored result for ``key``, unpickled, or None.  Returns None
        (a miss) for absent keys, hash collisions (canonical texts compared),
        rows written under a different schema version, and rows whose payload
        fails to deserialize or whose plan fails static verification
        (:func:`repro.analysis.check_plan`) -- those rows are deleted and
        counted in ``invalid``, so a corrupted store degrades to cache misses
        instead of serving broken plans or raising into the controller."""
        canon = canonical_key(key)
        khash = key_hash(key)
        row = self._conn.execute(
            "SELECT key_text, schema_version, payload FROM plans WHERE key_hash = ?",
            (khash,),
        ).fetchone()
        if row is None or row[0] != canon:
            self.misses += 1
            return None
        if int(row[1]) != self.schema_version:
            self.stale += 1
            self.misses += 1
            return None
        try:
            result = pickle.loads(row[2])
        except Exception as exc:
            self._invalidate_row(khash, f"payload failed to deserialize: {exc!r}")
            return None
        detail = self._verify_payload(result)
        if detail is not None:
            self._invalidate_row(khash, detail)
            return None
        self.hits += 1
        return result

    def _verify_payload(self, result) -> str | None:
        """Static-verification detail for a deserialized payload, or None if
        it is servable.  Only objects that carry plans (``.plan`` / ``.plans``)
        are checked; anything else passes through untouched.  A *crash* in the
        checker itself is logged and the payload served -- an analyzer bug
        must not take down serving."""
        plans = getattr(result, "plans", None)
        if plans is None:  # PlacementResult nests them one level down
            plans = getattr(getattr(result, "placement", None), "plans", None)
        if plans is None:
            plan = getattr(result, "plan", None)
            plans = () if plan is None else (plan,)
        if not plans:
            return None
        try:
            from ..analysis import check_plan
        except Exception:  # pragma: no cover - analysis package missing
            return None
        for plan in plans:
            try:
                rep = check_plan(plan)
            except Exception:
                _log.warning(
                    "plan-store verifier crashed on a stored payload; "
                    "serving the row unverified", exc_info=True
                )
                return None
            if not rep.ok:
                return "stored plan failed verification: " + "; ".join(
                    str(f) for f in rep.findings[:3]
                )
        return None

    def _invalidate_row(self, khash: str, detail: str) -> None:
        """Drop one corrupt/invalid row and count the read as a miss."""
        _log.warning("plan store row invalidated (%s)", detail)
        self._conn.execute("DELETE FROM plans WHERE key_hash = ?", (khash,))
        self._conn.commit()
        self.invalid += 1
        self.misses += 1

    def put(self, key, result, provenance: dict | None = None, kind: str | None = None) -> None:
        """Persist one optimised result under ``key`` (last-writer-wins --
        safe because equal keys imply equal band representatives imply equal
        plans).  ``provenance`` is stored as JSON; ``kind`` defaults to the
        key's cache namespace (``key[0][0]``)."""
        prov = dict(provenance or {})
        self._conn.execute(
            "INSERT OR REPLACE INTO plans "
            "(key_hash, key_text, kind, schema_version, payload, makespan, "
            " engine, created_s, provenance) VALUES (?,?,?,?,?,?,?,?,?)",
            (
                key_hash(key),
                canonical_key(key),
                kind if kind is not None else _kind_of(key),
                self.schema_version,
                pickle.dumps(result, protocol=pickle.HIGHEST_PROTOCOL),
                float(getattr(result, "makespan", float("nan"))),
                prov.get("engine"),
                time.time(),
                json.dumps(prov, sort_keys=True),
            ),
        )
        self._conn.commit()
        self.writes += 1

    def provenance(self, key) -> dict | None:
        """The provenance record stored with ``key`` (schema-checked like
        :meth:`get`, but without deserialising the payload)."""
        row = self._conn.execute(
            "SELECT key_text, schema_version, provenance, makespan, created_s "
            "FROM plans WHERE key_hash = ?",
            (key_hash(key),),
        ).fetchone()
        if row is None or row[0] != canonical_key(key) or int(row[1]) != self.schema_version:
            return None
        out = json.loads(row[2]) if row[2] else {}
        out["makespan"] = row[3]
        out["created_s"] = row[4]
        return out

    # -- inventory / invalidation -----------------------------------------

    def __len__(self) -> int:
        return int(
            self._conn.execute(
                "SELECT COUNT(*) FROM plans WHERE schema_version = ?",
                (self.schema_version,),
            ).fetchone()[0]
        )

    def keys(self, kind: str | None = None) -> list[str]:
        """Canonical key texts of the live (current-schema) entries."""
        q = "SELECT key_text FROM plans WHERE schema_version = ?"
        args: tuple = (self.schema_version,)
        if kind is not None:
            q += " AND kind = ?"
            args += (kind,)
        return [r[0] for r in self._conn.execute(q + " ORDER BY key_text", args)]

    def stats(self) -> dict:
        return dict(
            entries=len(self),
            hits=self.hits,
            misses=self.misses,
            stale=self.stale,
            writes=self.writes,
            invalid=self.invalid,
            path=self.path,
        )

    def invalidate(self, kind: str | None = None) -> int:
        """Delete entries (all, or one cache namespace); returns rows dropped.
        The explicit hammer -- config changes do NOT need it (they key
        differently), schema changes do not either (rows become unreadable);
        this is for operator-driven resets (e.g. a recalibrated cluster)."""
        if kind is None:
            cur = self._conn.execute("DELETE FROM plans")
        else:
            cur = self._conn.execute("DELETE FROM plans WHERE kind = ?", (kind,))
        self._conn.commit()
        return cur.rowcount

    def prune_stale(self) -> int:
        """Garbage-collect rows written under a different schema version
        (they are already unreadable); returns rows dropped."""
        cur = self._conn.execute(
            "DELETE FROM plans WHERE schema_version != ?", (self.schema_version,)
        )
        self._conn.commit()
        return cur.rowcount

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        self._conn.close()

    def __enter__(self) -> "PlanStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
