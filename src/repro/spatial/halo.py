"""SPMD spatial parallelism: receptive-field-exact halo exchange (TPU form of HALP).

Under ``shard_map`` the image height axis is sharded across a mesh axis.  Each
device computes a conv layer on its own rows after exchanging the thin halo the
receptive field requires (``halo_lo = p`` rows from the neighbour above,
``halo_hi = k - p - s`` rows from below, the exact analogue of the paper's
eqs. 8-9 for an aligned N-way split).

Two execution modes:

* ``overlap=False`` -- exchange, then one VALID conv over the extended slab.
* ``overlap=True``  -- the HALP schedule: the ``ppermute`` for the halos is
  issued first, the *interior* rows (which need no remote data) are convolved
  immediately, and the boundary rows are finished when the halos land.  On TPU
  the XLA latency-hiding scheduler overlaps the collective with the interior
  conv -- communication is hidden behind compute, exactly the paper's
  "seamless collaboration" (see DESIGN.md for the host-ES -> SPMD mapping).

Two compute engines:

* ``engine="lax"``    -- XLA convs (three per layer under ``overlap=True``).
* ``engine="pallas"`` -- the HALP-fused kernel
  (:func:`repro.kernels.halo_conv.halo_conv2d`): ONE ``pallas_call`` whose
  interior row tiles gather straight from the shard while the boundary tiles
  are the only consumers of ``ppermute`` data, so the overlap happens at
  kernel granularity (eqs. 9-15; docs/equations.md#fused-kernel).  Geometries
  the kernel cannot express (``p > k - s``, grouped non-depthwise convs) fall
  back to the bit-compatible ``lax`` path.

Capacity-weighted shards (``heights=...``): a pod mixing device generations
deploys the *skewed* split the optimizer chose (``plan_even(ratios=...)``)
instead of the equal one.  Per-device blocks stay equal-shaped (shard_map
needs that): shard ``j`` holds ``heights[j]`` valid rows **top-aligned** in a
``max(heights)``-row block, and every row past the valid region MUST be zero
(:func:`to_padded_shards` builds the layout; the spatial ops preserve the
invariant by masking their outputs).  Halo donations then come from each
shard's *valid* edge rows -- the bottom donation is a dynamic slice at
``heights[j] - lo`` -- and edge shards receive zeros (the conv's zero
padding), so per-shard output offsets and edge padding track the skewed
split exactly.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels.halo_conv.halo_conv import halo_conv2d

__all__ = [
    "halo_sizes",
    "exchange_halos",
    "conv2d_spatial",
    "max_pool_spatial",
    "shard_heights",
    "plan_shard_heights",
    "spatial_alignment",
    "to_padded_shards",
    "merge_padded_shards",
]


def halo_sizes(k: int, s: int, p: int) -> tuple[int, int]:
    """Rows needed from the neighbour above / below for an aligned shard."""
    lo, hi = p, k - p - s
    if lo < 0 or lo >= k or hi >= k:
        raise ValueError(f"unsupported geometry k={k} s={s} p={p}")
    return lo, max(0, hi)


def _check_halo_fits(hs: int, lo: int, hi: int) -> None:
    """A neighbour can only donate rows it owns: a halo larger than the shard
    height would need rows from *two* shards away.  ``x[:, -lo:]`` silently
    truncates to the ``hs`` available rows in that case -- the receiving
    shard would convolve wrong (shifted) rows -- so fail loudly instead."""
    if lo > hs or hi > hs:
        raise ValueError(
            f"halo exceeds shard height: need lo={lo}/hi={hi} rows from the "
            f"neighbouring shards but each shard holds only {hs} rows; use "
            f"fewer/taller shards (or run this layer unsharded)"
        )


# ---------------------------------------------------------------------------
# capacity-weighted shard layout
# ---------------------------------------------------------------------------


def _norm_ratios(n: int, ratios) -> list[float]:
    if ratios is None:
        return [1.0 / n] * n
    ratios = list(ratios)
    if len(ratios) != n:
        raise ValueError(f"need one ratio per shard, got {len(ratios)} for n={n}")
    total = sum(ratios)
    if total <= 0 or any(r < 0 for r in ratios):
        raise ValueError(f"ratios must be non-negative with a positive sum, got {ratios}")
    return [r / total for r in ratios]


def shard_heights(
    total: int, n: int, ratios: Sequence[float] | None = None, align: int = 1
) -> tuple[int, ...]:
    """Capacity-weighted shard heights: ``n`` positive row counts summing to
    ``total``, each a multiple of ``align`` (the product of the strides the
    deployment steps through, so every later layer keeps per-shard stride
    alignment), shares within one ``align`` unit of the ratio split."""
    from ..core.partition import _min_one_unit, _split_counts

    if total % align:
        raise ValueError(f"total rows {total} not divisible by alignment {align}")
    units = total // align
    if units < n:
        raise ValueError(
            f"cannot give {n} shards at least {align} rows each from {total}"
        )
    counts = _min_one_unit(_split_counts(units, _norm_ratios(n, ratios)), units)
    return tuple(c * align for c in counts)


def spatial_alignment(net) -> int:
    """Product of all layer strides of a :class:`~repro.core.nets.ConvNetGeom`
    -- the ``align`` that keeps weighted shard heights stride-divisible at
    every depth of the network."""
    align = 1
    for g in net.layers:
        align *= g.s
    return align


def plan_shard_heights(plan, align: int = 1) -> tuple[int, ...]:
    """Input-shard heights deploying an N-way ``plan_even(ratios=...)`` plan
    through ``shard_map``: the plan's first-layer row shares (the optimizer's
    capacity weighting), re-quantised to ``align``.  This is how the spatial
    engine *consumes* the planner's weighted split."""
    rows = [plan.parts[0].out[es].rows for es in plan.es_names]
    return shard_heights(plan.net.in_rows, len(rows), ratios=rows, align=align)


def to_padded_shards(x: jax.Array, heights: Sequence[int]) -> jax.Array:
    """Re-lay a global [B, H, ...] tensor (H == sum(heights)) into the padded
    weighted-shard form: [B, n * max(heights), ...] where shard ``j``'s block
    holds its ``heights[j]`` rows top-aligned and zeros below (the invariant
    every weighted spatial op preserves)."""
    heights = tuple(int(h) for h in heights)
    if x.shape[1] != sum(heights):
        raise ValueError(f"rows {x.shape[1]} != sum of shard heights {sum(heights)}")
    hmax = max(heights)
    pads = [(0, 0)] * (x.ndim - 2)
    parts, off = [], 0
    for h in heights:
        parts.append(jnp.pad(x[:, off : off + h], ((0, 0), (0, hmax - h), *pads)))
        off += h
    return jnp.concatenate(parts, axis=1)


def merge_padded_shards(y: jax.Array, heights: Sequence[int]) -> jax.Array:
    """Inverse of :func:`to_padded_shards`: drop each block's padding rows and
    re-concatenate the valid rows (``heights`` are the *output* heights of the
    layer stack, e.g. the input heights divided by the total stride)."""
    heights = tuple(int(h) for h in heights)
    hmax = max(heights)
    if y.shape[1] != hmax * len(heights):
        raise ValueError(
            f"rows {y.shape[1]} != {len(heights)} blocks of {hmax} padded rows"
        )
    return jnp.concatenate(
        [y[:, j * hmax : j * hmax + h] for j, h in enumerate(heights)], axis=1
    )


def _heights_setup(heights, axis_name: str, lo: int, hi: int, s: int):
    """Validate a weighted layout against the mesh + geometry; returns the
    normalised heights, this shard's index, and its (traced) valid height."""
    heights = tuple(int(h) for h in heights)
    if any(h <= 0 for h in heights):
        raise ValueError(f"shard heights must be positive, got {heights}")
    if s > 1 and any(h % s for h in heights):
        raise ValueError(f"shard heights {heights} not all divisible by stride {s}")
    _check_halo_fits(min(heights), lo, hi)
    n = lax.psum(1, axis_name)
    if len(heights) != n:
        raise ValueError(f"got {len(heights)} shard heights for a {n}-way mesh axis")
    idx = lax.axis_index(axis_name)
    hs_j = jnp.asarray(heights, jnp.int32)[idx]
    return heights, idx, hs_j


def _issue_halos_weighted(x, lo, hi, heights, hs_j, axis_name):
    """ppermute the *valid-edge* rows of each weighted shard: the bottom
    donation starts at the dynamic row ``hs_j - lo``.  Non-wrapping perms:
    edge shards receive zeros (the conv's zero padding)."""
    n = len(heights)
    top = bot = None
    if lo:
        donate = lax.dynamic_slice_in_dim(x, hs_j - lo, lo, axis=1)
        top = lax.ppermute(donate, axis_name, [(i, i + 1) for i in range(n - 1)])
    if hi:
        bot = lax.ppermute(x[:, :hi], axis_name, [(i, i - 1) for i in range(1, n)])
    return top, bot


def _weighted_ext(x, top, bot, lo, hi, hs_j):
    """[top_halo; x; bottom_halo] in the weighted layout: the bottom halo is
    spliced at the dynamic row ``lo + hs_j`` (right below the valid region);
    rows between the halo and the block end stay zero."""
    ext = x
    if lo:
        ext = jnp.concatenate([top, ext], axis=1)
    if hi:
        ext = jnp.concatenate([ext, jnp.zeros_like(bot)], axis=1)
        ext = lax.dynamic_update_slice_in_dim(ext, bot, lo + hs_j, axis=1)
    return ext


def _mask_rows(y, o_j):
    """Zero rows past the shard's valid output height (the layout invariant)."""
    keep = (jnp.arange(y.shape[1]) < o_j)[None, :, None, None]
    return jnp.where(keep, y, jnp.zeros((), y.dtype))


# ---------------------------------------------------------------------------
# halo exchange
# ---------------------------------------------------------------------------


def exchange_halos(
    x: jax.Array, lo: int, hi: int, axis_name: str,
    heights: Sequence[int] | None = None,
) -> jax.Array:
    """Return x extended with ``lo`` rows from above and ``hi`` rows from below.

    Edge shards receive zeros (the conv's zero padding).  x: [B, Hs, W, C].
    Raises ``ValueError`` when the shard is too thin to donate the requested
    halo (``lo > Hs`` or ``hi > Hs``) instead of silently truncating.

    With ``heights`` (capacity-weighted layout) the donations come from each
    shard's valid edge rows and the bottom halo lands at the dynamic row
    ``lo + heights[j]`` of the returned buffer (zeros in between)."""
    if heights is not None:
        heights, _idx, hs_j = _heights_setup(heights, axis_name, lo, hi, 1)
        if x.shape[1] != max(heights):
            raise ValueError(
                f"block height {x.shape[1]} != max shard height {max(heights)}"
            )
        top, bot = _issue_halos_weighted(x, lo, hi, heights, hs_j, axis_name)
        return _weighted_ext(x, top, bot, lo, hi, hs_j)
    _check_halo_fits(x.shape[1], lo, hi)
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    parts = [x]
    if lo:
        down = [(i, (i + 1) % n) for i in range(n)]  # my bottom rows -> next shard
        top = lax.ppermute(x[:, -lo:], axis_name, down)
        top = jnp.where(idx == 0, jnp.zeros_like(top), top)
        parts.insert(0, top)
    if hi:
        up = [(i, (i - 1) % n) for i in range(n)]  # my top rows -> previous shard
        bot = lax.ppermute(x[:, :hi], axis_name, up)
        bot = jnp.where(idx == n - 1, jnp.zeros_like(bot), bot)
        parts.append(bot)
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else x


def _conv_valid(x, p, s, groups=1):
    y = lax.conv_general_dilated(
        x, p["w"], (s, s), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    if "b" in p:
        y = y + p["b"]
    return y


def _pallas_supported(
    k: int, s: int, p: int, groups: int, c: int, wts, w: int | None = None
) -> bool:
    """The single source of truth for fused-path eligibility: geometries the
    fused kernel expresses are exact halos (p <= k - s), groups either trivial
    or depthwise, and -- given the shard width ``w`` -- a positive output
    width (``w + 2p >= k``; narrower maps make ``(w + 2p - k) // s + 1 <= 0``
    and the kernel's reshape blows up mid-trace).  Agreement with what
    ``halo_conv2d`` actually traces is pinned by
    ``repro.analysis.kernel_check``."""
    if k - p - s < 0:
        return False
    if w is not None and w + 2 * p < k:
        return False
    return groups == 1 or (groups == c == wts.shape[-1] and wts.shape[2] == 1)


def conv2d_spatial(
    x: jax.Array,
    params,
    k: int,
    s: int = 1,
    p: int = 0,
    axis_name: str = "sp",
    overlap: bool = True,
    groups: int = 1,
    engine: str = "lax",
    interpret: bool = False,
    heights: Sequence[int] | None = None,
) -> jax.Array:
    """Spatially-sharded conv (height axis sharded over ``axis_name``).

    Requires the shard height to be a multiple of ``s``.  Width uses ordinary
    SAME semantics via explicit padding.

    ``engine="pallas"`` fuses boundary-row packing + conv into one
    ``pallas_call`` (interior tiles never touch the halos -- the HALP overlap
    at kernel granularity); unsupported geometries fall back to ``lax``.
    NOTE: ``pallas_call`` has no shard_map replication rule, so the enclosing
    ``shard_map`` must pass ``check_rep=False`` when this engine is selected.
    ``interpret=True`` runs the kernel in interpreter mode (CI / CPU).
    ``heights`` switches to the capacity-weighted padded layout (see module
    docstring)."""
    if engine not in ("lax", "pallas"):
        raise ValueError(f"unknown engine {engine!r}; use 'lax' or 'pallas'")
    if heights is not None:
        return _conv2d_spatial_weighted(
            x, params, k, s, p, axis_name, overlap, groups, engine, interpret, heights
        )
    b, hs, w, c = x.shape
    if hs % s:
        raise ValueError(f"shard rows {hs} not divisible by stride {s}")
    lo, hi = halo_sizes(k, s, p)

    if engine == "pallas" and _pallas_supported(k, s, p, groups, c, params["w"], w):
        # --- fused path: ppermute halos, then ONE kernel whose boundary tiles
        # are the only consumers of the remote rows (eqs. 9-15 fused).
        _check_halo_fits(hs, lo, hi)
        n = lax.psum(1, axis_name)
        top = (
            lax.ppermute(x[:, -lo:], axis_name, [(i, i + 1) for i in range(n - 1)])
            if lo else None
        )
        bot = (
            lax.ppermute(x[:, :hi], axis_name, [(i, i - 1) for i in range(1, n)])
            if hi else None
        )
        return halo_conv2d(
            x, top, bot, params["w"], params.get("b"),
            stride=s, padding=p, groups=groups, interpret=interpret,
        )

    if p:  # width padding (the height padding is the edge shards' zero halos)
        x = jnp.pad(x, ((0, 0), (0, 0), (p, p), (0, 0)))

    if not overlap or (lo == 0 and hi == 0):
        ext = exchange_halos(x, lo, hi, axis_name)
        y = _conv_valid(ext, params, s, groups)
        return y[:, : hs // s]

    # --- HALP schedule: issue halos first, compute interior, then boundaries.
    # (x is already width-padded, so the halos carry the width padding too.)
    _check_halo_fits(hs, lo, hi)
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    top_halo = bot_halo = None
    if lo:
        top_halo = lax.ppermute(
            x[:, -lo:], axis_name, [(i, (i + 1) % n) for i in range(n)]
        )
        top_halo = jnp.where(idx == 0, jnp.zeros_like(top_halo), top_halo)
    if hi:
        bot_halo = lax.ppermute(
            x[:, :hi], axis_name, [(i, (i - 1) % n) for i in range(n)]
        )
        bot_halo = jnp.where(idx == n - 1, jnp.zeros_like(bot_halo), bot_halo)

    # Within-shard output row t (0-indexed) reads extended rows
    # [t*s - lo, t*s - lo + k); interior rows touch no halo.
    nrows = hs // s
    t_lo = -(-lo // s)  # ceil(lo / s)
    t_hi = (hs + lo - k) // s
    if t_hi < t_lo:  # shard too thin for an interior: plain exchanged conv
        parts = [q for q in (top_halo, x, bot_halo) if q is not None]
        ext = jnp.concatenate(parts, axis=1) if len(parts) > 1 else x
        return _conv_valid(ext, params, s, groups)[:, :nrows]

    pieces = []
    if t_lo > 0:  # top boundary rows 0..t_lo-1 finish once the top halo lands
        slab = jnp.concatenate([top_halo, x[:, : (t_lo - 1) * s - lo + k]], axis=1)
        pieces.append(_conv_valid(slab, params, s, groups)[:, :t_lo])
    pieces.append(
        _conv_valid(x[:, t_lo * s - lo : t_hi * s - lo + k], params, s, groups)
    )
    if t_hi + 1 < nrows:  # bottom boundary rows
        slab = x[:, (t_hi + 1) * s - lo :]
        if bot_halo is not None:
            slab = jnp.concatenate([slab, bot_halo], axis=1)
        pieces.append(_conv_valid(slab, params, s, groups)[:, : nrows - t_hi - 1])
    return jnp.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]


def _conv2d_spatial_weighted(
    x, params, k, s, p, axis_name, overlap, groups, engine, interpret, heights
):
    """Capacity-weighted conv over padded blocks (see module docstring)."""
    b, hmax, w, c = x.shape
    lo, hi = halo_sizes(k, s, p)
    heights, _idx, hs_j = _heights_setup(heights, axis_name, lo, hi, s)
    if hmax != max(heights):
        raise ValueError(f"block height {hmax} != max shard height {max(heights)}")
    o_j = hs_j // s
    o_max = hmax // s
    wts = params["w"]

    # halos are issued from the *unpadded* shard, before anything else, so
    # both engines can overlap them with interior compute
    top, bot = _issue_halos_weighted(x, lo, hi, heights, hs_j, axis_name)

    if engine == "pallas" and _pallas_supported(k, s, p, groups, c, wts, w):
        pad_rows = hi + (-(hmax + hi)) % s
        x_ext = (
            jnp.concatenate([x, jnp.zeros((b, pad_rows, w, c), x.dtype)], axis=1)
            if pad_rows else x
        )
        zero_bot = jnp.zeros((b, hi, w, c), x.dtype) if hi else None
        n_fix = -(-hi // s)  # valid output rows whose window crosses the bottom edge
        if hi and min(heights) >= n_fix * s + lo:
            # Overlapped bottom halo: the kernel never consumes the bottom
            # ppermute (its bottom operand is zeros and the rows below the
            # valid region are the layout's zeros), so the scheduler can hide
            # that collective behind the *whole* kernel, not just its last
            # tiles.  The last n_fix valid rows -- the only ones whose window
            # crosses the shard's bottom edge -- are then recomputed by a thin
            # fix-up conv, the sole consumer of the bottom halo.  The top halo
            # stays a kernel operand (only tile 0 reads it).
            y = halo_conv2d(
                x_ext, top, zero_bot, wts, params.get("b"),
                stride=s, padding=p, groups=groups, interpret=interpret,
            )
            slab = lax.dynamic_slice_in_dim(
                x, hs_j - n_fix * s - lo, n_fix * s + lo, axis=1
            )
            slab = jnp.concatenate([slab, bot], axis=1)
            if p:
                slab = jnp.pad(slab, ((0, 0), (0, 0), (p, p), (0, 0)))
            y_fix = _conv_valid(slab, params, s, groups)
            y = lax.dynamic_update_slice_in_dim(
                y[:, :o_max], y_fix, o_j - n_fix, axis=1
            )
            return _mask_rows(y, o_j)
        # Shards too thin to source the fix-up slab locally (or hi == 0):
        # embed the bottom halo at its dynamic row pre-kernel (the splice
        # serialises the bottom collective before the kernel, but only rows
        # shorter than n_fix*s + lo ever take this path).
        if hi:
            x_ext = lax.dynamic_update_slice_in_dim(x_ext, bot, hs_j, axis=1)
        y = halo_conv2d(
            x_ext, top, zero_bot, wts, params.get("b"),
            stride=s, padding=p, groups=groups, interpret=interpret,
        )
        return _mask_rows(y[:, :o_max], o_j)

    def wpad(a):
        return jnp.pad(a, ((0, 0), (0, 0), (p, p), (0, 0))) if p else a

    xw = wpad(x)
    topw = wpad(top) if top is not None else None
    botw = wpad(bot) if bot is not None else None
    ext = _weighted_ext(xw, topw, botw, lo, hi, hs_j)

    t_lo = -(-lo // s)  # ceil(lo / s)
    hs_min = min(heights)
    t_hi = (hs_min + lo - k) // s  # interior rows valid on EVERY shard
    if not overlap or (lo == 0 and hi == 0) or t_hi < t_lo:
        return _mask_rows(_conv_valid(ext, params, s, groups)[:, :o_max], o_j)

    # HALP schedule, weighted: the interior slab is bounded by the *thinnest*
    # shard (static shapes); rows past it come off the spliced ext buffer.
    pieces = []
    if t_lo > 0:
        slab = jnp.concatenate([topw, xw[:, : (t_lo - 1) * s - lo + k]], axis=1)
        pieces.append(_conv_valid(slab, params, s, groups)[:, :t_lo])
    pieces.append(_conv_valid(xw[:, t_lo * s - lo : t_hi * s - lo + k], params, s, groups))
    if t_hi + 1 < o_max:
        slab = ext[:, (t_hi + 1) * s :]
        pieces.append(_conv_valid(slab, params, s, groups)[:, : o_max - t_hi - 1])
    y = jnp.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]
    return _mask_rows(y, o_j)


def max_pool_spatial(
    x: jax.Array, k: int = 2, s: int = 2, axis_name: str = "sp",
    heights: Sequence[int] | None = None,
) -> jax.Array:
    """Spatially-sharded max pool (aligned shards need no halo when k == s).

    With ``heights`` the pool runs on the capacity-weighted padded layout:
    output heights are the input heights divided by the stride."""
    b, hs, w, c = x.shape
    if heights is not None:
        lo, hi = halo_sizes(k, s, 0)
        heights, _idx, hs_j = _heights_setup(heights, axis_name, lo, hi, s)
        if hs != max(heights):
            raise ValueError(f"block height {hs} != max shard height {max(heights)}")
        top, bot = _issue_halos_weighted(x, lo, hi, heights, hs_j, axis_name)
        ext = _weighted_ext(x, top, bot, lo, hi, hs_j)
        y = lax.reduce_window(
            ext, -jnp.inf, lax.max, (1, k, k, 1), (1, s, s, 1), "VALID"
        )
        return _mask_rows(y[:, : hs // s], hs_j // s)
    if hs % s:
        raise ValueError("shard not aligned to pool stride")
    lo, hi = halo_sizes(k, s, 0)
    x = exchange_halos(x, lo, hi, axis_name)
    y = lax.reduce_window(x, -jnp.inf, lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")
    return y[:, : hs // s]
