"""Static verification CLI: run every ``repro.analysis`` analyzer, exit 1 on findings.

The CI entry point for the static-analysis job (and a local pre-commit
sanity check): it always runs the config-keying lint, and optionally

* ``--store PATH`` -- verify every current-schema row of a persistent
  :class:`~repro.core.planstore.PlanStore` artifact: payloads must
  deserialize and every plan they carry must pass
  :func:`~repro.analysis.check_plan` (the same verifier ``PlanStore.get``
  applies online; running it offline catches a corrupted artifact before a
  fleet warm-starts from it);
* ``--benchmarks`` -- rebuild the benchmark/demo configurations (the demo
  cluster of ``tools/precompute_plans.py``, full VGG-16, ViT-L/16) and push
  each through all four analyzers: plan invariants, DAG
  acyclicity/transfer/orphan checks, template-vs-scalar duration audits, and
  ``jax.eval_shape`` kernel geometry evaluation.

No findings -> exit 0 and a one-line summary per section.  Any finding ->
printed as ``[check] where: detail`` and exit 1.

Usage::

    python tools/check.py                       # keying lint only
    python tools/check.py --store plans_warm.sqlite --benchmarks
"""
from __future__ import annotations

import argparse
import pickle
import sqlite3
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from repro.analysis import (  # noqa: E402
    Report,
    check_dag,
    check_keying,
    check_plan,
    check_plan_kernels,
    check_template,
)
from repro.core.nets import vgg16_geom, vit_l16_geom  # noqa: E402
from repro.core.partition import (  # noqa: E402
    plan_halp_topology,
    plan_layout,
    plan_scheme,
    scheme_layout,
)
from repro.core.planstore import PLAN_SCHEMA_VERSION  # noqa: E402


def _plans_of(payload) -> list:
    """Every plan object a stored payload carries (OptimizeResult ``.plan``,
    TaskPlacement ``.plans``, PlacementResult ``.placement.plans``)."""
    plans = getattr(payload, "plans", None)
    if plans is None:
        plans = getattr(getattr(payload, "placement", None), "plans", None)
    if plans is not None:
        return list(plans)
    plan = getattr(payload, "plan", None)
    return [] if plan is None else [plan]


def check_store(path: str) -> Report:
    """Verify every current-schema row of a PlanStore sqlite file."""
    rep = Report()
    if not Path(path).exists():
        rep.add("store.payload", path, "store file does not exist")
        return rep
    conn = sqlite3.connect(path)
    try:
        rows = conn.execute(
            "SELECT key_text, payload FROM plans WHERE schema_version = ?",
            (PLAN_SCHEMA_VERSION,),
        ).fetchall()
    finally:
        conn.close()
    for key_text, payload in rows:
        where = key_text if len(key_text) <= 64 else key_text[:61] + "..."
        rep.tick()
        try:
            obj = pickle.loads(payload)
        except Exception as exc:
            rep.add("store.payload", where, f"payload failed to deserialize: {exc!r}")
            continue
        for plan in _plans_of(obj):
            sub = check_plan(plan)
            rep.tick(sub.checks)
            for f in sub.findings:
                rep.add(f.check, f"{where} :: {f.where}", f.detail)
    return rep


def check_benchmarks() -> Report:
    """Rebuild the benchmark/demo configurations and verify plans, DAGs,
    templates, and kernel geometries statically."""
    from precompute_plans import demo_net, demo_topology
    from repro.core.events import (
        DagTemplate,
        _layout_quantities,
        _scheme_quantities,
        _scheme_template,
        build_halp_dag,
        build_scheme_dag,
    )
    from repro.core.simulator import Sim

    rep = Report()
    demo, topo = demo_net(), demo_topology()
    secs = topo.secondaries
    cases = [
        ("demo/halo", demo, topo),
        ("vgg16/halo", vgg16_geom(), topo),
    ]

    # --- plan invariants + fused-kernel geometry (halo plans)
    for label, net, top in cases:
        plan = plan_halp_topology(net, top)
        for sub in (check_plan(plan), check_plan_kernels(plan)):
            rep.tick(sub.checks)
            for f in sub.findings:
                rep.add(f.check, f"{label} :: {f.where}", f.detail)

    # --- mixed-scheme plans (conv net + the attention net)
    for label, net in (("vgg16/scheme", vgg16_geom()), ("vit_l16/scheme", vit_l16_geom())):
        plan = plan_scheme(net, topo)
        for sub in (check_plan(plan), check_plan_kernels(plan)):
            rep.tick(sub.checks)
            for f in sub.findings:
                rep.add(f.check, f"{label} :: {f.where}", f.detail)

    # --- built DAGs: halo (per-task clones) and mixed-scheme
    sim = Sim()
    build_halp_dag(sim, [plan_halp_topology(demo, topo)], topo)
    sub = check_dag(sim)
    rep.tick(sub.checks)
    for f in sub.findings:
        rep.add(f.check, f"demo/halo-dag :: {f.where}", f.detail)

    slay = scheme_layout(vit_l16_geom(), secs, host=topo.host)
    sim = Sim()
    build_scheme_dag(sim, slay, 2, topo)
    sub = check_dag(sim)
    rep.tick(sub.checks)
    for f in sub.findings:
        rep.add(f.check, f"vit_l16/scheme-dag :: {f.where}", f.detail)

    # --- template factorisation audits (build-time assert -> finding)
    lay = plan_layout(demo, secs, host=topo.host)
    try:
        tmpl = DagTemplate.from_layouts([lay], topo, physical=False)
    except AssertionError as exc:
        rep.add("dag.template", "demo/halo-template", f"build-time self-check failed: {exc}")
    else:
        sub = check_template(tmpl, _layout_quantities([lay]), topo)
        rep.tick(sub.checks)
        for f in sub.findings:
            rep.add(f.check, f"demo/halo-template :: {f.where}", f.detail)
    try:
        stmpl = _scheme_template(slay, 1, topo)
    except AssertionError as exc:
        rep.add("dag.template", "vit_l16/scheme-template", f"build-time self-check failed: {exc}")
    else:
        sub = check_template(stmpl, _scheme_quantities(slay, 1), topo)
        rep.tick(sub.checks)
        for f in sub.findings:
            rep.add(f.check, f"vit_l16/scheme-template :: {f.where}", f.detail)
    return rep


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--store", help="PlanStore sqlite file to verify row-by-row")
    ap.add_argument(
        "--benchmarks",
        action="store_true",
        help="verify the benchmark/demo plan, DAG, template, kernel configs",
    )
    args = ap.parse_args(argv)

    sections: list[tuple[str, Report]] = []
    t0 = time.perf_counter()
    sections.append(("keying", check_keying()))
    if args.store:
        sections.append((f"store {args.store}", check_store(args.store)))
    if args.benchmarks:
        sections.append(("benchmarks", check_benchmarks()))

    failures = 0
    for label, rep in sections:
        status = "ok" if rep.ok else f"{len(rep.findings)} finding(s)"
        print(f"{label}: {status} ({rep.checks} checks)")
        for f in rep.findings:
            failures += 1
            print(f"  {f}")
    print(f"total: {failures} finding(s) in {time.perf_counter() - t0:.2f}s")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
