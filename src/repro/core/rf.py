"""Receptive-field arithmetic for segment-based partitioning (paper §II, eqs. 1-4, 8-9).

The paper's central correctness tool: given a range of *output* rows of a
convolutional/pooling layer, compute the exact range of *input* rows required to
produce them.  Partitioning on these ranges is lossless -- the distributed output
is bit-identical to single-device inference.

Two range calculators are provided:

* ``input_range_exact``  -- exact sliding-window interval algebra (used by the
  partitioner and the TPU spatial engine).  For output rows ``[o_lo, o_hi]``
  (1-indexed, inclusive) of a layer with kernel ``k``, stride ``s``, padding ``p``:
  ``in_lo = (o_lo-1)*s + 1 - p`` and ``in_hi = (o_hi-1)*s + k - p`` clipped to the
  valid input rows (out-of-range rows are the zero padding).

* ``input_range_paper``  -- the paper's eqs. (8)-(9) verbatim, driven by the
  cumulative receptive-field chain of eqs. (2)-(4).  The paper's end formula uses
  ``(OE+1)*j`` which is slightly conservative (it may cover a few extra rows for
  strided layers); ``tests/test_rf.py`` asserts exact ⊆ paper, so the paper
  formulas never under-provision rows (accuracy is preserved either way).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from fractions import Fraction
from typing import Sequence

__all__ = [
    "LayerGeom",
    "RFState",
    "out_size",
    "rf_chain",
    "input_range_exact",
    "input_range_paper",
    "propagate_range",
    "attn",
    "conv",
    "pool",
]


@dataclass(frozen=True)
class LayerGeom:
    """Geometry of one layer: sliding-window (conv/pool/depthwise) or attention.

    Row/column symmetric (the paper partitions along rows of square tensors).
    ``c_in``/``c_out`` are carried for FLOP and byte accounting.  An ``attn``
    layer is multi-head self-attention over the H*W token grid: shape-wise the
    identity window (k=1, s=1, p=0), but *every* output row depends on *every*
    input row, so the receptive-field partitioner must never row-split it --
    ``heads`` carries the head count for head/sequence-split accounting instead.
    """

    name: str
    kind: str  # "conv" | "pool" | "depthwise" | "attn"
    k: int
    s: int = 1
    p: int = 0
    c_in: int = 1
    c_out: int = 1
    heads: int = 1

    def out_rows(self, in_rows: int) -> int:
        return out_size(in_rows, self.k, self.s, self.p)

    def flops_per_out_row(self, out_width: int) -> float:
        """FLOPs to produce one output row (2 FLOPs per MAC), paper convention."""
        if self.kind == "conv":
            return 2.0 * self.k * self.k * self.c_in * self.c_out * out_width
        if self.kind == "depthwise":
            return 2.0 * self.k * self.k * self.c_out * out_width
        if self.kind == "attn":
            # Per token of the row: QKV projections (3 * 2*d^2) plus scores and
            # weighted values against all S = out_width^2 tokens (2 * 2*S*d).
            d, tokens = self.c_in, out_width * out_width
            return out_width * (6.0 * d * d + 4.0 * tokens * d)
        # pooling: one compare/add per window element
        return float(self.k * self.k * self.c_out * out_width)


def conv(name: str, c_in: int, c_out: int, k: int = 3, s: int = 1, p: int = 1) -> LayerGeom:
    return LayerGeom(name=name, kind="conv", k=k, s=s, p=p, c_in=c_in, c_out=c_out)


def attn(name: str, d: int, heads: int) -> LayerGeom:
    """Multi-head self-attention over the spatial token grid (d = model width)."""
    if d % heads:
        raise ValueError(f"model width {d} not divisible by {heads} heads")
    return LayerGeom(name=name, kind="attn", k=1, s=1, p=0, c_in=d, c_out=d, heads=heads)


def pool(name: str, c: int, k: int = 2, s: int = 2, p: int = 0) -> LayerGeom:
    return LayerGeom(name=name, kind="pool", k=k, s=s, p=p, c_in=c, c_out=c)


def out_size(i: int, k: int, s: int, p: int) -> int:
    """Paper eq. (1): O = floor((I + 2p - k)/s) + 1."""
    o = (i + 2 * p - k) // s + 1
    if o < 1:
        raise ValueError(f"non-positive output size for I={i}, k={k}, s={s}, p={p}")
    return o


@dataclass(frozen=True)
class RFState:
    """Cumulative receptive-field state after a layer (paper eqs. 1-4).

    ``sigma`` is the (possibly fractional) input-row index of the centre of the
    receptive field of the *first* output row; kept exact as a Fraction.
    """

    out: int  # O_{g_i}: output rows
    jump: int  # j_{g_i}: cumulative stride
    rf: int  # r_{g_i}: receptive-field extent in input rows
    sigma: Fraction  # σ_{g_i}: centre row of first output's receptive field

    @staticmethod
    def for_input(in_rows: int) -> "RFState":
        # identity "layer 0": each input row is its own receptive field.
        return RFState(out=in_rows, jump=1, rf=1, sigma=Fraction(1))


def _advance(state: RFState, g: LayerGeom) -> RFState:
    """Apply eqs. (1)-(4) for one layer."""
    o = out_size(state.out, g.k, g.s, g.p)
    j = state.jump * g.s  # eq. (2)
    r = state.rf + (g.k - 1) * state.jump  # eq. (3)
    sigma = state.sigma + (Fraction(g.k - 1, 2) - g.p) * state.jump  # eq. (4)
    return RFState(out=o, jump=j, rf=r, sigma=sigma)


def rf_chain(in_rows: int, layers: Sequence[LayerGeom]) -> list[RFState]:
    """Cumulative receptive-field states for every layer (index i == after layer i)."""
    states = []
    st = RFState.for_input(in_rows)
    for g in layers:
        st = _advance(st, g)
        states.append(st)
    return states


def input_range_exact(
    o_lo: int, o_hi: int, k: int, s: int, p: int, in_rows: int
) -> tuple[int, int]:
    """Exact input rows (1-indexed inclusive, clipped) needed for output rows [o_lo, o_hi]."""
    if not 1 <= o_lo <= o_hi:
        raise ValueError(f"bad output range [{o_lo}, {o_hi}]")
    lo = (o_lo - 1) * s + 1 - p
    hi = (o_hi - 1) * s + k - p
    return max(lo, 1), min(hi, in_rows)


def input_range_paper(
    o_lo: int, o_hi: int, state: RFState, in_rows: int
) -> tuple[int, int]:
    """Paper eqs. (8)-(9) verbatim, with the cumulative state of the layer.

    Maps output rows of layer g_i to rows of the *original input* of the chain
    whose state is ``state``.  For a single layer pass a chain of length 1.
    """
    half = (state.rf - 1) // 2  # floor((r-1)/2)
    is_ = state.sigma + (o_lo - 1) * state.jump - half  # eq. (8)
    ie = state.sigma + (o_hi + 1) * state.jump - half  # eq. (9)
    return max(math.floor(is_), 1), min(math.ceil(ie), in_rows)


def propagate_range(
    layers: Sequence[LayerGeom],
    in_rows: int,
    layer_idx: int,
    o_range: tuple[int, int],
) -> list[tuple[int, int]]:
    """Back-propagate an output-row range of layer ``layer_idx`` through the chain.

    Returns one (lo, hi) per level: index 0 is the range on the original input,
    index i (1-based) is the range on the output of layer i-1 ... ending with
    ``o_range`` itself at index ``layer_idx + 1``.  Exact algebra (lossless).
    """
    sizes = [in_rows]
    for g in layers:
        sizes.append(out_size(sizes[-1], g.k, g.s, g.p))
    lo, hi = o_range
    if not 1 <= lo <= hi <= sizes[layer_idx + 1]:
        raise ValueError(f"range {o_range} invalid for layer {layer_idx} (O={sizes[layer_idx + 1]})")
    ranges = [o_range]
    for i in range(layer_idx, -1, -1):
        g = layers[i]
        lo, hi = input_range_exact(lo, hi, g.k, g.s, g.p, sizes[i])
        ranges.append((lo, hi))
    ranges.reverse()
    return ranges
