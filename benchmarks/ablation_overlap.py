"""Beyond-paper ablation: HALP overlap-zone width vs. inference time.

The paper fixes the host zone at 4 rows; this sweep shows the trade-off the
scheduler navigates: wider zones shift compute to the host (serialising in the
multi-task regime) while narrower zones leave less boundary slack.
"""
import sys

sys.path.insert(0, "src")

from repro.core import GTX_1080TI, AGX_XAVIER, Link, simulate_halp, vgg16_geom

NET = vgg16_geom()


def run() -> dict:
    out = {}
    print("\n== ablation: overlap-zone width (rows) vs HALP time, 40 Gbps ==")
    print(f"{'rows':>5s} {'1 task 1080TI (ms)':>20s} {'4 tasks 1080TI (ms)':>20s} {'4 tasks Xavier (ms)':>20s}")
    for w in (2, 4, 6, 8, 12, 16, 24):
        t1 = simulate_halp(NET, GTX_1080TI, Link(40e9), overlap_rows=w)["total"]
        t4 = simulate_halp(NET, GTX_1080TI, Link(40e9), n_tasks=4, overlap_rows=w)["total"]
        t4x = simulate_halp(NET, AGX_XAVIER, Link(40e9), n_tasks=4, overlap_rows=w)["total"]
        print(f"{w:5d} {t1*1e3:20.3f} {t4*1e3:20.3f} {t4x*1e3:20.3f}")
        print(f"ablation_overlap_{w},{t4*1e6:.1f},{4/t4:.0f}")
        out[w] = (t1, t4)
    best = min(out, key=lambda w: out[w][1])
    print(f"best 4-task width: {best} rows (paper uses 4)")
    return out


if __name__ == "__main__":
    run()
