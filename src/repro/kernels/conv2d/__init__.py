from .conv2d import conv2d_tiles
from .ops import conv2d_pallas
from .ref import conv2d_ref
