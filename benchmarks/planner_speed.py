"""Planner-latency benchmark: batched DAG-template engine vs the scalar path.

HALP's value is *online*: the replan/placement controllers re-optimise on
every adopted rate-bucket switch, so the planner's own wall-clock latency is
a serving-path quantity, not a tooling nicety.  This benchmark tracks it
across the three planner entry points, comparing the batched engine (plan
layouts + cached DAG templates + ``Sim.run_batch``; see
``repro.core.events``) against the pre-template scalar path (full plan build
+ DAG build + scalar DES per candidate), which stays callable via
``engine="scalar"``:

* **optimize_single** -- single-task ``optimize_plan`` on the canonical
  heterogeneous pair (fast+slow secondary, 40 vs 8 Gbps links -- the
  Table-IV cluster of ``benchmarks/hetero_sweep.py``).
* **place_4task**    -- 4-task ``place_tasks`` on the skewed 8-ES pool of
  ``benchmarks/multitask_placement.py`` (swap search + per-task refinement).
* **replan_storm**   -- a drifting channel forcing a fresh ``optimize_plan``
  per epoch against new rates (the plan-cache *miss* path of
  ``repro.core.replan``): per-epoch planning latency under realistic reuse
  (layouts/templates are rate-independent, so the storm hits their caches
  exactly as a live controller would).

Both engines share one search loop and price candidates bit-identically, so
every scenario also asserts the returned plans are *equal* -- the speedup is
pure pricing, not a different search.  Timings are wall-clock per call; each
engine's first call pays the one-off template/layout builds and is reported
separately (``cold_ms``), medians are over the steady-state repeats -- the
per-replan latency an online controller actually sees.

Emits ``BENCH_planner.json`` (``--out`` to move it, ``--smoke`` for the CI
artifact run).  Acceptance (tests/test_benchmarks.py): plans equal in every
scenario and the speedup floors hold.  CSV rows
(``name,us_per_call,derived``) match the other benchmarks' format.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    GTX_1080TI,
    CollabTopology,
    Link,
    optimize_plan,
    place_tasks,
    vgg16_geom,
)
from repro.core.simulator import GaussMarkovTrace  # noqa: E402

try:  # either invocation style: `python benchmarks/planner_speed.py` or module
    from benchmarks.multitask_placement import build_pool  # noqa: E402
except ModuleNotFoundError:  # pragma: no cover - direct-script path setup
    sys.path.insert(0, "benchmarks")
    from multitask_placement import build_pool  # noqa: E402

NET = vgg16_geom()
FAST_BPS = 40e9
SLOW_BPS = 8e9


def hetero_pair() -> CollabTopology:
    """The Table-IV heterogeneous pair: one full-speed secondary on a fast
    link, one 0.35x secondary behind a slow link."""
    slow = GTX_1080TI.scaled(0.35, "slow")
    return CollabTopology(
        host="e0",
        secondaries=("fast", "slow"),
        platforms={"e0": GTX_1080TI, "fast": GTX_1080TI, "slow": slow},
        links={
            ("e0", "fast"): Link(FAST_BPS),
            ("fast", "e0"): Link(FAST_BPS),
            ("e0", "slow"): Link(SLOW_BPS),
            ("slow", "e0"): Link(SLOW_BPS),
        },
        default_link=Link(FAST_BPS),
    )


def _timed(fn) -> tuple[float, object]:
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e3, out


def _plan_key(res) -> tuple:
    return (res.ratios, res.overlap_rows, res.makespan)


def _placement_key(res) -> tuple:
    return (res.placement.assignments, res.knobs, res.makespan, res.avg_delay)


def _scenario(
    times: dict[str, list[float]],
    cold: dict[str, float],
    equal: bool,
    evals: dict[str, int],
) -> dict:
    med_b = statistics.median(times["batched"])
    med_s = statistics.median(times["scalar"])
    return dict(
        batched_ms=times["batched"],
        scalar_ms=times["scalar"],
        cold_ms=cold,
        median_batched_ms=med_b,
        median_scalar_ms=med_s,
        speedup=med_s / med_b,
        plans_equal=equal,
        evaluations=evals,
    )


def _bench_call(call, key_of, repeats: int) -> dict:
    """Per engine: one cold call (first template/layout builds, reported
    separately -- online controllers pay it once per cluster lifetime), then
    ``repeats`` timed steady-state calls, which is the per-replan latency the
    serving loop actually sees."""
    times = {"batched": [], "scalar": []}
    cold = {}
    keys = []
    evals = {}
    for engine in ("batched", "scalar"):
        ms, res = _timed(lambda: call(engine))
        cold[engine] = ms
        keys.append(key_of(res))
        for _ in range(repeats):
            ms, res = _timed(lambda: call(engine))
            times[engine].append(ms)
            keys.append(key_of(res))
        evals[engine] = res.evaluations
    return _scenario(times, cold, len(set(keys)) == 1, evals)


def bench_optimize_single(repeats: int) -> dict:
    topo = hetero_pair()
    return _bench_call(
        lambda engine: optimize_plan(NET, topo, n_tasks=1, engine=engine),
        _plan_key,
        repeats,
    )


def bench_place_4task(repeats: int) -> dict:
    pool = build_pool()
    return _bench_call(
        lambda engine: place_tasks(NET, pool, 4, engine=engine),
        _placement_key,
        repeats,
    )


def bench_replan_storm(epochs: int) -> dict:
    """Fresh single-task optimisation per epoch against drifted link rates --
    the latency a controller pays on every plan-cache miss."""
    base = hetero_pair()
    fast = GaussMarkovTrace(lo=10e9, hi=40e9, seed=7).rates(epochs)
    slow = GaussMarkovTrace(lo=2e9, hi=10e9, seed=11).rates(epochs)
    topos = [
        base.with_links(
            {
                ("e0", "fast"): Link(rf),
                ("fast", "e0"): Link(rf),
                ("e0", "slow"): Link(rs),
                ("slow", "e0"): Link(rs),
            }
        )
        for rf, rs in zip(fast, slow)
    ]
    times = {"batched": [], "scalar": []}
    cold = {}
    equal = True
    evals = {"batched": 0, "scalar": 0}
    for epoch, topo in enumerate(topos):
        ms_b, rb = _timed(lambda: optimize_plan(NET, topo, n_tasks=1, engine="batched"))
        ms_s, rs_ = _timed(lambda: optimize_plan(NET, topo, n_tasks=1, engine="scalar"))
        if epoch == 0:  # first epoch of a fresh cluster: template/layout builds
            cold = {"batched": ms_b, "scalar": ms_s}
        else:
            times["batched"].append(ms_b)
            times["scalar"].append(ms_s)
        equal = equal and _plan_key(rb) == _plan_key(rs_)
        evals["batched"] += rb.evaluations
        evals["scalar"] += rs_.evaluations
    return _scenario(times, cold, equal, evals)


def run_all(smoke: bool = False, out_path: str | None = "BENCH_planner.json") -> dict:
    repeats = 3 if smoke else 5
    epochs = 5 if smoke else 12
    scenarios = {
        "optimize_single": bench_optimize_single(repeats),
        "place_4task": bench_place_4task(2 if smoke else 3),
        "replan_storm": bench_replan_storm(epochs),
    }
    out = dict(
        config=dict(smoke=smoke, repeats=repeats, storm_epochs=epochs, net=NET.name),
        floors=dict(optimize_single=10.0, place_4task=5.0),
        scenarios=scenarios,
    )
    print("\n== Planner latency: batched DAG-template engine vs scalar path ==")
    print(
        f"{'scenario':16s} {'batched (ms)':>12s} {'scalar (ms)':>12s} {'speedup':>8s} "
        f"{'cold (ms)':>10s} plans"
    )
    for name, sc in scenarios.items():
        print(
            f"{name:16s} {sc['median_batched_ms']:12.1f} {sc['median_scalar_ms']:12.1f} "
            f"{sc['speedup']:7.1f}x {sc['cold_ms']['batched']:10.1f} "
            f"{'equal' if sc['plans_equal'] else 'DIVERGED'}"
        )
        print(f"planner_{name},{sc['median_batched_ms']*1e3:.0f},{sc['speedup']:.2f}")
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)
        print(f"\nwrote {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_planner.json")
    args = ap.parse_args()
    run_all(smoke=args.smoke, out_path=args.out)
