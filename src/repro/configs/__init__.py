"""Config registry: one module per assigned architecture (+ the paper's VGG-16).

``get(name)`` / ``list_archs()`` trigger registration lazily.
"""
from .base import Arch, Cell, REGISTRY, get, list_archs  # noqa: F401

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from . import (  # noqa: F401
        codeqwen15_7b,
        convnext_b,
        deepseek_v3_671b,
        dit_xl2,
        efficientnet_b7,
        moonshot_v1_16b_a3b,
        qwen3_4b,
        swin_b,
        unet_sd15,
        vgg16,
        vit_l16,
    )
    _LOADED = True
