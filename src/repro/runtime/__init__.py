from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .fault import FaultConfig, FaultTolerantTrainer, InjectedFault
from .serve import (
    BatchingEngine,
    Request,
    ServeConfig,
    ServedTrace,
    ServeLoopConfig,
    VirtualClock,
    choose_batch_size,
    plan_aware_batch_size,
    serve_trace,
)
from .traffic import (
    DeadlineClass,
    DiurnalProcess,
    FlashCrowdProcess,
    PoissonProcess,
    Trace,
    make_trace,
)
