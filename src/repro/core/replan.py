"""Online channel-adaptive re-planning: estimate, bucket, cache, re-optimise.

The paper's §V.D evaluates HALP under a *time-variant* offloading channel but
still runs one plan chosen offline against nominal rates; DistrEdge
(arXiv 2202.01699) and the authors' own prototype (arXiv 2211.13778) show the
remaining latency on real testbeds comes from exactly that gap -- measured link
rates drift away from the nominals the partition was optimised for.  This
module closes the loop online, in three layers:

* :class:`LinkRateEstimator` -- an EWMA over observed per-link transfer times
  ``rate_sample = 8 * nbytes / elapsed``, seeded from the
  :class:`~repro.core.topology.CollabTopology` nominals, one estimate per
  directed host<->secondary pair (secondaries never talk directly, so 2N
  links suffice; any other measured pair -- e.g. the IoT offload uplink of an
  :class:`~repro.core.reliability.OffloadChannel` -- can be folded in through
  the same ``observe``).

* :class:`PlanCache` -- an LRU map from **(topology fingerprint + optimiser
  config, quantised rate buckets)** to the
  :class:`~repro.core.optimizer.OptimizeResult`
  for that operating point.  Rates are quantised into geometric bands of width
  ``bucket_frac`` (30% by default): every rate inside a band maps to the same
  key, and the plan is optimised against the band's *representative* (geometric
  centre) rate, so cache entries are reproducible regardless of which measured
  rate first filled them.  In steady state -- a mean-reverting channel
  revisiting a handful of bands -- every plan request is an O(1) dict hit.

* :class:`ReplanController` -- the policy.  Each control epoch it re-buckets
  the current estimates and applies **hysteresis**: the estimates must sit
  outside the active bands for ``hysteresis`` consecutive epochs before the
  latest bucket key becomes active (a single-epoch rate excursion therefore
  cannot thrash the plan, at the cost of reacting ``hysteresis - 1`` epochs
  late; a steadily drifting channel is not starved).  Only when the active key
  changes does the controller consult the cache, and only on a cache miss does
  it rebuild the :class:`CollabTopology` with the band-representative rates
  and invoke :func:`~repro.core.optimizer.optimize_plan`.  Setting
  ``bucket_frac=0`` keys on the exact estimates (every drift is a miss): that
  degenerate configuration is the "always re-plan" upper-baseline used by
  ``benchmarks/replan_sweep.py``.

The re-optimisation objective defaults to the discrete-event simulator (the
repo's ground truth); ``ReplanConfig(use_simulator=False)`` switches to the
paper's closed-form recursion (:func:`~repro.core.schedule.halp_closed_form`),
which prices the same event topology ~two orders of magnitude faster but, for
``n_tasks > 1``, over-weights communication (see :class:`ReplanConfig`).
Plans produced here are geometry-only (row partitions), so a plan optimised
for estimated rates is always *valid* (lossless) under the true rates -- only
its latency is at stake.  ``runtime.serve`` consumes the controller through
:func:`~repro.runtime.serve.plan_aware_batch_size`, which feeds the *current*
plan's predicted makespan into ``choose_batch_size``.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Mapping

from .nets import ConvNetGeom
from .optimizer import OptimizeResult, optimize_plan
from .partition import HALPPlan
from .schedule import halp_closed_form
from .topology import CollabTopology, Link

__all__ = [
    "LinkRateEstimator",
    "PlanCache",
    "ReplanConfig",
    "ReplanController",
    "StaticPlanner",
    "optimize_static",
    "topology_fingerprint",
    "rate_bucket",
    "bucket_rate",
]

# Reference rate for the geometric bucket grid.  Any positive constant works
# (it only shifts bucket indices); 1 Mbps keeps indices small and readable for
# both Mbps offload channels and Gbps ES-ES links.
BUCKET_REF_BPS = 1e6


def rate_bucket(rate_bps: float, bucket_frac: float) -> float:
    """Quantise a rate into a geometric band index of width ``bucket_frac``.

    Band ``i`` covers ``[REF * (1+f)^i, REF * (1+f)^(i+1))``; with the default
    f = 0.3 two rates land in the same band iff they differ by < 30%.
    ``bucket_frac <= 0`` disables quantisation and returns the exact rate
    (the always-replan degenerate keying)."""
    if rate_bps <= 0:
        raise ValueError(f"rate must be positive, got {rate_bps}")
    if bucket_frac <= 0:
        return rate_bps
    return math.floor(math.log(rate_bps / BUCKET_REF_BPS) / math.log1p(bucket_frac))


def bucket_rate(bucket: float, bucket_frac: float) -> float:
    """The band's representative rate (geometric centre) -- the rate plans are
    optimised against, so a band's cached plan is independent of which
    measured rate first triggered it."""
    if bucket_frac <= 0:
        return bucket  # exact keying: the "bucket" is the rate itself
    return BUCKET_REF_BPS * (1.0 + bucket_frac) ** (bucket + 0.5)


def topology_fingerprint(topology: CollabTopology) -> tuple:
    """Hashable identity of everything the optimum depends on *except* rates:
    host/secondary names in order and per-ES effective compute."""
    return (
        topology.host,
        topology.secondaries,
        tuple((es, topology.platform_of(es).eff_flops) for es in topology.es_names),
    )


class LinkRateEstimator:
    """EWMA per-link rate estimates from observed transfer times.

    Each observation ``(src, dst, nbytes, elapsed_s)`` yields a rate sample
    ``8 * nbytes / elapsed_s``; the estimate moves ``alpha`` of the way toward
    it.  Estimates are seeded from nominal rates, so before any traffic a
    controller optimises for the nominal rates' *bands* (representative rates
    within ``bucket_frac`` of the nominals -- close to, but not necessarily
    identical with, the offline nominal-rate plan)."""

    def __init__(self, nominal_bps: Mapping[tuple[str, str], float], alpha: float = 0.4):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._rates = dict(nominal_bps)

    @classmethod
    def from_topology(cls, topology: CollabTopology, alpha: float = 0.4) -> "LinkRateEstimator":
        """Seed one estimate per directed host<->secondary link from nominals."""
        return cls(
            {pair: topology.link_between(*pair).rate_bps for pair in topology.collab_pairs()},
            alpha=alpha,
        )

    def observe(self, src: str, dst: str, nbytes: float, elapsed_s: float) -> float:
        """Fold one observed transfer in; returns the updated estimate."""
        if nbytes <= 0 or elapsed_s <= 0:
            raise ValueError(f"need positive bytes/elapsed, got {nbytes}, {elapsed_s}")
        sample = 8.0 * nbytes / elapsed_s
        prev = self._rates.get((src, dst))
        est = sample if prev is None else (1.0 - self.alpha) * prev + self.alpha * sample
        self._rates[(src, dst)] = est
        return est

    def rate(self, src: str, dst: str) -> float:
        return self._rates[(src, dst)]

    def rates(self) -> dict[tuple[str, str], float]:
        return dict(self._rates)


class PlanCache:
    """LRU cache of optimisation results keyed on (fingerprint, buckets),
    where the fingerprint covers the cluster *and* the optimiser config.

    ``get`` / ``put`` are O(1); ``hits``/``misses``/``evictions`` make the
    amortisation claim measurable (``benchmarks/replan_sweep.py`` asserts a
    >= 90% steady-state hit rate)."""

    def __init__(self, capacity: int = 128):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict[tuple, OptimizeResult] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple) -> OptimizeResult | None:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return entry

    def peek(self, key: tuple) -> OptimizeResult | None:
        """Read without touching hit/miss counters or the LRU order.  The
        serving path (latency predictions, admission control) peeks, so the
        telemetry keeps counting *plan requests per control epoch* -- the
        quantity the amortisation claim is stated in -- rather than being
        swamped by per-admission lookups."""
        return self._entries.get(key)

    def put(self, key: tuple, result: OptimizeResult) -> None:
        self._entries[key] = result
        self._entries.move_to_end(key)
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def entries(self) -> list[OptimizeResult]:
        """All cached results, least- to most-recently used (e.g. for
        verifying every plan a controller ever served stays lossless)."""
        return list(self._entries.values())


@dataclass(frozen=True)
class ReplanConfig:
    """Knobs of the online re-planner (see the module docstring for design)."""

    bucket_frac: float = 0.3  # geometric band width; <= 0 keys on exact rates
    hysteresis: int = 2  # consecutive epochs outside the active bands to adopt
    alpha: float = 0.4  # EWMA weight of the rate estimator
    n_tasks: int = 4  # concurrent tasks the plan is optimised for
    overlap_choices: tuple[int, ...] = (2, 4, 6, 8)
    max_rounds: int = 6  # coordinate-descent budget per re-optimisation
    # Candidate-pricing engine for cache-miss re-optimisations.  "batched"
    # (the DAG-template + vectorized-DES fast path) and "scalar" return
    # bit-identical plans; the knob exists so benchmarks can price the miss
    # path both ways.  Misses therefore pay the fast path by default.
    engine: str = "batched"
    # Hard planner-latency bounds for the miss path (None/0.0 = unbounded):
    # eval_budget caps priced candidates per optimize_plan call, tol stops a
    # replan once a descent round improves the makespan by less than this.
    eval_budget: int | None = None
    tol: float = 0.0
    # Objective engine.  The DES is the repo's ground truth and the default:
    # the closed form prices each secondary slot's uplink as shared across
    # tasks (eq. 17's x n_tasks) while the DES models the paper's multi-task
    # deployment of N * n_tasks distinct secondaries with their own links, so
    # for n_tasks > 1 the closed form over-weights communication and
    # over-shrinks slow-link segments.  Set False for the ~20x cheaper
    # closed-form search when the re-plan latency budget is tight (it stays a
    # safe choice for single-task controllers, where the two engines agree).
    use_simulator: bool = True


def _optimize_against(
    net: ConvNetGeom, topology: CollabTopology, config: ReplanConfig
) -> OptimizeResult:
    """One plan optimisation against the given topology's rates."""
    objective = None
    if not config.use_simulator:

        def objective(ratios: tuple[float, ...], w: int) -> float:
            try:
                return halp_closed_form(
                    net,
                    topology=topology,
                    ratios=ratios,
                    overlap_rows=w,
                    n_tasks=config.n_tasks,
                )["total"]
            except (AssertionError, ValueError):
                return float("inf")

    return optimize_plan(
        net,
        topology,
        n_tasks=config.n_tasks,
        overlap_choices=config.overlap_choices,
        max_rounds=config.max_rounds,
        objective=objective,
        engine=config.engine,
        eval_budget=config.eval_budget,
        tol=config.tol,
    )


def optimize_static(
    net: ConvNetGeom, topology: CollabTopology, config: ReplanConfig = ReplanConfig()
) -> OptimizeResult:
    """The offline baseline: optimise once against *nominal* rates.

    Uses the same objective/budget as :class:`ReplanController`, so benchmark
    comparisons isolate adaptivity rather than optimiser settings."""
    return _optimize_against(net, topology, config)


class StaticPlanner:
    """Planner-protocol wrapper around one fixed plan (the paper's baseline):
    ignores all observations, serves the same plan every epoch."""

    def __init__(self, plan: HALPPlan):
        self._plan = plan

    def observe_transfer(self, src: str, dst: str, nbytes: float, elapsed_s: float) -> None:
        pass

    def plan_for_epoch(self) -> HALPPlan:
        return self._plan


class ReplanController:
    """Channel-adaptive planner: EWMA estimates -> buckets -> hysteresis ->
    cached :func:`optimize_plan`.

    Implements the same planner protocol as :class:`StaticPlanner`
    (``observe_transfer`` + ``plan_for_epoch``), so
    :func:`~repro.core.simulator.replay_rate_trace` and the serving loop drive
    either interchangeably.

    Subclasses may override :meth:`_optimize` to swap what is recomputed on a
    bucket switch (e.g. :class:`~repro.core.placement.PlacementController`
    re-places *every task* instead of re-optimising one shared plan); the
    estimator, bucketing, hysteresis, cache, and telemetry are inherited
    unchanged.  ``_cache_kind`` namespaces cache keys so different controller
    kinds can share one :class:`PlanCache`."""

    _cache_kind = "plan"

    def __init__(
        self,
        net: ConvNetGeom,
        topology: CollabTopology,
        config: ReplanConfig = ReplanConfig(),
        cache: PlanCache | None = None,
    ):
        self.net = net
        self.nominal = topology
        self.config = config
        self.cache = cache if cache is not None else PlanCache()
        self.estimator = LinkRateEstimator.from_topology(topology, alpha=config.alpha)
        # identity of everything a cached optimum depends on besides the rate
        # buckets: the cluster and every optimiser-facing config knob (bucket
        # indices are grid-relative, so bucket_frac in particular must key) --
        # controllers with different configs can then share one PlanCache
        self._fingerprint = (
            self._cache_kind,
            topology_fingerprint(topology),
            config.bucket_frac,
            config.n_tasks,
            tuple(config.overlap_choices),
            config.max_rounds,
            config.use_simulator,
            # search-bounding knobs change which plan a miss produces, so they
            # must key; the pricing engine does NOT (bit-identical scores) --
            # batched and scalar controllers share entries by design
            config.eval_budget,
            config.tol,
        )
        self._active = self._bucket_key()
        self._pending_count = 0  # consecutive epochs spent outside the active bands
        # telemetry
        self.epochs = 0
        self.replans = 0  # adopted bucket switches
        self.optimizer_calls = 0
        self._calibration = 1.0  # measured/predicted latency EWMA (serving)

    # -- bucketing ------------------------------------------------------------

    def _bucket_key(self) -> tuple:
        f = self.config.bucket_frac
        return tuple(
            sorted((pair, rate_bucket(r, f)) for pair, r in self.estimator.rates().items())
        )

    def estimated_topology(self) -> CollabTopology:
        """The nominal topology rebuilt with the active buckets' representative
        rates -- what plans are optimised against."""
        f = self.config.bucket_frac
        links = {pair: Link(bucket_rate(b, f)) for pair, b in self._active}
        return self.nominal.with_links(links)

    # -- planner protocol -----------------------------------------------------

    def observe_transfer(self, src: str, dst: str, nbytes: float, elapsed_s: float) -> float:
        """Feed one observed transfer into the rate estimator."""
        return self.estimator.observe(src, dst, nbytes, elapsed_s)

    def step(self) -> bool:
        """Advance one control epoch; returns True iff the active bucket key
        switched (i.e. the serving plan may change).

        Hysteresis: the estimates must sit *outside* the active bands for
        ``hysteresis`` consecutive epochs (<= 1 means immediately) before the
        most recent candidate key is adopted; wandering back inside the
        active bands resets the counter.  Counting epochs-away-from-active
        (rather than epochs-on-one-candidate) means a channel drifting
        monotonically across one band per epoch still replans after the
        hysteresis lag instead of being starved by its own motion."""
        self.epochs += 1
        candidate = self._bucket_key()
        if candidate == self._active:
            self._pending_count = 0
            return False
        self._pending_count += 1
        if self._pending_count < max(1, self.config.hysteresis):
            return False
        self._active = candidate
        self._pending_count = 0
        self.replans += 1
        return True

    def _optimize(self, topology: CollabTopology) -> OptimizeResult:
        """Recompute the operating point for ``topology`` (cache-miss path).
        Subclasses override this to re-place instead of re-plan."""
        return _optimize_against(self.net, topology, self.config)

    def current(self) -> OptimizeResult:
        """The active operating point's plan: an O(1) cache hit in steady
        state, a fresh :meth:`_optimize` run on a miss.

        This is the *per-epoch* entry point and the one place hit/miss
        telemetry is counted; out-of-epoch reads (``plan``, ``makespan``, the
        serving integration) go through :meth:`_active_result` instead."""
        key = (self._fingerprint, self._active)
        result = self.cache.get(key)
        if result is None:
            result = self._optimize(self.estimated_topology())
            self.optimizer_calls += 1
            self.cache.put(key, result)
        return result

    def _active_result(self) -> OptimizeResult:
        """The active plan without disturbing the epoch telemetry (peek);
        falls through to :meth:`current` only if the entry is genuinely
        absent (first request, or evicted)."""
        result = self.cache.peek((self._fingerprint, self._active))
        return result if result is not None else self.current()

    def plan_for_epoch(self) -> HALPPlan:
        """One control epoch: hysteresis step, then the (cached) active plan."""
        self.step()
        return self.current().plan

    @property
    def plan(self) -> HALPPlan:
        return self._active_result().plan

    @property
    def makespan(self) -> float:
        """Predicted makespan of the active plan at ``config.n_tasks``."""
        return self._active_result().makespan

    # -- serving integration --------------------------------------------------

    def _raw_predicted_latency(self, batch_size: int) -> float:
        return halp_closed_form(
            self.net,
            topology=self.estimated_topology(),
            plan=self._active_result().plan,
            n_tasks=batch_size,
        )["total"]

    def predicted_latency(self, batch_size: int) -> float:
        """Closed-form makespan of the *current* plan for a batch of
        ``batch_size`` tasks, scaled by the measured-latency calibration --
        the latency model ``choose_batch_size`` admits batches against."""
        return self._raw_predicted_latency(batch_size) * self._calibration

    def observe_batch_latency(self, batch_size: int, elapsed_s: float) -> None:
        """Fold a measured batch latency back in: the ratio measured/predicted
        becomes an EWMA calibration factor on future predictions (clamped to
        [0.1, 10] so one outlier batch cannot poison admission control)."""
        if elapsed_s <= 0 or batch_size < 1:
            return
        predicted = self._raw_predicted_latency(batch_size)
        if predicted <= 0:
            return
        ratio = min(10.0, max(0.1, elapsed_s / predicted))
        a = self.config.alpha
        self._calibration = (1.0 - a) * self._calibration + a * ratio

    def stats(self) -> dict:
        return dict(
            epochs=self.epochs,
            replans=self.replans,
            optimizer_calls=self.optimizer_calls,
            cache_hits=self.cache.hits,
            cache_misses=self.cache.misses,
            cache_entries=len(self.cache),
            cache_hit_rate=self.cache.hit_rate,
            calibration=self._calibration,
        )
