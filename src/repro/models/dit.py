"""DiT (Peebles & Xie, arXiv:2212.09748) -- dit-xl2.

Latent-space diffusion transformer with adaLN-zero conditioning.  The model
runs on an 8x-downsampled latent (img_res/8) with patch size 2; a 50-step
sampler is 50 forwards of this backbone (the drivers scan over steps).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from .common import Params, conv_params, dense_params, keygen, norm_params, stack_layers, trunc_normal
from .layers import dense, gelu, layernorm

__all__ = ["DiTConfig", "init", "apply", "timestep_embedding"]


@dataclass(frozen=True)
class DiTConfig:
    name: str = "dit-xl2"
    img_res: int = 256
    patch: int = 2
    n_layers: int = 28
    d_model: int = 1152
    n_heads: int = 16
    mlp_ratio: int = 4
    latent_ch: int = 4
    num_classes: int = 1000
    learn_sigma: bool = True
    remat: bool = True

    @property
    def latent_res(self) -> int:
        return self.img_res // 8

    @property
    def n_tokens(self) -> int:
        return (self.latent_res // self.patch) ** 2

    @property
    def out_ch(self) -> int:
        return self.latent_ch * (2 if self.learn_sigma else 1)


def timestep_embedding(t: jax.Array, dim: int, max_period: float = 10_000.0) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def _block_init(key, d, mlp_ratio, dtype):
    ks = keygen(key)
    return {
        "wqkv": dense_params(next(ks), d, 3 * d, dtype=dtype),
        "wo": dense_params(next(ks), d, d, dtype=dtype),
        "fc1": dense_params(next(ks), d, mlp_ratio * d, dtype=dtype),
        "fc2": dense_params(next(ks), mlp_ratio * d, d, dtype=dtype),
        # adaLN-zero modulation: 6 per-channel (shift, scale, gate) vectors;
        # initialised to zero so every block starts as identity.
        "ada": {
            "w": jnp.zeros((d, 6 * d), dtype),
            "b": jnp.zeros((6 * d,), dtype),
        },
    }


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None]) + shift[:, None]


def _block_apply(p, x, c, n_heads):
    """x [B, N, D], c [B, D] conditioning."""
    b, n, d = x.shape
    mod = dense(gelu(c), p["ada"])  # [B, 6D]
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    h = _modulate(_ln(x), sh1, sc1)
    qkv = dense(h, p["wqkv"]).reshape(b, n, 3, n_heads, d // n_heads)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    logits = jnp.einsum("bnhd,bmhd->bhnm", q, k) / jnp.sqrt(d / n_heads)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
    a = jnp.einsum("bhnm,bmhd->bnhd", probs, v).reshape(b, n, d)
    x = x + g1[:, None] * dense(a, p["wo"])
    h = _modulate(_ln(x), sh2, sc2)
    return x + g2[:, None] * dense(gelu(dense(h, p["fc1"])), p["fc2"])


def _ln(x, eps=1e-6):
    """Parameter-free LN (adaLN supplies scale/shift)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps)


def init(key, cfg: DiTConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    d = cfg.d_model
    return {
        "patch_embed": conv_params(next(ks), cfg.patch, cfg.latent_ch, d, dtype=dtype),
        "pos": trunc_normal(next(ks), (1, cfg.n_tokens, d), dtype=dtype),
        "t_mlp1": dense_params(next(ks), 256, d, dtype=dtype),
        "t_mlp2": dense_params(next(ks), d, d, dtype=dtype),
        "label_embed": trunc_normal(next(ks), (cfg.num_classes + 1, d), 0.02, dtype),
        "blocks": stack_layers(
            lambda k: _block_init(k, d, cfg.mlp_ratio, dtype), next(ks), cfg.n_layers
        ),
        "final_ada": {"w": jnp.zeros((d, 2 * d), dtype), "b": jnp.zeros((2 * d,), dtype)},
        "final": dense_params(next(ks), d, cfg.patch * cfg.patch * cfg.out_ch, dtype=dtype),
    }


def apply(params: Params, cfg: DiTConfig, x_latent, t, y) -> jax.Array:
    """x_latent [B, H, W, C_lat], t [B] timesteps, y [B] class labels ->
    predicted noise (+sigma) [B, H, W, out_ch]."""
    from .layers import conv2d  # local import to avoid cycle

    b, hh, ww, _ = x_latent.shape
    x = conv2d(x_latent, params["patch_embed"], stride=cfg.patch, padding="VALID")
    gh, gw = x.shape[1], x.shape[2]
    x = x.reshape(b, gh * gw, cfg.d_model) + params["pos"][:, : gh * gw]
    t_emb = timestep_embedding(t, 256).astype(x.dtype)
    temb = dense(gelu(dense(t_emb, params["t_mlp1"])), params["t_mlp2"])
    c = (temb + params["label_embed"][y]).astype(x.dtype)

    def body(h, p_l):
        return _block_apply(p_l, h, c, cfg.n_heads), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = lax.scan(body, x, params["blocks"])

    mod = dense(gelu(c), params["final_ada"])
    sh, sc = jnp.split(mod, 2, axis=-1)
    x = _modulate(_ln(x), sh, sc)
    x = dense(x, params["final"])  # [B, N, p*p*out]
    p_ = cfg.patch
    x = x.reshape(b, gh, gw, p_, p_, cfg.out_ch).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gh * p_, gw * p_, cfg.out_ch)
