"""Cold vs warm restart through the persistent PlanStore.

The claim under test (ROADMAP open item 2, the PR's tentpole): a controller
restarted against a populated :class:`~repro.core.planstore.PlanStore` serves
every operating point it has seen before with **zero optimizer calls**, its
first plan arrives store-speed instead of optimiser-speed, and every
store-served plan is **bit-identical** to the one a cold controller optimises
fresh (same ``HALPPlan`` equality, float-equal makespans/ratios) -- pickled
results round-trip exactly, and band-representative keying makes the entries
reproducible regardless of which process computed them.

Three phases over one drifting trace (links wander, the last secondary
straggles -- the ``benchmarks/straggler_sweep.py`` drift modes on the small
demo cluster of ``tools/precompute_plans.py``):

* **cold**  -- fresh store file: every new operating point pays the
  optimiser; we record optimizer calls and time-to-first-plan.
* **warm**  -- a *new* controller + *new* store connection on the same file
  (the process-restart model): same trace, zero optimizer calls required,
  per-epoch plans/makespans compared bit-exactly against the cold run.
* **reconfigured** -- same store, one optimiser knob changed
  (``max_rounds``): the config lives in the content key, so the controller
  must re-optimise from scratch (never serves a stale plan) -- the
  invalidation-by-keying guarantee.

Emits ``BENCH_planstore.json`` (``--out`` to move it, ``--smoke`` for the CI
run).  Acceptance: ``tests/test_benchmarks.py::test_planstore_bench_acceptance``
pins warm calls == 0, bit-identity, the reconfigure re-optimise, and a floor
on the warm first-plan speedup.  CSV rows (``name,us_per_call,derived``)
match the other benchmarks' format.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import dataclasses  # noqa: E402

from repro.core import GaussMarkovTrace, PlanStore, ReplanController  # noqa: E402
from tools.precompute_plans import (  # noqa: E402
    NOMINAL_BPS,
    demo_config,
    demo_net,
    demo_topology,
)


def _drift_trace(n_epochs: int) -> tuple[list, list, list]:
    """(rate of e0<->a, rate of e0<->b, eff-FLOP/s of straggler b) per epoch."""
    link_a = GaussMarkovTrace(
        lo=0.3 * NOMINAL_BPS, hi=1.5 * NOMINAL_BPS, corr=0.85, sigma_frac=0.15, seed=3
    ).rates(n_epochs)
    link_b = GaussMarkovTrace(
        lo=0.2 * NOMINAL_BPS, hi=1.2 * NOMINAL_BPS, corr=0.85, sigma_frac=0.15, seed=5
    ).rates(n_epochs)
    nominal_flops = demo_topology().platform_of("b").eff_flops
    straggle = GaussMarkovTrace(
        lo=0.3 * nominal_flops, hi=nominal_flops, mean=0.5 * nominal_flops,
        corr=0.9, sigma_frac=0.1, start=nominal_flops, seed=7,
    ).rates(n_epochs)
    return link_a, link_b, straggle


def _run_controller(store_path: str, n_epochs: int, config=None) -> dict:
    """One controller lifetime over the drift trace against ``store_path``.

    Opens its own store connection (the restart/process model), records the
    wall time of the very first plan request, and keeps the per-epoch
    (bucket key, plan, makespan) trail for bit-identity comparison."""
    link_a, link_b, straggle = _drift_trace(n_epochs)
    with PlanStore(store_path) as store:
        ctrl = ReplanController(
            demo_net(), demo_topology(),
            config if config is not None else demo_config(),
            store=store,
        )
        t0 = time.perf_counter()
        ctrl.current()
        first_plan_s = time.perf_counter() - t0
        trail = []
        t0 = time.perf_counter()
        for e in range(n_epochs):
            for src, dst, rate in (
                ("e0", "a", link_a[e]), ("a", "e0", link_a[e]),
                ("e0", "b", link_b[e]), ("b", "e0", link_b[e]),
            ):
                # nbytes chosen so 8*nbytes/elapsed == rate at elapsed=1
                ctrl.observe_transfer(src, dst, rate / 8.0, 1.0)
            ctrl.observe_compute("b", straggle[e], 1.0)
            ctrl.step()
            r = ctrl.current()
            trail.append((ctrl._active, r.plan, r.makespan))
        stats = ctrl.stats()
        return dict(
            first_plan_s=first_plan_s,
            epochs_s=time.perf_counter() - t0,
            optimizer_calls=ctrl.optimizer_calls,
            replans=ctrl.replans,
            store_hits=stats.get("store_hits", 0),
            store_entries=stats.get("store_entries", 0),
            trail=trail,
        )


def run_all(smoke: bool = False, out_path: str | None = "BENCH_planstore.json") -> dict:
    n_epochs = 40 if smoke else 200
    with tempfile.TemporaryDirectory() as tmp:
        store_path = os.path.join(tmp, "plans.sqlite")

        cold = _run_controller(store_path, n_epochs)
        warm = _run_controller(store_path, n_epochs)

        plans_identical = all(
            kc == kw and pc == pw
            for (kc, pc, _), (kw, pw, _) in zip(cold["trail"], warm["trail"])
        )
        makespans_identical = all(
            mc == mw for (_, _, mc), (_, _, mw) in zip(cold["trail"], warm["trail"])
        )

        # a changed optimiser knob keys differently: same store, but every
        # operating point is new -- the controller must re-optimise
        recfg = dataclasses.replace(demo_config(), max_rounds=demo_config().max_rounds + 1)
        reconfigured = _run_controller(store_path, n_epochs, config=recfg)

        out = dict(
            n_epochs=n_epochs,
            distinct_operating_points=len({k for k, _, _ in cold["trail"]}),
            cold={k: v for k, v in cold.items() if k != "trail"},
            warm={k: v for k, v in warm.items() if k != "trail"},
            reconfigured={
                k: v for k, v in reconfigured.items() if k != "trail"
            },
            warm_optimizer_calls=warm["optimizer_calls"],
            plans_bit_identical=plans_identical,
            makespans_bit_identical=makespans_identical,
            reconfigured_reoptimized=reconfigured["optimizer_calls"] > 0,
            warm_first_plan_speedup=cold["first_plan_s"] / max(1e-9, warm["first_plan_s"]),
        )

    print(f"epochs {n_epochs}, distinct operating points "
          f"{out['distinct_operating_points']}")
    print(f"{'phase':14s} {'opt calls':>9s} {'first plan (ms)':>16s} "
          f"{'epochs (ms)':>12s} {'store hits':>10s}")
    for phase in ("cold", "warm", "reconfigured"):
        m = out[phase]
        print(
            f"{phase:14s} {m['optimizer_calls']:9d} {m['first_plan_s']*1e3:16.2f} "
            f"{m['epochs_s']*1e3:12.1f} {m['store_hits']:10d}"
        )
        print(f"planstore_{phase}_first_plan,{m['first_plan_s']*1e6:.1f},"
              f"{m['optimizer_calls']}")
    print(
        f"\nwarm restart: {out['warm_optimizer_calls']} optimizer calls "
        f"(bit-identical plans: {out['plans_bit_identical']}, makespans: "
        f"{out['makespans_bit_identical']}), first plan "
        f"{out['warm_first_plan_speedup']:.1f}x faster than cold"
    )
    print(f"planstore_warm_speedup,,{out['warm_first_plan_speedup']:.2f}")
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True, default=str)
        print(f"\nwrote {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_planstore.json")
    args = ap.parse_args()
    run_all(smoke=args.smoke, out_path=args.out)
