from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .grad_compress import compress_bf16, compress_topk, topk_sparsify
from .schedules import constant, warmup_cosine
