"""Pallas TPU kernel: flash attention (causal), online-softmax over KV blocks.

Grid: (batch*heads, n_q_blocks); the kernel scans KV blocks for one Q block,
keeping the running max / normaliser / accumulator in VMEM.  Block shapes are
MXU-aligned (q_block x d and kv_block x d matmuls).  This is the on-device
analogue of models.attention._sdpa_chunked_causal (the pure-JAX oracle path).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, scale: float, causal: bool):
    """One (bh, qi) grid step: q [1, QB, D]; k/v [1, S, D]; o [1, QB, D]."""
    qb = q_ref.shape[1]
    d = q_ref.shape[2]
    s = k_ref.shape[1]
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # [QB, D]

    m = jnp.full((qb,), NEG_INF, jnp.float32)
    l = jnp.zeros((qb,), jnp.float32)
    acc = jnp.zeros((qb, d), jnp.float32)

    n_kv = s // kv_block
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kv_block), 0)

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_ref[0], (j * kv_block, 0), (kv_block, d))
        v = jax.lax.dynamic_slice(v_ref[0], (j * kv_block, 0), (kv_block, d))
        logits = jnp.dot(q, k.astype(jnp.float32).T, preferred_element_type=jnp.float32)
        if causal:
            kv_pos = j * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (qb, kv_block), 1
            )
            logits = jnp.where(q_pos >= kv_pos, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = alpha[:, None] * acc + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    if causal:
        # only KV blocks up to (and including) the diagonal contribute
        n_iter = jnp.minimum(n_kv, (qi + 1) * qb // kv_block + (1 if qb % kv_block else 0))
        n_iter = jnp.maximum(n_iter, 1)
    else:
        n_iter = n_kv
    m, l, acc = jax.lax.fori_loop(0, n_iter, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, H, T, D]
    k: jax.Array,  # [B, H, S, D]
    v: jax.Array,  # [B, H, S, D]
    *,
    causal: bool = True,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, t, d = q.shape
    s = k.shape[2]
    assert t % q_block == 0 and s % kv_block == 0, (t, s, q_block, kv_block)
    scale = 1.0 / math.sqrt(d)
    qf = q.reshape(b * h, t, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    kernel = functools.partial(
        _flash_kernel, kv_block=kv_block, scale=scale, causal=causal
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, t // q_block),
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, s, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, s, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, t, d)
