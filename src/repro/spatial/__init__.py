"""TPU-native spatial parallelism: the paper's receptive-field partitioning as a
shard_map halo-exchange engine (deployment form) plus a single-device plan
executor (semantic model, used for losslessness proofs)."""
from .halo import (
    conv2d_spatial,
    exchange_halos,
    halo_sizes,
    max_pool_spatial,
    merge_padded_shards,
    plan_shard_heights,
    shard_heights,
    spatial_alignment,
    to_padded_shards,
)
from .partition_apply import run_plan, segment_forward
