"""Paper reproduction benchmarks: one function per table/figure of
*Distributed Deep Learning Inference Acceleration using Seamless Collaboration
in Edge Computing* (Li, Iosifidis, Zhang, 2022).

Every function prints a human-readable table plus ``name,us_per_call,derived``
CSV rows and returns a dict of {metric: (ours, paper)} pairs used by
tests/test_benchmarks.py to assert reproduction quality.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.core import (
    AGX_XAVIER,
    GTX_1080TI,
    Link,
    OffloadChannel,
    enhanced_modnn_delay,
    equal_ratios,
    evaluate_plan,
    halp_closed_form,
    optimize_plan,
    plan_halp,
    rate_fluctuation,
    service_reliability,
    simulate_halp,
    simulate_modnn,
    speedup_ratio,
    standalone_time,
    vgg16_geom,
)

NET = vgg16_geom()
RATES = (40e9, 60e9, 80e9, 100e9)


def table1_layer_times() -> dict:
    """Table I analogue: per-layer ingredient times of g1/g2 on the 1080TI at
    40 Gbps (computed from our calibrated model; the paper's were measured)."""
    link = Link(40e9)
    plan = plan_halp(NET, overlap_rows=4)
    rows = {}
    print("\n== Table I: per-layer HALP ingredients, GTX 1080TI @ 40 Gbps (ms) ==")
    print(f"{'layer':10s} {'t_int':>8s} {'t_cmp_dep':>10s} {'t_com_dep':>10s} {'t_cmp_rest':>11s}")
    for i in (0, 1):
        g = NET.layers[i]
        dep = plan.message(i, "e1", "e0")
        own = plan.parts[i].out["e1"]
        w = NET.sizes()[i + 1]
        t_int = link.comm_time(4 * plan.parts[0].inp["e1"].rows * NET.in_rows * 3) if i == 0 else 0.0
        t_cmp_dep = GTX_1080TI.compute_time(g.flops_per_out_row(w) * dep.rows)
        t_com_dep = link.comm_time(plan.message_bytes(i, "e1", "e0"))
        t_cmp_rest = GTX_1080TI.compute_time(g.flops_per_out_row(w) * (own.rows - dep.rows))
        print(f"{g.name:10s} {t_int*1e3:8.4f} {t_cmp_dep*1e3:10.4f} {t_com_dep*1e3:10.4f} {t_cmp_rest*1e3:11.4f}")
        print(f"table1_{g.name},{(t_int+t_cmp_dep+t_com_dep+t_cmp_rest)*1e6:.2f},")
        rows[g.name] = dict(t_int=t_int, t_cmp_dep=t_cmp_dep, t_com_dep=t_com_dep, t_cmp_rest=t_cmp_rest)
    # paper anchors: g1 t_int=0.057ms (60.2% of 0.113ms incl. t_com), g2 tiny coms
    rows["paper_g1_comm_frac"] = (rows["conv1_1"]["t_int"] + rows["conv1_1"]["t_com_dep"], 0.068e-3)
    return rows


def fig6_single_task() -> dict:
    """Fig. 6: single-task speedup ratio rho (eq. 21) vs. ES-ES rate."""
    out = {}
    print("\n== Fig. 6: single-task speedup ratio rho = 1 - T/t_pre ==")
    print(f"{'platform':18s} {'rate':>6s} {'HALP T(ms)':>10s} {'rho':>7s} {'x-speedup':>9s} {'MoDNN3 rho':>10s}")
    for plat in (GTX_1080TI, AGX_XAVIER):
        t_pre = standalone_time(NET, plat)
        for rate in RATES:
            t = simulate_halp(NET, plat, Link(rate))["total"]
            tm = simulate_modnn(NET, plat, Link(rate), 3)["total"]
            rho = speedup_ratio(t, t_pre)
            print(
                f"{plat.name:18s} {rate/1e9:4.0f}G {t*1e3:10.3f} {rho:7.3f} "
                f"{t_pre/t:8.2f}x {speedup_ratio(tm, t_pre):10.3f}"
            )
            print(f"fig6_{plat.name.split()[0]}_{int(rate/1e9)}G,{t*1e6:.1f},{rho:.4f}")
            out[(plat.name, rate)] = (t_pre / t, rho)
    # paper claim: 1.75-2.04x single-task speedup across platforms/rates
    return out


def fig7_multi_task() -> dict:
    """Fig. 7: 4-task average-delay speedup ratio."""
    out = {}
    print("\n== Fig. 7: 4-task speedup ratio (average delay) ==")
    for plat in (GTX_1080TI, AGX_XAVIER):
        t_pre = standalone_time(NET, plat)
        for rate in RATES:
            r = simulate_halp(NET, plat, Link(rate), n_tasks=4)
            rho = speedup_ratio(r["avg_delay"], t_pre)
            print(
                f"{plat.name:18s} {rate/1e9:4.0f}G avg_delay={r['avg_delay']*1e3:7.3f}ms "
                f"rho={rho:6.3f} ({t_pre/r['avg_delay']:4.2f}x)"
            )
            print(f"fig7_{plat.name.split()[0]}_{int(rate/1e9)}G,{r['avg_delay']*1e6:.1f},{rho:.4f}")
            out[(plat.name, rate)] = t_pre / r["avg_delay"]
    return out


# Paper Table II (fps)
PAPER_TABLE2 = {
    ("GTX 1080TI", "pre"): 851,
    ("GTX 1080TI", "halp"): {40e9: 1364, 60e9: 1384, 80e9: 1413, 100e9: 1423},
    ("GTX 1080TI", "orig"): {40e9: 327, 60e9: 415, 80e9: 479, 100e9: 529},
    ("GTX 1080TI", "enh"): {40e9: 498, 60e9: 629, 80e9: 724, 100e9: 797},
    ("JETSON AGX Xavier", "pre"): 124,
    ("JETSON AGX Xavier", "halp"): {40e9: 219, 60e9: 221, 80e9: 223, 100e9: 225},
    ("JETSON AGX Xavier", "orig"): {40e9: 98, 60e9: 105, 80e9: 109, 100e9: 112},
    ("JETSON AGX Xavier", "enh"): {40e9: 138, 60e9: 146, 80e9: 151, 100e9: 152},
}


def table2_throughput() -> dict:
    """Table II: average throughput of 4 tasks per batch (fps), ours vs paper."""
    out = {}
    print("\n== Table II: 4-task throughput (fps) -- ours (paper) ==")
    for plat in (GTX_1080TI, AGX_XAVIER):
        t_pre = standalone_time(NET, plat)
        pre = 4.0 / t_pre
        print(f"{plat.name}: pre-trained {pre:.0f} ({PAPER_TABLE2[(plat.name, 'pre')]})")
        for rate in RATES:
            link = Link(rate)
            halp = 4.0 / simulate_halp(NET, plat, link, n_tasks=4)["total"]
            orig = 1.0 / simulate_modnn(NET, plat, link, 9)["total"]
            enh = enhanced_modnn_delay(NET, plat, link)["throughput"]
            p = {k: PAPER_TABLE2[(plat.name, k)][rate] for k in ("halp", "orig", "enh")}
            print(
                f"  {rate/1e9:4.0f}G  HALP {halp:6.0f} ({p['halp']:4d})   "
                f"OrigMoDNN {orig:5.0f} ({p['orig']:3d})   EnhMoDNN {enh:5.0f} ({p['enh']:3d})"
            )
            print(f"table2_halp_{plat.name.split()[0]}_{int(rate/1e9)}G,{1e6*4/halp:.1f},{halp:.0f}")
            out[(plat.name, rate)] = (halp, p["halp"])
    return out


# Paper Table III (reliability)
PAPER_TABLE3 = {
    ("pre", 40e6, 1e-3): 0.815931,
    ("pre", 40e6, 5e-3): 0.571420,
    ("pre", 60e6, 5e-3): 1.0,
    ("pre", 60e6, 9e-3): 0.999934,
    ("pre", 60e6, 14e-3): 0.992992,
    ("pre", 100e6, 14e-3): 1.0,
    ("pre", 100e6, 18e-3): 0.999640,
    ("halp", 40e6, 1e-3): 1.0,
    ("halp", 40e6, 5e-3): 0.999104,
    ("halp", 60e6, 5e-3): 1.0,
    ("halp", 60e6, 9e-3): 1.0,
    ("halp", 60e6, 14e-3): 0.999774,
    ("halp", 100e6, 14e-3): 1.0,
    ("halp", 100e6, 18e-3): 0.999993,
}


def table3_reliability() -> dict:
    """Table III: service reliability on Xavier under a time-variant channel.

    Constants reverse-engineered from the paper's own entries (DESIGN.md):
    deadline = 4 frames / 30 fps; offload = 4 x 125 KB; T_inf(pre) = 32.43 ms
    (slack 0.9 ms at 40 Mbps -> Phi(0.9) = 0.815931 exactly); T_inf(HALP) =
    17.77 ms (Table II's 225 fps).  We report both the paper-implied constants
    and our simulator's own Xavier times."""
    deadline = 4.0 / 30.0
    t_pre_paper, t_halp_paper = 32.43e-3, 17.77e-3
    # our simulator's equivalents
    t_pre_sim = standalone_time(NET, AGX_XAVIER)
    t_halp_sim = simulate_halp(NET, AGX_XAVIER, Link(100e9), n_tasks=4)["total"]
    out = {}
    print("\n== Table III: service reliability (ours@paper-constants | ours@sim | paper) ==")
    cases = [
        (40e6, 1e-3), (40e6, 5e-3), (60e6, 5e-3), (60e6, 9e-3), (60e6, 14e-3),
        (100e6, 14e-3), (100e6, 18e-3),
    ]
    for rate, sigma in cases:
        ch = OffloadChannel(rate_bps=rate, sigma_s=sigma)
        phi_mbps = rate_fluctuation(ch) / 1e6
        for kind, t_p, t_s in (
            ("pre", t_pre_paper, t_pre_sim),
            ("halp", t_halp_paper, t_halp_sim),
        ):
            ours = service_reliability(ch, t_p, deadline)
            sim = service_reliability(ch, t_s, deadline)
            paper = PAPER_TABLE3[(kind, rate, sigma)]
            print(
                f"  {kind:4s} {rate/1e6:4.0f}Mbps sigma={sigma*1e3:4.1f}ms phi={phi_mbps:5.1f} "
                f"-> {ours:.6f} | {sim:.6f} | {paper:.6f}"
            )
            out[(kind, rate, sigma)] = (ours, paper)
        print(f"table3_{int(rate/1e6)}M_{int(sigma*1e3)}ms,,{out[('halp', rate, sigma)][0]:.6f}")
    return out


def table4_heterogeneous_optimizer() -> dict:
    """Beyond the paper: optimizer-chosen plans on a heterogeneous cluster.

    One fast (1080TI-class) + one 0.35x secondary behind a 10 Gbps link; the
    naive equal split (the paper's default partition) vs. the coordinate-
    descent optimum over (segment ratios, overlap rows).  The scenario is the
    sweep's ``slow_x0.35_@10G`` point, built by the same helper so the two
    benchmarks cannot diverge; see ``benchmarks/hetero_sweep.py``."""
    try:
        from .hetero_sweep import _two_secondary_topology
    except ImportError:  # run as a script: benchmarks/ is sys.path[0]
        from hetero_sweep import _two_secondary_topology

    topo = _two_secondary_topology(slow_factor=0.35, slow_gbps=10.0)
    equal = evaluate_plan(NET, topo, equal_ratios(topo), 4)
    res = optimize_plan(NET, topo)
    gain = 1.0 - res.makespan / equal
    print("\n== Table IV (ours): heterogeneous cluster, equal split vs optimizer ==")
    print(
        f"  equal-split {equal*1e3:7.3f} ms   optimized {res.makespan*1e3:7.3f} ms "
        f"({gain*100:.1f}% faster; ratios={[round(r, 3) for r in res.ratios]}, "
        f"overlap={res.overlap_rows} rows, {res.evaluations} simulator evals)"
    )
    print(f"table4_hetero_opt,{res.makespan*1e6:.1f},{gain:.4f}")
    return dict(equal=equal, optimized=res.makespan, gain=gain, ratios=res.ratios,
                overlap_rows=res.overlap_rows)


def run_all():
    t1 = table1_layer_times()
    f6 = fig6_single_task()
    f7 = fig7_multi_task()
    t2 = table2_throughput()
    t3 = table3_reliability()
    t4 = table4_heterogeneous_optimizer()
    return dict(table1=t1, fig6=f6, fig7=f7, table2=t2, table3=t3, table4=t4)


if __name__ == "__main__":
    run_all()
