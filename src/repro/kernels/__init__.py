"""Pallas TPU kernels for the compute hot-spots (each with ops.py wrapper and
ref.py pure-jnp oracle, validated in interpret mode):

* conv2d    -- direct conv as MXU matmuls over VMEM row tiles (the paper's
               hot-spot; explicit halo-tile materialisation mirrors HALP)
* halo_conv -- HALP-fused spatially-sharded conv (interior tiles independent
               of the ppermuted halos -> comm hides behind compute)
* attention -- causal flash attention (online softmax over KV blocks)
"""
