"""EfficientNet (Tan & Le, arXiv:1905.11946) -- efficientnet-b7
(width_mult=2.0, depth_mult=3.1, img_res=600).

MBConv blocks with squeeze-excitation.  Every operator except the SE global
pool is sliding-window, so the paper's partitioning applies layer-wise; the SE
pool is the one cross-segment synchronisation point (noted in DESIGN.md).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import Params, conv_params, dense_params, keygen
from .layers import (
    batchnorm_inference,
    batchnorm_train,
    conv2d,
    dense,
    global_avg_pool,
    silu,
    softmax_xent,
)

__all__ = ["EfficientNetConfig", "init", "apply"]

# B0 baseline: (expand, channels, repeats, stride, kernel)
B0_STAGES = (
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
)


@dataclass(frozen=True)
class EfficientNetConfig:
    name: str = "efficientnet-b7"
    img_res: int = 600
    width_mult: float = 2.0
    depth_mult: float = 3.1
    num_classes: int = 1000
    in_channels: int = 3
    se_ratio: float = 0.25
    stem_ch: int = 32
    head_ch: int = 1280

    def round_ch(self, c: int) -> int:
        c = c * self.width_mult
        new = max(8, int(c + 4) // 8 * 8)
        if new < 0.9 * c:
            new += 8
        return new

    def round_reps(self, r: int) -> int:
        return int(math.ceil(self.depth_mult * r))

    def stages(self):
        return [
            (e, self.round_ch(c), self.round_reps(r), s, k) for e, c, r, s, k in B0_STAGES
        ]


def _bn_params(c, dtype):
    return {
        "scale": jnp.ones((c,), dtype),
        "b": jnp.zeros((c,), dtype),
        "mean": jnp.zeros((c,), dtype),
        "var": jnp.ones((c,), dtype),
    }


def _mbconv_init(key, c_in, c_out, expand, k, se_ratio, dtype):
    ks = keygen(key)
    c_mid = c_in * expand
    p: Params = {}
    if expand != 1:
        p["expand"] = conv_params(next(ks), 1, c_in, c_mid, bias=False, dtype=dtype)
        p["bn0"] = _bn_params(c_mid, dtype)
    p["dw"] = conv_params(next(ks), k, c_mid, c_mid, bias=False, groups=c_mid, dtype=dtype)
    p["bn1"] = _bn_params(c_mid, dtype)
    c_se = max(1, int(c_in * se_ratio))
    p["se_reduce"] = dense_params(next(ks), c_mid, c_se, dtype=dtype)
    p["se_expand"] = dense_params(next(ks), c_se, c_mid, dtype=dtype)
    p["project"] = conv_params(next(ks), 1, c_mid, c_out, bias=False, dtype=dtype)
    p["bn2"] = _bn_params(c_out, dtype)
    return p


def _bn(x, p, train):
    return batchnorm_train(x, p) if train else batchnorm_inference(x, p)


def _mbconv_apply(p, x, stride, k, train):
    c_in = x.shape[-1]
    h = x
    if "expand" in p:
        h = silu(_bn(conv2d(h, p["expand"], padding="VALID"), p["bn0"], train))
    pad = (k - 1) // 2
    h = silu(_bn(conv2d(h, p["dw"], stride=stride, padding=pad, groups=h.shape[-1]), p["bn1"], train))
    # squeeze-excitation (the global pool is the cross-segment sync point)
    se = global_avg_pool(h)
    se = jax.nn.sigmoid(dense(silu(dense(se, p["se_reduce"])), p["se_expand"]))
    h = h * se[:, None, None, :]
    h = _bn(conv2d(h, p["project"], padding="VALID"), p["bn2"], train)
    if stride == 1 and h.shape[-1] == c_in:
        h = h + x
    return h


def init(key, cfg: EfficientNetConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    stem_c = cfg.round_ch(cfg.stem_ch)
    p: Params = {
        "stem": conv_params(next(ks), 3, cfg.in_channels, stem_c, bias=False, dtype=dtype),
        "stem_bn": _bn_params(stem_c, dtype),
        "blocks": [],
    }
    c_in = stem_c
    blocks = []
    # static metadata (stride/kernel) lives in block_meta(cfg); params are arrays
    for e, c_out, reps, s, k in cfg.stages():
        for r in range(reps):
            blocks.append(_mbconv_init(next(ks), c_in, c_out, e, k, cfg.se_ratio, dtype))
            c_in = c_out
    p["blocks"] = blocks
    head_c = cfg.round_ch(cfg.head_ch)
    p["head_conv"] = conv_params(next(ks), 1, c_in, head_c, bias=False, dtype=dtype)
    p["head_bn"] = _bn_params(head_c, dtype)
    p["fc"] = dense_params(next(ks), head_c, cfg.num_classes, dtype=dtype)
    return p


def block_meta(cfg: EfficientNetConfig) -> list[tuple[int, int]]:
    """Static (stride, kernel) per block, aligned with params['blocks']."""
    meta = []
    for e, c_out, reps, s, k in cfg.stages():
        for r in range(reps):
            meta.append((s if r == 0 else 1, k))
    return meta


def apply(params: Params, cfg: EfficientNetConfig, x: jax.Array, train: bool = False) -> jax.Array:
    x = silu(_bn(conv2d(x, params["stem"], stride=2, padding=1), params["stem_bn"], train))
    for p_b, (s, k) in zip(params["blocks"], block_meta(cfg)):
        x = _mbconv_apply(p_b, x, s, k, train)
    x = silu(_bn(conv2d(x, params["head_conv"], padding="VALID"), params["head_bn"], train))
    return dense(global_avg_pool(x), params["fc"])


def loss_fn(params, cfg: EfficientNetConfig, images, labels):
    logits = apply(params, cfg, images, train=True)
    return softmax_xent(logits, labels), {"logits": logits}
