"""Named sharding/config variants for perf hill-climbing (EXPERIMENTS.md §Perf).

A variant is a set of overrides consulted by the sharding rules and step
builders.  The dry-run selects one with ``--variant NAME`` (or the
REPRO_VARIANT env var); results are cached under a variant-suffixed key so
baselines are never overwritten.

Variants are deliberately *small, orthogonal knobs* -- each §Perf iteration
flips one and re-derives the roofline terms.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

__all__ = ["Variant", "get_variant", "set_variant", "VARIANTS"]


@dataclass(frozen=True)
class Variant:
    name: str
    # sharding knobs
    lm_fsdp_small: bool = False  # FSDP also for the small/dense LMs
    constrain_residual: bool = False  # pin [B,S,D] residual: batch over dp
    seq_shard_activations: bool = False  # constrain [B,S,D] acts: S over model
    embed_vocab_shard: bool = False  # embed: shard vocab (not d_model)
    replicate_lm_head: bool = False
    gather_experts: bool = False  # EP off: replicate experts (ablation)
    # step knobs
    no_remat: bool = False
    q_chunk: int | None = None  # chunked-attention block override
    diffusion_spatial2d: bool = False  # 2-D spatial shard for gen; no conv TP
    notes: str = ""


VARIANTS: dict[str, Variant] = {
    "base": Variant("base"),
    "seq_shard": Variant(
        "seq_shard",
        seq_shard_activations=True,
        notes="sequence-parallel activation constraints between TP blocks",
    ),
    "vocab_shard": Variant(
        "vocab_shard",
        embed_vocab_shard=True,
        notes="embedding sharded over vocab instead of d_model",
    ),
    "fsdp_all": Variant(
        "fsdp_all", lm_fsdp_small=True, notes="ZeRO-3 for every LM arch"
    ),
    "no_remat": Variant("no_remat", no_remat=True, notes="disable activation ckpt"),
    "ep_off": Variant("ep_off", gather_experts=True, notes="ablate expert parallelism"),
    # code-level improvements land in `opt` so the baseline records survive
    "opt": Variant(
        "opt",
        constrain_residual=True,
        notes="sort-based MoE dispatch + carry-derived attention masks + bf16 "
        "cotangents (f32 cast inside the loss) + rematted attention chunks + "
        "residual-stream sharding constraint (batch x dp)",
    ),
    "spatial2d": Variant(
        "spatial2d",
        diffusion_spatial2d=True,
        notes="diffusion gen: 2-D spatial sharding (H x data, W x model), "
        "replicated conv params -- the paper's partitioning instead of TP",
    ),
}

_ACTIVE = VARIANTS["base"]


def set_variant(name: str) -> Variant:
    global _ACTIVE
    _ACTIVE = VARIANTS[name]
    return _ACTIVE


def get_variant() -> Variant:
    env = os.environ.get("REPRO_VARIANT")
    if env and env != _ACTIVE.name and env in VARIANTS:
        set_variant(env)
    return _ACTIVE
