from .attention import flash_attention
from .ops import gqa_flash
from .ref import attention_ref
