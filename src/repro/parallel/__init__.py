from .sharding import input_shardings, param_shardings, shard_rules, state_shardings
