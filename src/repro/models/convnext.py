"""ConvNeXt (Liu et al., arXiv:2201.03545) -- convnext-b.

Pure sliding-window operators end to end: the paper's receptive-field
partitioning applies to every layer (the 7x7 depthwise convs are the widest
halos in the assigned pool -- a showcase for the spatial engine).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from .common import Params, conv_params, dense_params, keygen, norm_params, stack_layers
from .layers import conv2d, dense, gelu, layernorm, softmax_xent

__all__ = ["ConvNeXtConfig", "init", "apply"]


@dataclass(frozen=True)
class ConvNeXtConfig:
    name: str = "convnext-b"
    img_res: int = 224
    depths: tuple[int, ...] = (3, 3, 27, 3)
    dims: tuple[int, ...] = (128, 256, 512, 1024)
    num_classes: int = 1000
    in_channels: int = 3
    layer_scale: float = 1e-6
    remat: bool = True


def _block_init(key, dim, dtype, layer_scale):
    ks = keygen(key)
    return {
        "dw": conv_params(next(ks), 7, dim, dim, groups=dim, dtype=dtype),
        "ln": norm_params(dim, dtype=dtype),
        "pw1": dense_params(next(ks), dim, 4 * dim, dtype=dtype),
        "pw2": dense_params(next(ks), 4 * dim, dim, dtype=dtype),
        "gamma": layer_scale * jnp.ones((dim,), dtype),
    }


def _block_apply(p, x):
    h = conv2d(x, p["dw"], padding=3, groups=x.shape[-1])
    h = layernorm(h, p["ln"])
    h = dense(gelu(dense(h, p["pw1"])), p["pw2"])
    return x + p["gamma"] * h


def init(key, cfg: ConvNeXtConfig, dtype=jnp.float32) -> Params:
    ks = keygen(key)
    p: Params = {
        "stem": conv_params(next(ks), 4, cfg.in_channels, cfg.dims[0], dtype=dtype),
        "stem_norm": norm_params(cfg.dims[0], dtype=dtype),
        "stages": [],
        "ln": norm_params(cfg.dims[-1], dtype=dtype),
        "head": dense_params(next(ks), cfg.dims[-1], cfg.num_classes, dtype=dtype),
    }
    stages = []
    for si, (depth, dim) in enumerate(zip(cfg.depths, cfg.dims)):
        stage = {
            "blocks": stack_layers(
                lambda k, dim=dim: _block_init(k, dim, dtype, cfg.layer_scale),
                next(ks),
                depth,
            )
        }
        if si + 1 < len(cfg.depths):
            stage["down_norm"] = norm_params(dim, dtype=dtype)
            stage["down"] = conv_params(next(ks), 2, dim, cfg.dims[si + 1], dtype=dtype)
        stages.append(stage)
    p["stages"] = stages
    return p


def apply(params: Params, cfg: ConvNeXtConfig, x: jax.Array) -> jax.Array:
    x = conv2d(x, params["stem"], stride=4, padding="VALID")
    x = layernorm(x, params["stem_norm"])
    for si, stage in enumerate(params["stages"]):
        depth = cfg.depths[si]
        if depth >= 6:

            def body(h, p_l):
                return _block_apply(p_l, h), None

            if cfg.remat:
                body = jax.checkpoint(body, prevent_cse=False)
            x, _ = lax.scan(body, x, stage["blocks"])
        else:
            for li in range(depth):
                p_l = jax.tree_util.tree_map(lambda a: a[li], stage["blocks"])
                x = _block_apply(p_l, x)
        if "down" in stage:
            x = layernorm(x, stage["down_norm"])
            x = conv2d(x, stage["down"], stride=2, padding="VALID")
    x = layernorm(jnp.mean(x, axis=(1, 2)), params["ln"])
    return dense(x, params["head"])


def loss_fn(params, cfg: ConvNeXtConfig, images, labels):
    logits = apply(params, cfg, images)
    return softmax_xent(logits, labels), {"logits": logits}
