"""jit'd wrapper for the Pallas direct-conv kernel: padding, halo-tile
construction (the HALP boundary rows, materialised), VMEM budget heuristics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .conv2d import conv2d_tiles

VMEM_BUDGET = 8 * 1024 * 1024  # bytes per grid step we allow ourselves


def _pick_tile_h(h: int, w_ext: int, cin: int, cout: int, k: int, itemsize: int):
    """Largest divisor tile height whose working set fits the VMEM budget."""
    for th in [t for t in (64, 32, 16, 8, 4, 2, 1) if h % t == 0]:
        tc = min(cout, 128)
        need = (
            (th + k - 1) * w_ext * cin + k * k * cin * tc + th * (w_ext - k + 1) * tc
        ) * max(itemsize, 4)
        if need <= VMEM_BUDGET:
            return th
    return 1


def conv2d_pallas(
    x: jax.Array,  # [N, H, W, Cin]  (NHWC)
    weights: jax.Array,  # [k, k, Cin, Cout]
    bias: jax.Array | None = None,
    *,
    padding: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Stride-1 SAME/VALID conv via the Pallas kernel (k = weights.shape[0])."""
    k = weights.shape[0]
    n, h, w, cin = x.shape
    cout = weights.shape[-1]
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    h_eff = x.shape[1] - (k - 1)  # output rows
    w_ext = x.shape[2]
    th = _pick_tile_h(h_eff, w_ext, cin, cout, k, x.dtype.itemsize)
    nt = h_eff // th
    # overlapping row tiles: tile t covers padded rows [t*th, t*th + th + k - 1)
    idx = (jnp.arange(nt) * th)[:, None] + jnp.arange(th + k - 1)[None]
    x_tiles = x[:, idx]  # [N, nT, TH + k - 1, W_ext, Cin]
    cout_tile = min(cout, 128)
    y = conv2d_tiles(
        x_tiles, weights, k=k, tile_h=th, cout_tile=cout_tile, interpret=interpret
    )
    y = y.reshape(n, h_eff, w_ext - (k - 1), cout)
    if bias is not None:
        y = y + bias
    return y
