"""Online channel-adaptive re-partitioning in one script.

1. replay a Gauss-Markov time-variant channel through the DES: one offline
   nominal-rate plan (the paper's deployment) vs the cached adaptive
   re-planner (``repro.core.replan``),
2. plan-cache amortisation: steady-state plan requests are O(1) lookups,
3. serving integration: the batcher feeds measured latencies back and
   ``plan_aware_batch_size`` re-admits against the *current* plan,
4. losslessness: the adaptive plan's distributed forward equals the
   single-device forward.

    PYTHONPATH=src python examples/replan_channel.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AGX_XAVIER,
    CollabTopology,
    GaussMarkovTrace,
    Link,
    OffloadChannel,
    ReplanConfig,
    ReplanController,
    StaticPlanner,
    optimize_static,
    replay_rate_trace,
)
from repro.core.reliability import IMAGE_BYTES
from repro.models import vgg
from repro.runtime.serve import BatchingEngine, ServeConfig, plan_aware_batch_size
from repro.spatial import run_plan

# A thin VGG-16 (64x64, 1/8 width) so the whole demo runs in seconds on CPU;
# Mbps-grade edge links make the schedule communication-dominated, which is
# exactly where adapting the partition to the measured channel pays off.
cfg = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10)
net = cfg.geom()
NOMINAL = 120e6
topo = CollabTopology(
    host="e0",
    secondaries=("a", "b"),
    platforms={"e0": AGX_XAVIER, "a": AGX_XAVIER, "b": AGX_XAVIER},
    default_link=Link(NOMINAL),
)
N_EPOCHS, N_TASKS = 36, 4
replan_cfg = ReplanConfig(n_tasks=N_TASKS)

# -- 1. static vs adaptive on the same channel replay -------------------------
trace_b = GaussMarkovTrace(
    lo=30e6, hi=NOMINAL, mean=50e6, corr=0.9, sigma_frac=0.1, start=NOMINAL, seed=5
).rates(N_EPOCHS)
link_rates = {("e0", "b"): trace_b, ("b", "e0"): trace_b}

static_plan = optimize_static(net, topo, replan_cfg).plan
static_run = replay_rate_trace(net, topo, StaticPlanner(static_plan), link_rates, n_tasks=N_TASKS)

controller = ReplanController(net, topo, replan_cfg)
adaptive_run = replay_rate_trace(net, topo, controller, link_rates, n_tasks=N_TASKS)


def b_share(plan) -> float:
    rows = plan.parts[0].out
    return rows["b"].rows / sum(seg.rows for seg in rows.values())

print("== channel replay: secondary b drifts 120 -> ~50 Mbps ==")
print(f"{'epoch':>5s} {'b rate':>8s} {'static':>9s} {'adaptive':>9s} {'b share':>8s}")
for s_rec, a_rec in zip(static_run, adaptive_run):
    if a_rec["epoch"] % 4:
        continue
    print(
        f"{a_rec['epoch']:5d} {s_rec['rates'][('e0', 'b')]/1e6:6.0f}Mb "
        f"{s_rec['makespan']*1e3:7.2f}ms {a_rec['makespan']*1e3:7.2f}ms "
        f"{b_share(a_rec['plan'])*100:7.1f}%"
    )

mean = lambda run: sum(r["makespan"] for r in run) / len(run)
print(
    f"mean makespan: static {mean(static_run)*1e3:.2f} ms, "
    f"adaptive {mean(adaptive_run)*1e3:.2f} ms"
)

# -- 2. the cache did the amortising ------------------------------------------
stats = controller.stats()
print(
    f"\n== plan cache == {stats['epochs']} epochs -> {stats['replans']} plan "
    f"switches, {stats['optimizer_calls']} optimizer calls, "
    f"hit rate {stats['cache_hit_rate']:.2f}"
)

# -- 3. serving: latency feedback + plan-aware admission ----------------------
params = vgg.init(jax.random.PRNGKey(0), cfg)
plan_now = controller.plan


@jax.jit
def model(batch):
    feats = run_plan(plan_now, params["features"], vgg.apply_layer, batch)
    return jnp.argmax(vgg.head(params, feats), axis=-1)


channel = OffloadChannel(rate_bps=100e6, sigma_s=1e-3)
batch0 = plan_aware_batch_size(controller, 4.0 / 30.0, channel, target=0.999, max_batch=8)
if batch0 == 0:  # admission says shed: no batch meets the deadline target
    raise SystemExit("admission returned 0 (shed): deadline infeasible on this plan")
engine = BatchingEngine(
    model, ServeConfig(max_batch=batch0), observer=controller.observe_batch_latency
)
for i in range(12):
    # generous deadline for the served requests: the first batch pays the CPU
    # jit compile, which is not the offload/inference path §V.D models
    engine.submit(
        jax.random.normal(jax.random.PRNGKey(i), (cfg.img_res, cfg.img_res, 3)),
        deadline_s=10.0,
    )
t0 = time.monotonic()
serve_stats = engine.run_until_drained()
print(
    f"\n== serving == admitted batch {batch0}; served {serve_stats['completed']} "
    f"requests in {time.monotonic()-t0:.2f}s, deadline met "
    f"{serve_stats['deadline_met_frac']*100:.0f}%, calibration "
    f"{controller.stats()['calibration']:.2f}"
)
# the channel collapses: feed the controller the measured (slow) transfers and
# re-admit -- the batch size follows the new plan's predicted makespan.
for _ in range(replan_cfg.hysteresis + 2):
    controller.observe_transfer("e0", "b", IMAGE_BYTES, 8.0 * IMAGE_BYTES / 30e6)
    controller.observe_transfer("b", "e0", IMAGE_BYTES, 8.0 * IMAGE_BYTES / 30e6)
    controller.step()
batch1 = plan_aware_batch_size(controller, 4.0 / 30.0, channel, target=0.999, max_batch=8)
print(
    f"after measured collapse to 30 Mbps: admitted batch {batch0} -> {batch1}"
    + (" (0 = shed: nothing meets the deadline now)" if batch1 == 0 else "")
)

# -- 4. losslessness of the adaptive plan -------------------------------------
x = jax.random.normal(jax.random.PRNGKey(99), (1, cfg.img_res, cfg.img_res, 3))
ref = vgg.features(params, cfg, x)
out = run_plan(controller.plan, params["features"], vgg.apply_layer, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("\n== losslessness: adaptive plan forward == single-device forward  OK ==")
print("\nreplan_channel complete.")
