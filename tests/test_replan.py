"""Online re-planning tests: rate estimator, bucketing, plan cache,
hysteresis, channel replay, serving integration, and losslessness of
replanned plans."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    AGX_XAVIER,
    CollabTopology,
    ComputeRateEstimator,
    GaussMarkovTrace,
    Link,
    OffloadChannel,
    PlanCache,
    ReplanConfig,
    ReplanController,
    StaticPlanner,
    bucket_rate,
    compute_band_flops,
    compute_bucket,
    optimize_static,
    rate_bucket,
    replay_rate_trace,
    replay_trace,
)
from repro.core.reliability import IMAGE_BYTES
from repro.core.replan import LinkRateEstimator, topology_fingerprint
from repro.models import vgg
from repro.runtime.serve import plan_aware_batch_size
from repro.spatial import run_plan

CFG = vgg.VGGConfig(img_res=64, width_mult=0.125, num_classes=10)
NET = CFG.geom()
NOMINAL = 120e6


def small_topology() -> CollabTopology:
    return CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={"e0": AGX_XAVIER, "a": AGX_XAVIER, "b": AGX_XAVIER},
        default_link=Link(NOMINAL),
    )


# closed-form objective: plan *validity* and cache/hysteresis mechanics are
# what these tests exercise, so the ~20x cheaper engine keeps them fast
FAST = ReplanConfig(use_simulator=False, alpha=1.0, hysteresis=1, bucket_frac=0.5)


def fast_link_topology() -> CollabTopology:
    """Same cluster on 50 Gbps links: compute-bound, so per-ES compute drift
    (not the channel) dominates the makespan -- the straggler test regime."""
    return CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={"e0": AGX_XAVIER, "a": AGX_XAVIER, "b": AGX_XAVIER},
        default_link=Link(50e9),
    )


def observe_rate(ctl: ReplanController, rate: float) -> None:
    """One epoch's worth of probe observations on b's (volatile) link."""
    for pair in (("e0", "b"), ("b", "e0")):
        ctl.observe_transfer(*pair, IMAGE_BYTES, 8.0 * IMAGE_BYTES / rate)


def observe_compute(ctl: ReplanController, es: str, flops_rate: float) -> None:
    """One epoch's worth of timing probes on one ES's compute."""
    ctl.observe_compute(es, 1e9, 1e9 / flops_rate)


# -- bucketing ----------------------------------------------------------------


def test_rate_bucket_bands():
    f = 0.25
    # same band iff within the geometric width; representative inside the band
    for r in (40e6, 120e6, 2.5e9, 100e9):
        b = rate_bucket(r, f)
        assert rate_bucket(r * 1.001, f) in (b, b + 1)
        rep = bucket_rate(b, f)
        assert rep / r < (1 + f) and r / rep < (1 + f)
    # monotone in the rate
    rates = [10e6 * (1.3**i) for i in range(20)]
    buckets = [rate_bucket(r, f) for r in rates]
    assert buckets == sorted(buckets)


def test_rate_bucket_exact_mode_and_errors():
    # bucket_frac <= 0 keys on the exact rate (always-replan degenerate mode)
    assert rate_bucket(123.0e6, 0.0) == 123.0e6
    assert bucket_rate(123.0e6, 0.0) == 123.0e6
    with pytest.raises(ValueError):
        rate_bucket(0.0, 0.25)


def test_compute_bucket_anchored_at_nominal():
    """Compute bands are centred on the calibrated nominal: the seed estimate
    sits in band 0 and band 0's representative is the nominal *exactly* --
    the property that keeps an undrifted joint controller bit-identical to
    the link-only controller."""
    nom = AGX_XAVIER.eff_flops
    f = 0.3
    assert compute_bucket(nom, nom, f) == 0
    assert compute_band_flops(0, nom, f) == nom  # exact, not approximate
    # a straggler collapsing to ~1/3 speed lands several bands down, and the
    # representative stays within the band's width of the estimate
    b = compute_bucket(0.3 * nom, nom, f)
    assert b < 0
    rep = compute_band_flops(b, nom, f)
    assert rep / (0.3 * nom) < (1 + f) and (0.3 * nom) / rep < (1 + f)
    # monotone in the estimate
    ests = [nom * (0.25 * 1.2**i) for i in range(12)]
    assert [compute_bucket(e, nom, f) for e in ests] == sorted(
        compute_bucket(e, nom, f) for e in ests
    )
    # small jitter inside the band does not move the key
    assert compute_bucket(nom * 1.05, nom, f) == 0
    # exact mode + errors
    assert compute_bucket(1.23e12, nom, 0.0) == 1.23e12
    assert compute_band_flops(1.23e12, nom, 0.0) == 1.23e12
    with pytest.raises(ValueError):
        compute_bucket(0.0, nom, f)
    with pytest.raises(ValueError):
        compute_bucket(nom, 0.0, f)


def test_topology_fingerprint_excludes_eff_flops():
    """eff_flops moved out of the fingerprint and into the bucketed key space:
    two same-named clusters at different compute levels share a fingerprint
    (their keys differ through the compute band anchors instead)."""
    a = small_topology()
    b = CollabTopology(
        host="e0",
        secondaries=("a", "b"),
        platforms={es: AGX_XAVIER.scaled(0.5) for es in ("e0", "a", "b")},
        default_link=Link(NOMINAL),
    )
    assert topology_fingerprint(a) == topology_fingerprint(b)
    ctl_a = ReplanController(NET, a, FAST)
    ctl_b = ReplanController(NET, b, FAST)
    assert ctl_a._bucket_key() != ctl_b._bucket_key()  # anchors differ


# -- estimator ----------------------------------------------------------------


def test_estimator_seeds_from_topology_and_ewma():
    topo = small_topology()
    est = LinkRateEstimator.from_topology(topo, alpha=0.4)
    assert est.rate("e0", "b") == NOMINAL
    assert set(est.rates()) == set(topo.collab_pairs())
    # one observed transfer at 30 Mbps moves the estimate 40% of the way
    est.observe("e0", "b", 125_000.0, 8 * 125_000.0 / 30e6)
    assert est.rate("e0", "b") == pytest.approx(0.6 * NOMINAL + 0.4 * 30e6)
    assert est.rate("b", "e0") == NOMINAL  # directions are independent
    with pytest.raises(ValueError):
        est.observe("e0", "b", 0.0, 1.0)
    with pytest.raises(ValueError):
        LinkRateEstimator({}, alpha=0.0)


def test_compute_estimator_seeds_from_topology_and_ewma():
    topo = small_topology()
    est = ComputeRateEstimator.from_topology(topo, alpha=0.4)
    nom = AGX_XAVIER.eff_flops
    # seeds cover the host too (host zones are compute the optimum reads)
    assert set(est.rates()) == {"e0", "a", "b"}
    assert est.rate("b") == nom
    # one timed chunk at 1/3 the nominal rate moves the estimate 40% over
    est.observe("b", 1e9, 1e9 / (nom / 3.0))
    assert est.rate("b") == pytest.approx(0.6 * nom + 0.4 * nom / 3.0)
    assert est.rate("a") == nom  # per-ES independence
    with pytest.raises(ValueError):
        est.observe("b", -1.0, 1.0)
    with pytest.raises(ValueError):
        est.observe("b", 1e9, 0.0)
    with pytest.raises(ValueError):
        ComputeRateEstimator({}, alpha=1.5)


# -- plan cache ---------------------------------------------------------------


def test_plan_cache_lru_and_stats():
    cache = PlanCache(capacity=2)
    a, b, c = object(), object(), object()
    assert cache.get("a") is None  # miss
    cache.put("a", a)
    cache.put("b", b)
    assert cache.get("a") is a  # hit; refreshes LRU position
    cache.put("c", c)  # evicts b (least recently used)
    assert cache.get("b") is None
    assert cache.get("a") is a and cache.get("c") is c
    assert cache.evictions == 1 and len(cache) == 2
    assert cache.hits == 3 and cache.misses == 2
    assert cache.hit_rate == pytest.approx(0.6)
    assert cache.entries() == [a, c]
    with pytest.raises(ValueError):
        PlanCache(capacity=0)


# -- hysteresis (step() only: no optimisation happens) ------------------------


def test_hysteresis_debounces_single_epoch_excursions():
    ctl = ReplanController(
        NET, small_topology(), ReplanConfig(alpha=1.0, hysteresis=3, bucket_frac=0.5)
    )
    # one deviant epoch, then back to nominal: never adopted
    observe_rate(ctl, 30e6)
    assert ctl.step() is False
    observe_rate(ctl, NOMINAL)
    assert ctl.step() is False
    assert ctl.replans == 0
    # the deviant bucket must persist `hysteresis` consecutive epochs
    observe_rate(ctl, 30e6)
    assert ctl.step() is False
    observe_rate(ctl, 30e6)
    assert ctl.step() is False
    observe_rate(ctl, 30e6)
    assert ctl.step() is True
    assert ctl.replans == 1
    # in-bucket jitter never triggers (29 vs 30 Mbps share a 50% band)
    observe_rate(ctl, 29e6)
    assert ctl.step() is False


def test_hysteresis_leq_one_adopts_immediately():
    ctl = ReplanController(
        NET, small_topology(), ReplanConfig(alpha=1.0, hysteresis=0, bucket_frac=0.5)
    )
    observe_rate(ctl, 30e6)
    assert ctl.step() is True and ctl.replans == 1


def test_hysteresis_not_starved_by_monotone_drift():
    """A channel crossing one bucket band per epoch still replans: the counter
    tracks consecutive epochs *outside* the active bands, not epochs on one
    candidate key."""
    ctl = ReplanController(
        NET, small_topology(), ReplanConfig(alpha=1.0, hysteresis=2, bucket_frac=0.5)
    )
    observe_rate(ctl, 60e6)  # new band vs the 120 Mbps nominal
    assert ctl.step() is False
    observe_rate(ctl, 30e6)  # yet another band: still counts toward adoption
    assert ctl.step() is True
    assert ctl.replans == 1


# -- controller + cache -------------------------------------------------------


def test_controller_cache_hits_on_bucket_revisit():
    ctl = ReplanController(NET, small_topology(), FAST)
    p_nominal = ctl.plan_for_epoch()  # miss 1: nominal bucket
    observe_rate(ctl, 30e6)
    p_slow = ctl.plan_for_epoch()  # miss 2: degraded bucket
    observe_rate(ctl, NOMINAL)
    assert ctl.plan_for_epoch() is p_nominal  # hit: nominal bucket cached
    observe_rate(ctl, 30e6)
    assert ctl.plan_for_epoch() is p_slow  # hit: degraded bucket cached
    assert ctl.cache.misses == 2 and ctl.cache.hits == 2
    assert ctl.optimizer_calls == 2 and ctl.replans == 3


def test_shared_cache_across_controllers():
    cache = PlanCache()
    a = ReplanController(NET, small_topology(), FAST, cache=cache)
    a.plan_for_epoch()
    b = ReplanController(NET, small_topology(), FAST, cache=cache)
    b.plan_for_epoch()  # identical fingerprint + bucket: shared entry
    assert cache.misses == 1 and cache.hits == 1
    assert b.optimizer_calls == 0
    # a different optimiser config must NOT collide on the shared cache
    # (bucket indices are grid-relative, so bucket_frac keys the fingerprint)
    c = ReplanController(
        NET, small_topology(),
        ReplanConfig(use_simulator=False, alpha=1.0, hysteresis=1, bucket_frac=0.3),
        cache=cache,
    )
    c.plan_for_epoch()
    assert c.optimizer_calls == 1 and cache.misses == 2


def test_serving_reads_do_not_skew_epoch_telemetry():
    """plan/makespan/predicted_latency peek at the cache: hit/miss counters
    keep measuring plan requests per control epoch only."""
    ctl = ReplanController(NET, small_topology(), FAST)
    ctl.plan_for_epoch()  # 1 miss (fills the cache)
    hits, misses = ctl.cache.hits, ctl.cache.misses
    _ = ctl.plan
    _ = ctl.makespan
    _ = ctl.predicted_latency(4)
    ctl.observe_batch_latency(4, 0.01)
    assert (ctl.cache.hits, ctl.cache.misses) == (hits, misses)
    ctl.plan_for_epoch()  # the epoch path still counts
    assert ctl.cache.hits == hits + 1


# -- joint compute+link adaptation --------------------------------------------


def test_undrifted_compute_matches_link_only_controller():
    """With compute at the nominals, the joint controller's estimated topology
    and served plans are identical to the link-only (adapt_compute=False)
    controller's -- including across link-bucket switches.  This pins the
    anchored-band property: compute adaptivity costs nothing until a
    straggler actually appears."""
    topo = small_topology()
    joint = ReplanController(NET, topo, FAST)
    link_only = ReplanController(
        NET, topo, dataclasses.replace(FAST, adapt_compute=False)
    )
    est = joint.estimated_topology()
    for es in topo.es_names:  # band-0 representatives are the nominals, exactly
        assert est.platform_of(es).eff_flops == topo.platform_of(es).eff_flops
    for rate in (NOMINAL, 30e6, NOMINAL, 60e6):
        observe_rate(joint, rate)
        observe_rate(link_only, rate)
        assert joint.plan_for_epoch().parts == link_only.plan_for_epoch().parts
    assert joint.replans == link_only.replans >= 2


def test_compute_straggler_triggers_replan_and_cache_revisit():
    """A straggling secondary switches the compute bucket, re-plans away from
    it, and revisiting the nominal operating point is a cache hit."""
    topo = fast_link_topology()
    ctl = ReplanController(NET, topo, FAST)
    nom = AGX_XAVIER.eff_flops
    p0 = ctl.plan_for_epoch()  # miss 1: nominal compute
    observe_compute(ctl, "b", 0.3 * nom)
    assert ctl.step() is True  # compute band moved -> adopted (hysteresis 1)
    p_slow = ctl.current().plan  # miss 2: straggler bucket
    rows = lambda p: sum(pt.out["b"].rows for pt in p.parts)
    assert rows(p_slow) < rows(p0)  # rows migrated off the straggler
    observe_compute(ctl, "b", nom)  # straggler recovers
    assert ctl.plan_for_epoch() is p0  # hit: nominal bucket cached
    observe_compute(ctl, "b", 0.3 * nom)
    assert ctl.plan_for_epoch() is p_slow  # hit: straggler bucket cached
    assert ctl.cache.misses == 2 and ctl.cache.hits == 2
    assert ctl.optimizer_calls == 2 and ctl.replans == 3


def test_adapt_compute_false_freezes_compute_estimates():
    topo = fast_link_topology()
    ctl = ReplanController(NET, topo, dataclasses.replace(FAST, adapt_compute=False))
    nom = AGX_XAVIER.eff_flops
    key0 = ctl._bucket_key()
    observe_compute(ctl, "b", 0.2 * nom)  # dropped: link-only baseline
    assert ctl.compute_estimator.rate("b") == nom
    assert ctl._bucket_key() == key0
    assert ctl.step() is False
    # mis-wired feeders still fail loudly even when frozen
    with pytest.raises(ValueError):
        ctl.observe_compute("nope", 1e9, 1.0)
    with pytest.raises(ValueError):
        ctl.observe_compute("b", -1e9, 1.0)


def test_shared_hysteresis_debounces_compute_excursions():
    """One deviant compute epoch never thrashes the plan; the shared counter
    also mixes link and compute deviations (epochs-away-from-active)."""
    topo = fast_link_topology()
    ctl = ReplanController(
        NET, topo, ReplanConfig(alpha=1.0, hysteresis=2, bucket_frac=0.5)
    )
    nom = AGX_XAVIER.eff_flops
    observe_compute(ctl, "b", 0.3 * nom)
    assert ctl.step() is False  # first epoch outside
    observe_compute(ctl, "b", nom)
    assert ctl.step() is False  # back inside: counter resets
    assert ctl.replans == 0
    observe_compute(ctl, "b", 0.3 * nom)
    assert ctl.step() is False
    observe_rate(ctl, 10e6)  # second epoch outside -- via the *link* axis
    assert ctl.step() is True  # shared hysteresis: mixed deviations adopt
    assert ctl.replans == 1


# -- satellite coverage: eviction fallthrough + calibration clamp -------------


def test_active_result_falls_through_to_current_after_eviction():
    """_active_result peeks at the cache; if the active entry was evicted it
    must fall through to current() (re-optimising) rather than serving
    nothing."""
    cache = PlanCache(capacity=1)
    ctl = ReplanController(NET, small_topology(), FAST, cache=cache)
    p0 = ctl.plan_for_epoch()  # fills the single slot
    calls = ctl.optimizer_calls
    cache.put(("someone", "else"), object())  # evicts the active entry
    assert cache.peek((ctl._fingerprint, ctl._active)) is None
    plan = ctl.plan  # out-of-epoch read: peek misses -> current() -> re-optimise
    assert plan.parts == p0.parts  # same operating point, same plan
    assert ctl.optimizer_calls == calls + 1
    assert ctl.plan is plan  # re-cached: subsequent reads peek again


def test_observe_batch_latency_clamp_bounds():
    """The measured/predicted ratio is clamped to [0.1, 10] before the EWMA,
    so one outlier batch cannot poison admission control in either
    direction; non-measurements (zero elapsed, empty batch) are ignored."""
    ctl = ReplanController(NET, small_topology(), FAST)  # alpha = 1.0
    base = ctl._raw_predicted_latency(2)
    ctl.observe_batch_latency(2, 1e6)  # absurdly slow measurement
    assert ctl.stats()["calibration"] == 10.0  # clamped at the upper bound
    ctl.observe_batch_latency(2, base * 1e-9)  # absurdly fast measurement
    assert ctl.stats()["calibration"] == 0.1  # clamped at the lower bound
    ctl.observe_batch_latency(2, 3.0 * base)  # in-range ratio passes through
    assert ctl.stats()["calibration"] == pytest.approx(3.0)
    for bad in ((2, 0.0), (2, -1.0), (0, 1.0)):
        before = ctl.stats()["calibration"]
        ctl.observe_batch_latency(*bad)
        assert ctl.stats()["calibration"] == before


# -- trace + replay -----------------------------------------------------------


def test_gauss_markov_trace_deterministic_and_bounded():
    tr = GaussMarkovTrace(lo=30e6, hi=120e6, corr=0.9, sigma_frac=0.2, seed=4)
    rates = tr.rates(100)
    assert rates == tr.rates(100)  # seeded determinism
    assert all(30e6 <= r <= 120e6 for r in rates)
    assert len(set(rates)) > 10  # actually moves
    frozen = GaussMarkovTrace(lo=1.0, hi=2.0, corr=1.0, sigma_frac=0.0, start=1.5)
    assert frozen.rates(5) == [1.5] * 5
    with pytest.raises(ValueError):
        GaussMarkovTrace(lo=2.0, hi=1.0)
    with pytest.raises(ValueError):
        GaussMarkovTrace(lo=0.0, hi=1.0, corr=1.5)


def test_replay_validates_traces():
    topo = small_topology()
    planner = StaticPlanner(optimize_static(NET, topo, FAST).plan)
    with pytest.raises(ValueError, match="at least one"):
        replay_rate_trace(NET, topo, planner, {}, n_tasks=1)
    short = {("e0", "b"): [NOMINAL] * 3, ("b", "e0"): [NOMINAL] * 3}
    with pytest.raises(ValueError, match="shortest trace"):
        replay_rate_trace(NET, topo, planner, short, n_epochs=5, n_tasks=1)
    assert len(replay_rate_trace(NET, topo, planner, short, n_tasks=1)) == 3


def test_replay_trace_validates_compute_traces():
    topo = fast_link_topology()
    planner = StaticPlanner(optimize_static(NET, topo, FAST).plan)
    with pytest.raises(ValueError, match="at least one"):
        replay_trace(NET, topo, planner, n_tasks=1)
    with pytest.raises(ValueError, match="not an ES"):
        replay_trace(
            NET, topo, planner, compute_rates={"ghost": [1e12] * 3}, n_tasks=1
        )
    with pytest.raises(ValueError, match="positive"):
        replay_trace(NET, topo, planner, compute_rates={"b": [0.0] * 3}, n_tasks=1)
    # n_epochs bounded by the shortest trace across BOTH kinds
    nom = AGX_XAVIER.eff_flops
    with pytest.raises(ValueError, match="shortest trace"):
        replay_trace(
            NET, topo, planner,
            link_rates={("e0", "b"): [50e9] * 9},
            compute_rates={"b": [nom] * 3},
            n_epochs=5, n_tasks=1,
        )
    run = replay_trace(
        NET, topo, planner, compute_rates={"b": [nom, 0.5 * nom, nom]}, n_tasks=1
    )
    assert len(run) == 3
    assert run[1]["compute_rates"] == {"b": 0.5 * nom}
    # a 2x-slower b with no re-plan shows up in the true (DES) makespan
    assert run[1]["makespan"] > run[0]["makespan"]


def test_replay_joint_adaptation_beats_static_on_straggler():
    """b's compute collapses to 0.3x at epoch 3 and stays: the joint
    controller re-balances rows off the straggler after the hysteresis lag
    and wins on mean makespan; the link-only controller (adapt_compute=False)
    serves the static plan throughout -- on this fixed channel it never even
    replans."""
    topo = fast_link_topology()
    nom = AGX_XAVIER.eff_flops
    n = 14
    trace = {"b": [nom] * 3 + [0.3 * nom] * (n - 3)}
    cfg = ReplanConfig(n_tasks=2, hysteresis=1, alpha=1.0)
    static = replay_trace(
        NET, topo, StaticPlanner(optimize_static(NET, topo, cfg).plan),
        compute_rates=trace, n_tasks=2,
    )
    link_only_ctl = ReplanController(
        NET, topo, dataclasses.replace(cfg, adapt_compute=False)
    )
    link_only = replay_trace(NET, topo, link_only_ctl, compute_rates=trace, n_tasks=2)
    joint_ctl = ReplanController(NET, topo, cfg)
    joint = replay_trace(NET, topo, joint_ctl, compute_rates=trace, n_tasks=2)
    mean = lambda run: sum(r["makespan"] for r in run) / len(run)
    assert link_only_ctl.replans == 0  # compute-blind: nothing to react to
    assert mean(link_only) == pytest.approx(mean(static))
    assert joint_ctl.replans >= 1
    assert mean(joint) < 0.95 * mean(link_only)
    assert joint[-1]["makespan"] < link_only[-1]["makespan"]


def test_compute_replanned_plan_is_lossless():
    """A plan re-optimised for a straggler bucket is still an exact row
    partition: executing it reproduces the single-device forward."""
    ctl = ReplanController(NET, fast_link_topology(), FAST)
    observe_compute(ctl, "b", 0.3 * AGX_XAVIER.eff_flops)
    plan = ctl.plan_for_epoch()
    assert sum(pt.out["b"].rows for pt in plan.parts) < sum(
        pt.out["a"].rows for pt in plan.parts
    )
    params = vgg.init(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, CFG.img_res, CFG.img_res, 3))
    ref = vgg.features(params, CFG, x)
    out = run_plan(plan, params["features"], vgg.apply_layer, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_replay_adaptive_beats_static_on_sustained_collapse():
    """b's link collapses 120 -> 30 Mbps at epoch 4 and stays: the adaptive
    planner re-balances after the hysteresis lag and wins on mean makespan;
    the DES objective keeps this a ground-truth comparison."""
    topo = small_topology()
    n = 16
    trace = [NOMINAL] * 4 + [30e6] * (n - 4)
    link_rates = {("e0", "b"): trace, ("b", "e0"): trace}
    cfg = ReplanConfig(n_tasks=2, hysteresis=1)
    static = replay_rate_trace(
        NET, topo, StaticPlanner(optimize_static(NET, topo, cfg).plan),
        link_rates, n_tasks=2,
    )
    ctl = ReplanController(NET, topo, cfg)
    adaptive = replay_rate_trace(NET, topo, ctl, link_rates, n_tasks=2)
    mean = lambda run: sum(r["makespan"] for r in run) / len(run)
    assert mean(adaptive) < 0.99 * mean(static)
    assert ctl.replans >= 1
    assert "planner_stats" in adaptive[-1]
    # once re-balanced, the adaptive plan wins in the degraded regime
    assert adaptive[-1]["makespan"] < static[-1]["makespan"]


# -- serving integration ------------------------------------------------------


def test_plan_aware_batch_size_tracks_channel():
    ctl = ReplanController(NET, small_topology(), FAST)
    channel = OffloadChannel(rate_bps=100e6, sigma_s=1e-3)
    generous = plan_aware_batch_size(ctl, 2.0, channel, target=0.999, max_batch=8)
    tight = plan_aware_batch_size(ctl, 0.045, channel, target=0.999, max_batch=8)
    assert 0 <= tight <= generous <= 8
    assert generous == 8  # 2 s of slack admits everything on the small net
    # an infeasible deadline sheds (0) instead of admitting a doomed batch
    assert tight == 0
    mid = plan_aware_batch_size(ctl, 0.06, channel, target=0.999, max_batch=8)
    assert mid >= 1
    # a measured collapse raises the predicted makespan, shrinking admission
    observe_rate(ctl, 5e6)
    ctl.step()
    degraded = plan_aware_batch_size(ctl, 0.06, channel, target=0.999, max_batch=8)
    assert degraded <= mid


def test_observe_batch_latency_calibrates_predictions():
    ctl = ReplanController(NET, small_topology(), FAST)
    before = ctl.predicted_latency(2)
    # measured latency 3x the raw prediction -> calibration moves up (alpha=1)
    ctl.observe_batch_latency(2, 3.0 * before)
    after = ctl.predicted_latency(2)
    assert after == pytest.approx(3.0 * before, rel=1e-6)
    # clamped against outliers
    ctl.observe_batch_latency(2, 1e6)
    assert ctl.stats()["calibration"] <= 10.0


# -- losslessness of replanned plans ------------------------------------------


def test_replanned_plan_is_lossless():
    ctl = ReplanController(NET, small_topology(), FAST)
    observe_rate(ctl, 30e6)
    plan = ctl.plan_for_epoch()
    params = vgg.init(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, CFG.img_res, CFG.img_res, 3))
    ref = vgg.features(params, CFG, x)
    out = run_plan(plan, params["features"], vgg.apply_layer, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# -- latency-memo eviction on bucket switch -----------------------------------


def test_latency_memo_evicted_on_bucket_switch():
    """The serving-path memo holds only the active operating point's rows
    after a bucket switch: without eviction it grows one latency table per
    key ever visited over a long-running controller."""
    ctl = ReplanController(NET, small_topology(), FAST)
    ctl.latency_table(4)
    assert len(ctl._latency_memo) == 4
    first_key = ctl._active
    observe_rate(ctl, 30e6)
    assert ctl.step()  # adopted: the old key's rows must be gone
    assert all(k[1] == ctl._active for k in ctl._latency_memo)
    assert not any(k[1] == first_key for k in ctl._latency_memo)
    ctl.latency_table(4)
    assert len(ctl._latency_memo) == 4
    # hit semantics intact: repricing the active point costs no new entries
    ctl.latency_table(4)
    assert len(ctl._latency_memo) == 4
    # returning to the first bucket reprices it fresh (correctness over
    # reuse: the memo is a per-operating-point working set, not a store)
    observe_rate(ctl, NOMINAL)
    assert ctl.step()
    ctl.latency_table(2)
    assert len(ctl._latency_memo) == 2
    assert all(k[1] == ctl._active for k in ctl._latency_memo)
