"""Discrete-event simulator for collaborative-ES schedules (ground truth).

The paper's closed-form recursions (eqs. 16-20, 22-23) approximate a job/message
DAG executed by FIFO compute resources (the ESs) and full-duplex point-to-point
links.  This module simulates that DAG exactly:

* every compute chunk and every message is a :class:`Job` bound to a resource,
* a resource serves its jobs in submission order (list scheduling -- the paper's
  schedule is static), a job starts when its resource is free *and* all
  dependencies have finished,
* the makespan of the sink job is the inference time.

The HALP DAG itself is laid out by ``repro.core.events.build_halp_dag`` -- the
same plan-walk the closed form prices -- so the two engines cross-validate on
identical structure (``tests/test_schedule.py``).  Arbitrary
:class:`~repro.core.topology.CollabTopology` instances are supported: N
secondaries, per-ES platforms, per-link rates.  The same engine doubles as the
straggler / fault-injection harness of the runtime (``repro.runtime.fault``):
per-resource slowdown factors model node degradation at scale.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from .events import build_halp_dag, init_bytes, resolve_halp_setup
from .nets import ConvNetGeom
from .partition import HALPPlan, plan_even
from .reliability import IMAGE_BYTES
from .topology import CollabTopology, Link, Platform

__all__ = [
    "Sim",
    "Job",
    "BatchRun",
    "simulate_halp",
    "simulate_modnn",
    "enhanced_modnn_delay",
    "GaussMarkovTrace",
    "replay_trace",
    "replay_rate_trace",
    "serve_latency_table",
]


@dataclass
class Job:
    jid: int
    name: str
    resource: str
    duration: float
    deps: tuple[int, ...]
    start: float = 0.0
    finish: float = 0.0


@dataclass
class BatchRun:
    """Result of :meth:`Sim.run_batch`: one DES sweep over B duration vectors.

    ``makespan[b]`` is candidate ``b``'s makespan; ``finish[j, b]`` the finish
    time of job ``j`` under candidate ``b`` (``finish_of`` mirrors
    :meth:`Sim.finish_of` for per-task head lookups)."""

    makespan: np.ndarray  # [B]
    finish: np.ndarray  # [J, B]

    def finish_of(self, jid: int) -> np.ndarray:
        return self.finish[jid]


class Sim:
    """Static list-scheduling simulator over FIFO resources."""

    def __init__(self) -> None:
        self.jobs: list[Job] = []
        self.slowdown: dict[str, float] = {}
        self._batch_deps: list[list[int]] | None = None

    def add(self, name: str, resource: str, duration: float, deps=()) -> int:
        jid = len(self.jobs)
        deps = tuple(d for d in deps if d is not None)
        self.jobs.append(Job(jid, name, resource, max(0.0, duration), deps))
        self._batch_deps = None
        return jid

    def run(self) -> float:
        """Resolve start/finish for all jobs; returns the makespan."""
        free: dict[str, float] = {}
        # Jobs on a resource are served in submission order (FIFO). Because a
        # later job on the same resource cannot start before an earlier one, a
        # single forward pass in submission order is exact as long as deps only
        # point backwards -- which the builders guarantee.
        for job in self.jobs:
            for d in job.deps:
                if d >= job.jid:
                    raise ValueError(f"forward dependency {d} -> {job.jid}")
            ready = max((self.jobs[d].finish for d in job.deps), default=0.0)
            start = max(ready, free.get(job.resource, 0.0))
            dur = job.duration * self.slowdown.get(job.resource, 1.0)
            job.start = start
            job.finish = start + dur
            free[job.resource] = job.finish
        return max((j.finish for j in self.jobs), default=0.0)

    def finish_of(self, jid: int) -> float:
        return self.jobs[jid].finish

    def _merged_deps(self) -> list[list[int]]:
        """Per-job dependency lists with the FIFO resource edge folded in.

        A job's start is ``max(dep finishes, previous job on its resource)``;
        adding the resource predecessor as an explicit edge turns the forward
        pass into a pure longest-path sweep, which is what lets ``run_batch``
        drop the per-candidate ``free`` bookkeeping.  Cached until the next
        :meth:`add`."""
        if self._batch_deps is None:
            merged: list[list[int]] = []
            last_on: dict[str, int] = {}
            for job in self.jobs:
                deps = list(job.deps)
                prev = last_on.get(job.resource)
                if prev is not None:
                    deps.append(prev)
                merged.append(deps)
                last_on[job.resource] = job.jid
            self._batch_deps = merged
        return self._batch_deps

    def run_batch(self, durations: np.ndarray | None = None) -> BatchRun:
        """Vectorized DES: score B duration vectors in one forward sweep.

        ``durations`` is a ``[B, J]`` (or ``[J]``) array of per-job durations
        -- typically produced by a :class:`~repro.core.events.DagTemplate` for
        B candidate plans sharing this Sim's job/message structure; ``None``
        scores the jobs' own durations (B = 1).  Per-resource ``slowdown``
        factors apply exactly as in :meth:`run`.

        Bit-consistent with the scalar :meth:`run`: the same float operations
        run in the same dependency order, only batched across candidates
        (``tests/test_conformance.py`` pins float *equality*, not closeness).
        Unlike :meth:`run` this does not mutate job start/finish state."""
        n_jobs = len(self.jobs)
        if durations is None:
            durations = np.array([[job.duration for job in self.jobs]])
        else:
            durations = np.asarray(durations, dtype=np.float64)
            if durations.ndim == 1:
                durations = durations[None, :]
            if durations.shape[1] != n_jobs:
                raise ValueError(
                    f"durations have {durations.shape[1]} jobs, sim has {n_jobs}"
                )
        if self.slowdown:
            factors = np.array(
                [self.slowdown.get(job.resource, 1.0) for job in self.jobs]
            )
            durations = durations * factors
        n_batch = durations.shape[0]
        merged = self._merged_deps()
        if n_batch * n_jobs <= 4096:
            # Small batches: plain-float forward passes beat per-job numpy
            # dispatch overhead.  max/+ on Python floats and on float64 arrays
            # are the same IEEE-754 operations, so this path is bit-identical
            # to the vectorized one below.
            finish = np.empty((n_jobs, n_batch))
            for b in range(n_batch):
                dur_b = durations[b].tolist()
                fin: list[float] = [0.0] * n_jobs
                for j, deps in enumerate(merged):
                    ready = 0.0
                    for d in deps:
                        fd = fin[d]
                        if fd > ready:
                            ready = fd
                    fin[j] = ready + dur_b[j]
                finish[:, b] = fin
            makespan = finish.max(axis=0) if n_jobs else np.zeros(n_batch)
            return BatchRun(makespan=makespan, finish=finish)
        dur = np.ascontiguousarray(durations.T)  # [J, B]
        finish = np.empty((n_jobs, n_batch))
        maximum = np.maximum
        add = np.add
        for j, deps in enumerate(merged):
            row = finish[j]
            if not deps:
                row[:] = dur[j]
            elif len(deps) == 1:
                add(finish[deps[0]], dur[j], out=row)
            else:
                maximum(finish[deps[0]], finish[deps[1]], out=row)
                for d in deps[2:]:
                    maximum(row, finish[d], out=row)
                row += dur[j]
        makespan = finish.max(axis=0) if n_jobs else np.zeros(n_batch)
        return BatchRun(makespan=makespan, finish=finish)


def _chunk_time(net: ConvNetGeom, platform: Platform, i: int, rows: int) -> float:
    width = net.sizes()[i + 1]
    return platform.compute_time(net.layers[i].flops_per_out_row(width) * rows)


def simulate_halp(
    net: ConvNetGeom,
    platform: Platform | None = None,
    link: Link | None = None,
    overlap_rows: int | None = None,
    n_tasks: int = 1,
    host_platform: Platform | None = None,
    slowdown: dict[str, float] | None = None,
    topology: CollabTopology | None = None,
    ratios: Sequence[float] | None = None,
    plan: HALPPlan | None = None,
) -> dict:
    """Simulate HALP for ``n_tasks`` tasks on N*n_tasks secondaries + one host.

    Two calling conventions:

    * paper-style: ``simulate_halp(net, platform, link, ...)`` -- the symmetric
      two-secondary triple with one shared platform/link (``host_platform``
      optionally differing), exactly the paper's setting;
    * topology-style: ``simulate_halp(net, topology=topo, ...)`` -- arbitrary
      N-way heterogeneous clusters with per-ES platforms and per-link rates;
      ``ratios`` overrides the capacity-weighted segment split and ``plan``
      overrides the plan entirely.

    Resources: the host ES name (host compute), ``{slot}^{t}`` (secondary
    compute), ``link:a->b`` (directed point-to-point links; Ethernet full
    duplex).  The host serves the per-task zones in task order within each
    layer (paper §IV.B).  ``slowdown`` maps resource name -> multiplicative
    factor (straggler injection).
    """
    topology, plan = resolve_halp_setup(
        net, platform, link, overlap_rows, topology, ratios, plan, host_platform
    )
    plans = [plan for _ in range(n_tasks)]
    sim = Sim()
    if slowdown:
        sim.slowdown.update(slowdown)
    heads = build_halp_dag(sim, plans, topology)
    makespan = sim.run()
    finishes = [sim.finish_of(h) for h in heads]
    return dict(
        total=makespan,
        per_task_finish=finishes,
        avg_delay=sum(finishes) / len(finishes),
        sim=sim,
        plan=plan,
    )


def simulate_modnn(
    net: ConvNetGeom,
    platform: Platform,
    link: Link,
    n_workers: int,
    slowdown: dict[str, float] | None = None,
) -> dict:
    """Conventional layer-wise parallelization (MoDNN): synchronous halo
    exchange through the host after every CL; host NIC serialises transfers."""
    plan = plan_even(net, n_workers)
    names = plan.es_names
    host = names[0]
    sim = Sim()
    if slowdown:
        sim.slowdown.update(slowdown)
    n_layers = len(net.layers)
    last: dict[str, int | None] = {}
    gate: dict[str, int | None] = {}  # message that worker w waits on before layer i

    for w in names[1:]:
        gate[w] = sim.add(
            f"int.{w}", f"link:{host}->{w}", link.comm_time(init_bytes(plan, w))
        )
    gate[host] = None

    for i in range(n_layers):
        chunks = {}
        for w in names:
            rows = plan.parts[i].out[w].rows
            chunks[w] = sim.add(
                f"cmp.{w}.g{i}", w, _chunk_time(net, platform, i, rows), [last.get(w), gate.get(w)]
            )
        # synchronous exchange: gathers serialise on host RX, scatters on host TX,
        # and every worker waits for its scatter before the next layer.
        gathers = []
        for w in names:
            for v in names:
                if v == w:
                    continue
                nbytes = plan.message_bytes(i, w, v)
                if nbytes:
                    gathers.append(
                        sim.add(
                            f"gather.{w}->{v}.g{i}",
                            f"{host}:rx",
                            link.comm_time(nbytes),
                            [chunks[w]],
                        )
                    )
        barrier = sim.add(f"merge.g{i}", host, 0.0, [chunks[host]] + gathers)
        for w in names:
            need = sum(
                plan.message_bytes(i, v, w) for v in names if v != w
            )
            if w == host or need == 0.0:
                gate[w] = barrier
            else:
                gate[w] = sim.add(
                    f"scatter.->{w}.g{i}", f"{host}:tx", link.comm_time(need), [barrier]
                )
        last = dict(chunks)

    final = []
    for w in names[1:]:
        nbytes = net.feature_bytes(n_layers - 1, plan.parts[-1].out[w].rows)
        final.append(
            sim.add(f"final.{w}", f"{host}:rx", link.comm_time(nbytes), [last[w]])
        )
    head = sim.add("head", host, platform.compute_time(net.head_flops), final + [last[host]])
    total = sim.run()
    return dict(total=total, sim=sim)


@dataclass(frozen=True)
class GaussMarkovTrace:
    """Bounded Gauss-Markov (AR(1), mean-reverting) rate process.

    The standard mobility/channel fading model the paper's §V.D time-variant
    channel implies: each step reverts ``1 - corr`` of the way to ``mean`` and
    adds Gaussian innovation, clipped to [lo, hi].  ``corr=0`` is i.i.d.
    sampling; ``corr=1`` removes the mean reversion (a clipped random walk --
    combine with ``sigma_frac=0`` to freeze the channel).  Deterministic given
    ``seed`` -- every policy in a comparison replays the identical trace.

    The process is agnostic to what the rate measures: link traces are in
    bits/s, and the same class models per-ES *compute* drift (effective
    FLOP/s of a thermally-throttled or co-loaded straggler ES -- the DistrEdge
    / arXiv 2211.13778 testbed observation) for :func:`replay_trace`'s
    ``compute_rates``."""

    lo: float
    hi: float
    corr: float = 0.9
    sigma_frac: float = 0.15  # innovation std as a fraction of (hi - lo)
    mean: float | None = None  # reversion level; default: the band midpoint
    start: float | None = None  # initial rate; default: the reversion level
    seed: int = 0

    def __post_init__(self) -> None:
        if not self.lo < self.hi:
            raise ValueError(f"need lo < hi, got [{self.lo}, {self.hi}]")
        if not 0.0 <= self.corr <= 1.0:
            raise ValueError(f"corr must be in [0, 1], got {self.corr}")

    def rates(self, n: int) -> list[float]:
        """The first ``n`` rates of the process."""
        rng = random.Random(self.seed)
        mean = (self.lo + self.hi) / 2.0 if self.mean is None else self.mean
        sigma = self.sigma_frac * (self.hi - self.lo)
        x = mean if self.start is None else self.start
        out = []
        for _ in range(n):
            out.append(x)
            x = mean + self.corr * (x - mean) + rng.gauss(0.0, sigma)
            x = min(self.hi, max(self.lo, x))
        return out


def _compute_slowdown(
    topology: CollabTopology, true_flops: Mapping[str, float], n_tasks: int
) -> dict[str, float]:
    """Per-resource slowdown factors realising true per-ES compute rates.

    The DES prices compute jobs from the topology's *nominal* ``eff_flops``;
    an ES whose true effective rate is ``r`` therefore runs every one of its
    jobs ``nominal / r`` times slower.  Host jobs run on the host ES name;
    secondary jobs run on the per-task clone resources ``{es}^{t}`` laid by
    :func:`~repro.core.events.build_halp_dag`, so the factor is applied to
    all ``n_tasks`` clones of a straggling secondary."""
    slow: dict[str, float] = {}
    for es, rate in true_flops.items():
        if es not in topology.platforms:
            raise ValueError(f"compute trace names {es!r}, not an ES of the topology")
        if rate <= 0:
            raise ValueError(f"compute rate for {es!r} must be positive, got {rate}")
        factor = topology.platform_of(es).eff_flops / rate
        if es == topology.host:
            slow[es] = factor
        else:
            for t in range(n_tasks):
                slow[f"{es}^{t}"] = factor
    return slow


def replay_trace(
    net: ConvNetGeom,
    topology: CollabTopology,
    planner,
    link_rates: Mapping[tuple[str, str], Sequence[float]] | None = None,
    compute_rates: Mapping[str, Sequence[float]] | None = None,
    n_epochs: int | None = None,
    n_tasks: int = 4,
    probe_bytes: float = float(IMAGE_BYTES),  # one image per link probe
    probe_flops: float = 1e9,  # one timed chunk per compute probe
) -> list[dict]:
    """Replay time-variant conditions through the DES, one plan per epoch.

    ``link_rates`` maps directed ES pairs to per-epoch true link rates and
    ``compute_rates`` maps ES names to per-epoch true effective FLOP/s (e.g.
    :meth:`GaussMarkovTrace.rates` for either); anything not listed stays at
    ``topology``'s nominal.  Per epoch the driver (a) asks ``planner`` for a
    plan -- the planner only ever sees *past* observations, so adaptive
    policies react with a one-epoch lag, exactly like a real serving loop --
    (b) simulates the makespan under the epoch's **true** rates: true link
    rates rebuild the topology's links, true compute rates map onto the DES
    through per-resource :attr:`Sim.slowdown` factors
    (``nominal_eff / true_eff`` on the ES's compute resources -- the same
    injection path the straggler/fault harness uses), and plans are
    geometry-only, so a stale plan is merely slow, never wrong -- and (c)
    feeds one observed ``probe_bytes`` transfer per traced link and one timed
    ``probe_flops`` execution per traced ES back to the planner.

    ``planner`` implements the replan protocol (``plan_for_epoch()`` +
    ``observe_transfer(src, dst, nbytes, elapsed_s)`` + -- when compute is
    traced -- ``observe_compute(es, flops, elapsed_s)``):
    :class:`~repro.core.replan.StaticPlanner` for the paper's offline
    baseline, :class:`~repro.core.replan.ReplanController` for the adaptive
    policies (link-only via ``ReplanConfig(adapt_compute=False)``, joint by
    default).  Returns one record per epoch with the true rates (``rates``
    for links, ``compute_rates`` per ES), the simulated makespan, the plan
    served, and -- for planners exposing ``stats()`` -- a snapshot of the
    planner's counters *after* serving the epoch (so cache hit rates over
    any window can be recovered from the records)."""
    link_rates = dict(link_rates or {})
    compute_rates = dict(compute_rates or {})
    if not link_rates and not compute_rates:
        raise ValueError(
            "need at least one trace: link_rates (directed pair -> rates) "
            "and/or compute_rates (ES -> effective FLOP/s)"
        )
    all_traces = list(link_rates.values()) + list(compute_rates.values())
    max_epochs = min(len(trace) for trace in all_traces)
    if n_epochs is None:
        n_epochs = max_epochs
    elif n_epochs > max_epochs:
        raise ValueError(
            f"n_epochs={n_epochs} exceeds the shortest trace ({max_epochs} "
            f"entries); extend the traces or drop n_epochs"
        )
    results = []
    for epoch in range(n_epochs):
        plan = planner.plan_for_epoch()
        rates = {pair: trace[epoch] for pair, trace in link_rates.items()}
        flops_now = {es: trace[epoch] for es, trace in compute_rates.items()}
        true_topology = topology.with_links({p: Link(r) for p, r in rates.items()})
        sim = simulate_halp(
            net,
            topology=true_topology,
            n_tasks=n_tasks,
            plan=plan,
            slowdown=_compute_slowdown(topology, flops_now, n_tasks) or None,
        )
        for (src, dst), rate in rates.items():
            planner.observe_transfer(src, dst, probe_bytes, 8.0 * probe_bytes / rate)
        for es, rate in flops_now.items():
            planner.observe_compute(es, probe_flops, probe_flops / rate)
        record = dict(
            epoch=epoch, rates=rates, compute_rates=flops_now,
            makespan=sim["total"], plan=plan,
        )
        if hasattr(planner, "stats"):
            record["planner_stats"] = planner.stats()
        results.append(record)
    return results


def replay_rate_trace(
    net: ConvNetGeom,
    topology: CollabTopology,
    planner,
    link_rates: Mapping[tuple[str, str], Sequence[float]],
    n_epochs: int | None = None,
    n_tasks: int = 4,
    probe_bytes: float = float(IMAGE_BYTES),
) -> list[dict]:
    """Link-only replay (superseded by :func:`replay_trace`, kept as the
    established entry point): equivalent to ``replay_trace`` with no compute
    traces, so compute stays at the nominals throughout."""
    if not link_rates:
        raise ValueError("link_rates must map at least one directed pair to a trace")
    return replay_trace(
        net, topology, planner,
        link_rates=link_rates, n_epochs=n_epochs, n_tasks=n_tasks,
        probe_bytes=probe_bytes,
    )


def enhanced_modnn_delay(
    net: ConvNetGeom, platform: Platform, link: Link, n_es: int = 9, n_tasks: int = 4
) -> dict:
    """Paper §V.C 'Enhanced MoDNN': first (n_tasks - 1) tasks run in parallel on
    disjoint groups of n_es // (n_tasks - 1) ESs, the last on all n_es.

    Returns T^E1, T^E2, the average per-task delay T^E1 + T^E2/n_tasks and
    throughput n_tasks / (T^E1 + T^E2)."""
    group = n_es // (n_tasks - 1)
    t_e1 = simulate_modnn(net, platform, link, group)["total"]
    t_e2 = simulate_modnn(net, platform, link, n_es)["total"]
    return dict(
        T_E1=t_e1,
        T_E2=t_e2,
        avg_delay=t_e1 + t_e2 / n_tasks,
        throughput=n_tasks / (t_e1 + t_e2),
    )


def serve_latency_table(
    net: ConvNetGeom,
    platform: Platform | None = None,
    link: Link | None = None,
    overlap_rows: int | None = None,
    topology: CollabTopology | None = None,
    ratios: Sequence[float] | None = None,
    plan: HALPPlan | None = None,
    host_platform: Platform | None = None,
    max_batch: int = 8,
    scenarios: Sequence[Mapping[str, float]] | None = None,
) -> np.ndarray:
    """DES-priced service-time model for the serving loop: ``table[s, b-1]``
    is the makespan of a ``b``-task batch under scenario ``s``.

    This is the request-stream replay's pricing step: for each batch width
    ``b`` the full HALP DAG for ``b`` concurrent tasks is laid once
    (:func:`~repro.core.events.build_halp_dag`) and every scenario's duration
    vector sweeps through :meth:`Sim.run_batch` in one vectorized pass, so a
    whole (scenario x batch-size) grid prices in milliseconds.  The serving
    loop (``repro.runtime.serve.serve_trace``) then replays millions of
    requests against the table without touching the DES again -- the
    batched-DES division of labour that makes a simulated million-request day
    cost seconds.

    ``scenarios`` is a sequence of per-resource slowdown mappings (one table
    row each; ``None`` means the single nominal scenario).  Keys are either
    raw DES resource names (``"link:e0->a"``, ``"a^0"``) or bare ES names,
    which expand exactly like the straggler harness: the host applies to its
    own compute resource, a secondary to all ``b`` per-task clones
    ``{es}^{t}``.  Calling conventions for the cluster match
    :func:`simulate_halp` (paper-style ``(platform, link)`` or
    ``topology=``)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    topology, plan = resolve_halp_setup(
        net, platform, link, overlap_rows, topology, ratios, plan, host_platform
    )
    scen = list(scenarios) if scenarios is not None else [{}]
    if not scen:
        raise ValueError("scenarios must be non-empty when given")
    table = np.empty((len(scen), max_batch))
    for b in range(1, max_batch + 1):
        sim = Sim()
        build_halp_dag(sim, [plan] * b, topology)
        base = np.array([job.duration for job in sim.jobs])
        resources = [job.resource for job in sim.jobs]
        durations = np.empty((len(scen), len(sim.jobs)))
        for s, mapping in enumerate(scen):
            slow: dict[str, float] = {}
            for key, factor in mapping.items():
                if factor <= 0:
                    raise ValueError(f"slowdown for {key!r} must be positive, got {factor}")
                if key in topology.platforms and key != topology.host:
                    for t in range(b):
                        slow[f"{key}^{t}"] = factor
                elif key.startswith("link:") and "->" in key and "^" not in key:
                    # bare directed pair: expand the secondary end to its
                    # per-task clone resources, like the compute case above
                    src, dst = key[len("link:") :].split("->", 1)
                    for t in range(b):
                        src_r = src if src == topology.host else f"{src}^{t}"
                        dst_r = dst if dst == topology.host else f"{dst}^{t}"
                        slow[f"link:{src_r}->{dst_r}"] = factor
                else:
                    slow[key] = factor
            durations[s] = base * np.array([slow.get(r, 1.0) for r in resources])
        table[:, b - 1] = sim.run_batch(durations).makespan
    return table
