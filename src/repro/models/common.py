"""Parameter-tree conventions and initializers shared by all model definitions.

Models are pure functions over nested-dict parameter pytrees:

    cfg    = SomeConfig(...)                  # dataclass in repro.configs
    params = init(jax.random.PRNGKey(0), cfg) # pytree of jnp arrays
    y      = apply(params, x, cfg)            # pure function

No Module system -- pjit/shard_map distribute pure functions, and parameter
sharding rules (repro.parallel.sharding) pattern-match on pytree paths.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of arrays

DEFAULT_DTYPE = jnp.float32


def keygen(key: jax.Array) -> Iterator[jax.Array]:
    """Infinite stream of fresh PRNG keys."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


def trunc_normal(key, shape, std=0.02, dtype=DEFAULT_DTYPE):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def lecun_normal(key, shape, fan_in, dtype=DEFAULT_DTYPE):
    std = math.sqrt(1.0 / max(1, fan_in))
    return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def he_normal(key, shape, fan_in, dtype=DEFAULT_DTYPE):
    std = math.sqrt(2.0 / max(1, fan_in))
    return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_params(key, d_in, d_out, bias=True, std=None, dtype=DEFAULT_DTYPE) -> Params:
    kw, kb = jax.random.split(key)
    w = (
        trunc_normal(kw, (d_in, d_out), std, dtype)
        if std is not None
        else lecun_normal(kw, (d_in, d_out), d_in, dtype)
    )
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def conv_params(key, k, c_in, c_out, bias=True, groups=1, dtype=DEFAULT_DTYPE) -> Params:
    """HWIO conv kernel; ``groups == c_in`` (with c_in==c_out) => depthwise."""
    kh, kw_ = (k, k) if isinstance(k, int) else k
    fan_in = kh * kw_ * (c_in // groups)
    p = {"w": he_normal(key, (kh, kw_, c_in // groups, c_out), fan_in, dtype)}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def norm_params(dim, bias=True, dtype=DEFAULT_DTYPE) -> Params:
    p = {"scale": jnp.ones((dim,), dtype)}
    if bias:
        p["b"] = jnp.zeros((dim,), dtype)
    return p


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def stack_layers(init_one: Callable[[jax.Array], Params], key, n: int) -> Params:
    """Initialise ``n`` identical layers as one stacked pytree (leading axis n).

    Stacked layouts let transformer stacks run under ``jax.lax.scan``, which
    keeps the HLO (and XLA compile time) independent of depth -- essential for
    the 512-device dry-runs of the 61-layer DeepSeek config.
    """
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)
