"""Serving engine: dynamic batching with the paper's deadline model.

Requests arrive with a deadline; the batcher groups them (max batch / max
delay), the engine runs the jitted forward (vision / VGG-HALP / LM decode),
and per-request completion is checked against deadlines.  Batch-size selection
uses the paper's reliability machinery: given the measured per-batch latency
model and an offload-time distribution, ``choose_batch_size`` picks the
largest batch whose P(deadline met) clears the target -- Table III turned into
a scheduling policy (the beyond-paper integration of §V-D).

The engine closes the measurement loop of the online re-planner
(``repro.core.replan``) on both axes: every executed batch's (size, latency)
is handed to an optional observer -- typically
``ReplanController.observe_batch_latency`` -- and per-ES chunk timings
reported through ``observe_es_time`` feed ``ReplanController.observe_compute``
(the compute side of joint compute+link adaptation: a straggling secondary is
attributed, not just absorbed into the scalar calibration).
``plan_aware_batch_size`` re-runs the admission policy against the *current*
plan's predicted makespan, so the admitted batch tracks channel and compute
drift alike; a return of ``0`` means shed -- no batch size can meet the
deadline at the target reliability.
The same loop drives per-task placement
(``repro.core.placement.PlacementController``): a bucket switch re-places
every task over the shared ES pool, and the controller's
``predicted_latency`` prices a candidate batch by simulating its tasks on
that pool -- including the queueing of tasks that wrap onto the same
secondaries -- so admission follows both the channel and the placement.

High-throughput serving under production traffic
------------------------------------------------

:func:`serve_trace` scales the same policy to production traffic: an
event-driven loop in *virtual time* (a :class:`VirtualClock` is the only
clock; no wall sleeps anywhere) that consumes a
:class:`~repro.runtime.traffic.Trace` of millions of seeded arrivals
(Poisson / diurnal / flash-crowd), forms batches asynchronously from
per-class EDF queues (launch when full or when the head request has waited
``max_delay_s``), admits each candidate batch with the per-class
generalisation of ``choose_batch_size`` (largest EDF prefix whose every
member clears its class's §V.D reliability target -- one precomputed
slack-threshold comparison per request, see
:func:`~repro.core.reliability.required_slack`), **sheds** head requests
that cannot clear their target even alone in a batch (the PR-5 "0 means
shed" semantics, now per request), and prices every executed batch from a
DES-produced latency table
(:func:`~repro.core.simulator.serve_latency_table`, i.e. the batched
``Sim.run_batch`` is the ground-truth service-time model).  A
million-request day simulates in seconds: isolated underload stretches are
served through a vectorized fast path that is bit-identical to the scalar
event loop (``ServeLoopConfig(fast_path=False)`` pins the equivalence in
``tests/test_serve.py``).
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.reliability import OffloadChannel, required_slack, service_reliability

__all__ = [
    "Request",
    "ServeConfig",
    "BatchingEngine",
    "VirtualClock",
    "ServeLoopConfig",
    "ServedTrace",
    "serve_trace",
    "choose_batch_size",
    "plan_aware_batch_size",
]


@dataclass(order=True)
class Request:
    deadline: float
    rid: int = field(compare=False)
    payload: Any = field(compare=False, default=None)
    arrival: float = field(compare=False, default=0.0)
    done: float | None = field(compare=False, default=None)
    result: Any = field(compare=False, default=None)  # per-request model output


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_delay_s: float = 0.002
    pad_to_max: bool = True  # keep one compiled shape (prod: bucketed shapes)

    def __post_init__(self) -> None:
        # choose_batch_size/plan_aware_batch_size return 0 to mean "shed"; an
        # engine built with max_batch=0 would busy-loop taking empty batches
        # forever, so refuse loudly -- the caller must handle shedding itself
        if self.max_batch < 1:
            raise ValueError(
                f"max_batch must be >= 1, got {self.max_batch}; an admission "
                f"result of 0 means shed/reject -- do not build an engine on it"
            )


class VirtualClock:
    """Deterministic manual clock: serving in simulated time, never wall time.

    Drop-in for the ``clock`` callable of :class:`BatchingEngine` (calling the
    instance returns the current virtual time), and the only notion of time
    :func:`serve_trace` has.  Tests advance it explicitly, so deadline and
    latency assertions are exact and instantaneous -- no ``time.sleep`` and no
    flakiness from scheduler jitter."""

    def __init__(self, start_s: float = 0.0):
        self._now = float(start_s)

    def __call__(self) -> float:
        return self._now

    def now(self) -> float:
        return self._now

    def advance(self, dt_s: float) -> float:
        """Move time forward by ``dt_s`` (>= 0); returns the new time."""
        if dt_s < 0:
            raise ValueError(f"virtual time cannot go backwards (dt={dt_s})")
        self._now += dt_s
        return self._now

    def advance_to(self, t_s: float) -> float:
        """Jump to absolute time ``t_s`` (>= now); returns the new time."""
        if t_s < self._now:
            raise ValueError(
                f"virtual time cannot go backwards ({t_s} < {self._now})"
            )
        self._now = float(t_s)
        return self._now


class BatchingEngine:
    """Deadline-aware dynamic batcher around a jitted ``fn(batch_payloads)``."""

    def __init__(
        self,
        fn: Callable,
        cfg: ServeConfig,
        clock: Callable = time.monotonic,
        observer: Callable[[int, float], None] | None = None,
        es_observer: Callable[[str, float, float], None] | None = None,
    ):
        self.fn = fn
        self.cfg = cfg
        self.clock = clock
        # called with (batch_size, elapsed_s) after every executed batch; wire
        # ReplanController.observe_batch_latency here to close the replan loop
        self.observer = observer
        # called with (es_name, flops, elapsed_s) for every reported per-ES
        # chunk execution; wire ReplanController.observe_compute here to close
        # the compute side of the joint replan loop (see observe_es_time)
        self.es_observer = es_observer
        self.queue: list[Request] = []  # deadline-ordered heap (EDF)
        self.completed: list[Request] = []
        self._rid = 0
        # arrival-ordered view of the queue for O(1) oldest-pending lookup in
        # ready(): submit() appends (the clock is monotone, so FIFO = arrival
        # order) and _take_batch() records taken rids for lazy head pruning
        self._fifo: deque[Request] = deque()
        self._taken: set[int] = set()

    def submit(self, payload, deadline_s: float) -> int:
        self._rid += 1
        req = Request(
            deadline=self.clock() + deadline_s,
            rid=self._rid,
            payload=payload,
            arrival=self.clock(),
        )
        heapq.heappush(self.queue, req)
        self._fifo.append(req)
        return self._rid

    def observe_es_time(self, es: str, flops: float, elapsed_s: float) -> None:
        """Per-ES timing hook: the distributed executor reports one measured
        compute chunk (which ES ran it, its FLOP count, wall-clock) as it
        completes.  Forwards to ``es_observer`` -- typically
        ``ReplanController.observe_compute`` -- so a straggling secondary
        moves the controller's compute estimate and, past the hysteresis,
        triggers a joint re-plan/re-placement.  The whole-batch ``observer``
        only calibrates a scalar latency factor; this hook is what attributes
        slowness to a *specific* ES."""
        if self.es_observer is not None:
            self.es_observer(es, flops, elapsed_s)

    def _take_batch(self) -> list[Request]:
        batch = []
        while self.queue and len(batch) < self.cfg.max_batch:
            req = heapq.heappop(self.queue)
            self._taken.add(req.rid)
            batch.append(req)
        return batch

    def _oldest_pending(self) -> Request:
        """The earliest-arrived queued request, O(1) amortised: prune taken
        requests off the FIFO head lazily (each request is appended and
        discarded exactly once over its lifetime, vs. the old O(n) min() scan
        of the whole heap on every poll)."""
        fifo = self._fifo
        while fifo[0].rid in self._taken:
            self._taken.discard(fifo.popleft().rid)
        return fifo[0]

    def ready(self) -> bool:
        """Whether a batch should launch *now*: the queue holds a full
        ``max_batch``, or the oldest queued request has already waited
        ``max_delay_s``.  This is the asynchronous batch-formation rule --
        formation is a pure decision on (queue, clock), decoupled from the
        execution that :meth:`step` performs -- and the same rule
        :func:`serve_trace` applies in virtual time at trace scale."""
        if not self.queue:
            return False
        if len(self.queue) >= self.cfg.max_batch:
            return True
        return self.clock() - self._oldest_pending().arrival >= self.cfg.max_delay_s

    def poll(self) -> list[Request]:
        """Run one batch iff :meth:`ready`; otherwise an empty no-op.  The
        driver loop's entry point: call on every arrival/timer tick, and
        batches form when full or when the head request's delay budget is
        spent -- never on a wall-clock sleep."""
        return self.step() if self.ready() else []

    def step(self) -> list[Request]:
        """Run one batch (earliest-deadline-first).  Returns completed reqs."""
        batch = self._take_batch()
        if not batch:
            return []
        payloads = [r.payload for r in batch]
        n = len(payloads)
        if self.cfg.pad_to_max and n < self.cfg.max_batch:
            payloads = payloads + [payloads[-1]] * (self.cfg.max_batch - n)
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *payloads)
        t0 = self.clock()
        out = self.fn(stacked)
        jax.block_until_ready(out)
        now = self.clock()
        if self.observer is not None:
            # report the *executed* width: with pad_to_max the forward ran
            # len(payloads) wide regardless of how many real requests were in
            # it, and that is the size the measured latency corresponds to
            # (anything else would skew a replan controller's calibration)
            self.observer(len(payloads), now - t0)
        for i, r in enumerate(batch):
            r.done = now
            r.result = jax.tree_util.tree_map(lambda x: x[i], out)
            self.completed.append(r)
        return batch

    def run_until_drained(self, max_batches: int = 10_000):
        b = 0
        while self.queue and b < max_batches:
            self.step()
            b += 1
        return self.stats()

    def stats(self) -> dict:
        met = [r for r in self.completed if r.done is not None and r.done <= r.deadline]
        lat = [r.done - r.arrival for r in self.completed if r.done is not None]
        return {
            "completed": len(self.completed),
            "deadline_met_frac": len(met) / max(1, len(self.completed)),
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
        }


def choose_batch_size(
    per_batch_latency_s: Callable[[int], float],
    deadline_s: float,
    channel: OffloadChannel,
    target: float = 0.99999,
    max_batch: int = 64,
) -> int:
    """Largest batch size whose service reliability clears ``target``
    (paper §V-D as an admission-control policy).

    Returns ``0`` when *no* batch size clears the target: the request stream
    cannot meet its deadline at the required reliability on the current plan
    and channel, so the caller must shed/reject (or renegotiate the deadline)
    rather than admit doomed work.  The historical behaviour of falling back
    to ``1`` silently admitted requests that were already known to miss."""
    best = 0
    for b in range(1, max_batch + 1):
        t_inf = per_batch_latency_s(b)
        rel = service_reliability(channel, t_inf, deadline_s)
        if rel >= target:
            best = b
    return best


def plan_aware_batch_size(
    controller,
    deadline_s: float,
    channel: OffloadChannel,
    target: float = 0.99999,
    max_batch: int = 64,
) -> int:
    """``choose_batch_size`` against the *current* plan's predicted makespan.

    ``controller`` is a :class:`~repro.core.replan.ReplanController` or a
    :class:`~repro.core.placement.PlacementController`: its
    ``predicted_latency(b)`` prices a b-task batch on whatever the controller
    is serving right now -- the closed form on the shared plan, or the
    shared-pool DES over the per-task placement (calibrated by measured batch
    latencies either way) -- so after a re-plan or re-placement the admitted
    batch size follows without re-measuring a latency curve.

    Like :func:`choose_batch_size`, returns ``0`` when even a single-task
    batch cannot clear ``target`` under the current plan's predicted
    makespan: the caller sheds until the controller re-plans onto a faster
    operating point (or the channel recovers)."""
    return choose_batch_size(
        controller.predicted_latency, deadline_s, channel, target=target, max_batch=max_batch
    )


# ---------------------------------------------------------------------------
# Trace-scale serving: the event-driven loop over production traffic models
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServeLoopConfig:
    """Knobs of :func:`serve_trace` (all times virtual; nothing sleeps).

    ``admission=True`` applies the per-class §V.D policy (shed requests that
    cannot clear their class target even at batch size 1, cap the batch at
    the largest EDF prefix where *every* member clears its target);
    ``admission=False`` is the accept-everything baseline the flash-crowd
    benchmark compares against.  ``channel`` adds the offloading leg:
    per-executed-batch time ``max(0, mu + sigma * noise)`` with seeded
    Gaussian noise (``None`` serves pure inference).  ``segment_bounds``
    split the horizon into piecewise-stationary segments, one latency-table
    row each (e.g. hourly channel states of a diurnal day).  ``fast_path``
    toggles the vectorized underload path -- results are bit-identical
    either way (pinned in ``tests/test_serve.py``); it exists only so the
    property harness can run the scalar reference."""

    max_batch: int = 8
    max_delay_s: float = 0.002
    admission: bool = True
    channel: OffloadChannel | None = None
    seed: int = 0  # offload-noise stream (one draw per executed batch)
    segment_bounds: tuple[float, ...] = ()
    fast_path: bool = True

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {self.max_delay_s}")
        if list(self.segment_bounds) != sorted(self.segment_bounds):
            raise ValueError(f"segment_bounds must be sorted, got {self.segment_bounds}")


@dataclass
class ServedTrace:
    """Outcome of one :func:`serve_trace` run, per request and per batch.

    ``fin[i]`` is request ``i``'s completion time (NaN if shed), ``shed[i]``
    whether admission dropped it, ``met[i]`` whether it finished within its
    absolute deadline (shed requests never meet).  ``batch_size_counts[b]``
    counts executed batches of width ``b`` -- the shed accounting plus this
    histogram is the loop's entire observable state, so determinism is one
    array comparison."""

    trace: Any  # repro.runtime.traffic.Trace
    fin: np.ndarray
    shed: np.ndarray
    met: np.ndarray
    n_batches: int
    batch_size_counts: np.ndarray

    def latency(self) -> np.ndarray:
        """Per-request sojourn time (completion - arrival; NaN if shed)."""
        return self.fin - self.trace.arrival

    @staticmethod
    def _stats_of(lat: np.ndarray, met: np.ndarray, shed: np.ndarray) -> dict:
        n = int(met.size)
        served = ~shed
        lat_served = lat[served]
        completed = int(served.sum())

        def pct(q: float) -> float:
            return float(np.percentile(lat_served, q)) if completed else 0.0

        return dict(
            n=n,
            completed=completed,
            shed=int(n - completed),
            shed_rate=float(shed.mean()) if n else 0.0,
            # met/total: a shed request is a missed request (the strictest
            # reading -- shedding only ever "helps" by protecting others)
            deadline_met_frac=float(met.mean()) if n else 0.0,
            met_of_admitted=float(met[served].mean()) if completed else 0.0,
            mean_latency_s=float(lat_served.mean()) if completed else 0.0,
            p50_latency_s=pct(50.0),
            p99_latency_s=pct(99.0),
            p999_latency_s=pct(99.9),
        )

    def stats(self) -> dict:
        """Whole-trace tail/shed metrics (plus batch-shape telemetry)."""
        out = self._stats_of(self.latency(), self.met, self.shed)
        out["n_batches"] = int(self.n_batches)
        out["mean_batch"] = (
            float(self.batch_size_counts @ np.arange(self.batch_size_counts.size))
            / self.n_batches
            if self.n_batches
            else 0.0
        )
        return out

    def class_stats(self) -> dict[str, dict]:
        """Per-deadline-class metrics, keyed by class name."""
        lat = self.latency()
        out = {}
        for ci, cls in enumerate(self.trace.classes):
            sel = self.trace.cls == ci
            out[cls.name] = self._stats_of(lat[sel], self.met[sel], self.shed[sel])
        return out


def _off_margins(cfg: ServeLoopConfig, classes) -> tuple[float, float, np.ndarray]:
    """(mu, sigma, per-class admission margin) of the offloading leg.

    ``margin[c] = mu + sigma * probit(target_c)`` is the batch-size-free part
    of :func:`~repro.core.reliability.required_slack`; a request with
    remaining slack ``s`` clears its class target in a batch of size ``b``
    iff ``s - margin[c] >= lat(b)``, turning every admission decision into
    one subtraction and one comparison."""
    from ..core.reliability import probit

    if cfg.channel is None:
        return 0.0, 0.0, np.zeros(len(classes))
    mu, sigma = cfg.channel.mu_s, cfg.channel.sigma_s
    if sigma <= 0:
        return mu, 0.0, np.full(len(classes), mu)
    return mu, sigma, np.array([mu + sigma * probit(c.target) for c in classes])


def serve_trace(trace, lat_table: np.ndarray, cfg: ServeLoopConfig = ServeLoopConfig()) -> ServedTrace:
    """Serve one arrival :class:`~repro.runtime.traffic.Trace` end-to-end in
    virtual time; returns the per-request/per-batch :class:`ServedTrace`.

    ``lat_table`` is the DES-produced service-time model: ``lat_table[s, b-1]``
    is the makespan of a ``b``-task batch during segment ``s``
    (:func:`~repro.core.simulator.serve_latency_table`, or a controller's
    ``latency_table``); a 1-D table means one stationary segment.

    The loop (documented here once, both code paths implement it exactly):

    1. **Formation** -- let ``first`` be the earliest pending arrival and
       ``t0 = max(server_free, first)``.  If a full ``max_batch`` is already
       pending at ``t0``, the batch forms at ``t0``; otherwise it forms at
       the *earlier* of the ``max_batch``-th pending arrival (the queue
       fills during the wait -- the launch-when-full rule of
       :meth:`BatchingEngine.ready`) and
       ``max(server_free, first + max_delay_s)`` (the head's delay budget).
    2. **EDF** -- up to ``max_batch`` arrived requests are taken earliest
       absolute deadline first (ties by arrival order), merged across the
       per-class queues.
    3. **Admission** (``cfg.admission``) -- doomed heads (slack below the
       class's :func:`~repro.core.reliability.required_slack` even at
       ``b=1`` -- exactly ``choose_batch_size(...) == 0``) are shed; the
       batch is then the largest EDF prefix in which every member clears its
       own class target at the prefix's width.
    4. **Execution** -- the batch occupies the server for
       ``offload + lat_table[segment, b-1]`` starting at formation time;
       completions are checked against absolute deadlines.

    Underload stretches (every pending queue empty, arrivals further apart
    than ``max_delay_s``) execute through a vectorized fast path that commits
    whole runs of singleton batches at once -- bit-identical to the scalar
    loop (same formation times, same shed decisions, same noise stream), so
    a million-request day costs seconds instead of a million Python
    iterations."""
    classes = trace.classes
    n = len(trace)
    n_cls = len(classes)
    lat_tab = np.asarray(lat_table, dtype=np.float64)
    if lat_tab.ndim == 1:
        lat_tab = lat_tab[None, :]
    if lat_tab.shape[0] != len(cfg.segment_bounds) + 1:
        raise ValueError(
            f"lat_table has {lat_tab.shape[0]} segment rows for "
            f"{len(cfg.segment_bounds)} bounds (need bounds+1)"
        )
    if lat_tab.shape[1] < cfg.max_batch:
        raise ValueError(
            f"lat_table covers batches 1..{lat_tab.shape[1]} but max_batch is "
            f"{cfg.max_batch}"
        )
    if np.any(lat_tab <= 0) or not np.all(np.isfinite(lat_tab)):
        raise ValueError("lat_table entries must be positive and finite")

    fin = np.full(n, np.nan)
    shed = np.zeros(n, dtype=bool)
    met = np.zeros(n, dtype=bool)
    counts = np.zeros(cfg.max_batch + 1, dtype=np.int64)
    out = ServedTrace(
        trace=trace, fin=fin, shed=shed, met=met, n_batches=0, batch_size_counts=counts
    )
    if n == 0:
        return out

    arrival = trace.arrival
    cls_of = trace.cls
    rel_dl = np.array([c.deadline_s for c in classes])
    deadline = arrival + rel_dl[cls_of]
    mu, sigma, off_margin = _off_margins(cfg, classes)
    # one noise value per *executed batch*, indexed by batch counter (not a
    # sequential stream), so the vectorized fast path and the scalar loop
    # consume identical values no matter how runs are cut
    pool = (
        np.random.default_rng(cfg.seed).standard_normal(n) if sigma > 0 else None
    )
    bounds = np.asarray(cfg.segment_bounds, dtype=np.float64)
    segmented = bounds.size > 0
    max_batch, max_delay = cfg.max_batch, cfg.max_delay_s

    # per-class EDF queues: within a class the absolute deadline order IS the
    # arrival order (one relative deadline per class), so each queue is its
    # sorted arrival array plus a head pointer, and EDF across classes only
    # ever compares the heads.  Consumption is therefore a per-class prefix.
    ix_c = [np.flatnonzero(cls_of == c) for c in range(n_cls)]
    arr_c = [arrival[ix] for ix in ix_c]
    dl_c = [deadline[ix] for ix in ix_c]
    n_c = [len(ix) for ix in ix_c]
    head = [0] * n_cls

    consumed = np.zeros(n, dtype=bool)  # global order, for the fast-path scan
    g = 0  # earliest globally-unconsumed request
    window = 1024  # fast-path probe size, adapts to the last committed run
    free = 0.0  # server next-free time
    remaining = n
    n_batches = 0
    lat1_col = lat_tab[:, 0]

    while remaining > 0:
        while consumed[g]:
            g += 1
        first_t = arrival[g]

        # ---- fast path: chains of singleton batches ------------------------
        # Hypothesis: the next requests each form and execute as their own
        # width-1 batch (the dominant regime away from bursts).  Everything
        # per-element (formation floor, segment, b=1 latency, offload noise,
        # admission threshold) precomputes vectorized; the only inherently
        # sequential part -- form_k = max(fin_{k-1}, a_k + delay) -- runs as
        # a tight validate-and-commit loop over plain floats using the SAME
        # expressions as the scalar step, so the committed prefix is
        # bit-identical to what the scalar loop would produce.  The first
        # element that would really batch up, shed, or cross a segment
        # boundary mid-wait breaks the chain and falls through.
        if cfg.fast_path and max_batch > 1:
            end = min(n, g + window)
            holes = consumed[g:end]
            if holes.any():
                end = g + int(np.argmax(holes))
            m = end - g
            if m > 0:
                a = arrival[g:end]
                run_cls = cls_of[g:end]
                x = a + max_delay  # formation floor of a solo head
                if segmented:
                    seg = np.searchsorted(bounds, x, side="right")
                    lat1 = lat1_col[seg]
                    # chain stays valid while form_k < the segment's upper edge
                    seg_hi = np.append(bounds, np.inf)[seg].tolist()
                else:
                    lat1 = np.full(m, lat1_col[0])
                    seg_hi = None
                t_off = np.full(m, mu)
                if pool is not None:
                    # all-singleton prefix => pool slots are consecutive
                    t_off = np.maximum(0.0, mu + sigma * pool[n_batches : n_batches + m])
                nxt = np.empty(m)
                nxt[:-1] = a[1:]
                # window/hole edge: the next *global* arrival is <= the next
                # pending one, so using it only ever invalidates, never admits
                nxt[-1] = arrival[end] if end < n else np.inf
                dls = deadline[g:end].tolist()
                offm = off_margin[run_cls].tolist()
                xs, nxts, lat1s, t_offs = x.tolist(), nxt.tolist(), lat1.tolist(), t_off.tolist()
                fr = free
                fins: list[float] = []
                r = 0
                admit = cfg.admission
                while r < m:
                    xk = xs[r]
                    form_k = xk if fr <= xk else fr
                    if nxts[r] <= form_k:  # a second request would join
                        break
                    if seg_hi is not None and form_k >= seg_hi[r]:
                        break  # queued past the segment edge; re-price scalar
                    # same expression order as the scalar margins, bit-exact
                    if admit and dls[r] - form_k - offm[r] < lat1s[r]:
                        break  # head is doomed; scalar step sheds it
                    fr = form_k + t_offs[r] + lat1s[r]
                    fins.append(fr)
                    r += 1
                if r > 0:
                    sl = slice(g, g + r)
                    fin_run = np.array(fins)
                    consumed[sl] = True
                    fin[sl] = fin_run
                    met[sl] = fin_run <= deadline[sl]
                    counts[1] += r
                    n_batches += r
                    for c, cnt in zip(*np.unique(run_cls[:r], return_counts=True)):
                        head[c] += int(cnt)
                    free = fr
                    remaining -= r
                    window = min(4096, max(64, 2 * r))
                    continue
                window = 64  # scalar territory ahead; probe small next time

        # ---- scalar event step: one batch formation -----------------------
        t0 = max(free, first_t)
        pending0 = 0
        pos = [0] * n_cls
        for c in range(n_cls):
            pos[c] = int(np.searchsorted(arr_c[c], t0, side="right"))
            pending0 += pos[c] - head[c]
        if pending0 >= max_batch:
            form_t = t0
        else:
            # the queue may fill to max_batch *during* the head's delay wait:
            # the batch then forms at the max_batch-th pending arrival
            # (BatchingEngine.ready's launch-when-full rule), not at the
            # budget.  The fill time is the `need`-th arrival after t0 --
            # gather at most `need` upcoming arrivals per class and merge.
            need = max_batch - pending0
            upcoming = np.concatenate(
                [arr_c[c][pos[c] : pos[c] + need] for c in range(n_cls)]
            )
            if len(upcoming) >= need:
                upcoming.sort()
                t_full = float(upcoming[need - 1])
            else:
                t_full = np.inf
            form_t = min(t_full, max(free, first_t + max_delay))
        ends = [int(np.searchsorted(arr_c[c], form_t, side="right")) for c in range(n_cls)]

        # EDF merge across the class heads (ties by global arrival index)
        cand_gi: list[int] = []
        cand_cls: list[int] = []
        cand_dl: list[float] = []
        cur = list(head)
        while len(cand_gi) < max_batch:
            best = -1
            best_key = (np.inf, n)
            for c in range(n_cls):
                if cur[c] < ends[c]:
                    key = (dl_c[c][cur[c]], int(ix_c[c][cur[c]]))
                    if key < best_key:
                        best, best_key = c, key
            if best < 0:
                break
            cand_gi.append(int(ix_c[best][cur[best]]))
            cand_cls.append(best)
            cand_dl.append(float(dl_c[best][cur[best]]))
            cur[best] += 1

        seg = int(np.searchsorted(bounds, form_t, side="right")) if segmented else 0
        lat_row = lat_tab[seg]
        margins = [
            cand_dl[i] - form_t - off_margin[cand_cls[i]] for i in range(len(cand_gi))
        ]
        start = 0
        if cfg.admission:
            # shed doomed heads: choose_batch_size(...) == 0 for them, and
            # their slack only shrinks from here -- drop them now so the
            # server's capacity goes to requests that can still make it
            while start < len(cand_gi) and margins[start] < lat_row[0]:
                gi = cand_gi[start]
                consumed[gi] = True
                shed[gi] = True
                head[cand_cls[start]] += 1
                remaining -= 1
                start += 1
            b_star = 0
            pref_min = np.inf
            for b in range(1, len(cand_gi) - start + 1):
                pref_min = min(pref_min, margins[start + b - 1])
                if pref_min >= lat_row[b - 1]:
                    b_star = b
        else:
            b_star = len(cand_gi)

        if b_star > 0:
            t_off = mu
            if pool is not None:
                t_off = max(0.0, mu + sigma * pool[n_batches])
            fin_t = form_t + t_off + lat_row[b_star - 1]
            for i in range(start, start + b_star):
                gi = cand_gi[i]
                consumed[gi] = True
                fin[gi] = fin_t
                met[gi] = fin_t <= cand_dl[i]
                head[cand_cls[i]] += 1
            remaining -= b_star
            free = fin_t
            n_batches += 1
            counts[b_star] += 1

    out.n_batches = n_batches
    return out
