"""Pipeline parallelism (GPipe-style) over a mesh axis, shard_map-native.

Each device along ``axis_name`` owns one *stage* (a slice of the layer stack);
microbatches stream through the ring with ``ppermute`` between stages.  With S
stages and M microbatches the schedule runs S + M - 1 ticks; bubble fraction
(S-1)/(S+M-1).  Designed for the ``pod`` axis of the production mesh (2
stages across pods, DP×TP inside each pod) where inter-pod links are the
scarce resource — the paper's principle again: only the thin activation
boundary crosses the slow link, and it crosses while both pods compute.

The implementation is deliberately simple (no interleaving/looping schedule);
it composes with the TP/FSDP rules because the stage body is an arbitrary
jax function.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)


def pipeline_apply(stage_params, x_micro, stage_fn, axis_name: str):
    """Run a pipelined forward inside shard_map.

    stage_params: this device's stage parameters (already sharded by stage).
    x_micro: [M, mb, ...] microbatches (same replica on every stage device;
             only stage 0 consumes them, the rest arrive by ppermute).
    stage_fn(params, x) -> y: one stage's computation (mb-level).
    Returns [M, mb, ...] outputs valid on the LAST stage device (other stages
    return garbage of the right shape; the caller selects stage S-1).
    """
    s = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    m = x_micro.shape[0]
    ticks = s + m - 1
    perm = [(i, (i + 1) % s) for i in range(s)]

    def tick(carry, t):
        buf, outs = carry  # buf: the activation currently entering this stage
        # stage 0 injects microbatch t (if any); others use the ppermuted buf
        inject = jnp.where(t < m, t, m - 1)
        x_in = jnp.where(idx == 0, x_micro[inject], buf)
        y = stage_fn(stage_params, x_in)
        # pass activations forward around the ring
        buf_next = lax.ppermute(y, axis_name, perm)
        # last stage records its finished microbatch (micro index t - (s-1))
        out_idx = jnp.clip(t - (s - 1), 0, m - 1)
        write = jnp.logical_and(idx == s - 1, t >= s - 1)
        outs = lax.cond(
            write,
            lambda o: lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
            lambda o: o,
            outs,
        )
        return (buf_next, outs), None

    y0 = jax.eval_shape(stage_fn, stage_params, x_micro[0])
    buf0 = jnp.zeros(y0.shape, y0.dtype)
    outs0 = jnp.zeros((m,) + tuple(y0.shape), y0.dtype)
    (_, outs), _ = lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
    # only the last stage holds real outputs; replicate them to every stage so
    # the caller sees a consistent value (one [M, ...]-sized all-reduce).
    outs = jnp.where(idx == s - 1, outs, jnp.zeros_like(outs))
    return lax.psum(outs, axis_name)
