"""Core neural-net layers in pure JAX (NHWC for convs, [B, T, D] for sequences)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import Params

# ---------------------------------------------------------------------------
# conv / pool
# ---------------------------------------------------------------------------


def conv2d(
    x: jax.Array,
    p: Params,
    stride: int | tuple[int, int] = 1,
    padding="SAME",
    groups: int = 1,
) -> jax.Array:
    """NHWC x HWIO -> NHWC convolution."""
    s = (stride, stride) if isinstance(stride, int) else stride
    if isinstance(padding, int):
        padding = [(padding, padding), (padding, padding)]
    y = lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=s,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.float32 if x.dtype == jnp.float32 else None,
    )
    if "b" in p:
        y = y + p["b"]
    return y


def depthwise_conv2d(x, p, stride=1, padding="SAME"):
    return conv2d(x, p, stride=stride, padding=padding, groups=x.shape[-1])


def max_pool(x, k=2, s=2, padding="VALID"):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, s, s, 1), padding
    )


def avg_pool(x, k=2, s=2, padding="VALID"):
    total = lax.reduce_window(x, 0.0, lax.add, (1, k, k, 1), (1, s, s, 1), padding)
    return total / float(k * k)


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# dense / norms / activations
# ---------------------------------------------------------------------------


def dense(x, p: Params):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def layernorm(x, p: Params, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps) * p["scale"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm(x, p: Params, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (x * lax.rsqrt(var + eps).astype(x.dtype)) * p["scale"]
    return y


def groupnorm(x, p: Params, groups=32, eps=1e-5):
    """NHWC group norm (U-Net)."""
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * lax.rsqrt(var + eps)
    y = xg.reshape(b, h, w, c) * p["scale"]
    if "b" in p:
        y = y + p["b"]
    return y


def batchnorm_inference(x, p: Params, eps=1e-5):
    """Inference-mode BN with folded running stats."""
    return (x - p["mean"]) * lax.rsqrt(p["var"] + eps) * p["scale"] + p["b"]


def batchnorm_train(x, p: Params, eps=1e-5, axes=(0, 1, 2)):
    """Batch-stats BN (no running-average update; fine for the smoke trainer)."""
    mu = jnp.mean(x, axis=axes, keepdims=True)
    var = jnp.var(x, axis=axes, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * p["scale"] + p["b"]


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def relu(x):
    return jax.nn.relu(x)


def softmax_xent(logits, labels):
    """Mean cross-entropy with integer labels.

    The explicit f32 cast does two jobs: stable logsumexp, and -- because the
    transpose of ``convert`` casts back -- it keeps the *cotangent* stream in
    the params' (bf16) dtype.  Without it the whole backward pass runs in f32,
    doubling activation-gradient memory traffic and every TP all-reduce
    (EXPERIMENTS.md §Perf, qwen3 iteration 2)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def drop_path(x, key, rate: float):
    """Stochastic depth (per-sample residual drop)."""
    if rate <= 0.0:
        return x
    shape = (x.shape[0],) + (1,) * (x.ndim - 1)
    keep = jax.random.bernoulli(key, 1.0 - rate, shape)
    return x * keep / (1.0 - rate)
