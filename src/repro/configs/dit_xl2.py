"""dit-xl2 [diffusion]: img_res=256 patch=2 n_layers=28 d_model=1152
n_heads=16.  [arXiv:2212.09748; paper]"""
from ..models import dit
from ..models.dit import DiTConfig
from .base import Arch, diffusion_cells, register

FULL = DiTConfig(name="dit-xl2", img_res=256, patch=2, n_layers=28,
                 d_model=1152, n_heads=16)
SMOKE = DiTConfig(name="dit-xl2-smoke", img_res=64, patch=2, n_layers=2,
                  d_model=64, n_heads=4, num_classes=10)

ARCH = register(
    Arch(
        name="dit-xl2",
        family="diffusion",
        cfg=FULL,
        smoke_cfg=SMOKE,
        cells=diffusion_cells(),
        module=dit,
        notes="latent diffusion transformer; gen shapes spatially shard the "
        "latent height over the data axis (HALP SP applied to serving)",
    )
)
