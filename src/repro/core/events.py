"""Shared HALP event topology: one plan-walk feeding both latency engines.

The closed-form recursion (``repro.core.schedule``) and the discrete-event
simulator (``repro.core.simulator``) must price the *same* jobs and messages
or their cross-validation is meaningless.  Historically each engine re-derived
the message structure from the plan independently; this module centralises it:

* per-slot *dependent* rows (the boundary rows a secondary must compute first
  and ship to its adjacent host zones, paper eq. 16's t_cmp^dep),
* per-zone host chunks (rows each adjacent secondary is waiting for,
  eqs. 11-12 / 18), the initial image slices (eq. 10) and the final sub-output
  merge (eqs. 13-14), and
* :func:`build_halp_dag`, which lays the full job/message DAG onto any
  ``Sim``-compatible scheduler with per-ES platforms and per-link rates drawn
  from a :class:`~repro.core.topology.CollabTopology`.

The closed form consumes the per-layer quantities; the simulator consumes the
DAG.  Both therefore see identical work and identical bytes by construction.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .nets import ConvNetGeom, DTYPE_BYTES
from .partition import (
    HALPPlan,
    PlanLayout,
    SCHEME_HALO,
    SCHEME_HOST,
    SCHEMES,
    SchemeLayout,
    Segment,
    plan_from_layout,
    plan_halp_topology,
    plan_layout,
    scheme_layout,
)
from .topology import CollabTopology

__all__ = [
    "SecStep",
    "ZoneStep",
    "init_bytes",
    "sec_step",
    "zone_step",
    "final_bytes",
    "resolve_halp_setup",
    "build_halp_dag",
    "build_multitask_dag",
    "build_scheme_dag",
    "DagTemplate",
    "HalpBatchEvaluator",
    "MultitaskBatchEvaluator",
    "SchemeBatchEvaluator",
    "simulate_scheme",
]


def resolve_halp_setup(
    net: ConvNetGeom,
    platform=None,
    link=None,
    overlap_rows: int | None = None,
    topology: CollabTopology | None = None,
    ratios=None,
    plan: HALPPlan | None = None,
    host_platform=None,
) -> tuple[CollabTopology, HALPPlan]:
    """Resolve the two calling conventions shared by both latency engines.

    Paper-style ``(platform, link)`` builds the symmetric two-secondary
    topology with the paper's equal split; topology-style takes an explicit
    :class:`CollabTopology` (capacity-weighted ratios by default).  Conflicting
    combinations raise ``TypeError`` instead of silently ignoring arguments."""
    if plan is not None and (ratios is not None or overlap_rows is not None):
        raise TypeError(
            "plan= already fixes the partition; do not also pass "
            "ratios/overlap_rows (they would be silently ignored)"
        )
    if topology is None:
        if platform is None or link is None:
            raise TypeError("pass either (platform, link) or topology=")
        topology = CollabTopology.symmetric(platform, link, host_platform=host_platform)
        if ratios is None:
            ratios = (0.5, 0.5)  # the paper's equal split, not capacity-weighted
    elif platform is not None or link is not None or host_platform is not None:
        raise TypeError(
            "topology= already carries platforms and links; do not also pass "
            "platform/link/host_platform (they would be silently ignored)"
        )
    if plan is None:
        plan = plan_halp_topology(
            net, topology, overlap_rows=4 if overlap_rows is None else overlap_rows,
            ratios=ratios,
        )
    return topology, plan


def init_bytes(plan: HALPPlan, sec_slot: str) -> float:
    """Eq. (10): bytes of the initial image slice sent to a secondary ES."""
    net = plan.net
    seg = plan.parts[0].inp[sec_slot]
    return DTYPE_BYTES * seg.rows * net.in_rows * net.in_channels


def final_bytes(plan: HALPPlan, sec_slot: str) -> float:
    """Eqs. (13)-(14): the g_N sub-output a secondary ships for the head merge."""
    return plan.message_bytes(len(plan.parts) - 1, sec_slot, plan.host)


@dataclass(frozen=True)
class SecStep:
    """One secondary slot's work at one layer."""

    slot: str
    own_rows: int
    dep_rows: int  # boundary rows computed first (sum over adjacent zones)
    sends: tuple[tuple[str, Segment, float], ...]  # (zone, rows, bytes) to host


@dataclass(frozen=True)
class ZoneStep:
    """One host zone's work at one layer: a chunk per adjacent secondary."""

    slot: str
    zone_rows: int
    above: str  # secondary above the zone (its rows are computed first)
    below: str
    rows_for_above: int
    rows_for_below: int
    bytes_to_above: float
    bytes_to_below: float


def _union_rows(segs: list[Segment]) -> int:
    """Distinct rows covered by possibly-overlapping segments (a 1-row middle
    secondary can owe the *same* row to both adjacent zones; it computes it
    once)."""
    rows = 0
    cur_hi = 0
    for seg in sorted((s for s in segs if s), key=lambda s: s.lo):
        lo = max(seg.lo, cur_hi + 1)
        if seg.hi >= lo:
            rows += seg.hi - lo + 1
            cur_hi = seg.hi
    return rows


def sec_step(plan: HALPPlan, layer: int, slot: str) -> SecStep:
    own = plan.parts[layer].out[slot]
    if layer + 1 >= len(plan.parts):
        # g_N: the whole sub-output is the boundary (eqs. 13-14).  The seed
        # convention -- kept for every N so cross-N accounting is uniform --
        # prices this send here AND in the final merge; the nominal zone key
        # is inert (no next layer to gate).
        zones = plan.adjacent_zones(slot)
        sends = (
            ((zones[0], own, plan.message_bytes(layer, slot, plan.host)),)
            if own and zones
            else ()
        )
        return SecStep(slot=slot, own_rows=own.rows, dep_rows=own.rows, sends=sends)
    # Adjacent zones are always listed (an empty send still orders the zone's
    # chunk behind the secondary's dep compute); non-adjacent zones appear
    # only when auto-reduced plans route rows into a widened host tail zone
    # (a direct uplink -- the no-secondary-exchange invariant is untouched).
    adjacent = plan.adjacent_zones(slot)
    targets = [*adjacent] + [
        z for z in plan.zone_slots if z not in adjacent and plan.message(layer, slot, z)
    ]
    sends = []
    for z in targets:
        seg = plan.message(layer, slot, z)
        sends.append((z, seg, plan.message_bytes(layer, slot, z)))
    return SecStep(
        slot=slot,
        own_rows=own.rows,
        dep_rows=min(own.rows, _union_rows([seg for _, seg, _ in sends])),
        sends=tuple(sends),
    )


def zone_step(plan: HALPPlan, layer: int, slot: str) -> ZoneStep:
    above, below = plan.adjacent_secondaries(slot)
    m_above = plan.message(layer, slot, above)
    return ZoneStep(
        slot=slot,
        zone_rows=plan.parts[layer].out[slot].rows,
        above=above,
        below=below,
        rows_for_above=m_above.rows,
        rows_for_below=plan.message(layer, slot, below).rows,
        bytes_to_above=plan.message_bytes(layer, slot, above),
        bytes_to_below=plan.message_bytes(layer, slot, below),
    )


def _row_flops(net: ConvNetGeom) -> list[float]:
    """Per-layer FLOPs per output row, hoisted once per DAG build (``sizes()``
    is O(layers), so calling it per job would be quadratic)."""
    sizes = net.sizes()
    return [g.flops_per_out_row(sizes[i + 1]) for i, g in enumerate(net.layers)]


def build_halp_dag(sim, plans: list[HALPPlan], topology: CollabTopology) -> list[int]:
    """Lay the full HALP job/message DAG for ``len(plans)`` concurrent tasks.

    Resources: the host ES name (host compute), ``{slot}^{t}`` (secondary
    compute, one instance per task), ``link:a->b`` (directed point-to-point
    links, full duplex).  The host serves the per-task zones in task order
    within each layer (paper §IV.B).  Returns the head job id of every task.

    This is the paper's §IV.B deployment: every task runs the *same* plan on
    its own clone of the secondary group (N x n_tasks distinct secondaries),
    so secondary resources are suffixed per task.  For *physically shared*
    secondaries with per-task plans, see :func:`build_multitask_dag`.
    """
    return _lay_halp_dag(sim, plans, topology, lambda t, s: f"{s}^{t}")


def build_multitask_dag(sim, plans: list[HALPPlan], topology: CollabTopology) -> list[int]:
    """Lay the job/message DAG for ``len(plans)`` tasks on ONE physical pool.

    Unlike :func:`build_halp_dag` (per-task secondary clones), every plan's
    slot names here are *physical* ES names of ``topology``: two tasks that
    name the same secondary contend for it (FIFO), all tasks contend for the
    host, and a directed link ``link:a->b`` is one resource no matter how
    many tasks route over it.  This is the engine behind per-task
    heterogeneous placement (``repro.core.placement``): tasks may carry
    different plans over different sub-topologies, and shared host/link
    contention falls out of the resource naming rather than being modelled
    separately.  Returns the head job id of every task."""
    if not plans:
        raise ValueError("need at least one task plan")
    net = plans[0].net
    host = plans[0].host
    for t, plan in enumerate(plans):
        if plan.net != net:
            raise ValueError(f"task {t}: all tasks must share one network geometry")
        if plan.host != host:
            raise ValueError(f"task {t}: host {plan.host!r} != task 0 host {host!r}")
        for s in plan.secondary_slots:
            if s not in topology.platforms:
                raise ValueError(f"task {t}: secondary {s!r} not in the topology pool")
    return _lay_halp_dag(sim, plans, topology, lambda t, s: s)


class _FloatPricer:
    """Default job pricing: exact floats, bit-identical to the historical
    inline pricing (``(num * rows) / den`` with integer-valued ``num * rows``
    products, so factorising the numerator out cannot change a single bit).

    ``num_cmp[i]`` is layer i's FLOPs per output row, ``num_msg[i]`` the
    *bits* per boundary row of layer i's output (8 x eq. 11's bytes-per-row),
    ``num_init`` the bits per input-image row slice (eq. 10) and ``num_head``
    the head FLOPs -- these are the template's duration *lanes*: every job
    duration is one of these numerators times a row count over a rate."""

    def __init__(self, net: ConvNetGeom, topology: CollabTopology | None):
        self.topology = topology
        self.num_cmp = _row_flops(net)
        sizes = net.sizes()
        self.num_msg = [
            8.0 * DTYPE_BYTES * sizes[i + 1] * g.c_out for i, g in enumerate(net.layers)
        ]
        self.num_init = 8.0 * DTYPE_BYTES * net.in_rows * net.in_channels
        self.num_head = net.head_flops

    def cmp(self, es: str, num: float, rows: float) -> float:
        return (num * rows) / self.topology.platform_of(es).eff_flops

    def com(self, src: str, dst: str, num: float, rows: float) -> float:
        return (num * rows) / self.topology.link_between(src, dst).rate_bps


class _RecordingPricer(_FloatPricer):
    """Prices like :class:`_FloatPricer` while recording each job's duration
    factorisation ``(numerator, rate-kind)`` in call order -- one record per
    ``sim.add`` (the builder prices every job exactly once, as its argument).
    The row counts themselves are *not* recorded: they are the per-candidate
    parameters a :class:`DagTemplate` fills in from plan layouts."""

    def __init__(self, net: ConvNetGeom, topology: CollabTopology):
        super().__init__(net, topology)
        self.nums: list[float] = []
        self.den_kinds: list[tuple] = []
        self.den_index: dict[tuple, int] = {}
        self.den_ids: list[int] = []

    def _record(self, num: float, kind: tuple) -> None:
        idx = self.den_index.get(kind)
        if idx is None:
            idx = self.den_index[kind] = len(self.den_kinds)
            self.den_kinds.append(kind)
        self.nums.append(num)
        self.den_ids.append(idx)

    def cmp(self, es: str, num: float, rows: float) -> float:
        self._record(num, ("es", es))
        return super().cmp(es, num, rows)

    def com(self, src: str, dst: str, num: float, rows: float) -> float:
        self._record(num, ("link", src, dst))
        return super().com(src, dst, num, rows)


def _lay_halp_dag(
    sim, plans: list[HALPPlan], topology: CollabTopology, sec_res, pricer=None,
    roots: Sequence[int | None] | None = None,
) -> list[int]:
    """Shared DAG builder behind both multi-task deployments.

    ``sec_res(task, slot)`` names the compute resource of a secondary slot
    (and its link endpoints).  Per layer, each secondary computes its
    dependent boundary rows first and ships them to the host zones that need
    them while computing the rest (eq. 16); the host computes each zone's
    rows-for-above chunk, sends it, then the rest, then sends below
    (eq. 18) -- a zone's chunks gate on the boundary messages it consumes
    from the previous layer.

    ``pricer`` turns (numerator lane, row count, resource) into a job
    duration; the default prices exact floats, a :class:`_RecordingPricer`
    additionally captures the factorisation for :class:`DagTemplate`.

    ``roots`` optionally gates each task's entry (initial slices and the
    host's first zone chunk) on an existing job -- mixed-scheme plans use
    this to chain a halo segment behind the previous segment's barrier.  The
    default (all None) is structurally identical to no gate (:meth:`Sim.add`
    drops None deps), so standalone builds are untouched bit-for-bit.
    """
    net = plans[0].net
    host = plans[0].host
    n_layers = len(net.layers)
    pr = pricer if pricer is not None else _FloatPricer(net, topology)
    num_cmp, num_msg = pr.num_cmp, pr.num_msg
    if roots is None:
        roots = [None] * len(plans)

    # Clone deployments pass the *same* plan object once per task; memoise the
    # step walks per distinct plan so n_tasks cost only one plan-walk each.
    step_cache: dict[tuple[int, int, str], SecStep | ZoneStep] = {}

    def sec_step_of(plan: HALPPlan, i: int, s: str) -> SecStep:
        key = (id(plan), i, s)
        step = step_cache.get(key)
        if step is None:
            step = step_cache[key] = sec_step(plan, i, s)
        return step

    def zone_step_of(plan: HALPPlan, i: int, z: str) -> ZoneStep:
        key = (id(plan), i, z)
        step = step_cache.get(key)
        if step is None:
            step = step_cache[key] = zone_step(plan, i, z)
        return step

    last_chunk: dict[tuple[int, str], int | None] = {}
    # (task, sec_slot, layer) -> message jobs the secondary needs before layer
    sec_gate: dict[tuple[int, str, int], list[int]] = {}
    # (task, layer, zone_slot) -> {src_sec: boundary message gating the zone}
    zone_in: dict[tuple[int, int, str], dict[str, int]] = {}

    # initial image distribution host -> secondaries (eq. 10)
    for t, plan in enumerate(plans):
        for s in plan.secondary_slots:
            jid = sim.add(
                f"int[{t}]{s}",
                f"link:{host}->{sec_res(t, s)}",
                pr.com(host, s, pr.num_init, plan.parts[0].inp[s].rows),
                [roots[t]],
            )
            sec_gate[(t, s, 0)] = [jid]
        if roots[t] is not None:
            last_chunk[(t, host)] = roots[t]

    for i in range(n_layers):
        # --- secondaries: dep chunk first, then rest; send dep while resting.
        for t, plan in enumerate(plans):
            for s in plan.secondary_slots:
                step = sec_step_of(plan, i, s)
                deps = [last_chunk.get((t, s))] + sec_gate.get((t, s, i), [])
                a = sim.add(
                    f"cmp[{t}]{s}.g{i}.dep",
                    sec_res(t, s),
                    pr.cmp(s, num_cmp[i], step.dep_rows),
                    deps,
                )
                for z, seg, _nbytes in step.sends:
                    m = sim.add(
                        f"msg[{t}]{s}->{host}.g{i}",
                        f"link:{sec_res(t, s)}->{host}",
                        pr.com(s, host, num_msg[i], seg.rows),
                        [a],
                    )
                    if i + 1 < n_layers:
                        zone_in.setdefault((t, i + 1, z), {})[s] = m
                b = sim.add(
                    f"cmp[{t}]{s}.g{i}.rest",
                    sec_res(t, s),
                    pr.cmp(s, num_cmp[i], step.own_rows - step.dep_rows),
                    [a],
                )
                last_chunk[(t, s)] = b
        # --- host: per task, zones in row order: chunk for the secondary above,
        # send; chunk the rest (gated on the below secondary's rows), send below.
        for t, plan in enumerate(plans):
            for z in plan.zone_slots:
                step = zone_step_of(plan, i, z)
                gates = zone_in.get((t, i, z), {})
                a = sim.add(
                    f"cmp[{t}]{z}.g{i}.for_{step.above}",
                    host,
                    pr.cmp(host, num_cmp[i], step.rows_for_above),
                    [last_chunk.get((t, host)), gates.get(step.above)],
                )
                s1 = sim.add(
                    f"msg[{t}]{z}->{step.above}.g{i}",
                    f"link:{host}->{sec_res(t, step.above)}",
                    pr.com(host, step.above, num_msg[i], step.rows_for_above),
                    [a],
                )
                b = sim.add(
                    f"cmp[{t}]{z}.g{i}.rest",
                    host,
                    pr.cmp(host, num_cmp[i], step.zone_rows - step.rows_for_above),
                    # the rest chunk consumes every other boundary message the
                    # zone received (positionally below, plus -- in reduced
                    # plans -- any dropped secondary routing into a tail zone)
                    [a] + [m for src, m in gates.items() if src != step.above],
                )
                s2 = sim.add(
                    f"msg[{t}]{z}->{step.below}.g{i}",
                    f"link:{host}->{sec_res(t, step.below)}",
                    pr.com(host, step.below, num_msg[i], step.rows_for_below),
                    [b],
                )
                last_chunk[(t, host)] = b
                if i + 1 < n_layers:
                    sec_gate.setdefault((t, step.above, i + 1), []).append(s1)
                    sec_gate.setdefault((t, step.below, i + 1), []).append(s2)
                # NOTE: zone rows stay on the host -- no job for the local move.

    # final merge: secondaries ship their g_N sub-outputs; host runs the head.
    heads = []
    for t, plan in enumerate(plans):
        merged = []
        for s in plan.secondary_slots:
            m = sim.add(
                f"final[{t}]{s}->{host}",
                f"link:{sec_res(t, s)}->{host}",
                pr.com(s, host, num_msg[n_layers - 1], plan.parts[-1].out[s].rows),
                [last_chunk[(t, s)]],
            )
            merged.append(m)
        h = sim.add(
            f"head[{t}]",
            host,
            pr.cmp(host, pr.num_head, 1),
            merged + [last_chunk[(t, host)]],
        )
        heads.append(h)
    return heads


# --------------------------------------------------------------------------
# Batched planning engine: DAG templates + layout-parameterised durations.
# --------------------------------------------------------------------------

def _layout_quantities(layouts: Sequence[PlanLayout]) -> np.ndarray:
    """Per-job row counts of one candidate, in the exact order
    :func:`_lay_halp_dag` prices jobs.

    This is the *parameter vector* of the template factorisation: job ``j``'s
    duration is ``nums[j] * q[j] / rate[j]`` where ``nums``/``rate`` live in
    the :class:`DagTemplate` (structure, shared across candidates) and ``q``
    is this walk (candidate-specific, pure integer arithmetic on the layout
    -- no Segment or HALPPlan objects).  The walk mirrors the builder:
    init slices, then per layer the secondary block (dep chunk, boundary
    sends, rest chunk) and the zone block (for-above chunk, send, rest chunk,
    send below), then the final merges and heads.  Any divergence from the
    builder is caught bit-exactly by :meth:`DagTemplate.from_layouts`'s
    build-time self-check."""
    walks = [lay.walk() for lay in layouts]
    vals: list[float] = []
    n_layers = layouts[0].n_layers
    for _sig, init_rows, _s, _z, _f in walks:
        vals += init_rows
    for i in range(n_layers):
        for _sig, _i, sec_layers, _z, _f in walks:
            vals += sec_layers[i]
        for _sig, _i, _s, zone_layers, _f in walks:
            vals += zone_layers[i]
    for _sig, _i, _s, _z, final_rows in walks:
        vals += final_rows
    return np.array(vals)


@dataclass
class DagTemplate:
    """The job/message DAG of one structural signature, durations factored out.

    ``sim`` holds the reference structure (job list, resources, dependencies)
    laid once by :func:`_lay_halp_dag`; ``nums``/``den_ids``/``den_kinds``
    factor every job's duration into ``num * rows / rate`` where ``num`` is a
    per-layer lane (FLOPs per output row for compute jobs, bits per boundary
    row for messages -- see :class:`_FloatPricer`), ``rows`` comes from a
    candidate's :func:`_layout_quantities` vector, and ``rate`` resolves
    against a topology at evaluation time (so one template serves every
    rate-drifted rebuild of the same cluster).  Scoring B candidates is then
    one :meth:`~repro.core.simulator.Sim.run_batch` sweep -- bit-identical
    to B scalar builds + runs, enforced at build time by a self-check."""

    sim: object  # repro.core.simulator.Sim
    heads: tuple[int, ...]
    nums: np.ndarray  # [J] duration-lane numerators
    den_ids: np.ndarray  # [J] index into den_kinds
    den_kinds: tuple[tuple, ...]  # ("es", name) | ("link", src, dst)

    @classmethod
    def from_layouts(
        cls,
        layouts: Sequence[PlanLayout],
        topology: CollabTopology,
        physical: bool,
    ) -> "DagTemplate":
        """Lay the DAG for ``layouts`` (one per task) and record the duration
        factorisation.  ``physical=False`` clones secondary resources per task
        (:func:`build_halp_dag`); ``physical=True`` keys them by ES name so
        tasks contend (:func:`build_multitask_dag`).  Raises AssertionError if
        the layout quantity walk does not reproduce the scalar builder's
        durations bit-for-bit."""
        from .simulator import Sim  # runtime import: simulator imports events

        plans = [plan_from_layout(lay) for lay in layouts]
        sim = Sim()
        pricer = _RecordingPricer(plans[0].net, topology)
        sec_res = (lambda t, s: s) if physical else (lambda t, s: f"{s}^{t}")
        heads = _lay_halp_dag(sim, plans, topology, sec_res, pricer=pricer)
        tmpl = cls(
            sim=sim,
            heads=tuple(heads),
            nums=np.array(pricer.nums),
            den_ids=np.array(pricer.den_ids),
            den_kinds=tuple(pricer.den_kinds),
        )
        quantities = _layout_quantities(layouts)
        if len(quantities) != len(sim.jobs):
            raise AssertionError(
                f"layout quantity walk produced {len(quantities)} entries for "
                f"{len(sim.jobs)} builder jobs -- the walks fell out of step"
            )
        ref = tmpl.durations(quantities, topology)[0]
        got = np.array([job.duration for job in sim.jobs])
        if not np.array_equal(ref, got):
            bad = int(np.flatnonzero(ref != got)[0])
            raise AssertionError(
                f"template durations diverge from the scalar builder at job "
                f"{bad} ({sim.jobs[bad].name}): {ref[bad]} != {got[bad]}"
            )
        return tmpl

    def rates(self, topology: CollabTopology) -> np.ndarray:
        """Per-den-kind rates (eff FLOP/s or link bps) under ``topology``."""
        return np.array(
            [
                topology.platform_of(kind[1]).eff_flops
                if kind[0] == "es"
                else topology.link_between(kind[1], kind[2]).rate_bps
                for kind in self.den_kinds
            ]
        )

    def durations(self, quantities: np.ndarray, topology: CollabTopology) -> np.ndarray:
        """[B, J] durations for B quantity vectors under ``topology``'s rates."""
        q = np.asarray(quantities, dtype=np.float64)
        if q.ndim == 1:
            q = q[None, :]
        return (self.nums * q) / self.rates(topology)[self.den_ids]

    def run(self, quantities: np.ndarray, topology: CollabTopology):
        """Score B candidates in one vectorized DES sweep (BatchRun)."""
        return self.sim.run_batch(self.durations(quantities, topology))


# Process-wide template cache: keyed on structure only (net, host, task
# structure, structural signature) -- never on rates, which resolve per call,
# so channel-drifting replans keep hitting the same templates.
_TEMPLATES: OrderedDict[tuple, DagTemplate] = OrderedDict()
_TEMPLATE_CAPACITY = 128


def _template_for(key: tuple, build) -> DagTemplate:
    tmpl = _TEMPLATES.get(key)
    if tmpl is None:
        tmpl = build()
        _TEMPLATES[key] = tmpl
        if len(_TEMPLATES) > _TEMPLATE_CAPACITY:
            _TEMPLATES.popitem(last=False)
    else:
        _TEMPLATES.move_to_end(key)
    return tmpl


# Process-wide layout cache.  A plan layout depends on (net, secondaries,
# host, overlap, ratios) but NOT on platform/link rates, so an online
# controller re-optimising the same cluster against drifting rate estimates
# revisits the same layouts over and over -- the dominant cost of a warm
# batched evaluation.  False stores infeasibility (also worth remembering).
_LAYOUTS: OrderedDict[tuple, "PlanLayout | bool"] = OrderedDict()
_LAYOUT_CAPACITY = 8192


def _layout_cached(
    net: ConvNetGeom,
    secondaries: tuple[str, ...],
    host: str,
    overlap_rows: int,
    ratios: tuple[float, ...],
    auto_reduce: bool = True,
) -> PlanLayout | None:
    key = (net, secondaries, host, overlap_rows, ratios, auto_reduce)
    hit = _LAYOUTS.get(key)
    if hit is None:
        try:
            hit = plan_layout(
                net,
                secondaries,
                host=host,
                overlap_rows=overlap_rows,
                ratios=ratios,
                auto_reduce=auto_reduce,
            )
        except (AssertionError, ValueError):
            hit = False
        _LAYOUTS[key] = hit
        if len(_LAYOUTS) > _LAYOUT_CAPACITY:
            _LAYOUTS.popitem(last=False)
    else:
        _LAYOUTS.move_to_end(key)
    return hit or None


class HalpBatchEvaluator:
    """Batched (ratios, overlap) candidate pricing for one cluster.

    The tentpole fast path of the planner: per candidate only the integer
    :class:`~repro.core.partition.PlanLayout` and its row-count vector are
    computed; the DAG structure is laid once per structural signature
    (:class:`DagTemplate`, cached process-wide) and all candidates sharing a
    signature are priced in one vectorized :meth:`Sim.run_batch` sweep.
    Scores are bit-identical to :func:`~repro.core.optimizer.evaluate_plan`'s
    scalar plan-build + DES path (pinned in ``tests/test_conformance.py``)."""

    def __init__(
        self,
        net: ConvNetGeom,
        topology: CollabTopology,
        n_tasks: int = 1,
        auto_reduce: bool = True,
    ):
        self.net = net
        self.topology = topology
        self.n_tasks = n_tasks
        self.auto_reduce = auto_reduce

    def layout_for(self, ratios, overlap_rows: int) -> PlanLayout | None:
        """The candidate's layout (process-wide cache), or None if infeasible."""
        return _layout_cached(
            self.net,
            self.topology.secondaries,
            self.topology.host,
            overlap_rows,
            tuple(ratios),
            self.auto_reduce,
        )

    def evaluate(self, candidates: Sequence[tuple]) -> list[float]:
        """DES makespans for ``(ratios, overlap_rows)`` candidates (+inf when
        infeasible), batched by structural signature."""
        scores = [float("inf")] * len(candidates)
        by_sig: dict[tuple, list[tuple[int, PlanLayout]]] = {}
        for k, (ratios, w) in enumerate(candidates):
            lay = self.layout_for(ratios, w)
            if lay is not None:
                by_sig.setdefault(lay.signature, []).append((k, lay))
        for sig, members in by_sig.items():
            key = ("clone", self.net, self.topology.host, self.n_tasks, sig)
            first = members[0][1]
            tmpl = _template_for(
                key,
                lambda lay=first: DagTemplate.from_layouts(
                    [lay] * self.n_tasks, self.topology, physical=False
                ),
            )
            q = np.stack(
                [_layout_quantities([lay] * self.n_tasks) for _k, lay in members]
            )
            run = tmpl.run(q, self.topology)
            for row, (k, _lay) in enumerate(members):
                scores[k] = float(run.makespan[row])
        return scores


class MultitaskBatchEvaluator:
    """Batched scoring of task -> secondaries assignments on one physical pool.

    Candidates are tuples of per-task secondary groups; each group gets the
    capacity-ratio plan layout over its sub-topology (the cheap scoring mode
    of :func:`~repro.core.placement.place_tasks`) and the whole assignment is
    priced on the shared-contention DAG (:func:`build_multitask_dag`
    semantics: host/links are physical resources) -- templated and batched
    exactly like the single-cluster evaluator."""

    def __init__(self, net: ConvNetGeom, pool: CollabTopology, overlap_rows: int = 4):
        self.net = net
        self.pool = pool
        self.overlap_rows = overlap_rows

    def layouts_for(self, groups: Sequence[Sequence[str]]) -> list[PlanLayout] | None:
        """Per-task layouts for one assignment, or None when any group is
        infeasible."""
        layouts = []
        for group in groups:
            try:
                sub = self.pool.sub_topology(group)
            except ValueError:
                return None
            lay = _layout_cached(
                self.net,
                sub.secondaries,
                self.pool.host,
                self.overlap_rows,
                sub.capacity_ratios(),
            )
            if lay is None:
                return None
            layouts.append(lay)
        return layouts

    def evaluate(self, candidates: Sequence[tuple]) -> list[dict | None]:
        """Shared-pool DES scores per assignment candidate: dicts with
        ``total`` / ``avg_delay`` / ``per_task_finish`` (None = infeasible),
        bit-identical to ``placement.simulate_placement``."""
        return self.evaluate_layout_sets(
            [self.layouts_for(groups) for groups in candidates]
        )

    def evaluate_layout_sets(
        self, candidates: Sequence[list[PlanLayout] | None]
    ) -> list[dict | None]:
        """Score prepared per-task layout lists (None entries stay None) --
        the entry point for plan sets whose knobs differ from the capacity
        default, e.g. a placement's per-task refined (ratios, overlap)."""
        results: list[dict | None] = [None] * len(candidates)
        by_sig: dict[tuple, list[tuple[int, list[PlanLayout]]]] = {}
        for k, layouts in enumerate(candidates):
            if layouts is not None:
                sig = tuple(lay.signature for lay in layouts)
                by_sig.setdefault(sig, []).append((k, layouts))
        for sig, members in by_sig.items():
            key = ("multi", self.net, self.pool.host, sig)
            first = members[0][1]
            tmpl = _template_for(
                key,
                lambda lays=first: DagTemplate.from_layouts(
                    lays, self.pool, physical=True
                ),
            )
            q = np.stack([_layout_quantities(lays) for _k, lays in members])
            run = tmpl.run(q, self.pool)
            for row, (k, _lays) in enumerate(members):
                finishes = [float(run.finish_of(h)[row]) for h in tmpl.heads]
                results[k] = dict(
                    total=float(run.makespan[row]),
                    avg_delay=sum(finishes) / len(finishes),
                    per_task_finish=tuple(finishes),
                )
        return results


# --------------------------------------------------------------------------
# Mixed-scheme DAGs: halo segments + hub-relayed (NP / head-sequence) segments
# priced through the same template machinery.
# --------------------------------------------------------------------------

class _SegmentLanes:
    """Duration lanes of one halo *segment* (its sub-net), delegating the
    actual pricing to the outer pricer so a :class:`_RecordingPricer` keeps
    accumulating one factorisation across every segment of a scheme DAG."""

    def __init__(self, base, sub_net: ConvNetGeom):
        self._base = base
        self.num_cmp = _row_flops(sub_net)
        sizes = sub_net.sizes()
        self.num_msg = [
            8.0 * DTYPE_BYTES * sizes[i + 1] * g.c_out
            for i, g in enumerate(sub_net.layers)
        ]
        self.num_init = 8.0 * DTYPE_BYTES * sub_net.in_rows * sub_net.in_channels
        self.num_head = sub_net.head_flops  # 0.0: segment heads are barriers

    def cmp(self, es: str, num: float, rows: float) -> float:
        return self._base.cmp(es, num, rows)

    def com(self, src: str, dst: str, num: float, rows: float) -> float:
        return self._base.com(src, dst, num, rows)


def _lay_scheme_dag(
    sim,
    slayout: SchemeLayout,
    n_tasks: int,
    topology: CollabTopology,
    sec_res,
    pricer=None,
) -> list[int]:
    """Lay the job/message DAG of a mixed-scheme plan for ``n_tasks`` tasks.

    Segments chain through per-task *barriers* (the job after which the host
    holds the segment's full output):

    * **halo** segments re-enter :func:`_lay_halp_dag` on their sub-net with
      ``roots`` gating the entry -- identical structure and lanes to a
      standalone halo DAG of those layers, so the pure-halo scheme plan prices
      float-identically to the legacy path;
    * **host_solo** segments are one host job per layer per task;
    * **hub** segments (non_penetrative / head_sequence) lay, per relay layer,
      an upload per secondary (its held slice of the layer's input), a
      download per secondary (what it lacks), and a sliced compute job;
      transfer-free layers (``relay=False`` in
      :func:`~repro.core.partition.hub_segment_fracs`) lay only the computes,
      so channel-local / row-local runs never synchronise across secondaries.
      A final gather + zero-duration host merge closes the segment.

    Job quantities are exactly mirrored by :func:`_scheme_quantities` (the
    template self-check enforces it bit-for-bit).  Returns the per-task head
    job ids."""
    net = slayout.net
    host = slayout.host
    secs = slayout.secondaries
    sizes = net.sizes()
    pr = pricer if pricer is not None else _FloatPricer(net, topology)
    cursor: list[int | None] = [None] * n_tasks
    for seg_idx, seg in enumerate(slayout.segments):
        if seg.scheme == SCHEME_HALO:
            lay = slayout.halo_layouts[seg_idx]
            sub_plan = plan_from_layout(lay)
            lanes = _SegmentLanes(pr, lay.net)
            heads = _lay_halp_dag(
                sim, [sub_plan] * n_tasks, topology, sec_res,
                pricer=lanes, roots=cursor,
            )
            cursor = list(heads)
            continue
        if seg.scheme == SCHEME_HOST:
            for i in range(seg.start, seg.stop + 1):
                flops = net.layer_flops(i)
                for t in range(n_tasks):
                    cursor[t] = sim.add(
                        f"solo[{t}].g{i}", host, pr.cmp(host, flops, 1.0), [cursor[t]]
                    )
            continue
        # hub segment (non_penetrative / head_sequence)
        fracs, final = slayout.hub_fracs[seg_idx]
        sec_prev: dict[tuple[int, int], int] = {}
        for off, (relay, up, down, share) in enumerate(fracs):
            i = seg.start + off
            g = net.layers[i]
            flops = net.layer_flops(i)
            bits_in = 8.0 * DTYPE_BYTES * sizes[i] * sizes[i] * g.c_in
            downs: dict[tuple[int, int], int] = {}
            if relay:
                ups: dict[int, list[int]] = {}
                for t in range(n_tasks):
                    for j, s in enumerate(secs):
                        ups.setdefault(t, []).append(
                            sim.add(
                                f"up[{t}]{s}.g{i}",
                                f"link:{sec_res(t, s)}->{host}",
                                pr.com(s, host, bits_in, up[j]),
                                [sec_prev.get((t, j))],
                            )
                        )
                for t in range(n_tasks):
                    for j, s in enumerate(secs):
                        downs[(t, j)] = sim.add(
                            f"down[{t}]{s}.g{i}",
                            f"link:{host}->{sec_res(t, s)}",
                            pr.com(host, s, bits_in, down[j]),
                            ups[t] + [cursor[t]],
                        )
            for t in range(n_tasks):
                for j, s in enumerate(secs):
                    sec_prev[(t, j)] = sim.add(
                        f"cmp[{t}]{s}.g{i}",
                        sec_res(t, s),
                        pr.cmp(s, flops, share[j]),
                        [downs.get((t, j)), sec_prev.get((t, j))],
                    )
        g = net.layers[seg.stop]
        bits_out = 8.0 * DTYPE_BYTES * sizes[seg.stop + 1] * sizes[seg.stop + 1] * g.c_out
        fins: dict[int, list[int]] = {}
        for t in range(n_tasks):
            for j, s in enumerate(secs):
                fins.setdefault(t, []).append(
                    sim.add(
                        f"gather[{t}]{s}.g{seg.stop}",
                        f"link:{sec_res(t, s)}->{host}",
                        pr.com(s, host, bits_out, final[j]),
                        [sec_prev.get((t, j))],
                    )
                )
        for t in range(n_tasks):
            cursor[t] = sim.add(
                f"merge[{t}].g{seg.stop}", host, pr.cmp(host, 0.0, 1.0),
                fins[t] + [cursor[t]],
            )
    heads = []
    for t in range(n_tasks):
        heads.append(
            sim.add(f"head[{t}]", host, pr.cmp(host, pr.num_head, 1.0), [cursor[t]])
        )
    return heads


def build_scheme_dag(
    sim, slayout: SchemeLayout, n_tasks: int, topology: CollabTopology
) -> list[int]:
    """Public mixed-scheme twin of :func:`build_halp_dag` (per-task secondary
    clones).  Returns the head job id of every task."""
    return _lay_scheme_dag(sim, slayout, n_tasks, topology, lambda t, s: f"{s}^{t}")


def _scheme_quantities(slayout: SchemeLayout, n_tasks: int) -> np.ndarray:
    """Per-job quantities of one mixed-scheme candidate, in the exact order
    :func:`_lay_scheme_dag` prices jobs (the scheme twin of
    :func:`_layout_quantities`; divergence is caught bit-exactly by the
    template self-check)."""
    vals: list[float] = []
    n_secs = len(slayout.secondaries)
    for seg_idx, seg in enumerate(slayout.segments):
        if seg.scheme == SCHEME_HALO:
            lay = slayout.halo_layouts[seg_idx]
            vals += _layout_quantities([lay] * n_tasks).tolist()
            # drop the sub-DAG's per-task head quantities? no: _layout_
            # quantities already includes them (the sub-head is a real job).
            continue
        if seg.scheme == SCHEME_HOST:
            vals += [1.0] * ((seg.stop - seg.start + 1) * n_tasks)
            continue
        fracs, final = slayout.hub_fracs[seg_idx]
        for relay, up, down, share in fracs:
            if relay:
                for _t in range(n_tasks):
                    vals += up
                for _t in range(n_tasks):
                    vals += down
            for _t in range(n_tasks):
                vals += share
        for _t in range(n_tasks):
            vals += final
        vals += [1.0] * n_tasks  # merge barriers
    vals += [1.0] * n_tasks  # heads
    return np.array(vals)


def _scheme_template(
    slayout: SchemeLayout, n_tasks: int, topology: CollabTopology
) -> DagTemplate:
    """Lay the scheme DAG once, record its duration factorisation, and verify
    the quantity walk reproduces the scalar builder bit-for-bit."""
    from .simulator import Sim  # runtime import: simulator imports events

    sim = Sim()
    pricer = _RecordingPricer(slayout.net, topology)
    heads = _lay_scheme_dag(
        sim, slayout, n_tasks, topology, lambda t, s: f"{s}^{t}", pricer=pricer
    )
    tmpl = DagTemplate(
        sim=sim,
        heads=tuple(heads),
        nums=np.array(pricer.nums),
        den_ids=np.array(pricer.den_ids),
        den_kinds=tuple(pricer.den_kinds),
    )
    quantities = _scheme_quantities(slayout, n_tasks)
    if len(quantities) != len(sim.jobs):
        raise AssertionError(
            f"scheme quantity walk produced {len(quantities)} entries for "
            f"{len(sim.jobs)} builder jobs -- the walks fell out of step"
        )
    ref = tmpl.durations(quantities, topology)[0]
    got = np.array([job.duration for job in sim.jobs])
    if not np.array_equal(ref, got):
        bad = int(np.flatnonzero(ref != got)[0])
        raise AssertionError(
            f"scheme template durations diverge from the scalar builder at "
            f"job {bad} ({sim.jobs[bad].name}): {ref[bad]} != {got[bad]}"
        )
    return tmpl


def _scheme_layout_cached(
    net: ConvNetGeom,
    secondaries: tuple[str, ...],
    host: str,
    overlap_rows: int,
    ratios: tuple[float, ...],
    assignment: tuple[str, ...],
    auto_reduce: bool = True,
) -> SchemeLayout | None:
    """Process-wide scheme-layout cache (rates never enter the key), sharing
    the halo layout cache's store and eviction.  False remembers infeasible
    assignments (a halo segment that cannot be realised)."""
    key = ("scheme", net, secondaries, host, overlap_rows, ratios, assignment, auto_reduce)
    hit = _LAYOUTS.get(key)
    if hit is None:
        try:
            hit = scheme_layout(
                net,
                secondaries,
                host=host,
                overlap_rows=overlap_rows,
                ratios=ratios,
                assignment=assignment,
                auto_reduce=auto_reduce,
            )
        except (AssertionError, ValueError):
            hit = False
        _LAYOUTS[key] = hit
        if len(_LAYOUTS) > _LAYOUT_CAPACITY:
            _LAYOUTS.popitem(last=False)
    else:
        _LAYOUTS.move_to_end(key)
    return hit or None


def simulate_scheme(
    net: ConvNetGeom,
    topology: CollabTopology,
    ratios=None,
    overlap_rows: int = 4,
    assignment: Sequence[str] | None = None,
    schemes: Sequence[str] = SCHEMES,
    n_tasks: int = 1,
    auto_reduce: bool = True,
) -> dict:
    """Scalar DES makespan of one mixed-scheme plan (the scheme twin of
    :func:`~repro.core.simulator.simulate_halp`); the batched evaluator is
    pinned float-equal to this path in ``tests/test_conformance.py``."""
    from .simulator import Sim  # runtime import: simulator imports events

    if ratios is None:
        ratios = topology.capacity_ratios()
    slay = scheme_layout(
        net,
        topology.secondaries,
        host=topology.host,
        overlap_rows=overlap_rows,
        ratios=ratios,
        assignment=assignment,
        schemes=schemes,
        auto_reduce=auto_reduce,
    )
    sim = Sim()
    heads = _lay_scheme_dag(sim, slay, n_tasks, topology, lambda t, s: f"{s}^{t}")
    total = sim.run()
    return dict(total=total, sim=sim, layout=slay, heads=tuple(heads))


class SchemeBatchEvaluator:
    """Batched (ratios, overlap, scheme-assignment) candidate pricing.

    The joint-search twin of :class:`HalpBatchEvaluator`: candidates sharing a
    structural signature (same fused segments, same halo sub-signatures) share
    one :class:`DagTemplate` and are priced in one vectorized
    :meth:`Sim.run_batch` sweep.  Scores are float-identical to
    :func:`simulate_scheme`'s scalar path."""

    def __init__(
        self,
        net: ConvNetGeom,
        topology: CollabTopology,
        n_tasks: int = 1,
        auto_reduce: bool = True,
    ):
        self.net = net
        self.topology = topology
        self.n_tasks = n_tasks
        self.auto_reduce = auto_reduce

    def layout_for(self, ratios, overlap_rows: int, assignment) -> SchemeLayout | None:
        return _scheme_layout_cached(
            self.net,
            self.topology.secondaries,
            self.topology.host,
            overlap_rows,
            tuple(ratios),
            tuple(assignment),
            self.auto_reduce,
        )

    def evaluate(self, candidates: Sequence[tuple]) -> list[float]:
        """DES makespans for ``(ratios, overlap_rows, assignment)`` candidates
        (+inf when infeasible), batched by structural signature."""
        scores = [float("inf")] * len(candidates)
        by_sig: dict[tuple, list[tuple[int, SchemeLayout]]] = {}
        for k, (ratios, w, assignment) in enumerate(candidates):
            lay = self.layout_for(ratios, w, assignment)
            if lay is not None:
                by_sig.setdefault(lay.signature, []).append((k, lay))
        for sig, members in by_sig.items():
            key = ("scheme", self.net, self.topology.host, self.n_tasks, sig)
            first = members[0][1]
            tmpl = _template_for(
                key,
                lambda lay=first: _scheme_template(lay, self.n_tasks, self.topology),
            )
            q = np.stack(
                [_scheme_quantities(lay, self.n_tasks) for _k, lay in members]
            )
            run = tmpl.run(q, self.topology)
            for row, (k, _lay) in enumerate(members):
                scores[k] = float(run.makespan[row])
        return scores
